//! END-TO-END DRIVER — the full three-layer stack on a realistic workload.
//!
//! Pipeline (everything after `make artifacts` is pure Rust + PJRT):
//!   1. synthesize a Flickr30k-like multimodal corpus (raw text+image records);
//!   2. embed it through the AOT-compiled CLIP towers (L2/L1 via PJRT);
//!   3. ingest into the serving coordinator (L3);
//!   4. OPDR: calibrate → plan dim(Y) for A=0.9 → reduce the collection;
//!   5. serve a batched query storm at full dim and at reduced dim;
//!   6. report recall@10, latency percentiles and throughput for both.
//!
//! This is the run recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: `make artifacts && cargo run --release --example multimodal_retrieval`

use opdr::config::ServeConfig;
use opdr::coordinator::Coordinator;
use opdr::data::records::generate_records;
use opdr::data::DatasetKind;
use opdr::embed::{embed_records, Encoder, HashEncoder, ModelKind, RuntimeEncoder};
use opdr::metrics::Metric;
use opdr::runtime::Engine;
use opdr::util::Stopwatch;

const CORPUS: usize = 1500;
const QUERIES: usize = 400;
const K: usize = 10;

fn main() -> opdr::Result<()> {
    // --- 1. Raw multimodal corpus ------------------------------------------
    let records = generate_records(DatasetKind::Flickr30k, CORPUS, 2026);
    println!("corpus: {CORPUS} flickr-like image-text records");

    // --- 2. Embed through the AOT towers ------------------------------------
    let engine = Engine::new("artifacts");
    let sw = Stopwatch::start();
    let set = match &engine {
        Ok(eng) => {
            let enc = RuntimeEncoder::new(eng);
            println!("encoder backend: {} (CLIP text+image towers via PJRT)", enc.backend_name());
            embed_records(&enc, ModelKind::Clip, &records, "flickr")?
        }
        Err(e) => {
            println!("encoder backend: hash-fallback (PJRT unavailable: {e})");
            embed_records(&HashEncoder::default(), ModelKind::Clip, &records, "flickr")?
        }
    };
    println!(
        "embedded {} records to {}-dim CLIP vectors in {:.1}s",
        set.len(),
        set.dim(),
        sw.elapsed_secs()
    );

    // --- 3. Ingest into the coordinator -------------------------------------
    let cfg = ServeConfig { workers: 4, max_batch: 32, max_wait_ms: 2, ..Default::default() };
    let coord = Coordinator::start(cfg)?;
    coord.create_collection("flickr", set.dim(), Metric::SqEuclidean)?;
    coord.ingest("flickr", set.data().to_vec())?;

    // Ground truth at full dimension for recall scoring.
    let mut truth = Vec::with_capacity(QUERIES);
    for qi in 0..QUERIES {
        truth.push(opdr::knn::knn_indices(
            set.vector(qi % CORPUS),
            set.data(),
            set.dim(),
            K,
            Metric::SqEuclidean,
        )?);
    }

    // --- 5a. Query storm at FULL dimension -----------------------------------
    let full = storm(&coord, &set, "full-dim")?;

    // --- 4. OPDR reduction ----------------------------------------------------
    let sw = Stopwatch::start();
    let planned = coord.build_reduced("flickr", 0.9, K)?;
    println!(
        "\nOPDR: calibrated + planned dim(Y) = {planned} (from {}) in {:.1}s",
        set.dim(),
        sw.elapsed_secs()
    );

    // --- 5b. Query storm at REDUCED dimension ---------------------------------
    let reduced = storm(&coord, &set, "opdr-reduced")?;

    // --- 6. Report -------------------------------------------------------------
    let recall = |results: &[Vec<usize>]| -> f64 {
        let mut hits = 0usize;
        for (t, got) in truth.iter().zip(results) {
            let gset: std::collections::HashSet<usize> = got.iter().copied().collect();
            hits += t.iter().filter(|n| gset.contains(&n.index)).count();
        }
        hits as f64 / (truth.len() * K) as f64
    };
    println!("\n== end-to-end summary (recall vs full-dim exact KNN) ==");
    println!(
        "full-dim    : recall@{K} = {:.3}  p50 = {}  p99 = {}  throughput = {:.0} qps",
        recall(&full.hits),
        opdr::util::timer::fmt_duration(full.p50),
        opdr::util::timer::fmt_duration(full.p99),
        full.qps
    );
    println!(
        "opdr-reduced: recall@{K} = {:.3}  p50 = {}  p99 = {}  throughput = {:.0} qps",
        recall(&reduced.hits),
        opdr::util::timer::fmt_duration(reduced.p50),
        opdr::util::timer::fmt_duration(reduced.p99),
        reduced.qps
    );
    println!(
        "speedup = {:.2}×  at recall {:.3}",
        reduced.qps / full.qps,
        recall(&reduced.hits)
    );
    println!("\n{}", coord.stats()?);
    coord.shutdown();
    Ok(())
}

struct StormResult {
    hits: Vec<Vec<usize>>,
    p50: std::time::Duration,
    p99: std::time::Duration,
    qps: f64,
}

fn storm(
    coord: &Coordinator,
    set: &opdr::data::EmbeddingSet,
    label: &str,
) -> opdr::Result<StormResult> {
    let sw = Stopwatch::start();
    let mut latencies = Vec::with_capacity(QUERIES);
    let mut hits = Vec::with_capacity(QUERIES);
    // Pipelined submission in windows to exercise the dynamic batcher.
    let window = 64;
    let mut qi = 0;
    while qi < QUERIES {
        let end = (qi + window).min(QUERIES);
        let mut rxs = Vec::with_capacity(end - qi);
        let t0 = Stopwatch::start();
        for i in qi..end {
            rxs.push(coord.search_async("flickr", set.vector(i % CORPUS).to_vec(), K)?);
        }
        for rx in rxs {
            let res = rx
                .recv()
                .map_err(|_| opdr::OpdrError::coordinator("dropped"))??;
            hits.push(res.neighbors.iter().map(|n| n.index).collect::<Vec<usize>>());
        }
        latencies.push(t0.elapsed_ns() / (end - qi) as f64);
        qi = end;
    }
    let secs = sw.elapsed_secs();
    let mut sorted = latencies.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p = |q: f64| {
        std::time::Duration::from_nanos(opdr::util::float::percentile_sorted(&sorted, q) as u64)
    };
    println!(
        "storm [{label}]: {QUERIES} queries in {secs:.2}s ({:.0} qps)",
        QUERIES as f64 / secs
    );
    Ok(StormResult { hits, p50: p(0.5), p99: p(0.99), qps: QUERIES as f64 / secs })
}
