//! Planner tour: calibrate the closed-form fit on each of the paper's seven
//! datasets and print the planned dimensionalities for a range of accuracy
//! targets — the practical artifact of the paper (`f ∘ g` composition).
//!
//! Run: `cargo run --release --example opdr_planner`

use opdr::data::{synth, DatasetKind};
use opdr::metrics::Metric;
use opdr::opdr::Planner;
use opdr::reduction::ReducerKind;
use opdr::report::Table;

fn main() -> opdr::Result<()> {
    let m = 120;
    let dim = 256;
    let k = 5;
    let targets = [0.7, 0.8, 0.9, 0.95];

    let mut table = Table::new(&["dataset", "c0", "c1", "R²", "A=0.7", "A=0.8", "A=0.9", "A=0.95"]);
    for kind in DatasetKind::ALL {
        let set = synth::generate(kind, m, dim, 42);
        let planner =
            Planner::calibrate(set.data(), dim, k, Metric::SqEuclidean, ReducerKind::Pca, 42)?;
        let fit = planner.fit();
        let mut row = vec![
            kind.name().to_string(),
            format!("{:.3}", fit.c0),
            format!("{:.3}", fit.c1),
            format!("{:.3}", fit.r_squared),
        ];
        for &t in &targets {
            row.push(planner.dim_for_accuracy(t, m).min(dim).to_string());
        }
        table.row(&row);
    }
    println!("planned dim(Y) at m={m}, original dim={dim}, k={k}:");
    println!("{}", table.render());
    println!(
        "reading: structured (materials) sets plan far smaller dims than diverse\n\
         web corpora at the same accuracy target — the paper's central practical point."
    );
    Ok(())
}
