//! Quickstart: the OPDR recipe in ~40 lines.
//!
//! 1. Get embeddings (here: a synthetic materials-science set).
//! 2. Sweep accuracy vs n/m and fit the closed form A = c0·ln(n/m) + c1.
//! 3. Invert it: plan dim(Y) for a target accuracy.
//! 4. Reduce with PCA at the planned dim and verify the measured accuracy.
//!
//! Run: `cargo run --release --example quickstart`

use opdr::data::{synth, DatasetKind};
use opdr::metrics::Metric;
use opdr::opdr::{accuracy, fit_log_model, sweep::SweepConfig, Planner};
use opdr::reduction::ReducerKind;

fn main() -> opdr::Result<()> {
    // 1. Embeddings: 120 points, 256-dim (synthetic stand-in for CLIP
    //    vectors of Materials Project records — see DESIGN.md §1).
    let set = synth::generate(DatasetKind::MaterialsObservable, 120, 256, 42);
    println!("dataset: {} ({} vectors, dim {})", set.label(), set.len(), set.dim());

    // 2. Sweep + fit.
    let cfg = SweepConfig {
        k: 5,
        metric: Metric::SqEuclidean,
        reducer: ReducerKind::Pca,
        sample_sizes: vec![30, 60, 90],
        dims_per_m: 10,
        repeats: 2,
        seed: 42,
    };
    let curve = opdr::opdr::accuracy_curve(&set, &cfg)?;
    let fit = fit_log_model(curve.points())?;
    println!(
        "closed form: A_k = {:.4}·ln(n/m) + {:.4}   (R² = {:.3} over {} sweep points)",
        fit.c0, fit.c1, fit.r_squared, fit.n_points
    );

    // 3. Plan dim(Y) for a 0.9 target at m = 90.
    let planner = Planner::from_fit(fit);
    let m = 90;
    let planned = planner.dim_for_accuracy(0.9, m).min(set.dim());
    println!("planned dim(Y) for A=0.9 at m={m}: {planned}");

    // 4. Reduce and verify.
    let subset = set.subset(&(0..m).collect::<Vec<_>>())?;
    let reduced = ReducerKind::Pca.build(0).fit_transform(subset.data(), set.dim(), planned)?;
    let measured = accuracy(subset.data(), set.dim(), &reduced, planned, cfg.k, cfg.metric)?;
    println!("measured accuracy at planned dim: {measured:.3} (target 0.9)");
    println!(
        "dimension reduction: {} → {} ({:.1}× smaller vectors)",
        set.dim(),
        planned,
        set.dim() as f64 / planned as f64
    );
    Ok(())
}
