//! ESC-50 audio–text retrieval: the paper's 2816-dim concatenation path
//! (BERT 768 ⊕ PANNs-CNN14 2048).
//!
//! Exercises the highest-dimensional embedding the paper evaluates, plans a
//! reduced dimension, and scores class-consistency of retrieval before and
//! after OPDR (same-class neighbors are the semantic signal in ESC-50).
//!
//! Run: `make artifacts && cargo run --release --example audio_retrieval`

use opdr::data::records::generate_records;
use opdr::data::DatasetKind;
use opdr::embed::{embed_records, HashEncoder, ModelKind, RuntimeEncoder};
use opdr::metrics::Metric;
use opdr::opdr::Planner;
use opdr::reduction::{Pca, ReducerKind};
use opdr::runtime::Engine;

const CLIPS: usize = 400; // of the 2000 in ESC-50

fn main() -> opdr::Result<()> {
    let records = generate_records(DatasetKind::Esc50, CLIPS, 50);
    println!("ESC-50-like corpus: {CLIPS} audio clips across 50 classes");

    let engine = Engine::new("artifacts");
    let set = match &engine {
        Ok(eng) => {
            println!("embedding with BERT+PANNs towers via PJRT");
            embed_records(&RuntimeEncoder::new(eng), ModelKind::BertPanns, &records, "esc50")?
        }
        Err(e) => {
            println!("embedding with hash fallback ({e})");
            embed_records(&HashEncoder::default(), ModelKind::BertPanns, &records, "esc50")?
        }
    };
    println!("embeddings: {} × {} (BERT 768 ⊕ PANNs 2048)", set.len(), set.dim());

    // Class consistency of full-dim KNN.
    let k = 5;
    let consistency = |data: &[f32], dim: usize| -> opdr::Result<f64> {
        let sets = opdr::knn::knn_indices_all(data, dim, k, Metric::Cosine)?;
        let mut same = 0usize;
        let mut total = 0usize;
        for (i, nb) in sets.iter().enumerate() {
            for &j in nb {
                total += 1;
                if records[i].class == records[j].class {
                    same += 1;
                }
            }
        }
        Ok(same as f64 / total as f64)
    };
    let full_consistency = consistency(set.data(), set.dim())?;
    println!("full-dim  ({}): same-class fraction of {k}-NN = {full_consistency:.3}", set.dim());

    // OPDR plan + reduce.
    let planner =
        Planner::calibrate(set.data(), set.dim(), k, Metric::Cosine, ReducerKind::Pca, 7)?;
    let fit = planner.fit();
    println!(
        "calibrated closed form: A = {:.3}·ln(n/m) + {:.3} (R² = {:.3})",
        fit.c0, fit.c1, fit.r_squared
    );
    let planned = planner.dim_for_accuracy(0.9, set.len()).min(set.dim());
    let model = Pca::new().fit(set.data(), set.dim(), planned)?;
    let reduced = model.project(set.data())?;
    let red_consistency = consistency(&reduced, planned)?;
    println!("opdr-reduced ({planned}): same-class fraction of {k}-NN = {red_consistency:.3}");

    let order_acc = opdr::opdr::accuracy(
        set.data(),
        set.dim(),
        &reduced,
        planned,
        k,
        Metric::Cosine,
    )?;
    println!(
        "order-preserving accuracy A_{k} = {order_acc:.3} at {:.1}× compression ({} → {planned})",
        set.dim() as f64 / planned as f64,
        set.dim()
    );
    assert!(
        red_consistency > full_consistency - 0.1,
        "reduction destroyed semantic neighborhoods"
    );
    Ok(())
}
