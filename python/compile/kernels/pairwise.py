"""Layer-1 Pallas kernel: tiled fused pairwise distances.

The query hot spot of the whole system: distances between a query batch
[Q, D] and the base set [N, D]. TPU-minded design (see DESIGN.md
§Hardware-Adaptation):

* the N dimension streams through the grid in `BN`-row tiles, so HBM→VMEM
  traffic is O(Q·D + N·D) instead of O(Q·N·D);
* squared-Euclidean uses the MXU-friendly expansion ‖q‖² − 2·q·bᵀ + ‖b‖²
  (one [BQ,D]×[D,BN] matmul per tile — systolic-array work, not lane-wise
  subtraction);
* cosine reuses the same matmul with norm corrections;
* Manhattan has no matmul form: it broadcasts in-register over the tile,
  which bounds the tile choice (BQ·BN·D elements live in VMEM).

VMEM budget per grid cell at the default artifact shape (Q=32, N=1024,
D=1024, BQ=32, BN=256, f32):
  q tile 32·1024·4 = 128 KiB, b tile 256·1024·4 = 1 MiB, out 32 KiB
  → ≈1.2 MiB ≪ 16 MiB VMEM; manhattan broadcast adds 32·256·1024·4 = 32 MiB
  which is why manhattan uses BN=64 (8 MiB) instead.

All kernels run with `interpret=True` (the CPU PJRT plugin cannot execute
Mosaic custom-calls); structure, not interpret-mode wallclock, is what the
perf pass optimizes at L1.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes (see VMEM budget above).
BQ = 32
BN = 256
BN_MANHATTAN = 64


def _sqeuclidean_kernel(q_ref, b_ref, o_ref):
    """One (BQ, BN) output tile of squared-Euclidean distances."""
    q = q_ref[...]                                   # [BQ, D]
    b = b_ref[...]                                   # [BN, D]
    qn = jnp.sum(q * q, axis=1, keepdims=True)       # [BQ, 1]
    bn = jnp.sum(b * b, axis=1, keepdims=True).T     # [1, BN]
    # MXU work: [BQ, D] @ [D, BN].
    qb = jax.lax.dot_general(
        q, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] = jnp.maximum(qn - 2.0 * qb + bn, 0.0)


def _cosine_kernel(q_ref, b_ref, o_ref):
    """One (BQ, BN) tile of cosine distances."""
    q = q_ref[...]
    b = b_ref[...]
    qn = jnp.sqrt(jnp.sum(q * q, axis=1, keepdims=True))
    bn = jnp.sqrt(jnp.sum(b * b, axis=1, keepdims=True)).T
    dot = jax.lax.dot_general(
        q, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    denom = qn * bn
    cos = jnp.where(denom > 1e-12, dot / jnp.maximum(denom, 1e-12), 0.0)
    o_ref[...] = 1.0 - cos


def _manhattan_kernel(q_ref, b_ref, o_ref):
    """One (BQ, BN) tile of L1 distances (broadcast, no matmul form)."""
    q = q_ref[...]                                   # [BQ, D]
    b = b_ref[...]                                   # [BN, D]
    o_ref[...] = jnp.sum(jnp.abs(q[:, None, :] - b[None, :, :]), axis=-1)


_KERNELS = {
    "sqeuclidean": (_sqeuclidean_kernel, BN),
    "cosine": (_cosine_kernel, BN),
    "manhattan": (_manhattan_kernel, BN_MANHATTAN),
}


@functools.partial(jax.jit, static_argnames=("metric",))
def pairwise_distances(q, b, metric="sqeuclidean"):
    """Tiled pairwise distance matrix via pallas_call.

    q: [Q, D], b: [N, D] → [Q, N]. Q must be a multiple of BQ (or smaller
    than BQ, in which case a single row-tile is used); N must be a multiple
    of the metric's BN (or smaller).
    """
    kernel, bn = _KERNELS[metric]
    q_rows, d = q.shape
    n_rows, d2 = b.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    bq = min(BQ, q_rows)
    bn = min(bn, n_rows)
    assert q_rows % bq == 0, f"Q={q_rows} not a multiple of {bq}"
    assert n_rows % bn == 0, f"N={n_rows} not a multiple of {bn}"

    grid = (q_rows // bq, n_rows // bn)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # Query tile: advance with grid axis 0, full D.
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            # Base tile: advance with grid axis 1 — streams N through VMEM.
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q_rows, n_rows), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q.astype(jnp.float32), b.astype(jnp.float32))
