"""Layer-1 Pallas kernel: Gram accumulation XᵀX.

The PCA-fit hot spot: the `covariance` artifact centers columns in the L2
graph and calls this kernel for the [M, D] → [D, D] accumulation. Tiling is
the transpose-shaped variant of `projection`: grid over (D/BD, D/BD) output
tiles with the full M contraction per cell. Working set at M=128, BD=128:
two 128·128·4 input tiles + one output tile ≈ 200 KiB.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BD = 128


def _gram_kernel(xi_ref, xj_ref, o_ref):
    """One (BD, BD) tile of XᵀX: xiᵀ @ xj over the full M axis."""
    o_ref[...] = jax.lax.dot_general(
        xi_ref[...], xj_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@jax.jit
def gram(x):
    """Tiled XᵀX via pallas_call. x: [M, D] → [D, D]."""
    m, d = x.shape
    bd = min(BD, d)
    assert d % bd == 0, f"D={d} not a multiple of {bd}"
    grid = (d // bd, d // bd)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            # Column block i (full M rows).
            pl.BlockSpec((m, bd), lambda i, j: (0, i)),
            # Column block j.
            pl.BlockSpec((m, bd), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bd, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        interpret=True,
        # x is passed twice so each grid cell can stream two independent
        # column blocks (i and j) through VMEM.
    )(x.astype(jnp.float32), x.astype(jnp.float32))
