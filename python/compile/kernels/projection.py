"""Layer-1 Pallas kernel: tiled dense projection (X @ W).

Used twice in the stack:
* the `pca_project` artifact (projecting full-dim embeddings into the OPDR
  space with the fitted PCA components);
* the output projection of every encoder tower in `model.py`.

Tiling: grid over (M/BM, N/BN) output tiles with the full K-contraction held
in VMEM per cell — K ≤ 2048 in all our shapes, so a (BM,K)+(K,BN)+(BM,BN)
working set at BM=BN=128, K=2048 is 128·2048·4 + 2048·128·4 + 128·128·4
≈ 2.1 MiB ≪ 16 MiB VMEM. On a real MXU this is one 128×128-tile systolic
pass per K-step; `preferred_element_type=f32` keeps the accumulator in f32
as bf16 inputs would on TPU.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 128
BN = 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (BM, BN) output tile: full-K contraction."""
    o_ref[...] = jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@jax.jit
def project(x, w):
    """Tiled x @ w via pallas_call. x: [M, K], w: [K, N] → [M, N]."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = min(BM, m)
    bn = min(BN, n)
    assert m % bm == 0, f"M={m} not a multiple of {bm}"
    assert n % bn == 0, f"N={n} not a multiple of {bn}"

    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), w.astype(jnp.float32))
