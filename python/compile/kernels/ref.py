"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has an exact jnp counterpart here; pytest
(`python/tests/test_kernels.py`) asserts allclose between the two across a
hypothesis sweep of shapes. These references are also what the Rust unit
tests mirror (`rust/src/metrics/pairwise.rs` uses the same matmul expansion),
so all three layers are comparable term-for-term.
"""

import jax.numpy as jnp


def pairwise_sqeuclidean(q, b):
    """Squared-Euclidean distance matrix via the matmul expansion.

    d²(x, y) = ‖x‖² − 2·x·y + ‖y‖², floored at 0 against cancellation.
    q: [Q, D], b: [N, D] → [Q, N].
    """
    qn = jnp.sum(q * q, axis=1, keepdims=True)          # [Q, 1]
    bn = jnp.sum(b * b, axis=1, keepdims=True).T        # [1, N]
    d = qn - 2.0 * (q @ b.T) + bn
    return jnp.maximum(d, 0.0)


def pairwise_cosine(q, b, eps=1e-12):
    """Cosine distance 1 − cos(q, b); zero vectors → distance 1."""
    qn = jnp.sqrt(jnp.sum(q * q, axis=1, keepdims=True))
    bn = jnp.sqrt(jnp.sum(b * b, axis=1, keepdims=True)).T
    dot = q @ b.T
    denom = qn * bn
    cos = jnp.where(denom > eps, dot / jnp.maximum(denom, eps), 0.0)
    return 1.0 - cos


def pairwise_manhattan(q, b):
    """L1 distance matrix. q: [Q, D], b: [N, D] → [Q, N]."""
    return jnp.sum(jnp.abs(q[:, None, :] - b[None, :, :]), axis=-1)


def projection(x, w):
    """Dense projection x @ w. x: [M, D], w: [D, N] → [M, N]."""
    return x @ w


def covariance(x):
    """Gram accumulation XᵀX. x: [M, D] → [D, D].

    (Column-centering and the 1/(m−1) scale happen on the Rust side /
    in the model graph; the kernel is the raw accumulation hot spot.)
    """
    return x.T @ x
