"""Layer-2 JAX graphs: encoder towers and retrieval compute.

The paper extracts embeddings with CLIP / ViT / BERT / PANNs. Pretrained
checkpoints are unavailable offline, so each tower here is a *deterministic,
fixed-seed* transformer encoder with the real model's output dimensionality
(CLIP text/image 512 each, BERT/ViT 768, PANNs 2048). The OPDR experiments
consume embedding geometry, not semantics — different towers produce
differently-shaped geometry, which is exactly what Figs 7–9 compare (see
DESIGN.md §1 for the substitution argument).

Every tower's output projection routes through the Layer-1 Pallas projection
kernel, and the retrieval graphs (`pairwise_topk_*`, `pca_project`,
`covariance`) are built directly on the Layer-1 kernels, so the AOT artifacts
exercise the full three-layer composition.

All graphs are shaped for the AOT manifest (see `aot.py`); the Rust runtime
zero-pads variable-size inputs to these fixed shapes.
"""

import functools

import jax
import jax.numpy as jnp

from compile.kernels import covariance as cov_kernel
from compile.kernels import pairwise as pairwise_kernel
from compile.kernels import projection as proj_kernel

# ---------------------------------------------------------------------------
# Fixed input geometry (must match rust/src/data/records.rs).
# ---------------------------------------------------------------------------
TEXT_TOKENS, TEXT_FEAT = 32, 64
IMAGE_PATCHES, IMAGE_FEAT = 64, 64
AUDIO_MELS, AUDIO_FRAMES = 64, 32
ENCODER_BATCH = 8

# Retrieval graph geometry (must match the manifest / rust runtime).
TOPK_Q = 32        # query batch capacity
TOPK_N = 1024      # base-set capacity
TOPK_D = 1024      # padded dimension capacity
TOPK_K = 64        # top-k capacity
PROJ_B = 64        # projection batch capacity
COV_M, COV_D = 128, 512

D_MODEL = 128
N_HEADS = 4
N_LAYERS = 2


# ---------------------------------------------------------------------------
# Deterministic parameter construction.
# ---------------------------------------------------------------------------
def _tower_params(seed, in_feat, out_dim):
    """Fixed-seed transformer parameters; seed is model-specific so BERT,
    ViT and the CLIP towers have independent geometries."""
    key = jax.random.PRNGKey(seed)
    keys = iter(jax.random.split(key, 6 + 8 * N_LAYERS))

    def dense(k, shape, scale=None):
        scale = scale if scale is not None else (1.0 / shape[0]) ** 0.5
        return jax.random.normal(k, shape, jnp.float32) * scale

    params = {
        "embed": dense(next(keys), (in_feat, D_MODEL)),
        "pos": dense(next(keys), (512, D_MODEL), scale=0.02),
        "out": dense(next(keys), (D_MODEL, out_dim)),
        "layers": [],
    }
    for _ in range(N_LAYERS):
        params["layers"].append(
            {
                "wq": dense(next(keys), (D_MODEL, D_MODEL)),
                "wk": dense(next(keys), (D_MODEL, D_MODEL)),
                "wv": dense(next(keys), (D_MODEL, D_MODEL)),
                "wo": dense(next(keys), (D_MODEL, D_MODEL)),
                "w1": dense(next(keys), (D_MODEL, 4 * D_MODEL)),
                "w2": dense(next(keys), (4 * D_MODEL, D_MODEL)),
                # Two spare keys burned to keep the layout stable if gains
                # are added later.
                "g1": jnp.ones((D_MODEL,), jnp.float32) + 0.0 * dense(next(keys), (D_MODEL,), scale=0.0),
                "g2": jnp.ones((D_MODEL,), jnp.float32) + 0.0 * dense(next(keys), (D_MODEL,), scale=0.0),
            }
        )
    return params


def _layer_norm(x, gain):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return gain * (x - mean) / jnp.sqrt(var + 1e-6)


def _attention(x, layer):
    """Multi-head self-attention. x: [B, T, D_MODEL]."""
    b, t, d = x.shape
    hd = d // N_HEADS

    def split(y):
        return y.reshape(b, t, N_HEADS, hd).transpose(0, 2, 1, 3)  # [B,H,T,hd]

    q, k, v = split(x @ layer["wq"]), split(x @ layer["wk"]), split(x @ layer["wv"])
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / (hd**0.5)
    attn = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", attn, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, d)
    return out @ layer["wo"]


def _encoder_tower(feats, params):
    """feats: [B, T, F] → [B, out_dim] embedding.

    Transformer encode → mean-pool → Pallas projection kernel.
    """
    b, t, _ = feats.shape
    x = feats @ params["embed"] + params["pos"][:t][None, :, :]
    for layer in params["layers"]:
        x = x + _attention(_layer_norm(x, layer["g1"]), layer)
        h = _layer_norm(x, layer["g2"])
        x = x + jax.nn.gelu(h @ layer["w1"]) @ layer["w2"]
    pooled = jnp.mean(x, axis=1)  # [B, D_MODEL]
    # Layer-1 kernel does the output projection. PROJ kernel wants M % BM == 0
    # or M < BM; ENCODER_BATCH=8 < 128 so a single row-tile is used.
    return proj_kernel.project(pooled, params["out"])


# Model registry: (seed, tokens, feat, out_dim). Seeds are arbitrary but
# fixed — they ARE the "pretrained weights" of this reproduction.
TOWERS = {
    "clip_text": (101, TEXT_TOKENS, TEXT_FEAT, 512),
    "clip_image": (102, IMAGE_PATCHES, IMAGE_FEAT, 512),
    "bert": (103, TEXT_TOKENS, TEXT_FEAT, 768),
    "vit": (104, IMAGE_PATCHES, IMAGE_FEAT, 768),
    "panns": (105, AUDIO_MELS, AUDIO_FRAMES, 2048),
}


@functools.lru_cache(maxsize=None)
def tower_fn(name):
    """A jit-able `[B, T*F] → [B, out]` function with baked-in parameters."""
    seed, tokens, feat, out_dim = TOWERS[name]
    params = _tower_params(seed, feat, out_dim)

    def fn(flat_feats):
        feats = flat_feats.reshape(flat_feats.shape[0], tokens, feat)
        return (_encoder_tower(feats, params),)

    return fn


# ---------------------------------------------------------------------------
# Retrieval graphs.
# ---------------------------------------------------------------------------
def pairwise_topk_fn(metric):
    """Graph: (queries [Q,D], base [N,D], pad_mask [N]) →
    (top-k distances [Q,K], top-k indices-as-f32 [Q,K]).

    `pad_mask` is 1.0 on padding rows of the base set; their distances are
    inflated so padded rows never enter the top-k. Indices are cast to f32 —
    the runtime interchange is f32-only.
    """

    def fn(queries, base, pad_mask):
        dists = pairwise_kernel.pairwise_distances(queries, base, metric=metric)
        dists = dists + pad_mask[None, :] * jnp.float32(1e30)
        # NOTE: lax.top_k lowers to the `topk(..., largest=true)` HLO op,
        # which the crate's XLA 0.5.1 text parser rejects; a full `sort`
        # (supported since antiquity) + slice is the portable spelling.
        iota = jax.lax.broadcasted_iota(jnp.int32, dists.shape, 1)
        sorted_d, sorted_i = jax.lax.sort((dists, iota), dimension=1, num_keys=1)
        return (
            jax.lax.slice_in_dim(sorted_d, 0, TOPK_K, axis=1),
            jax.lax.slice_in_dim(sorted_i, 0, TOPK_K, axis=1).astype(jnp.float32),
        )

    return fn


def pca_project_fn(x, w):
    """Graph: project a padded batch through padded PCA components.

    x: [PROJ_B, TOPK_D] (rows beyond the live batch zero),
    w: [TOPK_D, TOPK_D] (columns beyond the target dim zero) → [PROJ_B, TOPK_D].
    """
    return (proj_kernel.project(x, w),)


def covariance_fn(x):
    """Graph: column-center then Gram-accumulate. x: [COV_M, COV_D] → [COV_D, COV_D].

    Matches `rust/src/linalg/ops.rs::covariance_matrix` up to the 1/(m−1)
    scale, which the caller applies (padding rows must be excluded there too).
    """
    centered = x - jnp.mean(x, axis=0, keepdims=True)
    return (cov_kernel.gram(centered),)
