"""Layer-2 graph checks: tower shapes/determinism, retrieval graph semantics."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def feats(seed, tokens, feat):
    return jax.random.normal(
        jax.random.PRNGKey(seed), (model.ENCODER_BATCH, tokens * feat), jnp.float32
    )


def test_tower_shapes_and_determinism():
    for name, (_, tokens, feat, out_dim) in model.TOWERS.items():
        fn = model.tower_fn(name)
        x = feats(1, tokens, feat)
        (out1,) = fn(x)
        (out2,) = fn(x)
        assert out1.shape == (model.ENCODER_BATCH, out_dim), name
        np.testing.assert_array_equal(out1, out2)
        assert jnp.isfinite(out1).all(), name


def test_towers_differ_from_each_other():
    x = feats(2, model.TEXT_TOKENS, model.TEXT_FEAT)
    (bert,) = model.tower_fn("bert")(x)
    (clip_t,) = model.tower_fn("clip_text")(x)
    # Different output dims already; compare energy distribution of the first
    # 512 dims to be thorough.
    assert not np.allclose(np.asarray(bert)[:, :512], np.asarray(clip_t))


def test_tower_is_input_sensitive():
    fn = model.tower_fn("bert")
    (a,) = fn(feats(3, model.TEXT_TOKENS, model.TEXT_FEAT))
    (b,) = fn(feats(4, model.TEXT_TOKENS, model.TEXT_FEAT))
    assert not np.allclose(np.asarray(a), np.asarray(b))


def test_pairwise_topk_graph_masks_padding():
    fn = model.pairwise_topk_fn("sqeuclidean")
    q = jax.random.normal(jax.random.PRNGKey(5), (model.TOPK_Q, model.TOPK_D), jnp.float32)
    base = jax.random.normal(jax.random.PRNGKey(6), (model.TOPK_N, model.TOPK_D), jnp.float32)
    # Mark the last half of the base set as padding.
    live = model.TOPK_N // 2
    mask = jnp.concatenate([jnp.zeros((live,)), jnp.ones((model.TOPK_N - live,))]).astype(jnp.float32)
    dists, idx = fn(q, base, mask)
    assert dists.shape == (model.TOPK_Q, model.TOPK_K)
    assert idx.shape == (model.TOPK_Q, model.TOPK_K)
    # No padded index may appear.
    assert (idx < live).all(), "padded rows leaked into top-k"
    # Distances ascending per row.
    d = np.asarray(dists)
    assert (np.diff(d, axis=1) >= -1e-4).all()


def test_pairwise_topk_graph_exact_against_ref():
    fn = model.pairwise_topk_fn("sqeuclidean")
    q = jax.random.normal(jax.random.PRNGKey(7), (model.TOPK_Q, model.TOPK_D), jnp.float32)
    base = jax.random.normal(jax.random.PRNGKey(8), (model.TOPK_N, model.TOPK_D), jnp.float32)
    mask = jnp.zeros((model.TOPK_N,), jnp.float32)
    dists, idx = fn(q, base, mask)
    full = np.asarray(ref.pairwise_sqeuclidean(q, base))
    for row in range(0, model.TOPK_Q, 7):
        want_idx = np.argsort(full[row], kind="stable")[: model.TOPK_K]
        got_idx = np.asarray(idx[row], dtype=np.int64)
        # Compare as sets (ties may reorder) and distances as sorted arrays.
        assert set(got_idx.tolist()) == set(want_idx.tolist())
        np.testing.assert_allclose(
            np.sort(np.asarray(dists[row])), np.sort(full[row][want_idx]), rtol=1e-3, atol=1e-3
        )


def test_pca_project_graph_matches_ref():
    x = jax.random.normal(jax.random.PRNGKey(9), (model.PROJ_B, model.TOPK_D), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(10), (model.TOPK_D, model.TOPK_D), jnp.float32)
    (out,) = model.pca_project_fn(x, w)
    np.testing.assert_allclose(out, ref.projection(x, w), rtol=1e-3, atol=1e-2)


def test_covariance_graph_centers_before_gram():
    x = jax.random.normal(jax.random.PRNGKey(11), (model.COV_M, model.COV_D), jnp.float32) + 5.0
    (out,) = model.covariance_fn(x)
    xc = x - x.mean(axis=0, keepdims=True)
    np.testing.assert_allclose(out, ref.covariance(xc), rtol=1e-3, atol=1e-2)
