"""Kernel-vs-reference correctness: the core L1 signal.

Each Pallas kernel (interpret=True) is checked against its pure-jnp oracle in
`compile.kernels.ref` — first on fixed shapes matching the AOT artifacts,
then across a hypothesis sweep of shapes/values.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import covariance, pairwise, projection, ref

jax.config.update("jax_platform_name", "cpu")

METRICS = ["sqeuclidean", "cosine", "manhattan"]
REFS = {
    "sqeuclidean": ref.pairwise_sqeuclidean,
    "cosine": ref.pairwise_cosine,
    "manhattan": ref.pairwise_manhattan,
}


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# Fixed artifact-shaped checks.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("metric", METRICS)
def test_pairwise_artifact_shape(metric):
    q = rand(0, 32, 256)
    b = rand(1, 512, 256)
    got = pairwise.pairwise_distances(q, b, metric=metric)
    want = REFS[metric](q, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_projection_artifact_shape():
    x = rand(2, 128, 512)
    w = rand(3, 512, 256)
    np.testing.assert_allclose(
        projection.project(x, w), ref.projection(x, w), rtol=1e-4, atol=1e-4
    )


def test_covariance_artifact_shape():
    x = rand(4, 128, 256)
    np.testing.assert_allclose(covariance.gram(x), ref.covariance(x), rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Metric properties.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("metric", METRICS)
def test_self_distance_zero(metric):
    x = rand(5, 32, 64)
    d = pairwise.pairwise_distances(x, x, metric=metric)
    np.testing.assert_allclose(jnp.diagonal(d), 0.0, atol=1e-3)


def test_sqeuclidean_nonnegative_under_cancellation():
    base = rand(6, 32, 128)
    near = base.at[:, 0].add(1e-6)
    d = pairwise.pairwise_distances(base, near, metric="sqeuclidean")
    assert (d >= 0.0).all()


def test_cosine_zero_vector_distance_one():
    q = jnp.zeros((32, 64), jnp.float32)
    b = rand(7, 64, 64)
    d = pairwise.pairwise_distances(q, b, metric="cosine")
    np.testing.assert_allclose(d, 1.0, atol=1e-5)


@pytest.mark.parametrize("metric", METRICS)
def test_zero_padding_invariance(metric):
    """The runtime pads dims with zeros; distances must be unchanged."""
    q = rand(8, 32, 64)
    b = rand(9, 64, 64)
    qp = jnp.pad(q, ((0, 0), (0, 64)))
    bp = jnp.pad(b, ((0, 0), (0, 64)))
    d0 = pairwise.pairwise_distances(q, b, metric=metric)
    d1 = pairwise.pairwise_distances(qp, bp, metric=metric)
    np.testing.assert_allclose(d0, d1, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Hypothesis sweeps: shapes and value scales.
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(
    q_tiles=st.integers(1, 3),
    n_tiles=st.integers(1, 3),
    d=st.sampled_from([8, 64, 160]),
    scale=st.sampled_from([1e-2, 1.0, 1e2]),
    metric=st.sampled_from(METRICS),
    seed=st.integers(0, 2**31 - 1),
)
def test_pairwise_matches_ref_across_shapes(q_tiles, n_tiles, d, scale, metric, seed):
    key = jax.random.PRNGKey(seed)
    kq, kb = jax.random.split(key)
    q = jax.random.normal(kq, (q_tiles * 32, d), jnp.float32) * scale
    b = jax.random.normal(kb, (n_tiles * 64, d), jnp.float32) * scale
    got = pairwise.pairwise_distances(q, b, metric=metric)
    want = REFS[metric](q, b)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3 * max(1.0, scale**2))


@settings(max_examples=15, deadline=None)
@given(
    m_tiles=st.integers(1, 2),
    k=st.sampled_from([16, 128, 384]),
    n_tiles=st.integers(1, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_projection_matches_ref_across_shapes(m_tiles, k, n_tiles, seed):
    key = jax.random.PRNGKey(seed)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (m_tiles * 128, k), jnp.float32)
    w = jax.random.normal(kw, (k, n_tiles * 128), jnp.float32)
    np.testing.assert_allclose(
        projection.project(x, w), ref.projection(x, w), rtol=1e-3, atol=1e-3
    )


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([8, 64, 200]),
    d_tiles=st.integers(1, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_covariance_matches_ref_across_shapes(m, d_tiles, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, d_tiles * 128), jnp.float32)
    np.testing.assert_allclose(covariance.gram(x), ref.covariance(x), rtol=2e-3, atol=2e-3)


def test_bfloat16_inputs_accumulate_in_f32():
    """MXU-style bf16 inputs: kernel must accept and accumulate in f32."""
    q = rand(10, 32, 128).astype(jnp.bfloat16)
    b = rand(11, 64, 128).astype(jnp.bfloat16)
    got = pairwise.pairwise_distances(q, b, metric="sqeuclidean")
    assert got.dtype == jnp.float32
    want = ref.pairwise_sqeuclidean(q.astype(jnp.float32), b.astype(jnp.float32))
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-1)
