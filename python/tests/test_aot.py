"""AOT contract tests: HLO-text interchange invariants the Rust side relies on.

These lower *small* graphs only (full artifact lowering is exercised by
`make artifacts`); what matters here is the format contract:
  * large constants must be materialized in the text (xla_extension 0.5.1
    parses the text back — elided constants silently become garbage weights);
  * no `topk` HLO op (0.5.1's parser predates it; we spell it sort+slice);
  * the manifest spec strings match the artifact plan shapes.
"""

import jax
import jax.numpy as jnp

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def test_large_constants_are_printed():
    const = jax.random.normal(jax.random.PRNGKey(0), (64, 32), jnp.float32)

    def fn(x):
        return (x @ const,)

    text = aot.to_hlo_text(fn, aot.spec(4, 64))
    # The 2048-element weight matrix must appear as a materialized literal,
    # not an elided "constant(...)" placeholder.
    assert "..." not in text or "constant({" in text
    # Heuristic: the text must be large enough to actually contain 2048 floats.
    assert len(text) > 2048 * 4, f"suspiciously small HLO text ({len(text)} bytes)"


def test_no_topk_op_in_retrieval_graphs():
    fn = model.pairwise_topk_fn("sqeuclidean")
    text = aot.to_hlo_text(
        fn,
        aot.spec(model.TOPK_Q, model.TOPK_D),
        aot.spec(model.TOPK_N, model.TOPK_D),
        aot.spec(model.TOPK_N),
    )
    assert " topk(" not in text, "topk op present — xla_extension 0.5.1 cannot parse it"
    assert "sort(" in text, "expected the sort+slice spelling"


def test_artifact_plan_shapes_consistent():
    plan = aot.artifact_plan()
    names = [p[0] for p in plan]
    assert len(names) == len(set(names)), "duplicate artifact names"
    expected = {
        "clip_text", "clip_image", "bert", "vit", "panns",
        "pairwise_topk_sqeuclidean", "pairwise_topk_cosine",
        "pairwise_topk_manhattan", "pca_project", "covariance",
    }
    assert set(names) == expected
    for name, _fn, specs, out_dims in plan:
        for s in specs:
            assert all(d > 0 for d in s.shape), f"{name}: bad input shape {s.shape}"
        for d in out_dims:
            assert all(x > 0 for x in d), f"{name}: bad output shape {d}"


def test_fmt_shape_spec_strings():
    assert aot.fmt_shape([32, 1024]) == "f32:32x1024"
    assert aot.fmt_shape([]) == "f32:scalar"
