//! Minimal TOML-subset parser.
//!
//! Supports: `[table]` and `[nested.table]` headers, `key = value` pairs with
//! string / integer / float / boolean / array values, `#` comments and blank
//! lines. Unsupported TOML (multi-line strings, inline tables, dates, arrays
//! of tables) is rejected with a line-numbered error — configs in this repo
//! stay inside the subset on purpose.

use crate::error::{OpdrError, Result};
use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    /// UTF-8 string.
    Str(String),
    /// 64-bit integer.
    Int(i64),
    /// 64-bit float.
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// Homogeneous-enough array of values.
    Array(Vec<TomlValue>),
    /// Nested table.
    Table(BTreeMap<String, TomlValue>),
}

impl TomlValue {
    /// Get `self` as a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    /// Get `self` as an integer.
    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// Get `self` as a float (ints coerce).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    /// Get `self` as a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// Get `self` as an array slice.
    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(a) => Some(a),
            _ => None,
        }
    }
    /// Get `self` as a table.
    pub fn as_table(&self) -> Option<&BTreeMap<String, TomlValue>> {
        match self {
            TomlValue::Table(t) => Some(t),
            _ => None,
        }
    }
    /// Dotted-path lookup ("serve.batch.max_wait_ms").
    pub fn get_path(&self, path: &str) -> Option<&TomlValue> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.as_table()?.get(part)?;
        }
        Some(cur)
    }
}

/// Parse a TOML-subset document into a root table.
pub fn parse_toml(src: &str) -> Result<TomlValue> {
    let mut root: BTreeMap<String, TomlValue> = BTreeMap::new();
    let mut current_path: Vec<String> = Vec::new();

    for (lineno, raw) in src.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') || line.starts_with("[[") {
                return Err(err(lineno, "malformed table header"));
            }
            let inner = &line[1..line.len() - 1];
            if inner.is_empty() {
                return Err(err(lineno, "empty table header"));
            }
            current_path = inner.split('.').map(|s| s.trim().to_string()).collect();
            if current_path.iter().any(|s| s.is_empty()) {
                return Err(err(lineno, "empty table path segment"));
            }
            // Materialize the table.
            ensure_table(&mut root, &current_path, lineno)?;
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| err(lineno, "expected `key = value`"))?;
        let key = line[..eq].trim().to_string();
        if key.is_empty() {
            return Err(err(lineno, "empty key"));
        }
        let value = parse_value(line[eq + 1..].trim(), lineno)?;
        let table = ensure_table(&mut root, &current_path, lineno)?;
        if table.insert(key.clone(), value).is_some() {
            return Err(err(lineno, &format!("duplicate key `{key}`")));
        }
    }
    Ok(TomlValue::Table(root))
}

fn err(lineno: usize, msg: &str) -> OpdrError {
    OpdrError::config(format!("line {}: {msg}", lineno + 1))
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn ensure_table<'a>(
    root: &'a mut BTreeMap<String, TomlValue>,
    path: &[String],
    lineno: usize,
) -> Result<&'a mut BTreeMap<String, TomlValue>> {
    let mut cur = root;
    for part in path {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| TomlValue::Table(BTreeMap::new()));
        cur = match entry {
            TomlValue::Table(t) => t,
            _ => return Err(err(lineno, &format!("`{part}` is not a table"))),
        };
    }
    Ok(cur)
}

fn parse_value(s: &str, lineno: usize) -> Result<TomlValue> {
    if s.is_empty() {
        return Err(err(lineno, "empty value"));
    }
    if s.starts_with('"') {
        if s.len() < 2 || !s.ends_with('"') {
            return Err(err(lineno, "unterminated string"));
        }
        let inner = &s[1..s.len() - 1];
        if inner.contains('"') {
            return Err(err(lineno, "escaped quotes unsupported"));
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            return Err(err(lineno, "unterminated array"));
        }
        let inner = s[1..s.len() - 1].trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(vec![]));
        }
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            items.push(parse_value(part.trim(), lineno)?);
        }
        return Ok(TomlValue::Array(items));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(err(lineno, &format!("unrecognized value `{s}`")))
}

/// Split an array body on commas at bracket depth zero.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if depth == 0 && !in_str => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_tables() {
        let doc = r#"
# top comment
name = "opdr"
threads = 8
ratio = 0.5
debug = true

[serve]
port = 8080

[serve.batch]
max_wait_ms = 5
"#;
        let v = parse_toml(doc).unwrap();
        assert_eq!(v.get_path("name").unwrap().as_str(), Some("opdr"));
        assert_eq!(v.get_path("threads").unwrap().as_int(), Some(8));
        assert_eq!(v.get_path("ratio").unwrap().as_float(), Some(0.5));
        assert_eq!(v.get_path("debug").unwrap().as_bool(), Some(true));
        assert_eq!(v.get_path("serve.port").unwrap().as_int(), Some(8080));
        assert_eq!(v.get_path("serve.batch.max_wait_ms").unwrap().as_int(), Some(5));
    }

    #[test]
    fn parses_arrays() {
        let v = parse_toml("ms = [10, 20, 30]\nnames = [\"a\", \"b\"]\nnested = [[1,2],[3]]").unwrap();
        let ms = v.get_path("ms").unwrap().as_array().unwrap();
        assert_eq!(ms.len(), 3);
        assert_eq!(ms[2].as_int(), Some(30));
        let names = v.get_path("names").unwrap().as_array().unwrap();
        assert_eq!(names[1].as_str(), Some("b"));
        let nested = v.get_path("nested").unwrap().as_array().unwrap();
        assert_eq!(nested[0].as_array().unwrap().len(), 2);
    }

    #[test]
    fn int_underscores_and_floats() {
        let v = parse_toml("big = 1_000_000\nf = 1e-3").unwrap();
        assert_eq!(v.get_path("big").unwrap().as_int(), Some(1_000_000));
        assert_eq!(v.get_path("f").unwrap().as_float(), Some(1e-3));
    }

    #[test]
    fn comment_inside_string_kept() {
        let v = parse_toml("s = \"a # b\"  # real comment").unwrap();
        assert_eq!(v.get_path("s").unwrap().as_str(), Some("a # b"));
    }

    #[test]
    fn errors_are_line_numbered() {
        let e = parse_toml("ok = 1\nbroken").unwrap_err();
        assert!(e.to_string().contains("line 2"), "{e}");
    }

    #[test]
    fn rejects_duplicates_and_bad_headers() {
        assert!(parse_toml("a = 1\na = 2").is_err());
        assert!(parse_toml("[[arr]]").is_err());
        assert!(parse_toml("[]").is_err());
        assert!(parse_toml("[a..b]").is_err());
    }

    #[test]
    fn rejects_unknown_values() {
        assert!(parse_toml("x = nope").is_err());
        assert!(parse_toml("x = \"unterminated").is_err());
        assert!(parse_toml("x = [1, 2").is_err());
    }

    #[test]
    fn int_coerces_to_float() {
        let v = parse_toml("x = 3").unwrap();
        assert_eq!(v.get_path("x").unwrap().as_float(), Some(3.0));
    }
}
