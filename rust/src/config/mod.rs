//! Configuration system.
//!
//! The offline crate set has no `serde`/`toml`, so [`toml`] implements the
//! TOML subset the configs need (tables, strings, ints, floats, bools,
//! homogeneous arrays, comments) and [`schema`] maps parsed values onto typed,
//! validated config structs used by the CLI, the coordinator and the bench
//! harness.

pub mod schema;
pub mod toml;

pub use schema::{DistConfig, ExperimentConfig, IndexPolicy, ServeConfig, SweepSpec};
pub use toml::{parse_toml, TomlValue};
