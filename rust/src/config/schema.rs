//! Typed, validated configuration structs on top of the TOML-subset parser.
//!
//! # Key reference
//!
//! The tables below list every key the parsers accept; `opdr-lint`'s
//! `config-docs-sync` rule checks them against the match arms in
//! `from_toml_str` in both directions, so a key cannot be added, renamed,
//! or removed without this reference moving with it. All keys are optional;
//! defaults in parentheses.
//!
//! Keys of the `[serve]` table ([`ServeConfig`]):
//!
//! | key | type | meaning |
//! |-----|------|---------|
//! | `workers` | int | worker threads in the search pool (4) |
//! | `max_batch` | int | dynamic batcher: max requests per batch (32) |
//! | `max_wait_ms` | int | dynamic batcher: max wait before a partial flush (2) |
//! | `queue_capacity` | int | request queue backpressure bound (1024) |
//! | `default_k` | int | default top-k for searches (10) |
//! | `use_runtime` | bool | PJRT accelerated distance path when artifacts exist (false) |
//! | `artifacts_dir` | string | artifacts directory ("artifacts") |
//! | `ivf_threshold` | int | collection size above which the ANN index serves (4096) |
//! | `ivf_nlist` | int | IVF cells (64) |
//! | `ivf_nprobe` | int | IVF cells probed per query (8) |
//! | `index_kind` | string | ANN structure: "exact" \| "ivf" \| "hnsw" ("ivf") |
//! | `index_sq8` | bool | SQ8-quantized vector storage (false) |
//! | `sq8_global_codebook` | bool | one SQ8 codebook per collection, not per shard (false) |
//! | `index_pq` | bool | product-quantized storage, ADC + rerank search (false) |
//! | `index_pq_m` | int | PQ subquantizers, 0 = auto dim/2 (0) |
//! | `index_pq_ksub` | int | PQ centroids per subspace (16) |
//! | `index_pq_opq` | bool | train an OPQ rotation before encoding (false) |
//! | `rerank_depth` | int | ADC candidates re-scored at full precision (64) |
//! | `hnsw_m` | int | HNSW max links per node (16) |
//! | `hnsw_ef_construction` | int | HNSW construction beam width (100) |
//! | `hnsw_ef_search` | int | HNSW search beam width (64) |
//! | `hnsw_heuristic` | bool | Malkov Algorithm 4 neighbor selection (true) |
//! | `shards` | int | index segments per collection (1) |
//! | `shard_min_vectors` | int | minimum rows per index segment (1024) |
//! | `build_workers` | int | dedicated index-build pool size (2) |
//! | `incremental_ingest` | bool | absorb appends into the delta segment (true) |
//! | `delta_max_vectors` | int | delta rows that trigger background compaction (2048) |
//! | `cold_tier` | string | full-precision row home: "ram" \| "mmap" ("ram") |
//! | `cold_dir` | string | directory for cold-tier vector files ("cold") |
//! | `recall_probe` | bool | background recall/μ probe on sampled queries (false) |
//! | `recall_probe_every` | int | probe sampling stride, 1 = every query (16) |
//!
//! Keys of the `[dist]` table ([`DistConfig`]):
//!
//! | key | type | meaning |
//! |-----|------|---------|
//! | `workers` | int | shard-worker processes, 0 = distribution off (0) |
//! | `listen` | string | worker listen template, port 0 = ephemeral ("127.0.0.1:0") |
//! | `connect_timeout_ms` | int | gateway→worker dial + handshake deadline (1000) |
//! | `request_deadline_ms` | int | per-query per-shard deadline before partial (2000) |
//! | `tracing` | bool | trace tails + stage histograms + flight recorder (true) |
//! | `recorder_capacity` | int | flight-recorder ring capacity (128) |
//! | `slow_query_ms` | int | gateway latency that pins a query in the recorder (250) |

use crate::config::toml::{parse_toml, TomlValue};
use crate::data::DatasetKind;
use crate::error::{OpdrError, Result};
use crate::index::{ColdTier, IndexKind, PqParams, Quantizer, Sq8Bounds, StorageSpec};
use crate::metrics::Metric;
use crate::reduction::ReducerKind;
use std::sync::Arc;

/// Specification of an accuracy-vs-n/m sweep (one paper figure).
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Dataset to generate/load.
    pub dataset: DatasetKind,
    /// Subset sizes `m` to sweep (paper: {10..80} materials, {10..300} web).
    pub sample_sizes: Vec<usize>,
    /// Neighborhood size `k`.
    pub k: usize,
    /// Distance metric.
    pub metric: Metric,
    /// Dimension-reduction method.
    pub reducer: ReducerKind,
    /// Embedding model name ("clip", "bert", "vit", "concat-bert-panns").
    pub model: String,
    /// RNG seed.
    pub seed: u64,
    /// Number of reduced dims per m: sweep n over this many log-spaced points.
    pub dims_per_m: usize,
    /// Repetitions per (m, n) cell, averaged.
    pub repeats: usize,
}

impl Default for SweepSpec {
    fn default() -> Self {
        SweepSpec {
            dataset: DatasetKind::MaterialsObservable,
            sample_sizes: vec![10, 20, 30, 40, 50, 60, 70, 80],
            k: 5,
            metric: Metric::SqEuclidean,
            reducer: ReducerKind::Pca,
            model: "clip".to_string(),
            seed: 42,
            dims_per_m: 12,
            repeats: 3,
        }
    }
}

impl SweepSpec {
    /// Validate invariants; call before running a sweep.
    pub fn validate(&self) -> Result<()> {
        if self.sample_sizes.is_empty() {
            return Err(OpdrError::config("sweep: sample_sizes empty"));
        }
        if self.k == 0 {
            return Err(OpdrError::config("sweep: k must be >= 1"));
        }
        for &m in &self.sample_sizes {
            if m <= self.k {
                return Err(OpdrError::config(format!(
                    "sweep: sample size m={m} must exceed k={}",
                    self.k
                )));
            }
        }
        if self.dims_per_m < 2 {
            return Err(OpdrError::config("sweep: dims_per_m must be >= 2"));
        }
        if self.repeats == 0 {
            return Err(OpdrError::config("sweep: repeats must be >= 1"));
        }
        Ok(())
    }
}

/// Experiment config file (`configs/*.toml`): one or more sweeps plus output.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Experiment name (used for output paths).
    pub name: String,
    /// Output directory for CSV series.
    pub out_dir: String,
    /// The sweeps to run.
    pub sweeps: Vec<SweepSpec>,
}

impl ExperimentConfig {
    /// Parse and validate from TOML text.
    pub fn from_toml_str(src: &str) -> Result<Self> {
        let root = parse_toml(src)?;
        let name = get_str(&root, "name")?.to_string();
        let out_dir = root
            .get_path("out_dir")
            .and_then(|v| v.as_str())
            .unwrap_or("bench_out")
            .to_string();

        let sweep_names: Vec<String> = match root.get_path("sweeps") {
            Some(v) => v
                .as_array()
                .ok_or_else(|| OpdrError::config("`sweeps` must be an array"))?
                .iter()
                .map(|x| {
                    x.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| OpdrError::config("`sweeps` entries must be strings"))
                })
                .collect::<Result<_>>()?,
            None => vec!["sweep".to_string()],
        };

        let mut sweeps = Vec::new();
        for sname in sweep_names {
            let table = root
                .get_path(&sname)
                .ok_or_else(|| OpdrError::config(format!("missing sweep table [{sname}]")))?;
            sweeps.push(sweep_from_table(table, &sname)?);
        }
        let cfg = ExperimentConfig { name, out_dir, sweeps };
        for s in &cfg.sweeps {
            s.validate()?;
        }
        Ok(cfg)
    }

    /// Parse and validate from a file path.
    pub fn from_file(path: &str) -> Result<Self> {
        let src = std::fs::read_to_string(path)?;
        Self::from_toml_str(&src)
    }
}

fn sweep_from_table(t: &TomlValue, ctx: &str) -> Result<SweepSpec> {
    let mut spec = SweepSpec::default();
    let table = t
        .as_table()
        .ok_or_else(|| OpdrError::config(format!("[{ctx}] is not a table")))?;
    for (key, val) in table {
        match key.as_str() {
            "dataset" => {
                let s = val
                    .as_str()
                    .ok_or_else(|| OpdrError::config(format!("[{ctx}] dataset must be a string")))?;
                spec.dataset = DatasetKind::parse(s)
                    .ok_or_else(|| OpdrError::config(format!("[{ctx}] unknown dataset `{s}`")))?;
            }
            "sample_sizes" => {
                spec.sample_sizes = val
                    .as_array()
                    .ok_or_else(|| OpdrError::config(format!("[{ctx}] sample_sizes must be an array")))?
                    .iter()
                    .map(|x| {
                        x.as_int()
                            .filter(|&i| i > 0)
                            .map(|i| i as usize)
                            .ok_or_else(|| OpdrError::config(format!("[{ctx}] bad sample size")))
                    })
                    .collect::<Result<_>>()?;
            }
            "k" => spec.k = pos_int(val, ctx, "k")?,
            "metric" => {
                let s = val
                    .as_str()
                    .ok_or_else(|| OpdrError::config(format!("[{ctx}] metric must be a string")))?;
                spec.metric = Metric::parse(s)
                    .ok_or_else(|| OpdrError::config(format!("[{ctx}] unknown metric `{s}`")))?;
            }
            "reducer" => {
                let s = val
                    .as_str()
                    .ok_or_else(|| OpdrError::config(format!("[{ctx}] reducer must be a string")))?;
                spec.reducer = ReducerKind::parse(s)
                    .ok_or_else(|| OpdrError::config(format!("[{ctx}] unknown reducer `{s}`")))?;
            }
            "model" => {
                spec.model = val
                    .as_str()
                    .ok_or_else(|| OpdrError::config(format!("[{ctx}] model must be a string")))?
                    .to_string();
            }
            "seed" => spec.seed = pos_int(val, ctx, "seed")? as u64,
            "dims_per_m" => spec.dims_per_m = pos_int(val, ctx, "dims_per_m")?,
            "repeats" => spec.repeats = pos_int(val, ctx, "repeats")?,
            other => {
                return Err(OpdrError::config(format!("[{ctx}] unknown key `{other}`")));
            }
        }
    }
    Ok(spec)
}

fn pos_int(v: &TomlValue, ctx: &str, key: &str) -> Result<usize> {
    v.as_int()
        .filter(|&i| i >= 0)
        .map(|i| i as usize)
        .ok_or_else(|| OpdrError::config(format!("[{ctx}] `{key}` must be a non-negative integer")))
}

fn get_str<'a>(root: &'a TomlValue, key: &str) -> Result<&'a str> {
    root.get_path(key)
        .and_then(|v| v.as_str())
        .ok_or_else(|| OpdrError::config(format!("missing string key `{key}`")))
}

/// How the coordinator picks and parameterizes the ANN substrate for a
/// collection (see [`crate::index`]). Assembled from [`ServeConfig`] via
/// [`ServeConfig::index_policy`] and consumed by
/// [`crate::index::build_index`].
#[derive(Debug, Clone)]
pub struct IndexPolicy {
    /// Structure for collections at or above `exact_threshold`.
    pub kind: IndexKind,
    /// Collections smaller than this always get an exact flat index.
    pub exact_threshold: usize,
    /// Store vectors SQ8-quantized (≈4× smaller serving copy).
    pub sq8: bool,
    /// SQ8: train one codebook over the whole collection instead of per
    /// segment, so sharded quantized results are bit-identical to the
    /// unsharded quantized index at exhaustive parameters.
    pub sq8_global_codebook: bool,
    /// Pre-trained SQ8 bounds injected by the sharded builder (runtime-only;
    /// not a config key).
    pub sq8_bounds: Option<Arc<Sq8Bounds>>,
    /// Store vectors product-quantized (≈16× smaller hot copy at the
    /// default `m = dim/2`, `ksub = 16`), searched through ADC tables plus a
    /// full-precision rerank. Mutually exclusive with `sq8`.
    pub pq: bool,
    /// PQ: subquantizer count (0 = auto `dim/2`).
    pub pq_m: usize,
    /// PQ: centroids per subspace (2..=256; ≤ 16 packs two codes per byte).
    pub pq_ksub: usize,
    /// PQ: train an OPQ rotation before encoding.
    pub pq_opq: bool,
    /// PQ: Lloyd iterations per subspace codebook.
    pub pq_train_iters: usize,
    /// PQ: OPQ alternating-least-squares rounds.
    pub pq_opq_iters: usize,
    /// PQ: ADC candidates re-scored at full precision per query (raised to
    /// `k` when `k` is larger; `≥ n` makes results bit-identical to the
    /// exact index).
    pub rerank_depth: usize,
    /// IVF: number of k-means cells.
    pub ivf_nlist: usize,
    /// IVF: cells probed per query.
    pub ivf_nprobe: usize,
    /// IVF: Lloyd iterations when training the coarse quantizer.
    pub ivf_train_iters: usize,
    /// HNSW: max links per node (layer 0 allows 2×).
    pub hnsw_m: usize,
    /// HNSW: construction beam width.
    pub hnsw_ef_construction: usize,
    /// HNSW: search beam width.
    pub hnsw_ef_search: usize,
    /// HNSW: Malkov Algorithm 4 heuristic neighbor selection (default on).
    pub hnsw_heuristic: bool,
    /// Split a collection into up to this many index segments: segments
    /// build in parallel on the worker pool and queries fan out per shard
    /// and merge order-exactly (see [`crate::index::shard`]). 1 = unsharded.
    pub shards: usize,
    /// Never create a shard with fewer rows than this (small collections
    /// degrade to fewer shards — sharding only pays off at scale).
    pub shard_min_vectors: usize,
    /// Where full-precision rows (flat payloads, PQ rerank tiers) live:
    /// RAM, or spilled to mmap'd cold files so collections larger than RAM
    /// can serve (see [`crate::data::mapped`]). Results are bit-identical
    /// either way.
    pub cold_tier: ColdTier,
}

impl Default for IndexPolicy {
    fn default() -> Self {
        IndexPolicy {
            kind: IndexKind::Ivf,
            exact_threshold: 4096,
            sq8: false,
            sq8_global_codebook: false,
            sq8_bounds: None,
            pq: false,
            pq_m: 0,
            pq_ksub: 16,
            pq_opq: false,
            pq_train_iters: 10,
            pq_opq_iters: 4,
            rerank_depth: 64,
            ivf_nlist: 64,
            ivf_nprobe: 8,
            ivf_train_iters: 10,
            hnsw_m: 16,
            hnsw_ef_construction: 100,
            hnsw_ef_search: 64,
            hnsw_heuristic: true,
            shards: 1,
            shard_min_vectors: 1024,
            cold_tier: ColdTier::Ram,
        }
    }
}

impl IndexPolicy {
    /// Validate invariants.
    pub fn validate(&self) -> Result<()> {
        if self.shards == 0 || self.shards > crate::index::shard::MAX_SHARDS {
            return Err(OpdrError::config(format!(
                "index: shards must be in [1, {}]",
                crate::index::shard::MAX_SHARDS
            )));
        }
        if self.sq8 && self.pq {
            return Err(OpdrError::config(
                "index: sq8 and pq are mutually exclusive quantizers",
            ));
        }
        if self.sq8_global_codebook && !self.sq8 {
            return Err(OpdrError::config(
                "index: sq8_global_codebook requires sq8 (the flag would be silently ignored)",
            ));
        }
        if self.pq_opq && !self.pq {
            return Err(OpdrError::config(
                "index: pq_opq requires pq (the flag would be silently ignored)",
            ));
        }
        if self.pq_ksub < 2 || self.pq_ksub > 256 {
            return Err(OpdrError::config("index: pq_ksub must be in [2, 256]"));
        }
        if self.pq_train_iters == 0 {
            return Err(OpdrError::config("index: pq_train_iters must be >= 1"));
        }
        if self.rerank_depth == 0 {
            return Err(OpdrError::config("index: rerank_depth must be >= 1"));
        }
        if self.ivf_nlist == 0 {
            return Err(OpdrError::config("index: ivf_nlist must be >= 1"));
        }
        if self.ivf_nprobe == 0 || self.ivf_nprobe > self.ivf_nlist {
            return Err(OpdrError::config("index: ivf_nprobe must be in [1, ivf_nlist]"));
        }
        if self.hnsw_m < 2 {
            return Err(OpdrError::config("index: hnsw_m must be >= 2"));
        }
        if self.hnsw_ef_construction == 0 || self.hnsw_ef_search == 0 {
            return Err(OpdrError::config("index: hnsw beam widths must be >= 1"));
        }
        if self.sq8 && matches!(self.cold_tier, ColdTier::Mmap(_)) {
            return Err(OpdrError::config(
                "index: cold_tier = mmap has no effect under sq8 storage \
                 (no full-precision tier to map) — it would be silently ignored",
            ));
        }
        Ok(())
    }

    /// The [`StorageSpec`] the substrates build their vector copy from
    /// (flat / SQ8 ± global bounds / PQ, each over the configured cold
    /// tier).
    pub fn storage_spec(&self) -> StorageSpec {
        let quant = if self.pq {
            Quantizer::Pq(PqParams {
                m: self.pq_m,
                ksub: self.pq_ksub,
                opq: self.pq_opq,
                train_iters: self.pq_train_iters,
                opq_iters: self.pq_opq_iters,
                rerank_depth: self.rerank_depth,
            })
        } else if self.sq8 {
            Quantizer::Sq8 { bounds: self.sq8_bounds.clone() }
        } else {
            Quantizer::Flat
        };
        StorageSpec { quant, cold_tier: self.cold_tier.clone() }
    }
}

/// Serving configuration for the coordinator.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Worker threads in the pool.
    pub workers: usize,
    /// Dynamic batcher: max requests per batch.
    pub max_batch: usize,
    /// Dynamic batcher: max wait before flushing a partial batch.
    pub max_wait_ms: u64,
    /// Request queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Default top-k for searches.
    pub default_k: usize,
    /// Use the PJRT accelerated distance path when artifacts are available.
    pub use_runtime: bool,
    /// Artifacts directory.
    pub artifacts_dir: String,
    /// Collections above this size are served by an ANN index (below it the
    /// index subsystem falls back to an exact flat scan).
    pub ivf_threshold: usize,
    /// IVF cells and probes.
    pub ivf_nlist: usize,
    /// Number of IVF cells probed per query.
    pub ivf_nprobe: usize,
    /// ANN structure for indexed collections ("exact" | "ivf" | "hnsw").
    pub index_kind: IndexKind,
    /// Store indexed vectors SQ8-quantized.
    pub index_sq8: bool,
    /// SQ8: one codebook over the whole collection instead of per shard.
    pub sq8_global_codebook: bool,
    /// Store indexed vectors product-quantized (ADC + rerank search).
    pub index_pq: bool,
    /// PQ subquantizer count (0 = auto `dim/2`).
    pub index_pq_m: usize,
    /// PQ centroids per subspace.
    pub index_pq_ksub: usize,
    /// PQ: train an OPQ rotation before encoding.
    pub index_pq_opq: bool,
    /// PQ: ADC candidates re-scored at full precision per query.
    pub rerank_depth: usize,
    /// HNSW max links per node.
    pub hnsw_m: usize,
    /// HNSW construction beam width.
    pub hnsw_ef_construction: usize,
    /// HNSW search beam width.
    pub hnsw_ef_search: usize,
    /// HNSW heuristic neighbor selection (Malkov Algorithm 4, default on).
    pub hnsw_heuristic: bool,
    /// Index segments per collection (parallel builds + query fan-out).
    pub shards: usize,
    /// Minimum rows per index segment.
    pub shard_min_vectors: usize,
    /// Workers in the dedicated index-build pool (segment builds never
    /// compete with search fan-out for pool slots).
    pub build_workers: usize,
    /// Incremental ingest (default on): appended rows are absorbed into the
    /// serving index's flat exact delta segment instead of invalidating the
    /// index, so queries never silently degrade to a brute-force scan
    /// between an ingest and the next rebuild. Off = the legacy
    /// invalidate-on-ingest behavior.
    pub incremental_ingest: bool,
    /// Compaction threshold: when a collection's delta segment exceeds this
    /// many rows, a background compaction on the build pool folds it into a
    /// rebuilt main index behind the generation-guarded swap.
    pub delta_max_vectors: usize,
    /// Serve full-precision rows (flat payloads, PQ rerank tiers) from
    /// mmap'd on-disk cold files instead of RAM (`cold_tier = "mmap"`), so
    /// collections larger than memory can serve. Results are bit-identical
    /// to the RAM tier; saves write the mmap-servable version-5 format.
    pub cold_tier_mmap: bool,
    /// Directory the cold tier spills its vector files into.
    pub cold_dir: String,
    /// Live recall probe (default off): shadow-execute a sampled fraction of
    /// served queries against the flat exact scans on a background thread
    /// and publish `recall@k` and the OPDR order-preservation measure μ as
    /// per-collection gauges in the metrics registry.
    pub recall_probe: bool,
    /// Probe sampling stride: every Nth query per collection is shadowed
    /// (1 = every query; only sensible for tests and small demos).
    pub recall_probe_every: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 4,
            max_batch: 32,
            max_wait_ms: 2,
            queue_capacity: 1024,
            default_k: 10,
            use_runtime: false,
            artifacts_dir: "artifacts".to_string(),
            ivf_threshold: 4096,
            ivf_nlist: 64,
            ivf_nprobe: 8,
            index_kind: IndexKind::Ivf,
            index_sq8: false,
            sq8_global_codebook: false,
            index_pq: false,
            index_pq_m: 0,
            index_pq_ksub: 16,
            index_pq_opq: false,
            rerank_depth: 64,
            hnsw_m: 16,
            hnsw_ef_construction: 100,
            hnsw_ef_search: 64,
            hnsw_heuristic: true,
            shards: 1,
            shard_min_vectors: 1024,
            build_workers: 2,
            incremental_ingest: true,
            delta_max_vectors: 2048,
            cold_tier_mmap: false,
            cold_dir: "cold".to_string(),
            recall_probe: false,
            recall_probe_every: 16,
        }
    }
}

impl ServeConfig {
    /// Parse the `[serve]` table of a TOML doc (all keys optional).
    /// Dependent keys given without their primary toggle (`index_pq_*` /
    /// `rerank_depth` without `index_pq`, `sq8_global_codebook` without
    /// `index_sq8`) are rejected rather than silently ignored.
    pub fn from_toml_str(src: &str) -> Result<Self> {
        let root = parse_toml(src)?;
        let mut cfg = ServeConfig::default();
        let mut seen: Vec<String> = Vec::new();
        if let Some(t) = root.get_path("serve").and_then(|v| v.as_table()) {
            for (key, val) in t {
                seen.push(key.clone());
                match key.as_str() {
                    "workers" => cfg.workers = pos_int(val, "serve", key)?,
                    "max_batch" => cfg.max_batch = pos_int(val, "serve", key)?,
                    "max_wait_ms" => cfg.max_wait_ms = pos_int(val, "serve", key)? as u64,
                    "queue_capacity" => cfg.queue_capacity = pos_int(val, "serve", key)?,
                    "default_k" => cfg.default_k = pos_int(val, "serve", key)?,
                    "use_runtime" => {
                        cfg.use_runtime = val
                            .as_bool()
                            .ok_or_else(|| OpdrError::config("serve.use_runtime must be a bool"))?
                    }
                    "artifacts_dir" => {
                        cfg.artifacts_dir = val
                            .as_str()
                            .ok_or_else(|| OpdrError::config("serve.artifacts_dir must be a string"))?
                            .to_string()
                    }
                    "ivf_threshold" => cfg.ivf_threshold = pos_int(val, "serve", key)?,
                    "ivf_nlist" => cfg.ivf_nlist = pos_int(val, "serve", key)?,
                    "ivf_nprobe" => cfg.ivf_nprobe = pos_int(val, "serve", key)?,
                    "index_kind" => {
                        let s = val.as_str().ok_or_else(|| {
                            OpdrError::config("serve.index_kind must be a string")
                        })?;
                        cfg.index_kind = IndexKind::parse(s).ok_or_else(|| {
                            OpdrError::config(format!("serve: unknown index kind `{s}`"))
                        })?;
                    }
                    "index_sq8" => {
                        cfg.index_sq8 = val
                            .as_bool()
                            .ok_or_else(|| OpdrError::config("serve.index_sq8 must be a bool"))?
                    }
                    "sq8_global_codebook" => {
                        cfg.sq8_global_codebook = val.as_bool().ok_or_else(|| {
                            OpdrError::config("serve.sq8_global_codebook must be a bool")
                        })?
                    }
                    "index_pq" => {
                        cfg.index_pq = val
                            .as_bool()
                            .ok_or_else(|| OpdrError::config("serve.index_pq must be a bool"))?
                    }
                    "index_pq_m" => cfg.index_pq_m = pos_int(val, "serve", key)?,
                    "index_pq_ksub" => cfg.index_pq_ksub = pos_int(val, "serve", key)?,
                    "index_pq_opq" => {
                        cfg.index_pq_opq = val.as_bool().ok_or_else(|| {
                            OpdrError::config("serve.index_pq_opq must be a bool")
                        })?
                    }
                    "rerank_depth" => cfg.rerank_depth = pos_int(val, "serve", key)?,
                    "hnsw_m" => cfg.hnsw_m = pos_int(val, "serve", key)?,
                    "hnsw_ef_construction" => {
                        cfg.hnsw_ef_construction = pos_int(val, "serve", key)?
                    }
                    "hnsw_ef_search" => cfg.hnsw_ef_search = pos_int(val, "serve", key)?,
                    "hnsw_heuristic" => {
                        cfg.hnsw_heuristic = val.as_bool().ok_or_else(|| {
                            OpdrError::config("serve.hnsw_heuristic must be a bool")
                        })?
                    }
                    "shards" => cfg.shards = pos_int(val, "serve", key)?,
                    "shard_min_vectors" => cfg.shard_min_vectors = pos_int(val, "serve", key)?,
                    "build_workers" => cfg.build_workers = pos_int(val, "serve", key)?,
                    "incremental_ingest" => {
                        cfg.incremental_ingest = val.as_bool().ok_or_else(|| {
                            OpdrError::config("serve.incremental_ingest must be a bool")
                        })?
                    }
                    "delta_max_vectors" => cfg.delta_max_vectors = pos_int(val, "serve", key)?,
                    "cold_tier" => {
                        let s = val.as_str().ok_or_else(|| {
                            OpdrError::config("serve.cold_tier must be a string")
                        })?;
                        cfg.cold_tier_mmap = match s.to_ascii_lowercase().as_str() {
                            "ram" => false,
                            "mmap" => true,
                            other => {
                                return Err(OpdrError::config(format!(
                                    "serve: unknown cold_tier `{other}` (expected ram | mmap)"
                                )))
                            }
                        };
                    }
                    "cold_dir" => {
                        cfg.cold_dir = val
                            .as_str()
                            .ok_or_else(|| OpdrError::config("serve.cold_dir must be a string"))?
                            .to_string()
                    }
                    "recall_probe" => {
                        cfg.recall_probe = val
                            .as_bool()
                            .ok_or_else(|| OpdrError::config("serve.recall_probe must be a bool"))?
                    }
                    "recall_probe_every" => {
                        cfg.recall_probe_every = pos_int(val, "serve", key)?
                    }
                    other => {
                        return Err(OpdrError::config(format!("serve: unknown key `{other}`")))
                    }
                }
            }
        }
        const PQ_DEPENDENT: [&str; 4] =
            ["index_pq_m", "index_pq_ksub", "index_pq_opq", "rerank_depth"];
        if !cfg.index_pq {
            if let Some(k) = seen.iter().find(|k| PQ_DEPENDENT.contains(&k.as_str())) {
                return Err(OpdrError::config(format!(
                    "serve: `{k}` requires index_pq = true (it would be silently ignored)"
                )));
            }
        }
        if !cfg.index_sq8 && seen.iter().any(|k| k == "sq8_global_codebook") {
            return Err(OpdrError::config(
                "serve: `sq8_global_codebook` requires index_sq8 = true                  (it would be silently ignored)",
            ));
        }
        if !cfg.incremental_ingest && seen.iter().any(|k| k == "delta_max_vectors") {
            return Err(OpdrError::config(
                "serve: `delta_max_vectors` requires incremental_ingest = true \
                 (it would be silently ignored)",
            ));
        }
        if !cfg.cold_tier_mmap && seen.iter().any(|k| k == "cold_dir") {
            return Err(OpdrError::config(
                "serve: `cold_dir` requires cold_tier = \"mmap\" \
                 (it would be silently ignored)",
            ));
        }
        if !cfg.recall_probe && seen.iter().any(|k| k == "recall_probe_every") {
            return Err(OpdrError::config(
                "serve: `recall_probe_every` requires recall_probe = true \
                 (it would be silently ignored)",
            ));
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Validate invariants.
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            return Err(OpdrError::config("serve.workers must be >= 1"));
        }
        if self.build_workers == 0 {
            return Err(OpdrError::config("serve.build_workers must be >= 1"));
        }
        if self.max_batch == 0 {
            return Err(OpdrError::config("serve.max_batch must be >= 1"));
        }
        if self.queue_capacity < self.max_batch {
            return Err(OpdrError::config("serve.queue_capacity must be >= max_batch"));
        }
        if self.default_k == 0 {
            return Err(OpdrError::config("serve.default_k must be >= 1"));
        }
        if self.delta_max_vectors == 0 {
            return Err(OpdrError::config("serve.delta_max_vectors must be >= 1"));
        }
        if self.cold_tier_mmap && self.cold_dir.is_empty() {
            return Err(OpdrError::config("serve.cold_dir must not be empty"));
        }
        if self.ivf_nprobe > self.ivf_nlist {
            return Err(OpdrError::config("serve.ivf_nprobe must be <= ivf_nlist"));
        }
        if self.recall_probe && self.recall_probe_every == 0 {
            return Err(OpdrError::config("serve.recall_probe_every must be >= 1"));
        }
        self.index_policy().validate()
    }

    /// Assemble the [`IndexPolicy`] the coordinator hands to
    /// [`crate::index::build_index`].
    pub fn index_policy(&self) -> IndexPolicy {
        IndexPolicy {
            kind: self.index_kind,
            exact_threshold: self.ivf_threshold,
            sq8: self.index_sq8,
            sq8_global_codebook: self.sq8_global_codebook,
            pq: self.index_pq,
            pq_m: self.index_pq_m,
            pq_ksub: self.index_pq_ksub,
            pq_opq: self.index_pq_opq,
            rerank_depth: self.rerank_depth,
            ivf_nlist: self.ivf_nlist,
            ivf_nprobe: self.ivf_nprobe,
            hnsw_m: self.hnsw_m,
            hnsw_ef_construction: self.hnsw_ef_construction,
            hnsw_ef_search: self.hnsw_ef_search,
            hnsw_heuristic: self.hnsw_heuristic,
            shards: self.shards,
            shard_min_vectors: self.shard_min_vectors,
            cold_tier: if self.cold_tier_mmap {
                ColdTier::Mmap(std::path::PathBuf::from(&self.cold_dir))
            } else {
                ColdTier::Ram
            },
            ..Default::default()
        }
    }
}

/// Distributed-serving configuration: the `[dist]` table. `workers = 0`
/// (the default) disables distribution entirely; `workers >= 1` makes
/// `serve-demo --distributed` split the collection into that many
/// contiguous shards, each served by a supervised worker process behind
/// the scatter-gather [`crate::dist::Gateway`].
#[derive(Debug, Clone)]
pub struct DistConfig {
    /// Shard-worker processes (0 = distribution disabled).
    pub workers: usize,
    /// Listen address template for spawned workers (`host:0` picks an
    /// ephemeral port per worker).
    pub listen: String,
    /// Gateway→worker dial + handshake deadline.
    pub connect_timeout_ms: u64,
    /// Per-query per-shard RPC deadline; a shard that misses it degrades
    /// the answer to `partial = true` instead of stalling the query.
    pub request_deadline_ms: u64,
    /// Cluster observability master switch: trace-id propagation on the v2
    /// tails, per-shard stage histograms, and the flight recorder. Off, the
    /// gateway sends v1-shaped frames (no tails) — the bench baseline for
    /// the observability-overhead floor.
    pub tracing: bool,
    /// Flight-recorder ring capacity (complete per-query span timelines
    /// held for the `SlowQueries` dump).
    pub recorder_capacity: usize,
    /// End-to-end gateway time at or above which a query is pinned in the
    /// flight recorder (partial queries pin regardless).
    pub slow_query_ms: u64,
}

impl Default for DistConfig {
    fn default() -> Self {
        DistConfig {
            workers: 0,
            listen: "127.0.0.1:0".to_string(),
            connect_timeout_ms: 1000,
            request_deadline_ms: 2000,
            tracing: true,
            recorder_capacity: 128,
            slow_query_ms: 250,
        }
    }
}

impl DistConfig {
    /// True when distribution is configured on.
    pub fn enabled(&self) -> bool {
        self.workers >= 1
    }

    /// Parse the `[dist]` table of a TOML doc (all keys optional). Tuning
    /// keys given while `workers` stays 0 are rejected rather than
    /// silently ignored.
    pub fn from_toml_str(src: &str) -> Result<Self> {
        let root = parse_toml(src)?;
        let mut cfg = DistConfig::default();
        let mut seen: Vec<String> = Vec::new();
        if let Some(t) = root.get_path("dist").and_then(|v| v.as_table()) {
            for (key, val) in t {
                seen.push(key.clone());
                match key.as_str() {
                    "workers" => cfg.workers = pos_int(val, "dist", key)?,
                    "listen" => {
                        cfg.listen = val
                            .as_str()
                            .ok_or_else(|| OpdrError::config("dist.listen must be a string"))?
                            .to_string()
                    }
                    "connect_timeout_ms" => {
                        cfg.connect_timeout_ms = pos_int(val, "dist", key)? as u64
                    }
                    "request_deadline_ms" => {
                        cfg.request_deadline_ms = pos_int(val, "dist", key)? as u64
                    }
                    "tracing" => {
                        cfg.tracing = val
                            .as_bool()
                            .ok_or_else(|| OpdrError::config("dist.tracing must be a bool"))?
                    }
                    "recorder_capacity" => {
                        cfg.recorder_capacity = pos_int(val, "dist", key)?
                    }
                    "slow_query_ms" => cfg.slow_query_ms = pos_int(val, "dist", key)? as u64,
                    other => {
                        return Err(OpdrError::config(format!("dist: unknown key `{other}`")))
                    }
                }
            }
        }
        if !cfg.enabled() {
            if let Some(k) = seen.iter().find(|k| *k != "workers") {
                return Err(OpdrError::config(format!(
                    "dist: `{k}` requires workers >= 1 (it would be silently ignored)"
                )));
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Validate invariants.
    pub fn validate(&self) -> Result<()> {
        if self.workers > crate::index::shard::MAX_SHARDS {
            return Err(OpdrError::config(format!(
                "dist.workers must be <= {}",
                crate::index::shard::MAX_SHARDS
            )));
        }
        if self.enabled() {
            if self.listen.is_empty() {
                return Err(OpdrError::config("dist.listen must not be empty"));
            }
            if self.connect_timeout_ms == 0 {
                return Err(OpdrError::config("dist.connect_timeout_ms must be >= 1"));
            }
            if self.request_deadline_ms == 0 {
                return Err(OpdrError::config("dist.request_deadline_ms must be >= 1"));
            }
            if self.recorder_capacity == 0 {
                return Err(OpdrError::config("dist.recorder_capacity must be >= 1"));
            }
            if self.slow_query_ms == 0 {
                return Err(OpdrError::config("dist.slow_query_ms must be >= 1"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
name = "fig1"
out_dir = "bench_out"
sweeps = ["materials", "flickr"]

[materials]
dataset = "materials-observable"
sample_sizes = [10, 20, 30]
k = 5
metric = "l2sq"
reducer = "pca"
model = "clip"
seed = 7
dims_per_m = 8
repeats = 2

[flickr]
dataset = "flickr30k"
sample_sizes = [10, 50]
k = 5
"#;

    #[test]
    fn full_experiment_roundtrip() {
        let cfg = ExperimentConfig::from_toml_str(DOC).unwrap();
        assert_eq!(cfg.name, "fig1");
        assert_eq!(cfg.sweeps.len(), 2);
        assert_eq!(cfg.sweeps[0].sample_sizes, vec![10, 20, 30]);
        assert_eq!(cfg.sweeps[0].seed, 7);
        assert_eq!(cfg.sweeps[1].dataset, DatasetKind::Flickr30k);
        // Defaults filled for the second sweep.
        assert_eq!(cfg.sweeps[1].repeats, 3);
    }

    #[test]
    fn missing_name_rejected() {
        assert!(ExperimentConfig::from_toml_str("out_dir = \"x\"").is_err());
    }

    #[test]
    fn unknown_key_rejected() {
        let doc = "name = \"x\"\n[sweep]\nbogus = 1";
        let e = ExperimentConfig::from_toml_str(doc).unwrap_err();
        assert!(e.to_string().contains("bogus"));
    }

    #[test]
    fn sweep_validation_enforced() {
        // m <= k invalid.
        let doc = "name = \"x\"\n[sweep]\nsample_sizes = [3]\nk = 5";
        assert!(ExperimentConfig::from_toml_str(doc).is_err());
    }

    #[test]
    fn serve_config_defaults_and_overrides() {
        let cfg = ServeConfig::from_toml_str("[serve]\nworkers = 2\nmax_batch = 16").unwrap();
        assert_eq!(cfg.workers, 2);
        assert_eq!(cfg.max_batch, 16);
        assert_eq!(cfg.max_wait_ms, ServeConfig::default().max_wait_ms);
        // Empty doc = all defaults.
        let d = ServeConfig::from_toml_str("").unwrap();
        assert_eq!(d.workers, 4);
    }

    #[test]
    fn serve_validation() {
        assert!(ServeConfig::from_toml_str("[serve]\nworkers = 0").is_err());
        assert!(ServeConfig::from_toml_str("[serve]\nqueue_capacity = 1\nmax_batch = 32").is_err());
        assert!(ServeConfig::from_toml_str("[serve]\nivf_nprobe = 100\nivf_nlist = 4").is_err());
    }

    #[test]
    fn serve_index_policy_keys() {
        let cfg = ServeConfig::from_toml_str(
            "[serve]\nindex_kind = \"hnsw\"\nindex_sq8 = true\nhnsw_m = 8\nhnsw_ef_search = 200\nivf_threshold = 100",
        )
        .unwrap();
        assert_eq!(cfg.index_kind, IndexKind::Hnsw);
        assert!(cfg.index_sq8);
        let p = cfg.index_policy();
        assert_eq!(p.kind, IndexKind::Hnsw);
        assert!(p.sq8);
        assert_eq!(p.hnsw_m, 8);
        assert_eq!(p.hnsw_ef_search, 200);
        assert_eq!(p.exact_threshold, 100);
        // Defaults flow through untouched keys.
        assert_eq!(p.hnsw_ef_construction, 100);
        assert_eq!(ServeConfig::from_toml_str("").unwrap().index_kind, IndexKind::Ivf);
    }

    #[test]
    fn serve_shard_keys_flow_into_policy() {
        let cfg = ServeConfig::from_toml_str(
            "[serve]\nshards = 8\nshard_min_vectors = 256",
        )
        .unwrap();
        assert_eq!(cfg.shards, 8);
        assert_eq!(cfg.shard_min_vectors, 256);
        let p = cfg.index_policy();
        assert_eq!(p.shards, 8);
        assert_eq!(p.shard_min_vectors, 256);
        // Defaults stay unsharded.
        let d = ServeConfig::from_toml_str("").unwrap();
        assert_eq!(d.shards, 1);
        assert_eq!(d.index_policy().shard_min_vectors, 1024);
        // shards = 0 and absurd counts are rejected.
        assert!(ServeConfig::from_toml_str("[serve]\nshards = 0").is_err());
        assert!(ServeConfig::from_toml_str("[serve]\nshards = 100000").is_err());
    }

    #[test]
    fn serve_pq_and_global_codebook_keys_flow_into_policy() {
        let cfg = ServeConfig::from_toml_str(
            "[serve]\nindex_pq = true\nindex_pq_m = 8\nindex_pq_ksub = 32\n\
             index_pq_opq = true\nrerank_depth = 200\nhnsw_heuristic = false\n\
             build_workers = 3\n",
        )
        .unwrap();
        assert!(cfg.index_pq);
        assert_eq!(cfg.build_workers, 3);
        let p = cfg.index_policy();
        assert!(p.pq && p.pq_opq && !p.hnsw_heuristic);
        assert_eq!(p.pq_m, 8);
        assert_eq!(p.pq_ksub, 32);
        assert_eq!(p.rerank_depth, 200);
        assert!(matches!(p.storage_spec().quant, Quantizer::Pq(pp) if pp.opq && pp.ksub == 32));
        // Global SQ8 codebook key.
        let cfg = ServeConfig::from_toml_str(
            "[serve]\nindex_sq8 = true\nsq8_global_codebook = true\n",
        )
        .unwrap();
        let p = cfg.index_policy();
        assert!(p.sq8 && p.sq8_global_codebook);
        assert!(matches!(p.storage_spec().quant, Quantizer::Sq8 { bounds: None }));
        // Defaults: flat storage, heuristic on, dedicated build pool.
        let d = ServeConfig::from_toml_str("").unwrap();
        assert!(!d.index_pq && d.hnsw_heuristic);
        assert_eq!(d.build_workers, 2);
        let spec = d.index_policy().storage_spec();
        assert!(matches!(spec.quant, Quantizer::Flat));
        assert_eq!(spec.cold_tier, ColdTier::Ram);
        // Invalid combinations / ranges.
        assert!(ServeConfig::from_toml_str("[serve]\nindex_pq = true\nindex_sq8 = true").is_err());
        assert!(ServeConfig::from_toml_str("[serve]\nindex_pq_ksub = 1000").is_err());
        assert!(ServeConfig::from_toml_str("[serve]\nrerank_depth = 0").is_err());
        assert!(ServeConfig::from_toml_str("[serve]\nbuild_workers = 0").is_err());
        assert!(ServeConfig::from_toml_str("[serve]\nindex_pq = 3").is_err());
        // Dependent keys without their primary toggle are rejected instead
        // of silently ignored — booleans and parameters alike.
        assert!(ServeConfig::from_toml_str("[serve]\nsq8_global_codebook = true").is_err());
        assert!(ServeConfig::from_toml_str("[serve]\nindex_pq_opq = true").is_err());
        let e = ServeConfig::from_toml_str("[serve]\nindex_pq_ksub = 32")
            .unwrap_err()
            .to_string();
        assert!(e.contains("requires index_pq"), "{e}");
        assert!(ServeConfig::from_toml_str("[serve]\nrerank_depth = 500").is_err());
    }

    #[test]
    fn serve_incremental_ingest_keys() {
        // Defaults: incremental ingest on with a sane compaction bound.
        let d = ServeConfig::from_toml_str("").unwrap();
        assert!(d.incremental_ingest);
        assert_eq!(d.delta_max_vectors, 2048);
        // Overrides parse.
        let cfg = ServeConfig::from_toml_str(
            "[serve]\nincremental_ingest = true\ndelta_max_vectors = 64\n",
        )
        .unwrap();
        assert!(cfg.incremental_ingest);
        assert_eq!(cfg.delta_max_vectors, 64);
        // Legacy mode still expressible.
        let legacy = ServeConfig::from_toml_str("[serve]\nincremental_ingest = false\n").unwrap();
        assert!(!legacy.incremental_ingest);
        // Dependent key without its toggle is rejected, not silently ignored.
        let e = ServeConfig::from_toml_str(
            "[serve]\nincremental_ingest = false\ndelta_max_vectors = 64\n",
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("requires incremental_ingest"), "{e}");
        // Range / type validation.
        assert!(ServeConfig::from_toml_str("[serve]\ndelta_max_vectors = 0").is_err());
        assert!(ServeConfig::from_toml_str("[serve]\nincremental_ingest = 3").is_err());
    }

    #[test]
    fn serve_cold_tier_keys() {
        // Default: RAM tier, nothing mapped.
        let d = ServeConfig::from_toml_str("").unwrap();
        assert!(!d.cold_tier_mmap);
        assert_eq!(d.index_policy().cold_tier, ColdTier::Ram);
        // Mmap tier with an explicit spill directory flows into the policy
        // and the storage spec.
        let cfg = ServeConfig::from_toml_str(
            "[serve]\ncold_tier = \"mmap\"\ncold_dir = \"/tmp/opdr-cold\"\n",
        )
        .unwrap();
        assert!(cfg.cold_tier_mmap);
        let p = cfg.index_policy();
        assert_eq!(p.cold_tier, ColdTier::Mmap(std::path::PathBuf::from("/tmp/opdr-cold")));
        assert_eq!(p.storage_spec().cold_tier, p.cold_tier);
        // "ram" is accepted explicitly; unknown tiers are not.
        assert!(ServeConfig::from_toml_str("[serve]\ncold_tier = \"ram\"\n").is_ok());
        assert!(ServeConfig::from_toml_str("[serve]\ncold_tier = \"ssd\"\n").is_err());
        assert!(ServeConfig::from_toml_str("[serve]\ncold_tier = 3\n").is_err());
        // Dependent key without the toggle is rejected, not silently
        // ignored.
        let e = ServeConfig::from_toml_str("[serve]\ncold_dir = \"x\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("requires cold_tier"), "{e}");
        // SQ8 has no full-precision tier to map: the combination is
        // rejected instead of silently doing nothing.
        let e = ServeConfig::from_toml_str(
            "[serve]\nindex_sq8 = true\ncold_tier = \"mmap\"\n",
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("sq8"), "{e}");
        // PQ + mmap is the headline combination and validates fine.
        assert!(ServeConfig::from_toml_str(
            "[serve]\nindex_pq = true\ncold_tier = \"mmap\"\n"
        )
        .is_ok());
    }

    #[test]
    fn serve_recall_probe_keys() {
        // Default: probe off with a sane sampling stride.
        let d = ServeConfig::from_toml_str("").unwrap();
        assert!(!d.recall_probe);
        assert_eq!(d.recall_probe_every, 16);
        // Overrides parse.
        let cfg = ServeConfig::from_toml_str(
            "[serve]\nrecall_probe = true\nrecall_probe_every = 4\n",
        )
        .unwrap();
        assert!(cfg.recall_probe);
        assert_eq!(cfg.recall_probe_every, 4);
        // Dependent key without the toggle is rejected, not silently
        // ignored.
        let e = ServeConfig::from_toml_str("[serve]\nrecall_probe_every = 4\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("requires recall_probe"), "{e}");
        // Range / type validation.
        assert!(ServeConfig::from_toml_str(
            "[serve]\nrecall_probe = true\nrecall_probe_every = 0\n"
        )
        .is_err());
        assert!(ServeConfig::from_toml_str("[serve]\nrecall_probe = 3\n").is_err());
    }

    #[test]
    fn serve_index_policy_validation() {
        assert!(ServeConfig::from_toml_str("[serve]\nindex_kind = \"quantum\"").is_err());
        assert!(ServeConfig::from_toml_str("[serve]\nhnsw_m = 1").is_err());
        assert!(ServeConfig::from_toml_str("[serve]\nhnsw_ef_search = 0").is_err());
        assert!(ServeConfig::from_toml_str("[serve]\nindex_sq8 = 3").is_err());
        let p = IndexPolicy { ivf_nprobe: 0, ..Default::default() };
        assert!(p.validate().is_err());
    }

    #[test]
    fn dist_config_defaults_and_overrides() {
        // Default: distribution off, sane timeouts.
        let d = DistConfig::from_toml_str("").unwrap();
        assert!(!d.enabled());
        assert_eq!(d.workers, 0);
        assert_eq!(d.listen, "127.0.0.1:0");
        assert_eq!(d.connect_timeout_ms, 1000);
        assert_eq!(d.request_deadline_ms, 2000);
        // Overrides parse.
        let cfg = DistConfig::from_toml_str(
            "[dist]\nworkers = 3\nlisten = \"127.0.0.1:0\"\nconnect_timeout_ms = 250\nrequest_deadline_ms = 500\n",
        )
        .unwrap();
        assert!(cfg.enabled());
        assert_eq!(cfg.workers, 3);
        assert_eq!(cfg.connect_timeout_ms, 250);
        assert_eq!(cfg.request_deadline_ms, 500);
        // Observability defaults: tracing on, a real ring, a sane slow bar.
        assert!(cfg.tracing);
        assert_eq!(cfg.recorder_capacity, 128);
        assert_eq!(cfg.slow_query_ms, 250);
    }

    #[test]
    fn dist_observability_keys() {
        let cfg = DistConfig::from_toml_str(
            "[dist]\nworkers = 2\ntracing = false\nrecorder_capacity = 16\nslow_query_ms = 40\n",
        )
        .unwrap();
        assert!(!cfg.tracing);
        assert_eq!(cfg.recorder_capacity, 16);
        assert_eq!(cfg.slow_query_ms, 40);
        // Dependent-key rule applies to the new keys too.
        let e = DistConfig::from_toml_str("[dist]\ntracing = false\n").unwrap_err().to_string();
        assert!(e.contains("requires workers"), "{e}");
        // Type and range errors are rejected.
        assert!(DistConfig::from_toml_str("[dist]\nworkers = 1\ntracing = 1\n").is_err());
        assert!(
            DistConfig::from_toml_str("[dist]\nworkers = 1\nrecorder_capacity = 0\n").is_err()
        );
        assert!(DistConfig::from_toml_str("[dist]\nworkers = 1\nslow_query_ms = 0\n").is_err());
    }

    #[test]
    fn dist_config_dependent_and_unknown_keys_rejected() {
        // Tuning keys without workers >= 1 are rejected, not silently
        // ignored.
        let e = DistConfig::from_toml_str("[dist]\nrequest_deadline_ms = 500\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("requires workers"), "{e}");
        let e = DistConfig::from_toml_str("[dist]\nworkers = 0\nlisten = \"x:0\"\n")
            .unwrap_err()
            .to_string();
        assert!(e.contains("requires workers"), "{e}");
        // Unknown keys and type errors are rejected.
        assert!(DistConfig::from_toml_str("[dist]\nbogus = 1\n").is_err());
        assert!(DistConfig::from_toml_str("[dist]\nworkers = \"two\"\n").is_err());
        assert!(DistConfig::from_toml_str("[dist]\nworkers = 1\nlisten = 9\n").is_err());
    }

    #[test]
    fn dist_config_validation() {
        // Zero timeouts are rejected when enabled.
        assert!(DistConfig::from_toml_str("[dist]\nworkers = 1\nconnect_timeout_ms = 0\n")
            .is_err());
        assert!(DistConfig::from_toml_str("[dist]\nworkers = 1\nrequest_deadline_ms = 0\n")
            .is_err());
        assert!(DistConfig::from_toml_str("[dist]\nworkers = 1\nlisten = \"\"\n").is_err());
        // The shard ceiling bounds the worker count.
        let too_many = DistConfig {
            workers: crate::index::shard::MAX_SHARDS + 1,
            ..Default::default()
        };
        assert!(too_many.validate().is_err());
    }
}
