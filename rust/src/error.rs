//! Unified error type for the OPDR crate.
//!
//! Hand-rolled `Display`/`Error` impls (the offline registry has no
//! `thiserror`); the message format is `<kind> error: <detail>` everywhere so
//! tests and operators can match on either part.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, OpdrError>;

/// Unified error type covering configuration, linear algebra, runtime (PJRT)
/// and coordinator failures.
#[derive(Debug)]
pub enum OpdrError {
    /// Configuration file / CLI errors.
    Config(String),

    /// Shape or argument mismatch in numeric code.
    Shape(String),

    /// Numerical failure (non-convergence, singular input, NaN).
    Numeric(String),

    /// Dataset / embedding-store errors.
    Data(String),

    /// PJRT runtime / artifact errors.
    Runtime(String),

    /// Coordinator / serving errors.
    Coordinator(String),

    /// Underlying XLA error.
    Xla(String),

    /// I/O error.
    Io(std::io::Error),
}

impl fmt::Display for OpdrError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            OpdrError::Config(m) => write!(f, "config error: {m}"),
            OpdrError::Shape(m) => write!(f, "shape error: {m}"),
            OpdrError::Numeric(m) => write!(f, "numeric error: {m}"),
            OpdrError::Data(m) => write!(f, "data error: {m}"),
            OpdrError::Runtime(m) => write!(f, "runtime error: {m}"),
            OpdrError::Coordinator(m) => write!(f, "coordinator error: {m}"),
            OpdrError::Xla(m) => write!(f, "xla error: {m}"),
            OpdrError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for OpdrError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            OpdrError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for OpdrError {
    fn from(e: std::io::Error) -> Self {
        OpdrError::Io(e)
    }
}

impl From<xla::Error> for OpdrError {
    fn from(e: xla::Error) -> Self {
        OpdrError::Xla(e.to_string())
    }
}

impl OpdrError {
    /// Shorthand constructor for shape errors.
    pub fn shape(msg: impl Into<String>) -> Self {
        OpdrError::Shape(msg.into())
    }
    /// Shorthand constructor for numeric errors.
    pub fn numeric(msg: impl Into<String>) -> Self {
        OpdrError::Numeric(msg.into())
    }
    /// Shorthand constructor for config errors.
    pub fn config(msg: impl Into<String>) -> Self {
        OpdrError::Config(msg.into())
    }
    /// Shorthand constructor for data errors.
    pub fn data(msg: impl Into<String>) -> Self {
        OpdrError::Data(msg.into())
    }
    /// Shorthand constructor for runtime errors.
    pub fn runtime(msg: impl Into<String>) -> Self {
        OpdrError::Runtime(msg.into())
    }
    /// Shorthand constructor for coordinator errors.
    pub fn coordinator(msg: impl Into<String>) -> Self {
        OpdrError::Coordinator(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_kind_and_message() {
        let e = OpdrError::shape("rows mismatch");
        assert_eq!(e.to_string(), "shape error: rows mismatch");
        let e = OpdrError::numeric("jacobi failed");
        assert!(e.to_string().contains("jacobi failed"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: OpdrError = io.into();
        assert!(matches!(e, OpdrError::Io(_)));
    }

    #[test]
    fn io_source_preserved() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: OpdrError = io.into();
        assert!(std::error::Error::source(&e).is_some());
        assert!(std::error::Error::source(&OpdrError::shape("x")).is_none());
    }
}
