//! Unified error type for the OPDR crate.

use thiserror::Error;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, OpdrError>;

/// Unified error type covering configuration, linear algebra, runtime (PJRT)
/// and coordinator failures.
#[derive(Debug, Error)]
pub enum OpdrError {
    /// Configuration file / CLI errors.
    #[error("config error: {0}")]
    Config(String),

    /// Shape or argument mismatch in numeric code.
    #[error("shape error: {0}")]
    Shape(String),

    /// Numerical failure (non-convergence, singular input, NaN).
    #[error("numeric error: {0}")]
    Numeric(String),

    /// Dataset / embedding-store errors.
    #[error("data error: {0}")]
    Data(String),

    /// PJRT runtime / artifact errors.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Coordinator / serving errors.
    #[error("coordinator error: {0}")]
    Coordinator(String),

    /// Underlying XLA error.
    #[error("xla error: {0}")]
    Xla(String),

    /// I/O error.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for OpdrError {
    fn from(e: xla::Error) -> Self {
        OpdrError::Xla(e.to_string())
    }
}

impl OpdrError {
    /// Shorthand constructor for shape errors.
    pub fn shape(msg: impl Into<String>) -> Self {
        OpdrError::Shape(msg.into())
    }
    /// Shorthand constructor for numeric errors.
    pub fn numeric(msg: impl Into<String>) -> Self {
        OpdrError::Numeric(msg.into())
    }
    /// Shorthand constructor for config errors.
    pub fn config(msg: impl Into<String>) -> Self {
        OpdrError::Config(msg.into())
    }
    /// Shorthand constructor for data errors.
    pub fn data(msg: impl Into<String>) -> Self {
        OpdrError::Data(msg.into())
    }
    /// Shorthand constructor for runtime errors.
    pub fn runtime(msg: impl Into<String>) -> Self {
        OpdrError::Runtime(msg.into())
    }
    /// Shorthand constructor for coordinator errors.
    pub fn coordinator(msg: impl Into<String>) -> Self {
        OpdrError::Coordinator(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_kind_and_message() {
        let e = OpdrError::shape("rows mismatch");
        assert_eq!(e.to_string(), "shape error: rows mismatch");
        let e = OpdrError::numeric("jacobi failed");
        assert!(e.to_string().contains("jacobi failed"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: OpdrError = io.into();
        assert!(matches!(e, OpdrError::Io(_)));
    }
}
