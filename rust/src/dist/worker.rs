//! The shard worker: one process (or in-process thread) serving k-NN over a
//! contiguous global-ID range of a collection.
//!
//! A worker owns an [`AnnIndex`] — typically loaded from a version-5 `OPDR`
//! cold file, so a supervisor respawn remaps the mmap'd annex and is back
//! serving in ~0 time — plus the shard's global row offset. Search hits are
//! remapped to global ids *worker-side* (`local id + start`), so the
//! gateway's scatter-gather is a plain [`crate::knn::merge_top_k`] over
//! `(global id, distance)` pairs, bit-identical to an in-process shard
//! merge.
//!
//! The accept loop is poll-based (non-blocking accept + a stop flag) and
//! every connection is handled on its own thread with a short read poll, so
//! a stalled or desynchronized client never blocks other connections and a
//! stop request tears the worker down within one poll interval — that
//! abrupt teardown is exactly what the crash/restart tests exercise.
//!
//! Protocol per connection: the client opens with [`Message::Hello`]; the
//! worker validates the protocol version (accepting the whole
//! [`MIN_PROTOCOL_VERSION`]..=[`PROTOCOL_VERSION`] window and negotiating
//! down to the client's) and answers [`Message::HelloAck`] carrying
//! `(start, len, dim)`. Then each [`Message::Search`] is answered with
//! [`Message::SearchOk`] (or a typed [`Message::Error`]) echoing the
//! request id; on a v2 connection a request carrying a trace id gets the
//! per-query stage timings back in the response tail. A frame that fails
//! to decode gets a best-effort typed error frame and the connection is
//! closed — after a malformed frame the stream may be desynchronized, and
//! reconnecting is the one safe resync.
//!
//! Every worker owns a private metrics [`Registry`] — query counters,
//! end-to-end query duration, per-stage histograms — which the gateway
//! federates over the v2 `MetricsPull`/`MetricsText` frames. A metrics
//! scrape deliberately bumps no query counters: the scraped snapshot must
//! equal the worker's own registry bit-for-bit.

use crate::data::store;
use crate::error::Result;
use crate::index::AnnIndex;
use crate::rpc::{
    is_timeout, version_supported, FramedTcp, Message, WireTrace, MIN_PROTOCOL_VERSION,
    PROTOCOL_VERSION,
};
use crate::telemetry::{registry, Registry, SearchTrace};
use crate::util::timer::Stopwatch;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

/// Read-poll interval: how often a blocked connection handler rechecks the
/// stop flag. Bounds both shutdown latency and the window in which an
/// abruptly killed worker still holds its sockets.
const POLL: Duration = Duration::from_millis(50);

/// Accept-poll interval for the non-blocking listener.
const ACCEPT_POLL: Duration = Duration::from_millis(3);

/// Serve `index` as the shard covering global rows `start..start+len` until
/// `stop` is set, recording into a private registry (discarded on return —
/// use [`serve_shard_observed`] to keep a handle for federation). Runs the
/// accept loop on the calling thread; one handler thread per connection.
pub fn serve_shard(
    listener: TcpListener,
    index: Arc<dyn AnnIndex>,
    start: usize,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    serve_shard_observed(listener, index, start, stop, Arc::new(Registry::new()))
}

/// [`serve_shard`] publishing into a caller-owned `registry` — the one the
/// worker answers `MetricsPull` scrapes from.
pub fn serve_shard_observed(
    listener: TcpListener,
    index: Arc<dyn AnnIndex>,
    start: usize,
    stop: Arc<AtomicBool>,
    registry: Arc<Registry>,
) -> Result<()> {
    listener.set_nonblocking(true)?;
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    // ORDERING: Relaxed — stop flag polled once per accept slice; shutdown
    // synchronizes through the join in `kill`, not through this load.
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let idx = Arc::clone(&index);
                let stop2 = Arc::clone(&stop);
                let reg = Arc::clone(&registry);
                handlers.push(thread::spawn(move || {
                    let _ = stream.set_nonblocking(false);
                    handle_conn(stream, idx.as_ref(), start, &stop2, &reg);
                }));
                handlers.retain(|h| !h.is_finished());
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(ACCEPT_POLL);
            }
            Err(_) => break,
        }
    }
    // Handlers observe the stop flag within one poll interval; join so the
    // worker's sockets are really gone when this returns.
    for h in handlers {
        let _ = h.join();
    }
    Ok(())
}

/// The worker-side instruments one connection handler touches.
struct WorkerMetrics {
    queries: Arc<crate::telemetry::Counter>,
    duration: Arc<crate::telemetry::LatencyHistogram>,
    queue_wait: Arc<crate::telemetry::LatencyHistogram>,
    scan: Arc<crate::telemetry::LatencyHistogram>,
    rerank: Arc<crate::telemetry::LatencyHistogram>,
    merge: Arc<crate::telemetry::LatencyHistogram>,
}

impl WorkerMetrics {
    fn new(reg: &Registry) -> WorkerMetrics {
        WorkerMetrics {
            queries: reg.counter(registry::WORKER_QUERIES_TOTAL, &[]),
            duration: reg.histogram(registry::WORKER_QUERY_DURATION, &[]),
            queue_wait: reg.histogram(registry::STAGE_DURATION, &[("stage", "queue_wait")]),
            scan: reg.histogram(registry::STAGE_DURATION, &[("stage", "scan")]),
            rerank: reg.histogram(registry::STAGE_DURATION, &[("stage", "rerank")]),
            merge: reg.histogram(registry::STAGE_DURATION, &[("stage", "merge")]),
        }
    }
}

/// One connection: handshake, then a request loop. Returns when the client
/// disconnects, a frame fails to decode, or `stop` is set.
fn handle_conn(
    stream: TcpStream,
    index: &dyn AnnIndex,
    start: usize,
    stop: &AtomicBool,
    registry: &Arc<Registry>,
) {
    let mut conn = FramedTcp::new(stream);
    if conn.set_deadline(POLL).is_err() {
        return;
    }
    // Handshake: the first decoded frame must be a Hello inside the
    // supported version window; the connection then speaks the client's
    // version (a v1 client never sees tails or metrics frames).
    let mut negotiated = PROTOCOL_VERSION;
    loop {
        // ORDERING: Relaxed — stop flag; eventual visibility within one
        // read-timeout slice is all shutdown latency depends on.
        if stop.load(Ordering::Relaxed) {
            return;
        }
        match conn.recv() {
            Ok((rid, Message::Hello { version })) => {
                if !version_supported(version) {
                    let _ = conn.send(
                        rid,
                        &Message::Error {
                            message: format!(
                                "worker speaks rpc versions {MIN_PROTOCOL_VERSION}..={PROTOCOL_VERSION}, client sent {version}"
                            ),
                        },
                    );
                    return;
                }
                negotiated = version.min(PROTOCOL_VERSION);
                let ack = Message::HelloAck {
                    version: negotiated,
                    start: start as u64,
                    len: index.len() as u64,
                    dim: index.dim() as u32,
                };
                if conn.send(rid, &ack).is_err() {
                    return;
                }
                break;
            }
            Ok((rid, other)) => {
                let _ = conn.send(
                    rid,
                    &Message::Error {
                        message: format!("expected hello, got {}", other.kind_name()),
                    },
                );
                return;
            }
            Err(e) if is_timeout(&e) => continue,
            Err(e) => {
                // Malformed frame (bad magic/crc/kind) — answer with the
                // typed reason, then close: the stream may be mid-frame.
                let _ = conn.send(0, &Message::Error { message: e.to_string() });
                return;
            }
        }
    }
    let wm = WorkerMetrics::new(registry);
    // Request loop.
    loop {
        // ORDERING: Relaxed — same stop flag as the handshake loop above.
        if stop.load(Ordering::Relaxed) {
            return;
        }
        match conn.recv() {
            Ok((rid, Message::Search { k, query, trace_id })) => {
                let decoded_at = Instant::now();
                let sw = Stopwatch::start();
                // Per-query stage splits come from a detached trace; its
                // totals feed both the response tail and the worker's own
                // registry histograms.
                let trace = SearchTrace::detached();
                let queue_wait = decoded_at.elapsed();
                let reply = match index.search_traced(&query, k as usize, &trace) {
                    Ok(neighbors) => {
                        let (scan, rerank, merge) =
                            (trace.scan.total(), trace.rerank.total(), trace.merge.total());
                        wm.queue_wait.record(queue_wait);
                        wm.scan.record(scan);
                        wm.rerank.record(rerank);
                        wm.merge.record(merge);
                        Message::SearchOk {
                            neighbors: neighbors
                                .into_iter()
                                .map(|nb| ((nb.index + start) as u64, nb.distance))
                                .collect(),
                            // The tail travels only on v2 connections and
                            // only when the request carried a trace id.
                            trace: trace_id
                                .filter(|_| negotiated >= 2)
                                .map(|tid| WireTrace {
                                    trace_id: tid,
                                    queue_ns: queue_wait.as_nanos() as u64,
                                    scan_ns: scan.as_nanos() as u64,
                                    rerank_ns: rerank.as_nanos() as u64,
                                    merge_ns: merge.as_nanos() as u64,
                                }),
                        }
                    }
                    Err(e) => Message::Error { message: e.to_string() },
                };
                wm.queries.inc();
                wm.duration.record(sw.elapsed());
                if conn.send(rid, &reply).is_err() {
                    return;
                }
            }
            Ok((rid, Message::MetricsPull)) => {
                // A scrape bumps no query counters: the snapshot must stay
                // bit-for-bit equal to the registry it copies.
                let reply = Message::MetricsText { text: registry.encode_snapshot() };
                if conn.send(rid, &reply).is_err() {
                    return;
                }
            }
            Ok((rid, Message::Ping)) => {
                if conn.send(rid, &Message::Pong).is_err() {
                    return;
                }
            }
            Ok((rid, other)) => {
                let _ = conn.send(
                    rid,
                    &Message::Error {
                        message: format!("unexpected {} frame", other.kind_name()),
                    },
                );
                return;
            }
            Err(e) if is_timeout(&e) => continue,
            Err(e) => {
                if matches!(&e, crate::error::OpdrError::Io(_)) {
                    // EOF / reset: the client went away; nothing to tell it.
                    return;
                }
                let _ = conn.send(0, &Message::Error { message: e.to_string() });
                return;
            }
        }
    }
}

/// An in-process shard worker on a loopback listener — the test double for
/// a worker process (real processes go through
/// [`crate::dist::ProcessWorker`]). `kill` is abrupt: the stop flag drops
/// every live connection within one poll interval, which is how the
/// crash/degraded-serving tests sever a shard mid-storm.
#[derive(Debug)]
pub struct ThreadWorker {
    addr: String,
    stop: Arc<AtomicBool>,
    registry: Arc<Registry>,
    handle: Option<JoinHandle<()>>,
}

impl ThreadWorker {
    /// Bind an ephemeral loopback port and serve `index` as the shard at
    /// global offset `start`.
    pub fn spawn(index: Arc<dyn AnnIndex>, start: usize) -> Result<ThreadWorker> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let registry = Arc::new(Registry::new());
        let reg2 = Arc::clone(&registry);
        let handle = thread::spawn(move || {
            let _ = serve_shard_observed(listener, index, start, stop2, reg2);
        });
        Ok(ThreadWorker { addr, stop, registry, handle: Some(handle) })
    }

    /// The worker's own metrics registry — the storage its `MetricsPull`
    /// snapshots copy, so federation tests can compare bit-for-bit.
    pub fn registry(&self) -> Arc<Registry> {
        Arc::clone(&self.registry)
    }

    /// [`ThreadWorker::spawn`] loading the shard from an `OPDR` file —
    /// version-5 files reload via mmap, which is what makes supervised
    /// respawn ~0 time.
    pub fn spawn_from_file(path: &str, start: usize) -> Result<ThreadWorker> {
        let index: Arc<dyn AnnIndex> = Arc::from(store::load_index(path)?);
        ThreadWorker::spawn(index, start)
    }

    /// The worker's `host:port`.
    pub fn addr(&self) -> String {
        self.addr.clone()
    }

    /// The stop flag — lets a test kill the worker out from under its
    /// supervisor, exactly like a crash.
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// True while the serve loop is running.
    pub fn is_alive(&self) -> bool {
        self.handle.as_ref().is_some_and(|h| !h.is_finished())
    }

    /// Stop serving and join the serve loop.
    pub fn kill(&mut self) {
        // ORDERING: Relaxed — stop flag; the join below is the real
        // synchronization point with the serve loop.
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ThreadWorker {
    fn drop(&mut self) {
        self.kill();
    }
}

/// Process entrypoint for the `serve-worker` CLI verb: load the shard from
/// `path` (version-5 files mmap their annex in place), bind `listen`, print
/// `listening <addr>` on stdout (the parent parses it to learn the
/// ephemeral port) and serve until the process is killed.
pub fn run_worker_from_file(path: &str, start: usize, listen: &str, heap: bool) -> Result<()> {
    let index: Arc<dyn AnnIndex> = if heap {
        Arc::from(store::load_index_heap(path)?)
    } else {
        Arc::from(store::load_index(path)?)
    };
    let listener = TcpListener::bind(listen)?;
    println!("listening {}", listener.local_addr()?);
    use std::io::Write;
    let _ = std::io::stdout().flush();
    // A process worker's registry is reachable only over `MetricsPull`, so
    // it lives here and dies with the process.
    serve_shard_observed(
        listener,
        index,
        start,
        Arc::new(AtomicBool::new(false)),
        Arc::new(Registry::new()),
    )
}
