//! The shard worker: one process (or in-process thread) serving k-NN over a
//! contiguous global-ID range of a collection.
//!
//! A worker owns an [`AnnIndex`] — typically loaded from a version-5 `OPDR`
//! cold file, so a supervisor respawn remaps the mmap'd annex and is back
//! serving in ~0 time — plus the shard's global row offset. Search hits are
//! remapped to global ids *worker-side* (`local id + start`), so the
//! gateway's scatter-gather is a plain [`crate::knn::merge_top_k`] over
//! `(global id, distance)` pairs, bit-identical to an in-process shard
//! merge.
//!
//! The accept loop is poll-based (non-blocking accept + a stop flag) and
//! every connection is handled on its own thread with a short read poll, so
//! a stalled or desynchronized client never blocks other connections and a
//! stop request tears the worker down within one poll interval — that
//! abrupt teardown is exactly what the crash/restart tests exercise.
//!
//! Protocol per connection: the client opens with [`Message::Hello`]; the
//! worker validates the protocol version and answers [`Message::HelloAck`]
//! carrying `(start, len, dim)`. Then each [`Message::Search`] is answered
//! with [`Message::SearchOk`] (or a typed [`Message::Error`]) echoing the
//! request id. A frame that fails to decode gets a best-effort typed error
//! frame and the connection is closed — after a malformed frame the stream
//! may be desynchronized, and reconnecting is the one safe resync.

use crate::data::store;
use crate::error::Result;
use crate::index::AnnIndex;
use crate::rpc::{is_timeout, FramedTcp, Message, PROTOCOL_VERSION};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::Duration;

/// Read-poll interval: how often a blocked connection handler rechecks the
/// stop flag. Bounds both shutdown latency and the window in which an
/// abruptly killed worker still holds its sockets.
const POLL: Duration = Duration::from_millis(50);

/// Accept-poll interval for the non-blocking listener.
const ACCEPT_POLL: Duration = Duration::from_millis(3);

/// Serve `index` as the shard covering global rows `start..start+len` until
/// `stop` is set. Runs the accept loop on the calling thread; one handler
/// thread per connection.
pub fn serve_shard(
    listener: TcpListener,
    index: Arc<dyn AnnIndex>,
    start: usize,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    listener.set_nonblocking(true)?;
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let idx = Arc::clone(&index);
                let stop2 = Arc::clone(&stop);
                handlers.push(thread::spawn(move || {
                    let _ = stream.set_nonblocking(false);
                    handle_conn(stream, idx.as_ref(), start, &stop2);
                }));
                handlers.retain(|h| !h.is_finished());
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(ACCEPT_POLL);
            }
            Err(_) => break,
        }
    }
    // Handlers observe the stop flag within one poll interval; join so the
    // worker's sockets are really gone when this returns.
    for h in handlers {
        let _ = h.join();
    }
    Ok(())
}

/// One connection: handshake, then a request loop. Returns when the client
/// disconnects, a frame fails to decode, or `stop` is set.
fn handle_conn(stream: TcpStream, index: &dyn AnnIndex, start: usize, stop: &AtomicBool) {
    let mut conn = FramedTcp::new(stream);
    if conn.set_deadline(POLL).is_err() {
        return;
    }
    // Handshake: the first decoded frame must be a version-matched Hello.
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        match conn.recv() {
            Ok((rid, Message::Hello { version })) => {
                if version != PROTOCOL_VERSION {
                    let _ = conn.send(
                        rid,
                        &Message::Error {
                            message: format!(
                                "worker speaks rpc version {PROTOCOL_VERSION}, client sent {version}"
                            ),
                        },
                    );
                    return;
                }
                let ack = Message::HelloAck {
                    version: PROTOCOL_VERSION,
                    start: start as u64,
                    len: index.len() as u64,
                    dim: index.dim() as u32,
                };
                if conn.send(rid, &ack).is_err() {
                    return;
                }
                break;
            }
            Ok((rid, other)) => {
                let _ = conn.send(
                    rid,
                    &Message::Error {
                        message: format!("expected hello, got {}", other.kind_name()),
                    },
                );
                return;
            }
            Err(e) if is_timeout(&e) => continue,
            Err(e) => {
                // Malformed frame (bad magic/crc/kind) — answer with the
                // typed reason, then close: the stream may be mid-frame.
                let _ = conn.send(0, &Message::Error { message: e.to_string() });
                return;
            }
        }
    }
    // Request loop.
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        match conn.recv() {
            Ok((rid, Message::Search { k, query })) => {
                let reply = match index.search(&query, k as usize) {
                    Ok(neighbors) => Message::SearchOk {
                        neighbors: neighbors
                            .into_iter()
                            .map(|nb| ((nb.index + start) as u64, nb.distance))
                            .collect(),
                    },
                    Err(e) => Message::Error { message: e.to_string() },
                };
                if conn.send(rid, &reply).is_err() {
                    return;
                }
            }
            Ok((rid, Message::Ping)) => {
                if conn.send(rid, &Message::Pong).is_err() {
                    return;
                }
            }
            Ok((rid, other)) => {
                let _ = conn.send(
                    rid,
                    &Message::Error {
                        message: format!("unexpected {} frame", other.kind_name()),
                    },
                );
                return;
            }
            Err(e) if is_timeout(&e) => continue,
            Err(e) => {
                if matches!(&e, crate::error::OpdrError::Io(_)) {
                    // EOF / reset: the client went away; nothing to tell it.
                    return;
                }
                let _ = conn.send(0, &Message::Error { message: e.to_string() });
                return;
            }
        }
    }
}

/// An in-process shard worker on a loopback listener — the test double for
/// a worker process (real processes go through
/// [`crate::dist::ProcessWorker`]). `kill` is abrupt: the stop flag drops
/// every live connection within one poll interval, which is how the
/// crash/degraded-serving tests sever a shard mid-storm.
#[derive(Debug)]
pub struct ThreadWorker {
    addr: String,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl ThreadWorker {
    /// Bind an ephemeral loopback port and serve `index` as the shard at
    /// global offset `start`.
    pub fn spawn(index: Arc<dyn AnnIndex>, start: usize) -> Result<ThreadWorker> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = thread::spawn(move || {
            let _ = serve_shard(listener, index, start, stop2);
        });
        Ok(ThreadWorker { addr, stop, handle: Some(handle) })
    }

    /// [`ThreadWorker::spawn`] loading the shard from an `OPDR` file —
    /// version-5 files reload via mmap, which is what makes supervised
    /// respawn ~0 time.
    pub fn spawn_from_file(path: &str, start: usize) -> Result<ThreadWorker> {
        let index: Arc<dyn AnnIndex> = Arc::from(store::load_index(path)?);
        ThreadWorker::spawn(index, start)
    }

    /// The worker's `host:port`.
    pub fn addr(&self) -> String {
        self.addr.clone()
    }

    /// The stop flag — lets a test kill the worker out from under its
    /// supervisor, exactly like a crash.
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// True while the serve loop is running.
    pub fn is_alive(&self) -> bool {
        self.handle.as_ref().is_some_and(|h| !h.is_finished())
    }

    /// Stop serving and join the serve loop.
    pub fn kill(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ThreadWorker {
    fn drop(&mut self) {
        self.kill();
    }
}

/// Process entrypoint for the `serve-worker` CLI verb: load the shard from
/// `path` (version-5 files mmap their annex in place), bind `listen`, print
/// `listening <addr>` on stdout (the parent parses it to learn the
/// ephemeral port) and serve until the process is killed.
pub fn run_worker_from_file(path: &str, start: usize, listen: &str, heap: bool) -> Result<()> {
    let index: Arc<dyn AnnIndex> = if heap {
        Arc::from(store::load_index_heap(path)?)
    } else {
        Arc::from(store::load_index(path)?)
    };
    let listener = TcpListener::bind(listen)?;
    println!("listening {}", listener.local_addr()?);
    use std::io::Write;
    let _ = std::io::stdout().flush();
    serve_shard(listener, index, start, Arc::new(AtomicBool::new(false)))
}
