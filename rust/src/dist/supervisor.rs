//! Worker lifecycle: process spawning and a supervisor that respawns
//! crashed workers with exponential backoff.
//!
//! The supervisor owns one worker (thread-backed in tests, a real child
//! process under `serve-demo --distributed`) through the [`WorkerHandle`]
//! trait. A monitor thread polls liveness; when the worker dies it flips
//! the `opdr_rpc_worker_up` gauge to 0, bumps
//! `opdr_rpc_worker_restarts_total`, sleeps an exponentially growing
//! backoff (so a crash-looping shard can't busy-spin the box), respawns
//! via the caller's factory closure and publishes the new address into the
//! shared [`AddrCell`] — which is all the gateway needs: its next query
//! re-dials the cell and the respawned worker mmap-reloads its version-5
//! shard file, so recovery is bounded by the backoff, not by an index
//! rebuild.

use super::gateway::AddrCell;
use crate::error::{OpdrError, Result};
use crate::telemetry::registry::{RPC_WORKER_RESTARTS, RPC_WORKER_UP};
use crate::telemetry::Registry;
use std::io::BufRead;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

/// Liveness-poll interval of the monitor thread.
const MONITOR_POLL: Duration = Duration::from_millis(20);
/// First respawn delay; doubles per consecutive crash.
const BACKOFF_BASE: Duration = Duration::from_millis(10);
/// Backoff ceiling.
const BACKOFF_CAP: Duration = Duration::from_secs(2);
/// A worker that stayed up this long resets the backoff to the base.
const STABLE_UPTIME: Duration = Duration::from_secs(1);

/// A supervised worker incarnation: something listening on an address that
/// can be liveness-checked and killed. Implemented by
/// [`crate::dist::ThreadWorker`] (in-process, for tests) and
/// [`ProcessWorker`] (a real child process).
pub trait WorkerHandle: Send {
    /// The worker's `host:port`.
    fn addr(&self) -> String;
    /// True while the worker is serving.
    fn is_alive(&mut self) -> bool;
    /// Tear the worker down (idempotent, best-effort).
    fn kill(&mut self);
}

impl WorkerHandle for super::worker::ThreadWorker {
    fn addr(&self) -> String {
        super::worker::ThreadWorker::addr(self)
    }
    fn is_alive(&mut self) -> bool {
        super::worker::ThreadWorker::is_alive(self)
    }
    fn kill(&mut self) {
        super::worker::ThreadWorker::kill(self)
    }
}

/// A shard worker running as a child process (the `serve-worker` CLI verb).
/// The child prints `listening <addr>` on stdout once bound; spawn blocks
/// until that line arrives so the caller always gets a dialable address.
#[derive(Debug)]
pub struct ProcessWorker {
    child: Child,
    addr: String,
}

impl ProcessWorker {
    /// Spawn `cmd` (stdout piped, stderr inherited) and parse the
    /// `listening <addr>` banner. A child that exits before printing it is
    /// a typed spawn failure, not a hang.
    pub fn spawn(mut cmd: Command) -> Result<ProcessWorker> {
        cmd.stdout(Stdio::piped()).stdin(Stdio::null());
        let mut child = cmd.spawn()?;
        let stdout = child
            .stdout
            .take()
            .ok_or_else(|| OpdrError::runtime("worker child has no stdout pipe"))?;
        let mut lines = std::io::BufReader::new(stdout).lines();
        let banner = match lines.next() {
            Some(Ok(line)) => line,
            Some(Err(e)) => {
                let _ = child.kill();
                let _ = child.wait();
                return Err(OpdrError::Io(e));
            }
            None => {
                let _ = child.kill();
                let _ = child.wait();
                return Err(OpdrError::runtime("worker child exited before reporting its address"));
            }
        };
        let addr = match banner.strip_prefix("listening ") {
            Some(a) if !a.trim().is_empty() => a.trim().to_string(),
            _ => {
                let _ = child.kill();
                let _ = child.wait();
                return Err(OpdrError::runtime(format!(
                    "worker child printed `{banner}`, expected `listening <addr>`"
                )));
            }
        };
        // Nobody reads the pipe after the banner; workers print nothing
        // else, so the pipe can never fill and stall the child.
        drop(lines);
        Ok(ProcessWorker { child, addr })
    }
}

impl WorkerHandle for ProcessWorker {
    fn addr(&self) -> String {
        self.addr.clone()
    }
    fn is_alive(&mut self) -> bool {
        matches!(self.child.try_wait(), Ok(None))
    }
    fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for ProcessWorker {
    fn drop(&mut self) {
        self.kill();
    }
}

/// Respawns a crashed worker with exponential backoff and keeps the
/// gateway's [`AddrCell`] pointed at the live incarnation.
pub struct Supervisor {
    name: String,
    stop: Arc<AtomicBool>,
    restarts: Arc<AtomicU64>,
    handle: Option<JoinHandle<()>>,
}

impl Supervisor {
    /// Spawn the first incarnation via `factory` (synchronously, so `cell`
    /// holds a dialable address on return) and start the monitor thread.
    /// Every respawn calls `factory` again and rewrites `cell`.
    pub fn start(
        name: impl Into<String>,
        cell: Arc<AddrCell>,
        mut factory: Box<dyn FnMut() -> Result<Box<dyn WorkerHandle>> + Send>,
        registry: Arc<Registry>,
    ) -> Result<Supervisor> {
        let name = name.into();
        let labels = [("worker", name.as_str())];
        let up = registry.gauge(RPC_WORKER_UP, &labels);
        let restarts_metric = registry.counter(RPC_WORKER_RESTARTS, &labels);
        let mut worker = factory()?;
        cell.write_addr(worker.addr());
        up.set(1.0);
        let stop = Arc::new(AtomicBool::new(false));
        let restarts = Arc::new(AtomicU64::new(0));
        let stop2 = Arc::clone(&stop);
        let restarts2 = Arc::clone(&restarts);
        let handle = thread::spawn(move || {
            let mut backoff = BACKOFF_BASE;
            let mut born = Instant::now();
            // ORDERING: Relaxed — a plain stop flag; the monitor only needs
            // to observe the store eventually (within one poll slice), and
            // shutdown synchronizes through the join, not this load.
            while !stop2.load(Ordering::Relaxed) {
                if worker.is_alive() {
                    if born.elapsed() >= STABLE_UPTIME {
                        backoff = BACKOFF_BASE;
                    }
                    thread::sleep(MONITOR_POLL);
                    continue;
                }
                // Crash detected.
                up.set(0.0);
                worker.kill(); // reap a half-dead incarnation
                if interruptible_sleep(&stop2, backoff) {
                    break;
                }
                backoff = (backoff * 2).min(BACKOFF_CAP);
                match factory() {
                    Ok(w) => {
                        worker = w;
                        cell.write_addr(worker.addr());
                        born = Instant::now();
                        restarts_metric.inc();
                        // ORDERING: Relaxed — monotonic restart counter;
                        // readers tolerate a stale total, nothing else is
                        // published through it.
                        restarts2.fetch_add(1, Ordering::Relaxed);
                        up.set(1.0);
                    }
                    Err(_) => {
                        // Respawn itself failed (port race, missing file);
                        // stay down and retry after the next, longer backoff.
                    }
                }
            }
            worker.kill();
            up.set(0.0);
        });
        Ok(Supervisor { name, stop, restarts, handle: Some(handle) })
    }

    /// The supervised worker's name (metric label).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Respawns performed so far.
    pub fn restarts(&self) -> u64 {
        // ORDERING: Relaxed — see the monitor's `fetch_add`; a stale
        // read of the counter is acceptable.
        self.restarts.load(Ordering::Relaxed)
    }

    /// Stop monitoring and kill the current incarnation.
    pub fn shutdown(&mut self) {
        // ORDERING: Relaxed — stop flag; `join` below is the real
        // synchronization point with the monitor thread.
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Supervisor {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Sleep up to `total` in short slices, returning true if `stop` was set —
/// so a capped backoff never delays supervisor shutdown by seconds.
fn interruptible_sleep(stop: &AtomicBool, total: Duration) -> bool {
    let slice = Duration::from_millis(5);
    let deadline = Instant::now() + total;
    while Instant::now() < deadline {
        // ORDERING: Relaxed — stop flag polled every slice; eventual
        // visibility is all shutdown latency depends on.
        if stop.load(Ordering::Relaxed) {
            return true;
        }
        thread::sleep(slice.min(deadline.saturating_duration_since(Instant::now())));
    }
    // ORDERING: Relaxed — same stop flag as above.
    stop.load(Ordering::Relaxed)
}
