//! The gateway: shard assignment plus order-exact scatter-gather over the
//! worker RPC, with degraded (`partial = true`) serving when a shard is
//! unreachable.
//!
//! The gateway owns the shard map: each worker slot carries a
//! [`WorkerSpec`] naming the shard and an [`AddrCell`] the supervisor
//! rewrites on respawn, so a worker that crashed and came back on a new
//! ephemeral port is re-dialed transparently. Per query the gateway fans
//! the request out to every slot concurrently, each under the configured
//! request deadline; per-shard top-k lists (already remapped to global ids
//! worker-side) merge through [`merge_top_k`] — the same bounded heap the
//! in-process sharded index uses, so a fully-healthy distributed answer is
//! **bitwise identical** to the unsharded one (machine-checked in
//! `tests/props.rs`).
//!
//! ## Degraded serving
//!
//! A slot that misses its deadline, fails to connect, or returns a
//! malformed frame contributes nothing to the merge; the query still
//! returns, flagged [`DistSearchResult::partial`], with
//! [`DistSearchResult::shards_ok`] of [`DistSearchResult::shards_total`]
//! healthy. The failed slot's connection is dropped (the stream may be
//! desynchronized) and re-dialed on the next query. Failures are never
//! silent: every outcome lands in the `opdr_rpc_*` metrics and the
//! per-worker `opdr_rpc_worker_up` liveness gauge.

use crate::config::DistConfig;
use crate::error::{OpdrError, Result};
use crate::knn::{merge_top_k, Neighbor};
use crate::rpc::{is_timeout, FramedTcp, Message, PROTOCOL_VERSION};
use crate::telemetry::registry::{
    RPC_DEADLINE_TOTAL, RPC_ERRORS_TOTAL, RPC_PARTIAL_TOTAL, RPC_REQUESTS_TOTAL,
    RPC_REQUEST_DURATION, RPC_WORKER_UP,
};
use crate::telemetry::{Counter, Gauge, LatencyHistogram, Registry};
use crate::util::timer::Stopwatch;
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A mutable worker address shared between the gateway and the supervisor:
/// respawned workers come back on fresh ephemeral ports, and rewriting the
/// cell is how the supervisor points the gateway at the new incarnation.
#[derive(Debug, Default)]
pub struct AddrCell {
    addr: Mutex<String>,
}

impl AddrCell {
    /// New cell holding `addr` (`host:port`).
    pub fn new(addr: impl Into<String>) -> Arc<AddrCell> {
        Arc::new(AddrCell { addr: Mutex::new(addr.into()) })
    }

    /// Current address.
    pub fn get(&self) -> String {
        self.addr.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }

    /// Replace the address (supervisor respawn path).
    pub fn set(&self, addr: impl Into<String>) {
        *self.addr.lock().unwrap_or_else(|p| p.into_inner()) = addr.into();
    }
}

/// One shard assignment: a stable name (metric label) plus the worker's
/// current address.
#[derive(Debug, Clone)]
pub struct WorkerSpec {
    /// Stable worker name (used as the `worker` metric label).
    pub name: String,
    /// Where the worker currently listens.
    pub addr: Arc<AddrCell>,
}

impl WorkerSpec {
    /// Spec with a fixed address.
    pub fn fixed(name: impl Into<String>, addr: impl Into<String>) -> WorkerSpec {
        WorkerSpec { name: name.into(), addr: AddrCell::new(addr) }
    }
}

/// A distributed search answer: merged neighbors plus the health of the
/// scatter that produced them. `partial == false` guarantees the neighbor
/// list is bitwise identical to the unsharded order-exact answer;
/// `partial == true` is the typed degraded result (never silently wrong —
/// surviving shards are still merged order-exactly).
#[derive(Debug, Clone)]
pub struct DistSearchResult {
    /// Merged top-k, ascending by (distance, global id).
    pub neighbors: Vec<Neighbor>,
    /// True when at least one shard contributed nothing before the
    /// deadline.
    pub partial: bool,
    /// Shards that answered in time.
    pub shards_ok: usize,
    /// Shards in the assignment.
    pub shards_total: usize,
}

/// Handshake-reported shard extent, kept for observability.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardInfo {
    /// First global row id.
    pub start: u64,
    /// Rows served.
    pub len: u64,
    /// Vector dimensionality.
    pub dim: u32,
}

struct Slot {
    spec: WorkerSpec,
    conn: Option<FramedTcp>,
    next_request_id: u64,
    info: ShardInfo,
    requests: Arc<Counter>,
    errors: Arc<Counter>,
    deadlines: Arc<Counter>,
    up: Arc<Gauge>,
    latency: Arc<LatencyHistogram>,
}

impl Slot {
    fn new(spec: WorkerSpec, registry: &Registry) -> Slot {
        let labels = [("worker", spec.name.as_str())];
        Slot {
            requests: registry.counter(RPC_REQUESTS_TOTAL, &labels),
            errors: registry.counter(RPC_ERRORS_TOTAL, &labels),
            deadlines: registry.counter(RPC_DEADLINE_TOTAL, &labels),
            up: registry.gauge(RPC_WORKER_UP, &labels),
            latency: registry.histogram(RPC_REQUEST_DURATION, &labels),
            spec,
            conn: None,
            next_request_id: 1,
            info: ShardInfo::default(),
        }
    }

    fn timeout_err(what: &str) -> OpdrError {
        OpdrError::Io(std::io::Error::new(
            std::io::ErrorKind::TimedOut,
            format!("rpc: {what} deadline exceeded"),
        ))
    }

    fn ensure_connected(&mut self, connect_timeout: Duration) -> Result<()> {
        if self.conn.is_some() {
            return Ok(());
        }
        let addr_str = self.spec.addr.get();
        let addr: SocketAddr = addr_str
            .parse()
            .map_err(|_| OpdrError::config(format!("rpc: bad worker address `{addr_str}`")))?;
        let dial = connect_timeout.max(Duration::from_millis(1));
        let stream = TcpStream::connect_timeout(&addr, dial)?;
        let mut conn = FramedTcp::new(stream);
        conn.set_deadline(connect_timeout)?;
        conn.send(0, &Message::Hello { version: PROTOCOL_VERSION })?;
        match conn.recv()? {
            (_, Message::HelloAck { version, start, len, dim }) => {
                if version != PROTOCOL_VERSION {
                    return Err(OpdrError::data(format!(
                        "rpc: worker `{}` speaks protocol {version}, gateway speaks {PROTOCOL_VERSION}",
                        self.spec.name
                    )));
                }
                self.info = ShardInfo { start, len, dim };
            }
            (_, Message::Error { message }) => {
                return Err(OpdrError::coordinator(format!(
                    "rpc: worker `{}` refused handshake: {message}",
                    self.spec.name
                )));
            }
            (_, other) => {
                return Err(OpdrError::data(format!(
                    "rpc: worker `{}` answered handshake with {}",
                    self.spec.name,
                    other.kind_name()
                )));
            }
        }
        self.conn = Some(conn);
        Ok(())
    }

    fn try_search(
        &mut self,
        query: &[f32],
        k: usize,
        connect_timeout: Duration,
        deadline: Duration,
    ) -> Result<Vec<(usize, f32)>> {
        self.ensure_connected(connect_timeout)?;
        let id = self.next_request_id;
        self.next_request_id += 1;
        let started = Instant::now();
        let conn = self.conn.as_mut().expect("connected above");
        conn.set_deadline(deadline)?;
        conn.send(id, &Message::Search { k: k as u32, query: query.to_vec() })?;
        loop {
            // Duplicated / reordered frames (and answers to requests we
            // already timed out) are discarded by request id; the loop is
            // bounded by the shrinking read deadline, never by frame count.
            let remaining = deadline
                .checked_sub(started.elapsed())
                .filter(|d| !d.is_zero())
                .ok_or_else(|| Slot::timeout_err("request"))?;
            conn.set_deadline(remaining)?;
            let (rid, msg) = conn.recv()?;
            if rid != id {
                continue;
            }
            return match msg {
                Message::SearchOk { neighbors } => {
                    let mut out = Vec::with_capacity(neighbors.len());
                    for (gid, dist) in neighbors {
                        let gid = usize::try_from(gid).map_err(|_| {
                            OpdrError::data("rpc: neighbor id exceeds the host's usize")
                        })?;
                        out.push((gid, dist));
                    }
                    Ok(out)
                }
                Message::Error { message } => Err(OpdrError::coordinator(format!(
                    "rpc: worker `{}`: {message}",
                    self.spec.name
                ))),
                other => Err(OpdrError::data(format!(
                    "rpc: worker `{}` answered search with {}",
                    self.spec.name,
                    other.kind_name()
                ))),
            };
        }
    }

    /// One scatter leg with metrics and connection hygiene.
    fn search(
        &mut self,
        query: &[f32],
        k: usize,
        connect_timeout: Duration,
        deadline: Duration,
    ) -> Result<Vec<(usize, f32)>> {
        let sw = Stopwatch::start();
        let out = self.try_search(query, k, connect_timeout, deadline);
        self.latency.record(sw.elapsed());
        self.requests.inc();
        match &out {
            Ok(_) => self.up.set(1.0),
            Err(e) => {
                // The stream may be mid-frame after any failure; drop it and
                // re-dial (possibly a respawned worker) on the next query.
                if let Some(conn) = self.conn.take() {
                    conn.shutdown();
                }
                self.up.set(0.0);
                if is_timeout(e) {
                    self.deadlines.inc();
                } else {
                    self.errors.inc();
                }
            }
        }
        out
    }
}

/// The scatter-gather front end over the shard workers.
pub struct Gateway {
    slots: Vec<Slot>,
    cfg: DistConfig,
    partial_total: Arc<Counter>,
    registry: Arc<Registry>,
}

impl Gateway {
    /// Gateway over `specs` (one slot per shard). Connections are dialed
    /// lazily on first use, so a gateway can start before its workers.
    pub fn new(specs: Vec<WorkerSpec>, cfg: DistConfig, registry: Arc<Registry>) -> Gateway {
        let slots = specs.into_iter().map(|s| Slot::new(s, &registry)).collect();
        let partial_total = registry.counter(RPC_PARTIAL_TOTAL, &[]);
        Gateway { slots, cfg, partial_total, registry }
    }

    /// The metrics registry the gateway publishes into.
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// Number of shards in the assignment.
    pub fn shards_total(&self) -> usize {
        self.slots.len()
    }

    /// Per-worker health from the last scatter: `(name, healthy)`.
    pub fn liveness(&self) -> Vec<(String, bool)> {
        self.slots.iter().map(|s| (s.spec.name.clone(), s.conn.is_some())).collect()
    }

    /// Scatter `query` to every shard, gather surviving top-k lists and
    /// merge them through the order-exact bounded heap. Always terminates
    /// within roughly `connect_timeout + request_deadline`; an unreachable
    /// shard degrades the answer to `partial = true` instead of failing or
    /// hanging it.
    pub fn search(&mut self, query: &[f32], k: usize) -> Result<DistSearchResult> {
        let shards_total = self.slots.len();
        if shards_total == 0 {
            return Err(OpdrError::config("gateway: no workers configured"));
        }
        let connect_timeout = Duration::from_millis(self.cfg.connect_timeout_ms.max(1));
        let deadline = Duration::from_millis(self.cfg.request_deadline_ms.max(1));
        let per_shard: Vec<Result<Vec<(usize, f32)>>> = std::thread::scope(|s| {
            let handles: Vec<_> = self
                .slots
                .iter_mut()
                .map(|slot| s.spawn(move || slot.search(query, k, connect_timeout, deadline)))
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        Err(OpdrError::coordinator("rpc: scatter thread panicked"))
                    })
                })
                .collect()
        });
        let mut shards_ok = 0usize;
        let mut candidates: Vec<(usize, f32)> = Vec::new();
        for hits in per_shard.into_iter().flatten() {
            shards_ok += 1;
            candidates.extend(hits);
        }
        let partial = shards_ok < shards_total;
        if partial {
            self.partial_total.inc();
        }
        let neighbors = merge_top_k(candidates, k)
            .into_iter()
            .map(|(index, distance)| Neighbor { index, distance })
            .collect();
        Ok(DistSearchResult { neighbors, partial, shards_ok, shards_total })
    }
}
