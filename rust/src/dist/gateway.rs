//! The gateway: shard assignment plus order-exact scatter-gather over the
//! worker RPC, with degraded (`partial = true`) serving when a shard is
//! unreachable.
//!
//! The gateway owns the shard map: each worker slot carries a
//! [`WorkerSpec`] naming the shard and an [`AddrCell`] the supervisor
//! rewrites on respawn, so a worker that crashed and came back on a new
//! ephemeral port is re-dialed transparently. Per query the gateway fans
//! the request out to every slot concurrently, each under the configured
//! request deadline; per-shard top-k lists (already remapped to global ids
//! worker-side) merge through [`merge_top_k`] — the same bounded heap the
//! in-process sharded index uses, so a fully-healthy distributed answer is
//! **bitwise identical** to the unsharded one (machine-checked in
//! `tests/props.rs`).
//!
//! ## Degraded serving
//!
//! A slot that misses its deadline, fails to connect, or returns a
//! malformed frame contributes nothing to the merge; the query still
//! returns, flagged [`DistSearchResult::partial`], with
//! [`DistSearchResult::shards_ok`] of [`DistSearchResult::shards_total`]
//! healthy. The failed slot's connection is dropped (the stream may be
//! desynchronized) and re-dialed on the next query. Failures are never
//! silent: every outcome lands in the `opdr_rpc_*` metrics and the
//! per-worker `opdr_rpc_worker_up` liveness gauge.
//!
//! ## Cluster-wide observability
//!
//! When [`DistConfig::tracing`] is on (the default) the gateway assigns
//! every query a trace id and carries it to each shard on the protocol-v2
//! `Search` tail; the `SearchOk` tail brings back the worker's
//! queue-wait/scan/rerank/merge stage splits, which land in the
//! `opdr_rpc_shard_stage_seconds{worker,stage}` histograms and — together
//! with the gateway-observed round trip, fault disposition, and merged
//! result checksum — in the [`FlightRecorder`] ring behind the
//! `SlowQueries` admin verb. A v1 worker (negotiated protocol < 2) simply
//! never sees a tail and never returns one; traces degrade to
//! gateway-side timing only.
//!
//! [`Gateway::cluster_metrics`] federates metrics: it scrapes every
//! worker's registry over `MetricsPull`/`MetricsText` (the lossless
//! snapshot encoding, not the rendered exposition, so histogram buckets
//! merge exactly) and renders one cluster exposition holding each sample
//! twice — once labeled `worker="<name>"` and once merged into the
//! unlabeled aggregate — plus the gateway's own registry. A dead worker
//! costs `opdr_rpc_worker_up 0` and an `opdr_rpc_scrape_errors_total`
//! tick, never a failed scrape.

use crate::config::DistConfig;
use crate::error::{OpdrError, Result};
use crate::knn::{merge_top_k, Neighbor};
use crate::metrics::Metric;
use crate::rpc::{
    crc32, is_timeout, version_supported, FramedTcp, Message, WireTrace, PROTOCOL_VERSION,
};
use crate::telemetry::registry::{
    RPC_DEADLINE_TOTAL, RPC_ERRORS_TOTAL, RPC_PARTIAL_TOTAL, RPC_REQUESTS_TOTAL,
    RPC_REQUEST_DURATION, RPC_SCRAPE_ERRORS_TOTAL, RPC_SHARD_STAGE_DURATION, RPC_WORKER_UP,
};
use crate::telemetry::{
    Counter, FlightRecorder, Gauge, LatencyHistogram, ProbeJob, QueryRecord, RecallProbe,
    Registry, ShardTiming,
};
use crate::util::timer::Stopwatch;
use crate::util::{lock_recover_ranked, ranks};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A mutable worker address shared between the gateway and the supervisor:
/// respawned workers come back on fresh ephemeral ports, and rewriting the
/// cell is how the supervisor points the gateway at the new incarnation.
#[derive(Debug, Default)]
pub struct AddrCell {
    addr: Mutex<String>,
}

impl AddrCell {
    /// New cell holding `addr` (`host:port`).
    pub fn new(addr: impl Into<String>) -> Arc<AddrCell> {
        Arc::new(AddrCell { addr: Mutex::new(addr.into()) })
    }

    /// Current address.
    pub fn read_addr(&self) -> String {
        lock_recover_ranked(&self.addr, ranks::DIST_SLOT).clone()
    }

    /// Replace the address (supervisor respawn path).
    pub fn write_addr(&self, addr: impl Into<String>) {
        *lock_recover_ranked(&self.addr, ranks::DIST_SLOT) = addr.into();
    }
}

/// One shard assignment: a stable name (metric label) plus the worker's
/// current address.
#[derive(Debug, Clone)]
pub struct WorkerSpec {
    /// Stable worker name (used as the `worker` metric label).
    pub name: String,
    /// Where the worker currently listens.
    pub addr: Arc<AddrCell>,
}

impl WorkerSpec {
    /// Spec with a fixed address.
    pub fn fixed(name: impl Into<String>, addr: impl Into<String>) -> WorkerSpec {
        WorkerSpec { name: name.into(), addr: AddrCell::new(addr) }
    }
}

/// A distributed search answer: merged neighbors plus the health of the
/// scatter that produced them. `partial == false` guarantees the neighbor
/// list is bitwise identical to the unsharded order-exact answer;
/// `partial == true` is the typed degraded result (never silently wrong —
/// surviving shards are still merged order-exactly).
#[derive(Debug, Clone)]
pub struct DistSearchResult {
    /// Merged top-k, ascending by (distance, global id).
    pub neighbors: Vec<Neighbor>,
    /// True when at least one shard contributed nothing before the
    /// deadline.
    pub partial: bool,
    /// Shards that answered in time.
    pub shards_ok: usize,
    /// Shards in the assignment.
    pub shards_total: usize,
}

/// Handshake-reported shard extent, kept for observability.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardInfo {
    /// First global row id.
    pub start: u64,
    /// Rows served.
    pub len: u64,
    /// Vector dimensionality.
    pub dim: u32,
}

/// Worker-reported stage names, in timeline order. Shared with the module
/// docs' metrics table and the flight-recorder dump.
const STAGES: [&str; 4] = ["queue_wait", "scan", "rerank", "merge"];

/// One scatter leg's full outcome: the hits (or typed failure), the
/// gateway-observed round trip, and the worker's v2 trace tail when the
/// negotiated protocol carried one.
struct ShardOutcome {
    hits: Result<Vec<(usize, f32)>>,
    rtt: Duration,
    wire: Option<WireTrace>,
}

struct Slot {
    spec: WorkerSpec,
    conn: Option<FramedTcp>,
    next_request_id: u64,
    /// Protocol version agreed at handshake (`min(worker, gateway)`).
    negotiated: u32,
    info: ShardInfo,
    requests: Arc<Counter>,
    errors: Arc<Counter>,
    deadlines: Arc<Counter>,
    scrape_errors: Arc<Counter>,
    up: Arc<Gauge>,
    latency: Arc<LatencyHistogram>,
    /// `opdr_rpc_shard_stage_seconds{worker,stage}`, indexed like
    /// [`STAGES`].
    stage_latency: [Arc<LatencyHistogram>; 4],
}

impl Slot {
    fn new(spec: WorkerSpec, registry: &Registry) -> Slot {
        let labels = [("worker", spec.name.as_str())];
        let stage_latency = STAGES.map(|stage| {
            registry.histogram(
                RPC_SHARD_STAGE_DURATION,
                &[("worker", spec.name.as_str()), ("stage", stage)],
            )
        });
        Slot {
            requests: registry.counter(RPC_REQUESTS_TOTAL, &labels),
            errors: registry.counter(RPC_ERRORS_TOTAL, &labels),
            deadlines: registry.counter(RPC_DEADLINE_TOTAL, &labels),
            scrape_errors: registry.counter(RPC_SCRAPE_ERRORS_TOTAL, &labels),
            up: registry.gauge(RPC_WORKER_UP, &labels),
            latency: registry.histogram(RPC_REQUEST_DURATION, &labels),
            stage_latency,
            spec,
            conn: None,
            next_request_id: 1,
            negotiated: PROTOCOL_VERSION,
            info: ShardInfo::default(),
        }
    }

    fn timeout_err(what: &str) -> OpdrError {
        OpdrError::Io(std::io::Error::new(
            std::io::ErrorKind::TimedOut,
            format!("rpc: {what} deadline exceeded"),
        ))
    }

    fn ensure_connected(&mut self, connect_timeout: Duration) -> Result<()> {
        if self.conn.is_some() {
            return Ok(());
        }
        let addr_str = self.spec.addr.read_addr();
        let addr: SocketAddr = addr_str
            .parse()
            .map_err(|_| OpdrError::config(format!("rpc: bad worker address `{addr_str}`")))?;
        let dial = connect_timeout.max(Duration::from_millis(1));
        let stream = TcpStream::connect_timeout(&addr, dial)?;
        let mut conn = FramedTcp::new(stream);
        conn.set_deadline(connect_timeout)?;
        conn.send(0, &Message::Hello { version: PROTOCOL_VERSION })?;
        match conn.recv()? {
            (_, Message::HelloAck { version, start, len, dim }) => {
                if !version_supported(version) {
                    return Err(OpdrError::data(format!(
                        "rpc: worker `{}` speaks protocol {version}, gateway supports {}..={PROTOCOL_VERSION}",
                        self.spec.name,
                        crate::rpc::MIN_PROTOCOL_VERSION,
                    )));
                }
                self.negotiated = version.min(PROTOCOL_VERSION);
                self.info = ShardInfo { start, len, dim };
            }
            (_, Message::Error { message }) => {
                return Err(OpdrError::coordinator(format!(
                    "rpc: worker `{}` refused handshake: {message}",
                    self.spec.name
                )));
            }
            (_, other) => {
                return Err(OpdrError::data(format!(
                    "rpc: worker `{}` answered handshake with {}",
                    self.spec.name,
                    other.kind_name()
                )));
            }
        }
        self.conn = Some(conn);
        Ok(())
    }

    fn try_search(
        &mut self,
        query: &[f32],
        k: usize,
        connect_timeout: Duration,
        deadline: Duration,
        trace_id: Option<u64>,
    ) -> Result<(Vec<(usize, f32)>, Option<WireTrace>)> {
        self.ensure_connected(connect_timeout)?;
        let id = self.next_request_id;
        self.next_request_id += 1;
        let started = Instant::now();
        // A v1 worker must never see a v2 tail; filtering here (not at the
        // caller) keeps the negotiation invariant in one place.
        let trace_id = trace_id.filter(|_| self.negotiated >= 2);
        let conn = self.conn.as_mut().expect("connected above");
        conn.set_deadline(deadline)?;
        conn.send(id, &Message::Search { k: k as u32, query: query.to_vec(), trace_id })?;
        loop {
            // Duplicated / reordered frames (and answers to requests we
            // already timed out) are discarded by request id; the loop is
            // bounded by the shrinking read deadline, never by frame count.
            let remaining = deadline
                .checked_sub(started.elapsed())
                .filter(|d| !d.is_zero())
                .ok_or_else(|| Slot::timeout_err("request"))?;
            conn.set_deadline(remaining)?;
            let (rid, msg) = conn.recv()?;
            if rid != id {
                continue;
            }
            return match msg {
                Message::SearchOk { neighbors, trace } => {
                    let mut out = Vec::with_capacity(neighbors.len());
                    for (gid, dist) in neighbors {
                        let gid = usize::try_from(gid).map_err(|_| {
                            OpdrError::data("rpc: neighbor id exceeds the host's usize")
                        })?;
                        out.push((gid, dist));
                    }
                    // A tail echoing a different trace id belongs to some
                    // other query (a corrupt or confused worker); keep the
                    // hits, discard the timing.
                    Ok((out, trace.filter(|t| Some(t.trace_id) == trace_id)))
                }
                Message::Error { message } => Err(OpdrError::coordinator(format!(
                    "rpc: worker `{}`: {message}",
                    self.spec.name
                ))),
                other => Err(OpdrError::data(format!(
                    "rpc: worker `{}` answered search with {}",
                    self.spec.name,
                    other.kind_name()
                ))),
            };
        }
    }

    /// One scatter leg with metrics and connection hygiene.
    fn search(
        &mut self,
        query: &[f32],
        k: usize,
        connect_timeout: Duration,
        deadline: Duration,
        trace_id: Option<u64>,
    ) -> ShardOutcome {
        let sw = Stopwatch::start();
        let out = self.try_search(query, k, connect_timeout, deadline, trace_id);
        let rtt = sw.elapsed();
        self.latency.record(rtt);
        self.requests.inc();
        let (hits, wire) = match out {
            Ok((hits, wire)) => {
                self.up.set(1.0);
                if let Some(t) = &wire {
                    for (h, ns) in self.stage_latency.iter().zip(t.stage_ns()) {
                        h.record(Duration::from_nanos(ns));
                    }
                }
                (Ok(hits), wire)
            }
            Err(e) => {
                // The stream may be mid-frame after any failure; drop it and
                // re-dial (possibly a respawned worker) on the next query.
                if let Some(conn) = self.conn.take() {
                    conn.shutdown();
                }
                self.up.set(0.0);
                if is_timeout(&e) {
                    self.deadlines.inc();
                } else {
                    self.errors.inc();
                }
                (Err(e), None)
            }
        };
        ShardOutcome { hits, rtt, wire }
    }

    /// Scrape the worker's metrics registry over `MetricsPull`, returning
    /// the lossless snapshot text. Same rid-echo/deadline discipline and
    /// connection hygiene as a search leg, but scrape outcomes land in
    /// `opdr_rpc_scrape_errors_total` rather than the query counters.
    fn pull_metrics(&mut self, connect_timeout: Duration, deadline: Duration) -> Result<String> {
        let out = self.try_pull_metrics(connect_timeout, deadline);
        match &out {
            Ok(_) => self.up.set(1.0),
            Err(_) => {
                if let Some(conn) = self.conn.take() {
                    conn.shutdown();
                }
                self.up.set(0.0);
                self.scrape_errors.inc();
            }
        }
        out
    }

    fn try_pull_metrics(
        &mut self,
        connect_timeout: Duration,
        deadline: Duration,
    ) -> Result<String> {
        self.ensure_connected(connect_timeout)?;
        if self.negotiated < 2 {
            return Err(OpdrError::data(format!(
                "rpc: worker `{}` speaks protocol {} (< 2), cannot scrape metrics",
                self.spec.name, self.negotiated
            )));
        }
        let id = self.next_request_id;
        self.next_request_id += 1;
        let started = Instant::now();
        let conn = self.conn.as_mut().expect("connected above");
        conn.set_deadline(deadline)?;
        conn.send(id, &Message::MetricsPull)?;
        loop {
            let remaining = deadline
                .checked_sub(started.elapsed())
                .filter(|d| !d.is_zero())
                .ok_or_else(|| Slot::timeout_err("scrape"))?;
            conn.set_deadline(remaining)?;
            let (rid, msg) = conn.recv()?;
            if rid != id {
                continue;
            }
            return match msg {
                Message::MetricsText { text } => Ok(text),
                Message::Error { message } => Err(OpdrError::coordinator(format!(
                    "rpc: worker `{}`: {message}",
                    self.spec.name
                ))),
                other => Err(OpdrError::data(format!(
                    "rpc: worker `{}` answered metrics-pull with {}",
                    self.spec.name,
                    other.kind_name()
                ))),
            };
        }
    }
}

/// A recall probe riding the distributed path: shadow-executes sampled
/// gateway answers against the attached corpus and publishes
/// `opdr_recall_probe_*` gauges into the gateway's registry.
struct ProbeAttachment {
    probe: RecallProbe,
    collection: String,
    /// Row-major corpus the workers collectively serve. Distributed
    /// serving ships unreduced vectors, so this doubles as both the
    /// serving-tier and full-fidelity matrix (`μ == recall` by
    /// construction — a drift between the two gauges would itself flag a
    /// bug).
    data: Arc<Vec<f32>>,
    dim: usize,
    metric: Metric,
}

/// The scatter-gather front end over the shard workers.
pub struct Gateway {
    slots: Vec<Slot>,
    cfg: DistConfig,
    partial_total: Arc<Counter>,
    registry: Arc<Registry>,
    /// Monotonic trace-id source. Plain counter, not a clock: ids need to
    /// be unique per gateway, not globally, and a counter keeps replays
    /// deterministic.
    trace_seq: AtomicU64,
    recorder: Arc<FlightRecorder>,
    probe: Option<ProbeAttachment>,
}

impl Gateway {
    /// Gateway over `specs` (one slot per shard). Connections are dialed
    /// lazily on first use, so a gateway can start before its workers.
    pub fn new(specs: Vec<WorkerSpec>, cfg: DistConfig, registry: Arc<Registry>) -> Gateway {
        let slots = specs.into_iter().map(|s| Slot::new(s, &registry)).collect();
        let partial_total = registry.counter(RPC_PARTIAL_TOTAL, &[]);
        let recorder = Arc::new(FlightRecorder::new(
            cfg.recorder_capacity,
            Duration::from_millis(cfg.slow_query_ms.max(1)),
        ));
        Gateway {
            slots,
            cfg,
            partial_total,
            registry,
            trace_seq: AtomicU64::new(0),
            recorder,
            probe: None,
        }
    }

    /// The metrics registry the gateway publishes into.
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// The slow-query flight recorder (the `SlowQueries` admin verb reads
    /// it through here).
    pub fn recorder(&self) -> &Arc<FlightRecorder> {
        &self.recorder
    }

    /// Attach a recall probe sampling one in `every` queries: each sampled
    /// answer is shadow-executed offline against `data` (the unreduced
    /// corpus the shards collectively serve) and `opdr_recall_probe_*`
    /// gauges land in the gateway registry. Replaces any prior attachment.
    pub fn attach_probe(
        &mut self,
        collection: impl Into<String>,
        data: Arc<Vec<f32>>,
        dim: usize,
        metric: Metric,
        every: usize,
    ) {
        let probe = RecallProbe::start(Arc::clone(&self.registry), every, 64);
        self.probe =
            Some(ProbeAttachment { probe, collection: collection.into(), data, dim, metric });
    }

    /// Detach the recall probe, draining its queue so every submitted
    /// sample is reflected in the gauges before this returns.
    pub fn detach_probe(&mut self) {
        if let Some(mut att) = self.probe.take() {
            att.probe.shutdown();
        }
    }

    /// Number of shards in the assignment.
    pub fn shards_total(&self) -> usize {
        self.slots.len()
    }

    /// Per-worker health from the last scatter: `(name, healthy)`.
    pub fn liveness(&self) -> Vec<(String, bool)> {
        self.slots.iter().map(|s| (s.spec.name.clone(), s.conn.is_some())).collect()
    }

    /// Scatter `query` to every shard, gather surviving top-k lists and
    /// merge them through the order-exact bounded heap. Always terminates
    /// within roughly `connect_timeout + request_deadline`; an unreachable
    /// shard degrades the answer to `partial = true` instead of failing or
    /// hanging it.
    pub fn search(&mut self, query: &[f32], k: usize) -> Result<DistSearchResult> {
        let shards_total = self.slots.len();
        if shards_total == 0 {
            return Err(OpdrError::config("gateway: no workers configured"));
        }
        let connect_timeout = Duration::from_millis(self.cfg.connect_timeout_ms.max(1));
        let deadline = Duration::from_millis(self.cfg.request_deadline_ms.max(1));
        // Ids start at 1 so a zero trace id on the wire always means
        // "untraced".
        // ORDERING: Relaxed — the counter only needs per-id uniqueness
        // (fetch_add is atomic at any ordering); no other memory is
        // published through the trace id.
        let trace_id =
            self.cfg.tracing.then(|| self.trace_seq.fetch_add(1, Ordering::Relaxed) + 1);
        let sw = Stopwatch::start();
        let outcomes: Vec<ShardOutcome> = std::thread::scope(|s| {
            let handles: Vec<_> = self
                .slots
                .iter_mut()
                .map(|slot| {
                    s.spawn(move || slot.search(query, k, connect_timeout, deadline, trace_id))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| ShardOutcome {
                        hits: Err(OpdrError::coordinator("rpc: scatter thread panicked")),
                        rtt: Duration::ZERO,
                        wire: None,
                    })
                })
                .collect()
        });
        let mut shards_ok = 0usize;
        let mut candidates: Vec<(usize, f32)> = Vec::new();
        for o in &outcomes {
            if let Ok(hits) = &o.hits {
                shards_ok += 1;
                candidates.extend_from_slice(hits);
            }
        }
        let partial = shards_ok < shards_total;
        if partial {
            self.partial_total.inc();
        }
        let neighbors: Vec<Neighbor> = merge_top_k(candidates, k)
            .into_iter()
            .map(|(index, distance)| Neighbor { index, distance })
            .collect();
        if let Some(tid) = trace_id {
            self.recorder.record(QueryRecord {
                trace_id: tid,
                k,
                partial,
                total: sw.elapsed(),
                result_checksum: merged_checksum(&neighbors),
                shards: self
                    .slots
                    .iter()
                    .zip(&outcomes)
                    .map(|(slot, o)| ShardTiming {
                        worker: slot.spec.name.clone(),
                        ok: o.hits.is_ok(),
                        error: o.hits.as_ref().err().map(|e| e.to_string()),
                        rtt: o.rtt,
                        stages: o.wire.map(|t| {
                            let [q, sc, re, me] = t.stage_ns().map(Duration::from_nanos);
                            (q, sc, re, me)
                        }),
                    })
                    .collect(),
            });
        }
        // Sample only complete answers: a partial answer's recall deficit
        // is a fault artifact, not a ranking-quality signal.
        if !partial {
            if let Some(att) = &self.probe {
                if att.probe.should_sample(&att.collection) {
                    att.probe.submit(ProbeJob {
                        collection: att.collection.clone(),
                        query_full: query.to_vec(),
                        query_serving: query.to_vec(),
                        k,
                        served: neighbors.iter().map(|n| n.index).collect(),
                        serving: Arc::clone(&att.data),
                        serving_dim: att.dim,
                        full: Arc::clone(&att.data),
                        full_dim: att.dim,
                        metric: att.metric,
                    });
                }
            }
        }
        Ok(DistSearchResult { neighbors, partial, shards_ok, shards_total })
    }

    /// Scrape every worker's registry snapshot over `MetricsPull`:
    /// `(worker name, snapshot text)` in slot order, `None` for a worker
    /// that could not be scraped (already reflected in
    /// `opdr_rpc_worker_up` and `opdr_rpc_scrape_errors_total`).
    pub fn scrape_metrics(&mut self) -> Vec<(String, Option<String>)> {
        let connect_timeout = Duration::from_millis(self.cfg.connect_timeout_ms.max(1));
        let deadline = Duration::from_millis(self.cfg.request_deadline_ms.max(1));
        self.slots
            .iter_mut()
            .map(|slot| {
                (slot.spec.name.clone(), slot.pull_metrics(connect_timeout, deadline).ok())
            })
            .collect()
    }

    /// Federate the cluster's metrics into one Prometheus exposition: every
    /// reachable worker's samples appear once labeled `worker="<name>"` and
    /// once merged into the unlabeled cluster aggregate, alongside the
    /// gateway's own registry (whose `opdr_rpc_worker_up` gauges report any
    /// worker the scrape could not reach). Never fails: a dead worker is a
    /// gauge flip, not an error.
    pub fn cluster_metrics(&mut self) -> String {
        let scraped = self.scrape_metrics();
        let cluster = Registry::new();
        for (i, (name, snap)) in scraped.iter().enumerate() {
            let Some(snap) = snap else { continue };
            let loaded = cluster
                .load_snapshot(snap, &[("worker", name.as_str())])
                .and_then(|()| cluster.load_snapshot(snap, &[]));
            if loaded.is_err() {
                // A malformed snapshot is a scrape failure discovered
                // after the transport succeeded; account for it the same
                // way and drop the (suspect) connection.
                let slot = &mut self.slots[i];
                if let Some(conn) = slot.conn.take() {
                    conn.shutdown();
                }
                slot.up.set(0.0);
                slot.scrape_errors.inc();
            }
        }
        // The gateway's own series (rpc_* health, probe gauges, liveness)
        // merge after the scrape so the worker_up flips above are visible.
        let _ = cluster.load_snapshot(&self.registry.encode_snapshot(), &[]);
        cluster.render()
    }
}

/// CRC-32 over the merged `(global id LE, distance-bits LE)` list — the
/// flight recorder's result fingerprint.
fn merged_checksum(neighbors: &[Neighbor]) -> u32 {
    let mut bytes = Vec::with_capacity(neighbors.len() * 12);
    for n in neighbors {
        bytes.extend_from_slice(&(n.index as u64).to_le_bytes());
        bytes.extend_from_slice(&n.distance.to_bits().to_le_bytes());
    }
    crc32(&bytes)
}
