//! Distributed serving: a scatter-gather gateway over shard-worker
//! processes, with supervised respawn and degraded (`partial = true`)
//! serving.
//!
//! The collection is split into contiguous global-ID ranges (the same
//! [`crate::index::shard::shard_ranges`] arithmetic the in-process sharded
//! index uses); each range is served by a [`worker`] — an in-process
//! [`ThreadWorker`] in tests, a real child process ([`ProcessWorker`],
//! spawned through the `serve-worker` CLI verb) in `serve-demo
//! --distributed N`. Workers load their shard from a version-5 `OPDR` cold
//! file, so a respawn remaps the mmap and is back serving in ~0 time.
//!
//! The [`Gateway`] owns the shard map and scatter-gathers every query
//! through [`crate::knn::merge_top_k`]; distances cross the wire as raw
//! little-endian f32 bits, so a fully-healthy distributed answer is
//! **bitwise identical** to the unsharded order-exact one. When a shard
//! misses its deadline or drops its socket the gateway returns the
//! surviving shards' merge flagged [`DistSearchResult::partial`] — never a
//! hang, never a silently wrong ranking. The [`Supervisor`] respawns
//! crashed workers with exponential backoff and repoints the gateway's
//! [`AddrCell`] at the new incarnation.
//!
//! The wire protocol (framing, CRC, deadlines, fault injection) lives in
//! [`crate::rpc`]; the fault matrix these guarantees are tested under is
//! `tests/dist_it.rs`.
//!
//! Cluster-wide observability rides the same protocol: protocol-v2 trace
//! tails carry a gateway-assigned trace id to every shard and bring back
//! per-stage worker timings (see [`Gateway`]'s module docs), the
//! `MetricsPull` frame federates every worker's registry into one
//! exposition ([`Gateway::cluster_metrics`]), and the last K query
//! timelines are held in a [`crate::telemetry::FlightRecorder`] for the
//! `SlowQueries` admin verb. The observability fault matrix is
//! `tests/dist_observability_it.rs`.

pub mod gateway;
pub mod supervisor;
pub mod worker;

pub use gateway::{AddrCell, DistSearchResult, Gateway, ShardInfo, WorkerSpec};
pub use supervisor::{ProcessWorker, Supervisor, WorkerHandle};
pub use worker::{run_worker_from_file, serve_shard, serve_shard_observed, ThreadWorker};
