//! Blocked pairwise-distance matrices.
//!
//! This is the pure-Rust fallback for the query hot path (the PJRT
//! `pairwise_topk` artifact is the accelerated path; see
//! [`crate::runtime`]). The squared-Euclidean case uses the same
//! `‖q‖² − 2QBᵀ + ‖b‖²` decomposition as the Pallas kernel so the two paths
//! are comparable term-for-term in tests.

use crate::error::{OpdrError, Result};
use crate::metrics::Metric;
use crate::util::float::norm_sq_f32;

/// Dense row-major `f32` distance matrix between `queries` (q×d) and `base`
/// (n×d); output is q×n.
pub fn pairwise_distances(
    queries: &[f32],
    base: &[f32],
    dim: usize,
    metric: Metric,
) -> Result<Vec<f32>> {
    if dim == 0 {
        return Err(OpdrError::shape("pairwise: dim must be > 0"));
    }
    if queries.len() % dim != 0 || base.len() % dim != 0 {
        return Err(OpdrError::shape("pairwise: data not a multiple of dim"));
    }
    let q = queries.len() / dim;
    let n = base.len() / dim;
    let mut out = vec![0.0f32; q * n];

    match metric {
        Metric::SqEuclidean | Metric::Euclidean => {
            // d²(x,y) = ‖x‖² − 2x·y + ‖y‖² — the matmul form. Precompute norms.
            let qn: Vec<f32> = (0..q).map(|i| norm_sq_f32(&queries[i * dim..(i + 1) * dim])).collect();
            let bn: Vec<f32> = (0..n).map(|j| norm_sq_f32(&base[j * dim..(j + 1) * dim])).collect();
            matmul_into(queries, base, dim, q, n, &mut out);
            for i in 0..q {
                let row = &mut out[i * n..(i + 1) * n];
                for (j, o) in row.iter_mut().enumerate() {
                    // o currently holds q·b
                    let mut d = qn[i] - 2.0 * *o + bn[j];
                    if d < 0.0 {
                        d = 0.0; // numerical floor
                    }
                    *o = if metric == Metric::Euclidean { d.sqrt() } else { d };
                }
            }
        }
        Metric::Cosine => {
            let qn: Vec<f32> = (0..q).map(|i| norm_sq_f32(&queries[i * dim..(i + 1) * dim]).sqrt()).collect();
            let bn: Vec<f32> = (0..n).map(|j| norm_sq_f32(&base[j * dim..(j + 1) * dim]).sqrt()).collect();
            matmul_into(queries, base, dim, q, n, &mut out);
            for i in 0..q {
                let row = &mut out[i * n..(i + 1) * n];
                for (j, o) in row.iter_mut().enumerate() {
                    let denom = qn[i] * bn[j];
                    *o = if denom == 0.0 { 1.0 } else { 1.0 - *o / denom };
                }
            }
        }
        Metric::NegDot => {
            matmul_into(queries, base, dim, q, n, &mut out);
            for o in &mut out {
                *o = -*o;
            }
        }
        Metric::Manhattan => {
            // No matmul form; blocked elementwise.
            for i in 0..q {
                let qi = &queries[i * dim..(i + 1) * dim];
                let row = &mut out[i * n..(i + 1) * n];
                for (j, o) in row.iter_mut().enumerate() {
                    *o = crate::metrics::manhattan(qi, &base[j * dim..(j + 1) * dim]);
                }
            }
        }
    }
    Ok(out)
}

/// Symmetric all-pairs distances of one set (n×n), exploiting symmetry.
pub fn pairwise_distances_symmetric(data: &[f32], dim: usize, metric: Metric) -> Result<Vec<f32>> {
    if dim == 0 || data.len() % dim != 0 {
        return Err(OpdrError::shape("pairwise_symmetric: bad dims"));
    }
    let n = data.len() / dim;
    let mut out = vec![0.0f32; n * n];
    for i in 0..n {
        let xi = &data[i * dim..(i + 1) * dim];
        for j in (i + 1)..n {
            let d = metric.distance(xi, &data[j * dim..(j + 1) * dim]);
            out[i * n + j] = d;
            out[j * n + i] = d;
        }
    }
    Ok(out)
}

/// `out[i*n + j] = queries_i · base_j` — blocked f32 GEMM-lite.
///
/// Perf-pass L3-1: the inner product uses the 8-accumulator
/// [`crate::util::float::dot_f32`] (ILP + vectorization), and base rows are
/// processed in 64-row blocks per query row so a block of `base` stays in L2
/// across the q queries.
fn matmul_into(queries: &[f32], base: &[f32], dim: usize, q: usize, n: usize, out: &mut [f32]) {
    const BLOCK: usize = 64;
    for jb in (0..n).step_by(BLOCK) {
        let jend = (jb + BLOCK).min(n);
        let mut i = 0;
        // 4-query micro-kernel: each base row is loaded once per 4 queries
        // (perf-pass L3-1c; register blocking halves memory traffic).
        while i + 4 <= q {
            let q0 = &queries[i * dim..(i + 1) * dim];
            let q1 = &queries[(i + 1) * dim..(i + 2) * dim];
            let q2 = &queries[(i + 2) * dim..(i + 3) * dim];
            let q3 = &queries[(i + 3) * dim..(i + 4) * dim];
            for j in jb..jend {
                let bj = &base[j * dim..(j + 1) * dim];
                let d = dot4(q0, q1, q2, q3, bj);
                out[i * n + j] = d[0];
                out[(i + 1) * n + j] = d[1];
                out[(i + 2) * n + j] = d[2];
                out[(i + 3) * n + j] = d[3];
            }
            i += 4;
        }
        while i < q {
            let qi = &queries[i * dim..(i + 1) * dim];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in jb..jend {
                let bj = &base[j * dim..(j + 1) * dim];
                orow[j] = crate::util::float::dot_f32(qi, bj);
            }
            i += 1;
        }
    }
}

/// Four simultaneous dot products against one base row, 8-wide accumulators.
#[inline]
fn dot4(q0: &[f32], q1: &[f32], q2: &[f32], q3: &[f32], b: &[f32]) -> [f32; 4] {
    let mut a0 = [0.0f32; 8];
    let mut a1 = [0.0f32; 8];
    let mut a2 = [0.0f32; 8];
    let mut a3 = [0.0f32; 8];
    let n = b.len();
    let chunks = n / 8;
    for c in 0..chunks {
        let o = c * 8;
        let bb: [f32; 8] = b[o..o + 8].try_into().unwrap();
        for l in 0..8 {
            a0[l] += q0[o + l] * bb[l];
            a1[l] += q1[o + l] * bb[l];
            a2[l] += q2[o + l] * bb[l];
            a3[l] += q3[o + l] * bb[l];
        }
    }
    let sum = |a: &[f32; 8]| (a[0] + a[1]) + (a[2] + a[3]) + ((a[4] + a[5]) + (a[6] + a[7]));
    let mut out = [sum(&a0), sum(&a1), sum(&a2), sum(&a3)];
    for i in chunks * 8..n {
        out[0] += q0[i] * b[i];
        out[1] += q1[i] * b[i];
        out[2] += q2[i] * b[i];
        out[3] += q3[i] * b[i];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(queries: &[f32], base: &[f32], dim: usize, metric: Metric) -> Vec<f32> {
        let q = queries.len() / dim;
        let n = base.len() / dim;
        let mut out = vec![0.0; q * n];
        for i in 0..q {
            for j in 0..n {
                out[i * n + j] =
                    metric.distance(&queries[i * dim..(i + 1) * dim], &base[j * dim..(j + 1) * dim]);
            }
        }
        out
    }

    #[test]
    fn matches_naive_all_metrics() {
        let mut rng = Rng::new(31);
        let dim = 17;
        let queries = rng.normal_vec_f32(5 * dim);
        let base = rng.normal_vec_f32(11 * dim);
        for metric in [
            Metric::Euclidean,
            Metric::SqEuclidean,
            Metric::Cosine,
            Metric::Manhattan,
            Metric::NegDot,
        ] {
            let fast = pairwise_distances(&queries, &base, dim, metric).unwrap();
            let slow = naive(&queries, &base, dim, metric);
            for (a, b) in fast.iter().zip(&slow) {
                assert!((a - b).abs() < 1e-3, "{}: {a} vs {b}", metric.name());
            }
        }
    }

    #[test]
    fn self_distance_zero() {
        let mut rng = Rng::new(2);
        let dim = 8;
        let x = rng.normal_vec_f32(4 * dim);
        let d = pairwise_distances(&x, &x, dim, Metric::SqEuclidean).unwrap();
        for i in 0..4 {
            assert!(d[i * 4 + i].abs() < 1e-4);
        }
    }

    #[test]
    fn symmetric_matches_general() {
        let mut rng = Rng::new(77);
        let dim = 6;
        let x = rng.normal_vec_f32(9 * dim);
        let s = pairwise_distances_symmetric(&x, dim, Metric::Euclidean).unwrap();
        let g = pairwise_distances(&x, &x, dim, Metric::Euclidean).unwrap();
        for (a, b) in s.iter().zip(&g) {
            assert!((a - b).abs() < 1e-4);
        }
        // Symmetry itself.
        for i in 0..9 {
            for j in 0..9 {
                assert_eq!(s[i * 9 + j], s[j * 9 + i]);
            }
        }
    }

    #[test]
    fn shape_errors() {
        assert!(pairwise_distances(&[1.0, 2.0], &[1.0], 0, Metric::Euclidean).is_err());
        assert!(pairwise_distances(&[1.0, 2.0, 3.0], &[1.0, 2.0], 2, Metric::Euclidean).is_err());
        assert!(pairwise_distances_symmetric(&[1.0, 2.0, 3.0], 2, Metric::Euclidean).is_err());
    }

    #[test]
    fn sqeuclidean_never_negative() {
        // Catastrophic cancellation in ‖x‖²−2xy+‖y‖² could go negative without the floor.
        let mut rng = Rng::new(4);
        let dim = 32;
        let base_point = rng.normal_vec_f32(dim);
        // Nearly identical points.
        let mut near = base_point.clone();
        near[0] += 1e-7;
        let d = pairwise_distances(&base_point, &near, dim, Metric::SqEuclidean).unwrap();
        assert!(d[0] >= 0.0);
    }
}
