//! Distance metrics over `f32` embedding vectors.
//!
//! The paper evaluates OPDR under Euclidean (L2), cosine and Manhattan
//! distances. All metrics here are *distances* (smaller = closer) so KNN code
//! is metric-agnostic. `SqEuclidean` is the L2 hot-path variant: it induces
//! the same neighbor ordering as L2 without the square root, and matches the
//! `‖q‖² − 2q·b + ‖b‖²` matmul expansion used by the Pallas kernel (L1) and
//! the `pairwise_topk` HLO artifact (L2).

pub mod pairwise;

pub use pairwise::{pairwise_distances, pairwise_distances_symmetric};

/// Supported distance metrics.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Metric {
    /// Euclidean (L2) distance.
    Euclidean,
    /// Squared Euclidean — same KNN ordering as L2, cheaper.
    SqEuclidean,
    /// Cosine distance `1 − cos(a, b)`; zero vectors treated as distance 1.
    Cosine,
    /// Manhattan (L1) distance.
    Manhattan,
    /// Negative dot product (maximum inner-product search as a distance).
    NegDot,
}

impl Metric {
    /// Parse from a config / CLI string.
    pub fn parse(s: &str) -> Option<Metric> {
        match s.to_ascii_lowercase().as_str() {
            "euclidean" | "l2" => Some(Metric::Euclidean),
            "sqeuclidean" | "l2sq" | "sql2" => Some(Metric::SqEuclidean),
            "cosine" | "cos" => Some(Metric::Cosine),
            "manhattan" | "l1" | "cityblock" => Some(Metric::Manhattan),
            "negdot" | "dot" | "mips" => Some(Metric::NegDot),
            _ => None,
        }
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            Metric::Euclidean => "euclidean",
            Metric::SqEuclidean => "sqeuclidean",
            Metric::Cosine => "cosine",
            Metric::Manhattan => "manhattan",
            Metric::NegDot => "negdot",
        }
    }

    /// Distance between two equal-length vectors.
    #[inline]
    pub fn distance(&self, a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        match self {
            Metric::Euclidean => sq_euclidean(a, b).sqrt(),
            Metric::SqEuclidean => sq_euclidean(a, b),
            Metric::Cosine => cosine_distance(a, b),
            Metric::Manhattan => manhattan(a, b),
            Metric::NegDot => -crate::util::float::dot_f32(a, b),
        }
    }

    /// Does zero-padding both vectors to a larger dimension preserve the
    /// distance exactly? True for every metric here — the property the padded
    /// fixed-shape HLO artifacts rely on.
    pub fn padding_invariant(&self) -> bool {
        true
    }
}

/// Squared Euclidean distance (8-accumulator form; see §Perf L3-1).
#[inline]
pub fn sq_euclidean(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0.0f32; 8];
    let ca = a.chunks_exact(8);
    let cb = b.chunks_exact(8);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        for l in 0..8 {
            let d = xa[l] - xb[l];
            acc[l] += d * d;
        }
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for i in 0..ra.len() {
        let d = ra[i] - rb[i];
        s += d * d;
    }
    s
}

/// Manhattan (L1) distance (8-accumulator form; see §Perf L3-1).
#[inline]
pub fn manhattan(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0.0f32; 8];
    let ca = a.chunks_exact(8);
    let cb = b.chunks_exact(8);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        for l in 0..8 {
            acc[l] += (xa[l] - xb[l]).abs();
        }
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for i in 0..ra.len() {
        s += (ra[i] - rb[i]).abs();
    }
    s
}

/// Cosine distance `1 − a·b/(‖a‖‖b‖)`; if either vector is zero, returns 1.
#[inline]
pub fn cosine_distance(a: &[f32], b: &[f32]) -> f32 {
    let dot = crate::util::float::dot_f32(a, b);
    let na = crate::util::float::norm_sq_f32(a).sqrt();
    let nb = crate::util::float::norm_sq_f32(b).sqrt();
    if na == 0.0 || nb == 0.0 {
        return 1.0;
    }
    1.0 - dot / (na * nb)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_aliases() {
        assert_eq!(Metric::parse("L2"), Some(Metric::Euclidean));
        assert_eq!(Metric::parse("cosine"), Some(Metric::Cosine));
        assert_eq!(Metric::parse("cityblock"), Some(Metric::Manhattan));
        assert_eq!(Metric::parse("bogus"), None);
    }

    #[test]
    fn known_distances() {
        let a = [0.0f32, 0.0];
        let b = [3.0f32, 4.0];
        assert_eq!(Metric::Euclidean.distance(&a, &b), 5.0);
        assert_eq!(Metric::SqEuclidean.distance(&a, &b), 25.0);
        assert_eq!(Metric::Manhattan.distance(&a, &b), 7.0);
    }

    #[test]
    fn cosine_identical_and_orthogonal() {
        let a = [1.0f32, 0.0];
        let b = [0.0f32, 1.0];
        assert!((Metric::Cosine.distance(&a, &a)).abs() < 1e-6);
        assert!((Metric::Cosine.distance(&a, &b) - 1.0).abs() < 1e-6);
        let c = [-1.0f32, 0.0];
        assert!((Metric::Cosine.distance(&a, &c) - 2.0).abs() < 1e-6);
    }

    #[test]
    fn cosine_zero_vector_safe() {
        let z = [0.0f32, 0.0];
        let a = [1.0f32, 1.0];
        assert_eq!(Metric::Cosine.distance(&z, &a), 1.0);
    }

    #[test]
    fn sq_euclidean_same_ordering_as_euclidean() {
        let q = [0.5f32, -1.0, 2.0];
        let xs = [[1.0f32, 0.0, 0.0], [0.0, -1.0, 2.0], [2.0, 2.0, 2.0]];
        // NaN-total order (hardening sweep): test oracles sort with
        // `total_cmp` so they can never be the thing that panics.
        let mut by_l2: Vec<usize> = (0..3).collect();
        by_l2.sort_by(|&i, &j| {
            Metric::Euclidean
                .distance(&q, &xs[i])
                .total_cmp(&Metric::Euclidean.distance(&q, &xs[j]))
        });
        let mut by_sq: Vec<usize> = (0..3).collect();
        by_sq.sort_by(|&i, &j| {
            Metric::SqEuclidean
                .distance(&q, &xs[i])
                .total_cmp(&Metric::SqEuclidean.distance(&q, &xs[j]))
        });
        assert_eq!(by_l2, by_sq);
    }

    #[test]
    fn zero_padding_preserves_all_metrics() {
        let a = [1.0f32, -2.0, 0.5];
        let b = [0.25f32, 1.5, -1.0];
        let pad =
            |v: &[f32]| -> Vec<f32> { v.iter().copied().chain(std::iter::repeat(0.0)).take(8).collect() };
        for m in [Metric::Euclidean, Metric::SqEuclidean, Metric::Cosine, Metric::Manhattan, Metric::NegDot] {
            let d0 = m.distance(&a, &b);
            let d1 = m.distance(&pad(&a), &pad(&b));
            assert!((d0 - d1).abs() < 1e-6, "{}: {d0} vs {d1}", m.name());
            assert!(m.padding_invariant());
        }
    }

    #[test]
    fn negdot_prefers_aligned() {
        let q = [1.0f32, 0.0];
        let aligned = [5.0f32, 0.0];
        let anti = [-5.0f32, 0.0];
        assert!(Metric::NegDot.distance(&q, &aligned) < Metric::NegDot.distance(&q, &anti));
    }
}
