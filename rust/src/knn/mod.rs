//! Exact and approximate k-nearest-neighbor search substrates.
//!
//! * [`topk`] — bounded-heap top-k selection over a distance row (the inner
//!   loop of every KNN query);
//! * [`brute`] — exact brute-force KNN used by the OPDR measure (the paper's
//!   ground truth is always exact KNN);
//! * [`ivf`] — an IVF-Flat inverted-file ANN index, the serving-scale
//!   substrate the coordinator uses for large collections.

pub mod brute;
pub mod ivf;
pub mod topk;

pub use brute::{knn_indices, knn_indices_all, Neighbor};
pub use ivf::IvfFlatIndex;
pub use topk::{merge_top_k, top_k_smallest};
