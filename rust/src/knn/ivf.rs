//! IVF-Flat inverted-file index — the serving-scale ANN substrate.
//!
//! The paper positions OPDR as a complement to vector indexes (FAISS, ScaNN,
//! HNSW): reduce the dimension first, then index. The coordinator uses this
//! index for collections above a size threshold; the measure/accuracy math
//! always uses exact [`crate::knn::brute`].
//!
//! Design: k-means (Lloyd) coarse quantizer with `nlist` centroids; queries
//! scan the `nprobe` nearest inverted lists exhaustively (flat).

use crate::error::{OpdrError, Result};
use crate::knn::topk::top_k_smallest;
use crate::knn::Neighbor;
use crate::metrics::Metric;
use crate::util::Rng;

/// IVF-Flat index over row-major f32 vectors.
#[derive(Debug, Clone)]
pub struct IvfFlatIndex {
    dim: usize,
    metric: Metric,
    nlist: usize,
    centroids: Vec<f32>,       // nlist × dim
    lists: Vec<Vec<usize>>,    // inverted lists of vector ids
    vectors: Vec<f32>,         // n × dim (owned copy)
}

impl IvfFlatIndex {
    /// Build an index with `nlist` coarse cells via Lloyd k-means
    /// (`train_iters` iterations, deterministic from `seed`).
    pub fn build(
        data: &[f32],
        dim: usize,
        metric: Metric,
        nlist: usize,
        train_iters: usize,
        seed: u64,
    ) -> Result<Self> {
        if dim == 0 || data.len() % dim != 0 {
            return Err(OpdrError::shape("ivf: bad data shape"));
        }
        let n = data.len() / dim;
        if n == 0 {
            return Err(OpdrError::data("ivf: empty data"));
        }
        let nlist = nlist.max(1).min(n);

        let mut rng = Rng::new(seed);
        let centroids = kmeans_train(data, dim, metric, nlist, train_iters, &mut rng);

        // Final assignment into inverted lists.
        let mut lists = vec![Vec::new(); nlist];
        for i in 0..n {
            let c = nearest_centroid(&data[i * dim..(i + 1) * dim], &centroids, dim, metric);
            lists[c].push(i);
        }

        Ok(IvfFlatIndex { dim, metric, nlist, centroids, lists, vectors: data.to_vec() })
    }

    /// Number of indexed vectors.
    pub fn len(&self) -> usize {
        self.vectors.len() / self.dim
    }

    /// True if the index holds no vectors.
    pub fn is_empty(&self) -> bool {
        self.vectors.is_empty()
    }

    /// Vector dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Number of inverted lists.
    pub fn nlist(&self) -> usize {
        self.nlist
    }

    /// Approximate k-NN search scanning the `nprobe` closest cells.
    /// `nprobe` is clamped to `[1, nlist]`; a query whose dimensionality
    /// does not match the index is rejected (never scanned as garbage).
    pub fn search(&self, query: &[f32], k: usize, nprobe: usize) -> Result<Vec<Neighbor>> {
        if query.len() != self.dim {
            return Err(OpdrError::shape(format!(
                "ivf search: query dim {} != index dim {}",
                query.len(),
                self.dim
            )));
        }
        let nprobe = nprobe.clamp(1, self.nlist);
        // Rank cells by centroid distance.
        let cdists: Vec<f32> = (0..self.nlist)
            .map(|c| self.metric.distance(query, &self.centroids[c * self.dim..(c + 1) * self.dim]))
            .collect();
        let cells = top_k_smallest(&cdists, nprobe);

        // Exhaustive scan within probed cells.
        let mut cand_idx = Vec::new();
        let mut cand_dist = Vec::new();
        for (c, _) in cells {
            for &vid in &self.lists[c] {
                let d = self
                    .metric
                    .distance(query, &self.vectors[vid * self.dim..(vid + 1) * self.dim]);
                cand_idx.push(vid);
                cand_dist.push(d);
            }
        }
        let picked = top_k_smallest(&cand_dist, k);
        Ok(picked
            .into_iter()
            .map(|(pos, distance)| Neighbor { index: cand_idx[pos], distance })
            .collect())
    }

    /// Recall@k of this index against exact brute-force on `queries`.
    pub fn recall_at_k(&self, queries: &[f32], k: usize, nprobe: usize) -> Result<f64> {
        if queries.len() % self.dim != 0 {
            return Err(OpdrError::shape("recall: bad query shape"));
        }
        let nq = queries.len() / self.dim;
        if nq == 0 {
            return Ok(1.0);
        }
        let mut hits = 0usize;
        let mut total = 0usize;
        for qi in 0..nq {
            let q = &queries[qi * self.dim..(qi + 1) * self.dim];
            let exact = crate::knn::knn_indices(q, &self.vectors, self.dim, k, self.metric)?;
            let approx = self.search(q, k, nprobe)?;
            let approx_set: std::collections::HashSet<usize> =
                approx.iter().map(|nb| nb.index).collect();
            for nb in &exact {
                total += 1;
                if approx_set.contains(&nb.index) {
                    hits += 1;
                }
            }
        }
        Ok(hits as f64 / total as f64)
    }
}

/// Lloyd k-means over row-major data: random distinct seeding, `train_iters`
/// assign/update rounds, empty cells re-seeded from random points. Returns
/// `nlist × dim` centroids. Deterministic given the RNG state; shared by
/// [`IvfFlatIndex`] and the coarse quantizer of [`crate::index::IvfIndex`].
pub(crate) fn kmeans_train(
    data: &[f32],
    dim: usize,
    metric: Metric,
    nlist: usize,
    train_iters: usize,
    rng: &mut Rng,
) -> Vec<f32> {
    let n = data.len() / dim;
    debug_assert!(nlist >= 1 && nlist <= n);
    let picks = rng.sample_indices(n, nlist);
    let mut centroids = vec![0.0f32; nlist * dim];
    for (c, &p) in picks.iter().enumerate() {
        centroids[c * dim..(c + 1) * dim].copy_from_slice(&data[p * dim..(p + 1) * dim]);
    }

    let mut assign = vec![0usize; n];
    for _ in 0..train_iters {
        // Assign.
        for i in 0..n {
            assign[i] = nearest_centroid(&data[i * dim..(i + 1) * dim], &centroids, dim, metric);
        }
        // Update.
        let mut sums = vec![0.0f64; nlist * dim];
        let mut counts = vec![0usize; nlist];
        for i in 0..n {
            let c = assign[i];
            counts[c] += 1;
            for k in 0..dim {
                sums[c * dim + k] += data[i * dim + k] as f64;
            }
        }
        for c in 0..nlist {
            if counts[c] == 0 {
                // Re-seed empty cell with a random point.
                let p = rng.below(n);
                centroids[c * dim..(c + 1) * dim].copy_from_slice(&data[p * dim..(p + 1) * dim]);
            } else {
                for k in 0..dim {
                    centroids[c * dim + k] = (sums[c * dim + k] / counts[c] as f64) as f32;
                }
            }
        }
    }
    centroids
}

pub(crate) fn nearest_centroid(x: &[f32], centroids: &[f32], dim: usize, metric: Metric) -> usize {
    let nlist = centroids.len() / dim;
    let mut best = 0usize;
    let mut best_d = f32::INFINITY;
    for c in 0..nlist {
        let d = metric.distance(x, &centroids[c * dim..(c + 1) * dim]);
        if d < best_d {
            best_d = d;
            best = c;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn clustered_data(n_per: usize, dim: usize, seed: u64) -> Vec<f32> {
        // 4 well-separated Gaussian blobs.
        let mut rng = Rng::new(seed);
        let mut data = Vec::new();
        for c in 0..4 {
            let center = 20.0 * c as f32;
            for _ in 0..n_per {
                for k in 0..dim {
                    let base = if k == 0 { center } else { 0.0 };
                    data.push(base + rng.normal() as f32);
                }
            }
        }
        data
    }

    #[test]
    fn builds_and_indexes_everything() {
        let dim = 4;
        let data = clustered_data(25, dim, 1);
        let idx = IvfFlatIndex::build(&data, dim, Metric::SqEuclidean, 4, 10, 7).unwrap();
        assert_eq!(idx.len(), 100);
        let total: usize = (0..idx.nlist()).map(|c| idx.lists[c].len()).sum();
        assert_eq!(total, 100);
    }

    #[test]
    fn full_probe_equals_exact() {
        let dim = 4;
        let data = clustered_data(20, dim, 3);
        let idx = IvfFlatIndex::build(&data, dim, Metric::SqEuclidean, 8, 10, 7).unwrap();
        let mut rng = Rng::new(11);
        let q = rng.normal_vec_f32(dim);
        let approx = idx.search(&q, 5, 8).unwrap();
        let exact = crate::knn::knn_indices(&q, &data, dim, 5, Metric::SqEuclidean).unwrap();
        assert_eq!(
            approx.iter().map(|n| n.index).collect::<Vec<_>>(),
            exact.iter().map(|n| n.index).collect::<Vec<_>>()
        );
    }

    #[test]
    fn recall_improves_with_nprobe() {
        let dim = 8;
        let data = clustered_data(50, dim, 5);
        let idx = IvfFlatIndex::build(&data, dim, Metric::SqEuclidean, 16, 8, 9).unwrap();
        let mut rng = Rng::new(13);
        let queries = rng.normal_vec_f32(10 * dim);
        let r1 = idx.recall_at_k(&queries, 5, 1).unwrap();
        let r_all = idx.recall_at_k(&queries, 5, 16).unwrap();
        assert!(r_all >= r1);
        assert!((r_all - 1.0).abs() < 1e-9, "full probe must be exact, got {r_all}");
    }

    #[test]
    fn empty_and_bad_shapes_rejected() {
        assert!(IvfFlatIndex::build(&[], 4, Metric::Euclidean, 4, 5, 1).is_err());
        assert!(IvfFlatIndex::build(&[1.0; 7], 4, Metric::Euclidean, 4, 5, 1).is_err());
        let data = clustered_data(10, 4, 1);
        let idx = IvfFlatIndex::build(&data, 4, Metric::Euclidean, 2, 5, 1).unwrap();
        assert!(idx.search(&[1.0; 3], 2, 1).is_err());
    }

    #[test]
    fn dim_mismatch_error_is_descriptive_and_nprobe_clamped() {
        let data = clustered_data(10, 4, 1);
        let idx = IvfFlatIndex::build(&data, 4, Metric::Euclidean, 4, 5, 1).unwrap();
        let e = idx.search(&[1.0; 6], 2, 1).unwrap_err().to_string();
        assert!(e.contains("query dim 6") && e.contains("index dim 4"), "{e}");
        // nprobe 0 and nprobe far above nlist both clamp instead of panicking.
        assert_eq!(idx.search(&[1.0; 4], 2, 0).unwrap().len(), 2);
        let full = idx.search(&[1.0; 4], 2, usize::MAX).unwrap();
        let exact = crate::knn::knn_indices(&[1.0; 4], &data, 4, 2, Metric::Euclidean).unwrap();
        assert_eq!(
            full.iter().map(|n| n.index).collect::<Vec<_>>(),
            exact.iter().map(|n| n.index).collect::<Vec<_>>()
        );
    }

    #[test]
    fn nlist_capped_at_n() {
        let data = clustered_data(1, 4, 2); // 4 points
        let idx = IvfFlatIndex::build(&data, 4, Metric::Euclidean, 100, 3, 1).unwrap();
        assert!(idx.nlist() <= 4);
    }
}
