//! Bounded top-k selection.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Max-heap entry so the heap root is the *worst* of the current best-k.
#[derive(Debug, Clone, Copy)]
struct HeapItem {
    dist: f32,
    idx: usize,
}

impl PartialEq for HeapItem {
    fn eq(&self, other: &Self) -> bool {
        self.dist == other.dist && self.idx == other.idx
    }
}
impl Eq for HeapItem {}
impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        // Ties broken by index for full determinism. `total_cmp` (not
        // `partial_cmp(..).unwrap_or(Equal)`): NaNs are filtered before
        // insertion, but the silent-Equal fallback would still desync this
        // ordering from the `total_cmp` oracle the property tests sort with
        // (-0.0 < +0.0 under total order), and it hides any future NaN leak.
        self.dist.total_cmp(&other.dist).then(self.idx.cmp(&other.idx))
    }
}

/// Bounded-heap selection of the `k` smallest `(index, distance)` candidates,
/// sorted ascending by (distance, index) with ties broken by index and NaN
/// distances skipped. This is the single selection kernel behind
/// [`top_k_smallest`] and the shard fan-out merge
/// ([`crate::index::shard::ShardedIndex`] feeds per-shard hit lists — already
/// remapped to global ids — straight through here, which is what makes the
/// sharded merge bit-identical to an unsharded scan).
pub fn merge_top_k<I>(candidates: I, k: usize) -> Vec<(usize, f32)>
where
    I: IntoIterator<Item = (usize, f32)>,
{
    if k == 0 {
        return Vec::new();
    }
    let mut heap: BinaryHeap<HeapItem> = BinaryHeap::with_capacity(k + 1);
    for (idx, dist) in candidates {
        if dist.is_nan() {
            continue;
        }
        if heap.len() < k {
            heap.push(HeapItem { dist, idx });
        } else if let Some(worst) = heap.peek() {
            // Same total order as the heap itself, so insertion and eviction
            // can never disagree on ties or signed zeros.
            if (HeapItem { dist, idx }) < *worst {
                heap.pop();
                heap.push(HeapItem { dist, idx });
            }
        }
    }
    let mut out: Vec<(usize, f32)> = heap.into_iter().map(|h| (h.idx, h.dist)).collect();
    out.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
    out
}

/// Indices of the `k` smallest values in `dists`, sorted ascending by
/// (value, index). NaNs are skipped. If `k >= len`, returns all finite
/// entries sorted.
pub fn top_k_smallest(dists: &[f32], k: usize) -> Vec<(usize, f32)> {
    merge_top_k(dists.iter().copied().enumerate(), k)
}

/// Top-k excluding one index (used for leave-one-out neighbor sets, i.e. the
/// paper's `Y \ {y_i}` in Eq. 2).
pub fn top_k_smallest_excluding(dists: &[f32], k: usize, exclude: usize) -> Vec<(usize, f32)> {
    merge_top_k(
        dists.iter().copied().enumerate().filter(|&(idx, _)| idx != exclude),
        k,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selects_smallest_sorted() {
        let d = [5.0, 1.0, 3.0, 0.5, 4.0];
        let t = top_k_smallest(&d, 3);
        assert_eq!(t.iter().map(|x| x.0).collect::<Vec<_>>(), vec![3, 1, 2]);
        assert_eq!(t[0].1, 0.5);
    }

    #[test]
    fn k_zero_and_k_larger_than_len() {
        assert!(top_k_smallest(&[1.0, 2.0], 0).is_empty());
        let t = top_k_smallest(&[2.0, 1.0], 10);
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].0, 1);
    }

    #[test]
    fn nan_skipped() {
        let d = [f32::NAN, 1.0, 2.0];
        let t = top_k_smallest(&d, 3);
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].0, 1);
    }

    #[test]
    fn deterministic_tie_break_by_index() {
        let d = [1.0, 1.0, 1.0, 1.0];
        let t = top_k_smallest(&d, 2);
        assert_eq!(t.iter().map(|x| x.0).collect::<Vec<_>>(), vec![0, 1]);
    }

    #[test]
    fn excluding_removes_self() {
        let d = [0.0, 1.0, 2.0, 3.0];
        let t = top_k_smallest_excluding(&d, 2, 0);
        assert_eq!(t.iter().map(|x| x.0).collect::<Vec<_>>(), vec![1, 2]);
    }

    #[test]
    fn merge_selects_across_lists_with_global_tie_break() {
        // Two "shards" with interleaved and tied distances: the merge must
        // order by (distance, global index), skipping NaN.
        let a = [(0usize, 1.0f32), (2, 0.5), (4, f32::NAN)];
        let b = [(1usize, 0.5f32), (3, 2.0), (5, 0.25)];
        let got = merge_top_k(a.iter().chain(b.iter()).copied(), 4);
        assert_eq!(
            got.iter().map(|x| x.0).collect::<Vec<_>>(),
            vec![5, 1, 2, 0],
            "{got:?}"
        );
        assert!(merge_top_k(a.iter().copied(), 0).is_empty());
        // k larger than the candidate set returns all finite entries.
        assert_eq!(merge_top_k(a.iter().copied(), 10).len(), 2);
    }

    #[test]
    fn signed_zeros_follow_total_order() {
        // total_cmp puts -0.0 strictly before +0.0, so equal-magnitude zero
        // distances order by sign first, then by index — bit-identical to
        // the total_cmp oracle the property tests use.
        let d = [0.0f32, -0.0, 0.0, -0.0];
        let t = top_k_smallest(&d, 4);
        assert_eq!(t.iter().map(|x| x.0).collect::<Vec<_>>(), vec![1, 3, 0, 2]);
        // And the bounded heap agrees with the exhaustive sort at every k.
        for k in 1..=4 {
            let bounded = top_k_smallest(&d, k);
            assert_eq!(
                bounded.iter().map(|x| x.0).collect::<Vec<_>>(),
                [1usize, 3, 0, 2][..k].to_vec(),
                "k={k}"
            );
        }
    }

    #[test]
    fn matches_full_sort_on_random_input() {
        let mut rng = crate::util::Rng::new(8);
        for _ in 0..20 {
            let n = 1 + rng.below(200);
            let k = 1 + rng.below(20);
            let d: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let fast = top_k_smallest(&d, k);
            let mut idx: Vec<usize> = (0..n).collect();
            // NaN-total order (hardening sweep): the oracle sort must never
            // be the thing that panics.
            idx.sort_by(|&a, &b| d[a].total_cmp(&d[b]).then(a.cmp(&b)));
            let slow: Vec<usize> = idx.into_iter().take(k.min(n)).collect();
            assert_eq!(fast.iter().map(|x| x.0).collect::<Vec<_>>(), slow);
        }
    }
}
