//! Exact brute-force KNN — the ground truth for the order-preserving measure.

use crate::error::{OpdrError, Result};
use crate::knn::topk::{top_k_smallest, top_k_smallest_excluding};
use crate::metrics::{pairwise_distances_symmetric, Metric};

/// One retrieved neighbor.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Neighbor {
    /// Index into the base set.
    pub index: usize,
    /// Distance from the query.
    pub distance: f32,
}

/// Exact k-nearest neighbors of `query` within `base` (n×dim row-major).
pub fn knn_indices(
    query: &[f32],
    base: &[f32],
    dim: usize,
    k: usize,
    metric: Metric,
) -> Result<Vec<Neighbor>> {
    if dim == 0 || query.len() != dim || base.len() % dim != 0 {
        return Err(OpdrError::shape("knn_indices: bad shapes"));
    }
    let dists = crate::metrics::pairwise_distances(query, base, dim, metric)?;
    Ok(top_k_smallest(&dists, k)
        .into_iter()
        .map(|(index, distance)| Neighbor { index, distance })
        .collect())
}

/// Leave-one-out exact KNN sets for every point of a dataset: result `[i]` is
/// the set (as sorted indices) of the k nearest neighbors of point `i`
/// excluding itself. This is `E_{k,i}` from Eq. (1) of the paper.
pub fn knn_indices_all(data: &[f32], dim: usize, k: usize, metric: Metric) -> Result<Vec<Vec<usize>>> {
    if dim == 0 || data.len() % dim != 0 {
        return Err(OpdrError::shape("knn_indices_all: bad shapes"));
    }
    let n = data.len() / dim;
    if k >= n && n > 0 {
        // k is capped at n-1 neighbors (everything except self).
    }
    let dists = pairwise_distances_symmetric(data, dim, metric)?;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let row = &dists[i * n..(i + 1) * n];
        let nb: Vec<usize> = top_k_smallest_excluding(row, k.min(n.saturating_sub(1)), i)
            .into_iter()
            .map(|(idx, _)| idx)
            .collect();
        out.push(nb);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_line_neighbors() {
        // Points at 0, 1, 2, 10 on a line.
        let base = [0.0f32, 1.0, 2.0, 10.0];
        let nb = knn_indices(&[1.1f32], &base, 1, 2, Metric::Euclidean).unwrap();
        assert_eq!(nb[0].index, 1);
        assert_eq!(nb[1].index, 2);
    }

    #[test]
    fn all_sets_exclude_self() {
        let data = [0.0f32, 1.0, 2.0, 3.0];
        let sets = knn_indices_all(&data, 1, 2, Metric::Euclidean).unwrap();
        for (i, s) in sets.iter().enumerate() {
            assert!(!s.contains(&i), "set {i} contains self");
            assert_eq!(s.len(), 2);
        }
        // Neighbors of point 0 (value 0.0): points 1 and 2.
        assert_eq!({ let mut s = sets[0].clone(); s.sort(); s }, vec![1, 2]);
    }

    #[test]
    fn k_capped_at_n_minus_1() {
        let data = [0.0f32, 1.0, 2.0];
        let sets = knn_indices_all(&data, 1, 10, Metric::Euclidean).unwrap();
        for s in &sets {
            assert_eq!(s.len(), 2);
        }
    }

    #[test]
    fn shape_errors() {
        assert!(knn_indices(&[1.0, 2.0], &[1.0, 2.0], 3, 1, Metric::Euclidean).is_err());
        assert!(knn_indices_all(&[1.0, 2.0, 3.0], 2, 1, Metric::Euclidean).is_err());
    }

    #[test]
    fn metric_changes_neighbors() {
        // Under L2 the nearest to q is a; under cosine it is b (aligned direction).
        let q = [1.0f32, 1.0];
        let base = [1.2f32, 0.8, /* a: close in L2 */ 10.0, 10.0 /* b: same direction */];
        let l2 = knn_indices(&q, &base, 2, 1, Metric::Euclidean).unwrap();
        let cos = knn_indices(&q, &base, 2, 1, Metric::Cosine).unwrap();
        assert_eq!(l2[0].index, 0);
        assert_eq!(cos[0].index, 1);
    }
}
