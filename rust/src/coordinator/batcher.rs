//! Dynamic batcher: vLLM-style collect-with-deadline.
//!
//! The scheduler thread drains the request queue into batches bounded by
//! `max_batch` requests or `max_wait` since the first request of the batch
//! arrived — whichever comes first. Small under load (latency) and full at
//! saturation (throughput). Extracted into a pure-ish struct so the policy is
//! unit-testable without threads.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batch collection policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Max requests per batch.
    pub max_batch: usize,
    /// Max time to wait after the first request before flushing.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(2) }
    }
}

/// Outcome of a collect call.
#[derive(Debug, PartialEq, Eq)]
pub enum CollectOutcome {
    /// Got a (non-empty) batch.
    Batch,
    /// Queue closed and drained; shut down.
    Closed,
}

/// Collect a batch from `rx` according to `policy` into `out` (cleared
/// first). Blocks for the first item, then drains greedily until the batch is
/// full or the deadline passes.
pub fn collect_batch<T>(
    rx: &Receiver<T>,
    policy: BatchPolicy,
    out: &mut Vec<T>,
) -> CollectOutcome {
    out.clear();
    // Block for the first element.
    match rx.recv() {
        Ok(item) => out.push(item),
        Err(_) => return CollectOutcome::Closed,
    }
    let deadline = Instant::now() + policy.max_wait;
    while out.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => out.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => {
                // Flush what we have; caller sees Closed on the next call.
                break;
            }
        }
    }
    CollectOutcome::Batch
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let mut out = Vec::new();
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50) };
        assert_eq!(collect_batch(&rx, policy, &mut out), CollectOutcome::Batch);
        assert_eq!(out, vec![0, 1, 2, 3]);
        assert_eq!(collect_batch(&rx, policy, &mut out), CollectOutcome::Batch);
        assert_eq!(out, vec![4, 5, 6, 7]);
    }

    #[test]
    fn flushes_partial_batch_on_deadline() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let policy = BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(10) };
        let mut out = Vec::new();
        let start = Instant::now();
        assert_eq!(collect_batch(&rx, policy, &mut out), CollectOutcome::Batch);
        assert_eq!(out, vec![1]);
        assert!(start.elapsed() >= Duration::from_millis(9));
        assert!(start.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn closed_empty_queue_reports_closed() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        let mut out = Vec::new();
        assert_eq!(collect_batch(&rx, BatchPolicy::default(), &mut out), CollectOutcome::Closed);
        assert!(out.is_empty());
    }

    #[test]
    fn disconnect_mid_batch_flushes_items() {
        let (tx, rx) = channel();
        tx.send(7).unwrap();
        tx.send(8).unwrap();
        drop(tx);
        let policy = BatchPolicy { max_batch: 10, max_wait: Duration::from_millis(100) };
        let mut out = Vec::new();
        assert_eq!(collect_batch(&rx, policy, &mut out), CollectOutcome::Batch);
        assert_eq!(out, vec![7, 8]);
        assert_eq!(collect_batch(&rx, policy, &mut out), CollectOutcome::Closed);
    }

    #[test]
    fn preserves_arrival_order() {
        let (tx, rx) = channel();
        for i in 0..32 {
            tx.send(i).unwrap();
        }
        let mut out = Vec::new();
        let policy = BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(5) };
        collect_batch(&rx, policy, &mut out);
        let sorted: Vec<i32> = { let mut s = out.clone(); s.sort(); s };
        assert_eq!(out, sorted);
    }
}
