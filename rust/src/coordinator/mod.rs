//! The serving coordinator — Layer 3 of the stack.
//!
//! A vLLM-router-style front end for multimodal KNN retrieval:
//!
//! * requests enter a **bounded queue** (backpressure: the submit call fails
//!   fast when the queue is full);
//! * a **scheduler thread** owns all collection state and the PJRT engine,
//!   drains the queue through the **dynamic batcher** ([`batcher`]) and
//!   executes search batches either on the PJRT `pairwise_topk` artifact or
//!   on the pure-Rust scoring path parallelized over a **worker pool**
//!   ([`crate::pool`] — shared with the index subsystem's shard fan-out;
//!   segment builds run on a **dedicated build pool** with per-collection
//!   builds-in-flight accounting ([`BuildTracker`]), so rebuilds never
//!   steal pool slots from any collection's searches);
//! * OPDR is a first-class verb: `BuildReduced` calibrates the planner on the
//!   collection, picks `dim(Y)` for the requested accuracy and swaps the
//!   serving copy to the reduced space;
//! * ingest is **incremental** by default: appended rows are absorbed into
//!   the serving index's flat exact delta segment
//!   ([`crate::index::delta`]) instead of invalidating it, and once the
//!   delta outgrows `[serve] delta_max_vectors` a background compaction on
//!   the build pool folds it into a rebuilt main index behind the
//!   rebase-aware swap ([`state::IndexSlot::install_rebased`]) — an ingest
//!   racing a compaction lands in the new delta, never lost.

pub mod batcher;
pub mod server;
pub mod state;

pub use batcher::{collect_batch, BatchPolicy, CollectOutcome};
pub use crate::pool::ThreadPool;
pub use server::{BuildTracker, Coordinator, SearchResult};
pub use state::{Collection, Collections, IndexSlot, ReducedState};
