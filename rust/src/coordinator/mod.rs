//! The serving coordinator — Layer 3 of the stack.
//!
//! A vLLM-router-style front end for multimodal KNN retrieval:
//!
//! * requests enter a **bounded queue** (backpressure: the submit call fails
//!   fast when the queue is full);
//! * a **scheduler thread** owns all collection state and the PJRT engine,
//!   drains the queue through the **dynamic batcher** ([`batcher`]) and
//!   executes search batches either on the PJRT `pairwise_topk` artifact or
//!   on the pure-Rust scoring path parallelized over a **worker pool**
//!   ([`crate::pool`] — shared with the index subsystem's shard fan-out;
//!   segment builds run on a **dedicated build pool** with per-collection
//!   builds-in-flight accounting ([`BuildTracker`]), so rebuilds never
//!   steal pool slots from any collection's searches);
//! * OPDR is a first-class verb: `BuildReduced` calibrates the planner on the
//!   collection, picks `dim(Y)` for the requested accuracy and swaps the
//!   serving copy to the reduced space;
//! * ingest is **incremental** by default: appended rows are absorbed into
//!   the serving index's flat exact delta segment
//!   ([`crate::index::delta`]) instead of invalidating it, and once the
//!   delta outgrows `[serve] delta_max_vectors` a background compaction on
//!   the build pool folds it into a rebuilt main index behind the
//!   rebase-aware swap ([`state::IndexSlot::install_rebased`]) — an ingest
//!   racing a compaction lands in the new delta, never lost.
//!
//! # Published metrics
//!
//! Every instrument lives in the coordinator's labeled registry
//! ([`crate::telemetry::Registry`]); the `Metrics` admin verb (and
//! `serve-demo --metrics`) renders them in the Prometheus text format, and
//! the legacy `stats` line is a view over the same storage. Names:
//!
//! | name | kind | labels | meaning |
//! |------|------|--------|---------|
//! | `opdr_requests_total` | counter | — and (`verb`, `collection`) | accepted requests; the labeled series count admin verbs at dispatch and searches at completion |
//! | `opdr_requests_completed_total` | counter | — | searches completed |
//! | `opdr_requests_rejected_total` | counter | — | searches rejected by queue backpressure |
//! | `opdr_batches_total` | counter | — | search batches executed |
//! | `opdr_vectors_scored_total` | counter | — | rows scored across all searches |
//! | `opdr_request_duration_seconds` | summary | (`verb`[, `collection`]) | end-to-end request latency; `verb="search"` without a collection label is the all-collections aggregate |
//! | `opdr_exec_duration_seconds` | summary | — | time inside batch execution |
//! | `opdr_stage_duration_seconds` | summary | `stage` | pipeline spans: `queue_wait`, `scan`, `rerank`, `merge`, `delta_scan` on the query path; `delta_append`, `build`, `swap` on the write path |
//! | `opdr_probe_recall_at_k` | gauge | `collection` | recall probe: running-mean `recall@k` of served results vs an exact full-dimensional scan |
//! | `opdr_probe_op_measure_mu` | gauge | `collection` | recall probe: running mean of the paper's order-preserving measure μ |
//! | `opdr_probe_samples_total` | counter | `collection` | queries the probe shadow-executed |
//! | `opdr_collection_rows` | gauge | `collection` | rows in the collection |
//! | `opdr_collection_shards` | gauge | `collection` | shards in the serving index (0 = unindexed) |
//! | `opdr_collection_delta_rows` | gauge | `collection` | delta rows awaiting compaction |
//! | `opdr_collection_cold_bytes` | gauge | `collection` | resident cold-tier bytes |
//! | `opdr_collection_mapped_bytes` | gauge | `collection` | mmap-served cold-tier bytes |
//! | `opdr_rpc_requests_total` | counter | `worker` | gateway→worker RPC requests sent ([`crate::dist`]) |
//! | `opdr_rpc_errors_total` | counter | `worker` | RPC transport/protocol failures (non-timeout) |
//! | `opdr_rpc_deadline_total` | counter | `worker` | RPC requests that missed their deadline |
//! | `opdr_rpc_partial_results_total` | counter | — | gateway queries answered degraded (`partial = true`) |
//! | `opdr_rpc_request_duration_seconds` | summary | `worker` | gateway-side RPC round-trip latency |
//! | `opdr_rpc_worker_up` | gauge | `worker` | worker liveness (1 healthy, 0 down) |
//! | `opdr_rpc_worker_restarts_total` | counter | `worker` | supervisor respawns of a crashed worker |
//! | `opdr_rpc_shard_stage_seconds` | summary | (`worker`, `stage`) | worker-reported per-stage shard timing (`queue_wait`, `scan`, `rerank`, `merge`) carried back on the protocol-v2 trace tail |
//! | `opdr_rpc_scrape_errors_total` | counter | `worker` | failed `MetricsPull` federation scrapes |
//! | `opdr_worker_queries_total` | counter | — (worker-side) | queries a shard worker served; federates with a `worker` label |
//! | `opdr_worker_query_duration_seconds` | summary | — (worker-side) | worker-side query latency; federates with a `worker` label |
//!
//! Histograms render as summaries with `quantile="0.5"`, `"0.99"`, `"0.999"`
//! samples in seconds plus `_sum`/`_count`. The topology gauges refresh on
//! each `Stats`/`Metrics` call; the probe gauges publish asynchronously from
//! the probe thread ([`crate::telemetry::RecallProbe`]).
//!
//! With a distributed gateway attached ([`Coordinator::attach_dist`]) two
//! more verbs exist: `ClusterMetrics` renders the **federated** cluster
//! exposition — every worker's registry scraped over `MetricsPull`, each
//! sample emitted once labeled `worker="<name>"` and once merged into the
//! unlabeled aggregate, plus the gateway's own series — and `SlowQueries`
//! dumps the slow-query flight recorder
//! ([`crate::telemetry::FlightRecorder`]): the last K query timelines with
//! trace ids, per-shard stage timings and fault dispositions.

pub mod batcher;
pub mod server;
pub mod state;

pub use batcher::{collect_batch, BatchPolicy, CollectOutcome};
pub use crate::pool::ThreadPool;
pub use server::{BuildTracker, Coordinator, SearchResult};
pub use state::{Collection, Collections, IndexSlot, ReducedState};
