//! The coordinator server: request types, scheduler loop, public handle.

use crate::config::ServeConfig;
use crate::coordinator::batcher::{collect_batch, BatchPolicy, CollectOutcome};
use crate::coordinator::state::Collections;
use crate::dist::Gateway;
use crate::error::{OpdrError, Result};
use crate::index::AnnIndex as _;
use crate::knn::Neighbor;
use crate::metrics::Metric;
use crate::pool::ThreadPool;
use crate::runtime::Engine;
use crate::telemetry::{registry, Metrics, ProbeJob, RecallProbe};
use crate::util::{lock_recover_ranked, ranks, Stopwatch};
use std::collections::HashMap;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Per-collection builds-in-flight accounting. One collection's rebuild
/// used to steer *every* collection's search batches off the worker pool
/// (the counter was global, and builds shared the search pool); now that
/// segment builds run on the dedicated build pool no collection is steered
/// at all, and the per-collection counts feed stats (`building=`) and the
/// deferred build responses.
#[derive(Debug, Default)]
pub struct BuildTracker {
    inner: Mutex<HashMap<String, usize>>,
    /// Completed delta compactions per collection (stats: `compactions=`).
    compactions: Mutex<HashMap<String, u64>>,
}

impl BuildTracker {
    /// Record a build starting for `collection`.
    pub fn begin(&self, collection: &str) {
        *lock_recover_ranked(&self.inner, ranks::COORDINATOR_BUILDS)
            .entry(collection.to_string())
            .or_insert(0) += 1;
    }

    /// Record a completed (installed) delta compaction for `collection`.
    pub fn record_compaction(&self, collection: &str) {
        *lock_recover_ranked(&self.compactions, ranks::COORDINATOR_COMPACTIONS)
            .entry(collection.to_string())
            .or_insert(0) += 1;
    }

    /// Delta compactions completed for `collection` since startup.
    pub fn compactions(&self, collection: &str) -> u64 {
        lock_recover_ranked(&self.compactions, ranks::COORDINATOR_COMPACTIONS)
            .get(collection)
            .copied()
            .unwrap_or(0)
    }

    /// Record a build finishing for `collection` (saturating; entries drop
    /// at zero so the map stays bounded by the set of rebuilding
    /// collections).
    pub fn finish(&self, collection: &str) {
        let mut map = lock_recover_ranked(&self.inner, ranks::COORDINATOR_BUILDS);
        if let Some(count) = map.get_mut(collection) {
            *count = count.saturating_sub(1);
            if *count == 0 {
                map.remove(collection);
            }
        }
    }

    /// Builds currently in flight for `collection`.
    pub fn in_flight(&self, collection: &str) -> usize {
        lock_recover_ranked(&self.inner, ranks::COORDINATOR_BUILDS)
            .get(collection)
            .copied()
            .unwrap_or(0)
    }

    /// Total builds in flight across all collections (the stats summary
    /// line reports it).
    pub fn total(&self) -> usize {
        lock_recover_ranked(&self.inner, ranks::COORDINATOR_BUILDS).values().sum()
    }
}

/// One search hit list.
#[derive(Debug, Clone)]
pub struct SearchResult {
    /// Ranked neighbors (ascending distance).
    pub neighbors: Vec<Neighbor>,
    /// Dimensionality the query was scored in (reduced or full).
    pub scored_dim: usize,
}

enum Request {
    Search {
        collection: String,
        query: Vec<f32>,
        k: usize,
        resp: SyncSender<Result<SearchResult>>,
        submitted: Stopwatch,
    },
    Admin(AdminOp, SyncSender<Result<String>>),
    /// Attach a distributed gateway: enables the `ClusterMetrics` and
    /// `SlowQueries` verbs for this coordinator.
    AttachDist(Arc<Mutex<Gateway>>),
    Shutdown,
}

enum AdminOp {
    CreateCollection { name: String, dim: usize, metric: Metric },
    Ingest { collection: String, vectors: Vec<f32> },
    BuildReduced { collection: String, target_accuracy: f64, k: usize },
    BuildIndex { collection: String },
    SaveIndex { collection: String, path: String },
    LoadIndex { collection: String, path: String },
    Stats,
    Metrics,
    ClusterMetrics,
    SlowQueries,
}

/// `(verb, collection)` labels for an admin op — feeds the per-verb request
/// counters and duration histograms. Ops without a collection (stats,
/// metrics) use the `_admin` pseudo-collection so every series has both
/// labels.
fn op_meta(op: &AdminOp) -> (&'static str, &str) {
    match op {
        AdminOp::CreateCollection { name, .. } => ("create_collection", name),
        AdminOp::Ingest { collection, .. } => ("ingest", collection),
        AdminOp::BuildReduced { collection, .. } => ("build_reduced", collection),
        AdminOp::BuildIndex { collection } => ("build_index", collection),
        AdminOp::SaveIndex { collection, .. } => ("save_index", collection),
        AdminOp::LoadIndex { collection, .. } => ("load_index", collection),
        AdminOp::Stats => ("stats", "_admin"),
        AdminOp::Metrics => ("metrics", "_admin"),
        AdminOp::ClusterMetrics => ("cluster_metrics", "_admin"),
        AdminOp::SlowQueries => ("slow_queries", "_admin"),
    }
}

/// Public handle to a running coordinator. Cloneable; dropping the last
/// handle does *not* stop the server — call [`Coordinator::shutdown`].
pub struct Coordinator {
    tx: SyncSender<Request>,
    scheduler: Option<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    cfg: ServeConfig,
}

impl std::fmt::Debug for Coordinator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Coordinator").field("cfg", &self.cfg).finish()
    }
}

impl Coordinator {
    /// Start the coordinator. If `cfg.use_runtime` is set, the scheduler
    /// thread creates a PJRT [`Engine`] over `cfg.artifacts_dir` and uses the
    /// `pairwise_topk_*` artifacts for batch scoring where shapes allow;
    /// otherwise (or on fallback) scoring runs on the worker pool.
    pub fn start(cfg: ServeConfig) -> Result<Coordinator> {
        cfg.validate()?;
        let (tx, rx) = sync_channel::<Request>(cfg.queue_capacity);
        let metrics = Arc::new(Metrics::new());
        let m2 = Arc::clone(&metrics);
        let cfg2 = cfg.clone();
        let scheduler = std::thread::Builder::new()
            .name("opdr-scheduler".to_string())
            .spawn(move || scheduler_loop(rx, cfg2, m2))
            .map_err(|e| OpdrError::coordinator(format!("spawn scheduler: {e}")))?;
        Ok(Coordinator { tx, scheduler: Some(scheduler), metrics, cfg })
    }

    /// Shared metrics (request counters, latency histograms).
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Serving config used at start.
    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    fn admin(&self, op: AdminOp) -> Result<String> {
        // Exactly one response per op, so a capacity-1 bounded channel can
        // never block the scheduler — and nothing on the serving path hands
        // out an unbounded queue.
        let (tx, rx) = sync_channel(1);
        self.tx
            .send(Request::Admin(op, tx))
            .map_err(|_| OpdrError::coordinator("coordinator stopped"))?;
        rx.recv().map_err(|_| OpdrError::coordinator("coordinator dropped response"))?
    }

    /// Create a collection.
    pub fn create_collection(&self, name: &str, dim: usize, metric: Metric) -> Result<()> {
        self.admin(AdminOp::CreateCollection { name: name.into(), dim, metric }).map(|_| ())
    }

    /// Ingest row-major vectors. With `incremental_ingest` (the default)
    /// the rows are absorbed into the serving index's flat exact delta
    /// segment — the index keeps serving — and a background compaction is
    /// scheduled once the delta outgrows `delta_max_vectors`; with it off,
    /// the legacy path invalidates the index and the reduced copy.
    pub fn ingest(&self, collection: &str, vectors: Vec<f32>) -> Result<usize> {
        let r = self.admin(AdminOp::Ingest { collection: collection.into(), vectors })?;
        r.parse::<usize>()
            .map_err(|_| OpdrError::coordinator("bad ingest response"))
    }

    /// Build the OPDR-reduced serving copy for a target accuracy; returns the
    /// planned dimension.
    pub fn build_reduced(&self, collection: &str, target_accuracy: f64, k: usize) -> Result<usize> {
        let r = self.admin(AdminOp::BuildReduced {
            collection: collection.into(),
            target_accuracy,
            k,
        })?;
        r.parse::<usize>()
            .map_err(|_| OpdrError::coordinator("bad build_reduced response"))
    }

    /// Build the ANN index on the current serving vectors (substrate chosen
    /// by the configured [`crate::config::IndexPolicy`]).
    pub fn build_index(&self, collection: &str) -> Result<()> {
        self.admin(AdminOp::BuildIndex { collection: collection.into() }).map(|_| ())
    }

    /// Persist a collection's built index as an `OPDR` index segment.
    pub fn save_index(&self, collection: &str, path: &str) -> Result<()> {
        self.admin(AdminOp::SaveIndex { collection: collection.into(), path: path.into() })
            .map(|_| ())
    }

    /// Load a previously saved index segment into a collection (validated
    /// against its current serving vectors).
    pub fn load_index(&self, collection: &str, path: &str) -> Result<()> {
        self.admin(AdminOp::LoadIndex { collection: collection.into(), path: path.into() })
            .map(|_| ())
    }

    /// Human-readable stats snapshot.
    pub fn stats(&self) -> Result<String> {
        self.admin(AdminOp::Stats)
    }

    /// Prometheus-style text exposition of every registered metric:
    /// per-(verb, collection) request counters and latency quantiles,
    /// per-stage pipeline histograms, probe gauges and the per-collection
    /// topology gauges refreshed by this call.
    pub fn metrics_text(&self) -> Result<String> {
        self.admin(AdminOp::Metrics)
    }

    /// Attach a distributed gateway, enabling [`Coordinator::cluster_metrics`]
    /// and [`Coordinator::slow_queries`]. The gateway is shared (the caller
    /// keeps serving queries through its own handle); admin-side scrapes and
    /// dumps lock it only for their own duration.
    pub fn attach_dist(&self, gateway: Arc<Mutex<Gateway>>) -> Result<()> {
        self.tx
            .send(Request::AttachDist(gateway))
            .map_err(|_| OpdrError::coordinator("coordinator stopped"))
    }

    /// Federated cluster exposition: every worker's registry scraped over
    /// `MetricsPull` and rendered once `worker="<name>"`-labeled and once
    /// merged into the unlabeled aggregate, plus the gateway's own series.
    /// Requires [`Coordinator::attach_dist`].
    pub fn cluster_metrics(&self) -> Result<String> {
        self.admin(AdminOp::ClusterMetrics)
    }

    /// The slow-query flight recorder's dump (trace ids, per-shard stage
    /// timings, fault dispositions). Requires [`Coordinator::attach_dist`].
    pub fn slow_queries(&self) -> Result<String> {
        self.admin(AdminOp::SlowQueries)
    }

    /// Submit a search; blocks for the result. Fails fast with a
    /// backpressure error when the queue is full.
    pub fn search(&self, collection: &str, query: Vec<f32>, k: usize) -> Result<SearchResult> {
        let rx = self.search_async(collection, query, k)?;
        rx.recv()
            .map_err(|_| OpdrError::coordinator("coordinator dropped response"))?
    }

    /// Submit a search; returns the response channel immediately (the caller
    /// can pipeline many requests — this is what the benches do).
    pub fn search_async(
        &self,
        collection: &str,
        query: Vec<f32>,
        k: usize,
    ) -> Result<Receiver<Result<SearchResult>>> {
        // One response per search; capacity 1 means the worker's send never
        // blocks even when the caller pipelines and reads late.
        let (tx, rx) = sync_channel(1);
        let req = Request::Search {
            collection: collection.into(),
            query,
            k,
            resp: tx,
            submitted: Stopwatch::start(),
        };
        match self.tx.try_send(req) {
            Ok(()) => {
                self.metrics.requests.inc();
                Ok(rx)
            }
            Err(TrySendError::Full(_)) => {
                self.metrics.rejected.inc();
                Err(OpdrError::coordinator("queue full (backpressure)"))
            }
            Err(TrySendError::Disconnected(_)) => Err(OpdrError::coordinator("coordinator stopped")),
        }
    }

    /// Stop the scheduler and wait for it to exit.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.scheduler.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.scheduler.take() {
            let _ = h.join();
        }
    }
}

fn scheduler_loop(rx: Receiver<Request>, cfg: ServeConfig, metrics: Arc<Metrics>) {
    let mut collections = Collections::new();
    let pool = ThreadPool::new(cfg.workers);
    // Segment builds run on their own pool so search fan-out never queues
    // behind multi-second build jobs — every collection keeps full
    // batch/shard parallelism while any collection rebuilds. The tracker
    // records builds-in-flight per collection (stats observability and the
    // deferred build responses).
    let build_pool = ThreadPool::new(cfg.build_workers);
    let builds_in_flight = Arc::new(BuildTracker::default());
    // Live recall probe: shadow-executes a sampled fraction of served
    // queries against the flat exact scans on its own thread and publishes
    // recall@k / μ gauges into the shared registry. Dropping it at loop exit
    // drains the queue and joins the thread.
    let probe: Option<RecallProbe> = if cfg.recall_probe {
        Some(RecallProbe::start(Arc::clone(&metrics.registry), cfg.recall_probe_every, 1024))
    } else {
        None
    };
    // The engine is created lazily so a missing artifacts dir only matters if
    // runtime execution was requested.
    let engine: Option<Engine> = if cfg.use_runtime {
        match Engine::new(&cfg.artifacts_dir) {
            Ok(e) => Some(e),
            Err(err) => {
                eprintln!("[coordinator] runtime disabled: {err}");
                None
            }
        }
    } else {
        None
    };

    let policy = BatchPolicy {
        max_batch: cfg.max_batch,
        max_wait: Duration::from_millis(cfg.max_wait_ms),
    };
    let mut batch: Vec<Request> = Vec::with_capacity(cfg.max_batch);
    // Distributed gateway attachment (ClusterMetrics / SlowQueries verbs).
    let mut dist: Option<Arc<Mutex<Gateway>>> = None;

    loop {
        match collect_batch(&rx, policy, &mut batch) {
            CollectOutcome::Closed => break,
            CollectOutcome::Batch => {}
        }
        // Partition: admin ops execute serially in arrival order relative to
        // the searches around them would require per-collection versioning;
        // we keep the simpler (and documented) model: admin ops in a batch
        // run first, then searches. (`BuildIndex` only *starts* here — the
        // segment builds run on the build pool and the response is deferred
        // to the atomic swap, so a long rebuild never stalls this loop.)
        let mut searches = Vec::new();
        let mut stop = false;
        for req in batch.drain(..) {
            match req {
                Request::Shutdown => stop = true,
                Request::Admin(op, resp) => {
                    // Per-verb observability: count the op and time its
                    // scheduler-side execution (deferred builds only spend
                    // their dispatch here; the build itself feeds the
                    // compaction_build / swap stage histograms).
                    let (verb, coll) = op_meta(&op);
                    metrics.verb_counter(verb, coll).inc();
                    let h = metrics.verb_histogram(verb, coll);
                    let sw = Stopwatch::start();
                    let mut ctx = AdminCtx {
                        collections: &mut collections,
                        cfg: &cfg,
                        metrics: &metrics,
                        build_pool: &build_pool,
                        builds_in_flight: &builds_in_flight,
                        dist: dist.as_ref(),
                    };
                    handle_admin(op, &mut ctx, resp);
                    h.record(sw.elapsed());
                }
                Request::AttachDist(gw) => dist = Some(gw),
                s @ Request::Search { .. } => searches.push(s),
            }
        }
        if !searches.is_empty() {
            let engine = engine.as_ref();
            execute_search_batch(searches, &collections, &pool, engine, &metrics, probe.as_ref());
        }
        if stop {
            break;
        }
    }
}

/// Everything an admin op needs besides the op itself and its response
/// channel: the scheduler-owned collection table plus the shared serving
/// and build infrastructure. One struct instead of a seven-way parameter
/// fan-out — this is what retired the `clippy::too_many_arguments` allows
/// that used to sit on [`handle_admin`] and [`spawn_build`].
struct AdminCtx<'a> {
    collections: &'a mut Collections,
    cfg: &'a ServeConfig,
    metrics: &'a Arc<Metrics>,
    build_pool: &'a ThreadPool,
    builds_in_flight: &'a Arc<BuildTracker>,
    dist: Option<&'a Arc<Mutex<Gateway>>>,
}

/// Execute one admin op and answer `resp`. Most ops run synchronously on
/// the scheduler thread; index (re)builds never do — `BuildIndex` (and the
/// re-index step of `BuildReduced`) snapshot the collection, fan
/// whole-segment builds out to the dedicated build pool and defer the
/// response until the finished index is atomically swapped in, while the
/// scheduler keeps draining search batches at full pool parallelism (the
/// per-collection `builds_in_flight` tracker feeds stats and the deferred
/// responses).
fn handle_admin(op: AdminOp, ctx: AdminCtx<'_>, resp: SyncSender<Result<String>>) {
    match op {
        AdminOp::BuildIndex { collection } => {
            spawn_build(&ctx, &collection, "ok".into(), false, resp);
        }
        AdminOp::Ingest { collection, vectors } => {
            // Incremental mode (the default) absorbs the rows into the
            // serving index's flat exact delta segment instead of dropping
            // the index; once the delta outgrows `delta_max_vectors` a
            // background compaction folds it into a rebuilt main index on
            // the build pool. The response is the row count either way —
            // compaction is fire-and-forget behind the rebased atomic swap.
            let incremental = ctx.cfg.incremental_ingest;
            let delta_append = &ctx.metrics.delta_append;
            let out = ctx.collections.get_mut(&collection).and_then(|c| {
                if incremental {
                    // Write-path span: the delta absorb (projection +
                    // wrapper swap) is the synchronous cost of an ingest.
                    let sw = Stopwatch::start();
                    let r = c.ingest_incremental(&vectors);
                    delta_append.record(sw.elapsed());
                    r
                } else {
                    c.ingest(&vectors)
                }
            });
            match out {
                Ok(n) => {
                    if incremental {
                        maybe_spawn_compaction(&ctx, &collection);
                    }
                    let _ = resp.send(Ok(n.to_string()));
                }
                Err(e) => {
                    let _ = resp.send(Err(e));
                }
            }
        }
        AdminOp::BuildReduced { collection, target_accuracy, k } => {
            // The reduction itself (planner calibration + PCA projection)
            // mutates the collection and runs here; the follow-up re-index
            // goes through the build pool like any other build.
            let reduced = ctx.collections.get_mut(&collection).and_then(|c| {
                c.build_reduced(target_accuracy, k, 64, 0xC0DE).map(|r| r.model.target_dim())
            });
            match reduced {
                Ok(dim) => {
                    let big_enough = ctx.collections.get(&collection).map_or(0, |c| c.len())
                        >= ctx.cfg.ivf_threshold;
                    if big_enough {
                        let msg = dim.to_string();
                        spawn_build(&ctx, &collection, msg, true, resp);
                    } else {
                        let _ = resp.send(Ok(dim.to_string()));
                    }
                }
                Err(e) => {
                    let _ = resp.send(Err(e));
                }
            }
        }
        other => {
            let _ = resp.send(handle_admin_sync(other, ctx));
        }
    }
}

/// Dispatch an index build for `collection` onto the dedicated build pool;
/// the deferred response maps a successful atomic swap to `ok_msg`. When
/// the snapshot is invalidated wholesale mid-build (legacy-mode ingest,
/// re-reduce, explicit build/load — incremental-mode ingests don't
/// invalidate, they rebase onto the finished index), the stale index is
/// discarded; `stale_ok` decides whether that still answers `ok_msg`
/// (BuildReduced: the reduction itself succeeded and serving falls back to
/// the exact scan) or reports the discarded build (explicit BuildIndex).
fn spawn_build(
    ctx: &AdminCtx<'_>,
    collection: &str,
    ok_msg: String,
    stale_ok: bool,
    resp: SyncSender<Result<String>>,
) {
    match ctx.collections.get(collection) {
        Ok(c) => {
            ctx.builds_in_flight.begin(collection);
            let builds = Arc::clone(ctx.builds_in_flight);
            let name = collection.to_string();
            let spans = Some(ctx.metrics.build_spans.clone());
            let pool = ctx.build_pool;
            c.spawn_index_build_traced(&ctx.cfg.index_policy(), 0xC0DE, pool, spans, move |r| {
                builds.finish(&name);
                let out = match r {
                    Ok(installed) if installed || stale_ok => Ok(ok_msg),
                    Ok(_) => Err(OpdrError::coordinator(format!(
                        "collection `{name}` changed during the index build; the stale \
                         index was discarded — rebuild required"
                    ))),
                    Err(e) => Err(e),
                };
                let _ = resp.send(out);
            });
        }
        Err(e) => {
            let _ = resp.send(Err(e));
        }
    }
}

/// Schedule a background delta compaction for `collection` when its delta
/// segment has outgrown `cfg.delta_max_vectors` and no build is already in
/// flight (compactions never stack — a fresh one is scheduled by the next
/// ingest if the delta is still over the bound). The compaction is the
/// ordinary pool rebuild over the merged `{main, delta}` snapshot; the swap
/// goes through the rebase-aware install, so rows ingested while it runs
/// land in the new index's delta.
fn maybe_spawn_compaction(ctx: &AdminCtx<'_>, collection: &str) {
    let Ok(c) = ctx.collections.get(collection) else { return };
    if c.delta_len() <= ctx.cfg.delta_max_vectors || ctx.builds_in_flight.in_flight(collection) > 0
    {
        return;
    }
    ctx.builds_in_flight.begin(collection);
    let builds = Arc::clone(ctx.builds_in_flight);
    let name = collection.to_string();
    let spans = Some(ctx.metrics.build_spans.clone());
    c.spawn_index_build_traced(&ctx.cfg.index_policy(), 0xC0DE, ctx.build_pool, spans, move |r| {
        builds.finish(&name);
        match r {
            Ok(true) => builds.record_compaction(&name),
            // A wholesale serving-state change (re-reduce, explicit build,
            // load) invalidated the snapshot; the discarded result is not a
            // compaction. Nothing is lost — the rows live in the serving
            // data and whatever replaced the snapshot.
            Ok(false) => {}
            Err(e) => eprintln!("[coordinator] compaction of `{name}` failed: {e}"),
        }
    });
}

fn handle_admin_sync(op: AdminOp, ctx: AdminCtx<'_>) -> Result<String> {
    let AdminCtx { collections, cfg, metrics, builds_in_flight: builds, dist, .. } = ctx;
    match op {
        AdminOp::CreateCollection { name, dim, metric } => {
            collections.create(&name, dim, metric)?;
            Ok("ok".into())
        }
        AdminOp::Ingest { .. } | AdminOp::BuildReduced { .. } | AdminOp::BuildIndex { .. } => {
            unreachable!("ingest and index builds are handled by handle_admin")
        }
        AdminOp::SaveIndex { collection, path } => {
            // A mmap cold tier round-trips through the mmap-servable
            // version-5 layout; the RAM tier keeps the inline formats.
            collections.get(&collection)?.save_index_as(&path, cfg.cold_tier_mmap)?;
            Ok("ok".into())
        }
        AdminOp::LoadIndex { collection, path } => {
            collections.get_mut(&collection)?.load_index(&path)?;
            Ok("ok".into())
        }
        AdminOp::Stats => {
            // The legacy stats line is a *view over the registry*: the
            // per-collection topology gauges are refreshed from live state,
            // then the n=/shards=/delta=/cold_bytes= keys are formatted from
            // the gauge read-back, and the summary counters are the very
            // Arc-shared instruments registered in [`Metrics::new`]. A
            // regression test pins the two surfaces to agree.
            let reg = &metrics.registry;
            let mut out = String::new();
            for name in collections.names() {
                let c = collections.get(&name)?;
                let (_, sdim) = c.serving_vectors();
                refresh_collection_gauges(&name, c, metrics);
                let lbl = [("collection", name.as_str())];
                let rows = reg.gauge(registry::COLLECTION_ROWS, &lbl).get() as usize;
                let indexed = match c.index() {
                    Some(ix) => {
                        let shards = reg.gauge(registry::COLLECTION_SHARDS, &lbl).get() as usize;
                        let delta = reg.gauge(registry::COLLECTION_DELTA_ROWS, &lbl).get() as usize;
                        let cold = reg.gauge(registry::COLLECTION_COLD_BYTES, &lbl).get() as usize;
                        let mapped =
                            reg.gauge(registry::COLLECTION_MAPPED_BYTES, &lbl).get() as usize;
                        // Tier accounting (hardening satellite): cold_bytes=
                        // used to print for every index, even with no rerank
                        // tier at all; the cold/mapped pair appears only
                        // when a tier exists, and distinguishes resident from
                        // mmap-served bytes.
                        let tier = if cold > 0 || mapped > 0 {
                            format!(" cold_bytes={cold} mapped_bytes={mapped}")
                        } else {
                            String::new()
                        };
                        format!(
                            "true kind={} shards={shards} delta={delta} quantized={} \
                             storage={} index_bytes={}{tier}",
                            ix.kind().name(),
                            ix.quantized(),
                            ix.storage_name(),
                            ix.memory_bytes(),
                        )
                    }
                    None => "false".to_string(),
                };
                out.push_str(&format!(
                    "collection {name}: n={rows} dim={} serving_dim={} building={} \
                     compactions={} indexed={indexed}\n",
                    c.dim,
                    sdim,
                    builds.in_flight(&name),
                    builds.compactions(&name),
                ));
            }
            out.push_str(&format!(
                "requests={} completed={} rejected={} batches={} builds_in_flight={} \
                 latency[{}] exec[{}]",
                metrics.requests.get(),
                metrics.completed.get(),
                metrics.rejected.get(),
                metrics.batches.get(),
                builds.total(),
                metrics.latency.summary(),
                metrics.exec_latency.summary(),
            ));
            Ok(out)
        }
        AdminOp::Metrics => {
            // Refresh the topology gauges so the exposition reflects the
            // collections as of this call, then render everything.
            for name in collections.names() {
                refresh_collection_gauges(&name, collections.get(&name)?, metrics);
            }
            Ok(metrics.registry.render())
        }
        AdminOp::ClusterMetrics => {
            let gw = dist.ok_or_else(|| {
                OpdrError::config("cluster_metrics: no distributed gateway attached")
            })?;
            Ok(lock_recover_ranked(gw, ranks::DIST_GATEWAY).cluster_metrics())
        }
        AdminOp::SlowQueries => {
            let gw = dist.ok_or_else(|| {
                OpdrError::config("slow_queries: no distributed gateway attached")
            })?;
            let dump = lock_recover_ranked(gw, ranks::DIST_GATEWAY).recorder().dump();
            Ok(dump)
        }
    }
}

/// Refresh the per-collection topology gauges (`opdr_collection_*`) from
/// live collection state. Both the legacy stats view and the Prometheus
/// exposition read these series back from the registry.
fn refresh_collection_gauges(
    name: &str,
    c: &crate::coordinator::state::Collection,
    metrics: &Metrics,
) {
    let reg = &metrics.registry;
    let lbl = [("collection", name)];
    reg.gauge(registry::COLLECTION_ROWS, &lbl).set(c.len() as f64);
    let (shards, delta, cold, mapped) = match c.index() {
        Some(ix) => {
            // A delta wrapper reports its main's shard count and the delta
            // backlog awaiting compaction.
            let (shards, delta) = match ix.as_delta() {
                Some(d) => (d.main().as_sharded().map_or(1, |s| s.num_shards()), d.delta_len()),
                None => (ix.as_sharded().map_or(1, |s| s.num_shards()), 0),
            };
            (shards, delta, ix.cold_bytes(), ix.mapped_bytes())
        }
        None => (0, 0, 0, 0),
    };
    reg.gauge(registry::COLLECTION_SHARDS, &lbl).set(shards as f64);
    reg.gauge(registry::COLLECTION_DELTA_ROWS, &lbl).set(delta as f64);
    reg.gauge(registry::COLLECTION_COLD_BYTES, &lbl).set(cold as f64);
    reg.gauge(registry::COLLECTION_MAPPED_BYTES, &lbl).set(mapped as f64);
}

/// One query of a search batch: reject failed projections, run `search`,
/// wrap the hits with the serving dimension. Shared by every scoring branch
/// (indexed / brute, inline / pooled) of [`execute_search_batch`].
fn run_one(
    q: &[f32],
    k: usize,
    sdim: usize,
    search: impl FnOnce(&[f32], usize) -> Result<Vec<Neighbor>>,
) -> Result<SearchResult> {
    if q.is_empty() {
        return Err(OpdrError::shape("query projection failed"));
    }
    search(q, k).map(|neighbors| SearchResult { neighbors, scored_dim: sdim })
}

fn execute_search_batch(
    searches: Vec<Request>,
    collections: &Collections,
    pool: &ThreadPool,
    engine: Option<&Engine>,
    metrics: &Metrics,
    probe: Option<&RecallProbe>,
) {
    metrics.batches.inc();
    let exec_sw = Stopwatch::start();

    // Group by collection so each group scores against one vector set.
    use std::collections::HashMap;
    struct Item {
        query: Vec<f32>,
        k: usize,
        resp: SyncSender<Result<SearchResult>>,
        submitted: Stopwatch,
    }
    let mut groups: HashMap<String, Vec<Item>> = HashMap::new();
    for req in searches {
        if let Request::Search { collection, query, k, resp, submitted } = req {
            // Queue-wait stage: submit → the batch starting to execute. The
            // stopwatch keeps running into the end-to-end latency record.
            metrics.queue_wait.record(submitted.elapsed());
            groups.entry(collection).or_default().push(Item { query, k, resp, submitted });
        }
    }

    for (cname, items) in groups {
        let coll = match collections.get(&cname) {
            Ok(c) => c,
            Err(e) => {
                let msg = e.to_string();
                for it in items {
                    let _ = it.resp.send(Err(OpdrError::coordinator(msg.clone())));
                    let _ = it.submitted; // latency not recorded for failures
                }
                continue;
            }
        };
        let (vecs, sdim) = coll.serving_vectors();
        metrics.vectors_scored.add((vecs.len() / sdim.max(1)) as u64 * items.len() as u64);
        // Per-(verb, collection) series for this group.
        let vh = metrics.verb_histogram("search", &cname);
        let vc = metrics.verb_counter("search", &cname);

        // Try the PJRT artifact path for eligible groups (no IVF index; the
        // engine path scores exhaustively).
        let engine_out = engine.and_then(|eng| {
            crate::coordinator::server::runtime_batch_search(eng, coll, &items_queries(&items), &items_ks(&items))
                .ok()
        });

        if let Some(results) = engine_out {
            for (it, res) in items.into_iter().zip(results) {
                metrics.completed.inc();
                let took = it.submitted.elapsed();
                metrics.latency.record(took);
                vh.record(took);
                vc.inc();
                let _ = it.resp.send(Ok(res));
            }
            continue;
        }

        // CPU path: project queries, then parallel per-query scoring.
        let projected: Vec<Result<Vec<f32>>> =
            items.iter().map(|it| coll.project_query(&it.query)).collect();
        let n = items.len();
        let shared: Arc<Vec<(Vec<f32>, usize)>> = Arc::new(
            projected
                .iter()
                .zip(&items)
                .map(|(p, it)| match p {
                    Ok(q) => (q.clone(), it.k),
                    Err(_) => (Vec::new(), it.k),
                })
                .collect(),
        );
        // Shared snapshot (perf-pass L3-2): built once per serving state, not
        // per batch — full-dim collections were paying a multi-MB memcpy here.
        let vecs_arc: Arc<Vec<f32>> = coll.serving_arc();
        let metric = coll.metric;
        let index_snapshot = coll.index();
        // Since PR 3, segment builds run on the dedicated build pool, so the
        // search pool is always free for scoring — no collection is ever
        // steered off it during a rebuild (the old global builds-in-flight
        // gate; per-collection accounting lives in `BuildTracker` for stats
        // and deferred build responses).
        let results: Vec<Vec<Result<SearchResult>>> = if let Some(index) = index_snapshot {
            if n > 1 {
                // Batched: parallelize across queries — each worker runs the
                // serial (per-shard sequential) search against one
                // batch-wide index snapshot, avoiding a blocking per-query
                // fan-out barrier on this thread. Stage timings land in the
                // shared trace histograms (Arc-backed, thread-safe).
                let shared = Arc::clone(&shared);
                let chunk = n.div_ceil(pool.size().max(1)).max(1);
                let trace = metrics.trace.clone();
                pool.map_chunks(n, chunk, move |range| {
                    range
                        .map(|i| {
                            let (q, k) = &shared[i];
                            run_one(q, *k, sdim, |q, k| index.search_traced(q, k, &trace))
                        })
                        .collect::<Vec<_>>()
                })
            } else {
                // Single query: fan it out across shards for latency.
                // Serial and fanned merges are order-exact, so the choice is
                // invisible in results. The whole batch runs against the one
                // `index` snapshot loaded above (never re-reads the slot
                // mid-batch).
                vec![shared
                    .iter()
                    .map(|(q, k)| {
                        run_one(q, *k, sdim, |q, k| {
                            if let Some(d) = index.as_delta() {
                                // Delta wrapper: fan its (possibly sharded)
                                // main out on the pool, scan the bounded
                                // delta inline.
                                return d.search_on_traced(pool, q, k, &metrics.trace);
                            }
                            match index.as_sharded() {
                                Some(sh) if sh.num_shards() > 1 => {
                                    sh.search_on_traced(pool, q, k, &metrics.trace)
                                }
                                _ => index.search_traced(q, k, &metrics.trace),
                            }
                        })
                    })
                    .collect()]
            }
        } else {
            let chunk = n.div_ceil(pool.size().max(1)).max(1);
            let shared = Arc::clone(&shared);
            let vecs = Arc::clone(&vecs_arc);
            let trace = metrics.trace.clone();
            pool.map_chunks(n, chunk, move |range| {
                range
                    .map(|i| {
                        let (q, k) = &shared[i];
                        run_one(q, *k, sdim, |q, k| {
                            let sw = Stopwatch::start();
                            let r = crate::knn::knn_indices(q, &vecs, sdim, k, metric);
                            trace.scan.record(sw.elapsed());
                            r
                        })
                    })
                    .collect::<Vec<_>>()
            })
        };

        let flat: Vec<Result<SearchResult>> = results.into_iter().flatten().collect();
        for (i, (it, res)) in items.into_iter().zip(flat).enumerate() {
            metrics.completed.inc();
            let took = it.submitted.elapsed();
            metrics.latency.record(took);
            vh.record(took);
            vc.inc();
            // Recall probe: shadow a sampled fraction of successful queries.
            // The job carries Arc snapshots, so the probe thread scans the
            // very vectors this query was served from (drop-not-block: a
            // full probe queue skips the sample rather than stall serving).
            if let (Some(p), Ok(r)) = (probe, &res) {
                if p.should_sample(&cname) {
                    let job = ProbeJob {
                        collection: cname.clone(),
                        query_full: it.query.clone(),
                        query_serving: shared[i].0.clone(),
                        k: it.k,
                        served: r.neighbors.iter().map(|nb| nb.index).collect(),
                        serving: Arc::clone(&vecs_arc),
                        serving_dim: sdim,
                        full: coll.full_arc(),
                        full_dim: coll.dim,
                        metric,
                    };
                    let _ = p.submit(job);
                }
            }
            let _ = it.resp.send(res);
        }

        fn items_queries(items: &[Item]) -> Vec<Vec<f32>> {
            items.iter().map(|i| i.query.clone()).collect()
        }
        fn items_ks(items: &[Item]) -> Vec<usize> {
            items.iter().map(|i| i.k).collect()
        }
    }
    metrics.exec_latency.record(exec_sw.elapsed());
}

/// Batch search through the `pairwise_topk_*` PJRT artifact. Returns one
/// [`SearchResult`] per query. Errors (shape too large for the artifact,
/// missing artifact) make the caller fall back to the CPU path.
pub fn runtime_batch_search(
    engine: &Engine,
    coll: &crate::coordinator::state::Collection,
    queries: &[Vec<f32>],
    ks: &[usize],
) -> Result<Vec<SearchResult>> {
    use crate::runtime::ArrayF32;
    let artifact = match coll.metric {
        Metric::SqEuclidean | Metric::Euclidean => "pairwise_topk_sqeuclidean",
        Metric::Cosine => "pairwise_topk_cosine",
        Metric::Manhattan => "pairwise_topk_manhattan",
        Metric::NegDot => return Err(OpdrError::runtime("no negdot artifact")),
    };
    let spec = engine.manifest().get(artifact)?.clone();
    // Artifact shapes: queries f32[Q, D], base f32[N, D] → dist f32[Q, K], idx f32[Q, K].
    let (q_cap, d_cap) = (spec.inputs[0].dims[0], spec.inputs[0].dims[1]);
    let n_cap = spec.inputs[1].dims[0];
    let k_cap = spec.outputs[0].dims[1];

    // Perf-pass Runtime-1: the padded base block + mask are cached in the
    // collection and rebuilt only when the serving state changes.
    let padded = coll.padded_base(n_cap, d_cap)?;
    let (n, sdim) = (padded.n, padded.dim);
    if n == 0 || queries.len() > q_cap {
        return Err(OpdrError::runtime("batch exceeds artifact capacity"));
    }
    if ks.iter().any(|&k| k > k_cap || k > n) {
        return Err(OpdrError::runtime("k exceeds artifact top-k"));
    }

    // Project queries into serving space and pad.
    let mut qblock = vec![0.0f32; queries.len() * sdim];
    for (i, q) in queries.iter().enumerate() {
        let p = coll.project_query(q)?;
        qblock[i * sdim..(i + 1) * sdim].copy_from_slice(&p);
    }
    let q_in = ArrayF32::padded_2d(&qblock, queries.len(), sdim, q_cap, d_cap)?;

    let out = engine.execute(artifact, &[q_in, padded.base.clone(), padded.mask.clone()])?;
    let dists = &out[0];
    let idxs = &out[1];

    let mut results = Vec::with_capacity(queries.len());
    for (qi, &k) in ks.iter().enumerate().take(queries.len()) {
        let mut neighbors = Vec::with_capacity(k);
        for j in 0..k {
            let idx = idxs.data[qi * k_cap + j] as usize;
            let distance = dists.data[qi * k_cap + j];
            if idx < n {
                neighbors.push(Neighbor { index: idx, distance });
            }
        }
        results.push(SearchResult { neighbors, scored_dim: sdim });
    }
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth, DatasetKind};

    fn test_cfg() -> ServeConfig {
        ServeConfig { workers: 2, max_batch: 8, max_wait_ms: 1, use_runtime: false, ..Default::default() }
    }

    #[test]
    fn lifecycle_create_ingest_search() {
        let coord = Coordinator::start(test_cfg()).unwrap();
        coord.create_collection("c", 16, Metric::SqEuclidean).unwrap();
        let set = synth::generate(DatasetKind::MaterialsObservable, 50, 16, 1);
        assert_eq!(coord.ingest("c", set.data().to_vec()).unwrap(), 50);

        let q = set.vector(3).to_vec();
        let res = coord.search("c", q, 5).unwrap();
        assert_eq!(res.neighbors.len(), 5);
        assert_eq!(res.neighbors[0].index, 3); // self is nearest
        assert_eq!(res.scored_dim, 16);
        coord.shutdown();
    }

    #[test]
    fn search_unknown_collection_errors() {
        let coord = Coordinator::start(test_cfg()).unwrap();
        let e = coord.search("missing", vec![0.0; 4], 2);
        assert!(e.is_err());
        coord.shutdown();
    }

    #[test]
    fn build_reduced_swaps_serving_dim() {
        let coord = Coordinator::start(test_cfg()).unwrap();
        coord.create_collection("c", 64, Metric::SqEuclidean).unwrap();
        let set = synth::generate(DatasetKind::MaterialsObservable, 70, 64, 2);
        coord.ingest("c", set.data().to_vec()).unwrap();
        let dim = coord.build_reduced("c", 0.85, 5).unwrap();
        assert!(dim >= 1 && dim < 64, "planned dim {dim}");
        let res = coord.search("c", set.vector(0).to_vec(), 3).unwrap();
        assert_eq!(res.scored_dim, dim);
        assert_eq!(res.neighbors[0].index, 0);
        coord.shutdown();
    }

    #[test]
    fn pipelined_async_searches_all_complete() {
        let coord = Coordinator::start(test_cfg()).unwrap();
        coord.create_collection("c", 8, Metric::SqEuclidean).unwrap();
        let set = synth::generate(DatasetKind::Flickr30k, 40, 8, 3);
        coord.ingest("c", set.data().to_vec()).unwrap();

        let mut rxs = Vec::new();
        for i in 0..30 {
            rxs.push(coord.search_async("c", set.vector(i % 40).to_vec(), 4).unwrap());
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv().unwrap().unwrap();
            assert_eq!(r.neighbors[0].index, i % 40);
        }
        assert_eq!(coord.metrics().completed.get(), 30);
        assert!(coord.metrics().batches.get() >= 1);
        coord.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let cfg = ServeConfig {
            workers: 1,
            max_batch: 2,
            queue_capacity: 2,
            max_wait_ms: 50,
            use_runtime: false,
            ..Default::default()
        };
        let coord = Coordinator::start(cfg).unwrap();
        coord.create_collection("c", 4, Metric::SqEuclidean).unwrap();
        // Big enough that scoring takes a moment.
        let set = synth::generate(DatasetKind::OmniCorpus, 2000, 4, 4);
        coord.ingest("c", set.data().to_vec()).unwrap();
        let mut rejected = 0;
        let mut rxs = Vec::new();
        for i in 0..200 {
            match coord.search_async("c", set.vector(i % 100).to_vec(), 2) {
                Ok(rx) => rxs.push(rx),
                Err(_) => rejected += 1,
            }
        }
        // Drain accepted ones.
        for rx in rxs {
            let _ = rx.recv();
        }
        // With a queue of 2 and slow scoring, some must have been rejected.
        assert!(rejected > 0, "expected backpressure rejections");
        assert_eq!(coord.metrics().rejected.get(), rejected as u64);
        coord.shutdown();
    }

    #[test]
    fn sharded_policy_served_collection_is_exact() {
        // A sharded exact index must serve byte-identical results to an
        // unsharded exact scan over the same vectors (same distance
        // kernel), and stats must report the shard count.
        let cfg = ServeConfig {
            workers: 2,
            max_batch: 8,
            max_wait_ms: 1,
            use_runtime: false,
            index_kind: crate::index::IndexKind::Exact,
            ivf_threshold: 0,
            shards: 4,
            shard_min_vectors: 1,
            ..Default::default()
        };
        let coord = Coordinator::start(cfg).unwrap();
        coord.create_collection("c", 12, Metric::SqEuclidean).unwrap();
        let set = synth::generate(DatasetKind::OmniCorpus, 120, 12, 8);
        coord.ingest("c", set.data().to_vec()).unwrap();

        let exact = crate::index::ExactIndex::build(
            set.data(),
            12,
            Metric::SqEuclidean,
            &crate::index::StorageSpec::flat(),
            1,
        )
        .unwrap();
        let want: Vec<Vec<(usize, u32)>> = (0..10)
            .map(|qi| {
                exact
                    .search(set.vector(qi), 5)
                    .unwrap()
                    .iter()
                    .map(|nb| (nb.index, nb.distance.to_bits()))
                    .collect()
            })
            .collect();

        coord.build_index("c").unwrap();
        let stats = coord.stats().unwrap();
        assert!(stats.contains("kind=exact") && stats.contains("shards=4"), "{stats}");
        // Accounting satellite: a flat index has no cold rerank tier, so
        // the stats line must not claim one.
        assert!(!stats.contains("cold_bytes="), "{stats}");
        for (qi, w) in want.iter().enumerate() {
            let got: Vec<(usize, u32)> = coord
                .search("c", set.vector(qi).to_vec(), 5)
                .unwrap()
                .neighbors
                .iter()
                .map(|nb| (nb.index, nb.distance.to_bits()))
                .collect();
            assert_eq!(&got, w, "query {qi} diverged under sharding");
        }
        coord.shutdown();
    }

    #[test]
    fn stats_reports_collections() {
        let coord = Coordinator::start(test_cfg()).unwrap();
        coord.create_collection("x", 8, Metric::Cosine).unwrap();
        let s = coord.stats().unwrap();
        assert!(s.contains("collection x"), "{s}");
        assert!(s.contains("building=0"), "{s}");
        assert!(s.contains("requests="));
        coord.shutdown();
    }

    #[test]
    fn build_tracker_counts_per_collection() {
        let t = BuildTracker::default();
        assert_eq!(t.in_flight("a"), 0);
        t.begin("a");
        t.begin("a");
        t.begin("b");
        assert_eq!(t.in_flight("a"), 2);
        assert_eq!(t.in_flight("b"), 1);
        assert_eq!(t.in_flight("c"), 0);
        assert_eq!(t.total(), 3);
        t.finish("a");
        assert_eq!(t.in_flight("a"), 1);
        t.finish("a");
        assert_eq!(t.in_flight("a"), 0);
        // Finishing a collection with no build in flight is a no-op, and an
        // unknown name never underflows.
        t.finish("a");
        t.finish("never-started");
        assert_eq!(t.total(), 1);
        t.finish("b");
        assert_eq!(t.total(), 0);
        // Compaction counters are per collection and independent of the
        // in-flight counts.
        assert_eq!(t.compactions("a"), 0);
        t.record_compaction("a");
        t.record_compaction("a");
        t.record_compaction("b");
        assert_eq!(t.compactions("a"), 2);
        assert_eq!(t.compactions("b"), 1);
        assert_eq!(t.compactions("never"), 0);
    }

    #[test]
    fn mmap_cold_tier_collection_serves_and_persists_exactly() {
        // The full vertical slice: a PQ collection whose rerank tier lives
        // in mmap'd cold files serves bitwise like the flat exact scan,
        // reports the mapped bytes in stats, and round-trips through the
        // version-5 cold file format.
        let n = 120;
        let dir =
            std::env::temp_dir().join(format!("opdr_coord_cold_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = ServeConfig {
            workers: 2,
            max_batch: 8,
            max_wait_ms: 1,
            use_runtime: false,
            index_kind: crate::index::IndexKind::Exact,
            ivf_threshold: 0,
            index_pq: true,
            rerank_depth: n,
            cold_tier_mmap: true,
            cold_dir: dir.join("tier").to_string_lossy().into_owned(),
            ..Default::default()
        };
        let coord = Coordinator::start(cfg).unwrap();
        coord.create_collection("c", 8, Metric::SqEuclidean).unwrap();
        let set = synth::generate(DatasetKind::OmniCorpus, n, 8, 77);
        coord.ingest("c", set.data().to_vec()).unwrap();
        coord.build_index("c").unwrap();
        let stats = coord.stats().unwrap();
        assert!(stats.contains("storage=pq"), "{stats}");
        assert!(
            stats.contains("cold_bytes=") && stats.contains("mapped_bytes="),
            "{stats}"
        );
        let flat = crate::index::ExactIndex::build(
            set.data(),
            8,
            Metric::SqEuclidean,
            &crate::index::StorageSpec::flat(),
            1,
        )
        .unwrap();
        let check = |coord: &Coordinator| {
            for qi in [0usize, 41, 119] {
                let want: Vec<(usize, u32)> = flat
                    .search(set.vector(qi), 6)
                    .unwrap()
                    .iter()
                    .map(|nb| (nb.index, nb.distance.to_bits()))
                    .collect();
                let got: Vec<(usize, u32)> = coord
                    .search("c", set.vector(qi).to_vec(), 6)
                    .unwrap()
                    .neighbors
                    .iter()
                    .map(|nb| (nb.index, nb.distance.to_bits()))
                    .collect();
                assert_eq!(got, want, "query {qi} diverged under the mmap tier");
            }
        };
        check(&coord);
        // Save writes the version-5 cold layout; loading it back serves
        // identically (the annex now maps straight from the saved file).
        let path = dir.join("c.opdx");
        let path_str = path.to_string_lossy().into_owned();
        coord.save_index("c", &path_str).unwrap();
        let raw = std::fs::read(&path).unwrap();
        assert_eq!(u32::from_le_bytes(raw[4..8].try_into().unwrap()), 5, "v5 on disk");
        coord.load_index("c", &path_str).unwrap();
        check(&coord);
        coord.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pq_policy_served_collection_is_exact_at_exhaustive_depth() {
        // A PQ-compressed exact index at rerank_depth ≥ n serves bitwise the
        // same results as the flat exact scan, through the whole coordinator
        // path, and stats report the pq storage + cold tier.
        let n = 150;
        let cfg = ServeConfig {
            workers: 2,
            max_batch: 8,
            max_wait_ms: 1,
            use_runtime: false,
            index_kind: crate::index::IndexKind::Exact,
            ivf_threshold: 0,
            index_pq: true,
            rerank_depth: n,
            ..Default::default()
        };
        let coord = Coordinator::start(cfg).unwrap();
        coord.create_collection("c", 8, Metric::SqEuclidean).unwrap();
        let set = synth::generate(DatasetKind::OmniCorpus, n, 8, 12);
        coord.ingest("c", set.data().to_vec()).unwrap();
        coord.build_index("c").unwrap();
        let stats = coord.stats().unwrap();
        assert!(stats.contains("storage=pq") && stats.contains("quantized=true"), "{stats}");
        let flat = crate::index::ExactIndex::build(
            set.data(),
            8,
            Metric::SqEuclidean,
            &crate::index::StorageSpec::flat(),
            1,
        )
        .unwrap();
        for qi in 0..10 {
            let want: Vec<(usize, u32)> = flat
                .search(set.vector(qi), 6)
                .unwrap()
                .iter()
                .map(|nb| (nb.index, nb.distance.to_bits()))
                .collect();
            let got: Vec<(usize, u32)> = coord
                .search("c", set.vector(qi).to_vec(), 6)
                .unwrap()
                .neighbors
                .iter()
                .map(|nb| (nb.index, nb.distance.to_bits()))
                .collect();
            assert_eq!(got, want, "query {qi} diverged under pq");
        }
        coord.shutdown();
    }

    #[test]
    fn poisoned_build_tracker_keeps_counting() {
        // Regression companion to the state-layer poison tests: a panic in
        // a build worker holding a tracker lock must not take down stats
        // reporting or the deferred-build bookkeeping on other threads.
        let t = BuildTracker::default();
        t.begin("c");
        t.begin("c");
        t.record_compaction("c");
        fn poison<T: Send>(m: &Mutex<T>) {
            std::thread::scope(|s| {
                let r = s
                    .spawn(|| {
                        // lint:allow(no-naked-lock-unwrap: deliberately poisoning the lock)
                        let _g = m.lock().unwrap();
                        panic!("poison");
                    })
                    .join();
                assert!(r.is_err(), "the poisoning thread must have panicked");
            });
            assert!(m.is_poisoned());
        }
        poison(&t.inner);
        poison(&t.compactions);

        // Reads and writes keep working across both poisoned locks.
        assert_eq!(t.in_flight("c"), 2);
        assert_eq!(t.total(), 2);
        t.finish("c");
        assert_eq!(t.in_flight("c"), 1);
        t.record_compaction("c");
        assert_eq!(t.compactions("c"), 2);
    }
}
