//! Collection state: vectors, fitted reducers, optional ANN index.

use crate::config::IndexPolicy;
use crate::data::EmbeddingSet;
use crate::error::{OpdrError, Result};
use crate::index::AnnIndex;
use crate::knn::Neighbor;
use crate::metrics::Metric;
use crate::opdr::Planner;
use crate::pool::ThreadPool;
use crate::reduction::{Pca, PcaModel, ReducerKind};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Atomic slot for a collection's serving index.
///
/// Searches [`load`](IndexSlot::load) an `Arc` snapshot under a briefly-held
/// lock, so serving never blocks on a rebuild; background builds
/// [`install`](IndexSlot::install) their result with the generation they
/// snapshotted — if an ingest or re-reduce bumped the generation in the
/// meantime ([`invalidate`](IndexSlot::invalidate)) the stale index is
/// dropped instead of installed, so a search can never observe an index
/// built from vectors the collection no longer serves.
#[derive(Debug, Default)]
pub struct IndexSlot {
    inner: Mutex<(u64, Option<Arc<dyn AnnIndex>>)>,
}

impl IndexSlot {
    /// Snapshot the current index (if any).
    pub fn load(&self) -> Option<Arc<dyn AnnIndex>> {
        self.inner.lock().unwrap().1.clone()
    }

    /// Current generation (captured before a build, checked at install).
    pub fn generation(&self) -> u64 {
        self.inner.lock().unwrap().0
    }

    /// Drop the index and bump the generation (serving state changed).
    pub fn invalidate(&self) {
        let mut g = self.inner.lock().unwrap();
        g.0 += 1;
        g.1 = None;
    }

    /// Atomically swap `index` in iff the generation still matches; returns
    /// whether the install happened.
    pub fn install(&self, index: Arc<dyn AnnIndex>, generation: u64) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.0 != generation {
            return false;
        }
        g.1 = Some(index);
        true
    }

    /// Bump the generation and install `index` in one step (the synchronous
    /// build/load paths): any background build still in flight against an
    /// older snapshot is thereby invalidated and its later install refused,
    /// so an explicitly built or loaded index is never silently replaced by
    /// a stale rebuild finishing afterwards.
    pub fn replace(&self, index: Arc<dyn AnnIndex>) {
        let mut g = self.inner.lock().unwrap();
        g.0 += 1;
        g.1 = Some(index);
    }
}

/// Zero-padded fixed-shape copy of the serving vectors for the PJRT
/// `pairwise_topk` artifact (perf-pass Runtime-1: built once per serving
/// state instead of per batch).
#[derive(Debug, Clone)]
pub struct PaddedBase {
    /// Base block padded to `[n_cap, d_cap]`.
    pub base: crate::runtime::ArrayF32,
    /// Padding mask `[n_cap]` (1.0 on dead rows).
    pub mask: crate::runtime::ArrayF32,
    /// Live rows.
    pub n: usize,
    /// Live dims.
    pub dim: usize,
}

/// A named vector collection with optional OPDR-reduced serving copy.
#[derive(Debug)]
pub struct Collection {
    /// Collection name.
    pub name: String,
    /// Full-dimensional vectors.
    pub dim: usize,
    data: Vec<f32>,
    /// Serving metric.
    pub metric: Metric,
    /// OPDR-reduced serving state, if built.
    pub reduced: Option<ReducedState>,
    /// ANN index over the active serving vectors (substrate chosen by the
    /// configured [`IndexPolicy`]: exact / IVF-Flat / HNSW, optionally SQ8,
    /// optionally sharded), behind an atomic slot so background rebuilds
    /// swap in without blocking searches.
    index: Arc<IndexSlot>,
    /// Shared snapshot of the serving vectors for worker threads (perf-pass
    /// L3-2: avoids cloning the whole block every batch). Invalidated on
    /// ingest / build_reduced.
    serving_cache: Mutex<Option<Arc<Vec<f32>>>>,
    /// Cached padded block for the PJRT artifact path, keyed by (n_cap, d_cap).
    padded_cache: Mutex<Option<((usize, usize), Arc<PaddedBase>)>>,
}

/// The reduced-dimension serving copy plus the model that produced it.
#[derive(Debug)]
pub struct ReducedState {
    /// Fitted projection (also used for query-time projection).
    pub model: PcaModel,
    /// Reduced vectors, row-major `n × reduced_dim`.
    pub data: Vec<f32>,
    /// The planner fit used to choose the dimension.
    pub planner: Planner,
    /// Accuracy target requested.
    pub target_accuracy: f64,
}

impl Collection {
    /// New empty collection.
    pub fn new(name: impl Into<String>, dim: usize, metric: Metric) -> Result<Self> {
        if dim == 0 {
            return Err(OpdrError::shape("collection: dim must be > 0"));
        }
        Ok(Collection {
            name: name.into(),
            dim,
            data: Vec::new(),
            metric,
            reduced: None,
            index: Arc::new(IndexSlot::default()),
            serving_cache: Mutex::new(None),
            padded_cache: Mutex::new(None),
        })
    }

    /// Snapshot of the serving index, if one is installed.
    pub fn index(&self) -> Option<Arc<dyn AnnIndex>> {
        self.index.load()
    }

    /// Number of vectors.
    pub fn len(&self) -> usize {
        self.data.len() / self.dim
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Raw full-dimensional vectors.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Append vectors (row-major, multiple of `dim`). Invalidates any reduced
    /// copy / index (they must be rebuilt).
    pub fn ingest(&mut self, vectors: &[f32]) -> Result<usize> {
        if vectors.len() % self.dim != 0 {
            return Err(OpdrError::shape(format!(
                "ingest into `{}`: {} floats is not a multiple of dim {}",
                self.name,
                vectors.len(),
                self.dim
            )));
        }
        self.data.extend_from_slice(vectors);
        self.reduced = None;
        self.index.invalidate();
        self.invalidate_caches();
        Ok(vectors.len() / self.dim)
    }

    fn invalidate_caches(&self) {
        *self.serving_cache.lock().unwrap() = None;
        *self.padded_cache.lock().unwrap() = None;
    }

    /// Shared snapshot of the serving vectors (built lazily, invalidated on
    /// state changes). Worker threads score against this without copying.
    pub fn serving_arc(&self) -> Arc<Vec<f32>> {
        let mut guard = self.serving_cache.lock().unwrap();
        if let Some(arc) = guard.as_ref() {
            return Arc::clone(arc);
        }
        let (vecs, _) = self.serving_vectors();
        let arc = Arc::new(vecs.to_vec());
        *guard = Some(Arc::clone(&arc));
        arc
    }

    /// Cached zero-padded serving block for the PJRT artifact path.
    pub fn padded_base(&self, n_cap: usize, d_cap: usize) -> Result<Arc<PaddedBase>> {
        let mut guard = self.padded_cache.lock().unwrap();
        if let Some((key, arc)) = guard.as_ref() {
            if *key == (n_cap, d_cap) {
                return Ok(Arc::clone(arc));
            }
        }
        let (vecs, dim) = self.serving_vectors();
        let n = vecs.len() / dim.max(1);
        if n > n_cap || dim > d_cap {
            return Err(OpdrError::runtime("collection exceeds artifact capacity"));
        }
        let base = crate::runtime::ArrayF32::padded_2d(vecs, n, dim, n_cap, d_cap)?;
        let mut mask = vec![0.0f32; n_cap];
        for m in mask.iter_mut().skip(n) {
            *m = 1.0;
        }
        let mask = crate::runtime::ArrayF32::new(mask, vec![n_cap])?;
        let arc = Arc::new(PaddedBase { base, mask, n, dim });
        *guard = Some(((n_cap, d_cap), Arc::clone(&arc)));
        Ok(arc)
    }

    /// Build the OPDR-reduced serving copy: calibrate the planner on (a
    /// sample of) this collection, choose `dim(Y)` for `target_accuracy`,
    /// fit PCA at that dimension and project everything.
    pub fn build_reduced(
        &mut self,
        target_accuracy: f64,
        k: usize,
        calibration_sample: usize,
        seed: u64,
    ) -> Result<&ReducedState> {
        let n = self.len();
        if n < k + 2 {
            return Err(OpdrError::data(format!(
                "collection `{}` has {n} vectors; need > k+1 = {}",
                self.name,
                k + 1
            )));
        }
        // Calibrate on a subsample to bound the sweep cost.
        let sample_n = calibration_sample.clamp(k + 2, n);
        let mut rng = crate::util::Rng::new(seed);
        let idx = rng.sample_indices(n, sample_n);
        let mut sample = Vec::with_capacity(sample_n * self.dim);
        for &i in &idx {
            sample.extend_from_slice(&self.data[i * self.dim..(i + 1) * self.dim]);
        }
        let planner =
            Planner::calibrate(&sample, self.dim, k, self.metric, ReducerKind::Pca, seed)?;
        let target_dim = planner.dim_for_accuracy(target_accuracy, sample_n).min(self.dim);

        let model = Pca::new().fit(&sample, self.dim, target_dim)?;
        let data = model.project(&self.data)?;
        self.reduced = Some(ReducedState { model, data, planner, target_accuracy });
        self.index.invalidate();
        self.invalidate_caches();
        Ok(self.reduced.as_ref().unwrap())
    }

    /// Build (or rebuild) the ANN index over the active serving vectors,
    /// with the substrate chosen by `policy` (exact below its threshold,
    /// then IVF/HNSW, optionally SQ8-quantized, sharded when
    /// `policy.shards > 1`). Blocks the caller; the coordinator's scheduler
    /// uses [`Collection::spawn_index_build`] instead so serving never
    /// waits on a rebuild.
    pub fn build_index(&mut self, policy: &IndexPolicy, seed: u64) -> Result<()> {
        let (vecs, dim) = self.serving_vectors();
        if vecs.is_empty() {
            return Err(OpdrError::data("build_index: empty collection"));
        }
        let index = crate::index::build_index(vecs, dim, self.metric, policy, seed)?;
        self.index.replace(Arc::from(index));
        Ok(())
    }

    /// Rebuild the index off-thread: snapshot the serving vectors, fan
    /// whole-segment builds out to `pool`
    /// ([`crate::index::shard::build_on_pool`]) and atomically swap the
    /// result in when done — searches keep serving the old index (or the
    /// exact scan) throughout. `on_done` runs on the collector thread with
    /// `Ok(true)` when the index was installed, `Ok(false)` when the
    /// collection changed while building (the stale index is discarded,
    /// never installed — serving falls back to the exact scan), and `Err`
    /// when the build itself failed.
    pub fn spawn_index_build(
        &self,
        policy: &IndexPolicy,
        seed: u64,
        pool: &ThreadPool,
        on_done: impl FnOnce(Result<bool>) + Send + 'static,
    ) {
        let data = self.serving_arc();
        let (_, dim) = self.serving_vectors();
        let metric = self.metric;
        let slot = Arc::clone(&self.index);
        let generation = slot.generation();
        crate::index::shard::build_on_pool(data, dim, metric, policy, seed, pool, move |res| {
            match res {
                Ok(index) => on_done(Ok(slot.install(Arc::from(index), generation))),
                Err(e) => on_done(Err(e)),
            }
        });
    }

    /// Persist the built index as an `OPDR` index segment.
    pub fn save_index(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let index = self.index().ok_or_else(|| {
            OpdrError::coordinator(format!("collection `{}` has no index to save", self.name))
        })?;
        crate::data::store::save_index(index.as_ref(), path)
    }

    /// Load a previously saved index segment, validating it against the
    /// current serving vectors (same count and dimensionality — an index
    /// built for different data must never silently serve it).
    pub fn load_index(&mut self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let index = crate::data::store::load_index(path)?;
        let (vecs, dim) = self.serving_vectors();
        let n = vecs.len() / dim.max(1);
        if index.dim() != dim || index.len() != n {
            return Err(OpdrError::coordinator(format!(
                "collection `{}`: loaded index is {}x{} but serving state is {}x{}",
                self.name,
                index.len(),
                index.dim(),
                n,
                dim
            )));
        }
        if index.metric() != self.metric {
            return Err(OpdrError::coordinator(format!(
                "collection `{}`: loaded index metric {} != collection metric {}",
                self.name,
                index.metric().name(),
                self.metric.name()
            )));
        }
        if !index.matches_data(vecs) {
            return Err(OpdrError::coordinator(format!(
                "collection `{}`: loaded index was built from different vectors \
                 than the current serving state",
                self.name
            )));
        }
        self.index.replace(Arc::from(index));
        Ok(())
    }

    /// The vectors queries are scored against: reduced copy if built, else
    /// the full-dimensional data.
    pub fn serving_vectors(&self) -> (&[f32], usize) {
        match &self.reduced {
            Some(r) => (&r.data, r.model.target_dim()),
            None => (&self.data, self.dim),
        }
    }

    /// Project a full-dimensional query into the serving space.
    pub fn project_query(&self, query: &[f32]) -> Result<Vec<f32>> {
        if query.len() != self.dim {
            return Err(OpdrError::shape(format!(
                "query dim {} != collection dim {}",
                query.len(),
                self.dim
            )));
        }
        match &self.reduced {
            Some(r) => r.model.project(query),
            None => Ok(query.to_vec()),
        }
    }

    /// Exact (or index-approximate, if indexed) k-NN search for a single
    /// *already-projected* query. Probe widths / beam sizes are baked into
    /// the index at build time by the [`IndexPolicy`].
    pub fn search_projected(&self, query: &[f32], k: usize) -> Result<Vec<Neighbor>> {
        self.search_projected_with(query, k, None)
    }

    /// [`Collection::search_projected`] with an optional worker pool: a
    /// multi-shard index fans the query out across its segments on the pool
    /// (byte-identical results to the serial path — the merge is
    /// order-exact). Must not be called from a pool worker.
    pub fn search_projected_with(
        &self,
        query: &[f32],
        k: usize,
        pool: Option<&ThreadPool>,
    ) -> Result<Vec<Neighbor>> {
        let (vecs, dim) = self.serving_vectors();
        if query.len() != dim {
            return Err(OpdrError::shape("search: projected query dim mismatch"));
        }
        if let Some(index) = self.index() {
            if let (Some(pool), Some(sharded)) = (pool, index.as_sharded()) {
                if sharded.num_shards() > 1 {
                    return sharded.search_on(pool, query, k);
                }
            }
            index.search(query, k)
        } else {
            crate::knn::knn_indices(query, vecs, dim, k, self.metric)
        }
    }
}

/// All collections, keyed by name.
#[derive(Debug, Default)]
pub struct Collections {
    map: HashMap<String, Collection>,
}

impl Collections {
    /// Empty registry.
    pub fn new() -> Self {
        Collections::default()
    }

    /// Create a collection; errors if the name exists.
    pub fn create(&mut self, name: &str, dim: usize, metric: Metric) -> Result<()> {
        if self.map.contains_key(name) {
            return Err(OpdrError::coordinator(format!("collection `{name}` already exists")));
        }
        self.map.insert(name.to_string(), Collection::new(name, dim, metric)?);
        Ok(())
    }

    /// Borrow a collection.
    pub fn get(&self, name: &str) -> Result<&Collection> {
        self.map
            .get(name)
            .ok_or_else(|| OpdrError::coordinator(format!("no collection `{name}`")))
    }

    /// Borrow mutably.
    pub fn get_mut(&mut self, name: &str) -> Result<&mut Collection> {
        self.map
            .get_mut(name)
            .ok_or_else(|| OpdrError::coordinator(format!("no collection `{name}`")))
    }

    /// Names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.map.keys().cloned().collect();
        v.sort();
        v
    }

    /// Load a generated [`EmbeddingSet`] as a new collection.
    pub fn create_from_set(&mut self, name: &str, set: &EmbeddingSet, metric: Metric) -> Result<()> {
        self.create(name, set.dim(), metric)?;
        self.get_mut(name)?.ingest(set.data())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth, DatasetKind};

    fn seeded_collection(n: usize, dim: usize) -> Collection {
        let set = synth::generate(DatasetKind::MaterialsObservable, n, dim, 5);
        let mut c = Collection::new("test", dim, Metric::SqEuclidean).unwrap();
        c.ingest(set.data()).unwrap();
        c
    }

    #[test]
    fn ingest_and_len() {
        let mut c = Collection::new("c", 4, Metric::Euclidean).unwrap();
        assert_eq!(c.ingest(&[0.0; 12]).unwrap(), 3);
        assert_eq!(c.len(), 3);
        assert!(c.ingest(&[0.0; 5]).is_err());
    }

    #[test]
    fn build_reduced_and_search() {
        let mut c = seeded_collection(60, 64);
        let r = c.build_reduced(0.8, 5, 50, 1).unwrap();
        let rdim = r.model.target_dim();
        assert!(rdim >= 1 && rdim <= 64);
        let (vecs, dim) = c.serving_vectors();
        assert_eq!(dim, rdim);
        assert_eq!(vecs.len(), 60 * rdim);

        // Search with a projected query: the top hit for a stored vector's own
        // full-dim form should be itself.
        let q_full: Vec<f32> = c.data()[..64].to_vec();
        let q = c.project_query(&q_full).unwrap();
        let hits = c.search_projected(&q, 3).unwrap();
        assert_eq!(hits[0].index, 0);
    }

    #[test]
    fn reduced_search_recall_vs_full() {
        let mut c = seeded_collection(80, 64);
        // Ground truth in full space.
        let q: Vec<f32> = c.data()[5 * 64..6 * 64].to_vec();
        let full = crate::knn::knn_indices(&q, c.data(), 64, 10, Metric::SqEuclidean).unwrap();
        c.build_reduced(0.9, 10, 60, 2).unwrap();
        let qp = c.project_query(&q).unwrap();
        let red = c.search_projected(&qp, 10).unwrap();
        let full_set: std::collections::HashSet<usize> = full.iter().map(|n| n.index).collect();
        let hits = red.iter().filter(|n| full_set.contains(&n.index)).count();
        assert!(hits >= 5, "recall too low: {hits}/10");
    }

    #[test]
    fn ingest_invalidates_reduced() {
        let mut c = seeded_collection(40, 32);
        c.build_reduced(0.8, 5, 30, 1).unwrap();
        assert!(c.reduced.is_some());
        c.ingest(&vec![0.0; 32]).unwrap();
        assert!(c.reduced.is_none());
    }

    #[test]
    fn index_path_used_when_built() {
        let mut c = seeded_collection(100, 16);
        let policy = IndexPolicy {
            exact_threshold: 10,
            ivf_nlist: 8,
            ivf_nprobe: 8,
            ..Default::default()
        };
        c.build_index(&policy, 3).unwrap();
        assert!(c.index().is_some());
        assert_eq!(c.index().unwrap().kind(), crate::index::IndexKind::Ivf);
        let q: Vec<f32> = c.data()[..16].to_vec();
        let hits = c.search_projected(&q, 5).unwrap();
        assert_eq!(hits[0].index, 0);
    }

    #[test]
    fn policy_selects_exact_below_threshold_and_hnsw_above() {
        let mut c = seeded_collection(80, 16);
        let policy = IndexPolicy {
            kind: crate::index::IndexKind::Hnsw,
            exact_threshold: 1000,
            ..Default::default()
        };
        c.build_index(&policy, 1).unwrap();
        assert_eq!(c.index().unwrap().kind(), crate::index::IndexKind::Exact);

        let policy = IndexPolicy { exact_threshold: 10, ..policy };
        c.build_index(&policy, 1).unwrap();
        let idx = c.index().unwrap();
        assert_eq!(idx.kind(), crate::index::IndexKind::Hnsw);
        let q: Vec<f32> = c.data()[3 * 16..4 * 16].to_vec();
        let hits = c.search_projected(&q, 5).unwrap();
        assert_eq!(hits[0].index, 3);
    }

    #[test]
    fn index_save_load_roundtrip_with_validation() {
        let dir = std::env::temp_dir().join(format!("opdr_state_idx_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.opdx");

        let mut c = seeded_collection(120, 16);
        let policy = IndexPolicy {
            kind: crate::index::IndexKind::Hnsw,
            exact_threshold: 10,
            sq8: true,
            ..Default::default()
        };
        c.build_index(&policy, 7).unwrap();
        let q: Vec<f32> = c.data()[5 * 16..6 * 16].to_vec();
        let before = c.search_projected(&q, 6).unwrap();
        c.save_index(&path).unwrap();

        // Fresh collection over the same data loads and serves identically.
        let mut c2 = seeded_collection(120, 16);
        c2.load_index(&path).unwrap();
        let after = c2.search_projected(&q, 6).unwrap();
        assert_eq!(before.len(), after.len());
        for (a, b) in before.iter().zip(&after) {
            assert_eq!(a.index, b.index);
            assert_eq!(a.distance.to_bits(), b.distance.to_bits());
        }

        // A mismatched collection refuses the segment.
        let mut c3 = seeded_collection(60, 16);
        let e = c3.load_index(&path).unwrap_err().to_string();
        assert!(e.contains("serving state"), "{e}");

        // Same shape but different data must also be refused.
        let set = synth::generate(DatasetKind::MaterialsObservable, 120, 16, 999);
        let mut c4 = Collection::new("other-data", 16, Metric::SqEuclidean).unwrap();
        c4.ingest(set.data()).unwrap();
        let e = c4.load_index(&path).unwrap_err().to_string();
        assert!(e.contains("different vectors"), "{e}");

        // No index → save errors.
        assert!(c3.save_index(dir.join("none.opdx")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ingest_invalidates_index() {
        let mut c = seeded_collection(50, 8);
        let policy = IndexPolicy { exact_threshold: 0, ..Default::default() };
        c.build_index(&policy, 1).unwrap();
        assert!(c.index().is_some());
        c.ingest(&vec![0.0; 8]).unwrap();
        assert!(c.index().is_none());
    }

    #[test]
    fn index_slot_generation_guard_drops_stale_installs() {
        let slot = IndexSlot::default();
        let data = vec![0.0f32; 8 * 4];
        let idx: Arc<dyn AnnIndex> = Arc::from(
            crate::index::build_index(
                &data,
                4,
                Metric::Euclidean,
                &IndexPolicy { kind: crate::index::IndexKind::Exact, ..Default::default() },
                1,
            )
            .unwrap(),
        );
        let gen0 = slot.generation();
        assert!(slot.install(Arc::clone(&idx), gen0));
        assert!(slot.load().is_some());
        // Invalidate (as ingest does), then try to install with the stale
        // generation: the install must be refused and the slot stay empty.
        slot.invalidate();
        assert!(slot.load().is_none());
        assert!(!slot.install(Arc::clone(&idx), gen0));
        assert!(slot.load().is_none());
        // A fresh generation installs fine.
        assert!(slot.install(Arc::clone(&idx), slot.generation()));
        assert!(slot.load().is_some());
        // `replace` (sync build / load paths) bumps the generation, so a
        // background build that snapshotted before it can't stomp the
        // explicitly installed index.
        let pre_replace = slot.generation();
        slot.replace(Arc::clone(&idx));
        assert!(!slot.install(idx, pre_replace));
        assert!(slot.load().is_some());
    }

    #[test]
    fn spawn_index_build_installs_off_thread() {
        let c = seeded_collection(80, 8);
        let pool = ThreadPool::new(2);
        let policy = IndexPolicy {
            kind: crate::index::IndexKind::Exact,
            exact_threshold: 0,
            shards: 4,
            shard_min_vectors: 1,
            ..Default::default()
        };
        let (tx, rx) = std::sync::mpsc::channel();
        c.spawn_index_build(&policy, 3, &pool, move |r| {
            let _ = tx.send(r);
        });
        assert!(rx.recv().unwrap().unwrap(), "install reported refused");
        let idx = c.index().expect("index installed");
        assert_eq!(idx.as_sharded().unwrap().num_shards(), 4);
        // Sharded search through the collection equals an unsharded exact
        // scan (same distance kernel; the matmul-form brute path rounds
        // differently, so it is only id-equal, not bit-equal).
        let q: Vec<f32> = c.data()[5 * 8..6 * 8].to_vec();
        let exact = crate::index::ExactIndex::build(
            c.data(),
            8,
            Metric::SqEuclidean,
            &crate::index::StorageSpec::flat(),
            1,
        )
        .unwrap();
        let want = exact.search(&q, 6).unwrap();
        for use_pool in [None, Some(&pool)] {
            let got = c.search_projected_with(&q, 6, use_pool).unwrap();
            crate::testing::assert_same_neighbors(&got, &want);
        }
    }

    #[test]
    fn spawn_index_build_reports_errors_and_skips_stale_installs() {
        // Empty collection: the build fails through `on_done`.
        let c = Collection::new("empty", 4, Metric::Euclidean).unwrap();
        let pool = ThreadPool::new(1);
        let (tx, rx) = std::sync::mpsc::channel();
        c.spawn_index_build(&IndexPolicy::default(), 1, &pool, move |r| {
            let _ = tx.send(r);
        });
        assert!(rx.recv().unwrap().is_err());

        // Ingest-after-snapshot: force the race deterministically by bumping
        // the generation before the collector can install.
        let mut c = seeded_collection(40, 8);
        let (tx, rx) = std::sync::mpsc::channel();
        {
            // Hold the pool hostage so the build can't finish yet.
            let (block_tx, block_rx) = std::sync::mpsc::channel::<()>();
            pool.execute(move || {
                let _ = block_rx.recv();
            });
            c.spawn_index_build(
                &IndexPolicy { exact_threshold: 0, ..Default::default() },
                1,
                &pool,
                move |r| {
                    let _ = tx.send(r);
                },
            );
            c.ingest(&vec![0.0; 8]).unwrap(); // bumps the generation
            block_tx.send(()).unwrap(); // release the pool
        }
        let res = rx.recv().unwrap();
        assert!(!res.unwrap(), "stale install must be refused");
        assert!(c.index().is_none(), "stale index must not be installed");
    }

    #[test]
    fn registry_create_get_duplicate() {
        let mut cs = Collections::new();
        cs.create("a", 8, Metric::Euclidean).unwrap();
        assert!(cs.create("a", 8, Metric::Euclidean).is_err());
        assert!(cs.get("a").is_ok());
        assert!(cs.get("b").is_err());
        assert_eq!(cs.names(), vec!["a".to_string()]);
    }

    #[test]
    fn too_few_vectors_for_reduce() {
        let mut c = Collection::new("tiny", 8, Metric::Euclidean).unwrap();
        c.ingest(&[0.0; 16]).unwrap(); // 2 vectors
        assert!(c.build_reduced(0.8, 5, 10, 1).is_err());
    }
}
