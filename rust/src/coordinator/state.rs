//! Collection state: vectors, fitted reducers, optional ANN index.

use crate::config::IndexPolicy;
use crate::data::EmbeddingSet;
use crate::error::{OpdrError, Result};
use crate::index::{AnnIndex, DeltaIndex};
use crate::knn::Neighbor;
use crate::metrics::Metric;
use crate::opdr::Planner;
use crate::pool::ThreadPool;
use crate::reduction::{Pca, PcaModel, ReducerKind};
use crate::telemetry::BuildSpans;
use crate::util::{lock_recover_ranked, ranks, Stopwatch};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Atomic slot for a collection's serving index.
///
/// Searches [`load`](IndexSlot::load) an `Arc` snapshot under a briefly-held
/// lock, so serving never blocks on a rebuild; background builds
/// [`install_rebased`](IndexSlot::install_rebased) their result with the
/// generation they snapshotted — if a wholesale serving-state change bumped
/// the generation in the meantime ([`invalidate`](IndexSlot::invalidate) /
/// [`replace`](IndexSlot::replace)) the stale index is dropped instead of
/// installed, so a search can never observe an index built from vectors the
/// collection no longer serves, while rows appended incrementally
/// ([`append_delta`](IndexSlot::append_delta)) are re-parented onto the
/// installed index's delta instead of being lost.
#[derive(Debug, Default)]
pub struct IndexSlot {
    inner: Mutex<(u64, Option<Arc<dyn AnnIndex>>)>,
}

impl IndexSlot {
    /// Snapshot the current index (if any).
    pub fn load(&self) -> Option<Arc<dyn AnnIndex>> {
        lock_recover_ranked(&self.inner, ranks::COORDINATOR_STATE).1.clone()
    }

    /// Current generation (captured before a build, checked at install).
    pub fn generation(&self) -> u64 {
        lock_recover_ranked(&self.inner, ranks::COORDINATOR_STATE).0
    }

    /// Drop the index and bump the generation (serving state changed).
    pub fn invalidate(&self) {
        let mut g = lock_recover_ranked(&self.inner, ranks::COORDINATOR_STATE);
        g.0 += 1;
        g.1 = None;
    }

    /// Bump the generation and install `index` in one step (the synchronous
    /// build/load paths): any background build still in flight against an
    /// older snapshot is thereby invalidated and its later install refused,
    /// so an explicitly built or loaded index is never silently replaced by
    /// a stale rebuild finishing afterwards.
    pub fn replace(&self, index: Arc<dyn AnnIndex>) {
        let mut g = lock_recover_ranked(&self.inner, ranks::COORDINATOR_STATE);
        g.0 += 1;
        g.1 = Some(index);
    }

    /// Incremental-ingest path: absorb `rows` (already in the serving space)
    /// into the serving index's delta segment by installing a new
    /// [`DeltaIndex`] wrapper that shares the main index `Arc` — the
    /// generation is *not* bumped, so a background compaction snapshotted
    /// before this append can still install via
    /// [`install_rebased`](IndexSlot::install_rebased), which re-parents
    /// these rows onto the new main. Returns whether the rows were
    /// absorbed; when no index is
    /// installed (or the wrapper cannot be built) there is nothing to
    /// absorb them into, so the generation is bumped instead — exactly like
    /// [`invalidate`](IndexSlot::invalidate) — ensuring an in-flight build
    /// covering fewer rows can never install.
    pub fn append_delta(&self, rows: &[f32]) -> bool {
        let mut g = lock_recover_ranked(&self.inner, ranks::COORDINATOR_STATE);
        let Some(cur) = g.1.clone() else {
            g.0 += 1;
            return false;
        };
        let wrapper = if let Some(d) = cur.as_delta() {
            d.extended(rows)
        } else {
            DeltaIndex::from_parts(Arc::clone(&cur), rows.to_vec())
        };
        match wrapper {
            Ok(w) => {
                g.1 = Some(Arc::new(w));
                true
            }
            Err(_) => {
                // Shape/metric drift between the installed index and the
                // serving rows: fall back to invalidation rather than serve
                // a wrapper that mislabels ids.
                g.0 += 1;
                g.1 = None;
                false
            }
        }
    }

    /// Generation-guarded install that tolerates delta appends: `index` was
    /// built from a snapshot covering serving rows `0..covered` at
    /// `generation`. If the generation still matches and no rows appeared
    /// since, `index` is installed bare; if delta-mode ingests appended rows
    /// past the snapshot (appends don't bump the generation), those rows are
    /// re-parented onto `index` as the new delta ([`DeltaIndex::rebase`]) —
    /// an ingest racing a compaction lands in the *new* delta, is never
    /// lost, and is never indexed twice. A wholesale change (invalidate /
    /// replace) bumps the generation and refuses the install as before.
    /// Successful installs bump the generation so a second in-flight build
    /// from the same snapshot cannot double-install. Returns whether the
    /// install happened.
    pub fn install_rebased(
        &self,
        index: Arc<dyn AnnIndex>,
        covered: usize,
        generation: u64,
    ) -> bool {
        let mut g = lock_recover_ranked(&self.inner, ranks::COORDINATOR_STATE);
        if g.0 != generation {
            return false;
        }
        let new_ix: Arc<dyn AnnIndex> = match g.1.as_ref() {
            Some(cur) if cur.len() != covered => {
                // Rows raced in since the snapshot; they live in the current
                // wrapper's delta tail. Anything else is drift — refuse.
                let Some(d) = cur.as_delta() else { return false };
                match d.rebase(index, covered) {
                    Ok(w) => Arc::new(w),
                    Err(_) => return false,
                }
            }
            _ => index,
        };
        g.0 += 1;
        g.1 = Some(new_ix);
        true
    }
}

/// Zero-padded fixed-shape copy of the serving vectors for the PJRT
/// `pairwise_topk` artifact (perf-pass Runtime-1: built once per serving
/// state instead of per batch).
#[derive(Debug, Clone)]
pub struct PaddedBase {
    /// Base block padded to `[n_cap, d_cap]`.
    pub base: crate::runtime::ArrayF32,
    /// Padding mask `[n_cap]` (1.0 on dead rows).
    pub mask: crate::runtime::ArrayF32,
    /// Live rows.
    pub n: usize,
    /// Live dims.
    pub dim: usize,
}

/// A named vector collection with optional OPDR-reduced serving copy.
#[derive(Debug)]
pub struct Collection {
    /// Collection name.
    pub name: String,
    /// Full-dimensional vectors.
    pub dim: usize,
    data: Vec<f32>,
    /// Serving metric.
    pub metric: Metric,
    /// OPDR-reduced serving state, if built.
    pub reduced: Option<ReducedState>,
    /// ANN index over the active serving vectors (substrate chosen by the
    /// configured [`IndexPolicy`]: exact / IVF-Flat / HNSW, optionally SQ8,
    /// optionally sharded), behind an atomic slot so background rebuilds
    /// swap in without blocking searches.
    index: Arc<IndexSlot>,
    /// Shared snapshot of the serving vectors for worker threads (perf-pass
    /// L3-2: avoids cloning the whole block every batch). Invalidated on
    /// ingest / build_reduced.
    serving_cache: Mutex<Option<Arc<Vec<f32>>>>,
    /// Shared snapshot of the *full-dimensional* vectors for the recall
    /// probe's ground-truth scan (same lifecycle as `serving_cache`).
    full_cache: Mutex<Option<Arc<Vec<f32>>>>,
    /// Cached padded block for the PJRT artifact path, keyed by (n_cap, d_cap).
    padded_cache: Mutex<Option<((usize, usize), Arc<PaddedBase>)>>,
}

/// The reduced-dimension serving copy plus the model that produced it.
#[derive(Debug)]
pub struct ReducedState {
    /// Fitted projection (also used for query-time projection).
    pub model: PcaModel,
    /// Reduced vectors, row-major `n × reduced_dim`.
    pub data: Vec<f32>,
    /// The planner fit used to choose the dimension.
    pub planner: Planner,
    /// Accuracy target requested.
    pub target_accuracy: f64,
}

impl Collection {
    /// New empty collection.
    pub fn new(name: impl Into<String>, dim: usize, metric: Metric) -> Result<Self> {
        if dim == 0 {
            return Err(OpdrError::shape("collection: dim must be > 0"));
        }
        Ok(Collection {
            name: name.into(),
            dim,
            data: Vec::new(),
            metric,
            reduced: None,
            index: Arc::new(IndexSlot::default()),
            serving_cache: Mutex::new(None),
            full_cache: Mutex::new(None),
            padded_cache: Mutex::new(None),
        })
    }

    /// Snapshot of the serving index, if one is installed.
    pub fn index(&self) -> Option<Arc<dyn AnnIndex>> {
        self.index.load()
    }

    /// Number of vectors.
    pub fn len(&self) -> usize {
        self.data.len() / self.dim
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Raw full-dimensional vectors.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Append vectors (row-major, multiple of `dim`). Invalidates any reduced
    /// copy / index (they must be rebuilt) — the legacy ingest path; the
    /// coordinator's incremental mode uses
    /// [`Collection::ingest_incremental`] instead so the serving index is
    /// never dropped. A zero-length ingest is a no-op: it returns `Ok(0)`
    /// without invalidating anything.
    pub fn ingest(&mut self, vectors: &[f32]) -> Result<usize> {
        if vectors.len() % self.dim != 0 {
            return Err(OpdrError::shape(format!(
                "ingest into `{}`: {} floats is not a multiple of dim {}",
                self.name,
                vectors.len(),
                self.dim
            )));
        }
        if vectors.is_empty() {
            return Ok(0);
        }
        self.data.extend_from_slice(vectors);
        self.reduced = None;
        self.index.invalidate();
        self.invalidate_caches();
        Ok(vectors.len() / self.dim)
    }

    /// Append vectors without dropping the serving state: new rows are
    /// projected through the existing reduction model (if one is fitted) and
    /// absorbed into the serving index's flat exact delta segment
    /// ([`crate::index::DeltaIndex`]), so searches keep using the index —
    /// no silent degradation to a brute-force scan between ingest and the
    /// next rebuild. When no index is installed this degrades to the legacy
    /// invalidation semantics (minus dropping the reduced copy, which stays
    /// valid — appended rows are projected through it). A zero-length
    /// ingest is a no-op returning `Ok(0)`.
    pub fn ingest_incremental(&mut self, vectors: &[f32]) -> Result<usize> {
        if vectors.len() % self.dim != 0 {
            return Err(OpdrError::shape(format!(
                "ingest into `{}`: {} floats is not a multiple of dim {}",
                self.name,
                vectors.len(),
                self.dim
            )));
        }
        if vectors.is_empty() {
            return Ok(0);
        }
        // Project into the serving space first so a projection error leaves
        // the collection untouched.
        let projected = match &self.reduced {
            Some(r) => Some(r.model.project(vectors)?),
            None => None,
        };
        self.data.extend_from_slice(vectors);
        match (projected, self.reduced.as_mut()) {
            (Some(p), Some(r)) => {
                r.data.extend_from_slice(&p);
                self.index.append_delta(&p);
            }
            _ => {
                self.index.append_delta(vectors);
            }
        }
        self.invalidate_caches();
        Ok(vectors.len() / self.dim)
    }

    /// Rows currently in the serving index's delta segment (0 when the
    /// index is bare or absent). The coordinator compares this against
    /// `[serve] delta_max_vectors` to schedule compactions.
    pub fn delta_len(&self) -> usize {
        self.index
            .load()
            .and_then(|ix| ix.as_delta().map(|d| d.delta_len()))
            .unwrap_or(0)
    }

    fn invalidate_caches(&self) {
        *lock_recover_ranked(&self.serving_cache, ranks::CACHE_SERVING) = None;
        *lock_recover_ranked(&self.full_cache, ranks::CACHE_FULL) = None;
        *lock_recover_ranked(&self.padded_cache, ranks::CACHE_PADDED) = None;
    }

    /// Shared snapshot of the serving vectors (built lazily, invalidated on
    /// state changes). Worker threads score against this without copying.
    pub fn serving_arc(&self) -> Arc<Vec<f32>> {
        let mut guard = lock_recover_ranked(&self.serving_cache, ranks::CACHE_SERVING);
        if let Some(arc) = guard.as_ref() {
            return Arc::clone(arc);
        }
        let (vecs, _) = self.serving_vectors();
        let arc = Arc::new(vecs.to_vec());
        *guard = Some(Arc::clone(&arc));
        arc
    }

    /// Shared snapshot of the full-dimensional vectors (lazily built like
    /// [`Collection::serving_arc`]). The recall probe scans this off-thread
    /// for the exact full-space neighbor sets.
    pub fn full_arc(&self) -> Arc<Vec<f32>> {
        let mut guard = lock_recover_ranked(&self.full_cache, ranks::CACHE_FULL);
        if let Some(arc) = guard.as_ref() {
            return Arc::clone(arc);
        }
        let arc = Arc::new(self.data.clone());
        *guard = Some(Arc::clone(&arc));
        arc
    }

    /// Cached zero-padded serving block for the PJRT artifact path.
    pub fn padded_base(&self, n_cap: usize, d_cap: usize) -> Result<Arc<PaddedBase>> {
        let mut guard = lock_recover_ranked(&self.padded_cache, ranks::CACHE_PADDED);
        if let Some((key, arc)) = guard.as_ref() {
            if *key == (n_cap, d_cap) {
                return Ok(Arc::clone(arc));
            }
        }
        let (vecs, dim) = self.serving_vectors();
        let n = vecs.len() / dim.max(1);
        if n > n_cap || dim > d_cap {
            return Err(OpdrError::runtime("collection exceeds artifact capacity"));
        }
        let base = crate::runtime::ArrayF32::padded_2d(vecs, n, dim, n_cap, d_cap)?;
        let mut mask = vec![0.0f32; n_cap];
        for m in mask.iter_mut().skip(n) {
            *m = 1.0;
        }
        let mask = crate::runtime::ArrayF32::new(mask, vec![n_cap])?;
        let arc = Arc::new(PaddedBase { base, mask, n, dim });
        *guard = Some(((n_cap, d_cap), Arc::clone(&arc)));
        Ok(arc)
    }

    /// Build the OPDR-reduced serving copy: calibrate the planner on (a
    /// sample of) this collection, choose `dim(Y)` for `target_accuracy`,
    /// fit PCA at that dimension and project everything.
    pub fn build_reduced(
        &mut self,
        target_accuracy: f64,
        k: usize,
        calibration_sample: usize,
        seed: u64,
    ) -> Result<&ReducedState> {
        let n = self.len();
        if n < k + 2 {
            return Err(OpdrError::data(format!(
                "collection `{}` has {n} vectors; need > k+1 = {}",
                self.name,
                k + 1
            )));
        }
        // Calibrate on a subsample to bound the sweep cost.
        let sample_n = calibration_sample.clamp(k + 2, n);
        let mut rng = crate::util::Rng::new(seed);
        let idx = rng.sample_indices(n, sample_n);
        let mut sample = Vec::with_capacity(sample_n * self.dim);
        for &i in &idx {
            sample.extend_from_slice(&self.data[i * self.dim..(i + 1) * self.dim]);
        }
        let planner =
            Planner::calibrate(&sample, self.dim, k, self.metric, ReducerKind::Pca, seed)?;
        let target_dim = planner.dim_for_accuracy(target_accuracy, sample_n).min(self.dim);

        let model = Pca::new().fit(&sample, self.dim, target_dim)?;
        let data = model.project(&self.data)?;
        self.reduced = Some(ReducedState { model, data, planner, target_accuracy });
        self.index.invalidate();
        self.invalidate_caches();
        Ok(self.reduced.as_ref().unwrap())
    }

    /// Build (or rebuild) the ANN index over the active serving vectors,
    /// with the substrate chosen by `policy` (exact below its threshold,
    /// then IVF/HNSW, optionally SQ8-quantized, sharded when
    /// `policy.shards > 1`). Blocks the caller; the coordinator's scheduler
    /// uses [`Collection::spawn_index_build`] instead so serving never
    /// waits on a rebuild.
    pub fn build_index(&mut self, policy: &IndexPolicy, seed: u64) -> Result<()> {
        let (vecs, dim) = self.serving_vectors();
        if vecs.is_empty() {
            return Err(OpdrError::data("build_index: empty collection"));
        }
        let index = crate::index::build_index(vecs, dim, self.metric, policy, seed)?;
        self.index.replace(Arc::from(index));
        Ok(())
    }

    /// Rebuild the index off-thread: snapshot the serving vectors, fan
    /// whole-segment builds out to `pool`
    /// ([`crate::index::shard::build_on_pool`]) and atomically swap the
    /// result in when done — searches keep serving the old index (or the
    /// exact scan) throughout. This is also the compaction path: the
    /// snapshot includes any delta rows, and the swap goes through
    /// [`IndexSlot::install_rebased`], so rows ingested incrementally
    /// *while* the build runs are re-parented onto the new index's delta
    /// instead of being lost. `on_done` runs on the collector thread with
    /// `Ok(true)` when the index was installed, `Ok(false)` when the
    /// collection changed wholesale while building (the stale index is
    /// discarded, never installed — serving falls back to the exact scan),
    /// and `Err` when the build itself failed.
    pub fn spawn_index_build(
        &self,
        policy: &IndexPolicy,
        seed: u64,
        pool: &ThreadPool,
        on_done: impl FnOnce(Result<bool>) + Send + 'static,
    ) {
        self.spawn_index_build_traced(policy, seed, pool, None, on_done)
    }

    /// [`Collection::spawn_index_build`] with optional write-path spans: the
    /// whole background build (snapshot → segment fan-out → collect) feeds
    /// `spans.build`, the atomic install feeds `spans.swap`.
    pub fn spawn_index_build_traced(
        &self,
        policy: &IndexPolicy,
        seed: u64,
        pool: &ThreadPool,
        spans: Option<BuildSpans>,
        on_done: impl FnOnce(Result<bool>) + Send + 'static,
    ) {
        let data = self.serving_arc();
        let (_, dim) = self.serving_vectors();
        let covered = data.len() / dim.max(1);
        let metric = self.metric;
        let slot = Arc::clone(&self.index);
        let generation = slot.generation();
        let build_sw = Stopwatch::start();
        crate::index::shard::build_on_pool(data, dim, metric, policy, seed, pool, move |res| {
            if let Some(s) = &spans {
                s.build.record(build_sw.elapsed());
            }
            match res {
                Ok(index) => {
                    let swap_sw = Stopwatch::start();
                    let installed = slot.install_rebased(Arc::from(index), covered, generation);
                    if let Some(s) = &spans {
                        s.swap.record(swap_sw.elapsed());
                    }
                    on_done(Ok(installed))
                }
                Err(e) => on_done(Err(e)),
            }
        });
    }

    /// Persist the built index as an `OPDR` index segment.
    pub fn save_index(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        self.save_index_as(path, false)
    }

    /// Persist the built index, choosing the layout: `cold = true` writes
    /// the version-5 cold format (full-precision rows in a
    /// 64-byte-aligned annex, loadable zero-copy via mmap), `false` the
    /// inline version-2/3/4 formats.
    pub fn save_index_as(&self, path: impl AsRef<std::path::Path>, cold: bool) -> Result<()> {
        let index = self.index().ok_or_else(|| {
            OpdrError::coordinator(format!("collection `{}` has no index to save", self.name))
        })?;
        if cold {
            crate::data::store::save_index_cold(index.as_ref(), path)
        } else {
            crate::data::store::save_index(index.as_ref(), path)
        }
    }

    /// Load a previously saved index segment, validating it against the
    /// current serving vectors (same count and dimensionality — an index
    /// built for different data must never silently serve it).
    pub fn load_index(&mut self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let index = crate::data::store::load_index(path)?;
        let (vecs, dim) = self.serving_vectors();
        let n = vecs.len() / dim.max(1);
        if index.dim() != dim || index.len() != n {
            return Err(OpdrError::coordinator(format!(
                "collection `{}`: loaded index is {}x{} but serving state is {}x{}",
                self.name,
                index.len(),
                index.dim(),
                n,
                dim
            )));
        }
        if index.metric() != self.metric {
            return Err(OpdrError::coordinator(format!(
                "collection `{}`: loaded index metric {} != collection metric {}",
                self.name,
                index.metric().name(),
                self.metric.name()
            )));
        }
        if !index.matches_data(vecs) {
            return Err(OpdrError::coordinator(format!(
                "collection `{}`: loaded index was built from different vectors \
                 than the current serving state",
                self.name
            )));
        }
        self.index.replace(Arc::from(index));
        Ok(())
    }

    /// The vectors queries are scored against: reduced copy if built, else
    /// the full-dimensional data.
    pub fn serving_vectors(&self) -> (&[f32], usize) {
        match &self.reduced {
            Some(r) => (&r.data, r.model.target_dim()),
            None => (&self.data, self.dim),
        }
    }

    /// Project a full-dimensional query into the serving space.
    pub fn project_query(&self, query: &[f32]) -> Result<Vec<f32>> {
        if query.len() != self.dim {
            return Err(OpdrError::shape(format!(
                "query dim {} != collection dim {}",
                query.len(),
                self.dim
            )));
        }
        match &self.reduced {
            Some(r) => r.model.project(query),
            None => Ok(query.to_vec()),
        }
    }

    /// Exact (or index-approximate, if indexed) k-NN search for a single
    /// *already-projected* query. Probe widths / beam sizes are baked into
    /// the index at build time by the [`IndexPolicy`].
    pub fn search_projected(&self, query: &[f32], k: usize) -> Result<Vec<Neighbor>> {
        self.search_projected_with(query, k, None)
    }

    /// [`Collection::search_projected`] with an optional worker pool: a
    /// multi-shard index fans the query out across its segments on the pool
    /// (byte-identical results to the serial path — the merge is
    /// order-exact). Must not be called from a pool worker.
    pub fn search_projected_with(
        &self,
        query: &[f32],
        k: usize,
        pool: Option<&ThreadPool>,
    ) -> Result<Vec<Neighbor>> {
        let (vecs, dim) = self.serving_vectors();
        if query.len() != dim {
            return Err(OpdrError::shape("search: projected query dim mismatch"));
        }
        if let Some(index) = self.index() {
            if let Some(pool) = pool {
                if let Some(delta) = index.as_delta() {
                    // The wrapper fans its (possibly sharded) main out on
                    // the pool and scans the bounded delta inline.
                    return delta.search_on(pool, query, k);
                }
                if let Some(sharded) = index.as_sharded() {
                    if sharded.num_shards() > 1 {
                        return sharded.search_on(pool, query, k);
                    }
                }
            }
            index.search(query, k)
        } else {
            crate::knn::knn_indices(query, vecs, dim, k, self.metric)
        }
    }
}

/// All collections, keyed by name.
#[derive(Debug, Default)]
pub struct Collections {
    map: HashMap<String, Collection>,
}

impl Collections {
    /// Empty registry.
    pub fn new() -> Self {
        Collections::default()
    }

    /// Create a collection; errors if the name exists.
    pub fn create(&mut self, name: &str, dim: usize, metric: Metric) -> Result<()> {
        if self.map.contains_key(name) {
            return Err(OpdrError::coordinator(format!("collection `{name}` already exists")));
        }
        self.map.insert(name.to_string(), Collection::new(name, dim, metric)?);
        Ok(())
    }

    /// Borrow a collection.
    pub fn get(&self, name: &str) -> Result<&Collection> {
        self.map
            .get(name)
            .ok_or_else(|| OpdrError::coordinator(format!("no collection `{name}`")))
    }

    /// Borrow mutably.
    pub fn get_mut(&mut self, name: &str) -> Result<&mut Collection> {
        self.map
            .get_mut(name)
            .ok_or_else(|| OpdrError::coordinator(format!("no collection `{name}`")))
    }

    /// Names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.map.keys().cloned().collect();
        v.sort();
        v
    }

    /// Load a generated [`EmbeddingSet`] as a new collection.
    pub fn create_from_set(&mut self, name: &str, set: &EmbeddingSet, metric: Metric) -> Result<()> {
        self.create(name, set.dim(), metric)?;
        self.get_mut(name)?.ingest(set.data())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth, DatasetKind};

    fn seeded_collection(n: usize, dim: usize) -> Collection {
        let set = synth::generate(DatasetKind::MaterialsObservable, n, dim, 5);
        let mut c = Collection::new("test", dim, Metric::SqEuclidean).unwrap();
        c.ingest(set.data()).unwrap();
        c
    }

    #[test]
    fn ingest_and_len() {
        let mut c = Collection::new("c", 4, Metric::Euclidean).unwrap();
        assert_eq!(c.ingest(&[0.0; 12]).unwrap(), 3);
        assert_eq!(c.len(), 3);
        assert!(c.ingest(&[0.0; 5]).is_err());
    }

    #[test]
    fn build_reduced_and_search() {
        let mut c = seeded_collection(60, 64);
        let r = c.build_reduced(0.8, 5, 50, 1).unwrap();
        let rdim = r.model.target_dim();
        assert!(rdim >= 1 && rdim <= 64);
        let (vecs, dim) = c.serving_vectors();
        assert_eq!(dim, rdim);
        assert_eq!(vecs.len(), 60 * rdim);

        // Search with a projected query: the top hit for a stored vector's own
        // full-dim form should be itself.
        let q_full: Vec<f32> = c.data()[..64].to_vec();
        let q = c.project_query(&q_full).unwrap();
        let hits = c.search_projected(&q, 3).unwrap();
        assert_eq!(hits[0].index, 0);
    }

    #[test]
    fn reduced_search_recall_vs_full() {
        let mut c = seeded_collection(80, 64);
        // Ground truth in full space.
        let q: Vec<f32> = c.data()[5 * 64..6 * 64].to_vec();
        let full = crate::knn::knn_indices(&q, c.data(), 64, 10, Metric::SqEuclidean).unwrap();
        c.build_reduced(0.9, 10, 60, 2).unwrap();
        let qp = c.project_query(&q).unwrap();
        let red = c.search_projected(&qp, 10).unwrap();
        let full_set: std::collections::HashSet<usize> = full.iter().map(|n| n.index).collect();
        let hits = red.iter().filter(|n| full_set.contains(&n.index)).count();
        assert!(hits >= 5, "recall too low: {hits}/10");
    }

    #[test]
    fn ingest_invalidates_reduced() {
        let mut c = seeded_collection(40, 32);
        c.build_reduced(0.8, 5, 30, 1).unwrap();
        assert!(c.reduced.is_some());
        c.ingest(&vec![0.0; 32]).unwrap();
        assert!(c.reduced.is_none());
    }

    #[test]
    fn index_path_used_when_built() {
        let mut c = seeded_collection(100, 16);
        let policy = IndexPolicy {
            exact_threshold: 10,
            ivf_nlist: 8,
            ivf_nprobe: 8,
            ..Default::default()
        };
        c.build_index(&policy, 3).unwrap();
        assert!(c.index().is_some());
        assert_eq!(c.index().unwrap().kind(), crate::index::IndexKind::Ivf);
        let q: Vec<f32> = c.data()[..16].to_vec();
        let hits = c.search_projected(&q, 5).unwrap();
        assert_eq!(hits[0].index, 0);
    }

    #[test]
    fn policy_selects_exact_below_threshold_and_hnsw_above() {
        let mut c = seeded_collection(80, 16);
        let policy = IndexPolicy {
            kind: crate::index::IndexKind::Hnsw,
            exact_threshold: 1000,
            ..Default::default()
        };
        c.build_index(&policy, 1).unwrap();
        assert_eq!(c.index().unwrap().kind(), crate::index::IndexKind::Exact);

        let policy = IndexPolicy { exact_threshold: 10, ..policy };
        c.build_index(&policy, 1).unwrap();
        let idx = c.index().unwrap();
        assert_eq!(idx.kind(), crate::index::IndexKind::Hnsw);
        let q: Vec<f32> = c.data()[3 * 16..4 * 16].to_vec();
        let hits = c.search_projected(&q, 5).unwrap();
        assert_eq!(hits[0].index, 3);
    }

    #[test]
    fn index_save_load_roundtrip_with_validation() {
        let dir = std::env::temp_dir().join(format!("opdr_state_idx_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.opdx");

        let mut c = seeded_collection(120, 16);
        let policy = IndexPolicy {
            kind: crate::index::IndexKind::Hnsw,
            exact_threshold: 10,
            sq8: true,
            ..Default::default()
        };
        c.build_index(&policy, 7).unwrap();
        let q: Vec<f32> = c.data()[5 * 16..6 * 16].to_vec();
        let before = c.search_projected(&q, 6).unwrap();
        c.save_index(&path).unwrap();

        // Fresh collection over the same data loads and serves identically.
        let mut c2 = seeded_collection(120, 16);
        c2.load_index(&path).unwrap();
        let after = c2.search_projected(&q, 6).unwrap();
        assert_eq!(before.len(), after.len());
        for (a, b) in before.iter().zip(&after) {
            assert_eq!(a.index, b.index);
            assert_eq!(a.distance.to_bits(), b.distance.to_bits());
        }

        // A mismatched collection refuses the segment.
        let mut c3 = seeded_collection(60, 16);
        let e = c3.load_index(&path).unwrap_err().to_string();
        assert!(e.contains("serving state"), "{e}");

        // Same shape but different data must also be refused.
        let set = synth::generate(DatasetKind::MaterialsObservable, 120, 16, 999);
        let mut c4 = Collection::new("other-data", 16, Metric::SqEuclidean).unwrap();
        c4.ingest(set.data()).unwrap();
        let e = c4.load_index(&path).unwrap_err().to_string();
        assert!(e.contains("different vectors"), "{e}");

        // No index → save errors.
        assert!(c3.save_index(dir.join("none.opdx")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ingest_invalidates_index() {
        let mut c = seeded_collection(50, 8);
        let policy = IndexPolicy { exact_threshold: 0, ..Default::default() };
        c.build_index(&policy, 1).unwrap();
        assert!(c.index().is_some());
        c.ingest(&vec![0.0; 8]).unwrap();
        assert!(c.index().is_none());
    }

    #[test]
    fn index_slot_generation_guard_drops_stale_installs() {
        let slot = IndexSlot::default();
        let data = vec![0.0f32; 8 * 4];
        let idx: Arc<dyn AnnIndex> = Arc::from(
            crate::index::build_index(
                &data,
                4,
                Metric::Euclidean,
                &IndexPolicy { kind: crate::index::IndexKind::Exact, ..Default::default() },
                1,
            )
            .unwrap(),
        );
        let gen0 = slot.generation();
        assert!(slot.install_rebased(Arc::clone(&idx), 8, gen0));
        assert!(slot.load().is_some());
        // Invalidate (as a legacy ingest does), then try to install with the
        // stale generation: the install must be refused and the slot stay
        // empty.
        slot.invalidate();
        assert!(slot.load().is_none());
        assert!(!slot.install_rebased(Arc::clone(&idx), 8, gen0));
        assert!(slot.load().is_none());
        // A fresh generation installs fine.
        assert!(slot.install_rebased(Arc::clone(&idx), 8, slot.generation()));
        assert!(slot.load().is_some());
        // `replace` (sync build / load paths) bumps the generation, so a
        // background build that snapshotted before it can't stomp the
        // explicitly installed index.
        let pre_replace = slot.generation();
        slot.replace(Arc::clone(&idx));
        assert!(!slot.install_rebased(idx, 8, pre_replace));
        assert!(slot.load().is_some());
    }

    #[test]
    fn spawn_index_build_installs_off_thread() {
        let c = seeded_collection(80, 8);
        let pool = ThreadPool::new(2);
        let policy = IndexPolicy {
            kind: crate::index::IndexKind::Exact,
            exact_threshold: 0,
            shards: 4,
            shard_min_vectors: 1,
            ..Default::default()
        };
        let (tx, rx) = std::sync::mpsc::channel();
        c.spawn_index_build(&policy, 3, &pool, move |r| {
            let _ = tx.send(r);
        });
        assert!(rx.recv().unwrap().unwrap(), "install reported refused");
        let idx = c.index().expect("index installed");
        assert_eq!(idx.as_sharded().unwrap().num_shards(), 4);
        // Sharded search through the collection equals an unsharded exact
        // scan (same distance kernel; the matmul-form brute path rounds
        // differently, so it is only id-equal, not bit-equal).
        let q: Vec<f32> = c.data()[5 * 8..6 * 8].to_vec();
        let exact = crate::index::ExactIndex::build(
            c.data(),
            8,
            Metric::SqEuclidean,
            &crate::index::StorageSpec::flat(),
            1,
        )
        .unwrap();
        let want = exact.search(&q, 6).unwrap();
        for use_pool in [None, Some(&pool)] {
            let got = c.search_projected_with(&q, 6, use_pool).unwrap();
            crate::testing::assert_same_neighbors(&got, &want);
        }
    }

    #[test]
    fn spawn_index_build_reports_errors_and_skips_stale_installs() {
        // Empty collection: the build fails through `on_done`.
        let c = Collection::new("empty", 4, Metric::Euclidean).unwrap();
        let pool = ThreadPool::new(1);
        let (tx, rx) = std::sync::mpsc::channel();
        c.spawn_index_build(&IndexPolicy::default(), 1, &pool, move |r| {
            let _ = tx.send(r);
        });
        assert!(rx.recv().unwrap().is_err());

        // Ingest-after-snapshot: force the race deterministically by bumping
        // the generation before the collector can install.
        let mut c = seeded_collection(40, 8);
        let (tx, rx) = std::sync::mpsc::channel();
        {
            // Hold the pool hostage so the build can't finish yet.
            let (block_tx, block_rx) = std::sync::mpsc::channel::<()>();
            pool.execute(move || {
                let _ = block_rx.recv();
            });
            c.spawn_index_build(
                &IndexPolicy { exact_threshold: 0, ..Default::default() },
                1,
                &pool,
                move |r| {
                    let _ = tx.send(r);
                },
            );
            c.ingest(&vec![0.0; 8]).unwrap(); // bumps the generation
            block_tx.send(()).unwrap(); // release the pool
        }
        let res = rx.recv().unwrap();
        assert!(!res.unwrap(), "stale install must be refused");
        assert!(c.index().is_none(), "stale index must not be installed");
    }

    #[test]
    fn zero_length_ingest_is_a_noop() {
        // Satellite regression: an empty ingest used to invalidate the
        // index, the reduced copy and both serving caches for a no-op
        // write. It must return Ok(0) and change nothing — in particular
        // the index generation, so in-flight builds are not spuriously
        // refused.
        let mut c = seeded_collection(60, 16);
        c.build_reduced(0.8, 5, 40, 1).unwrap();
        let policy = IndexPolicy { exact_threshold: 0, ..Default::default() };
        c.build_index(&policy, 1).unwrap();
        let gen_before = c.index.generation();
        assert_eq!(c.ingest(&[]).unwrap(), 0);
        assert_eq!(c.ingest_incremental(&[]).unwrap(), 0);
        assert_eq!(c.index.generation(), gen_before, "generation must be unchanged");
        assert!(c.index().is_some(), "index must survive a zero-length ingest");
        assert!(c.reduced.is_some(), "reduced copy must survive a zero-length ingest");
        assert_eq!(c.len(), 60);
        // Ragged input still errors.
        assert!(c.ingest(&[0.0; 3]).is_err());
        assert!(c.ingest_incremental(&[0.0; 3]).is_err());
    }

    #[test]
    fn ingest_incremental_extends_delta_and_serves_exactly() {
        let dim = 8;
        let mut c = seeded_collection(50, dim);
        let policy = IndexPolicy {
            kind: crate::index::IndexKind::Exact,
            exact_threshold: 0,
            ..Default::default()
        };
        c.build_index(&policy, 1).unwrap();
        assert_eq!(c.delta_len(), 0);

        let extra = synth::generate(DatasetKind::Flickr30k, 15, dim, 99);
        assert_eq!(c.ingest_incremental(&extra.data()[..10 * dim]).unwrap(), 10);
        let ix = c.index().expect("index survives incremental ingest");
        assert_eq!(ix.len(), 60);
        assert_eq!(c.delta_len(), 10);
        // A second ingest extends the same wrapper's delta.
        assert_eq!(c.ingest_incremental(&extra.data()[10 * dim..]).unwrap(), 5);
        assert_eq!(c.delta_len(), 15);
        assert_eq!(c.len(), 65);

        // Searches over index+delta are bitwise the flat exact scan over
        // the concatenated rows.
        let flat = crate::index::ExactIndex::build(
            c.data(),
            dim,
            Metric::SqEuclidean,
            &crate::index::StorageSpec::flat(),
            1,
        )
        .unwrap();
        let pool = ThreadPool::new(2);
        for qi in [0usize, 49, 55, 64] {
            let q: Vec<f32> = c.data()[qi * dim..(qi + 1) * dim].to_vec();
            let want = flat.search(&q, 7).unwrap();
            assert_eq!(want[0].index, qi, "self-hit");
            for use_pool in [None, Some(&pool)] {
                let got = c.search_projected_with(&q, 7, use_pool).unwrap();
                crate::testing::assert_same_neighbors(&want, &got);
            }
        }
    }

    #[test]
    fn ingest_incremental_projects_through_reduced_model() {
        let dim = 32;
        let mut c = seeded_collection(60, dim);
        c.build_reduced(0.8, 5, 40, 1).unwrap();
        let rdim = c.reduced.as_ref().unwrap().model.target_dim();
        let policy = IndexPolicy {
            kind: crate::index::IndexKind::Exact,
            exact_threshold: 0,
            ..Default::default()
        };
        c.build_index(&policy, 1).unwrap();

        let extra = synth::generate(DatasetKind::MaterialsObservable, 8, dim, 321);
        assert_eq!(c.ingest_incremental(extra.data()).unwrap(), 8);
        // Reduced copy stays fitted and grows with the projected rows.
        let r = c.reduced.as_ref().expect("reduced copy survives");
        assert_eq!(r.data.len(), 68 * rdim);
        let (vecs, sdim) = c.serving_vectors();
        assert_eq!(sdim, rdim);
        assert_eq!(vecs.len() / rdim, 68);
        assert_eq!(c.delta_len(), 8);
        // An appended row's own projection finds it first.
        let q = c.project_query(&extra.data()[..dim]).unwrap();
        let hits = c.search_projected(&q, 3).unwrap();
        assert_eq!(hits[0].index, 60);
    }

    #[test]
    fn ingest_incremental_without_index_invalidates_generation() {
        let mut c = seeded_collection(30, 8);
        assert!(c.index().is_none());
        let gen_before = c.index.generation();
        assert_eq!(c.ingest_incremental(&vec![0.0; 8]).unwrap(), 1);
        assert!(c.index().is_none());
        assert!(
            c.index.generation() > gen_before,
            "no delta to absorb the rows: in-flight builds must be invalidated"
        );
    }

    #[test]
    fn index_slot_append_delta_and_rebased_install() {
        let dim = 4;
        let data = crate::util::Rng::new(9).normal_vec_f32(20 * dim);
        let build = |rows: &[f32]| -> Arc<dyn AnnIndex> {
            Arc::from(
                crate::index::build_index(
                    rows,
                    dim,
                    Metric::SqEuclidean,
                    &IndexPolicy {
                        kind: crate::index::IndexKind::Exact,
                        exact_threshold: 0,
                        ..Default::default()
                    },
                    1,
                )
                .unwrap(),
            )
        };
        let slot = IndexSlot::default();
        // Appending with no index installed bumps the generation instead.
        let g0 = slot.generation();
        assert!(!slot.append_delta(&data[..dim]));
        assert!(slot.generation() > g0);

        slot.replace(build(&data[..12 * dim]));
        let gen = slot.generation();
        // Delta appends do not bump the generation.
        assert!(slot.append_delta(&data[12 * dim..16 * dim]));
        assert_eq!(slot.generation(), gen);
        let ix = slot.load().unwrap();
        assert_eq!(ix.as_delta().unwrap().delta_len(), 4);

        // A compaction that snapshotted 14 rows (12 main + 2 delta) installs
        // with the 2 uncovered rows re-parented as the new delta.
        assert!(slot.install_rebased(build(&data[..14 * dim]), 14, gen));
        let ix = slot.load().unwrap();
        let d = ix.as_delta().unwrap();
        assert_eq!(d.main_len(), 14);
        assert_eq!(d.delta_len(), 2);
        assert_eq!(d.delta_rows(), &data[14 * dim..16 * dim]);
        // The install bumped the generation: a second build from the same
        // snapshot is refused.
        assert!(!slot.install_rebased(build(&data[..14 * dim]), 14, gen));
        // A compaction covering everything installs bare.
        let gen2 = slot.generation();
        assert!(slot.install_rebased(build(&data[..16 * dim]), 16, gen2));
        assert!(slot.load().unwrap().as_delta().is_none());
        // Covered count that is not explainable by delta appends is refused
        // (the tail would not be a delta suffix).
        let gen3 = slot.generation();
        assert!(slot.append_delta(&data[16 * dim..20 * dim]));
        assert!(!slot.install_rebased(build(&data[..15 * dim]), 15, gen3));
    }

    #[test]
    fn compaction_rebase_lands_racing_ingest_in_new_delta() {
        // Acceptance: an ingest racing a compaction must land in the *new*
        // delta — never lost, never doubly indexed. Forced deterministically
        // by holding the build pool hostage while the racing ingest lands.
        let dim = 8;
        let mut c = seeded_collection(40, dim);
        let policy = IndexPolicy {
            kind: crate::index::IndexKind::Exact,
            exact_threshold: 0,
            ..Default::default()
        };
        c.build_index(&policy, 1).unwrap();
        let extra = synth::generate(DatasetKind::OmniCorpus, 11, dim, 555);
        let (a, b) = extra.data().split_at(6 * dim);
        c.ingest_incremental(a).unwrap();
        assert_eq!(c.delta_len(), 6);

        let pool = ThreadPool::new(1);
        let (block_tx, block_rx) = std::sync::mpsc::channel::<()>();
        pool.execute(move || {
            let _ = block_rx.recv();
        });
        // "Compaction": a background rebuild snapshotting 46 rows.
        let (tx, rx) = std::sync::mpsc::channel();
        c.spawn_index_build(&policy, 1, &pool, move |r| {
            let _ = tx.send(r);
        });
        // Racing ingest while the build is queued behind the hostage job.
        c.ingest_incremental(b).unwrap();
        assert_eq!(c.delta_len(), 11);
        block_tx.send(()).unwrap();

        assert!(rx.recv().unwrap().unwrap(), "rebased install must succeed");
        let ix = c.index().expect("compacted index installed");
        let d = ix.as_delta().expect("racing rows live in the new delta");
        assert_eq!(d.main_len(), 46, "compaction covered base + first delta");
        assert_eq!(d.delta_len(), 5, "exactly the racing rows remain");
        assert_eq!(ix.len(), 51);
        assert_eq!(d.delta_rows(), b);

        // No row lost, none doubly indexed: bitwise equal to a fresh flat
        // exact index over the full serving data, and every row self-hits.
        let flat = crate::index::ExactIndex::build(
            c.data(),
            dim,
            Metric::SqEuclidean,
            &crate::index::StorageSpec::flat(),
            1,
        )
        .unwrap();
        for qi in [0usize, 39, 40, 45, 46, 50] {
            let q: Vec<f32> = c.data()[qi * dim..(qi + 1) * dim].to_vec();
            let want = flat.search(&q, 8).unwrap();
            assert_eq!(want[0].index, qi);
            let got = c.search_projected(&q, 8).unwrap();
            crate::testing::assert_same_neighbors(&want, &got);
        }
    }

    #[test]
    fn searches_keep_serving_index_plus_delta_during_inflight_compaction() {
        // Search during an in-flight compaction: the old wrapper keeps
        // serving (order-exact) until the swap lands.
        let dim = 8;
        let mut c = seeded_collection(40, dim);
        let policy = IndexPolicy {
            kind: crate::index::IndexKind::Exact,
            exact_threshold: 0,
            ..Default::default()
        };
        c.build_index(&policy, 1).unwrap();
        let extra = synth::generate(DatasetKind::Esc50, 6, dim, 777);
        c.ingest_incremental(extra.data()).unwrap();

        let pool = ThreadPool::new(1);
        let (block_tx, block_rx) = std::sync::mpsc::channel::<()>();
        pool.execute(move || {
            let _ = block_rx.recv();
        });
        let (tx, rx) = std::sync::mpsc::channel();
        c.spawn_index_build(&policy, 1, &pool, move |r| {
            let _ = tx.send(r);
        });
        // While the compaction is queued, searches serve index + delta.
        let flat = crate::index::ExactIndex::build(
            c.data(),
            dim,
            Metric::SqEuclidean,
            &crate::index::StorageSpec::flat(),
            1,
        )
        .unwrap();
        assert_eq!(c.delta_len(), 6, "delta still serving during the compaction");
        for qi in [0usize, 41, 45] {
            let q: Vec<f32> = c.data()[qi * dim..(qi + 1) * dim].to_vec();
            let want = flat.search(&q, 5).unwrap();
            let got = c.search_projected(&q, 5).unwrap();
            crate::testing::assert_same_neighbors(&want, &got);
        }
        block_tx.send(()).unwrap();
        assert!(rx.recv().unwrap().unwrap());
        // Swap landed: delta folded in, results unchanged.
        assert_eq!(c.delta_len(), 0);
        assert!(c.index().unwrap().as_delta().is_none());
        for qi in [0usize, 41, 45] {
            let q: Vec<f32> = c.data()[qi * dim..(qi + 1) * dim].to_vec();
            let want = flat.search(&q, 5).unwrap();
            let got = c.search_projected(&q, 5).unwrap();
            crate::testing::assert_same_neighbors(&want, &got);
        }
    }

    #[test]
    fn registry_create_get_duplicate() {
        let mut cs = Collections::new();
        cs.create("a", 8, Metric::Euclidean).unwrap();
        assert!(cs.create("a", 8, Metric::Euclidean).is_err());
        assert!(cs.get("a").is_ok());
        assert!(cs.get("b").is_err());
        assert_eq!(cs.names(), vec!["a".to_string()]);
    }

    #[test]
    fn too_few_vectors_for_reduce() {
        let mut c = Collection::new("tiny", 8, Metric::Euclidean).unwrap();
        c.ingest(&[0.0; 16]).unwrap(); // 2 vectors
        assert!(c.build_reduced(0.8, 5, 10, 1).is_err());
    }

    /// Poison `m` the way a real incident would: a thread panics while
    /// holding the guard.
    fn poison<T: Send>(m: &Mutex<T>) {
        std::thread::scope(|s| {
            let r = s
                .spawn(|| {
                    // lint:allow(no-naked-lock-unwrap: deliberately poisoning the lock)
                    let _g = m.lock().unwrap();
                    panic!("poison");
                })
                .join();
            assert!(r.is_err(), "the poisoning thread must have panicked");
        });
        assert!(m.is_poisoned());
    }

    #[test]
    fn poisoned_serving_cache_keeps_serving() {
        // Regression (PR 4 only covered telemetry): a panic while holding a
        // collection cache lock must not turn every later search on other
        // threads into a poison panic. The caches hold idempotently
        // rebuildable snapshots, so recovery is always sound.
        let mut c = seeded_collection(50, 16);
        let before = c.search_projected(&c.data()[..16].to_vec(), 5).unwrap();
        poison(&c.serving_cache);
        poison(&c.full_cache);
        poison(&c.padded_cache);

        // Cache reads, rebuilds, and invalidation all keep working …
        let arc = c.serving_arc();
        assert_eq!(arc.len(), 50 * 16);
        assert_eq!(c.full_arc().len(), 50 * 16);
        let after = c.search_projected(&c.data()[..16].to_vec(), 5).unwrap();
        crate::testing::assert_same_neighbors(&before, &after);

        // … including the invalidate-on-ingest path across the same locks.
        c.ingest_incremental(&vec![0.25; 16]).unwrap();
        assert_eq!(c.serving_arc().len(), 51 * 16);
    }

    #[test]
    fn poisoned_index_slot_keeps_swapping() {
        let slot = IndexSlot::default();
        let set = synth::generate(DatasetKind::MaterialsObservable, 30, 8, 3);
        let ix: Arc<dyn AnnIndex> = Arc::from(
            crate::index::build_index(set.data(), 8, Metric::SqEuclidean, &IndexPolicy::default(), 7)
                .unwrap(),
        );
        slot.replace(Arc::clone(&ix));
        let gen_before = slot.generation();
        poison(&slot.inner);

        // Every slot operation still works on the poisoned mutex: loads,
        // generation reads, delta appends, and the rebase-guarded install.
        assert!(slot.load().is_some());
        assert_eq!(slot.generation(), gen_before);
        assert!(slot.append_delta(&[0.5; 8]));
        assert!(slot.load().unwrap().as_delta().is_some());
        // The compaction snapshotted 30 rows; the raced-in append survives
        // the install as the re-parented delta — poison changed nothing.
        assert!(slot.install_rebased(ix, 30, gen_before));
        let installed = slot.load().unwrap();
        assert_eq!(installed.len(), 31);
        assert!(installed.as_delta().is_some());
    }
}
