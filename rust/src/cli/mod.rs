//! Hand-rolled CLI argument parser (no `clap` in the offline registry).
//!
//! Grammar: `opdr <subcommand> [--flag] [--key value] [positional...]`.
//! Flags may be given as `--key value` or `--key=value`. Unknown flags are
//! errors so typos fail loudly.

use crate::error::{OpdrError, Result};
use std::collections::BTreeMap;

/// Parsed command line: subcommand, flags, positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token.
    pub subcommand: String,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
    positionals: Vec<String>,
    known_flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Self> {
        let mut args = Args::default();
        let mut iter = tokens.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if stripped.is_empty() {
                    return Err(OpdrError::config("bare `--` not supported"));
                }
                if let Some(eq) = stripped.find('=') {
                    args.flags
                        .insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else if iter.peek().map_or(false, |next| !next.starts_with("--")) {
                    let val = iter.next().unwrap();
                    args.flags.insert(stripped.to_string(), val);
                } else {
                    args.bools.push(stripped.to_string());
                }
            } else if args.subcommand.is_empty() {
                args.subcommand = tok;
            } else {
                args.positionals.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse the process argv.
    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    /// String flag value.
    pub fn get(&mut self, key: &str) -> Option<&str> {
        self.known_flags.push(key.to_string());
        self.flags.get(key).map(|s| s.as_str())
    }

    /// String flag with default.
    pub fn get_or(&mut self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Integer flag.
    pub fn get_usize(&mut self, key: &str) -> Result<Option<usize>> {
        match self.get(key) {
            None => Ok(None),
            Some(s) => s
                .parse::<usize>()
                .map(Some)
                .map_err(|_| OpdrError::config(format!("--{key} expects an integer, got `{s}`"))),
        }
    }

    /// Integer flag with default.
    pub fn get_usize_or(&mut self, key: &str, default: usize) -> Result<usize> {
        Ok(self.get_usize(key)?.unwrap_or(default))
    }

    /// u64 flag with default (seeds).
    pub fn get_u64_or(&mut self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse::<u64>()
                .map_err(|_| OpdrError::config(format!("--{key} expects a u64, got `{s}`"))),
        }
    }

    /// Float flag with default.
    pub fn get_f64_or(&mut self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse::<f64>()
                .map_err(|_| OpdrError::config(format!("--{key} expects a float, got `{s}`"))),
        }
    }

    /// Boolean switch (present without value).
    pub fn has(&mut self, key: &str) -> bool {
        self.known_flags.push(key.to_string());
        self.bools.iter().any(|b| b == key) || self.flags.contains_key(key)
    }

    /// Positional arguments after the subcommand.
    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    /// After reading all expected flags, reject anything unconsumed.
    pub fn finish(&self) -> Result<()> {
        for key in self.flags.keys().chain(self.bools.iter()) {
            if !self.known_flags.iter().any(|k| k == key) {
                return Err(OpdrError::config(format!("unknown flag --{key}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_flags_positionals() {
        // NOTE: `--flag value` consumes the next non-flag token, so positionals
        // go before flags or after a `--key=value` form.
        let mut a = parse(&["sweep", "--k", "5", "--metric=cosine", "extra", "--verbose"]);
        assert_eq!(a.subcommand, "sweep");
        assert_eq!(a.get("k"), Some("5"));
        assert_eq!(a.get("metric"), Some("cosine"));
        assert!(a.has("verbose"));
        assert_eq!(a.positionals(), &["extra".to_string()]);
        a.finish().unwrap();
    }

    #[test]
    fn typed_getters() {
        let mut a = parse(&["x", "--n", "12", "--ratio", "0.5", "--seed", "99"]);
        assert_eq!(a.get_usize_or("n", 1).unwrap(), 12);
        assert_eq!(a.get_f64_or("ratio", 0.0).unwrap(), 0.5);
        assert_eq!(a.get_u64_or("seed", 0).unwrap(), 99);
        assert_eq!(a.get_usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn bad_types_error() {
        let mut a = parse(&["x", "--n", "notanum"]);
        assert!(a.get_usize("n").is_err());
    }

    #[test]
    fn unknown_flag_rejected_by_finish() {
        let mut a = parse(&["x", "--known", "1", "--typo", "2"]);
        let _ = a.get("known");
        assert!(a.finish().is_err());
    }

    #[test]
    fn boolean_flag_followed_by_flag() {
        let mut a = parse(&["x", "--fast", "--k", "3"]);
        assert!(a.has("fast"));
        assert_eq!(a.get("k"), Some("3"));
    }

    #[test]
    fn empty_args_ok() {
        let a = parse(&[]);
        assert!(a.subcommand.is_empty());
    }
}
