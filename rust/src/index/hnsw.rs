//! Deterministic HNSW (Hierarchical Navigable Small World) graph index.
//!
//! Malkov & Yashunin's layered skip-list-over-graphs: every vector gets a
//! geometrically distributed top level (seeded [`Rng`], so builds are
//! deterministic), upper layers are sparse "express lanes" descended
//! greedily, and layer 0 holds the dense neighborhood graph searched with a
//! bounded beam (`ef`). Construction inserts points one at a time, linking
//! each to up to `m` discovered neighbors per layer (degree-capped at `2m`
//! on layer 0, `m` above) and shrinking overfull adjacency lists.
//!
//! Neighbor selection follows Malkov's Algorithm 4 (the *heuristic*:
//! a candidate is kept only when it is closer to the query node than to any
//! already-selected neighbor, which spreads links across directions and
//! keeps clustered regions navigable) when [`HnswParams::heuristic`] is on —
//! the default — and plain `m`-nearest selection otherwise. Both are
//! deterministic; the flag is a build-time choice and is deliberately not
//! persisted (a loaded graph already has its topology), so the on-disk
//! format is unchanged.
//!
//! Distances during *construction* use the raw full-precision rows;
//! distances during *search* go through the [`VectorStore`] (asymmetric when
//! SQ8-quantized; ADC lookup tables when PQ-quantized, followed by the
//! full-precision rerank stage), so the graph topology is identical between
//! a flat and a quantized build of the same data — only the scoring differs.
//!
//! Determinism contract (tested): equal `(data, params, seed)` give
//! bit-identical indexes, and a serialize/deserialize round-trip preserves
//! search results exactly.

use crate::data::mapped::{AnnexWriter, ColdContext};
use crate::error::{OpdrError, Result};
use crate::index::{io, pq, AnnIndex, IndexKind, StorageSpec, VectorStore};
use crate::knn::Neighbor;
use crate::metrics::Metric;
use crate::telemetry::SearchTrace;
use crate::util::timer::Stopwatch;
use crate::util::Rng;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::io::{Read, Write};

/// Maximum level a node may be assigned (keeps the descent bounded even on
/// adversarial RNG draws).
const MAX_LEVEL_CAP: u8 = 15;

/// HNSW construction / search parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HnswParams {
    /// Max links per node on layers ≥ 1 (layer 0 allows `2m`).
    pub m: usize,
    /// Beam width while inserting.
    pub ef_construction: usize,
    /// Default beam width while searching (raised to `k` when `k` is larger).
    pub ef_search: usize,
    /// Use Malkov Algorithm 4 heuristic neighbor selection during
    /// construction (default on; build-time only, not persisted).
    pub heuristic: bool,
}

impl Default for HnswParams {
    fn default() -> Self {
        HnswParams { m: 16, ef_construction: 100, ef_search: 64, heuristic: true }
    }
}

/// f32 with the IEEE-754 `totalOrder` (indexed data is finite, but a NaN
/// that ever leaked in would sort to the ends instead of silently comparing
/// equal to everything and scrambling the candidate heaps).
#[derive(Debug, Clone, Copy, PartialEq)]
struct OrdF32(f32);

impl Eq for OrdF32 {}

impl PartialOrd for OrdF32 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrdF32 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// The layered graph index.
#[derive(Debug, Clone)]
pub struct HnswIndex {
    metric: Metric,
    params: HnswParams,
    /// Entry point for the greedy descent (a node at `max_level`).
    entry: u32,
    /// Highest populated layer.
    max_level: usize,
    /// Top level of each node.
    levels: Vec<u8>,
    /// Adjacency: `links[node][level]` → neighbor ids, `level ≤ levels[node]`.
    links: Vec<Vec<Vec<u32>>>,
    store: VectorStore,
}

impl HnswIndex {
    /// Build over row-major `data`; deterministic from `seed`. Degenerate
    /// parameters are clamped (`m ≥ 2`, beams ≥ 1) rather than rejected.
    pub fn build(
        data: &[f32],
        dim: usize,
        metric: Metric,
        params: HnswParams,
        storage: &StorageSpec,
        seed: u64,
    ) -> Result<HnswIndex> {
        if dim == 0 || data.len() % dim != 0 {
            return Err(OpdrError::shape("hnsw: bad data shape"));
        }
        let n = data.len() / dim;
        if n == 0 {
            return Err(OpdrError::data("hnsw: empty data"));
        }
        let params = HnswParams {
            m: params.m.max(2),
            ef_construction: params.ef_construction.max(params.m.max(2)),
            ef_search: params.ef_search.max(1),
            heuristic: params.heuristic,
        };
        let m = params.m;

        // Seeded geometric level assignment: P(level ≥ l) = m^-l.
        let mut rng = Rng::new(seed);
        let inv_log_m = 1.0 / (m as f64).ln();
        let levels: Vec<u8> = (0..n).map(|_| sample_level(&mut rng, inv_log_m)).collect();

        let mut links: Vec<Vec<Vec<u32>>> =
            levels.iter().map(|&l| vec![Vec::new(); l as usize + 1]).collect();
        let mut entry: u32 = 0;
        let mut max_level = levels[0] as usize;

        for i in 1..n {
            let q = &data[i * dim..(i + 1) * dim];
            let l = levels[i] as usize;
            let top = max_level;

            // Greedy descent through layers above this node's level.
            let mut ep = entry;
            for lvl in (l + 1..=top).rev() {
                ep = greedy_descend(ep, lvl, &links, |id| {
                    metric.distance(q, &data[id * dim..(id + 1) * dim])
                });
            }

            // Beam-search and link on each layer the node participates in.
            for lvl in (0..=l.min(top)).rev() {
                let cands = search_layer(n, ep, params.ef_construction, lvl, &links, |id| {
                    metric.distance(q, &data[id * dim..(id + 1) * dim])
                });
                ep = cands[0].1;
                let max_deg = if lvl == 0 { 2 * m } else { m };
                let selected: Vec<u32> = if params.heuristic {
                    select_neighbors_heuristic(&cands, m, |a, b| {
                        dist_rows(data, dim, metric, a as usize, b as usize)
                    })
                } else {
                    cands.iter().take(m).map(|&(_, id)| id).collect()
                };
                links[i][lvl] = selected.clone();
                for &nb in &selected {
                    let nbu = nb as usize;
                    links[nbu][lvl].push(i as u32);
                    if links[nbu][lvl].len() > max_deg {
                        let mut scored: Vec<(OrdF32, u32)> = links[nbu][lvl]
                            .iter()
                            .map(|&x| {
                                (OrdF32(dist_rows(data, dim, metric, nbu, x as usize)), x)
                            })
                            .collect();
                        scored.sort();
                        links[nbu][lvl] = if params.heuristic {
                            select_neighbors_heuristic(&scored, max_deg, |a, b| {
                                dist_rows(data, dim, metric, a as usize, b as usize)
                            })
                        } else {
                            scored.truncate(max_deg);
                            scored.into_iter().map(|(_, x)| x).collect()
                        };
                    }
                }
            }

            if l > max_level {
                max_level = l;
                entry = i as u32;
            }
        }

        let store = VectorStore::build(data, dim, storage, seed)?;
        Ok(HnswIndex { metric, params, entry, max_level, levels, links, store })
    }

    /// Construction / search parameters (after clamping).
    pub fn params(&self) -> HnswParams {
        self.params
    }

    /// Highest populated layer.
    pub fn max_level(&self) -> usize {
        self.max_level
    }

    /// Deserialize (payload written by [`AnnIndex::write_to`]); validates
    /// structural invariants so a corrupt file cannot cause out-of-bounds
    /// traversal.
    pub(crate) fn read_from(r: &mut dyn Read) -> Result<HnswIndex> {
        HnswIndex::read_with(r, None)
    }

    /// [`HnswIndex::read_from`] with an optional cold context (version-5
    /// files: external payloads resolve against the file's mapped annex).
    pub(crate) fn read_with(r: &mut dyn Read, cx: Option<&ColdContext>) -> Result<HnswIndex> {
        let metric = io::metric_from_tag(io::read_u8(r)?)?;
        let m = io::read_u64_usize(r)?;
        let ef_construction = io::read_u64_usize(r)?;
        let ef_search = io::read_u64_usize(r)?;
        let entry = io::read_u64(r)?;
        let max_level = io::read_u64_usize(r)?;
        let n = io::read_u64_usize(r)?;
        if n == 0 || n > io::MAX_ELEMS || m < 2 {
            return Err(OpdrError::data("hnsw: corrupt header"));
        }
        if entry as usize >= n || max_level > MAX_LEVEL_CAP as usize {
            return Err(OpdrError::data("hnsw: corrupt entry point"));
        }
        // `n` is untrusted: bound the eager preallocations so a lying
        // header truncates instead of aborting on OOM.
        let mut levels = Vec::with_capacity(n.min(io::ALLOC_CHUNK));
        let mut links = Vec::with_capacity(n.min(io::ALLOC_CHUNK));
        for _ in 0..n {
            let l = io::read_u8(r)?;
            if l > MAX_LEVEL_CAP {
                return Err(OpdrError::data("hnsw: corrupt node level"));
            }
            let mut per_node = Vec::with_capacity(l as usize + 1);
            for _ in 0..=l {
                let len = io::read_u32(r)? as usize;
                if len > n {
                    return Err(OpdrError::data("hnsw: corrupt adjacency length"));
                }
                per_node.push(io::read_u32s(r, len)?);
            }
            levels.push(l);
            links.push(per_node);
        }
        let store = VectorStore::read_with(r, cx)?;
        if store.len() != n {
            return Err(OpdrError::data("hnsw: store length mismatch"));
        }
        if (levels[entry as usize] as usize) < max_level {
            return Err(OpdrError::data("hnsw: entry below max level"));
        }
        // Every link must point inside the graph at a node that reaches the
        // link's layer; otherwise traversal would index out of bounds.
        for per_node in &links {
            for (lvl, list) in per_node.iter().enumerate() {
                for &v in list {
                    let vu = v as usize;
                    if vu >= n || (levels[vu] as usize) < lvl {
                        return Err(OpdrError::data("hnsw: corrupt link"));
                    }
                }
            }
        }
        // `heuristic` is a construction-time choice; the loaded graph's
        // topology already reflects it, so the default is recorded.
        let params = HnswParams { m, ef_construction, ef_search, heuristic: true };
        Ok(HnswIndex { metric, params, entry, max_level, levels, links, store })
    }

    fn write_impl(&self, w: &mut dyn Write, annex: Option<&mut AnnexWriter>) -> Result<()> {
        io::write_u8(w, io::metric_tag(self.metric))?;
        io::write_u64(w, self.params.m as u64)?;
        io::write_u64(w, self.params.ef_construction as u64)?;
        io::write_u64(w, self.params.ef_search as u64)?;
        io::write_u64(w, self.entry as u64)?;
        io::write_u64(w, self.max_level as u64)?;
        io::write_u64(w, self.len() as u64)?;
        for (node, per_node) in self.links.iter().enumerate() {
            io::write_u8(w, self.levels[node])?;
            for list in per_node {
                io::write_u32(w, list.len() as u32)?;
                for &id in list {
                    io::write_u32(w, id)?;
                }
            }
        }
        self.store.write_with(w, annex)
    }

    fn search_impl(
        &self,
        query: &[f32],
        k: usize,
        trace: Option<&SearchTrace>,
    ) -> Result<Vec<Neighbor>> {
        let dim = self.dim();
        if query.len() != dim {
            return Err(OpdrError::shape(format!(
                "hnsw search: query dim {} != index dim {dim}",
                query.len()
            )));
        }
        if k == 0 {
            return Ok(Vec::new());
        }
        if let Some(p) = self.store.as_pq() {
            // PQ path: walk the graph on ADC lookups, then rerank the beam's
            // top `rerank_depth` at full precision. The beam is widened to
            // the rerank depth so the candidate stage can fill it. The graph
            // walk is the ADC scan stage; the rerank attributes separately.
            let sw = Stopwatch::start();
            let table = pq::AdcTable::new(p, self.metric, query)?;
            let depth = p.rerank_depth().max(k);
            let mut ep = self.entry;
            for lvl in (1..=self.max_level).rev() {
                ep = greedy_descend(ep, lvl, &self.links, |id| table.lookup(id));
            }
            let ef = self.params.ef_search.max(k).max(depth);
            let found =
                search_layer(self.len(), ep, ef, 0, &self.links, |id| table.lookup(id));
            let ids: Vec<usize> =
                found.into_iter().take(depth).map(|(_, id)| id as usize).collect();
            if let Some(t) = trace {
                t.scan.record(sw.elapsed());
            }
            let sw = Stopwatch::start();
            let out = pq::rerank(p, self.metric, query, ids, k);
            if let Some(t) = trace {
                t.rerank.record(sw.elapsed());
            }
            return Ok(out);
        }
        let sw = Stopwatch::start();
        let mut scratch = Vec::new();
        let mut ep = self.entry;
        for lvl in (1..=self.max_level).rev() {
            ep = greedy_descend(ep, lvl, &self.links, |id| {
                self.store.distance(self.metric, query, id, &mut scratch)
            });
        }
        let ef = self.params.ef_search.max(k);
        let found = search_layer(self.len(), ep, ef, 0, &self.links, |id| {
            self.store.distance(self.metric, query, id, &mut scratch)
        });
        let out = found
            .into_iter()
            .take(k)
            .map(|(d, id)| Neighbor { index: id as usize, distance: d.0 })
            .collect();
        if let Some(t) = trace {
            t.scan.record(sw.elapsed());
        }
        Ok(out)
    }
}

impl AnnIndex for HnswIndex {
    fn kind(&self) -> IndexKind {
        IndexKind::Hnsw
    }

    fn len(&self) -> usize {
        self.store.len()
    }

    fn dim(&self) -> usize {
        self.store.dim()
    }

    fn metric(&self) -> Metric {
        self.metric
    }

    fn quantized(&self) -> bool {
        self.store.quantized()
    }

    fn storage_name(&self) -> &'static str {
        self.store.name()
    }

    fn memory_bytes(&self) -> usize {
        let links_bytes: usize = self
            .links
            .iter()
            .map(|per| per.iter().map(|l| l.len() * std::mem::size_of::<u32>()).sum::<usize>())
            .sum();
        self.store.memory_bytes() + links_bytes + self.levels.len()
    }

    fn cold_bytes(&self) -> usize {
        self.store.cold_bytes()
    }

    fn mapped_bytes(&self) -> usize {
        self.store.mapped_bytes()
    }

    fn matches_data(&self, data: &[f32]) -> bool {
        self.store.matches(data)
    }

    fn search(&self, query: &[f32], k: usize) -> Result<Vec<Neighbor>> {
        self.search_impl(query, k, None)
    }

    fn search_traced(&self, query: &[f32], k: usize, trace: &SearchTrace) -> Result<Vec<Neighbor>> {
        self.search_impl(query, k, Some(trace))
    }

    fn write_to(&self, w: &mut dyn Write) -> Result<()> {
        self.write_impl(w, None)
    }

    fn write_cold(&self, w: &mut dyn Write, annex: &mut AnnexWriter) -> Result<()> {
        self.write_impl(w, Some(annex))
    }
}

/// Geometric level draw: `floor(−ln(U) / ln(m))`, capped.
fn sample_level(rng: &mut Rng, inv_log_m: f64) -> u8 {
    let u = rng.uniform().max(f64::MIN_POSITIVE);
    let l = (-u.ln() * inv_log_m).floor();
    if l >= MAX_LEVEL_CAP as f64 {
        MAX_LEVEL_CAP
    } else {
        l as u8
    }
}

/// Raw-row distance used during construction.
#[inline]
fn dist_rows(data: &[f32], dim: usize, metric: Metric, a: usize, b: usize) -> f32 {
    metric.distance(&data[a * dim..(a + 1) * dim], &data[b * dim..(b + 1) * dim])
}

/// Malkov Algorithm 4 (SELECT-NEIGHBORS-HEURISTIC, the hnswlib shrink rule):
/// walk candidates ascending by distance to the query node and keep one only
/// when it is closer to the query than to every already-kept neighbor
/// (`dist_between(cand, kept) ≥ cand's query distance`). This spreads links
/// across directions instead of piling them into one cluster, which is what
/// keeps the graph navigable between clusters. May select fewer than
/// `max_links`; the closest candidate is always kept, so every inserted node
/// stays bidirectionally linked to its nearest discovered neighbor (the
/// connectivity the exhaustive-beam exactness contract relies on).
/// Deterministic: candidates arrive sorted by `(distance, id)`.
fn select_neighbors_heuristic<F: FnMut(u32, u32) -> f32>(
    cands: &[(OrdF32, u32)],
    max_links: usize,
    mut dist_between: F,
) -> Vec<u32> {
    let mut selected: Vec<u32> = Vec::with_capacity(max_links.min(cands.len()));
    for &(d, id) in cands {
        if selected.len() >= max_links {
            break;
        }
        if selected.iter().all(|&s| dist_between(id, s) >= d.0) {
            selected.push(id);
        }
    }
    selected
}

/// Greedy hill descent on one layer: move to the closest neighbor until no
/// strict improvement. `dist(id)` scores a node against the implicit query.
fn greedy_descend<F: FnMut(usize) -> f32>(
    mut ep: u32,
    lvl: usize,
    links: &[Vec<Vec<u32>>],
    mut dist: F,
) -> u32 {
    let mut best = dist(ep as usize);
    loop {
        let mut improved = false;
        for &v in &links[ep as usize][lvl] {
            let d = dist(v as usize);
            if d < best {
                best = d;
                ep = v;
                improved = true;
            }
        }
        if !improved {
            return ep;
        }
    }
}

/// Visited-node set for one beam search. The beam only touches ~`ef·2m`
/// nodes, so for large graphs a hash set avoids the O(n) allocate+memset a
/// dense bitmap would pay per query; small graphs use the bitmap (faster
/// constants, and exhaustive `ef ≥ n` searches touch everything anyway).
enum Visited {
    Dense(Vec<bool>),
    Sparse(std::collections::HashSet<u32>),
}

impl Visited {
    fn new(n: usize, ef: usize) -> Visited {
        // Dense wins when the expected visit count is a sizable fraction of n.
        if n <= 4096 || ef.saturating_mul(64) >= n {
            Visited::Dense(vec![false; n])
        } else {
            Visited::Sparse(std::collections::HashSet::new())
        }
    }

    /// Mark `id`; returns true when it was not visited before.
    fn insert(&mut self, id: u32) -> bool {
        match self {
            Visited::Dense(v) => {
                let seen = &mut v[id as usize];
                !std::mem::replace(seen, true)
            }
            Visited::Sparse(s) => s.insert(id),
        }
    }
}

/// Bounded beam search on one layer (the classic SEARCH-LAYER): returns up
/// to `ef` nodes ascending by `(distance, id)`. With `ef ≥ n` this visits
/// the entire connected component, making the result exact.
fn search_layer<F: FnMut(usize) -> f32>(
    n: usize,
    ep: u32,
    ef: usize,
    lvl: usize,
    links: &[Vec<Vec<u32>>],
    mut dist: F,
) -> Vec<(OrdF32, u32)> {
    let ef = ef.max(1);
    let mut visited = Visited::new(n, ef);
    visited.insert(ep);
    let d0 = OrdF32(dist(ep as usize));

    // Min-heap of the expansion frontier; max-heap of the best `ef` found.
    let mut frontier: BinaryHeap<Reverse<(OrdF32, u32)>> = BinaryHeap::new();
    let mut best: BinaryHeap<(OrdF32, u32)> = BinaryHeap::new();
    frontier.push(Reverse((d0, ep)));
    best.push((d0, ep));

    while let Some(Reverse((d, u))) = frontier.pop() {
        if best.len() >= ef {
            if let Some(&(worst, _)) = best.peek() {
                if d > worst {
                    break;
                }
            }
        }
        for &v in &links[u as usize][lvl] {
            if !visited.insert(v) {
                continue;
            }
            let dv = OrdF32(dist(v as usize));
            let admit = best.len() < ef || best.peek().map(|&(w, _)| dv < w).unwrap_or(true);
            if admit {
                frontier.push(Reverse((dv, v)));
                best.push((dv, v));
                if best.len() > ef {
                    best.pop();
                }
            }
        }
    }
    let mut out = best.into_vec();
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn normal_data(n: usize, dim: usize, seed: u64) -> Vec<f32> {
        Rng::new(seed).normal_vec_f32(n * dim)
    }

    fn recall(
        idx: &HnswIndex,
        data: &[f32],
        dim: usize,
        queries: &[Vec<f32>],
        k: usize,
    ) -> f64 {
        let mut hits = 0usize;
        for q in queries {
            let got: std::collections::HashSet<usize> =
                idx.search(q, k).unwrap().iter().map(|n| n.index).collect();
            let want = crate::knn::knn_indices(q, data, dim, k, idx.metric()).unwrap();
            hits += want.iter().filter(|n| got.contains(&n.index)).count();
        }
        hits as f64 / (queries.len() * k) as f64
    }

    #[test]
    fn exhaustive_beam_is_exact() {
        // With degree cap 2m ≥ n (no pruning) and ef ≥ n the layer-0 beam
        // visits the whole graph, so results must equal brute force
        // including tie order.
        let dim = 4;
        let n = 30;
        let data = normal_data(n, dim, 1);
        let params = HnswParams { m: 16, ef_construction: 32, ef_search: 64, heuristic: true };
        let idx =
            HnswIndex::build(&data, dim, Metric::SqEuclidean, params, &StorageSpec::flat(), 7)
                .unwrap();
        let mut rng = Rng::new(2);
        for _ in 0..8 {
            let q = rng.normal_vec_f32(dim);
            let got = idx.search(&q, 5).unwrap();
            let want = crate::knn::knn_indices(&q, &data, dim, 5, Metric::SqEuclidean).unwrap();
            assert_eq!(
                got.iter().map(|x| x.index).collect::<Vec<_>>(),
                want.iter().map(|x| x.index).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn high_recall_on_larger_set() {
        let dim = 16;
        let n = 1000;
        let data = normal_data(n, dim, 3);
        let params = HnswParams { m: 16, ef_construction: 100, ef_search: 128, heuristic: true };
        let idx =
            HnswIndex::build(&data, dim, Metric::SqEuclidean, params, &StorageSpec::flat(), 9)
                .unwrap();
        let queries: Vec<Vec<f32>> =
            (0..20).map(|i| data[i * 37 * dim % (n * dim - dim)..][..dim].to_vec()).collect();
        let r = recall(&idx, &data, dim, &queries, 10);
        assert!(r >= 0.9, "hnsw recall@10 = {r}");
    }

    #[test]
    fn deterministic_across_builds() {
        let dim = 8;
        let data = normal_data(200, dim, 5);
        let params = HnswParams::default();
        let a = HnswIndex::build(&data, dim, Metric::Euclidean, params, &StorageSpec::flat(), 42)
            .unwrap();
        let b = HnswIndex::build(&data, dim, Metric::Euclidean, params, &StorageSpec::flat(), 42)
            .unwrap();
        let mut rng = Rng::new(6);
        for _ in 0..5 {
            let q = rng.normal_vec_f32(dim);
            let ra = a.search(&q, 7).unwrap();
            let rb = b.search(&q, 7).unwrap();
            assert_eq!(ra.len(), rb.len());
            for (x, y) in ra.iter().zip(&rb) {
                assert_eq!(x.index, y.index);
                assert_eq!(x.distance.to_bits(), y.distance.to_bits());
            }
        }
    }

    #[test]
    fn roundtrip_bit_identical_results() {
        let dim = 8;
        let data = normal_data(150, dim, 8);
        for sq8 in [false, true] {
            let idx = HnswIndex::build(
                &data,
                dim,
                Metric::SqEuclidean,
                HnswParams::default(),
                sq8,
                4,
            )
            .unwrap();
            let mut buf = Vec::new();
            idx.write_to(&mut buf).unwrap();
            let back = HnswIndex::read_from(&mut buf.as_slice()).unwrap();
            assert_eq!(back.max_level(), idx.max_level());
            let mut rng = Rng::new(1);
            for _ in 0..6 {
                let q = rng.normal_vec_f32(dim);
                let ra = idx.search(&q, 9).unwrap();
                let rb = back.search(&q, 9).unwrap();
                assert_eq!(ra.len(), rb.len());
                for (x, y) in ra.iter().zip(&rb) {
                    assert_eq!(x.index, y.index);
                    assert_eq!(x.distance.to_bits(), y.distance.to_bits());
                }
            }
        }
    }

    #[test]
    fn sq8_variant_shrinks_and_still_finds_neighbors() {
        let dim = 16;
        let n = 400;
        let data = normal_data(n, dim, 11);
        let params = HnswParams { m: 16, ef_construction: 100, ef_search: 128, heuristic: true };
        let flat =
            HnswIndex::build(&data, dim, Metric::SqEuclidean, params, &StorageSpec::flat(), 2)
                .unwrap();
        let sq8 =
            HnswIndex::build(&data, dim, Metric::SqEuclidean, params, &StorageSpec::sq8(), 2)
                .unwrap();
        assert!(sq8.quantized());
        assert!(sq8.memory_bytes() < flat.memory_bytes());
        let queries: Vec<Vec<f32>> = (0..10).map(|i| data[i * dim..][..dim].to_vec()).collect();
        let r = recall(&sq8, &data, dim, &queries, 10);
        assert!(r >= 0.7, "hnsw+sq8 recall@10 = {r}");
    }

    #[test]
    fn heuristic_exhaustive_beam_still_exact() {
        // The heuristic may select fewer than m links, but the nearest
        // candidate is always kept (bidirectionally), so layer 0 stays
        // connected and an exhaustive beam remains exact.
        let dim = 4;
        let n = 40;
        let data = normal_data(n, dim, 51);
        for heuristic in [true, false] {
            let params =
                HnswParams { m: n, ef_construction: 2 * n, ef_search: 4 * n, heuristic };
            let idx =
                HnswIndex::build(&data, dim, Metric::SqEuclidean, params, &StorageSpec::flat(), 7)
                    .unwrap();
            let mut rng = Rng::new(5);
            for _ in 0..6 {
                let q = rng.normal_vec_f32(dim);
                let got = idx.search(&q, 6).unwrap();
                let want =
                    crate::knn::knn_indices(&q, &data, dim, 6, Metric::SqEuclidean).unwrap();
                assert_eq!(
                    got.iter().map(|x| x.index).collect::<Vec<_>>(),
                    want.iter().map(|x| x.index).collect::<Vec<_>>(),
                    "heuristic={heuristic}"
                );
            }
        }
    }

    #[test]
    fn heuristic_prunes_no_worse_recall_than_plain_on_clustered_data() {
        // Two far-apart clusters: heuristic selection keeps cross-cluster
        // links navigable. Both variants must stay usable; the heuristic one
        // must not regress below the plain one by more than noise.
        let dim = 8;
        let n = 400;
        let mut rng = Rng::new(61);
        let mut data = Vec::with_capacity(n * dim);
        for i in 0..n {
            let center = if i % 2 == 0 { 0.0 } else { 30.0 };
            for _ in 0..dim {
                data.push(center + rng.normal() as f32);
            }
        }
        let queries: Vec<Vec<f32>> = (0..10).map(|i| data[i * dim..][..dim].to_vec()).collect();
        for heuristic in [true, false] {
            let params = HnswParams { m: 8, ef_construction: 60, ef_search: 48, heuristic };
            let idx =
                HnswIndex::build(&data, dim, Metric::SqEuclidean, params, &StorageSpec::flat(), 3)
                    .unwrap();
            let r = recall(&idx, &data, dim, &queries, 10);
            assert!(r >= 0.7, "heuristic={heuristic} recall {r}");
        }
    }

    #[test]
    fn pq_storage_exhaustive_beam_and_depth_is_bitwise_exact() {
        use crate::index::PqParams;
        let dim = 6;
        let n = 30;
        let data = normal_data(n, dim, 71);
        let params = HnswParams { m: n, ef_construction: 2 * n, ef_search: 4 * n, heuristic: true };
        let spec = StorageSpec::pq_with(PqParams { rerank_depth: n, ..Default::default() });
        let idx =
            HnswIndex::build(&data, dim, Metric::SqEuclidean, params, &spec, 7).unwrap();
        assert!(idx.quantized());
        assert_eq!(idx.storage_name(), "pq");
        let flat = crate::index::ExactIndex::build(
            &data,
            dim,
            Metric::SqEuclidean,
            &StorageSpec::flat(),
            7,
        )
        .unwrap();
        let mut rng = Rng::new(9);
        for _ in 0..6 {
            let q = rng.normal_vec_f32(dim);
            let a = flat.search(&q, 8).unwrap();
            let b = idx.search(&q, 8).unwrap();
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.index, y.index);
                assert_eq!(x.distance.to_bits(), y.distance.to_bits());
            }
        }
    }

    #[test]
    fn corrupt_payloads_rejected() {
        let dim = 4;
        let data = normal_data(20, dim, 1);
        let idx =
            HnswIndex::build(
                &data,
                dim,
                Metric::Euclidean,
                HnswParams::default(),
                &StorageSpec::flat(),
                3,
            )
            .unwrap();
        let mut buf = Vec::new();
        idx.write_to(&mut buf).unwrap();
        // Truncation.
        assert!(HnswIndex::read_from(&mut &buf[..buf.len() / 2]).is_err());
        // Entry point out of range: bytes 25..33 hold the entry id.
        let mut bad = buf.clone();
        bad[25..33].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(HnswIndex::read_from(&mut bad.as_slice()).is_err());
    }

    #[test]
    fn edge_cases_single_node_and_large_k() {
        let data = vec![1.0f32, 2.0, 3.0];
        let idx = HnswIndex::build(
            &data,
            3,
            Metric::Euclidean,
            HnswParams::default(),
            &StorageSpec::flat(),
            1,
        )
        .unwrap();
        let hits = idx.search(&[1.0, 2.0, 3.0], 5).unwrap();
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].index, 0);
        assert!(idx.search(&[1.0, 2.0], 1).is_err());
        assert!(idx.search(&[0.0; 3], 0).unwrap().is_empty());

        let data = normal_data(12, 4, 2);
        let idx = HnswIndex::build(
            &data,
            4,
            Metric::Euclidean,
            HnswParams::default(),
            &StorageSpec::flat(),
            1,
        )
        .unwrap();
        let all = idx.search(&data[..4].to_vec(), 50).unwrap();
        assert_eq!(all.len(), 12);
        // Ascending by distance.
        for w in all.windows(2) {
            assert!(w[0].distance <= w[1].distance);
        }
    }
}
