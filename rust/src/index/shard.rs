//! Sharded index: a collection split into `S` independent index segments.
//!
//! Each segment is a complete [`AnnIndex`] (any substrate: exact / IVF-Flat /
//! HNSW, flat or SQ8 storage) over a contiguous slice of the collection's
//! rows; segment-local hit ids are remapped to global ids by adding the
//! segment's row offset. Sharding buys two things on the serving path:
//!
//! * **parallel builds** — whole-segment builds are independent, so
//!   [`build_on_pool`] fans them out across the coordinator's worker pool and
//!   a collector thread assembles and delivers the finished index without
//!   ever blocking the caller (the scheduler thread);
//! * **parallel queries** — [`ShardedIndex::search_on`] fans one query out
//!   across segments on the pool and merges per-segment top-k lists through
//!   the bounded heap in [`crate::knn::topk::merge_top_k`].
//!
//! ## Exactness contract (machine-checked in `tests/props.rs`)
//!
//! The fan-out/merge is *order-exact*, not approximately-recall-equal:
//! merging each segment's top-k (remapped to global ids) under the global
//! (distance, index) order returns byte-identical neighbors to searching the
//! same segments serially — and, for substrates whose per-segment search is
//! exhaustive (exact flat scan; IVF at full probe; HNSW at `m ≥ n`,
//! `ef ≥ 4n`), byte-identical neighbors to the *unsharded* index over the
//! whole collection, including tie and NaN-distance vectors and `k ≥ n`.
//! SQ8 codebooks default to per-segment training (the FAISS/Lucene
//! segment-local convention), so quantized distances are defined relative
//! to each segment's codebook and the merge contract still holds
//! bit-for-bit; with [`IndexPolicy::sq8_global_codebook`] the builder
//! trains one [`Sq8Bounds`] over the whole collection and every segment
//! encodes against it, making quantized sharded results bit-identical to
//! the *unsharded* quantized index at exhaustive parameters too. PQ
//! segments keep segment-local codebooks — their full-precision rerank
//! stage already pins exhaustive-depth results to the exact index
//! regardless of codebooks.
//!
//! Partitioning, per-shard seeds and therefore every segment structure are
//! deterministic: equal `(data, policy, seed)` give bit-identical sharded
//! indexes whether built serially or on the pool.

use crate::config::IndexPolicy;
use crate::data::mapped::{AnnexWriter, ColdContext};
use crate::error::{OpdrError, Result};
use crate::index::{io, AnnIndex, IndexKind, Sq8Bounds};
use crate::knn::topk::merge_top_k;
use crate::knn::Neighbor;
use crate::metrics::Metric;
use crate::pool::ThreadPool;
use crate::telemetry::SearchTrace;
use crate::util::timer::Stopwatch;
use std::io::{Read, Write};
use std::ops::Range;
use std::sync::mpsc::sync_channel;
use std::sync::Arc;

/// Upper bound on the segment count accepted from disk (a corrupt header
/// must not trigger huge allocations).
pub const MAX_SHARDS: usize = 4096;

/// Deterministic per-shard build seed (shard 0 keeps `seed` itself, so a
/// single-shard build is bit-identical to the unsharded build path).
pub fn shard_seed(seed: u64, shard: usize) -> u64 {
    seed.wrapping_add((shard as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Deterministic balanced partition of `n` rows into at most `shards`
/// contiguous ranges, never creating a shard smaller than
/// `shard_min_vectors` (a minimum of 0 is treated as 1). Always returns at
/// least one range; earlier ranges get the remainder rows.
pub fn shard_ranges(n: usize, shards: usize, shard_min_vectors: usize) -> Vec<Range<usize>> {
    let max_by_min = (n / shard_min_vectors.max(1)).max(1);
    let s = shards.max(1).min(max_by_min).min(n.max(1));
    let base = n / s;
    let rem = n % s;
    let mut out = Vec::with_capacity(s);
    let mut start = 0usize;
    for i in 0..s {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// The per-segment build policy: the substrate is decided once from the
/// *collection* size (so a shard slice dropping under `exact_threshold`
/// never silently changes substrate), and recursion into sharding is off.
fn leaf_policy(n: usize, policy: &IndexPolicy) -> IndexPolicy {
    IndexPolicy {
        kind: if n < policy.exact_threshold { IndexKind::Exact } else { policy.kind },
        exact_threshold: 0,
        shards: 1,
        ..policy.clone()
    }
}

/// [`leaf_policy`] plus the global-codebook option: when
/// `sq8_global_codebook` is on, train one set of [`Sq8Bounds`] over the
/// *whole* collection and pin it into the leaf policy so every segment
/// encodes against identical codebooks.
fn leaf_policy_with_bounds(
    data: &[f32],
    dim: usize,
    n: usize,
    policy: &IndexPolicy,
) -> Result<IndexPolicy> {
    let mut leaf = leaf_policy(n, policy);
    if leaf.sq8 && leaf.sq8_global_codebook && leaf.sq8_bounds.is_none() {
        leaf.sq8_bounds = Some(Arc::new(Sq8Bounds::train(data, dim)?));
    }
    Ok(leaf)
}

/// A collection served by `S` independent index segments with stable
/// global-id remapping (segment `s` owns global rows
/// `offsets[s]..offsets[s+1]`).
#[derive(Debug)]
pub struct ShardedIndex {
    metric: Metric,
    dim: usize,
    /// Row offsets; `offsets[0] == 0`, `offsets[S] == len()`.
    offsets: Vec<usize>,
    /// Segments are `Arc` so query fan-out can move clones onto the pool.
    segments: Vec<Arc<dyn AnnIndex>>,
}

impl ShardedIndex {
    /// Assemble from already-built segments (offsets accumulate in order).
    /// All segments must be non-empty, share one dimensionality and metric,
    /// and be leaf indexes (nesting sharded segments is rejected).
    pub fn from_segments(segments: Vec<Box<dyn AnnIndex>>) -> Result<ShardedIndex> {
        if segments.is_empty() {
            return Err(OpdrError::data("sharded index: no segments"));
        }
        if segments.len() > MAX_SHARDS {
            return Err(OpdrError::data(format!(
                "sharded index: {} segments exceeds the cap of {MAX_SHARDS}",
                segments.len()
            )));
        }
        let dim = segments[0].dim();
        let metric = segments[0].metric();
        let mut offsets = Vec::with_capacity(segments.len() + 1);
        offsets.push(0usize);
        for (s, seg) in segments.iter().enumerate() {
            if seg.as_sharded().is_some() {
                return Err(OpdrError::data(
                    "sharded index: nested sharded segments are not supported",
                ));
            }
            if seg.is_empty() {
                return Err(OpdrError::data(format!("sharded index: segment {s} is empty")));
            }
            if seg.dim() != dim {
                return Err(OpdrError::data(format!(
                    "sharded index: segment {s} dim {} != segment 0 dim {dim}",
                    seg.dim()
                )));
            }
            if seg.metric() != metric {
                return Err(OpdrError::data(format!(
                    "sharded index: segment {s} metric {} != segment 0 metric {}",
                    seg.metric().name(),
                    metric.name()
                )));
            }
            offsets.push(offsets.last().unwrap() + seg.len());
        }
        let segments = segments
            .into_iter()
            .map(|seg| -> Arc<dyn AnnIndex> { Arc::from(seg) })
            .collect();
        Ok(ShardedIndex { metric, dim, offsets, segments })
    }

    /// Build serially per `policy` (partition via [`shard_ranges`], one
    /// [`crate::index::build_index`] call per slice with [`shard_seed`]).
    /// Bit-identical to [`build_on_pool`] over the same inputs.
    pub fn build(
        data: &[f32],
        dim: usize,
        metric: Metric,
        policy: &IndexPolicy,
        seed: u64,
    ) -> Result<ShardedIndex> {
        if dim == 0 || data.len() % dim != 0 {
            return Err(OpdrError::shape(format!(
                "sharded index build: {} floats is not a multiple of dim {dim}",
                data.len()
            )));
        }
        let n = data.len() / dim;
        if n == 0 {
            return Err(OpdrError::data("sharded index build: empty data"));
        }
        let ranges = shard_ranges(n, policy.shards, policy.shard_min_vectors);
        let leaf = leaf_policy_with_bounds(data, dim, n, policy)?;
        let mut segments: Vec<Box<dyn AnnIndex>> = Vec::with_capacity(ranges.len());
        for (s, r) in ranges.iter().enumerate() {
            segments.push(crate::index::build_index(
                &data[r.start * dim..r.end * dim],
                dim,
                metric,
                &leaf,
                shard_seed(seed, s),
            )?);
        }
        ShardedIndex::from_segments(segments)
    }

    /// Number of segments.
    pub fn num_shards(&self) -> usize {
        self.segments.len()
    }

    /// Global-id range owned by segment `s`.
    pub fn shard_range(&self, s: usize) -> Range<usize> {
        self.offsets[s]..self.offsets[s + 1]
    }

    /// Shared handle to segment `s`'s leaf index. The distribution layer
    /// ([`crate::dist`]) serves these same leaves from shard workers, which
    /// is what makes a gateway merge bitwise comparable to the in-process
    /// fan-out for every substrate × storage (including segment-local SQ8
    /// codebooks).
    pub fn segment(&self, s: usize) -> Arc<dyn AnnIndex> {
        Arc::clone(&self.segments[s])
    }

    fn check_query(&self, query: &[f32]) -> Result<()> {
        if query.len() != self.dim {
            return Err(OpdrError::shape(format!(
                "sharded search: query dim {} != index dim {}",
                query.len(),
                self.dim
            )));
        }
        Ok(())
    }

    /// Merge per-segment hit lists (in segment order) into the global top-k.
    fn merge(&self, per_segment: Vec<Vec<Neighbor>>, k: usize) -> Vec<Neighbor> {
        let cands = per_segment.into_iter().enumerate().flat_map(|(s, hits)| {
            let base = self.offsets[s];
            hits.into_iter().map(move |nb| (nb.index + base, nb.distance))
        });
        merge_top_k(cands, k)
            .into_iter()
            .map(|(index, distance)| Neighbor { index, distance })
            .collect()
    }

    /// Fan the query out across segments on `pool` and merge, returning
    /// byte-identical results to the serial [`AnnIndex::search`].
    ///
    /// Must not be called from a pool worker itself (the fan-out would wait
    /// on jobs that can never be scheduled); the coordinator calls it from
    /// the scheduler thread. Queries fanned out while segment builds occupy
    /// the pool queue behind them — latency, not a deadlock (a rebuild's
    /// *own* collection keeps serving its previous index either way).
    pub fn search_on(&self, pool: &ThreadPool, query: &[f32], k: usize) -> Result<Vec<Neighbor>> {
        self.search_on_impl(pool, query, k, None)
    }

    /// [`ShardedIndex::search_on`] with per-stage latency attribution: each
    /// segment search records into the trace's scan (and, for quantized
    /// segments, rerank) histograms from its worker, and the global top-k
    /// merge records into the merge histogram. Results stay byte-identical.
    pub fn search_on_traced(
        &self,
        pool: &ThreadPool,
        query: &[f32],
        k: usize,
        trace: &SearchTrace,
    ) -> Result<Vec<Neighbor>> {
        self.search_on_impl(pool, query, k, Some(trace))
    }

    fn search_on_impl(
        &self,
        pool: &ThreadPool,
        query: &[f32],
        k: usize,
        trace: Option<&SearchTrace>,
    ) -> Result<Vec<Neighbor>> {
        if self.segments.len() < 2 || pool.size() < 2 {
            return match trace {
                Some(t) => self.search_traced(query, k, t),
                None => self.search(query, k),
            };
        }
        self.check_query(query)?;
        let q = Arc::new(query.to_vec());
        // One slot per segment: every worker's send succeeds immediately
        // even before this thread starts draining (bounded, never blocks).
        let (tx, rx) = sync_channel::<(usize, Result<Vec<Neighbor>>)>(self.segments.len());
        for (s, seg) in self.segments.iter().enumerate() {
            let seg = Arc::clone(seg);
            let q = Arc::clone(&q);
            let tx = tx.clone();
            // The trace is a bundle of Arc'd histograms — cloning it moves
            // cheap handles into the 'static pool closure.
            let trace = trace.cloned();
            pool.execute(move || {
                let res = match &trace {
                    Some(t) => seg.search_traced(&q, k, t),
                    None => seg.search(&q, k),
                };
                let _ = tx.send((s, res));
            });
        }
        drop(tx);
        let mut parts: Vec<(usize, Result<Vec<Neighbor>>)> = rx.iter().collect();
        if parts.len() != self.segments.len() {
            return Err(OpdrError::coordinator("sharded search: a shard result was dropped"));
        }
        // Deterministic merge and error order regardless of completion order.
        parts.sort_by_key(|p| p.0);
        let mut per_segment = Vec::with_capacity(parts.len());
        for (_, res) in parts {
            per_segment.push(res?);
        }
        let sw = Stopwatch::start();
        let merged = self.merge(per_segment, k);
        if let Some(t) = trace {
            t.merge.record(sw.elapsed());
        }
        Ok(merged)
    }
}

impl AnnIndex for ShardedIndex {
    fn kind(&self) -> IndexKind {
        // Segments built through `build`/`build_on_pool` share one substrate;
        // hand-assembled mixed-kind segment sets report their first segment.
        self.segments[0].kind()
    }

    fn len(&self) -> usize {
        *self.offsets.last().unwrap()
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn metric(&self) -> Metric {
        self.metric
    }

    fn quantized(&self) -> bool {
        self.segments.iter().all(|s| s.quantized())
    }

    fn storage_name(&self) -> &'static str {
        self.segments[0].storage_name()
    }

    fn memory_bytes(&self) -> usize {
        self.segments.iter().map(|s| s.memory_bytes()).sum()
    }

    fn cold_bytes(&self) -> usize {
        self.segments.iter().map(|s| s.cold_bytes()).sum()
    }

    fn mapped_bytes(&self) -> usize {
        self.segments.iter().map(|s| s.mapped_bytes()).sum()
    }

    fn search(&self, query: &[f32], k: usize) -> Result<Vec<Neighbor>> {
        self.check_query(query)?;
        let mut per_segment = Vec::with_capacity(self.segments.len());
        for seg in &self.segments {
            per_segment.push(seg.search(query, k)?);
        }
        Ok(self.merge(per_segment, k))
    }

    fn search_traced(&self, query: &[f32], k: usize, trace: &SearchTrace) -> Result<Vec<Neighbor>> {
        self.check_query(query)?;
        let mut per_segment = Vec::with_capacity(self.segments.len());
        for seg in &self.segments {
            per_segment.push(seg.search_traced(query, k, trace)?);
        }
        let sw = Stopwatch::start();
        let merged = self.merge(per_segment, k);
        trace.merge.record(sw.elapsed());
        Ok(merged)
    }

    fn matches_data(&self, data: &[f32]) -> bool {
        if data.len() != self.len() * self.dim {
            return false;
        }
        self.segments
            .iter()
            .zip(self.offsets.windows(2))
            .all(|(seg, w)| seg.matches_data(&data[w[0] * self.dim..w[1] * self.dim]))
    }

    fn as_sharded(&self) -> Option<&ShardedIndex> {
        Some(self)
    }

    /// Multi-segment payload: `u32` segment count, then per segment a header
    /// (`u32` kind tag, `u8` metric tag, `u64` n, `u64` dim, `u64` global
    /// start row, `u64` payload bytes) followed by the segment's own
    /// serialized payload. The start row pins each segment to its position
    /// in the global id space, so a file whose segment records were
    /// reordered fails validation instead of silently remapping ids. The
    /// store frames this as an `OPDR` version-3 file
    /// ([`crate::data::store::write_index`]).
    fn write_to(&self, w: &mut dyn Write) -> Result<()> {
        self.write_impl(w, None)
    }

    fn write_cold(&self, w: &mut dyn Write, annex: &mut AnnexWriter) -> Result<()> {
        self.write_impl(w, Some(annex))
    }
}

impl ShardedIndex {
    /// Multi-segment serialization shared by the inline ([`AnnIndex::write_to`])
    /// and cold ([`AnnIndex::write_cold`]) paths: `u32` segment count, then
    /// per segment a header (`u32` kind tag, `u8` metric tag, `u64` n,
    /// `u64` dim, `u64` global start row, `u64` payload bytes) followed by
    /// the segment's own serialized payload. With an annex, each segment's
    /// full-precision rows externalize into the shared annex in segment
    /// (= global row) order.
    fn write_impl(&self, w: &mut dyn Write, mut annex: Option<&mut AnnexWriter>) -> Result<()> {
        io::write_u32(w, self.segments.len() as u32)?;
        for (s, seg) in self.segments.iter().enumerate() {
            let mut payload = Vec::new();
            match annex.as_deref_mut() {
                Some(a) => seg.write_cold(&mut payload, a)?,
                None => seg.write_to(&mut payload)?,
            }
            io::write_u32(w, seg.kind().tag())?;
            io::write_u8(w, io::metric_tag(seg.metric()))?;
            io::write_u64(w, seg.len() as u64)?;
            io::write_u64(w, seg.dim() as u64)?;
            io::write_u64(w, self.offsets[s] as u64)?;
            io::write_u64(w, payload.len() as u64)?;
            io::write_bytes(w, &payload)?;
        }
        Ok(())
    }

    /// Deserialize the multi-segment payload (inverse of
    /// [`AnnIndex::write_to`]); every per-shard header is validated against
    /// its decoded payload so a corrupt or reshuffled file fails loudly
    /// instead of serving wrong neighbors.
    pub(crate) fn read_from(r: &mut dyn Read) -> Result<ShardedIndex> {
        ShardedIndex::read_with(r, None)
    }

    /// [`ShardedIndex::read_from`] with an optional cold context (version-5
    /// files: segment payloads resolve external rows against the file's
    /// mapped annex).
    pub(crate) fn read_with(r: &mut dyn Read, cx: Option<&ColdContext>) -> Result<ShardedIndex> {
        let count = io::read_u32(r)? as usize;
        if count == 0 {
            return Err(OpdrError::data("sharded index: zero segment count"));
        }
        if count > MAX_SHARDS {
            return Err(OpdrError::data(format!(
                "sharded index: unreasonable segment count {count}"
            )));
        }
        let mut segments: Vec<Box<dyn AnnIndex>> = Vec::with_capacity(count);
        let mut next_start = 0usize;
        for s in 0..count {
            let header = |e: OpdrError| {
                OpdrError::data(format!("sharded index: shard {s} header truncated: {e}"))
            };
            let kind_tag = io::read_u32(r).map_err(header)?;
            let kind = IndexKind::from_tag(kind_tag).map_err(|_| {
                OpdrError::data(format!("sharded index: shard {s}: bad kind tag {kind_tag}"))
            })?;
            let metric_byte = io::read_u8(r).map_err(header)?;
            let metric = io::metric_from_tag(metric_byte)
                .map_err(|e| OpdrError::data(format!("sharded index: shard {s}: {e}")))?;
            let n = io::read_u64_usize(r).map_err(header)?;
            let dim = io::read_u64_usize(r).map_err(header)?;
            let start = io::read_u64_usize(r).map_err(header)?;
            if start != next_start {
                return Err(OpdrError::data(format!(
                    "sharded index: shard {s}: declared start row {start} != expected \
                     {next_start} (segment records out of order?)"
                )));
            }
            next_start = next_start
                .checked_add(n)
                .ok_or_else(|| OpdrError::data("sharded index: row count overflow"))?;
            let payload_len = io::read_u64_usize(r).map_err(header)?;
            if payload_len > io::MAX_ELEMS {
                return Err(OpdrError::data(format!(
                    "sharded index: shard {s}: unreasonable payload length {payload_len}"
                )));
            }
            let payload = io::read_bytes(r, payload_len)
                .map_err(|e| OpdrError::data(format!("sharded index: shard {s} truncated: {e}")))?;
            let mut slice = payload.as_slice();
            let seg = crate::index::read_index_payload_with(kind_tag, &mut slice, cx)
                .map_err(|e| OpdrError::data(format!("sharded index: shard {s}: {e}")))?;
            if !slice.is_empty() {
                return Err(OpdrError::data(format!(
                    "sharded index: shard {s}: {} unconsumed payload bytes \
                     (declared length does not match the segment)",
                    slice.len()
                )));
            }
            if seg.kind() != kind || seg.len() != n || seg.dim() != dim || seg.metric() != metric {
                return Err(OpdrError::data(format!(
                    "sharded index: shard {s}: payload does not match its header \
                     ({}x{} {} vs declared {n}x{dim} {})",
                    seg.len(),
                    seg.dim(),
                    seg.metric().name(),
                    metric.name()
                )));
            }
            segments.push(seg);
        }
        ShardedIndex::from_segments(segments)
    }
}

/// Build an index per `policy` over a shared data snapshot, fanning
/// whole-segment builds out to `pool` and delivering the finished index to
/// `done` from a collector thread. The caller — the coordinator's scheduler
/// thread — returns immediately (only cheap shape checks run on it; the
/// global-codebook bounds scan and the job dispatch happen on the collector
/// thread, which submits through a detached [`ThreadPool::handle`]) and
/// keeps serving searches while segments build; `done` runs on the
/// collector thread once every segment finished (or failed). When
/// partitioning yields a single segment the bare segment index is delivered
/// (no wrapper), preserving the unsharded format and search path. Must not
/// be called from a pool worker.
pub fn build_on_pool(
    data: Arc<Vec<f32>>,
    dim: usize,
    metric: Metric,
    policy: &IndexPolicy,
    seed: u64,
    pool: &ThreadPool,
    done: impl FnOnce(Result<Box<dyn AnnIndex>>) + Send + 'static,
) {
    if dim == 0 || data.len() % dim != 0 {
        done(Err(OpdrError::shape(format!(
            "index build: {} floats is not a multiple of dim {dim}",
            data.len()
        ))));
        return;
    }
    let n = data.len() / dim;
    if n == 0 {
        done(Err(OpdrError::data("index build: empty data")));
        return;
    }
    let ranges = shard_ranges(n, policy.shards, policy.shard_min_vectors);
    let expected = ranges.len();
    let submit = pool.handle();
    let policy = policy.clone();
    std::thread::Builder::new()
        .name("opdr-index-build".to_string())
        .spawn(move || {
            // Everything with real cost runs here, off the caller's thread:
            // the global-codebook bounds scan (O(n·dim) when enabled), the
            // per-segment job dispatch, and the collection of results.
            let leaf = match leaf_policy_with_bounds(data.as_slice(), dim, n, &policy) {
                Ok(leaf) => leaf,
                Err(e) => {
                    done(Err(e));
                    return;
                }
            };
            // One slot per segment job, so build workers never block on the
            // collector no matter when it drains.
            let (tx, rx) = sync_channel::<(usize, Result<Box<dyn AnnIndex>>)>(ranges.len());
            for (s, range) in ranges.into_iter().enumerate() {
                let data = Arc::clone(&data);
                let leaf = leaf.clone();
                let tx = tx.clone();
                submit.execute(move || {
                    let slice = &data[range.start * dim..range.end * dim];
                    let seed = shard_seed(seed, s);
                    let _ =
                        tx.send((s, crate::index::build_index(slice, dim, metric, &leaf, seed)));
                });
            }
            drop(tx);
            let mut parts: Vec<(usize, Result<Box<dyn AnnIndex>>)> = rx.iter().collect();
            if parts.len() != expected {
                done(Err(OpdrError::coordinator("index build: a segment build was dropped")));
                return;
            }
            parts.sort_by_key(|p| p.0);
            let mut segments = Vec::with_capacity(expected);
            let mut first_err: Option<OpdrError> = None;
            for (_, res) in parts {
                match res {
                    Ok(seg) => segments.push(seg),
                    Err(e) => {
                        first_err = Some(e);
                        break;
                    }
                }
            }
            if let Some(e) = first_err {
                done(Err(e));
                return;
            }
            if segments.len() == 1 {
                done(Ok(segments.pop().unwrap()));
                return;
            }
            done(
                ShardedIndex::from_segments(segments)
                    .map(|sharded| Box::new(sharded) as Box<dyn AnnIndex>),
            );
        })
        .expect("spawn index-build collector");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::IndexPolicy;
    use crate::util::Rng;

    fn exact_policy(shards: usize) -> IndexPolicy {
        IndexPolicy {
            kind: IndexKind::Exact,
            exact_threshold: 0,
            shards,
            shard_min_vectors: 1,
            ..Default::default()
        }
    }

    #[test]
    fn ranges_are_balanced_contiguous_and_min_bounded() {
        assert_eq!(shard_ranges(10, 3, 1), vec![0..4, 4..7, 7..10]);
        assert_eq!(shard_ranges(10, 1, 1), vec![0..10]);
        // shard_min_vectors caps the shard count.
        assert_eq!(shard_ranges(10, 8, 5), vec![0..5, 5..10]);
        assert_eq!(shard_ranges(10, 8, 100), vec![0..10]);
        // More shards than rows degrades to one row per shard.
        assert_eq!(shard_ranges(2, 5, 0), vec![0..1, 1..2]);
        // Total coverage, no gaps, for a spread of inputs.
        for n in [1usize, 7, 64, 1000] {
            for s in [1usize, 2, 3, 8] {
                let rs = shard_ranges(n, s, 1);
                assert_eq!(rs[0].start, 0);
                assert_eq!(rs.last().unwrap().end, n);
                for w in rs.windows(2) {
                    assert_eq!(w[0].end, w[1].start);
                    assert!(!w[0].is_empty());
                }
            }
        }
    }

    #[test]
    fn shard_zero_keeps_the_base_seed() {
        assert_eq!(shard_seed(42, 0), 42);
        assert_ne!(shard_seed(42, 1), shard_seed(42, 2));
    }

    #[test]
    fn sharded_exact_matches_unsharded_bitwise() {
        let mut rng = Rng::new(17);
        let dim = 6;
        let n = 53; // not divisible by the shard count
        let data = rng.normal_vec_f32(n * dim);
        let single =
            crate::index::build_index(&data, dim, Metric::SqEuclidean, &exact_policy(1), 3)
                .unwrap();
        let sharded =
            ShardedIndex::build(&data, dim, Metric::SqEuclidean, &exact_policy(4), 3).unwrap();
        assert_eq!(sharded.num_shards(), 4);
        assert_eq!(sharded.len(), n);
        assert_eq!(sharded.dim(), dim);
        for k in [1usize, 5, n, n + 10] {
            for _ in 0..4 {
                let q = rng.normal_vec_f32(dim);
                let a = single.search(&q, k).unwrap();
                let b = sharded.search(&q, k).unwrap();
                crate::testing::assert_same_neighbors(&a, &b);
            }
        }
    }

    #[test]
    fn pool_fanout_matches_serial_search_bitwise() {
        let mut rng = Rng::new(23);
        let dim = 5;
        let data = rng.normal_vec_f32(40 * dim);
        let sharded = ShardedIndex::build(&data, dim, Metric::Cosine, &exact_policy(3), 9).unwrap();
        let pool = ThreadPool::new(3);
        for _ in 0..5 {
            let q = rng.normal_vec_f32(dim);
            let a = sharded.search(&q, 7).unwrap();
            let b = sharded.search_on(&pool, &q, 7).unwrap();
            crate::testing::assert_same_neighbors(&a, &b);
        }
    }

    #[test]
    fn build_on_pool_matches_serial_build_bitwise() {
        let mut rng = Rng::new(31);
        let dim = 4;
        let data = Arc::new(rng.normal_vec_f32(30 * dim));
        let policy = IndexPolicy {
            kind: IndexKind::Hnsw,
            exact_threshold: 0,
            shards: 3,
            shard_min_vectors: 1,
            ..Default::default()
        };
        let serial = ShardedIndex::build(&data, dim, Metric::SqEuclidean, &policy, 5).unwrap();
        let pool = ThreadPool::new(2);
        let (tx, rx) = sync_channel(1);
        build_on_pool(Arc::clone(&data), dim, Metric::SqEuclidean, &policy, 5, &pool, move |r| {
            let _ = tx.send(r);
        });
        let built = rx.recv().unwrap().unwrap();
        assert!(built.as_sharded().is_some());
        for _ in 0..5 {
            let q = rng.normal_vec_f32(dim);
            let a = serial.search(&q, 6).unwrap();
            let b = built.search(&q, 6).unwrap();
            crate::testing::assert_same_neighbors(&a, &b);
        }
    }

    #[test]
    fn build_on_pool_single_segment_stays_unwrapped() {
        let mut rng = Rng::new(37);
        let dim = 4;
        let data = Arc::new(rng.normal_vec_f32(20 * dim));
        let pool = ThreadPool::new(2);
        let (tx, rx) = sync_channel(1);
        build_on_pool(
            Arc::clone(&data),
            dim,
            Metric::Euclidean,
            &exact_policy(1),
            1,
            &pool,
            move |r| {
                let _ = tx.send(r);
            },
        );
        let built = rx.recv().unwrap().unwrap();
        assert!(built.as_sharded().is_none());
        assert_eq!(built.kind(), IndexKind::Exact);

        // Errors surface through `done` too (empty data).
        let (tx, rx) = sync_channel(1);
        let empty = Arc::new(Vec::new());
        build_on_pool(empty, dim, Metric::Euclidean, &exact_policy(1), 1, &pool, move |r| {
            let _ = tx.send(r);
        });
        assert!(rx.recv().unwrap().is_err());
    }

    #[test]
    fn from_segments_validates_consistency() {
        let mut rng = Rng::new(41);
        let a = rng.normal_vec_f32(10 * 4);
        let b = rng.normal_vec_f32(10 * 5);
        let seg = |data: &[f32], dim: usize, metric: Metric| {
            crate::index::build_index(data, dim, metric, &exact_policy(1), 1).unwrap()
        };
        assert!(ShardedIndex::from_segments(vec![]).is_err());
        let e = ShardedIndex::from_segments(vec![
            seg(&a, 4, Metric::Euclidean),
            seg(&b, 5, Metric::Euclidean),
        ])
        .unwrap_err()
        .to_string();
        assert!(e.contains("dim"), "{e}");
        let e = ShardedIndex::from_segments(vec![
            seg(&a, 4, Metric::Euclidean),
            seg(&a, 4, Metric::Cosine),
        ])
        .unwrap_err()
        .to_string();
        assert!(e.contains("metric"), "{e}");
        // Nesting is rejected.
        let inner = ShardedIndex::build(&a, 4, Metric::Euclidean, &exact_policy(2), 1).unwrap();
        let inner: Box<dyn AnnIndex> = Box::new(inner);
        let e = ShardedIndex::from_segments(vec![inner, seg(&a, 4, Metric::Euclidean)])
            .unwrap_err()
            .to_string();
        assert!(e.contains("nested"), "{e}");
    }

    #[test]
    fn matches_data_checks_every_segment_slice() {
        let mut rng = Rng::new(43);
        let dim = 4;
        let data = rng.normal_vec_f32(24 * dim);
        let sharded =
            ShardedIndex::build(&data, dim, Metric::SqEuclidean, &exact_policy(3), 2).unwrap();
        assert!(sharded.matches_data(&data));
        let mut other = data.clone();
        // Flip one value in the *last* shard's slice.
        let last = other.len() - 1;
        other[last] += 1.0;
        assert!(!sharded.matches_data(&other));
        assert!(!sharded.matches_data(&data[..data.len() - dim]));
    }

    #[test]
    fn payload_roundtrip_preserves_results_bitwise() {
        let mut rng = Rng::new(47);
        let dim = 6;
        let data = rng.normal_vec_f32(36 * dim);
        for (kind, sq8) in [
            (IndexKind::Exact, false),
            (IndexKind::Exact, true),
            (IndexKind::Ivf, false),
            (IndexKind::Hnsw, true),
        ] {
            let policy = IndexPolicy {
                kind,
                sq8,
                ivf_nlist: 4,
                ivf_nprobe: 4,
                ..exact_policy(3)
            };
            let idx = ShardedIndex::build(&data, dim, Metric::SqEuclidean, &policy, 11).unwrap();
            let mut buf = Vec::new();
            idx.write_to(&mut buf).unwrap();
            let back = ShardedIndex::read_from(&mut buf.as_slice()).unwrap();
            assert_eq!(back.num_shards(), idx.num_shards());
            assert_eq!(back.quantized(), sq8);
            let q = rng.normal_vec_f32(dim);
            let a = idx.search(&q, 8).unwrap();
            let b = back.search(&q, 8).unwrap();
            crate::testing::assert_same_neighbors(&a, &b);
        }
    }

    /// Per-shard record layout after the u32 count: u32 kind | u8 metric |
    /// u64 n | u64 dim | u64 start row | u64 payload_len | payload
    /// (37 header bytes), used by the file-surgery tests below.
    const SHARD_HEADER_BYTES: usize = 37;

    #[test]
    fn inflated_payload_length_rejected() {
        // An inflated payload length whose extra bytes the segment decoder
        // doesn't consume must be rejected, not silently absorbed.
        let mut rng = Rng::new(59);
        let dim = 4;
        let data = rng.normal_vec_f32(20 * dim);
        let sharded =
            ShardedIndex::build(&data, dim, Metric::SqEuclidean, &exact_policy(2), 1).unwrap();
        let mut buf = Vec::new();
        sharded.write_to(&mut buf).unwrap();
        // The payload_len field is the last 8 header bytes of each record.
        let len1_off = 4 + SHARD_HEADER_BYTES - 8;
        let len1 = u64::from_le_bytes(buf[len1_off..len1_off + 8].try_into().unwrap()) as usize;
        let len2_off = 4 + SHARD_HEADER_BYTES + len1 + SHARD_HEADER_BYTES - 8;
        let len2 = u64::from_le_bytes(buf[len2_off..len2_off + 8].try_into().unwrap());
        buf[len2_off..len2_off + 8].copy_from_slice(&(len2 + 4).to_le_bytes());
        buf.extend_from_slice(&[0xAB; 4]);
        let e = ShardedIndex::read_from(&mut buf.as_slice()).unwrap_err().to_string();
        assert!(e.contains("unconsumed payload"), "{e}");
    }

    #[test]
    fn reordered_segment_records_rejected() {
        // Two equal-shape shard records swapped in place still satisfy every
        // per-record check; the global start row pins each record to its id
        // range so the swap fails loudly instead of remapping ids.
        let mut rng = Rng::new(61);
        let dim = 4;
        let data = rng.normal_vec_f32(20 * dim); // 2 shards of 10 rows
        let sharded =
            ShardedIndex::build(&data, dim, Metric::SqEuclidean, &exact_policy(2), 1).unwrap();
        let mut buf = Vec::new();
        sharded.write_to(&mut buf).unwrap();
        let record = (buf.len() - 4) / 2; // equal flat segments → equal records
        let mut swapped = buf[..4].to_vec();
        swapped.extend_from_slice(&buf[4 + record..]);
        swapped.extend_from_slice(&buf[4..4 + record]);
        assert_eq!(swapped.len(), buf.len());
        let e = ShardedIndex::read_from(&mut swapped.as_slice()).unwrap_err().to_string();
        assert!(e.contains("start row"), "{e}");
        // The untouched buffer still loads.
        assert!(ShardedIndex::read_from(&mut buf.as_slice()).is_ok());
    }

    #[test]
    fn query_dim_checked() {
        let mut rng = Rng::new(53);
        let data = rng.normal_vec_f32(12 * 4);
        let sharded =
            ShardedIndex::build(&data, 4, Metric::Euclidean, &exact_policy(2), 1).unwrap();
        let e = sharded.search(&[0.0; 3], 2).unwrap_err().to_string();
        assert!(e.contains("query dim 3"), "{e}");
    }

    #[test]
    fn global_sq8_codebook_makes_sharded_equal_unsharded_bitwise() {
        let mut rng = Rng::new(67);
        let dim = 5;
        let n = 48;
        let data = rng.normal_vec_f32(n * dim);
        let policy = IndexPolicy {
            sq8: true,
            sq8_global_codebook: true,
            ..exact_policy(4)
        };
        let unsharded = crate::index::build_index(
            &data,
            dim,
            Metric::SqEuclidean,
            &IndexPolicy { shards: 1, ..policy.clone() },
            3,
        )
        .unwrap();
        let sharded = ShardedIndex::build(&data, dim, Metric::SqEuclidean, &policy, 3).unwrap();
        assert!(sharded.quantized());
        for _ in 0..6 {
            let q = rng.normal_vec_f32(dim);
            let a = unsharded.search(&q, 7).unwrap();
            let b = sharded.search(&q, 7).unwrap();
            crate::testing::assert_same_neighbors(&a, &b);
        }
        // Segment-local codebooks (the default) generally diverge in the
        // last ulp across shard boundaries, which is exactly why the global
        // option exists; the merge itself stays order-exact either way.
        let local = ShardedIndex::build(
            &data,
            dim,
            Metric::SqEuclidean,
            &IndexPolicy { sq8_global_codebook: false, ..policy },
            3,
        )
        .unwrap();
        assert_eq!(local.num_shards(), 4);
    }

    #[test]
    fn pq_segments_roundtrip_and_rerank_exactly_at_full_depth() {
        let mut rng = Rng::new(71);
        let dim = 6;
        let n = 42;
        let data = rng.normal_vec_f32(n * dim);
        let policy = IndexPolicy {
            pq: true,
            rerank_depth: n,
            ..exact_policy(3)
        };
        let sharded = ShardedIndex::build(&data, dim, Metric::SqEuclidean, &policy, 5).unwrap();
        assert!(sharded.quantized());
        assert_eq!(sharded.storage_name(), "pq");
        assert_eq!(sharded.cold_bytes(), n * dim * 4);
        // Exhaustive rerank depth: bit-identical to the unsharded flat scan.
        let flat = crate::index::ExactIndex::build(
            &data,
            dim,
            Metric::SqEuclidean,
            &crate::index::StorageSpec::flat(),
            5,
        )
        .unwrap();
        for _ in 0..6 {
            let q = rng.normal_vec_f32(dim);
            let a = flat.search(&q, 9).unwrap();
            let b = sharded.search(&q, 9).unwrap();
            crate::testing::assert_same_neighbors(&a, &b);
        }
        // And the multi-segment payload round-trips bit-identically.
        let mut buf = Vec::new();
        sharded.write_to(&mut buf).unwrap();
        let back = ShardedIndex::read_from(&mut buf.as_slice()).unwrap();
        let q = rng.normal_vec_f32(dim);
        crate::testing::assert_same_neighbors(
            &sharded.search(&q, 8).unwrap(),
            &back.search(&q, 8).unwrap(),
        );
    }
}
