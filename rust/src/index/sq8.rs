//! SQ8 scalar-quantized vector storage.
//!
//! Each dimension gets a per-dimension affine codebook `(lo, step)` trained
//! from the data's min/max; values are stored as one byte each:
//! `code = round((x − lo) / step)` clamped to `[0, 255]`, decoded as
//! `lo + code·step`. Distances are *asymmetric*: the query stays full
//! precision and only the stored side is decoded, so the quantization error
//! enters each comparison once (the FAISS `SQ8` convention).
//!
//! The payload is 4× smaller than flat f32 plus `2·dim` f32 of codebook —
//! the serving-copy shrink the index subsystem composes under IVF and HNSW.
//!
//! Codebooks are either trained on the encoded slice itself
//! ([`Sq8Storage::train`], the FAISS/Lucene segment-local convention) or
//! supplied as pre-trained global bounds ([`Sq8Bounds`] +
//! [`Sq8Storage::encode_with`]): the sharded builder trains one
//! [`Sq8Bounds`] over the *whole* collection when
//! `[serve] sq8_global_codebook` is set, so every segment decodes through
//! identical codebooks and quantized sharded results are bit-identical to
//! the unsharded quantized index at exhaustive parameters (machine-checked
//! in `tests/props.rs`).

use crate::error::{OpdrError, Result};
use crate::index::io;
use std::io::{Read, Write};

/// Pre-trained per-dimension quantization bounds, shareable across segments
/// (the global-codebook option of the sharded builder).
#[derive(Debug, Clone, PartialEq)]
pub struct Sq8Bounds {
    /// Per-dimension lower bound.
    lo: Vec<f32>,
    /// Per-dimension step ((max − min) / 255; 0 for constant dims).
    step: Vec<f32>,
}

impl Sq8Bounds {
    /// Train bounds from row-major `n × dim` data (min/max per dimension).
    pub fn train(data: &[f32], dim: usize) -> Result<Sq8Bounds> {
        if dim == 0 || data.len() % dim != 0 {
            return Err(OpdrError::shape("sq8 bounds: bad data shape"));
        }
        let n = data.len() / dim;
        if n == 0 {
            return Err(OpdrError::data("sq8 bounds: empty data"));
        }
        if data.iter().any(|x| !x.is_finite()) {
            return Err(OpdrError::numeric("sq8 bounds: non-finite input"));
        }
        let mut lo = vec![f32::INFINITY; dim];
        let mut hi = vec![f32::NEG_INFINITY; dim];
        for row in 0..n {
            for d in 0..dim {
                let x = data[row * dim + d];
                lo[d] = lo[d].min(x);
                hi[d] = hi[d].max(x);
            }
        }
        let step: Vec<f32> = (0..dim).map(|d| (hi[d] - lo[d]) / 255.0).collect();
        Ok(Sq8Bounds { lo, step })
    }

    /// Dimensionality these bounds were trained for.
    pub fn dim(&self) -> usize {
        self.lo.len()
    }
}

/// SQ8-encoded vectors with per-dimension min/step codebooks.
#[derive(Debug, Clone, PartialEq)]
pub struct Sq8Storage {
    dim: usize,
    /// Per-dimension lower bound of the quantization range.
    lo: Vec<f32>,
    /// Per-dimension quantization step ((max − min) / 255; 0 for constant dims).
    step: Vec<f32>,
    /// Row-major `n × dim` codes.
    codes: Vec<u8>,
}

impl Sq8Storage {
    /// Train codebooks on `data` (row-major `n × dim`) and encode every row.
    /// Exactly [`Sq8Storage::encode_with`] over [`Sq8Bounds::train`]ed
    /// bounds, so a single-segment "global codebook" build is bit-identical
    /// to the plain segment-local one.
    pub fn train(data: &[f32], dim: usize) -> Result<Sq8Storage> {
        let bounds = Sq8Bounds::train(data, dim)?;
        Sq8Storage::encode_with(&bounds, data, dim)
    }

    /// Encode `data` against pre-trained `bounds` (values outside the
    /// trained range clamp to the nearest code). The sharded builder feeds
    /// every segment the same collection-wide bounds here when the global
    /// codebook option is on.
    pub fn encode_with(bounds: &Sq8Bounds, data: &[f32], dim: usize) -> Result<Sq8Storage> {
        if dim == 0 || data.len() % dim != 0 {
            return Err(OpdrError::shape("sq8: bad data shape"));
        }
        if bounds.dim() != dim {
            return Err(OpdrError::shape(format!(
                "sq8: bounds dim {} != data dim {dim}",
                bounds.dim()
            )));
        }
        let n = data.len() / dim;
        if n == 0 {
            return Err(OpdrError::data("sq8: empty data"));
        }
        if data.iter().any(|x| !x.is_finite()) {
            return Err(OpdrError::numeric("sq8: non-finite input"));
        }
        let (lo, step) = (&bounds.lo, &bounds.step);
        let mut codes = Vec::with_capacity(n * dim);
        for row in 0..n {
            for d in 0..dim {
                let x = data[row * dim + d];
                let code = if step[d] > 0.0 {
                    ((x - lo[d]) / step[d]).round().clamp(0.0, 255.0) as u8
                } else {
                    0
                };
                codes.push(code);
            }
        }
        Ok(Sq8Storage { dim, lo: lo.clone(), step: step.clone(), codes })
    }

    /// Number of encoded vectors.
    pub fn len(&self) -> usize {
        self.codes.len() / self.dim
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.codes.is_empty()
    }

    /// Vector dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Decode vector `id` into `out` (must be `dim` long).
    #[inline]
    pub fn decode_into(&self, id: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim);
        let row = &self.codes[id * self.dim..(id + 1) * self.dim];
        for d in 0..self.dim {
            out[d] = self.lo[d] + row[d] as f32 * self.step[d];
        }
    }

    /// Decode vector `id` into a fresh Vec.
    pub fn reconstruct(&self, id: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim];
        self.decode_into(id, &mut out);
        out
    }

    /// Worst-case absolute reconstruction error for dimension `d`
    /// (half a quantization step).
    pub fn max_error(&self, d: usize) -> f32 {
        self.step[d] * 0.5
    }

    /// Resident bytes (codes + codebooks).
    pub fn memory_bytes(&self) -> usize {
        self.codes.len() + (self.lo.len() + self.step.len()) * std::mem::size_of::<f32>()
    }

    /// Serialize.
    pub(crate) fn write_to(&self, w: &mut dyn Write) -> Result<()> {
        io::write_u64(w, self.len() as u64)?;
        io::write_u64(w, self.dim as u64)?;
        io::write_f32s(w, &self.lo)?;
        io::write_f32s(w, &self.step)?;
        io::write_bytes(w, &self.codes)
    }

    /// Deserialize (inverse of [`Sq8Storage::write_to`]).
    pub(crate) fn read_from(r: &mut dyn Read) -> Result<Sq8Storage> {
        let n = io::read_u64_usize(r)?;
        let dim = io::read_u64_usize(r)?;
        if dim == 0 {
            return Err(OpdrError::data("sq8: dim is zero"));
        }
        let count = io::checked_count(n, dim)?;
        let lo = io::read_f32s(r, dim)?;
        let step = io::read_f32s(r, dim)?;
        if lo.iter().any(|x| !x.is_finite())
            || step.iter().any(|&s| s < 0.0 || !s.is_finite())
        {
            return Err(OpdrError::data("sq8: corrupt codebook"));
        }
        let codes = io::read_bytes(r, count)?;
        Ok(Sq8Storage { dim, lo, step, codes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn reconstruction_error_within_half_step() {
        let mut rng = Rng::new(3);
        let dim = 5;
        let n = 40;
        let data: Vec<f32> = (0..n * dim).map(|_| rng.uniform_range(-4.0, 4.0) as f32).collect();
        let s = Sq8Storage::train(&data, dim).unwrap();
        assert_eq!(s.len(), n);
        for id in 0..n {
            let rec = s.reconstruct(id);
            for d in 0..dim {
                let err = (rec[d] - data[id * dim + d]).abs();
                // Half a step plus float slack.
                assert!(err <= s.max_error(d) + 1e-5, "id {id} dim {d}: err {err}");
            }
        }
    }

    #[test]
    fn constant_dimension_is_lossless() {
        let data = vec![
            7.0f32, 1.0, //
            7.0, 2.0, //
            7.0, 3.0,
        ];
        let s = Sq8Storage::train(&data, 2).unwrap();
        for id in 0..3 {
            assert_eq!(s.reconstruct(id)[0], 7.0);
        }
    }

    #[test]
    fn range_extremes_nearly_exact() {
        // The range minimum decodes exactly (code 0); the maximum decodes to
        // lo + 255·step, which may differ from hi by float rounding only.
        let data = vec![-2.0f32, 10.0, 2.0, 20.0];
        let s = Sq8Storage::train(&data, 2).unwrap();
        assert_eq!(s.reconstruct(0), vec![-2.0, 10.0]);
        let top = s.reconstruct(1);
        assert!((top[0] - 2.0).abs() < 1e-4 && (top[1] - 20.0).abs() < 1e-4, "{top:?}");
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(Sq8Storage::train(&[], 4).is_err());
        assert!(Sq8Storage::train(&[1.0; 7], 4).is_err());
        assert!(Sq8Storage::train(&[1.0, f32::NAN], 2).is_err());
    }

    #[test]
    fn roundtrip_bit_identical() {
        let mut rng = Rng::new(9);
        let data = rng.normal_vec_f32(25 * 8);
        let s = Sq8Storage::train(&data, 8).unwrap();
        let mut buf = Vec::new();
        s.write_to(&mut buf).unwrap();
        let back = Sq8Storage::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn corrupt_codebook_rejected() {
        let data = vec![0.0f32, 1.0, 2.0, 3.0];
        let s = Sq8Storage::train(&data, 2).unwrap();
        let mut buf = Vec::new();
        s.write_to(&mut buf).unwrap();
        // Flip a step value to NaN: bytes 16.. hold lo (2×f32) then step.
        let mut bad = buf.clone();
        let step_off = 16 + 8;
        bad[step_off..step_off + 4].copy_from_slice(&f32::NAN.to_le_bytes());
        assert!(Sq8Storage::read_from(&mut bad.as_slice()).is_err());
        // A non-finite lo must be rejected too (it would silently NaN every
        // decoded distance and searches would return empty).
        let mut bad = buf.clone();
        bad[16..20].copy_from_slice(&f32::INFINITY.to_le_bytes());
        assert!(Sq8Storage::read_from(&mut bad.as_slice()).is_err());
    }

    #[test]
    fn global_bounds_encode_matches_local_train_bitwise() {
        let mut rng = Rng::new(21);
        let dim = 6;
        let data = rng.normal_vec_f32(30 * dim);
        let local = Sq8Storage::train(&data, dim).unwrap();
        let bounds = Sq8Bounds::train(&data, dim).unwrap();
        let global = Sq8Storage::encode_with(&bounds, &data, dim).unwrap();
        assert_eq!(local, global);
        // Encoding a slice with whole-collection bounds: decoded values stay
        // inside the global range even when the slice's own range is tighter.
        let slice = &data[..10 * dim];
        let seg = Sq8Storage::encode_with(&bounds, slice, dim).unwrap();
        assert_eq!(seg.len(), 10);
        let mut dec = vec![0.0f32; dim];
        seg.decode_into(3, &mut dec);
        assert!(dec.iter().all(|x| x.is_finite()));
        // Out-of-range values (possible when bounds come from other data)
        // clamp instead of wrapping.
        let zeros = vec![0.0f32; dim * 2];
        let tight = Sq8Bounds::train(&zeros, dim).unwrap();
        let wild: Vec<f32> = (0..dim).map(|i| i as f32 * 100.0).collect();
        let clamped = Sq8Storage::encode_with(&tight, &wild, dim).unwrap();
        assert!(clamped.reconstruct(0).iter().all(|x| x.is_finite()));
    }

    #[test]
    fn bounds_validation() {
        assert!(Sq8Bounds::train(&[], 3).is_err());
        assert!(Sq8Bounds::train(&[1.0, f32::NAN], 2).is_err());
        let b = Sq8Bounds::train(&[1.0, 2.0, 3.0, 4.0], 2).unwrap();
        assert_eq!(b.dim(), 2);
        assert!(Sq8Storage::encode_with(&b, &[1.0, 2.0, 3.0], 3).is_err());
    }

    #[test]
    fn memory_is_about_a_quarter() {
        let mut rng = Rng::new(1);
        let dim = 64;
        let data = rng.normal_vec_f32(100 * dim);
        let s = Sq8Storage::train(&data, dim).unwrap();
        let flat_bytes = data.len() * 4;
        assert!(s.memory_bytes() < flat_bytes / 3, "{} vs {flat_bytes}", s.memory_bytes());
    }
}
