//! Exact flat index: exhaustive scan over a [`VectorStore`].
//!
//! The ground-truth substrate of the subsystem, and the automatic choice for
//! small collections where ANN structures cost more than they save. With SQ8
//! storage it becomes "exact over quantized vectors" — the same scan order
//! and tie-breaking, 4× less resident memory. With PQ storage the scan is an
//! ADC table sweep followed by the full-precision rerank stage: at
//! exhaustive `rerank_depth` this is bit-identical to the flat scan.

use crate::data::mapped::{AnnexWriter, ColdContext};
use crate::error::{OpdrError, Result};
use crate::index::{io, pq, AnnIndex, IndexKind, StorageSpec, VectorStore};
use crate::knn::topk::top_k_smallest;
use crate::knn::Neighbor;
use crate::metrics::Metric;
use crate::telemetry::SearchTrace;
use crate::util::timer::Stopwatch;
use std::io::{Read, Write};

/// Exhaustive-scan index.
#[derive(Debug, Clone)]
pub struct ExactIndex {
    metric: Metric,
    store: VectorStore,
}

impl ExactIndex {
    /// Build over row-major `data` with the given storage (flat/SQ8/PQ).
    pub fn build(
        data: &[f32],
        dim: usize,
        metric: Metric,
        storage: &StorageSpec,
        seed: u64,
    ) -> Result<ExactIndex> {
        let store = VectorStore::build(data, dim, storage, seed)?;
        if store.is_empty() {
            return Err(OpdrError::data("exact index: empty data"));
        }
        Ok(ExactIndex { metric, store })
    }

    /// Deserialize (payload written by [`AnnIndex::write_to`]).
    pub(crate) fn read_from(r: &mut dyn Read) -> Result<ExactIndex> {
        ExactIndex::read_with(r, None)
    }

    /// [`ExactIndex::read_from`] with an optional cold context (version-5
    /// files: external payloads resolve against the file's mapped annex).
    pub(crate) fn read_with(r: &mut dyn Read, cx: Option<&ColdContext>) -> Result<ExactIndex> {
        let metric = io::metric_from_tag(io::read_u8(r)?)?;
        let store = VectorStore::read_with(r, cx)?;
        Ok(ExactIndex { metric, store })
    }

    fn write_impl(&self, w: &mut dyn Write, annex: Option<&mut AnnexWriter>) -> Result<()> {
        io::write_u8(w, io::metric_tag(self.metric))?;
        self.store.write_with(w, annex)
    }

    fn search_impl(
        &self,
        query: &[f32],
        k: usize,
        trace: Option<&SearchTrace>,
    ) -> Result<Vec<Neighbor>> {
        if query.len() != self.dim() {
            return Err(OpdrError::shape(format!(
                "exact search: query dim {} != index dim {}",
                query.len(),
                self.dim()
            )));
        }
        let n = self.len();
        if let Some(p) = self.store.as_pq() {
            // Two-stage: ADC table sweep over all ids, then full-precision
            // rerank of the top `rerank_depth` candidates.
            return pq::two_stage_search_traced(p, self.metric, query, 0..n, k, trace);
        }
        let sw = Stopwatch::start();
        let mut scratch = Vec::new();
        let dists: Vec<f32> =
            (0..n).map(|id| self.store.distance(self.metric, query, id, &mut scratch)).collect();
        let out = top_k_smallest(&dists, k)
            .into_iter()
            .map(|(index, distance)| Neighbor { index, distance })
            .collect();
        if let Some(t) = trace {
            t.scan.record(sw.elapsed());
        }
        Ok(out)
    }
}

impl AnnIndex for ExactIndex {
    fn kind(&self) -> IndexKind {
        IndexKind::Exact
    }

    fn len(&self) -> usize {
        self.store.len()
    }

    fn dim(&self) -> usize {
        self.store.dim()
    }

    fn metric(&self) -> Metric {
        self.metric
    }

    fn quantized(&self) -> bool {
        self.store.quantized()
    }

    fn storage_name(&self) -> &'static str {
        self.store.name()
    }

    fn memory_bytes(&self) -> usize {
        self.store.memory_bytes()
    }

    fn cold_bytes(&self) -> usize {
        self.store.cold_bytes()
    }

    fn mapped_bytes(&self) -> usize {
        self.store.mapped_bytes()
    }

    fn matches_data(&self, data: &[f32]) -> bool {
        self.store.matches(data)
    }

    fn search(&self, query: &[f32], k: usize) -> Result<Vec<Neighbor>> {
        self.search_impl(query, k, None)
    }

    fn search_traced(&self, query: &[f32], k: usize, trace: &SearchTrace) -> Result<Vec<Neighbor>> {
        self.search_impl(query, k, Some(trace))
    }

    fn write_to(&self, w: &mut dyn Write) -> Result<()> {
        self.write_impl(w, None)
    }

    fn write_cold(&self, w: &mut dyn Write, annex: &mut AnnexWriter) -> Result<()> {
        self.write_impl(w, Some(annex))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn matches_brute_force_exactly() {
        let mut rng = Rng::new(5);
        let dim = 8;
        let data = rng.normal_vec_f32(60 * dim);
        let idx =
            ExactIndex::build(&data, dim, Metric::SqEuclidean, &StorageSpec::flat(), 1).unwrap();
        for _ in 0..5 {
            let q = rng.normal_vec_f32(dim);
            let got = idx.search(&q, 7).unwrap();
            let want = crate::knn::knn_indices(&q, &data, dim, 7, Metric::SqEuclidean).unwrap();
            assert_eq!(
                got.iter().map(|n| n.index).collect::<Vec<_>>(),
                want.iter().map(|n| n.index).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn sq8_variant_high_recall() {
        let mut rng = Rng::new(6);
        let dim = 16;
        let data = rng.normal_vec_f32(200 * dim);
        let idx =
            ExactIndex::build(&data, dim, Metric::SqEuclidean, &StorageSpec::sq8(), 1).unwrap();
        assert!(idx.quantized());
        assert_eq!(idx.storage_name(), "sq8");
        let mut hits = 0;
        let nq = 10;
        let k = 10;
        for qi in 0..nq {
            let q = data[qi * dim..(qi + 1) * dim].to_vec();
            let got: std::collections::HashSet<usize> =
                idx.search(&q, k).unwrap().iter().map(|n| n.index).collect();
            let want = crate::knn::knn_indices(&q, &data, dim, k, Metric::SqEuclidean).unwrap();
            hits += want.iter().filter(|n| got.contains(&n.index)).count();
        }
        let recall = hits as f64 / (nq * k) as f64;
        assert!(recall >= 0.8, "sq8 exact recall {recall}");
    }

    #[test]
    fn dim_mismatch_rejected() {
        let data = vec![0.0f32; 12];
        let idx = ExactIndex::build(&data, 4, Metric::Euclidean, &StorageSpec::flat(), 1).unwrap();
        let e = idx.search(&[0.0; 3], 2).unwrap_err().to_string();
        assert!(e.contains("query dim 3"), "{e}");
    }

    #[test]
    fn roundtrip_preserves_results_bitwise() {
        let mut rng = Rng::new(8);
        let dim = 6;
        let data = rng.normal_vec_f32(40 * dim);
        for spec in [StorageSpec::flat(), StorageSpec::sq8(), StorageSpec::pq()] {
            let idx = ExactIndex::build(&data, dim, Metric::Cosine, &spec, 2).unwrap();
            let mut buf = Vec::new();
            idx.write_to(&mut buf).unwrap();
            let back = ExactIndex::read_from(&mut buf.as_slice()).unwrap();
            let q = rng.normal_vec_f32(dim);
            let a = idx.search(&q, 5).unwrap();
            let b = back.search(&q, 5).unwrap();
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.index, y.index);
                assert_eq!(x.distance.to_bits(), y.distance.to_bits());
            }
        }
    }

    #[test]
    fn pq_at_exhaustive_rerank_depth_is_bitwise_flat_exact() {
        use crate::index::PqParams;
        let mut rng = Rng::new(12);
        let dim = 8;
        let n = 70;
        let data = rng.normal_vec_f32(n * dim);
        let flat =
            ExactIndex::build(&data, dim, Metric::SqEuclidean, &StorageSpec::flat(), 3).unwrap();
        for opq in [false, true] {
            let spec =
                StorageSpec::pq_with(PqParams { opq, rerank_depth: n, ..Default::default() });
            let pq = ExactIndex::build(&data, dim, Metric::SqEuclidean, &spec, 3).unwrap();
            assert_eq!(pq.storage_name(), "pq");
            assert!(pq.cold_bytes() > 0);
            for _ in 0..5 {
                let q = rng.normal_vec_f32(dim);
                let a = flat.search(&q, 9).unwrap();
                let b = pq.search(&q, 9).unwrap();
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(&b) {
                    assert_eq!(x.index, y.index, "opq={opq}");
                    assert_eq!(x.distance.to_bits(), y.distance.to_bits(), "opq={opq}");
                }
            }
        }
    }
}
