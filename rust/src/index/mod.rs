//! Pluggable ANN index subsystem.
//!
//! The paper positions OPDR as a *complement* to vector indexes: reduce the
//! dimension first, then index. This module is the "then index" half — a
//! common [`AnnIndex`] trait over interchangeable search substrates:
//!
//! * [`exact`] — flat exhaustive scan (the ground-truth substrate, and the
//!   automatic choice below [`IndexPolicy::exact_threshold`]);
//! * [`ivf`] — IVF-Flat inverted lists over a k-means coarse quantizer
//!   (FAISS-style), generalizing [`crate::knn::IvfFlatIndex`] to quantized
//!   storage;
//! * [`hnsw`] — a deterministic Hierarchical Navigable Small World graph
//!   (layered greedy + beam search, seeded level assignment);
//! * [`sq8`] — per-dimension scalar (8-bit) quantized storage with
//!   asymmetric distance, composable under every substrate above to shrink
//!   the serving copy ~4× (optionally with a collection-wide global
//!   codebook shared across shards);
//! * [`pq`] — product-quantized storage (optionally OPQ-rotated) with ADC
//!   lookup-table scans and an order-exact full-precision rerank stage,
//!   composable under every substrate for a ~16× hot-copy shrink;
//! * [`shard`] — segment sharding over any of the above: a collection is
//!   split into `S` contiguous segments ([`IndexPolicy::shards`] /
//!   [`IndexPolicy::shard_min_vectors`]), segments build in parallel on the
//!   coordinator's worker pool, and queries fan out per shard and merge
//!   through the bounded top-k heap with an order-exact (not merely
//!   recall-equal) guarantee;
//! * [`delta`] — incremental ingest over any of the above: writes are
//!   absorbed into a flat exact delta segment behind the immutable main
//!   index ([`DeltaIndex`]), searches fan out over `{main, delta}` and
//!   merge order-exactly, and a background compaction folds the delta back
//!   into the main index behind the coordinator's generation-guarded swap.
//!
//! Substrate × storage composition is expressed by [`StorageSpec`]: every
//! substrate builds over a [`VectorStore`] whose quantizer is flat f32, SQ8
//! or PQ, so the full matrix {exact, IVF, HNSW} × {f32, SQ8, PQ}
//! (± sharding) is available from one [`IndexPolicy`]. Orthogonally, the
//! spec's [`ColdTier`] knob decides where full-precision rows live: in RAM
//! (the default) or spilled to an mmap'd on-disk vector file
//! ([`crate::data::mapped`]), so PQ rerank tiers and flat payloads can
//! serve zero-copy from disk for collections larger than RAM.
//!
//! Indexes serialize through [`AnnIndex::write_to`] into the versioned
//! `OPDR` binary format (see [`crate::data::store`]): single-segment indexes
//! as version-2 segments, sharded indexes as version-3 multi-segment files
//! with validated per-shard headers, and delta-augmented indexes as
//! version-4 files carrying the main payload plus a delta record.
//! [`AnnIndex::write_cold`] additionally serializes into the version-5 cold
//! layout, externalizing full-precision payloads into a 64-byte-aligned
//! annex that loads back mapped-in-place. All builds are deterministic from
//! the seed: identical data + policy + seed ⇒ bit-identical indexes, and
//! the cold tier never changes search results (bit-identical to the RAM
//! tier — machine-checked in `tests/props.rs`).

pub mod delta;
pub mod exact;
pub mod hnsw;
pub mod ivf;
pub mod pq;
pub mod shard;
pub mod sq8;

pub use delta::DeltaIndex;
pub use exact::ExactIndex;
pub use hnsw::{HnswIndex, HnswParams};
pub use ivf::{IvfIndex, IvfParams};
pub use pq::{AdcTable, PqParams, PqStorage};
pub use shard::ShardedIndex;
pub use sq8::{Sq8Bounds, Sq8Storage};

use crate::config::IndexPolicy;
use crate::data::mapped::{AnnexWriter, ColdContext, RowBlock};
use crate::error::{OpdrError, Result};
use crate::knn::Neighbor;
use crate::metrics::Metric;
use crate::telemetry::SearchTrace;
use crate::util::timer::Stopwatch;
use std::io::{Read, Write};
use std::path::PathBuf;
use std::sync::Arc;

/// Which search structure an index uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IndexKind {
    /// Exhaustive flat scan (exact).
    Exact,
    /// IVF-Flat inverted lists.
    Ivf,
    /// HNSW layered graph.
    Hnsw,
}

impl IndexKind {
    /// Parse from a config / CLI string.
    pub fn parse(s: &str) -> Option<IndexKind> {
        match s.to_ascii_lowercase().as_str() {
            "exact" | "flat" | "brute" => Some(IndexKind::Exact),
            "ivf" | "ivf-flat" | "ivfflat" => Some(IndexKind::Ivf),
            "hnsw" => Some(IndexKind::Hnsw),
            _ => None,
        }
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            IndexKind::Exact => "exact",
            IndexKind::Ivf => "ivf",
            IndexKind::Hnsw => "hnsw",
        }
    }

    /// Stable on-disk tag.
    pub(crate) fn tag(&self) -> u32 {
        match self {
            IndexKind::Exact => 0,
            IndexKind::Ivf => 1,
            IndexKind::Hnsw => 2,
        }
    }

    /// Inverse of [`IndexKind::tag`].
    pub(crate) fn from_tag(tag: u32) -> Result<IndexKind> {
        match tag {
            0 => Ok(IndexKind::Exact),
            1 => Ok(IndexKind::Ivf),
            2 => Ok(IndexKind::Hnsw),
            other => Err(OpdrError::data(format!("index: unknown kind tag {other}"))),
        }
    }
}

/// Where a store's full-precision rows live: resident in RAM (the
/// default), or spilled to an mmap'd on-disk vector file under the given
/// directory ([`crate::data::mapped`]) so PQ rerank tiers and flat
/// payloads serve zero-copy from disk. Quantized hot copies (SQ8 codes, PQ
/// codes + codebooks) always stay resident — the tier only moves the
/// full-precision bytes.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub enum ColdTier {
    /// Full-precision rows stay in RAM.
    #[default]
    Ram,
    /// Full-precision rows are spilled to (and served mmap'd from) cold
    /// files under this directory.
    Mmap(PathBuf),
}

/// Which quantizer a substrate's owned vector copy uses.
#[derive(Debug, Clone, Default)]
pub enum Quantizer {
    /// Row-major f32 (no quantization).
    #[default]
    Flat,
    /// SQ8 scalar quantization; `bounds` pins a pre-trained (global)
    /// codebook, `None` trains segment-locally.
    Sq8 {
        /// Pre-trained global bounds, if any.
        bounds: Option<Arc<Sq8Bounds>>,
    },
    /// Product quantization with a two-stage (ADC + full-precision rerank)
    /// search.
    Pq(PqParams),
}

/// How a substrate stores its owned copy of the serving vectors: a
/// [`Quantizer`] for the hot copy plus the [`ColdTier`] the full-precision
/// rows live in. Assembled from [`IndexPolicy`] by
/// [`IndexPolicy::storage_spec`]; the sharded builder may inject
/// collection-wide [`Sq8Bounds`] so every segment shares one SQ8 codebook.
#[derive(Debug, Clone, Default)]
pub struct StorageSpec {
    /// Hot-copy quantizer.
    pub quant: Quantizer,
    /// Tier for the full-precision rows (flat payloads, PQ rerank rows).
    pub cold_tier: ColdTier,
}

impl StorageSpec {
    fn of(quant: Quantizer) -> StorageSpec {
        StorageSpec { quant, cold_tier: ColdTier::Ram }
    }

    /// Flat f32 storage.
    pub fn flat() -> StorageSpec {
        StorageSpec::of(Quantizer::Flat)
    }

    /// Segment-locally trained SQ8 storage.
    pub fn sq8() -> StorageSpec {
        StorageSpec::of(Quantizer::Sq8 { bounds: None })
    }

    /// PQ storage with default parameters.
    pub fn pq() -> StorageSpec {
        StorageSpec::of(Quantizer::Pq(PqParams::default()))
    }

    /// PQ storage with explicit parameters.
    pub fn pq_with(params: PqParams) -> StorageSpec {
        StorageSpec::of(Quantizer::Pq(params))
    }

    /// The same spec with its cold tier replaced.
    pub fn with_cold_tier(mut self, tier: ColdTier) -> StorageSpec {
        self.cold_tier = tier;
        self
    }
}

/// A k-NN search substrate over an owned copy of the serving vectors.
///
/// Implementations are `Send + Sync` so the coordinator can hold them behind
/// a `Box<dyn AnnIndex>` inside state that moves across threads, and must be
/// deterministic: equal build inputs give bit-identical search results, and
/// a [`write_to`](AnnIndex::write_to)/read round-trip preserves results
/// exactly (the persistence contract [`crate::data::store::save_index`]
/// relies on).
pub trait AnnIndex: Send + Sync + std::fmt::Debug {
    /// Which structure this is.
    fn kind(&self) -> IndexKind;

    /// Number of indexed vectors.
    fn len(&self) -> usize;

    /// True when no vectors are indexed.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Dimensionality of indexed vectors (and queries).
    fn dim(&self) -> usize;

    /// Distance metric the index was built for.
    fn metric(&self) -> Metric;

    /// True when vectors are stored quantized (SQ8 or PQ).
    fn quantized(&self) -> bool;

    /// Storage name of the serving copy: `"f32"`, `"sq8"` or `"pq"`.
    fn storage_name(&self) -> &'static str {
        "f32"
    }

    /// Approximate hot resident bytes of the index (vectors + structure).
    /// PQ storage excludes its full-precision rerank tier — see
    /// [`AnnIndex::cold_bytes`].
    fn memory_bytes(&self) -> usize;

    /// Bytes of the cold rerank tier (PQ only; 0 otherwise) — the
    /// full-precision rows the two-stage search reranks against. Resident
    /// when the tier is RAM-backed; see [`AnnIndex::mapped_bytes`] for the
    /// portion served zero-copy from an mmap'd cold file instead.
    fn cold_bytes(&self) -> usize {
        0
    }

    /// Bytes served zero-copy from mmap'd cold files (0 for RAM-resident
    /// indexes). Counts both mapped PQ rerank tiers and mapped flat
    /// payloads; `memory_bytes() + mapped-tier bytes` is the full logical
    /// footprint, of which only `memory_bytes()` is resident.
    fn mapped_bytes(&self) -> usize {
        0
    }

    /// k nearest neighbors of `query`, ascending by (distance, index).
    fn search(&self, query: &[f32], k: usize) -> Result<Vec<Neighbor>>;

    /// [`AnnIndex::search`] with per-stage latency attribution into `trace`.
    ///
    /// Results are bit-identical to `search` — tracing only adds stopwatches
    /// around the stages a substrate actually executes. The default times
    /// the whole search as a substrate scan; quantized and composite
    /// substrates override it to split ADC scan from rerank and to attribute
    /// shard/delta merges.
    fn search_traced(
        &self,
        query: &[f32],
        k: usize,
        trace: &SearchTrace,
    ) -> Result<Vec<Neighbor>> {
        let sw = Stopwatch::start();
        let out = self.search(query, k);
        trace.scan.record(sw.elapsed());
        out
    }

    /// True when the index's owned vector copy matches `data` (bit-exact for
    /// flat storage, within quantization error for SQ8). Used when loading a
    /// persisted segment so an index built from *different* data of the same
    /// shape never silently serves a collection.
    fn matches_data(&self, data: &[f32]) -> bool;

    /// Serialize the index payload (kind tag and framing are written by
    /// [`crate::data::store::write_index`]).
    fn write_to(&self, w: &mut dyn Write) -> Result<()>;

    /// Serialize the payload for the version-5 cold layout: full-precision
    /// vector payloads (flat rows, PQ rerank tiers) are pushed into `annex`
    /// and replaced by start-row references, so the loaded file can serve
    /// them mapped in place. The default writes the ordinary inline payload
    /// — correct for indexes with nothing to externalize; storage-bearing
    /// substrates override it.
    fn write_cold(&self, w: &mut dyn Write, annex: &mut AnnexWriter) -> Result<()> {
        let _ = annex;
        self.write_to(w)
    }

    /// Concrete [`ShardedIndex`] view when this index is sharded. The store
    /// uses it to pick the multi-segment (version-3) format and the
    /// coordinator to fan queries out across shards on the worker pool.
    fn as_sharded(&self) -> Option<&ShardedIndex> {
        None
    }

    /// Concrete [`DeltaIndex`] view when this index is a delta-augmented
    /// wrapper. The store uses it to pick the version-4 format and the
    /// coordinator to extend / rebase the delta across ingests and
    /// compactions.
    fn as_delta(&self) -> Option<&DeltaIndex> {
        None
    }
}

/// Build an index over row-major `data` per `policy`: collections smaller
/// than `policy.exact_threshold` get an exact flat index regardless of the
/// configured kind (ANN structures only pay off at scale), larger ones get
/// `policy.kind`. SQ8 storage applies to whichever substrate is chosen.
/// When `policy.shards` (bounded below by `policy.shard_min_vectors` rows
/// per shard) partitions the data into more than one segment, the result is
/// a [`ShardedIndex`] over that substrate; a single-segment partition keeps
/// the bare substrate index so existing format and search paths are
/// untouched. (This builds serially; the coordinator's background path,
/// [`shard::build_on_pool`], fans segment builds out to the worker pool and
/// yields a bit-identical index.)
pub fn build_index(
    data: &[f32],
    dim: usize,
    metric: Metric,
    policy: &IndexPolicy,
    seed: u64,
) -> Result<Box<dyn AnnIndex>> {
    if dim == 0 || data.len() % dim != 0 {
        return Err(OpdrError::shape(format!(
            "index build: {} floats is not a multiple of dim {dim}",
            data.len()
        )));
    }
    let n = data.len() / dim;
    if n == 0 {
        return Err(OpdrError::data("index build: empty data"));
    }
    if shard::shard_ranges(n, policy.shards, policy.shard_min_vectors).len() > 1 {
        return Ok(Box::new(ShardedIndex::build(data, dim, metric, policy, seed)?));
    }
    let kind = if n < policy.exact_threshold { IndexKind::Exact } else { policy.kind };
    let storage = policy.storage_spec();
    match kind {
        IndexKind::Exact => Ok(Box::new(ExactIndex::build(data, dim, metric, &storage, seed)?)),
        IndexKind::Ivf => Ok(Box::new(IvfIndex::build(
            data,
            dim,
            metric,
            IvfParams {
                nlist: policy.ivf_nlist,
                train_iters: policy.ivf_train_iters,
                nprobe: policy.ivf_nprobe,
            },
            &storage,
            seed,
        )?)),
        IndexKind::Hnsw => Ok(Box::new(HnswIndex::build(
            data,
            dim,
            metric,
            HnswParams {
                m: policy.hnsw_m,
                ef_construction: policy.hnsw_ef_construction,
                ef_search: policy.hnsw_ef_search,
                heuristic: policy.hnsw_heuristic,
            },
            &storage,
            seed,
        )?)),
    }
}

/// Deserialize an index payload given its kind tag (the framing half lives
/// in [`crate::data::store::read_index`]).
pub(crate) fn read_index_payload(kind_tag: u32, r: &mut dyn Read) -> Result<Box<dyn AnnIndex>> {
    read_index_payload_with(kind_tag, r, None)
}

/// [`read_index_payload`] with an optional cold context: inside a
/// version-5 file, externalized vector payloads resolve against the file's
/// annex (mapped or heap) instead of being decoded inline.
pub(crate) fn read_index_payload_with(
    kind_tag: u32,
    r: &mut dyn Read,
    cx: Option<&ColdContext>,
) -> Result<Box<dyn AnnIndex>> {
    match IndexKind::from_tag(kind_tag)? {
        IndexKind::Exact => Ok(Box::new(ExactIndex::read_with(r, cx)?)),
        IndexKind::Ivf => Ok(Box::new(IvfIndex::read_with(r, cx)?)),
        IndexKind::Hnsw => Ok(Box::new(HnswIndex::read_with(r, cx)?)),
    }
}

// ---------------------------------------------------------------------------
// Vector storage shared by the substrates: flat f32, SQ8- or PQ-quantized.
// ---------------------------------------------------------------------------

/// Owned copy of the indexed vectors: flat `f32`, SQ8- or PQ-quantized.
/// Full-precision rows (the flat payload, PQ's rerank tier) live in a
/// [`RowBlock`], so they are served identically from RAM or from an mmap'd
/// cold file ([`ColdTier::Mmap`]).
#[derive(Debug, Clone, PartialEq)]
pub enum VectorStore {
    /// Row-major `n × dim` f32 payload (resident or tiered).
    Flat(RowBlock),
    /// Scalar-quantized payload with per-dimension codebooks.
    Sq8(Sq8Storage),
    /// Product-quantized payload with per-subspace codebooks, optional OPQ
    /// rotation, ADC tables and a full-precision rerank tier.
    Pq(PqStorage),
}

impl VectorStore {
    /// Build from row-major data per `spec` (`seed` drives PQ codebook
    /// training; flat and SQ8 storage ignore it). With
    /// [`ColdTier::Mmap`], full-precision rows are spilled to a cold file
    /// under the configured directory and served mapped; search results are
    /// bit-identical to the RAM tier either way.
    pub fn build(data: &[f32], dim: usize, spec: &StorageSpec, seed: u64) -> Result<VectorStore> {
        if dim == 0 || data.len() % dim != 0 {
            return Err(OpdrError::shape("vector store: bad data shape"));
        }
        match &spec.quant {
            Quantizer::Flat => {
                let rows = match &spec.cold_tier {
                    ColdTier::Ram => RowBlock::from_ram(dim, data.to_vec())?,
                    ColdTier::Mmap(dir) => RowBlock::spill(dir, data, dim)?,
                };
                Ok(VectorStore::Flat(rows))
            }
            Quantizer::Sq8 { bounds: None } => Ok(VectorStore::Sq8(Sq8Storage::train(data, dim)?)),
            Quantizer::Sq8 { bounds: Some(b) } => {
                Ok(VectorStore::Sq8(Sq8Storage::encode_with(b, data, dim)?))
            }
            Quantizer::Pq(params) => {
                let mut pq = PqStorage::train(data, dim, params, seed)?;
                if let ColdTier::Mmap(dir) = &spec.cold_tier {
                    pq.spill_cold(dir)?;
                }
                Ok(VectorStore::Pq(pq))
            }
        }
    }

    /// Number of stored vectors.
    pub fn len(&self) -> usize {
        match self {
            VectorStore::Flat(rows) => rows.n(),
            VectorStore::Sq8(s) => s.len(),
            VectorStore::Pq(p) => p.len(),
        }
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Vector dimensionality.
    pub fn dim(&self) -> usize {
        match self {
            VectorStore::Flat(rows) => rows.dim(),
            VectorStore::Sq8(s) => s.dim(),
            VectorStore::Pq(p) => p.dim(),
        }
    }

    /// True for quantized (SQ8 or PQ) storage.
    pub fn quantized(&self) -> bool {
        !matches!(self, VectorStore::Flat(_))
    }

    /// Storage name: `"f32"`, `"sq8"` or `"pq"`.
    pub fn name(&self) -> &'static str {
        match self {
            VectorStore::Flat(_) => "f32",
            VectorStore::Sq8(_) => "sq8",
            VectorStore::Pq(_) => "pq",
        }
    }

    /// The PQ storage, when this store is product-quantized. The substrates
    /// use it to route searches through the two-stage ADC + rerank path.
    pub fn as_pq(&self) -> Option<&PqStorage> {
        match self {
            VectorStore::Pq(p) => Some(p),
            _ => None,
        }
    }

    /// Distance from a full-precision `query` to stored vector `id`
    /// (asymmetric for quantized storage: the query stays f32, the stored
    /// side is decoded through `scratch` to avoid per-candidate allocation).
    /// For PQ this is the generic per-candidate fallback — batch scans go
    /// through [`AdcTable`] instead.
    #[inline]
    pub fn distance(&self, metric: Metric, query: &[f32], id: usize, scratch: &mut Vec<f32>) -> f32 {
        match self {
            VectorStore::Flat(rows) => metric.distance(query, rows.row(id)),
            VectorStore::Sq8(s) => {
                scratch.resize(s.dim(), 0.0);
                s.decode_into(id, scratch);
                metric.distance(query, scratch)
            }
            VectorStore::Pq(p) => {
                // Allocation-free, but the rotation is still recomputed per
                // candidate (this method is stateless): scan loops over PQ
                // storage should build one [`AdcTable`] per query instead.
                let dim = p.dim();
                scratch.resize(2 * dim, 0.0);
                let (dec, rq) = scratch.split_at_mut(dim);
                p.decode_into(id, dec);
                if p.has_rotation() {
                    p.rotate_query_into(query, rq);
                    metric.distance(rq, dec)
                } else {
                    metric.distance(query, dec)
                }
            }
        }
    }

    /// Resident bytes of the payload (PQ excludes its rerank tier; a
    /// mapped flat payload counts 0 here — see
    /// [`VectorStore::mapped_bytes`]).
    pub fn memory_bytes(&self) -> usize {
        match self {
            VectorStore::Flat(rows) => rows.resident_bytes(),
            VectorStore::Sq8(s) => s.memory_bytes(),
            VectorStore::Pq(p) => p.memory_bytes(),
        }
    }

    /// Bytes of the cold full-precision rerank tier (PQ only).
    pub fn cold_bytes(&self) -> usize {
        match self {
            VectorStore::Pq(p) => p.rerank_bytes(),
            _ => 0,
        }
    }

    /// Bytes served zero-copy from mmap'd cold files (mapped flat payloads
    /// and mapped PQ rerank tiers; 0 when everything is resident).
    pub fn mapped_bytes(&self) -> usize {
        match self {
            VectorStore::Flat(rows) => rows.mapped_bytes(),
            VectorStore::Sq8(_) => 0,
            VectorStore::Pq(p) => p.mapped_bytes(),
        }
    }

    /// True when this store holds (an encoding of) exactly `other`:
    /// bit-identical for flat and PQ storage (PQ keeps the original rows in
    /// its rerank tier), within half a quantization step per dimension for
    /// SQ8.
    pub fn matches(&self, other: &[f32]) -> bool {
        match self {
            VectorStore::Flat(rows) => rows.matches(other),
            VectorStore::Sq8(s) => {
                let dim = s.dim();
                if other.len() != s.len() * dim {
                    return false;
                }
                let mut dec = vec![0.0f32; dim];
                for id in 0..s.len() {
                    s.decode_into(id, &mut dec);
                    for d in 0..dim {
                        let x = other[id * dim + d];
                        let tol = s.max_error(d) + 1e-4 * (1.0 + x.abs());
                        if (dec[d] - x).abs() > tol {
                            return false;
                        }
                    }
                }
                true
            }
            VectorStore::Pq(p) => p.matches(other),
        }
    }

    /// Serialize (tag + payload). Tags: 0 = flat inline, 1 = SQ8, 2 = PQ
    /// inline (the record kind added for the PQ subsystem), and — only
    /// inside version-5 cold files, where `annex` is present — 3 =
    /// PQ-external and 4 = flat-external, whose full-precision rows live
    /// in the file's annex as a `u64` start-row reference. Tags unknown to
    /// a reader fail with a descriptive error instead of misparsing.
    pub(crate) fn write_with(
        &self,
        w: &mut dyn Write,
        annex: Option<&mut AnnexWriter>,
    ) -> Result<()> {
        match self {
            VectorStore::Flat(rows) => match annex {
                None => {
                    io::write_u8(w, 0)?;
                    io::write_u64(w, rows.n() as u64)?;
                    io::write_u64(w, rows.dim() as u64)?;
                    rows.write_f32s(w)
                }
                Some(a) => {
                    io::write_u8(w, 4)?;
                    io::write_u64(w, rows.n() as u64)?;
                    io::write_u64(w, rows.dim() as u64)?;
                    io::write_u64(w, a.push_rows(rows)?)
                }
            },
            VectorStore::Sq8(s) => {
                io::write_u8(w, 1)?;
                s.write_to(w)
            }
            VectorStore::Pq(p) => match annex {
                None => {
                    io::write_u8(w, 2)?;
                    p.write_to(w)
                }
                Some(a) => {
                    io::write_u8(w, 3)?;
                    p.write_external(w, a)
                }
            },
        }
    }

    /// [`VectorStore::write_with`] without an annex (the inline v2/3/4
    /// layouts).
    pub(crate) fn write_to(&self, w: &mut dyn Write) -> Result<()> {
        self.write_with(w, None)
    }

    /// Deserialize (inverse of [`VectorStore::write_with`]). External tags
    /// (3/4) require the cold context of the enclosing version-5 file;
    /// outside one they fail with a typed error instead of misparsing.
    pub(crate) fn read_with(r: &mut dyn Read, cx: Option<&ColdContext>) -> Result<VectorStore> {
        match io::read_u8(r)? {
            0 => {
                let n = io::read_u64_usize(r)?;
                let dim = io::read_u64_usize(r)?;
                if dim == 0 {
                    return Err(OpdrError::data("vector store: dim is zero"));
                }
                let count = io::checked_count(n, dim)?;
                let data = io::read_f32s(r, count)?;
                Ok(VectorStore::Flat(RowBlock::from_ram(dim, data)?))
            }
            1 => Ok(VectorStore::Sq8(Sq8Storage::read_from(r)?)),
            2 => Ok(VectorStore::Pq(PqStorage::read_from(r)?)),
            3 => {
                let cx = cx.ok_or_else(|| {
                    OpdrError::data(
                        "vector store: external PQ rerank tier outside a version-5 cold file",
                    )
                })?;
                Ok(VectorStore::Pq(PqStorage::read_external(r, cx)?))
            }
            4 => {
                let cx = cx.ok_or_else(|| {
                    OpdrError::data(
                        "vector store: external flat rows outside a version-5 cold file",
                    )
                })?;
                let n = io::read_u64_usize(r)?;
                let dim = io::read_u64_usize(r)?;
                let start = io::read_u64_usize(r)?;
                if dim == 0 || n == 0 {
                    return Err(OpdrError::data("vector store: corrupt external flat header"));
                }
                if dim != cx.file.dim() {
                    return Err(OpdrError::data(format!(
                        "vector store: external rows are dim {dim} but the annex is dim {}",
                        cx.file.dim()
                    )));
                }
                Ok(VectorStore::Flat(RowBlock::tiered(Arc::clone(&cx.file), start, n)?))
            }
            other => Err(OpdrError::data(format!("vector store: unknown storage tag {other}"))),
        }
    }

    /// [`VectorStore::read_with`] without a cold context.
    pub(crate) fn read_from(r: &mut dyn Read) -> Result<VectorStore> {
        VectorStore::read_with(r, None)
    }
}

// ---------------------------------------------------------------------------
// Little-endian binary IO helpers shared by the index serializers.
// ---------------------------------------------------------------------------

pub(crate) mod io {
    //! Little-endian read/write helpers for index (de)serialization.

    use crate::error::{OpdrError, Result};
    use crate::metrics::Metric;
    use std::io::{Read, Write};

    /// Cap on deserialized element counts (matches the embedding store's
    /// payload bound): corrupt headers must not trigger huge allocations.
    pub const MAX_ELEMS: usize = 1 << 31;

    /// Eager-preallocation cap for length fields read from disk. A corrupt
    /// or hostile header may declare any count up to [`MAX_ELEMS`]
    /// (gibibytes); readers must not hand that straight to
    /// `Vec::with_capacity`/`vec![0; n]` — they would abort on OOM before
    /// the truncated payload gets a chance to fail the read. Instead every
    /// read path preallocates at most this many elements and lets the
    /// vector grow as bytes actually arrive, so a lying length field ends
    /// in the ordinary typed truncation error.
    pub const ALLOC_CHUNK: usize = 1 << 16;

    pub fn write_u8(w: &mut dyn Write, v: u8) -> Result<()> {
        w.write_all(&[v])?;
        Ok(())
    }

    pub fn read_u8(r: &mut dyn Read) -> Result<u8> {
        let mut b = [0u8; 1];
        r.read_exact(&mut b)?;
        Ok(b[0])
    }

    pub fn write_u32(w: &mut dyn Write, v: u32) -> Result<()> {
        w.write_all(&v.to_le_bytes())?;
        Ok(())
    }

    pub fn read_u32(r: &mut dyn Read) -> Result<u32> {
        let mut b = [0u8; 4];
        r.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }

    pub fn write_u64(w: &mut dyn Write, v: u64) -> Result<()> {
        w.write_all(&v.to_le_bytes())?;
        Ok(())
    }

    pub fn read_u64(r: &mut dyn Read) -> Result<u64> {
        let mut b = [0u8; 8];
        r.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }

    /// Read a u64 and narrow it to usize with a range check.
    pub fn read_u64_usize(r: &mut dyn Read) -> Result<usize> {
        let v = read_u64(r)?;
        usize::try_from(v).map_err(|_| OpdrError::data("index io: 64-bit count on 32-bit host"))
    }

    /// `a * b` with overflow + sanity bounds (element counts).
    pub fn checked_count(a: usize, b: usize) -> Result<usize> {
        let count = a
            .checked_mul(b)
            .ok_or_else(|| OpdrError::data("index io: size overflow"))?;
        if count > MAX_ELEMS {
            return Err(OpdrError::data("index io: payload too large"));
        }
        Ok(count)
    }

    pub fn write_f32s(w: &mut dyn Write, xs: &[f32]) -> Result<()> {
        for &x in xs {
            w.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }

    pub fn read_f32s(r: &mut dyn Read, count: usize) -> Result<Vec<f32>> {
        if count > MAX_ELEMS {
            return Err(OpdrError::data("index io: payload too large"));
        }
        // Bounded preallocation: `count` comes from an untrusted length
        // field, so the vector grows only as bytes actually arrive.
        let mut out = Vec::with_capacity(count.min(ALLOC_CHUNK));
        let mut b = [0u8; 4];
        for _ in 0..count {
            r.read_exact(&mut b)?;
            out.push(f32::from_le_bytes(b));
        }
        Ok(out)
    }

    pub fn write_bytes(w: &mut dyn Write, xs: &[u8]) -> Result<()> {
        w.write_all(xs)?;
        Ok(())
    }

    pub fn read_bytes(r: &mut dyn Read, count: usize) -> Result<Vec<u8>> {
        if count > MAX_ELEMS {
            return Err(OpdrError::data("index io: payload too large"));
        }
        // Chunked, bounded-preallocation read: a lying length field fails
        // with the typed truncation error instead of a huge upfront alloc.
        let mut out = Vec::with_capacity(count.min(ALLOC_CHUNK));
        let mut buf = [0u8; 8192];
        let mut remaining = count;
        while remaining > 0 {
            let take = remaining.min(buf.len());
            r.read_exact(&mut buf[..take])?;
            out.extend_from_slice(&buf[..take]);
            remaining -= take;
        }
        Ok(out)
    }

    /// Chunked u32 list read with the same bounded-preallocation contract.
    pub fn read_u32s(r: &mut dyn Read, count: usize) -> Result<Vec<u32>> {
        if count > MAX_ELEMS {
            return Err(OpdrError::data("index io: payload too large"));
        }
        let mut out = Vec::with_capacity(count.min(ALLOC_CHUNK));
        let mut b = [0u8; 4];
        for _ in 0..count {
            r.read_exact(&mut b)?;
            out.push(u32::from_le_bytes(b));
        }
        Ok(out)
    }

    /// Stable on-disk tag for a metric.
    pub fn metric_tag(m: Metric) -> u8 {
        match m {
            Metric::Euclidean => 0,
            Metric::SqEuclidean => 1,
            Metric::Cosine => 2,
            Metric::Manhattan => 3,
            Metric::NegDot => 4,
        }
    }

    /// Inverse of [`metric_tag`].
    pub fn metric_from_tag(tag: u8) -> Result<Metric> {
        match tag {
            0 => Ok(Metric::Euclidean),
            1 => Ok(Metric::SqEuclidean),
            2 => Ok(Metric::Cosine),
            3 => Ok(Metric::Manhattan),
            4 => Ok(Metric::NegDot),
            other => Err(OpdrError::data(format!("index io: unknown metric tag {other}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn kind_parse_roundtrip_and_tags() {
        for kind in [IndexKind::Exact, IndexKind::Ivf, IndexKind::Hnsw] {
            assert_eq!(IndexKind::parse(kind.name()), Some(kind));
            assert_eq!(IndexKind::from_tag(kind.tag()).unwrap(), kind);
        }
        assert_eq!(IndexKind::parse("bogus"), None);
        assert!(IndexKind::from_tag(99).is_err());
    }

    #[test]
    fn metric_tags_roundtrip() {
        for m in [
            Metric::Euclidean,
            Metric::SqEuclidean,
            Metric::Cosine,
            Metric::Manhattan,
            Metric::NegDot,
        ] {
            assert_eq!(io::metric_from_tag(io::metric_tag(m)).unwrap(), m);
        }
        assert!(io::metric_from_tag(200).is_err());
    }

    #[test]
    fn vector_store_all_storages_roundtrip() {
        let mut rng = Rng::new(4);
        let dim = 6;
        let data = rng.normal_vec_f32(20 * dim);
        for (spec, name, quantized) in [
            (StorageSpec::flat(), "f32", false),
            (StorageSpec::sq8(), "sq8", true),
            (StorageSpec::pq(), "pq", true),
            (StorageSpec::pq_with(PqParams { opq: true, ..Default::default() }), "pq", true),
        ] {
            let store = VectorStore::build(&data, dim, &spec, 7).unwrap();
            assert_eq!(store.len(), 20);
            assert_eq!(store.dim(), dim);
            assert_eq!(store.quantized(), quantized);
            assert_eq!(store.name(), name);
            let mut buf = Vec::new();
            store.write_to(&mut buf).unwrap();
            let back = VectorStore::read_from(&mut buf.as_slice()).unwrap();
            assert_eq!(store, back);
        }
        // Unknown storage tag rejected.
        let mut buf = Vec::new();
        VectorStore::build(&data, dim, &StorageSpec::flat(), 7)
            .unwrap()
            .write_to(&mut buf)
            .unwrap();
        buf[0] = 9;
        let e = VectorStore::read_from(&mut buf.as_slice()).unwrap_err().to_string();
        assert!(e.contains("storage tag"), "{e}");
    }

    #[test]
    fn factory_respects_exact_threshold() {
        let mut rng = Rng::new(7);
        let dim = 4;
        let data = rng.normal_vec_f32(50 * dim);
        let policy = crate::config::IndexPolicy {
            kind: IndexKind::Hnsw,
            exact_threshold: 100,
            ..Default::default()
        };
        let idx = build_index(&data, dim, Metric::SqEuclidean, &policy, 1).unwrap();
        assert_eq!(idx.kind(), IndexKind::Exact);
        let policy = crate::config::IndexPolicy { exact_threshold: 10, ..policy };
        let idx = build_index(&data, dim, Metric::SqEuclidean, &policy, 1).unwrap();
        assert_eq!(idx.kind(), IndexKind::Hnsw);
    }

    #[test]
    fn factory_routes_multi_segment_partitions_to_sharded() {
        let mut rng = Rng::new(9);
        let dim = 4;
        let data = rng.normal_vec_f32(64 * dim);
        let policy = crate::config::IndexPolicy {
            exact_threshold: 0,
            shards: 4,
            shard_min_vectors: 8,
            ..Default::default()
        };
        let idx = build_index(&data, dim, Metric::SqEuclidean, &policy, 1).unwrap();
        assert_eq!(idx.as_sharded().unwrap().num_shards(), 4);
        assert_eq!(idx.len(), 64);
        // A minimum that only allows one shard keeps the bare substrate.
        let policy = crate::config::IndexPolicy { shard_min_vectors: 64, ..policy };
        let idx = build_index(&data, dim, Metric::SqEuclidean, &policy, 1).unwrap();
        assert!(idx.as_sharded().is_none());
    }

    #[test]
    fn factory_rejects_bad_shapes() {
        let policy = crate::config::IndexPolicy::default();
        assert!(build_index(&[1.0; 7], 4, Metric::Euclidean, &policy, 1).is_err());
        assert!(build_index(&[], 4, Metric::Euclidean, &policy, 1).is_err());
        assert!(build_index(&[1.0; 8], 0, Metric::Euclidean, &policy, 1).is_err());
    }

    #[test]
    fn quantized_store_distance_close_to_flat() {
        let mut rng = Rng::new(11);
        let dim = 8;
        let data = rng.normal_vec_f32(30 * dim);
        let flat = VectorStore::build(&data, dim, &StorageSpec::flat(), 1).unwrap();
        let sq8 = VectorStore::build(&data, dim, &StorageSpec::sq8(), 1).unwrap();
        let q = rng.normal_vec_f32(dim);
        let mut scratch = Vec::new();
        for id in 0..30 {
            let d0 = flat.distance(Metric::Euclidean, &q, id, &mut scratch);
            let d1 = sq8.distance(Metric::Euclidean, &q, id, &mut scratch);
            assert!((d0 - d1).abs() < 0.1, "id {id}: {d0} vs {d1}");
        }
        assert!(sq8.memory_bytes() < flat.memory_bytes() / 3);
        // PQ: the generic per-candidate fallback decodes to something in the
        // data's neighborhood. (At this tiny n the codebooks dominate the
        // hot bytes; the ≥8× claim is asserted at realistic n in
        // `tests/props.rs` and the bench.)
        let pq = VectorStore::build(&data, dim, &StorageSpec::pq(), 1).unwrap();
        for id in 0..30 {
            let d0 = flat.distance(Metric::Euclidean, &q, id, &mut scratch);
            let d1 = pq.distance(Metric::Euclidean, &q, id, &mut scratch);
            assert!((d0 - d1).abs() < 2.0, "id {id}: {d0} vs {d1}");
        }
        assert!(pq.memory_bytes() < flat.memory_bytes());
        assert_eq!(pq.cold_bytes(), data.len() * 4);
        assert!(pq.matches(&data));
    }

    #[test]
    fn mmap_cold_tier_builds_serve_bitwise_like_ram() {
        let dir = std::env::temp_dir().join(format!("opdr_store_cold_{}", std::process::id()));
        let mut rng = Rng::new(21);
        let dim = 6;
        let data = rng.normal_vec_f32(40 * dim);
        let q = rng.normal_vec_f32(dim);
        for spec in [StorageSpec::flat(), StorageSpec::pq()] {
            let ram = VectorStore::build(&data, dim, &spec, 5).unwrap();
            let cold_spec = spec.clone().with_cold_tier(ColdTier::Mmap(dir.clone()));
            let cold = VectorStore::build(&data, dim, &cold_spec, 5).unwrap();
            assert_eq!(cold.len(), 40);
            assert!(cold.matches(&data), "{}: tiered rows must match the input", cold.name());
            // Tiered accounting: the cold-tier size is backing-independent,
            // and mapped bytes leave the resident count (on hosts where the
            // mapping succeeds; the heap fallback stays resident but
            // correct).
            assert_eq!(cold.cold_bytes(), ram.cold_bytes(), "{}", cold.name());
            match &cold {
                // Flat: the payload itself moves tiers.
                VectorStore::Flat(_) => assert_eq!(
                    cold.memory_bytes() + cold.mapped_bytes(),
                    ram.memory_bytes(),
                    "flat: mapped bytes must leave the resident count"
                ),
                // PQ: the hot copy is unchanged; only the rerank tier maps.
                VectorStore::Pq(_) => {
                    assert_eq!(cold.memory_bytes(), ram.memory_bytes(), "pq hot copy");
                    assert!(
                        cold.mapped_bytes() == 0 || cold.mapped_bytes() == cold.cold_bytes(),
                        "pq: the mapped bytes are the rerank tier or nothing"
                    );
                }
                VectorStore::Sq8(_) => unreachable!("no sq8 spec in this loop"),
            }
            // Per-candidate distances are bit-identical across tiers.
            let mut s1 = Vec::new();
            let mut s2 = Vec::new();
            for id in 0..40 {
                let a = ram.distance(Metric::SqEuclidean, &q, id, &mut s1);
                let b = cold.distance(Metric::SqEuclidean, &q, id, &mut s2);
                assert_eq!(a.to_bits(), b.to_bits(), "{} id {id}", cold.name());
            }
        }
        // SQ8 has no full-precision tier: the knob is a no-op by design.
        let sq8 = VectorStore::build(
            &data,
            dim,
            &StorageSpec::sq8().with_cold_tier(ColdTier::Mmap(dir.clone())),
            5,
        )
        .unwrap();
        assert_eq!(sq8.mapped_bytes(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
