//! Pluggable ANN index subsystem.
//!
//! The paper positions OPDR as a *complement* to vector indexes: reduce the
//! dimension first, then index. This module is the "then index" half — a
//! common [`AnnIndex`] trait over interchangeable search substrates:
//!
//! * [`exact`] — flat exhaustive scan (the ground-truth substrate, and the
//!   automatic choice below [`IndexPolicy::exact_threshold`]);
//! * [`ivf`] — IVF-Flat inverted lists over a k-means coarse quantizer
//!   (FAISS-style), generalizing [`crate::knn::IvfFlatIndex`] to quantized
//!   storage;
//! * [`hnsw`] — a deterministic Hierarchical Navigable Small World graph
//!   (layered greedy + beam search, seeded level assignment);
//! * [`sq8`] — per-dimension scalar (8-bit) quantized storage with
//!   asymmetric distance, composable under every substrate above to shrink
//!   the serving copy ~4× (optionally with a collection-wide global
//!   codebook shared across shards);
//! * [`pq`] — product-quantized storage (optionally OPQ-rotated) with ADC
//!   lookup-table scans and an order-exact full-precision rerank stage,
//!   composable under every substrate for a ~16× hot-copy shrink;
//! * [`shard`] — segment sharding over any of the above: a collection is
//!   split into `S` contiguous segments ([`IndexPolicy::shards`] /
//!   [`IndexPolicy::shard_min_vectors`]), segments build in parallel on the
//!   coordinator's worker pool, and queries fan out per shard and merge
//!   through the bounded top-k heap with an order-exact (not merely
//!   recall-equal) guarantee;
//! * [`delta`] — incremental ingest over any of the above: writes are
//!   absorbed into a flat exact delta segment behind the immutable main
//!   index ([`DeltaIndex`]), searches fan out over `{main, delta}` and
//!   merge order-exactly, and a background compaction folds the delta back
//!   into the main index behind the coordinator's generation-guarded swap.
//!
//! Substrate × storage composition is expressed by [`StorageSpec`]: every
//! substrate builds over a [`VectorStore`] that is flat f32, SQ8 or PQ, so
//! the full matrix {exact, IVF, HNSW} × {f32, SQ8, PQ} (± sharding) is
//! available from one [`IndexPolicy`].
//!
//! Indexes serialize through [`AnnIndex::write_to`] into the versioned
//! `OPDR` binary format (see [`crate::data::store`]): single-segment indexes
//! as version-2 segments, sharded indexes as version-3 multi-segment files
//! with validated per-shard headers, and delta-augmented indexes as
//! version-4 files carrying the main payload plus a delta record. All
//! builds are deterministic from the seed: identical data + policy + seed ⇒
//! bit-identical indexes.

pub mod delta;
pub mod exact;
pub mod hnsw;
pub mod ivf;
pub mod pq;
pub mod shard;
pub mod sq8;

pub use delta::DeltaIndex;
pub use exact::ExactIndex;
pub use hnsw::{HnswIndex, HnswParams};
pub use ivf::IvfIndex;
pub use pq::{AdcTable, PqParams, PqStorage};
pub use shard::ShardedIndex;
pub use sq8::{Sq8Bounds, Sq8Storage};

use crate::config::IndexPolicy;
use crate::error::{OpdrError, Result};
use crate::knn::Neighbor;
use crate::metrics::Metric;
use std::io::{Read, Write};
use std::sync::Arc;

/// Which search structure an index uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IndexKind {
    /// Exhaustive flat scan (exact).
    Exact,
    /// IVF-Flat inverted lists.
    Ivf,
    /// HNSW layered graph.
    Hnsw,
}

impl IndexKind {
    /// Parse from a config / CLI string.
    pub fn parse(s: &str) -> Option<IndexKind> {
        match s.to_ascii_lowercase().as_str() {
            "exact" | "flat" | "brute" => Some(IndexKind::Exact),
            "ivf" | "ivf-flat" | "ivfflat" => Some(IndexKind::Ivf),
            "hnsw" => Some(IndexKind::Hnsw),
            _ => None,
        }
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            IndexKind::Exact => "exact",
            IndexKind::Ivf => "ivf",
            IndexKind::Hnsw => "hnsw",
        }
    }

    /// Stable on-disk tag.
    pub(crate) fn tag(&self) -> u32 {
        match self {
            IndexKind::Exact => 0,
            IndexKind::Ivf => 1,
            IndexKind::Hnsw => 2,
        }
    }

    /// Inverse of [`IndexKind::tag`].
    pub(crate) fn from_tag(tag: u32) -> Result<IndexKind> {
        match tag {
            0 => Ok(IndexKind::Exact),
            1 => Ok(IndexKind::Ivf),
            2 => Ok(IndexKind::Hnsw),
            other => Err(OpdrError::data(format!("index: unknown kind tag {other}"))),
        }
    }
}

/// How a substrate stores its owned copy of the serving vectors. Assembled
/// from [`IndexPolicy`] by [`IndexPolicy::storage_spec`]; the sharded
/// builder may inject collection-wide [`Sq8Bounds`] so every segment shares
/// one SQ8 codebook.
#[derive(Debug, Clone, Default)]
pub enum StorageSpec {
    /// Row-major f32 (no quantization).
    #[default]
    Flat,
    /// SQ8 scalar quantization; `bounds` pins a pre-trained (global)
    /// codebook, `None` trains segment-locally.
    Sq8 {
        /// Pre-trained global bounds, if any.
        bounds: Option<Arc<Sq8Bounds>>,
    },
    /// Product quantization with a two-stage (ADC + full-precision rerank)
    /// search.
    Pq(PqParams),
}

impl StorageSpec {
    /// Flat f32 storage.
    pub fn flat() -> StorageSpec {
        StorageSpec::Flat
    }

    /// Segment-locally trained SQ8 storage.
    pub fn sq8() -> StorageSpec {
        StorageSpec::Sq8 { bounds: None }
    }

    /// PQ storage with default parameters.
    pub fn pq() -> StorageSpec {
        StorageSpec::Pq(PqParams::default())
    }
}

/// A k-NN search substrate over an owned copy of the serving vectors.
///
/// Implementations are `Send + Sync` so the coordinator can hold them behind
/// a `Box<dyn AnnIndex>` inside state that moves across threads, and must be
/// deterministic: equal build inputs give bit-identical search results, and
/// a [`write_to`](AnnIndex::write_to)/read round-trip preserves results
/// exactly (the persistence contract [`crate::data::store::save_index`]
/// relies on).
pub trait AnnIndex: Send + Sync + std::fmt::Debug {
    /// Which structure this is.
    fn kind(&self) -> IndexKind;

    /// Number of indexed vectors.
    fn len(&self) -> usize;

    /// True when no vectors are indexed.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Dimensionality of indexed vectors (and queries).
    fn dim(&self) -> usize;

    /// Distance metric the index was built for.
    fn metric(&self) -> Metric;

    /// True when vectors are stored quantized (SQ8 or PQ).
    fn quantized(&self) -> bool;

    /// Storage name of the serving copy: `"f32"`, `"sq8"` or `"pq"`.
    fn storage_name(&self) -> &'static str {
        "f32"
    }

    /// Approximate hot resident bytes of the index (vectors + structure).
    /// PQ storage excludes its full-precision rerank tier — see
    /// [`AnnIndex::cold_bytes`].
    fn memory_bytes(&self) -> usize;

    /// Bytes of the cold rerank tier (PQ only; 0 otherwise). Held in RAM in
    /// this implementation, but modeled as the tier a production deployment
    /// would mmap from disk.
    fn cold_bytes(&self) -> usize {
        0
    }

    /// k nearest neighbors of `query`, ascending by (distance, index).
    fn search(&self, query: &[f32], k: usize) -> Result<Vec<Neighbor>>;

    /// True when the index's owned vector copy matches `data` (bit-exact for
    /// flat storage, within quantization error for SQ8). Used when loading a
    /// persisted segment so an index built from *different* data of the same
    /// shape never silently serves a collection.
    fn matches_data(&self, data: &[f32]) -> bool;

    /// Serialize the index payload (kind tag and framing are written by
    /// [`crate::data::store::write_index`]).
    fn write_to(&self, w: &mut dyn Write) -> Result<()>;

    /// Concrete [`ShardedIndex`] view when this index is sharded. The store
    /// uses it to pick the multi-segment (version-3) format and the
    /// coordinator to fan queries out across shards on the worker pool.
    fn as_sharded(&self) -> Option<&ShardedIndex> {
        None
    }

    /// Concrete [`DeltaIndex`] view when this index is a delta-augmented
    /// wrapper. The store uses it to pick the version-4 format and the
    /// coordinator to extend / rebase the delta across ingests and
    /// compactions.
    fn as_delta(&self) -> Option<&DeltaIndex> {
        None
    }
}

/// Build an index over row-major `data` per `policy`: collections smaller
/// than `policy.exact_threshold` get an exact flat index regardless of the
/// configured kind (ANN structures only pay off at scale), larger ones get
/// `policy.kind`. SQ8 storage applies to whichever substrate is chosen.
/// When `policy.shards` (bounded below by `policy.shard_min_vectors` rows
/// per shard) partitions the data into more than one segment, the result is
/// a [`ShardedIndex`] over that substrate; a single-segment partition keeps
/// the bare substrate index so existing format and search paths are
/// untouched. (This builds serially; the coordinator's background path,
/// [`shard::build_on_pool`], fans segment builds out to the worker pool and
/// yields a bit-identical index.)
pub fn build_index(
    data: &[f32],
    dim: usize,
    metric: Metric,
    policy: &IndexPolicy,
    seed: u64,
) -> Result<Box<dyn AnnIndex>> {
    if dim == 0 || data.len() % dim != 0 {
        return Err(OpdrError::shape(format!(
            "index build: {} floats is not a multiple of dim {dim}",
            data.len()
        )));
    }
    let n = data.len() / dim;
    if n == 0 {
        return Err(OpdrError::data("index build: empty data"));
    }
    if shard::shard_ranges(n, policy.shards, policy.shard_min_vectors).len() > 1 {
        return Ok(Box::new(ShardedIndex::build(data, dim, metric, policy, seed)?));
    }
    let kind = if n < policy.exact_threshold { IndexKind::Exact } else { policy.kind };
    let storage = policy.storage_spec();
    match kind {
        IndexKind::Exact => Ok(Box::new(ExactIndex::build(data, dim, metric, &storage, seed)?)),
        IndexKind::Ivf => Ok(Box::new(IvfIndex::build(
            data,
            dim,
            metric,
            policy.ivf_nlist,
            policy.ivf_train_iters,
            policy.ivf_nprobe,
            &storage,
            seed,
        )?)),
        IndexKind::Hnsw => Ok(Box::new(HnswIndex::build(
            data,
            dim,
            metric,
            HnswParams {
                m: policy.hnsw_m,
                ef_construction: policy.hnsw_ef_construction,
                ef_search: policy.hnsw_ef_search,
                heuristic: policy.hnsw_heuristic,
            },
            &storage,
            seed,
        )?)),
    }
}

/// Deserialize an index payload given its kind tag (the framing half lives
/// in [`crate::data::store::read_index`]).
pub(crate) fn read_index_payload(kind_tag: u32, r: &mut dyn Read) -> Result<Box<dyn AnnIndex>> {
    match IndexKind::from_tag(kind_tag)? {
        IndexKind::Exact => Ok(Box::new(ExactIndex::read_from(r)?)),
        IndexKind::Ivf => Ok(Box::new(IvfIndex::read_from(r)?)),
        IndexKind::Hnsw => Ok(Box::new(HnswIndex::read_from(r)?)),
    }
}

// ---------------------------------------------------------------------------
// Vector storage shared by the substrates: flat f32 or SQ8-quantized.
// ---------------------------------------------------------------------------

/// Owned copy of the indexed vectors: flat `f32`, SQ8- or PQ-quantized.
#[derive(Debug, Clone, PartialEq)]
pub enum VectorStore {
    /// Row-major `n × dim` f32 payload.
    Flat {
        /// Vector dimensionality.
        dim: usize,
        /// Row-major payload.
        data: Vec<f32>,
    },
    /// Scalar-quantized payload with per-dimension codebooks.
    Sq8(Sq8Storage),
    /// Product-quantized payload with per-subspace codebooks, optional OPQ
    /// rotation, ADC tables and a full-precision rerank tier.
    Pq(PqStorage),
}

impl VectorStore {
    /// Build from row-major data per `spec` (`seed` drives PQ codebook
    /// training; flat and SQ8 storage ignore it).
    pub fn build(data: &[f32], dim: usize, spec: &StorageSpec, seed: u64) -> Result<VectorStore> {
        if dim == 0 || data.len() % dim != 0 {
            return Err(OpdrError::shape("vector store: bad data shape"));
        }
        match spec {
            StorageSpec::Flat => Ok(VectorStore::Flat { dim, data: data.to_vec() }),
            StorageSpec::Sq8 { bounds: None } => {
                Ok(VectorStore::Sq8(Sq8Storage::train(data, dim)?))
            }
            StorageSpec::Sq8 { bounds: Some(b) } => {
                Ok(VectorStore::Sq8(Sq8Storage::encode_with(b, data, dim)?))
            }
            StorageSpec::Pq(params) => {
                Ok(VectorStore::Pq(PqStorage::train(data, dim, params, seed)?))
            }
        }
    }

    /// Number of stored vectors.
    pub fn len(&self) -> usize {
        match self {
            VectorStore::Flat { dim, data } => data.len() / dim,
            VectorStore::Sq8(s) => s.len(),
            VectorStore::Pq(p) => p.len(),
        }
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Vector dimensionality.
    pub fn dim(&self) -> usize {
        match self {
            VectorStore::Flat { dim, .. } => *dim,
            VectorStore::Sq8(s) => s.dim(),
            VectorStore::Pq(p) => p.dim(),
        }
    }

    /// True for quantized (SQ8 or PQ) storage.
    pub fn quantized(&self) -> bool {
        !matches!(self, VectorStore::Flat { .. })
    }

    /// Storage name: `"f32"`, `"sq8"` or `"pq"`.
    pub fn name(&self) -> &'static str {
        match self {
            VectorStore::Flat { .. } => "f32",
            VectorStore::Sq8(_) => "sq8",
            VectorStore::Pq(_) => "pq",
        }
    }

    /// The PQ storage, when this store is product-quantized. The substrates
    /// use it to route searches through the two-stage ADC + rerank path.
    pub fn as_pq(&self) -> Option<&PqStorage> {
        match self {
            VectorStore::Pq(p) => Some(p),
            _ => None,
        }
    }

    /// Distance from a full-precision `query` to stored vector `id`
    /// (asymmetric for quantized storage: the query stays f32, the stored
    /// side is decoded through `scratch` to avoid per-candidate allocation).
    /// For PQ this is the generic per-candidate fallback — batch scans go
    /// through [`AdcTable`] instead.
    #[inline]
    pub fn distance(&self, metric: Metric, query: &[f32], id: usize, scratch: &mut Vec<f32>) -> f32 {
        match self {
            VectorStore::Flat { dim, data } => {
                metric.distance(query, &data[id * dim..(id + 1) * dim])
            }
            VectorStore::Sq8(s) => {
                scratch.resize(s.dim(), 0.0);
                s.decode_into(id, scratch);
                metric.distance(query, scratch)
            }
            VectorStore::Pq(p) => {
                // Allocation-free, but the rotation is still recomputed per
                // candidate (this method is stateless): scan loops over PQ
                // storage should build one [`AdcTable`] per query instead.
                let dim = p.dim();
                scratch.resize(2 * dim, 0.0);
                let (dec, rq) = scratch.split_at_mut(dim);
                p.decode_into(id, dec);
                if p.has_rotation() {
                    p.rotate_query_into(query, rq);
                    metric.distance(rq, dec)
                } else {
                    metric.distance(query, dec)
                }
            }
        }
    }

    /// Hot resident bytes of the payload (PQ excludes its rerank tier).
    pub fn memory_bytes(&self) -> usize {
        match self {
            VectorStore::Flat { data, .. } => data.len() * std::mem::size_of::<f32>(),
            VectorStore::Sq8(s) => s.memory_bytes(),
            VectorStore::Pq(p) => p.memory_bytes(),
        }
    }

    /// Bytes of the cold full-precision rerank tier (PQ only).
    pub fn cold_bytes(&self) -> usize {
        match self {
            VectorStore::Pq(p) => p.rerank_bytes(),
            _ => 0,
        }
    }

    /// True when this store holds (an encoding of) exactly `other`:
    /// bit-identical for flat and PQ storage (PQ keeps the original rows in
    /// its rerank tier), within half a quantization step per dimension for
    /// SQ8.
    pub fn matches(&self, other: &[f32]) -> bool {
        match self {
            VectorStore::Flat { data, .. } => {
                data.len() == other.len()
                    && data.iter().zip(other).all(|(a, b)| a.to_bits() == b.to_bits())
            }
            VectorStore::Sq8(s) => {
                let dim = s.dim();
                if other.len() != s.len() * dim {
                    return false;
                }
                let mut dec = vec![0.0f32; dim];
                for id in 0..s.len() {
                    s.decode_into(id, &mut dec);
                    for d in 0..dim {
                        let x = other[id * dim + d];
                        let tol = s.max_error(d) + 1e-4 * (1.0 + x.abs());
                        if (dec[d] - x).abs() > tol {
                            return false;
                        }
                    }
                }
                true
            }
            VectorStore::Pq(p) => p.matches(other),
        }
    }

    /// Serialize (tag + payload). Tags: 0 = flat, 1 = SQ8, 2 = PQ (the
    /// record kind added for the PQ subsystem; older readers reject it with
    /// a descriptive error instead of misparsing).
    pub(crate) fn write_to(&self, w: &mut dyn Write) -> Result<()> {
        match self {
            VectorStore::Flat { dim, data } => {
                io::write_u8(w, 0)?;
                io::write_u64(w, (data.len() / dim) as u64)?;
                io::write_u64(w, *dim as u64)?;
                io::write_f32s(w, data)
            }
            VectorStore::Sq8(s) => {
                io::write_u8(w, 1)?;
                s.write_to(w)
            }
            VectorStore::Pq(p) => {
                io::write_u8(w, 2)?;
                p.write_to(w)
            }
        }
    }

    /// Deserialize (inverse of [`VectorStore::write_to`]).
    pub(crate) fn read_from(r: &mut dyn Read) -> Result<VectorStore> {
        match io::read_u8(r)? {
            0 => {
                let n = io::read_u64_usize(r)?;
                let dim = io::read_u64_usize(r)?;
                if dim == 0 {
                    return Err(OpdrError::data("vector store: dim is zero"));
                }
                let count = io::checked_count(n, dim)?;
                let data = io::read_f32s(r, count)?;
                Ok(VectorStore::Flat { dim, data })
            }
            1 => Ok(VectorStore::Sq8(Sq8Storage::read_from(r)?)),
            2 => Ok(VectorStore::Pq(PqStorage::read_from(r)?)),
            other => Err(OpdrError::data(format!("vector store: unknown storage tag {other}"))),
        }
    }
}

// ---------------------------------------------------------------------------
// Little-endian binary IO helpers shared by the index serializers.
// ---------------------------------------------------------------------------

pub(crate) mod io {
    //! Little-endian read/write helpers for index (de)serialization.

    use crate::error::{OpdrError, Result};
    use crate::metrics::Metric;
    use std::io::{Read, Write};

    /// Cap on deserialized element counts (matches the embedding store's
    /// payload bound): corrupt headers must not trigger huge allocations.
    pub const MAX_ELEMS: usize = 1 << 31;

    pub fn write_u8(w: &mut dyn Write, v: u8) -> Result<()> {
        w.write_all(&[v])?;
        Ok(())
    }

    pub fn read_u8(r: &mut dyn Read) -> Result<u8> {
        let mut b = [0u8; 1];
        r.read_exact(&mut b)?;
        Ok(b[0])
    }

    pub fn write_u32(w: &mut dyn Write, v: u32) -> Result<()> {
        w.write_all(&v.to_le_bytes())?;
        Ok(())
    }

    pub fn read_u32(r: &mut dyn Read) -> Result<u32> {
        let mut b = [0u8; 4];
        r.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }

    pub fn write_u64(w: &mut dyn Write, v: u64) -> Result<()> {
        w.write_all(&v.to_le_bytes())?;
        Ok(())
    }

    pub fn read_u64(r: &mut dyn Read) -> Result<u64> {
        let mut b = [0u8; 8];
        r.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }

    /// Read a u64 and narrow it to usize with a range check.
    pub fn read_u64_usize(r: &mut dyn Read) -> Result<usize> {
        let v = read_u64(r)?;
        usize::try_from(v).map_err(|_| OpdrError::data("index io: 64-bit count on 32-bit host"))
    }

    /// `a * b` with overflow + sanity bounds (element counts).
    pub fn checked_count(a: usize, b: usize) -> Result<usize> {
        let count = a
            .checked_mul(b)
            .ok_or_else(|| OpdrError::data("index io: size overflow"))?;
        if count > MAX_ELEMS {
            return Err(OpdrError::data("index io: payload too large"));
        }
        Ok(count)
    }

    pub fn write_f32s(w: &mut dyn Write, xs: &[f32]) -> Result<()> {
        for &x in xs {
            w.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }

    pub fn read_f32s(r: &mut dyn Read, count: usize) -> Result<Vec<f32>> {
        if count > MAX_ELEMS {
            return Err(OpdrError::data("index io: payload too large"));
        }
        let mut out = Vec::with_capacity(count);
        let mut b = [0u8; 4];
        for _ in 0..count {
            r.read_exact(&mut b)?;
            out.push(f32::from_le_bytes(b));
        }
        Ok(out)
    }

    pub fn write_bytes(w: &mut dyn Write, xs: &[u8]) -> Result<()> {
        w.write_all(xs)?;
        Ok(())
    }

    pub fn read_bytes(r: &mut dyn Read, count: usize) -> Result<Vec<u8>> {
        if count > MAX_ELEMS {
            return Err(OpdrError::data("index io: payload too large"));
        }
        let mut out = vec![0u8; count];
        r.read_exact(&mut out)?;
        Ok(out)
    }

    /// Stable on-disk tag for a metric.
    pub fn metric_tag(m: Metric) -> u8 {
        match m {
            Metric::Euclidean => 0,
            Metric::SqEuclidean => 1,
            Metric::Cosine => 2,
            Metric::Manhattan => 3,
            Metric::NegDot => 4,
        }
    }

    /// Inverse of [`metric_tag`].
    pub fn metric_from_tag(tag: u8) -> Result<Metric> {
        match tag {
            0 => Ok(Metric::Euclidean),
            1 => Ok(Metric::SqEuclidean),
            2 => Ok(Metric::Cosine),
            3 => Ok(Metric::Manhattan),
            4 => Ok(Metric::NegDot),
            other => Err(OpdrError::data(format!("index io: unknown metric tag {other}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn kind_parse_roundtrip_and_tags() {
        for kind in [IndexKind::Exact, IndexKind::Ivf, IndexKind::Hnsw] {
            assert_eq!(IndexKind::parse(kind.name()), Some(kind));
            assert_eq!(IndexKind::from_tag(kind.tag()).unwrap(), kind);
        }
        assert_eq!(IndexKind::parse("bogus"), None);
        assert!(IndexKind::from_tag(99).is_err());
    }

    #[test]
    fn metric_tags_roundtrip() {
        for m in [
            Metric::Euclidean,
            Metric::SqEuclidean,
            Metric::Cosine,
            Metric::Manhattan,
            Metric::NegDot,
        ] {
            assert_eq!(io::metric_from_tag(io::metric_tag(m)).unwrap(), m);
        }
        assert!(io::metric_from_tag(200).is_err());
    }

    #[test]
    fn vector_store_all_storages_roundtrip() {
        let mut rng = Rng::new(4);
        let dim = 6;
        let data = rng.normal_vec_f32(20 * dim);
        for (spec, name, quantized) in [
            (StorageSpec::flat(), "f32", false),
            (StorageSpec::sq8(), "sq8", true),
            (StorageSpec::pq(), "pq", true),
            (StorageSpec::Pq(PqParams { opq: true, ..Default::default() }), "pq", true),
        ] {
            let store = VectorStore::build(&data, dim, &spec, 7).unwrap();
            assert_eq!(store.len(), 20);
            assert_eq!(store.dim(), dim);
            assert_eq!(store.quantized(), quantized);
            assert_eq!(store.name(), name);
            let mut buf = Vec::new();
            store.write_to(&mut buf).unwrap();
            let back = VectorStore::read_from(&mut buf.as_slice()).unwrap();
            assert_eq!(store, back);
        }
        // Unknown storage tag rejected.
        let mut buf = Vec::new();
        VectorStore::build(&data, dim, &StorageSpec::flat(), 7)
            .unwrap()
            .write_to(&mut buf)
            .unwrap();
        buf[0] = 9;
        let e = VectorStore::read_from(&mut buf.as_slice()).unwrap_err().to_string();
        assert!(e.contains("storage tag"), "{e}");
    }

    #[test]
    fn factory_respects_exact_threshold() {
        let mut rng = Rng::new(7);
        let dim = 4;
        let data = rng.normal_vec_f32(50 * dim);
        let policy = crate::config::IndexPolicy {
            kind: IndexKind::Hnsw,
            exact_threshold: 100,
            ..Default::default()
        };
        let idx = build_index(&data, dim, Metric::SqEuclidean, &policy, 1).unwrap();
        assert_eq!(idx.kind(), IndexKind::Exact);
        let policy = crate::config::IndexPolicy { exact_threshold: 10, ..policy };
        let idx = build_index(&data, dim, Metric::SqEuclidean, &policy, 1).unwrap();
        assert_eq!(idx.kind(), IndexKind::Hnsw);
    }

    #[test]
    fn factory_routes_multi_segment_partitions_to_sharded() {
        let mut rng = Rng::new(9);
        let dim = 4;
        let data = rng.normal_vec_f32(64 * dim);
        let policy = crate::config::IndexPolicy {
            exact_threshold: 0,
            shards: 4,
            shard_min_vectors: 8,
            ..Default::default()
        };
        let idx = build_index(&data, dim, Metric::SqEuclidean, &policy, 1).unwrap();
        assert_eq!(idx.as_sharded().unwrap().num_shards(), 4);
        assert_eq!(idx.len(), 64);
        // A minimum that only allows one shard keeps the bare substrate.
        let policy = crate::config::IndexPolicy { shard_min_vectors: 64, ..policy };
        let idx = build_index(&data, dim, Metric::SqEuclidean, &policy, 1).unwrap();
        assert!(idx.as_sharded().is_none());
    }

    #[test]
    fn factory_rejects_bad_shapes() {
        let policy = crate::config::IndexPolicy::default();
        assert!(build_index(&[1.0; 7], 4, Metric::Euclidean, &policy, 1).is_err());
        assert!(build_index(&[], 4, Metric::Euclidean, &policy, 1).is_err());
        assert!(build_index(&[1.0; 8], 0, Metric::Euclidean, &policy, 1).is_err());
    }

    #[test]
    fn quantized_store_distance_close_to_flat() {
        let mut rng = Rng::new(11);
        let dim = 8;
        let data = rng.normal_vec_f32(30 * dim);
        let flat = VectorStore::build(&data, dim, &StorageSpec::flat(), 1).unwrap();
        let sq8 = VectorStore::build(&data, dim, &StorageSpec::sq8(), 1).unwrap();
        let q = rng.normal_vec_f32(dim);
        let mut scratch = Vec::new();
        for id in 0..30 {
            let d0 = flat.distance(Metric::Euclidean, &q, id, &mut scratch);
            let d1 = sq8.distance(Metric::Euclidean, &q, id, &mut scratch);
            assert!((d0 - d1).abs() < 0.1, "id {id}: {d0} vs {d1}");
        }
        assert!(sq8.memory_bytes() < flat.memory_bytes() / 3);
        // PQ: the generic per-candidate fallback decodes to something in the
        // data's neighborhood. (At this tiny n the codebooks dominate the
        // hot bytes; the ≥8× claim is asserted at realistic n in
        // `tests/props.rs` and the bench.)
        let pq = VectorStore::build(&data, dim, &StorageSpec::pq(), 1).unwrap();
        for id in 0..30 {
            let d0 = flat.distance(Metric::Euclidean, &q, id, &mut scratch);
            let d1 = pq.distance(Metric::Euclidean, &q, id, &mut scratch);
            assert!((d0 - d1).abs() < 2.0, "id {id}: {d0} vs {d1}");
        }
        assert!(pq.memory_bytes() < flat.memory_bytes());
        assert_eq!(pq.cold_bytes(), data.len() * 4);
        assert!(pq.matches(&data));
    }
}
