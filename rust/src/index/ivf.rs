//! IVF-Flat as an [`AnnIndex`] substrate over quantizable storage.
//!
//! Same coarse structure as [`crate::knn::IvfFlatIndex`] (Lloyd k-means
//! centroids + inverted lists, exhaustive scan of the `nprobe` nearest
//! cells) but generalized for the index subsystem: vectors live in a
//! [`VectorStore`] (flat, SQ8 or PQ — the PQ path sweeps ADC tables over
//! the probed cells and reranks at full precision), `nprobe` is part of the
//! built index so the trait-level [`AnnIndex::search`] stays
//! parameter-free, and the whole structure serializes into the `OPDR` index
//! segment format.

use crate::data::mapped::{AnnexWriter, ColdContext};
use crate::error::{OpdrError, Result};
use crate::index::{io, pq, AnnIndex, IndexKind, StorageSpec, VectorStore};
use crate::knn::ivf::{kmeans_train, nearest_centroid};
use crate::knn::topk::top_k_smallest;
use crate::knn::Neighbor;
use crate::metrics::Metric;
use crate::telemetry::SearchTrace;
use crate::util::timer::Stopwatch;
use crate::util::Rng;
use std::io::{Read, Write};

/// Coarse-quantizer shape for [`IvfIndex::build`]: cell count, k-means
/// training iterations, and the default probe width baked into the built
/// index (the trait-level [`AnnIndex::search`] stays parameter-free).
#[derive(Debug, Clone, Copy)]
pub struct IvfParams {
    /// Number of inverted lists (clamped to `[1, n]` at build time).
    pub nlist: usize,
    /// Lloyd iterations for the coarse k-means.
    pub train_iters: usize,
    /// Default cells probed per query (clamped to `[1, nlist]`).
    pub nprobe: usize,
}

/// Inverted-file index with a k-means coarse quantizer.
#[derive(Debug, Clone)]
pub struct IvfIndex {
    metric: Metric,
    nlist: usize,
    nprobe: usize,
    /// `nlist × dim` coarse centroids (always full precision).
    centroids: Vec<f32>,
    /// Inverted lists of vector ids.
    lists: Vec<Vec<u32>>,
    store: VectorStore,
}

impl IvfIndex {
    /// Build with the [`IvfParams`] coarse shape, deterministic from
    /// `seed`. `storage` picks flat/SQ8/PQ for the scanned copy; the coarse
    /// quantizer always trains on the raw full-precision rows.
    pub fn build(
        data: &[f32],
        dim: usize,
        metric: Metric,
        params: IvfParams,
        storage: &StorageSpec,
        seed: u64,
    ) -> Result<IvfIndex> {
        if dim == 0 || data.len() % dim != 0 {
            return Err(OpdrError::shape("ivf index: bad data shape"));
        }
        let n = data.len() / dim;
        if n == 0 {
            return Err(OpdrError::data("ivf index: empty data"));
        }
        let nlist = params.nlist.clamp(1, n);
        let nprobe = params.nprobe.clamp(1, nlist);

        let mut rng = Rng::new(seed);
        let centroids = kmeans_train(data, dim, metric, nlist, params.train_iters, &mut rng);
        let mut lists = vec![Vec::new(); nlist];
        for i in 0..n {
            let c = nearest_centroid(&data[i * dim..(i + 1) * dim], &centroids, dim, metric);
            lists[c].push(i as u32);
        }
        let store = VectorStore::build(data, dim, storage, seed)?;
        Ok(IvfIndex { metric, nlist, nprobe, centroids, lists, store })
    }

    /// Number of inverted lists.
    pub fn nlist(&self) -> usize {
        self.nlist
    }

    /// Default probe width used by [`AnnIndex::search`].
    pub fn nprobe(&self) -> usize {
        self.nprobe
    }

    /// Deserialize (payload written by [`AnnIndex::write_to`]).
    pub(crate) fn read_from(r: &mut dyn Read) -> Result<IvfIndex> {
        IvfIndex::read_with(r, None)
    }

    /// [`IvfIndex::read_from`] with an optional cold context (version-5
    /// files: external payloads resolve against the file's mapped annex).
    pub(crate) fn read_with(r: &mut dyn Read, cx: Option<&ColdContext>) -> Result<IvfIndex> {
        let metric = io::metric_from_tag(io::read_u8(r)?)?;
        let nlist = io::read_u64_usize(r)?;
        let nprobe = io::read_u64_usize(r)?;
        let dim = io::read_u64_usize(r)?;
        if nlist == 0 || dim == 0 {
            return Err(OpdrError::data("ivf index: corrupt header"));
        }
        if nprobe == 0 || nprobe > nlist {
            return Err(OpdrError::data("ivf index: corrupt nprobe"));
        }
        // `nlist` is untrusted: bound the eager preallocation and let the
        // lists grow as bytes arrive (a lying header must truncate, not
        // abort on OOM).
        let centroids = io::read_f32s(r, io::checked_count(nlist, dim)?)?;
        let mut lists = Vec::with_capacity(nlist.min(io::ALLOC_CHUNK));
        for _ in 0..nlist {
            let len = io::read_u64_usize(r)?;
            if len > io::MAX_ELEMS {
                return Err(OpdrError::data("ivf index: corrupt list length"));
            }
            lists.push(io::read_u32s(r, len)?);
        }
        let store = VectorStore::read_with(r, cx)?;
        if store.dim() != dim {
            return Err(OpdrError::data("ivf index: store dim mismatch"));
        }
        let n = store.len();
        if lists.iter().flatten().any(|&id| id as usize >= n) {
            return Err(OpdrError::data("ivf index: list id out of range"));
        }
        Ok(IvfIndex { metric, nlist, nprobe, centroids, lists, store })
    }

    fn write_impl(&self, w: &mut dyn Write, annex: Option<&mut AnnexWriter>) -> Result<()> {
        io::write_u8(w, io::metric_tag(self.metric))?;
        io::write_u64(w, self.nlist as u64)?;
        io::write_u64(w, self.nprobe as u64)?;
        io::write_u64(w, self.dim() as u64)?;
        io::write_f32s(w, &self.centroids)?;
        for list in &self.lists {
            io::write_u64(w, list.len() as u64)?;
            for &id in list {
                io::write_u32(w, id)?;
            }
        }
        self.store.write_with(w, annex)
    }

    fn search_impl(
        &self,
        query: &[f32],
        k: usize,
        trace: Option<&SearchTrace>,
    ) -> Result<Vec<Neighbor>> {
        let dim = self.dim();
        if query.len() != dim {
            return Err(OpdrError::shape(format!(
                "ivf search: query dim {} != index dim {dim}",
                query.len()
            )));
        }
        let sw = Stopwatch::start();
        // Rank cells by centroid distance.
        let cdists: Vec<f32> = (0..self.nlist)
            .map(|c| self.metric.distance(query, &self.centroids[c * dim..(c + 1) * dim]))
            .collect();
        let cells = top_k_smallest(&cdists, self.nprobe);

        if let Some(p) = self.store.as_pq() {
            // Two-stage PQ path: ADC table sweep over the probed cells'
            // members, then full-precision rerank of the top candidates.
            // (The centroid ranking above is a few µs and attributes to the
            // ADC scan stage inside the traced two-stage call.)
            let ids = cells
                .into_iter()
                .flat_map(|(c, _)| self.lists[c].iter().map(|&vid| vid as usize));
            return pq::two_stage_search_traced(p, self.metric, query, ids, k, trace);
        }

        // Exhaustive (asymmetric for SQ8) scan within probed cells.
        let mut cand_idx = Vec::new();
        let mut cand_dist = Vec::new();
        let mut scratch = Vec::new();
        for (c, _) in cells {
            for &vid in &self.lists[c] {
                let d = self.store.distance(self.metric, query, vid as usize, &mut scratch);
                cand_idx.push(vid as usize);
                cand_dist.push(d);
            }
        }
        let picked = top_k_smallest(&cand_dist, k);
        let out = picked
            .into_iter()
            .map(|(pos, distance)| Neighbor { index: cand_idx[pos], distance })
            .collect();
        if let Some(t) = trace {
            t.scan.record(sw.elapsed());
        }
        Ok(out)
    }
}

impl AnnIndex for IvfIndex {
    fn kind(&self) -> IndexKind {
        IndexKind::Ivf
    }

    fn len(&self) -> usize {
        self.store.len()
    }

    fn dim(&self) -> usize {
        self.store.dim()
    }

    fn metric(&self) -> Metric {
        self.metric
    }

    fn quantized(&self) -> bool {
        self.store.quantized()
    }

    fn storage_name(&self) -> &'static str {
        self.store.name()
    }

    fn memory_bytes(&self) -> usize {
        let lists_bytes: usize =
            self.lists.iter().map(|l| l.len() * std::mem::size_of::<u32>()).sum();
        self.store.memory_bytes()
            + self.centroids.len() * std::mem::size_of::<f32>()
            + lists_bytes
    }

    fn cold_bytes(&self) -> usize {
        self.store.cold_bytes()
    }

    fn mapped_bytes(&self) -> usize {
        self.store.mapped_bytes()
    }

    fn matches_data(&self, data: &[f32]) -> bool {
        self.store.matches(data)
    }

    fn search(&self, query: &[f32], k: usize) -> Result<Vec<Neighbor>> {
        self.search_impl(query, k, None)
    }

    fn search_traced(&self, query: &[f32], k: usize, trace: &SearchTrace) -> Result<Vec<Neighbor>> {
        self.search_impl(query, k, Some(trace))
    }

    fn write_to(&self, w: &mut dyn Write) -> Result<()> {
        self.write_impl(w, None)
    }

    fn write_cold(&self, w: &mut dyn Write, annex: &mut AnnexWriter) -> Result<()> {
        self.write_impl(w, Some(annex))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn blobs(n_per: usize, dim: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut data = Vec::new();
        for c in 0..4 {
            let center = 20.0 * c as f32;
            for _ in 0..n_per {
                for k in 0..dim {
                    let base = if k == 0 { center } else { 0.0 };
                    data.push(base + rng.normal() as f32);
                }
            }
        }
        data
    }

    #[test]
    fn full_probe_matches_exact() {
        let dim = 4;
        let data = blobs(20, dim, 3);
        let params = IvfParams { nlist: 8, train_iters: 10, nprobe: 8 };
        let idx =
            IvfIndex::build(&data, dim, Metric::SqEuclidean, params, &StorageSpec::flat(), 7)
                .unwrap();
        let mut rng = Rng::new(11);
        let q = rng.normal_vec_f32(dim);
        let got = idx.search(&q, 5).unwrap();
        let exact = crate::knn::knn_indices(&q, &data, dim, 5, Metric::SqEuclidean).unwrap();
        assert_eq!(
            got.iter().map(|n| n.index).collect::<Vec<_>>(),
            exact.iter().map(|n| n.index).collect::<Vec<_>>()
        );
    }

    #[test]
    fn all_points_indexed_and_params_clamped() {
        let dim = 4;
        let data = blobs(5, dim, 2); // 20 points
        let params = IvfParams { nlist: 500, train_iters: 4, nprobe: 900 };
        let idx = IvfIndex::build(&data, dim, Metric::Euclidean, params, &StorageSpec::flat(), 1)
            .unwrap();
        assert!(idx.nlist() <= 20);
        assert!(idx.nprobe() <= idx.nlist());
        let total: usize = idx.lists.iter().map(|l| l.len()).sum();
        assert_eq!(total, 20);
        assert_eq!(idx.len(), 20);
    }

    #[test]
    fn sq8_shrinks_memory_with_usable_recall() {
        let dim = 8;
        let data = blobs(50, dim, 5);
        let p888 = IvfParams { nlist: 8, train_iters: 8, nprobe: 8 };
        let flat =
            IvfIndex::build(&data, dim, Metric::SqEuclidean, p888, &StorageSpec::flat(), 9)
                .unwrap();
        let sq8 = IvfIndex::build(&data, dim, Metric::SqEuclidean, p888, &StorageSpec::sq8(), 9)
            .unwrap();
        assert!(sq8.memory_bytes() < flat.memory_bytes() / 2);
        let mut hits = 0;
        let k = 5;
        for qi in 0..10 {
            let q = &data[qi * dim..(qi + 1) * dim];
            let want: std::collections::HashSet<usize> =
                flat.search(q, k).unwrap().iter().map(|n| n.index).collect();
            hits += sq8.search(q, k).unwrap().iter().filter(|n| want.contains(&n.index)).count();
        }
        // Quantization may reshuffle near-tied in-cluster ranks; it must not
        // lose the neighborhood wholesale.
        assert!(hits as f64 / (10 * k) as f64 >= 0.6, "sq8 recall {hits}/50");
    }

    #[test]
    fn roundtrip_bit_identical_results() {
        let dim = 6;
        let data = blobs(25, dim, 8);
        for spec in [StorageSpec::flat(), StorageSpec::sq8(), StorageSpec::pq()] {
            let params = IvfParams { nlist: 6, train_iters: 6, nprobe: 3 };
            let idx =
                IvfIndex::build(&data, dim, Metric::SqEuclidean, params, &spec, 4).unwrap();
            let mut buf = Vec::new();
            idx.write_to(&mut buf).unwrap();
            let back = IvfIndex::read_from(&mut buf.as_slice()).unwrap();
            let mut rng = Rng::new(2);
            for _ in 0..5 {
                let q = rng.normal_vec_f32(dim);
                let a = idx.search(&q, 4).unwrap();
                let b = back.search(&q, 4).unwrap();
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(&b) {
                    assert_eq!(x.index, y.index);
                    assert_eq!(x.distance.to_bits(), y.distance.to_bits());
                }
            }
        }
    }

    #[test]
    fn rejects_corrupt_payload() {
        let dim = 4;
        let data = blobs(5, dim, 1);
        let idx =
            IvfIndex::build(
                &data,
                dim,
                Metric::Euclidean,
                IvfParams { nlist: 4, train_iters: 4, nprobe: 2 },
                &StorageSpec::flat(),
                3,
            )
            .unwrap();
        let mut buf = Vec::new();
        idx.write_to(&mut buf).unwrap();
        // Truncation.
        assert!(IvfIndex::read_from(&mut &buf[..buf.len() - 5]).is_err());
        // Corrupt nprobe (> nlist): bytes 1..9 hold nlist, 9..17 nprobe.
        let mut bad = buf.clone();
        bad[9..17].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(IvfIndex::read_from(&mut bad.as_slice()).is_err());
    }

    #[test]
    fn query_dim_checked() {
        let dim = 4;
        let data = blobs(5, dim, 1);
        let idx =
            IvfIndex::build(
                &data,
                dim,
                Metric::Euclidean,
                IvfParams { nlist: 4, train_iters: 4, nprobe: 2 },
                &StorageSpec::flat(),
                3,
            )
            .unwrap();
        assert!(idx.search(&[0.0; 5], 2).is_err());
    }
}
