//! Product-quantized (PQ / OPQ) vector storage with ADC search and an
//! order-exact full-precision rerank.
//!
//! The vector is split into `m` contiguous subspaces of `dim/m` dimensions;
//! each subspace gets its own k-means codebook of `ksub` centroids (trained
//! through the shared [`kmeans_train`] kernel), and a vector is stored as
//! `m` codebook indices — one byte per subquantizer, or a nibble when
//! `ksub ≤ 16` (two codes packed per byte). With the default `m = dim/2`,
//! `ksub = 16` configuration the hot serving payload is ~16× smaller than
//! flat f32, versus ~4× for SQ8.
//!
//! **OPQ**: an optional learned orthonormal rotation applied before
//! encoding, trained by alternating least squares (Ge et al.'s OPQ-NP):
//! alternate (a) codebook training + assignment in the rotated space with
//! (b) the orthogonal Procrustes update `R = U Vᵀ` from the SVD of
//! `X̂ᵀX` — computed here from the symmetric eigendecomposition
//! ([`crate::linalg::eigh`]) of `(X̂ᵀX)ᵀ(X̂ᵀX)`. Rotation spreads variance
//! across subspaces so the per-subspace codebooks waste fewer bits.
//!
//! **ADC** (asymmetric distance computation): at query time the query stays
//! full precision; one `m × ksub` lookup table of per-subspace partial
//! distances is built per query, after which every candidate costs `m` table
//! adds instead of a `dim`-wide decode + distance. All five metrics are
//! supported (cosine keeps a second per-subspace squared-norm table).
//!
//! **Two-stage search** (the exactness contract, machine-checked in
//! `tests/props.rs::prop_pq_rerank_is_order_exact_at_full_depth`): the ADC
//! scan is only a candidate generator. The top `rerank_depth` ADC candidates
//! are re-scored against the full-precision rows through the same
//! [`merge_top_k`] kernel every other index path uses, so the final order is
//! decided by exact distances. At exhaustive `rerank_depth ≥ n` the returned
//! top-k is therefore **bit-identical** to [`crate::index::ExactIndex`] over
//! flat storage — for every substrate (exact / IVF at full probe / HNSW at
//! exhaustive beam), sharded or not, PQ compression costs zero correctness.
//!
//! The full-precision rows live in a `rerank` tier held by the storage but
//! excluded from [`PqStorage::memory_bytes`] (reported separately by
//! [`PqStorage::rerank_bytes`]): codes + codebooks + rotation are the hot
//! RAM-resident copy, while the rerank tier is a
//! [`RowBlock`] — RAM-resident by default, or served
//! **zero-copy from an mmap'd on-disk cold file** when the storage was
//! built with [`crate::index::ColdTier::Mmap`] (or loaded from a version-5
//! `OPDR` file, whose 64-byte-aligned annex maps in place). The tier never
//! changes results: rerank distances are computed from the same bits
//! either way.

use crate::data::mapped::{AnnexWriter, ColdContext, RowBlock};
use crate::error::{OpdrError, Result};
use crate::index::io;
use crate::knn::ivf::{kmeans_train, nearest_centroid};
use crate::knn::topk::merge_top_k;
use crate::knn::Neighbor;
use crate::linalg::{eigh, Mat};
use crate::metrics::{manhattan, sq_euclidean, Metric};
use crate::telemetry::SearchTrace;
use crate::util::float::{dot_f32, norm_sq_f32};
use crate::util::timer::Stopwatch;
use crate::util::Rng;
use std::io::{Read, Write};

/// Training / search parameters for PQ storage (assembled from
/// [`crate::config::IndexPolicy`] by
/// [`crate::config::IndexPolicy::storage_spec`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PqParams {
    /// Number of subquantizers; 0 = auto (`dim/2`, i.e. 2-dim subspaces).
    /// Clamped to the largest divisor of `dim` not exceeding the request.
    pub m: usize,
    /// Centroids per subspace, clamped to `[2, 256]` (and to `n`). Values
    /// ≤ 16 store two codes per byte.
    pub ksub: usize,
    /// Train an OPQ rotation before encoding.
    pub opq: bool,
    /// Lloyd iterations per subspace codebook.
    pub train_iters: usize,
    /// Alternating-least-squares rounds for the OPQ rotation.
    pub opq_iters: usize,
    /// ADC candidates re-scored at full precision per query (raised to `k`
    /// when `k` is larger; `≥ n` makes the search exactly [`ExactIndex`]-
    /// equal).
    ///
    /// [`ExactIndex`]: crate::index::ExactIndex
    pub rerank_depth: usize,
}

impl Default for PqParams {
    fn default() -> Self {
        PqParams { m: 0, ksub: 16, opq: false, train_iters: 10, opq_iters: 4, rerank_depth: 64 }
    }
}

/// PQ-encoded vectors: per-subspace codebooks, packed codes, optional OPQ
/// rotation, plus the full-precision rerank tier.
#[derive(Debug, Clone, PartialEq)]
pub struct PqStorage {
    n: usize,
    dim: usize,
    /// Subquantizer count (divides `dim`).
    m: usize,
    /// Dimensions per subspace (`dim / m`).
    dsub: usize,
    /// Centroids per subspace (≤ 256; ≤ 16 packs two codes per byte).
    ksub: usize,
    /// Default ADC candidate depth for the two-stage search.
    rerank_depth: usize,
    /// OPQ rotation, row-major `dim × dim` (`y = R·x`), when trained.
    rotation: Option<Vec<f32>>,
    /// `m × ksub × dsub` centroids.
    codebooks: Vec<f32>,
    /// Row-major codes, `n × row_bytes` (nibble-packed when `ksub ≤ 16`).
    codes: Vec<u8>,
    /// Full-precision rows (cold rerank tier, original/unrotated space) —
    /// RAM-resident or served from an mmap'd cold file.
    rerank: RowBlock,
}

impl PqStorage {
    /// Train codebooks (and optionally an OPQ rotation) on `data` and encode
    /// every row. Deterministic from `seed`.
    pub fn train(data: &[f32], dim: usize, params: &PqParams, seed: u64) -> Result<PqStorage> {
        if dim == 0 || data.len() % dim != 0 {
            return Err(OpdrError::shape("pq: bad data shape"));
        }
        let n = data.len() / dim;
        if n == 0 {
            return Err(OpdrError::data("pq: empty data"));
        }
        if data.iter().any(|x| !x.is_finite()) {
            return Err(OpdrError::numeric("pq: non-finite input"));
        }
        let want_m = if params.m == 0 { (dim / 2).max(1) } else { params.m.min(dim).max(1) };
        // Largest divisor of dim not exceeding the request (1 always works).
        let m = (1..=want_m).rev().find(|mm| dim % mm == 0).unwrap_or(1);
        let dsub = dim / m;
        let ksub = params.ksub.clamp(2, 256).min(n);
        let train_iters = params.train_iters.max(1);
        let rerank_depth = params.rerank_depth.max(1);
        let mut rng = Rng::new(seed);

        let shape = PqShape { m, dsub, ksub };
        let rotation = if params.opq && dim > 1 {
            train_opq_rotation(
                data,
                dim,
                n,
                shape,
                train_iters.min(4),
                params.opq_iters.max(1),
                &mut rng,
            )?
        } else {
            None
        };

        let rotated;
        let y: &[f32] = match &rotation {
            Some(r) => {
                rotated = rotate_rows(data, dim, r);
                &rotated
            }
            None => data,
        };
        let codebooks = train_codebooks(y, n, dim, shape, train_iters, &mut rng);
        let codes = encode_all(y, n, dim, shape, &codebooks);
        Ok(PqStorage {
            n,
            dim,
            m,
            dsub,
            ksub,
            rerank_depth,
            rotation,
            codebooks,
            codes,
            rerank: RowBlock::from_ram(dim, data.to_vec())?,
        })
    }

    /// Spill the rerank tier to a fresh cold file under `dir` and serve it
    /// mapped (heap fallback where mmap is unavailable). The file lives
    /// exactly as long as this storage; results are bit-identical to the
    /// RAM tier.
    pub fn spill_cold(&mut self, dir: &std::path::Path) -> Result<()> {
        let mut rows = Vec::with_capacity(self.n * self.dim);
        for i in 0..self.n {
            rows.extend_from_slice(self.rerank.row(i));
        }
        self.rerank = RowBlock::spill(dir, &rows, self.dim)?;
        Ok(())
    }

    /// Number of encoded vectors.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Vector dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Number of subquantizers.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Centroids per subspace.
    pub fn ksub(&self) -> usize {
        self.ksub
    }

    /// Default ADC candidate depth of the two-stage search.
    pub fn rerank_depth(&self) -> usize {
        self.rerank_depth
    }

    /// True when an OPQ rotation is applied before encoding.
    pub fn has_rotation(&self) -> bool {
        self.rotation.is_some()
    }

    /// Two codes per byte?
    #[inline]
    fn packed(&self) -> bool {
        self.ksub <= 16
    }

    /// Code bytes per row.
    #[inline]
    fn row_bytes(&self) -> usize {
        row_bytes_for(self.m, self.ksub)
    }

    /// Code of vector `id` in subspace `s`.
    #[inline]
    pub(crate) fn code(&self, id: usize, s: usize) -> usize {
        code_at(&self.codes, self.row_bytes(), self.packed(), id, s)
    }

    /// Decode vector `id` (the rotated-space reconstruction when OPQ is on)
    /// into `out` (must be `dim` long).
    pub fn decode_into(&self, id: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim);
        for s in 0..self.m {
            let c = self.code(id, s);
            let cent = &self.codebooks[(s * self.ksub + c) * self.dsub..][..self.dsub];
            out[s * self.dsub..(s + 1) * self.dsub].copy_from_slice(cent);
        }
    }

    /// Decode vector `id` into a fresh Vec.
    pub fn reconstruct(&self, id: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim];
        self.decode_into(id, &mut out);
        out
    }

    /// Rotate a query into the encoded space (identity copy without OPQ).
    pub fn rotate_query(&self, q: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim];
        self.rotate_query_into(q, &mut out);
        out
    }

    /// [`PqStorage::rotate_query`] into a caller-provided buffer (must be
    /// `dim` long) — no allocation.
    pub fn rotate_query_into(&self, q: &[f32], out: &mut [f32]) {
        match &self.rotation {
            Some(r) => rotate_into(q, self.dim, r, out),
            None => out.copy_from_slice(q),
        }
    }

    /// Full-precision row `id` (the cold rerank tier — resident or served
    /// zero-copy from the mapped cold file).
    #[inline]
    pub fn rerank_row(&self, id: usize) -> &[f32] {
        self.rerank.row(id)
    }

    /// Hot resident bytes: codes + codebooks + rotation. The full-precision
    /// rerank tier is excluded (see [`PqStorage::rerank_bytes`] and the
    /// module docs).
    pub fn memory_bytes(&self) -> usize {
        self.codes.len()
            + self.codebooks.len() * std::mem::size_of::<f32>()
            + self.rotation.as_ref().map_or(0, |r| r.len() * std::mem::size_of::<f32>())
    }

    /// Total bytes of the cold full-precision rerank tier (resident +
    /// mapped; see [`PqStorage::mapped_bytes`] for the split).
    pub fn rerank_bytes(&self) -> usize {
        self.rerank.total_bytes()
    }

    /// Rerank-tier bytes served zero-copy from an mmap'd cold file (0 for
    /// the RAM tier or the heap fallback).
    pub fn mapped_bytes(&self) -> usize {
        self.rerank.mapped_bytes()
    }

    /// True when this store was built from exactly `other` (the rerank tier
    /// keeps the original rows, so the check is bitwise).
    pub fn matches(&self, other: &[f32]) -> bool {
        self.rerank.matches(other)
    }

    /// Serialize the header + hot copy (everything but the rerank tier):
    /// the shared prefix of the inline (tag 2) and external (tag 3)
    /// records.
    fn write_hot(&self, w: &mut dyn Write) -> Result<()> {
        io::write_u64(w, self.n as u64)?;
        io::write_u64(w, self.dim as u64)?;
        io::write_u64(w, self.m as u64)?;
        io::write_u64(w, self.ksub as u64)?;
        io::write_u64(w, self.rerank_depth as u64)?;
        io::write_u8(w, u8::from(self.rotation.is_some()))?;
        if let Some(r) = &self.rotation {
            io::write_f32s(w, r)?;
        }
        io::write_f32s(w, &self.codebooks)?;
        io::write_bytes(w, &self.codes)
    }

    /// Serialize (the `pq` record kind inside `OPDR` index segments): hot
    /// copy + the rerank rows inline.
    pub(crate) fn write_to(&self, w: &mut dyn Write) -> Result<()> {
        self.write_hot(w)?;
        self.rerank.write_f32s(w)
    }

    /// Serialize for a version-5 cold file: the rerank rows move into the
    /// file's 64-byte-aligned annex and only their `u64` start row stays
    /// in the record, so the loaded tier serves mapped in place.
    pub(crate) fn write_external(&self, w: &mut dyn Write, annex: &mut AnnexWriter) -> Result<()> {
        self.write_hot(w)?;
        io::write_u64(w, annex.push_rows(&self.rerank)?)
    }

    /// Deserialize the inline (tag 2) record — the rerank rows follow the
    /// codes; every structural invariant is validated so a corrupt record
    /// fails loudly instead of serving garbage distances.
    pub(crate) fn read_from(r: &mut dyn Read) -> Result<PqStorage> {
        PqStorage::read_with(r, None)
    }

    /// Deserialize the external (tag 3) record of a version-5 cold file —
    /// the rerank tier resolves to a window of the file's annex (mapped
    /// where possible) instead of being decoded.
    pub(crate) fn read_external(r: &mut dyn Read, cx: &ColdContext) -> Result<PqStorage> {
        PqStorage::read_with(r, Some(cx))
    }

    fn read_with(r: &mut dyn Read, external: Option<&ColdContext>) -> Result<PqStorage> {
        let n = io::read_u64_usize(r)?;
        let dim = io::read_u64_usize(r)?;
        let m = io::read_u64_usize(r)?;
        let ksub = io::read_u64_usize(r)?;
        let rerank_depth = io::read_u64_usize(r)?;
        if dim == 0 || n == 0 {
            return Err(OpdrError::data("pq: corrupt header"));
        }
        if m == 0 || m > dim || dim % m != 0 {
            return Err(OpdrError::data(format!(
                "pq: corrupt subquantizer count {m} for dim {dim}"
            )));
        }
        if ksub == 0 || ksub > 256 {
            return Err(OpdrError::data(format!("pq: corrupt ksub {ksub}")));
        }
        if rerank_depth == 0 {
            return Err(OpdrError::data("pq: corrupt rerank depth"));
        }
        let dsub = dim / m;
        let rotation = match io::read_u8(r)? {
            0 => None,
            1 => {
                let rot = io::read_f32s(r, io::checked_count(dim, dim)?)?;
                if rot.iter().any(|x| !x.is_finite()) {
                    return Err(OpdrError::data("pq: corrupt rotation"));
                }
                Some(rot)
            }
            other => return Err(OpdrError::data(format!("pq: bad rotation flag {other}"))),
        };
        let cb_count = io::checked_count(io::checked_count(m, ksub)?, dsub)?;
        let codebooks = io::read_f32s(r, cb_count)?;
        if codebooks.iter().any(|x| !x.is_finite()) {
            return Err(OpdrError::data("pq: corrupt codebook"));
        }
        let row_bytes = row_bytes_for(m, ksub);
        let codes = io::read_bytes(r, io::checked_count(n, row_bytes)?)?;
        let rerank = match external {
            None => {
                let rows = io::read_f32s(r, io::checked_count(n, dim)?)?;
                if rows.iter().any(|x| !x.is_finite()) {
                    return Err(OpdrError::data("pq: corrupt rerank payload"));
                }
                RowBlock::from_ram(dim, rows)?
            }
            Some(cx) => {
                // The rerank rows live in the enclosing cold file's annex;
                // resolve (and range-check) the reference. The NaN scan is
                // deliberately skipped here: paging a larger-than-RAM tier
                // in at load time would defeat it, and a NaN row degrades
                // to being skipped by the top-k contract, never to a wrong
                // neighbor.
                let start = io::read_u64_usize(r)?;
                if cx.file.dim() != dim {
                    return Err(OpdrError::data(format!(
                        "pq: external rerank tier is dim {} but the annex is dim {}",
                        dim,
                        cx.file.dim()
                    )));
                }
                RowBlock::tiered(std::sync::Arc::clone(&cx.file), start, n)?
            }
        };
        let store = PqStorage {
            n,
            dim,
            m,
            dsub,
            ksub,
            rerank_depth,
            rotation,
            codebooks,
            codes,
            rerank,
        };
        for id in 0..n {
            for s in 0..m {
                if store.code(id, s) >= ksub {
                    return Err(OpdrError::data(format!(
                        "pq: code out of range in row {id} subspace {s}"
                    )));
                }
            }
            // An odd subquantizer count leaves the top nibble of each row's
            // last byte unused; it must be zero (anything else is corruption).
            if store.packed() && m % 2 == 1 {
                let last = store.codes[id * row_bytes + row_bytes - 1];
                if last >> 4 != 0 {
                    return Err(OpdrError::data(format!("pq: stray bits in row {id}")));
                }
            }
        }
        Ok(store)
    }
}

/// Code bytes per row for a given `(m, ksub)`.
#[inline]
fn row_bytes_for(m: usize, ksub: usize) -> usize {
    if ksub <= 16 {
        m.div_ceil(2)
    } else {
        m
    }
}

/// Read the code of row `id`, subspace `s` from a raw code buffer — the one
/// place that knows the packed-nibble layout (low nibble = even subspace).
#[inline]
fn code_at(codes: &[u8], row_bytes: usize, packed: bool, id: usize, s: usize) -> usize {
    if packed {
        let b = codes[id * row_bytes + s / 2];
        (if s % 2 == 0 { b & 0x0F } else { b >> 4 }) as usize
    } else {
        codes[id * row_bytes + s] as usize
    }
}

/// Rotate one vector: `out = R·x` (row-major `R`, `dim × dim`).
fn rotate_into(x: &[f32], dim: usize, r: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), dim);
    for (a, o) in out.iter_mut().enumerate() {
        *o = dot_f32(&r[a * dim..(a + 1) * dim], x);
    }
}

/// Rotate every row of a row-major block.
fn rotate_rows(data: &[f32], dim: usize, r: &[f32]) -> Vec<f32> {
    let n = data.len() / dim;
    let mut out = vec![0.0f32; data.len()];
    for i in 0..n {
        let (src, dst) = (&data[i * dim..(i + 1) * dim], &mut out[i * dim..(i + 1) * dim]);
        rotate_into(src, dim, r, dst);
    }
    out
}

/// Subspace geometry threaded through the raw training/encoding helpers
/// (before a [`PqStorage`] exists to carry it): subquantizer count, dims
/// per subspace, centroids per subspace.
#[derive(Debug, Clone, Copy)]
struct PqShape {
    m: usize,
    dsub: usize,
    ksub: usize,
}

/// Train one k-means codebook per subspace over (possibly rotated) rows `y`.
fn train_codebooks(
    y: &[f32],
    n: usize,
    dim: usize,
    shape: PqShape,
    iters: usize,
    rng: &mut Rng,
) -> Vec<f32> {
    let PqShape { m, dsub, ksub } = shape;
    let mut codebooks = Vec::with_capacity(m * ksub * dsub);
    let mut sub = vec![0.0f32; n * dsub];
    for s in 0..m {
        for i in 0..n {
            sub[i * dsub..(i + 1) * dsub]
                .copy_from_slice(&y[i * dim + s * dsub..i * dim + (s + 1) * dsub]);
        }
        // PQ codebooks always minimize L2 reconstruction error; the serving
        // metric is applied at ADC/rerank time.
        codebooks.extend_from_slice(&kmeans_train(
            &sub,
            dsub,
            Metric::SqEuclidean,
            ksub,
            iters,
            rng,
        ));
    }
    codebooks
}

/// Assign every row to its nearest centroid per subspace and pack the codes.
fn encode_all(y: &[f32], n: usize, dim: usize, shape: PqShape, codebooks: &[f32]) -> Vec<u8> {
    let PqShape { m, dsub, ksub } = shape;
    let packed = ksub <= 16;
    let row_bytes = row_bytes_for(m, ksub);
    let mut codes = vec![0u8; n * row_bytes];
    for i in 0..n {
        for s in 0..m {
            let xs = &y[i * dim + s * dsub..i * dim + (s + 1) * dsub];
            let cb = &codebooks[s * ksub * dsub..(s + 1) * ksub * dsub];
            let c = nearest_centroid(xs, cb, dsub, Metric::SqEuclidean) as u8;
            if packed {
                let byte = &mut codes[i * row_bytes + s / 2];
                *byte |= if s % 2 == 0 { c } else { c << 4 };
            } else {
                codes[i * row_bytes + s] = c;
            }
        }
    }
    codes
}

/// Decode one row from raw codebooks/codes (used during OPQ training before
/// a `PqStorage` exists).
fn decode_raw(codes: &[u8], codebooks: &[f32], id: usize, shape: PqShape, out: &mut [f32]) {
    let PqShape { m, dsub, ksub } = shape;
    let packed = ksub <= 16;
    let row_bytes = row_bytes_for(m, ksub);
    for s in 0..m {
        let c = code_at(codes, row_bytes, packed, id, s);
        let cent = &codebooks[(s * ksub + c) * dsub..][..dsub];
        out[s * dsub..(s + 1) * dsub].copy_from_slice(cent);
    }
}

/// OPQ-NP alternating least squares: alternate codebook training in the
/// rotated space with the orthogonal Procrustes update `R = U Vᵀ` from the
/// SVD of `M = X̂ᵀX` (computed via [`eigh`] of `MᵀM`: `MᵀM = V Σ² Vᵀ`,
/// `U = M V Σ⁻¹`). A rank-deficient `M` (degenerate data) keeps the last
/// well-defined rotation instead of dividing by ~0 singular values.
fn train_opq_rotation(
    data: &[f32],
    dim: usize,
    n: usize,
    shape: PqShape,
    kmeans_iters: usize,
    opq_iters: usize,
    rng: &mut Rng,
) -> Result<Option<Vec<f32>>> {
    // Identity start.
    let mut r = vec![0.0f32; dim * dim];
    for a in 0..dim {
        r[a * dim + a] = 1.0;
    }
    let mut decoded = vec![0.0f32; dim];
    for _ in 0..opq_iters {
        let y = rotate_rows(data, dim, &r);
        let codebooks = train_codebooks(&y, n, dim, shape, kmeans_iters, rng);
        let codes = encode_all(&y, n, dim, shape, &codebooks);
        // M[a][b] = Σ_i x̂_i[a] · x_i[b]  (reconstructions vs raw rows).
        let mut mdat = vec![0.0f64; dim * dim];
        for i in 0..n {
            decode_raw(&codes, &codebooks, i, shape, &mut decoded);
            let x = &data[i * dim..(i + 1) * dim];
            for a in 0..dim {
                let xa = decoded[a] as f64;
                if xa == 0.0 {
                    continue;
                }
                let row = &mut mdat[a * dim..(a + 1) * dim];
                for b in 0..dim {
                    row[b] += xa * x[b] as f64;
                }
            }
        }
        let mmat = Mat::from_vec(dim, dim, mdat)?;
        let mtm = mmat.transpose().matmul(&mmat)?;
        let eig = match eigh(&mtm) {
            Ok(e) => e,
            Err(_) => break,
        };
        let smax = eig.values.first().copied().unwrap_or(0.0);
        if smax <= 0.0 || eig.values.iter().any(|&v| v <= 1e-12 * smax) {
            break; // rank-deficient: keep the last rotation
        }
        // U = M V Σ⁻¹, then R = U Vᵀ.
        let v = &eig.vectors;
        let mut u = mmat.matmul(v)?;
        for (k, &lambda) in eig.values.iter().enumerate() {
            let sigma = lambda.sqrt();
            for a in 0..dim {
                u[(a, k)] /= sigma;
            }
        }
        let rnew = u.matmul(&v.transpose())?;
        r = rnew.data().iter().map(|&x| x as f32).collect();
    }
    Ok(Some(r))
}

// ---------------------------------------------------------------------------
// ADC lookup tables + the two-stage search shared by every substrate.
// ---------------------------------------------------------------------------

/// Per-query ADC lookup tables: `m × ksub` partial terms so each candidate
/// costs `m` table adds. Cosine carries a second squared-norm table (the
/// reconstruction norm decomposes additively across subspaces).
#[derive(Debug)]
pub struct AdcTable<'a> {
    pq: &'a PqStorage,
    metric: Metric,
    /// `m × ksub` partial distances (sq-L2 / L1) or partial dots (cosine,
    /// negdot).
    lut: Vec<f32>,
    /// Cosine only: `m × ksub` centroid squared norms.
    norm_lut: Vec<f32>,
    /// Cosine only: query L2 norm.
    q_norm: f32,
}

impl<'a> AdcTable<'a> {
    /// Build the table for one query (rotating it into the encoded space
    /// when OPQ is on).
    pub fn new(pq: &'a PqStorage, metric: Metric, query: &[f32]) -> Result<AdcTable<'a>> {
        if query.len() != pq.dim {
            return Err(OpdrError::shape(format!(
                "pq adc: query dim {} != storage dim {}",
                query.len(),
                pq.dim
            )));
        }
        let rotated;
        let q: &[f32] = match &pq.rotation {
            Some(_) => {
                rotated = pq.rotate_query(query);
                &rotated
            }
            None => query,
        };
        let (m, ksub, dsub) = (pq.m, pq.ksub, pq.dsub);
        let cosine = metric == Metric::Cosine;
        let mut lut = vec![0.0f32; m * ksub];
        let mut norm_lut = if cosine { vec![0.0f32; m * ksub] } else { Vec::new() };
        for s in 0..m {
            let qs = &q[s * dsub..(s + 1) * dsub];
            for c in 0..ksub {
                let cent = &pq.codebooks[(s * ksub + c) * dsub..][..dsub];
                lut[s * ksub + c] = match metric {
                    Metric::SqEuclidean | Metric::Euclidean => sq_euclidean(qs, cent),
                    Metric::Manhattan => manhattan(qs, cent),
                    Metric::Cosine | Metric::NegDot => dot_f32(qs, cent),
                };
                if cosine {
                    norm_lut[s * ksub + c] = norm_sq_f32(cent);
                }
            }
        }
        let q_norm = if cosine { norm_sq_f32(q).sqrt() } else { 0.0 };
        Ok(AdcTable { pq, metric, lut, norm_lut, q_norm })
    }

    /// ADC distance from the table's query to encoded vector `id`.
    #[inline]
    pub fn lookup(&self, id: usize) -> f32 {
        let (m, ksub) = (self.pq.m, self.pq.ksub);
        if self.metric == Metric::Cosine {
            let mut dot = 0.0f32;
            let mut nsq = 0.0f32;
            for s in 0..m {
                let c = self.pq.code(id, s);
                dot += self.lut[s * ksub + c];
                nsq += self.norm_lut[s * ksub + c];
            }
            let nx = nsq.sqrt();
            if self.q_norm == 0.0 || nx == 0.0 {
                return 1.0;
            }
            return 1.0 - dot / (self.q_norm * nx);
        }
        let mut acc = 0.0f32;
        for s in 0..m {
            acc += self.lut[s * ksub + self.pq.code(id, s)];
        }
        match self.metric {
            Metric::SqEuclidean | Metric::Manhattan => acc,
            Metric::Euclidean => acc.sqrt(),
            Metric::NegDot => -acc,
            Metric::Cosine => unreachable!("cosine handled above"),
        }
    }
}

/// Stage 2: re-score candidate ids against the full-precision rerank rows
/// and select the top `k` through the shared [`merge_top_k`] kernel. With
/// the candidate set covering all rows this is exactly the flat exact scan
/// (same distances, same (distance, index) tie-break, NaN skipped).
pub(crate) fn rerank(
    pq: &PqStorage,
    metric: Metric,
    query: &[f32],
    ids: impl IntoIterator<Item = usize>,
    k: usize,
) -> Vec<Neighbor> {
    merge_top_k(
        ids.into_iter().map(|id| (id, metric.distance(query, pq.rerank_row(id)))),
        k,
    )
    .into_iter()
    .map(|(index, distance)| Neighbor { index, distance })
    .collect()
}

/// The full two-stage search over a candidate id stream: ADC-scan the ids,
/// keep the best `max(rerank_depth, k)`, then [`rerank`] them at full
/// precision. Used by the exact scan (all ids) and IVF (probed cells).
pub(crate) fn two_stage_search(
    pq: &PqStorage,
    metric: Metric,
    query: &[f32],
    ids: impl IntoIterator<Item = usize>,
    k: usize,
) -> Result<Vec<Neighbor>> {
    two_stage_search_traced(pq, metric, query, ids, k, None)
}

/// [`two_stage_search`] with the ADC scan and the full-precision rerank
/// attributed to their stage histograms. Results are identical with or
/// without a trace — the stopwatches sit between stages, not inside them.
pub(crate) fn two_stage_search_traced(
    pq: &PqStorage,
    metric: Metric,
    query: &[f32],
    ids: impl IntoIterator<Item = usize>,
    k: usize,
    trace: Option<&SearchTrace>,
) -> Result<Vec<Neighbor>> {
    let sw = Stopwatch::start();
    let table = AdcTable::new(pq, metric, query)?;
    let depth = pq.rerank_depth.max(k);
    let cands = merge_top_k(ids.into_iter().map(|id| (id, table.lookup(id))), depth);
    if let Some(t) = trace {
        t.scan.record(sw.elapsed());
    }
    let sw = Stopwatch::start();
    let out = rerank(pq, metric, query, cands.into_iter().map(|(id, _)| id), k);
    if let Some(t) = trace {
        t.rerank.record(sw.elapsed());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::knn::topk::top_k_smallest;

    const METRICS: [Metric; 5] = [
        Metric::SqEuclidean,
        Metric::Euclidean,
        Metric::Cosine,
        Metric::Manhattan,
        Metric::NegDot,
    ];

    fn normal_data(n: usize, dim: usize, seed: u64) -> Vec<f32> {
        Rng::new(seed).normal_vec_f32(n * dim)
    }

    #[test]
    fn subquantizer_count_adapts_to_dim() {
        let data = normal_data(20, 8, 1);
        let pq = PqStorage::train(&data, 8, &PqParams::default(), 1).unwrap();
        assert_eq!(pq.m(), 4); // auto = dim/2
        assert_eq!(pq.dim(), 8);
        // Prime dim: the only divisor ≤ dim/2 is 1.
        let data = normal_data(20, 7, 2);
        let pq = PqStorage::train(&data, 7, &PqParams::default(), 1).unwrap();
        assert_eq!(pq.m(), 1);
        // Explicit non-divisor request falls back to the largest divisor.
        let data = normal_data(20, 12, 3);
        let pq =
            PqStorage::train(&data, 12, &PqParams { m: 5, ..Default::default() }, 1).unwrap();
        assert_eq!(pq.m(), 4);
    }

    #[test]
    fn reconstruction_is_finite_and_roughly_close() {
        let dim = 8;
        let n = 200;
        let data = normal_data(n, dim, 5);
        let pq = PqStorage::train(&data, dim, &PqParams::default(), 7).unwrap();
        assert_eq!(pq.len(), n);
        let mut worst = 0.0f32;
        for id in 0..n {
            let rec = pq.reconstruct(id);
            assert!(rec.iter().all(|x| x.is_finite()));
            let err = sq_euclidean(&rec, &data[id * dim..(id + 1) * dim]);
            worst = worst.max(err);
        }
        // 16 centroids per 2-dim subspace of unit normals: coarse but sane
        // (the bound is deliberately loose — outliers land far from their
        // nearest centroid; exactness never depends on reconstruction).
        assert!(worst < 4.0 * dim as f32, "worst sq reconstruction error {worst}");
    }

    #[test]
    fn packing_kicks_in_at_ksub_16() {
        let dim = 8;
        let data = normal_data(100, dim, 9);
        let small =
            PqStorage::train(&data, dim, &PqParams { ksub: 16, ..Default::default() }, 1).unwrap();
        let big =
            PqStorage::train(&data, dim, &PqParams { ksub: 17, ..Default::default() }, 1).unwrap();
        assert!(small.packed());
        assert!(!big.packed());
        assert_eq!(small.codes.len(), 100 * 2); // m=4 packed
        assert_eq!(big.codes.len(), 100 * 4);
        // Codes survive the nibble round-trip.
        for id in [0usize, 13, 99] {
            for s in 0..small.m() {
                assert!(small.code(id, s) < 16);
            }
        }
    }

    #[test]
    fn two_stage_at_full_depth_is_bitwise_exact_for_every_metric() {
        let dim = 6;
        let n = 50;
        let mut data = normal_data(n, dim, 11);
        // Duplicate rows so tie-breaking is load-bearing.
        data.copy_within(0..dim, 3 * dim);
        data.copy_within(0..dim, 17 * dim);
        for opq in [false, true] {
            let params = PqParams { opq, rerank_depth: n + 5, ..Default::default() };
            let pq = PqStorage::train(&data, dim, &params, 3).unwrap();
            let mut rng = Rng::new(21);
            for metric in METRICS {
                for k in [1usize, 7, n, n + 3] {
                    let q = rng.normal_vec_f32(dim);
                    let got = two_stage_search(&pq, metric, &q, 0..n, k).unwrap();
                    let dists: Vec<f32> = (0..n)
                        .map(|id| metric.distance(&q, &data[id * dim..(id + 1) * dim]))
                        .collect();
                    let want = top_k_smallest(&dists, k);
                    assert_eq!(got.len(), want.len(), "opq={opq} {} k={k}", metric.name());
                    for (g, (wi, wd)) in got.iter().zip(&want) {
                        assert_eq!(g.index, *wi, "opq={opq} {} k={k}", metric.name());
                        assert_eq!(g.distance.to_bits(), wd.to_bits());
                    }
                }
            }
        }
    }

    #[test]
    fn nan_query_yields_empty_results_like_exact() {
        let dim = 4;
        let n = 20;
        let data = normal_data(n, dim, 13);
        let pq = PqStorage::train(
            &data,
            dim,
            &PqParams { rerank_depth: n, ..Default::default() },
            1,
        )
        .unwrap();
        let q = vec![f32::NAN; dim];
        let got = two_stage_search(&pq, Metric::SqEuclidean, &q, 0..n, 5).unwrap();
        assert!(got.is_empty());
    }

    #[test]
    fn adc_tracks_true_reconstruction_distance() {
        let dim = 8;
        let n = 120;
        let data = normal_data(n, dim, 17);
        for opq in [false, true] {
            let pq = PqStorage::train(
                &data,
                dim,
                &PqParams { opq, ..Default::default() },
                5,
            )
            .unwrap();
            let mut rng = Rng::new(3);
            let q = rng.normal_vec_f32(dim);
            for metric in METRICS {
                let table = AdcTable::new(&pq, metric, &q).unwrap();
                let rq = pq.rotate_query(&q);
                let mut dec = vec![0.0f32; dim];
                for id in [0usize, 7, 64, n - 1] {
                    pq.decode_into(id, &mut dec);
                    let want = metric.distance(&rq, &dec);
                    let got = table.lookup(id);
                    assert!(
                        (got - want).abs() <= 1e-3 * (1.0 + want.abs()),
                        "opq={opq} {} id {id}: adc {got} vs decode {want}",
                        metric.name()
                    );
                }
            }
        }
    }

    #[test]
    fn opq_rotation_is_orthonormal() {
        let dim = 6;
        let data = normal_data(150, dim, 23);
        let pq = PqStorage::train(
            &data,
            dim,
            &PqParams { opq: true, ..Default::default() },
            9,
        )
        .unwrap();
        assert!(pq.has_rotation());
        let r = pq.rotation.as_ref().unwrap();
        // R Rᵀ ≈ I.
        for a in 0..dim {
            for b in 0..dim {
                let mut s = 0.0f64;
                for k in 0..dim {
                    s += r[a * dim + k] as f64 * r[b * dim + k] as f64;
                }
                let want = if a == b { 1.0 } else { 0.0 };
                assert!((s - want).abs() < 1e-3, "RRᵀ[{a}][{b}] = {s}");
            }
        }
        // Rotation preserves L2 distances (up to float error).
        let mut rng = Rng::new(4);
        let x = rng.normal_vec_f32(dim);
        let y = rng.normal_vec_f32(dim);
        let d0 = sq_euclidean(&x, &y);
        let d1 = sq_euclidean(&pq.rotate_query(&x), &pq.rotate_query(&y));
        assert!((d0 - d1).abs() < 1e-3 * (1.0 + d0), "{d0} vs {d1}");
    }

    #[test]
    fn deterministic_across_builds() {
        let dim = 8;
        let data = normal_data(100, dim, 29);
        for opq in [false, true] {
            let params = PqParams { opq, ..Default::default() };
            let a = PqStorage::train(&data, dim, &params, 42).unwrap();
            let b = PqStorage::train(&data, dim, &params, 42).unwrap();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn roundtrip_bit_identical() {
        let dim = 8;
        let data = normal_data(60, dim, 31);
        for (opq, ksub) in [(false, 16), (true, 16), (false, 32)] {
            let pq = PqStorage::train(
                &data,
                dim,
                &PqParams { opq, ksub, ..Default::default() },
                6,
            )
            .unwrap();
            let mut buf = Vec::new();
            pq.write_to(&mut buf).unwrap();
            let back = PqStorage::read_from(&mut buf.as_slice()).unwrap();
            assert_eq!(pq, back);
        }
    }

    #[test]
    fn odd_subquantizer_count_packs_and_roundtrips() {
        // dim 6 with m=3 (odd) exercises the unused-nibble path.
        let dim = 6;
        let data = normal_data(40, dim, 37);
        let pq =
            PqStorage::train(&data, dim, &PqParams { m: 3, ..Default::default() }, 2).unwrap();
        assert_eq!(pq.m(), 3);
        assert_eq!(pq.row_bytes(), 2);
        let mut buf = Vec::new();
        pq.write_to(&mut buf).unwrap();
        assert_eq!(PqStorage::read_from(&mut buf.as_slice()).unwrap(), pq);
    }

    #[test]
    fn corrupt_payloads_rejected() {
        let dim = 4;
        let data = normal_data(10, dim, 41);
        let pq = PqStorage::train(
            &data,
            dim,
            &PqParams { ksub: 10, ..Default::default() },
            1,
        )
        .unwrap();
        let mut buf = Vec::new();
        pq.write_to(&mut buf).unwrap();
        // Truncation at several cuts.
        for cut in [0usize, 7, 20, buf.len() / 2, buf.len() - 2] {
            assert!(PqStorage::read_from(&mut &buf[..cut]).is_err(), "cut {cut} accepted");
        }
        // Header layout: n | dim | m | ksub | rerank_depth (u64 each) | flag.
        // Non-divisor m.
        let mut bad = buf.clone();
        bad[16..24].copy_from_slice(&3u64.to_le_bytes());
        assert!(PqStorage::read_from(&mut bad.as_slice()).is_err());
        // Absurd ksub.
        let mut bad = buf.clone();
        bad[24..32].copy_from_slice(&1000u64.to_le_bytes());
        assert!(PqStorage::read_from(&mut bad.as_slice()).is_err());
        // Zero rerank depth.
        let mut bad = buf.clone();
        bad[32..40].copy_from_slice(&0u64.to_le_bytes());
        assert!(PqStorage::read_from(&mut bad.as_slice()).is_err());
        // NaN centroid (codebooks start right after the 41-byte header when
        // no rotation is stored).
        let mut bad = buf.clone();
        bad[41..45].copy_from_slice(&f32::NAN.to_le_bytes());
        let e = PqStorage::read_from(&mut bad.as_slice()).unwrap_err().to_string();
        assert!(e.contains("codebook"), "{e}");
        // Out-of-range code: ksub=10 < 16 packs nibbles, so 0x0F is invalid.
        let cb_bytes = pq.codebooks.len() * 4;
        let code_off = 41 + cb_bytes;
        let mut bad = buf.clone();
        bad[code_off] = 0xFF;
        let e = PqStorage::read_from(&mut bad.as_slice()).unwrap_err().to_string();
        assert!(e.contains("code out of range"), "{e}");
        // NaN in the rerank tier.
        let code_bytes = pq.codes.len();
        let mut bad = buf.clone();
        let rer_off = code_off + code_bytes;
        bad[rer_off..rer_off + 4].copy_from_slice(&f32::INFINITY.to_le_bytes());
        let e = PqStorage::read_from(&mut bad.as_slice()).unwrap_err().to_string();
        assert!(e.contains("rerank"), "{e}");
    }

    #[test]
    fn spilled_cold_tier_serves_bitwise_identical_results() {
        let dir = std::env::temp_dir().join(format!("opdr_pq_spill_{}", std::process::id()));
        let dim = 8;
        let n = 60;
        let data = normal_data(n, dim, 47);
        let params = PqParams { rerank_depth: n, ..Default::default() };
        let ram = PqStorage::train(&data, dim, &params, 5).unwrap();
        let mut cold = PqStorage::train(&data, dim, &params, 5).unwrap();
        cold.spill_cold(&dir).unwrap();
        assert_eq!(cold.rerank_bytes(), n * dim * 4);
        assert!(cold.matches(&data), "tiered rerank rows must stay bitwise");
        assert!(
            cold.mapped_bytes() == 0 || cold.mapped_bytes() == cold.rerank_bytes(),
            "mapped bytes are the whole tier or the heap fallback"
        );
        // Hot copies are identical, and the two-stage search is bitwise
        // equal at every k — the tier never changes results.
        assert_eq!(ram.memory_bytes(), cold.memory_bytes());
        let mut rng = Rng::new(9);
        for _ in 0..5 {
            let q = rng.normal_vec_f32(dim);
            for k in [1usize, 7, n] {
                let a = two_stage_search(&ram, Metric::SqEuclidean, &q, 0..n, k).unwrap();
                let b = two_stage_search(&cold, Metric::SqEuclidean, &q, 0..n, k).unwrap();
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(&b) {
                    assert_eq!(x.index, y.index);
                    assert_eq!(x.distance.to_bits(), y.distance.to_bits());
                }
            }
        }
        drop(cold);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(PqStorage::train(&[], 4, &PqParams::default(), 1).is_err());
        assert!(PqStorage::train(&[1.0; 7], 4, &PqParams::default(), 1).is_err());
        assert!(PqStorage::train(&[1.0, f32::NAN], 2, &PqParams::default(), 1).is_err());
        assert!(PqStorage::train(&[1.0; 8], 0, &PqParams::default(), 1).is_err());
    }

    #[test]
    fn hot_memory_at_least_8x_smaller_than_flat() {
        let dim = 16;
        let n = 1000;
        let data = normal_data(n, dim, 43);
        let pq = PqStorage::train(&data, dim, &PqParams::default(), 3).unwrap();
        let flat = n * dim * 4;
        assert!(
            pq.memory_bytes() * 8 <= flat,
            "pq hot bytes {} vs flat {flat}",
            pq.memory_bytes()
        );
        assert_eq!(pq.rerank_bytes(), flat);
        assert!(pq.matches(&data));
        let mut other = data.clone();
        other[5] += 1.0;
        assert!(!pq.matches(&other));
    }
}
