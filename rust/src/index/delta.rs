//! Delta segment: incremental ingest without dropping the serving index.
//!
//! Before this module existed, any ingest invalidated the collection's ANN
//! index, silently degrading every query to a brute-force scan until the
//! next full rebuild — a latency cliff on the serving path. The fix is the
//! LSM-style pattern used by FAISS/Lucene-family systems: writes are
//! absorbed into a small, flat, *exact* **delta segment** appended behind
//! the immutable main index, and a background **compaction** rebuilds the
//! main index over the merged data once the delta exceeds a configured
//! bound (`[serve] delta_max_vectors`).
//!
//! [`DeltaIndex`] is the fan-out wrapper: an [`AnnIndex`] over
//! `{main index, delta rows}` where global ids `0..main.len()` live in the
//! main index and `main.len()..len()` in the delta. A search queries the
//! main index for its top-k, scans the delta exhaustively with the same
//! per-row distance kernel the flat [`crate::index::ExactIndex`] uses, and
//! merges both candidate streams through the bounded heap in
//! [`crate::knn::topk::merge_top_k`].
//!
//! ## Exactness contract (machine-checked in `tests/props.rs`)
//!
//! The merge is *order-exact*, not approximately-recall-equal: for any main
//! index whose own search is exhaustive-exact (exact flat scan; IVF at full
//! probe; HNSW at `m ≥ n`, `ef ≥ 4n`; PQ at `rerank_depth ≥ n`), the
//! wrapper's top-k is **bitwise identical** to a freshly built flat exact
//! index over the concatenated rows — including duplicate rows straddling
//! the main/delta boundary (the global (distance, index) tie-break), NaN
//! delta rows and NaN queries (skipped on both sides), and `k ≥ N`. For
//! quantized mains (SQ8), where quantized distances are defined relative to
//! the main's codebooks, the merge is still order-exact against the
//! reference merge of independently searched parts; the delta rows are
//! always served at full precision.
//!
//! The wrapper is immutable like every other index: ingest builds a new
//! wrapper sharing the main index `Arc` ([`DeltaIndex::extended`]), and a
//! finished compaction re-parents any rows ingested while it ran onto the
//! new main ([`DeltaIndex::rebase`]) so a racing ingest lands in the new
//! delta instead of being lost — the coordinator drives both through
//! [`crate::coordinator::IndexSlot`].
//!
//! Persistence: a delta-augmented index is written as a version-4 `OPDR`
//! file (main payload + a delta record); see [`crate::data::store`].

use crate::data::mapped::{AnnexWriter, ColdContext};
use crate::error::{OpdrError, Result};
use crate::index::{io, AnnIndex, IndexKind};
use crate::knn::topk::merge_top_k;
use crate::knn::Neighbor;
use crate::metrics::Metric;
use crate::pool::ThreadPool;
use crate::telemetry::SearchTrace;
use crate::util::timer::Stopwatch;
use std::io::{Read, Write};
use std::sync::Arc;

/// An immutable main index plus a flat, exact, append-only delta segment.
#[derive(Debug, Clone)]
pub struct DeltaIndex {
    main: Arc<dyn AnnIndex>,
    metric: Metric,
    dim: usize,
    /// Row-major delta rows owning global ids `main.len()..len()`.
    rows: Vec<f32>,
}

impl DeltaIndex {
    /// Wrap `main` with a non-empty delta of row-major `rows` (served at
    /// full precision regardless of the main's storage). Nesting wrappers is
    /// rejected — a delta extension reuses the existing wrapper's main.
    pub fn from_parts(main: Arc<dyn AnnIndex>, rows: Vec<f32>) -> Result<DeltaIndex> {
        if main.as_delta().is_some() {
            return Err(OpdrError::data("delta index: nesting delta wrappers is not supported"));
        }
        let dim = main.dim();
        if dim == 0 {
            return Err(OpdrError::shape("delta index: main index has dim 0"));
        }
        if rows.is_empty() || rows.len() % dim != 0 {
            return Err(OpdrError::shape(format!(
                "delta index: {} delta floats is not a non-zero multiple of dim {dim}",
                rows.len()
            )));
        }
        Ok(DeltaIndex { metric: main.metric(), dim, main, rows })
    }

    /// A new wrapper with `more` rows appended to the delta, sharing the
    /// same main index `Arc` (ingest path: the old wrapper keeps serving
    /// in-flight searches unchanged).
    pub fn extended(&self, more: &[f32]) -> Result<DeltaIndex> {
        if more.is_empty() || more.len() % self.dim != 0 {
            return Err(OpdrError::shape(format!(
                "delta extend: {} floats is not a non-zero multiple of dim {}",
                more.len(),
                self.dim
            )));
        }
        let mut rows = Vec::with_capacity(self.rows.len() + more.len());
        rows.extend_from_slice(&self.rows);
        rows.extend_from_slice(more);
        Ok(DeltaIndex { main: Arc::clone(&self.main), metric: self.metric, dim: self.dim, rows })
    }

    /// Re-parent this wrapper onto a freshly compacted `new_main` covering
    /// global rows `0..covered`: rows the compaction snapshot did not see
    /// (`covered..len()`, necessarily a suffix of the current delta) become
    /// the new delta, so an ingest racing the compaction is never lost and
    /// no row is indexed twice. `covered` must lie inside the current
    /// delta's id range (a compaction always covers at least its main).
    pub fn rebase(&self, new_main: Arc<dyn AnnIndex>, covered: usize) -> Result<DeltaIndex> {
        let base = self.main.len();
        if covered < base || covered >= self.len() {
            return Err(OpdrError::data(format!(
                "delta rebase: covered rows {covered} outside the delta range [{base}, {})",
                self.len()
            )));
        }
        if new_main.len() != covered || new_main.dim() != self.dim {
            return Err(OpdrError::data(format!(
                "delta rebase: new main is {}x{} but must cover {covered}x{}",
                new_main.len(),
                new_main.dim(),
                self.dim
            )));
        }
        if new_main.metric() != self.metric {
            return Err(OpdrError::data("delta rebase: metric mismatch"));
        }
        DeltaIndex::from_parts(new_main, self.rows[(covered - base) * self.dim..].to_vec())
    }

    /// The wrapped main index.
    pub fn main(&self) -> &Arc<dyn AnnIndex> {
        &self.main
    }

    /// Rows indexed by the main index (the delta's global id base).
    pub fn main_len(&self) -> usize {
        self.main.len()
    }

    /// Rows in the delta segment.
    pub fn delta_len(&self) -> usize {
        self.rows.len() / self.dim
    }

    /// Raw row-major delta rows.
    pub fn delta_rows(&self) -> &[f32] {
        &self.rows
    }

    fn check_query(&self, query: &[f32]) -> Result<()> {
        if query.len() != self.dim {
            return Err(OpdrError::shape(format!(
                "delta search: query dim {} != index dim {}",
                query.len(),
                self.dim
            )));
        }
        Ok(())
    }

    /// Merge the main's hit list with an exhaustive delta scan. The delta
    /// rows are scored with the same kernel as the flat exact scan
    /// ([`Metric::distance`] per row), so a wrapper over an exhaustive-exact
    /// main is bitwise identical to the flat exact index over the
    /// concatenated rows; NaN distances are skipped by the merge.
    fn merged(&self, main_hits: Vec<Neighbor>, query: &[f32], k: usize) -> Vec<Neighbor> {
        let base = self.main.len();
        let delta = self
            .rows
            .chunks_exact(self.dim)
            .enumerate()
            .map(|(i, row)| (base + i, self.metric.distance(query, row)));
        let cands = main_hits.into_iter().map(|nb| (nb.index, nb.distance)).chain(delta);
        merge_top_k(cands, k)
            .into_iter()
            .map(|(index, distance)| Neighbor { index, distance })
            .collect()
    }

    /// [`DeltaIndex::merged`] with the delta scan and the main+delta merge
    /// attributed to their stage histograms. The candidate stream keeps the
    /// exact order of the untraced path (main hits first, then delta rows in
    /// row order), so results stay bitwise identical.
    fn merged_traced(
        &self,
        main_hits: Vec<Neighbor>,
        query: &[f32],
        k: usize,
        trace: Option<&SearchTrace>,
    ) -> Vec<Neighbor> {
        let Some(t) = trace else {
            return self.merged(main_hits, query, k);
        };
        let base = self.main.len();
        let sw = Stopwatch::start();
        let delta: Vec<(usize, f32)> = self
            .rows
            .chunks_exact(self.dim)
            .enumerate()
            .map(|(i, row)| (base + i, self.metric.distance(query, row)))
            .collect();
        t.delta_scan.record(sw.elapsed());
        let sw = Stopwatch::start();
        let cands = main_hits.into_iter().map(|nb| (nb.index, nb.distance)).chain(delta);
        let out = merge_top_k(cands, k)
            .into_iter()
            .map(|(index, distance)| Neighbor { index, distance })
            .collect();
        t.merge.record(sw.elapsed());
        out
    }

    /// [`AnnIndex::search`] with a worker pool: a sharded main fans the
    /// query out across its segments on `pool` (byte-identical to the serial
    /// path); the delta scan stays on the calling thread — it is bounded by
    /// the compaction threshold. Must not be called from a pool worker.
    pub fn search_on(&self, pool: &ThreadPool, query: &[f32], k: usize) -> Result<Vec<Neighbor>> {
        self.search_on_impl(pool, query, k, None)
    }

    /// [`DeltaIndex::search_on`] with per-stage latency attribution.
    pub fn search_on_traced(
        &self,
        pool: &ThreadPool,
        query: &[f32],
        k: usize,
        trace: &SearchTrace,
    ) -> Result<Vec<Neighbor>> {
        self.search_on_impl(pool, query, k, Some(trace))
    }

    fn search_on_impl(
        &self,
        pool: &ThreadPool,
        query: &[f32],
        k: usize,
        trace: Option<&SearchTrace>,
    ) -> Result<Vec<Neighbor>> {
        self.check_query(query)?;
        let main_hits = match (self.main.as_sharded(), trace) {
            (Some(sh), t) if sh.num_shards() > 1 && pool.size() > 1 => match t {
                Some(t) => sh.search_on_traced(pool, query, k, t)?,
                None => sh.search_on(pool, query, k)?,
            },
            (_, Some(t)) => self.main.search_traced(query, k, t)?,
            (_, None) => self.main.search(query, k)?,
        };
        Ok(self.merged_traced(main_hits, query, k, trace))
    }
}

impl AnnIndex for DeltaIndex {
    fn kind(&self) -> IndexKind {
        self.main.kind()
    }

    fn len(&self) -> usize {
        self.main.len() + self.delta_len()
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn metric(&self) -> Metric {
        self.metric
    }

    /// The delta is always full-precision; quantization describes the main.
    fn quantized(&self) -> bool {
        self.main.quantized()
    }

    fn storage_name(&self) -> &'static str {
        self.main.storage_name()
    }

    fn memory_bytes(&self) -> usize {
        self.main.memory_bytes() + self.rows.len() * std::mem::size_of::<f32>()
    }

    fn cold_bytes(&self) -> usize {
        self.main.cold_bytes()
    }

    fn mapped_bytes(&self) -> usize {
        self.main.mapped_bytes()
    }

    fn search(&self, query: &[f32], k: usize) -> Result<Vec<Neighbor>> {
        self.check_query(query)?;
        let main_hits = self.main.search(query, k)?;
        Ok(self.merged(main_hits, query, k))
    }

    fn search_traced(&self, query: &[f32], k: usize, trace: &SearchTrace) -> Result<Vec<Neighbor>> {
        self.check_query(query)?;
        let main_hits = self.main.search_traced(query, k, trace)?;
        Ok(self.merged_traced(main_hits, query, k, Some(trace)))
    }

    fn matches_data(&self, data: &[f32]) -> bool {
        let split = self.main.len() * self.dim;
        if data.len() != split + self.rows.len() {
            return false;
        }
        self.rows.iter().zip(&data[split..]).all(|(a, b)| a.to_bits() == b.to_bits())
            && self.main.matches_data(&data[..split])
    }

    fn as_delta(&self) -> Option<&DeltaIndex> {
        Some(self)
    }

    /// Delta-augmented payload: `u8` sharded flag, the main's payload
    /// (prefixed with its `u32` kind tag when unsharded, exactly as a
    /// version-2/3 body), then the delta record (`u8` metric tag, `u64` n,
    /// `u64` dim, row-major f32 rows). The store frames this as an `OPDR`
    /// version-4 file ([`crate::data::store::write_index`]).
    fn write_to(&self, w: &mut dyn Write) -> Result<()> {
        self.write_impl(w, None)
    }

    /// Cold (version-5) serialization: the main's full-precision payloads
    /// externalize into the annex; the delta rows stay inline — they are
    /// the bounded hot write buffer (`[serve] delta_max_vectors`), and the
    /// next compaction folds them into the mapped main anyway.
    fn write_cold(&self, w: &mut dyn Write, annex: &mut AnnexWriter) -> Result<()> {
        self.write_impl(w, Some(annex))
    }
}

impl DeltaIndex {
    fn write_impl(&self, w: &mut dyn Write, annex: Option<&mut AnnexWriter>) -> Result<()> {
        let sharded = self.main.as_sharded().is_some();
        io::write_u8(w, u8::from(sharded))?;
        if !sharded {
            io::write_u32(w, self.main.kind().tag())?;
        }
        match annex {
            Some(a) => self.main.write_cold(w, a)?,
            None => self.main.write_to(w)?,
        }
        io::write_u8(w, io::metric_tag(self.metric))?;
        io::write_u64(w, self.delta_len() as u64)?;
        io::write_u64(w, self.dim as u64)?;
        io::write_f32s(w, &self.rows)
    }

    /// Deserialize (inverse of [`AnnIndex::write_to`]); the delta record is
    /// validated against the decoded main so a corrupt or mismatched file
    /// fails loudly instead of serving wrong rows.
    pub(crate) fn read_from(r: &mut dyn Read) -> Result<DeltaIndex> {
        DeltaIndex::read_with(r, None)
    }

    /// [`DeltaIndex::read_from`] with an optional cold context (version-5
    /// files: the main's external rows resolve against the file's mapped
    /// annex; the delta record is always inline).
    pub(crate) fn read_with(r: &mut dyn Read, cx: Option<&ColdContext>) -> Result<DeltaIndex> {
        let main: Box<dyn AnnIndex> = match io::read_u8(r)? {
            0 => {
                let kind_tag = io::read_u32(r)?;
                crate::index::read_index_payload_with(kind_tag, r, cx)?
            }
            1 => Box::new(crate::index::shard::ShardedIndex::read_with(r, cx)?),
            other => {
                return Err(OpdrError::data(format!(
                    "delta index: unknown main layout flag {other}"
                )))
            }
        };
        let metric = io::metric_from_tag(io::read_u8(r)?)
            .map_err(|e| OpdrError::data(format!("delta index: {e}")))?;
        if metric != main.metric() {
            return Err(OpdrError::data(format!(
                "delta index: delta metric {} != main metric {}",
                metric.name(),
                main.metric().name()
            )));
        }
        let n = io::read_u64_usize(r)?;
        if n == 0 {
            return Err(OpdrError::data(
                "delta index: empty delta record (an empty delta is stored as a bare index)",
            ));
        }
        let dim = io::read_u64_usize(r)?;
        if dim != main.dim() {
            return Err(OpdrError::data(format!(
                "delta index: delta dim {dim} != main dim {}",
                main.dim()
            )));
        }
        let count = io::checked_count(n, dim)?;
        let rows = io::read_f32s(r, count)
            .map_err(|e| OpdrError::data(format!("delta index: delta rows truncated: {e}")))?;
        DeltaIndex::from_parts(Arc::from(main), rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::IndexPolicy;
    use crate::index::{build_index, ExactIndex, StorageSpec};
    use crate::util::Rng;

    fn exact_arc(data: &[f32], dim: usize, metric: Metric) -> Arc<dyn AnnIndex> {
        Arc::from(build_index(
            data,
            dim,
            metric,
            &IndexPolicy { kind: IndexKind::Exact, exact_threshold: 0, ..Default::default() },
            1,
        )
        .unwrap())
    }

    #[test]
    fn wrapper_is_bitwise_flat_exact_over_concat() {
        let mut rng = Rng::new(3);
        let dim = 5;
        let (n0, n1) = (24, 9);
        let data = rng.normal_vec_f32((n0 + n1) * dim);
        let wrapper =
            DeltaIndex::from_parts(exact_arc(&data[..n0 * dim], dim, Metric::SqEuclidean),
                data[n0 * dim..].to_vec())
            .unwrap();
        assert_eq!(wrapper.len(), n0 + n1);
        assert_eq!(wrapper.main_len(), n0);
        assert_eq!(wrapper.delta_len(), n1);
        let flat =
            ExactIndex::build(&data, dim, Metric::SqEuclidean, &StorageSpec::flat(), 1).unwrap();
        for k in [1usize, 7, n0 + n1, n0 + n1 + 5] {
            for _ in 0..4 {
                let q = rng.normal_vec_f32(dim);
                let a = flat.search(&q, k).unwrap();
                let b = wrapper.search(&q, k).unwrap();
                crate::testing::assert_same_neighbors(&a, &b);
            }
        }
    }

    #[test]
    fn extended_appends_and_shares_the_main() {
        let mut rng = Rng::new(7);
        let dim = 4;
        let data = rng.normal_vec_f32(30 * dim);
        let main = exact_arc(&data[..20 * dim], dim, Metric::Euclidean);
        let w1 = DeltaIndex::from_parts(Arc::clone(&main), data[20 * dim..25 * dim].to_vec())
            .unwrap();
        let w2 = w1.extended(&data[25 * dim..]).unwrap();
        assert_eq!(w1.delta_len(), 5);
        assert_eq!(w2.delta_len(), 10);
        assert!(Arc::ptr_eq(w1.main(), w2.main()));
        let flat =
            ExactIndex::build(&data, dim, Metric::Euclidean, &StorageSpec::flat(), 1).unwrap();
        let q = rng.normal_vec_f32(dim);
        crate::testing::assert_same_neighbors(
            &flat.search(&q, 8).unwrap(),
            &w2.search(&q, 8).unwrap(),
        );
        // Shape errors.
        assert!(w1.extended(&[]).is_err());
        assert!(w1.extended(&[0.0; 3]).is_err());
    }

    #[test]
    fn rebase_keeps_only_uncovered_rows() {
        let mut rng = Rng::new(11);
        let dim = 4;
        let data = rng.normal_vec_f32(30 * dim);
        let w = DeltaIndex::from_parts(
            exact_arc(&data[..20 * dim], dim, Metric::SqEuclidean),
            data[20 * dim..].to_vec(),
        )
        .unwrap();
        // Compaction covered 26 rows; rows 26..30 raced in.
        let new_main = exact_arc(&data[..26 * dim], dim, Metric::SqEuclidean);
        let rebased = w.rebase(new_main, 26).unwrap();
        assert_eq!(rebased.main_len(), 26);
        assert_eq!(rebased.delta_len(), 4);
        assert_eq!(rebased.delta_rows(), &data[26 * dim..]);
        let flat =
            ExactIndex::build(&data, dim, Metric::SqEuclidean, &StorageSpec::flat(), 1).unwrap();
        let q = rng.normal_vec_f32(dim);
        crate::testing::assert_same_neighbors(
            &flat.search(&q, 9).unwrap(),
            &rebased.search(&q, 9).unwrap(),
        );
        // covered outside the delta range, wrong shape and wrong metric all
        // refuse instead of mislabeling rows.
        let m26 = exact_arc(&data[..26 * dim], dim, Metric::SqEuclidean);
        assert!(w.rebase(Arc::clone(&m26), 19).is_err()); // < main_len
        assert!(w.rebase(Arc::clone(&m26), 30).is_err()); // == len
        assert!(w.rebase(Arc::clone(&m26), 27).is_err()); // len mismatch
        let wrong_metric = exact_arc(&data[..26 * dim], dim, Metric::Cosine);
        assert!(w.rebase(wrong_metric, 26).is_err());
    }

    #[test]
    fn construction_validates_shapes_and_nesting() {
        let mut rng = Rng::new(13);
        let dim = 4;
        let data = rng.normal_vec_f32(10 * dim);
        let main = exact_arc(&data, dim, Metric::Euclidean);
        assert!(DeltaIndex::from_parts(Arc::clone(&main), vec![]).is_err());
        assert!(DeltaIndex::from_parts(Arc::clone(&main), vec![0.0; 3]).is_err());
        let w = DeltaIndex::from_parts(main, vec![0.0; dim]).unwrap();
        let nested: Arc<dyn AnnIndex> = Arc::new(w);
        let e = DeltaIndex::from_parts(nested, vec![0.0; dim]).unwrap_err().to_string();
        assert!(e.contains("nesting"), "{e}");
    }

    #[test]
    fn nan_delta_rows_and_nan_queries_skipped_like_exact() {
        let mut rng = Rng::new(17);
        let dim = 3;
        let mut data = rng.normal_vec_f32(12 * dim);
        data[8 * dim] = f32::NAN; // NaN row in the delta region
        let w = DeltaIndex::from_parts(
            exact_arc(&data[..6 * dim], dim, Metric::SqEuclidean),
            data[6 * dim..].to_vec(),
        )
        .unwrap();
        let flat =
            ExactIndex::build(&data, dim, Metric::SqEuclidean, &StorageSpec::flat(), 1).unwrap();
        let q = rng.normal_vec_f32(dim);
        crate::testing::assert_same_neighbors(
            &flat.search(&q, 12).unwrap(),
            &w.search(&q, 12).unwrap(),
        );
        // NaN query: empty on both sides.
        assert!(w.search(&[f32::NAN; 3], 4).unwrap().is_empty());
        // Query dim checked.
        assert!(w.search(&[0.0; 2], 4).is_err());
    }

    #[test]
    fn pool_fanout_over_sharded_main_matches_serial() {
        let mut rng = Rng::new(19);
        let dim = 4;
        let data = rng.normal_vec_f32(40 * dim);
        let policy = IndexPolicy {
            kind: IndexKind::Exact,
            exact_threshold: 0,
            shards: 3,
            shard_min_vectors: 1,
            ..Default::default()
        };
        let main: Arc<dyn AnnIndex> =
            Arc::from(build_index(&data[..30 * dim], dim, Metric::Cosine, &policy, 2).unwrap());
        assert!(main.as_sharded().is_some());
        let w = DeltaIndex::from_parts(main, data[30 * dim..].to_vec()).unwrap();
        let pool = ThreadPool::new(3);
        for _ in 0..5 {
            let q = rng.normal_vec_f32(dim);
            let a = w.search(&q, 7).unwrap();
            let b = w.search_on(&pool, &q, 7).unwrap();
            crate::testing::assert_same_neighbors(&a, &b);
        }
    }

    #[test]
    fn payload_roundtrips_bitwise_for_plain_and_sharded_mains() {
        let mut rng = Rng::new(23);
        let dim = 6;
        let data = rng.normal_vec_f32(36 * dim);
        for shards in [1usize, 3] {
            let policy = IndexPolicy {
                kind: IndexKind::Hnsw,
                exact_threshold: 0,
                sq8: shards == 1,
                shards,
                shard_min_vectors: 1,
                ..Default::default()
            };
            let main: Arc<dyn AnnIndex> = Arc::from(
                build_index(&data[..30 * dim], dim, Metric::SqEuclidean, &policy, 4).unwrap(),
            );
            let w = DeltaIndex::from_parts(main, data[30 * dim..].to_vec()).unwrap();
            let mut buf = Vec::new();
            w.write_to(&mut buf).unwrap();
            let back = DeltaIndex::read_from(&mut buf.as_slice()).unwrap();
            assert_eq!(back.main_len(), 30);
            assert_eq!(back.delta_len(), 6);
            assert_eq!(back.kind(), w.kind());
            assert_eq!(back.quantized(), w.quantized());
            let q = rng.normal_vec_f32(dim);
            crate::testing::assert_same_neighbors(
                &w.search(&q, 9).unwrap(),
                &back.search(&q, 9).unwrap(),
            );
            // Truncations anywhere fail cleanly.
            for cut in [buf.len() - 3, buf.len() / 2, 3, 0] {
                assert!(DeltaIndex::read_from(&mut &buf[..cut]).is_err(), "cut {cut}");
            }
        }
    }

    #[test]
    fn accounting_covers_main_plus_delta() {
        let mut rng = Rng::new(29);
        let dim = 4;
        let data = rng.normal_vec_f32(20 * dim);
        let main = exact_arc(&data[..16 * dim], dim, Metric::SqEuclidean);
        let main_bytes = main.memory_bytes();
        let w = DeltaIndex::from_parts(main, data[16 * dim..].to_vec()).unwrap();
        assert_eq!(w.memory_bytes(), main_bytes + 4 * dim * 4);
        assert_eq!(w.cold_bytes(), 0);
        assert!(w.matches_data(&data));
        assert!(!w.matches_data(&data[..19 * dim]));
        let mut other = data.clone();
        other[17 * dim] += 1.0; // flip a delta row
        assert!(!w.matches_data(&other));
    }
}
