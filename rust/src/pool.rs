//! Minimal worker thread pool (no `tokio`/`rayon` offline).
//!
//! Fixed worker count, bounded in-flight via the job channel, `scope`-style
//! chunked parallel map for the scoring hot path. Lives at the crate root
//! (not under [`crate::coordinator`]) because both the coordinator's
//! scoring path and the index subsystem's shard builds / query fan-out
//! ([`crate::index::shard`]) run on it; the coordinator re-exports it for
//! compatibility.

use crate::util::lock_recover;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size thread pool.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadPool").field("workers", &self.workers.len()).finish()
    }
}

impl ThreadPool {
    /// Spawn `size` workers (at least 1).
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("opdr-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = lock_recover(&rx);
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool not shut down")
            .send(Box::new(f))
            .expect("worker channel open");
    }

    /// A cheap cloneable `'static` submit handle onto the same workers, for
    /// helper threads that outlive the borrow of `&ThreadPool` (the index
    /// build collector uses one to dispatch segment jobs off the scheduler
    /// thread). Jobs submitted after the pool is dropped are silently
    /// discarded — the submitting side observes that through its own result
    /// channel going quiet, not through a panic.
    pub fn handle(&self) -> PoolHandle {
        PoolHandle { tx: self.tx.as_ref().expect("pool not shut down").clone() }
    }

    /// Parallel map over chunks of `0..n`: calls `f(range)` on the pool and
    /// collects results in submission order. `f` must be cloneable state-free
    /// work (wrap shared inputs in `Arc`).
    pub fn map_chunks<R, F>(&self, n: usize, chunk: usize, f: F) -> Vec<R>
    where
        R: Send + 'static,
        F: Fn(std::ops::Range<usize>) -> R + Send + Sync + 'static,
    {
        if n == 0 {
            return vec![];
        }
        let chunk = chunk.max(1);
        let f = Arc::new(f);
        let (tx, rx) = channel();
        let mut count = 0usize;
        let mut start = 0usize;
        while start < n {
            let end = (start + chunk).min(n);
            let tx = tx.clone();
            let f = Arc::clone(&f);
            let idx = count;
            self.execute(move || {
                let r = f(start..end);
                let _ = tx.send((idx, r));
            });
            count += 1;
            start = end;
        }
        drop(tx);
        let mut results: Vec<(usize, R)> = rx.iter().collect();
        results.sort_by_key(|&(i, _)| i);
        results.into_iter().map(|(_, r)| r).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Close the channel; workers exit after draining. (Outstanding
        // `PoolHandle`s keep the channel open until they drop too.)
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Detached submit handle created by [`ThreadPool::handle`].
#[derive(Clone)]
pub struct PoolHandle {
    tx: Sender<Job>,
}

impl std::fmt::Debug for PoolHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PoolHandle").finish()
    }
}

impl PoolHandle {
    /// Submit a job; silently dropped if every worker has exited.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let _ = self.tx.send(Box::new(f));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        drop(tx);
        let done = rx.iter().count();
        assert_eq!(done, 100);
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_chunks_ordered_and_complete() {
        let pool = ThreadPool::new(3);
        let out = pool.map_chunks(10, 3, |r| r.clone().sum::<usize>());
        // chunks: 0..3, 3..6, 6..9, 9..10
        assert_eq!(out, vec![3, 12, 21, 9]);
    }

    #[test]
    fn map_chunks_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = pool.map_chunks(0, 4, |_| 0);
        assert!(out.is_empty());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        drop(pool); // must not hang or panic
    }

    #[test]
    fn size_floor_is_one() {
        assert_eq!(ThreadPool::new(0).size(), 1);
    }

    #[test]
    fn handle_submits_from_detached_thread() {
        let pool = ThreadPool::new(2);
        let handle = pool.handle();
        let (tx, rx) = channel();
        std::thread::spawn(move || {
            for i in 0..10 {
                let tx = tx.clone();
                handle.execute(move || {
                    let _ = tx.send(i);
                });
            }
        })
        .join()
        .unwrap();
        let mut got: Vec<i32> = rx.iter().take(10).collect();
        got.sort_unstable();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }
}
