//! Minimal worker thread pool (no `tokio`/`rayon` offline).
//!
//! Fixed worker count, bounded job queue with caller-runs overflow,
//! `scope`-style chunked parallel map for the scoring hot path. Lives at
//! the crate root (not under [`crate::coordinator`]) because both the
//! coordinator's scoring path and the index subsystem's shard builds /
//! query fan-out ([`crate::index::shard`]) run on it; the coordinator
//! re-exports it for compatibility.
//!
//! The queue is a `sync_channel`, never the unbounded `mpsc::channel`: a
//! submission burst can't grow an invisible heap of boxed closures. When
//! the queue is full the submitting thread runs the job *inline*
//! (caller-runs). That keeps every job's completion guarantee — nothing is
//! dropped, so [`ThreadPool::map_chunks`] stays complete — while applying
//! backpressure at the source: a producer that outruns the workers ends up
//! doing the work itself instead of queueing more.

use crate::util::{lock_recover_ranked, ranks};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Queue slots per worker when the capacity isn't given explicitly
/// ([`ThreadPool::new`]). Deep enough that chunked fan-outs (one job per
/// chunk, chunks ≈ workers) never trip caller-runs in the common case,
/// shallow enough that a runaway producer is throttled within one burst.
const DEFAULT_QUEUE_DEPTH_PER_WORKER: usize = 64;

/// A fixed-size thread pool.
pub struct ThreadPool {
    tx: Option<SyncSender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadPool").field("workers", &self.workers.len()).finish()
    }
}

impl ThreadPool {
    /// Spawn `size` workers (at least 1) with the default queue depth.
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        ThreadPool::with_queue_capacity(size, size * DEFAULT_QUEUE_DEPTH_PER_WORKER)
    }

    /// Spawn `size` workers (at least 1) over a job queue bounded at
    /// `capacity` (at least 1). Submissions beyond the bound run inline on
    /// the submitting thread (caller-runs) instead of blocking or growing
    /// an unbounded queue.
    pub fn with_queue_capacity(size: usize, capacity: usize) -> ThreadPool {
        let size = size.max(1);
        let (tx, rx) = sync_channel::<Job>(capacity.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("opdr-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = lock_recover_ranked(&rx, ranks::POOL_QUEUE);
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job. If the queue is full the job runs inline on the
    /// calling thread — submission never blocks and never drops work.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let tx = self.tx.as_ref().expect("pool not shut down");
        match tx.try_send(Box::new(f)) {
            Ok(()) => {}
            Err(TrySendError::Full(job)) => job(),
            // Workers only exit once the sending side is closed, and the
            // pool holds a sender for its whole lifetime — mirror the old
            // unbounded-send invariant.
            Err(TrySendError::Disconnected(_)) => panic!("worker channel open"),
        }
    }

    /// A cheap cloneable `'static` submit handle onto the same workers, for
    /// helper threads that outlive the borrow of `&ThreadPool` (the index
    /// build collector uses one to dispatch segment jobs off the scheduler
    /// thread). Jobs submitted after the pool is dropped are silently
    /// discarded — the submitting side observes that through its own result
    /// channel going quiet, not through a panic.
    pub fn handle(&self) -> PoolHandle {
        PoolHandle { tx: self.tx.as_ref().expect("pool not shut down").clone() }
    }

    /// Parallel map over chunks of `0..n`: calls `f(range)` on the pool and
    /// collects results in submission order. `f` must be cloneable state-free
    /// work (wrap shared inputs in `Arc`).
    pub fn map_chunks<R, F>(&self, n: usize, chunk: usize, f: F) -> Vec<R>
    where
        R: Send + 'static,
        F: Fn(std::ops::Range<usize>) -> R + Send + Sync + 'static,
    {
        if n == 0 {
            return vec![];
        }
        let chunk = chunk.max(1);
        let f = Arc::new(f);
        // One slot per chunk: every worker's result send succeeds without
        // blocking even if this thread hasn't started draining yet.
        let (tx, rx) = sync_channel(n.div_ceil(chunk));
        let mut count = 0usize;
        let mut start = 0usize;
        while start < n {
            let end = (start + chunk).min(n);
            let tx = tx.clone();
            let f = Arc::clone(&f);
            let idx = count;
            self.execute(move || {
                let r = f(start..end);
                let _ = tx.send((idx, r));
            });
            count += 1;
            start = end;
        }
        drop(tx);
        let mut results: Vec<(usize, R)> = rx.iter().collect();
        results.sort_by_key(|&(i, _)| i);
        results.into_iter().map(|(_, r)| r).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Close the channel; workers exit after draining. (Outstanding
        // `PoolHandle`s keep the channel open until they drop too.)
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Detached submit handle created by [`ThreadPool::handle`].
#[derive(Clone)]
pub struct PoolHandle {
    tx: SyncSender<Job>,
}

impl std::fmt::Debug for PoolHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PoolHandle").finish()
    }
}

impl PoolHandle {
    /// Submit a job; runs inline when the queue is full (caller-runs, same
    /// as [`ThreadPool::execute`]); silently dropped if every worker has
    /// exited.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        match self.tx.try_send(Box::new(f)) {
            Ok(()) => {}
            Err(TrySendError::Full(job)) => job(),
            Err(TrySendError::Disconnected(_)) => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = sync_channel(100);
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        drop(tx);
        let done = rx.iter().count();
        assert_eq!(done, 100);
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_chunks_ordered_and_complete() {
        let pool = ThreadPool::new(3);
        let out = pool.map_chunks(10, 3, |r| r.clone().sum::<usize>());
        // chunks: 0..3, 3..6, 6..9, 9..10
        assert_eq!(out, vec![3, 12, 21, 9]);
    }

    #[test]
    fn map_chunks_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = pool.map_chunks(0, 4, |_| 0);
        assert!(out.is_empty());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        drop(pool); // must not hang or panic
    }

    #[test]
    fn size_floor_is_one() {
        assert_eq!(ThreadPool::new(0).size(), 1);
    }

    #[test]
    fn handle_submits_from_detached_thread() {
        let pool = ThreadPool::new(2);
        let handle = pool.handle();
        let (tx, rx) = sync_channel(10);
        std::thread::spawn(move || {
            for i in 0..10 {
                let tx = tx.clone();
                handle.execute(move || {
                    let _ = tx.send(i);
                });
            }
        })
        .join()
        .unwrap();
        let mut got: Vec<i32> = rx.iter().take(10).collect();
        got.sort_unstable();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }

    /// Backpressure regression: a full queue must neither block the
    /// submitter nor drop jobs — overflow runs inline (caller-runs).
    #[test]
    fn full_queue_runs_job_on_submitter_without_blocking_or_dropping() {
        // One worker, one queue slot. Park the worker on a gate so the
        // queue stays full for the whole submission burst.
        let pool = ThreadPool::with_queue_capacity(1, 1);
        let gate = Arc::new(Mutex::new(()));
        // lint:allow(no-naked-lock-unwrap: test-owned gate, never poisoned)
        let held = gate.lock().unwrap();
        {
            let gate = Arc::clone(&gate);
            pool.execute(move || {
                // lint:allow(no-naked-lock-unwrap: test-owned gate, never poisoned)
                drop(gate.lock().unwrap());
            });
        }
        // Give the worker a beat to take the gate job off the queue.
        std::thread::sleep(std::time::Duration::from_millis(20));

        let counter = Arc::new(AtomicUsize::new(0));
        let submitter = std::thread::current().id();
        let inline_runs = Arc::new(AtomicUsize::new(0));
        // Slot 1 fills the queue; jobs 2..=8 overflow and must run inline
        // right here, on this thread, while the worker is still parked.
        for _ in 0..8 {
            let c = Arc::clone(&counter);
            let inline = Arc::clone(&inline_runs);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                if std::thread::current().id() == submitter {
                    inline.fetch_add(1, Ordering::SeqCst);
                }
            });
        }
        // Overflow jobs already ran (submission did not block on the full
        // queue), and at least one provably ran on the submitting thread.
        assert!(counter.load(Ordering::SeqCst) >= 7);
        assert!(inline_runs.load(Ordering::SeqCst) >= 7);

        drop(held); // release the worker; the queued job drains
        drop(pool); // joins workers
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }
}
