//! Length-prefixed binary RPC between the gateway and shard workers.
//!
//! The distribution layer ([`crate::dist`]) splits serving into a front-end
//! gateway and N shard-worker processes on local sockets. This module owns
//! the wire: a versioned handshake, request-id-stamped frames with a
//! per-message CRC ([`frame`] — the byte table lives there), read/write
//! deadlines on the socket ([`FramedTcp`]), and the deterministic
//! fault-injection doubles the whole correctness story is tested under
//! ([`fault`]).
//!
//! Design rules, in the order they matter:
//!
//! 1. **Never trust a length field.** The decoder clamps preallocation to
//!    [`frame::ALLOC_CHUNK`] and caps declared lengths at
//!    [`frame::MAX_PAYLOAD_BYTES`], exactly like the version-5 store
//!    hardening — a corrupt or hostile frame ends in a typed error, never
//!    an OOM abort or a panic.
//! 2. **Never block forever.** Every socket read and write carries a
//!    deadline ([`FramedTcp::set_deadline`]); expiry surfaces as a typed
//!    timeout ([`is_timeout`]) the gateway converts into degraded
//!    (`partial = true`) serving, counted in `opdr_rpc_deadline_total`.
//! 3. **Never mis-pair request and response.** Responses echo the request
//!    id; a duplicated or reordered frame is discarded by id, so a faulty
//!    transport can delay or repeat frames without ever producing a
//!    silently wrong ranking.
//!
//! Distances travel as raw little-endian f32 bits (NaN payloads included),
//! so a scatter-gathered merge through [`crate::knn::merge_top_k`] is
//! bit-identical to the same merge in process.

pub mod fault;
pub mod frame;

pub use fault::{Fault, FaultProxy, FaultScript, FaultyTransport};
pub use frame::{
    crc32, decode_frame, encode_frame, read_frame, version_supported, Message, WireTrace,
    ALLOC_CHUNK, FRAME_MAGIC, HEADER_BYTES, MAX_PAYLOAD_BYTES, MIN_PROTOCOL_VERSION,
    PROTOCOL_VERSION,
};

use crate::error::{OpdrError, Result};
use std::net::{Shutdown, TcpStream};
use std::time::Duration;

/// True when `e` is a socket-deadline expiry (`SO_RCVTIMEO`/`SO_SNDTIMEO`
/// surface as `WouldBlock` on Unix, `TimedOut` elsewhere) — the gateway
/// counts these separately from transport/protocol failures.
pub fn is_timeout(e: &OpdrError) -> bool {
    match e {
        OpdrError::Io(io) => matches!(
            io.kind(),
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
        ),
        _ => false,
    }
}

/// A framed RPC connection over TCP: one [`Message`] per frame, with a
/// read/write deadline applied to the underlying socket. After any error
/// the stream may be mid-frame (desynchronized); callers drop the
/// connection and reconnect rather than resynchronize.
#[derive(Debug)]
pub struct FramedTcp {
    stream: TcpStream,
}

impl FramedTcp {
    /// Wrap a connected stream (enables `TCP_NODELAY`; frames are tiny and
    /// latency-bound).
    pub fn new(stream: TcpStream) -> FramedTcp {
        let _ = stream.set_nodelay(true);
        FramedTcp { stream }
    }

    /// Set the read *and* write deadline for subsequent frames. A zero
    /// duration is clamped to 1ms (zero means "no timeout" to the OS,
    /// which is exactly what a deadline must never silently become).
    pub fn set_deadline(&self, d: Duration) -> Result<()> {
        let d = d.max(Duration::from_millis(1));
        self.stream.set_read_timeout(Some(d))?;
        self.stream.set_write_timeout(Some(d))?;
        Ok(())
    }

    /// Send one frame (a single `write_all` of the encoded bytes).
    pub fn send(&mut self, request_id: u64, msg: &Message) -> Result<()> {
        let buf = encode_frame(request_id, msg)?;
        use std::io::Write;
        self.stream.write_all(&buf)?;
        Ok(())
    }

    /// Receive one frame, enforcing the configured deadline.
    pub fn recv(&mut self) -> Result<(u64, Message)> {
        read_frame(&mut self.stream)
    }

    /// Sever both directions (idempotent, best-effort).
    pub fn shutdown(&self) {
        let _ = self.stream.shutdown(Shutdown::Both);
    }
}
