//! Length-prefixed binary frame codec for the gateway ↔ shard-worker RPC.
//!
//! ## The frame layout
//!
//! Every message travels in one frame — a fixed 21-byte header followed by a
//! length-prefixed, CRC-guarded payload (all integers little-endian):
//!
//! | offset | bytes | field |
//! |-------:|------:|-------|
//! |      0 |     4 | magic `OPRC` |
//! |      4 |     1 | frame kind (see [`Message`] tags) |
//! |      5 |     8 | u64 request id |
//! |     13 |     4 | u32 payload byte length (≤ [`MAX_PAYLOAD_BYTES`]) |
//! |     17 |     4 | u32 CRC-32 (IEEE) of the payload |
//! |     21 |     … | payload |
//!
//! The request id is echoed by every response, so a gateway that sees a
//! duplicated or reordered frame (a retransmitting proxy, a worker answering
//! a request the gateway already timed out) can discard it by id instead of
//! mis-pairing request and response. The CRC covers the payload; corruption
//! of the header itself is caught by the magic / kind / length validation.
//!
//! ## Protocol v2: trace tails and metrics frames
//!
//! Version 2 (negotiated in the `Hello`/`HelloAck` handshake; v1 peers are
//! still accepted) adds observability without disturbing the v1 byte
//! layout. Because every variable-length body is count-delimited, a v2
//! sender appends a fixed-size **tail** after the v1 payload and the
//! decoder discriminates by the exact number of remaining bytes — zero
//! remaining is a v1 frame, the tail size is a v2 frame, anything else is
//! the usual trailing-bytes error:
//!
//! | frame | v1 payload | optional v2 tail |
//! |-------|------------|------------------|
//! | `Search` | `k u32 · count u64 · count × f32` | `trace_id u64` (8 bytes) |
//! | `SearchOk` | `count u64 · count × (id u64 · dist f32)` | `trace_id u64 · queue_ns u64 · scan_ns u64 · rerank_ns u64 · merge_ns u64` (40 bytes) |
//!
//! v2 also adds two frame kinds for metrics federation: `MetricsPull`
//! (kind 8, empty payload) asks a worker for its registry; `MetricsText`
//! (kind 9, `len u64 · utf-8 bytes` — the [`Message::Error`] shape) carries
//! the worker's lossless registry snapshot back (see
//! `telemetry::registry::Registry::encode_snapshot`). A v1 peer never sees
//! either: the gateway only sends tails and pulls after the handshake
//! negotiated version 2.
//!
//! ## Decoder hardening
//!
//! The decoder treats every header field as hostile, matching the version-5
//! store hardening ([`crate::index::io`]):
//!
//! * a declared payload length above [`MAX_PAYLOAD_BYTES`] fails with a
//!   typed error **before any allocation**;
//! * lengths under the cap preallocate at most
//!   [`ALLOC_CHUNK`](crate::index::io::ALLOC_CHUNK) bytes and grow only as
//!   bytes actually arrive, so a lying length field ends in the ordinary
//!   typed truncation error instead of an OOM abort;
//! * a CRC mismatch, an unknown frame kind, a bad magic and trailing payload
//!   bytes each fail with a distinct typed [`OpdrError`] — never a panic.

use crate::error::{OpdrError, Result};
use crate::index::io;
use std::io::Read;

/// RPC protocol version, exchanged in the [`Message::Hello`] /
/// [`Message::HelloAck`] handshake. Version 2 adds the observability tails
/// and metrics frames (see the module docs); peers still speaking
/// [`MIN_PROTOCOL_VERSION`] are accepted and simply never sent a tail. A
/// peer outside the supported range refuses the connection with a typed
/// error instead of misparsing frames.
pub const PROTOCOL_VERSION: u32 = 2;

/// Oldest protocol version both sides still accept (v1: no trace tails, no
/// metrics frames).
pub const MIN_PROTOCOL_VERSION: u32 = 1;

/// True when `version` is one this build can speak.
pub fn version_supported(version: u32) -> bool {
    (MIN_PROTOCOL_VERSION..=PROTOCOL_VERSION).contains(&version)
}

/// Per-query stage timings carried in the v2 `SearchOk` tail: the worker's
/// queue wait (decode → execution) and its [`SearchTrace`] stage totals, in
/// nanoseconds, echoing the query's trace id. Fixed 40-byte wire layout.
///
/// [`SearchTrace`]: crate::telemetry::SearchTrace
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct WireTrace {
    /// Gateway-assigned query trace id, echoed back.
    pub trace_id: u64,
    /// Time between frame decode and search execution start.
    pub queue_ns: u64,
    /// Substrate scan time.
    pub scan_ns: u64,
    /// Full-precision rerank time (0 for unquantized indexes).
    pub rerank_ns: u64,
    /// Shard/delta merge time.
    pub merge_ns: u64,
}

impl WireTrace {
    /// Stage durations in timeline order: queue wait, scan, rerank, merge.
    pub fn stage_ns(&self) -> [u64; 4] {
        [self.queue_ns, self.scan_ns, self.rerank_ns, self.merge_ns]
    }
}

/// Byte length of the `SearchOk` v2 tail.
const SEARCH_OK_TAIL_BYTES: usize = 40;

/// Byte length of the `Search` v2 tail.
const SEARCH_TAIL_BYTES: usize = 8;

/// Frame magic (`OPRC` = OPDR RPC).
pub const FRAME_MAGIC: [u8; 4] = *b"OPRC";

/// Fixed frame header size in bytes.
pub const HEADER_BYTES: usize = 21;

/// Cap on a frame's declared payload length. Larger declarations fail
/// before any allocation: the biggest legitimate payload is a query or a
/// top-k response, both far below this.
pub const MAX_PAYLOAD_BYTES: usize = 1 << 26;

/// Eager-preallocation clamp for untrusted length fields — re-exported from
/// the store's io hardening so tests can state the shared contract.
pub const ALLOC_CHUNK: usize = io::ALLOC_CHUNK;

// CRC-32 (IEEE 802.3, reflected, polynomial 0xEDB88320), table built at
// compile time — the offline build has no crc crate.
const CRC_TABLE: [u32; 256] = build_crc_table();

const fn build_crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut j = 0;
        while j < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            j += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// CRC-32 (IEEE) of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// One RPC message; the variant doubles as the frame kind tag.
#[derive(Debug, Clone)]
pub enum Message {
    /// Client → worker: open a session at this protocol version.
    Hello {
        /// Client protocol version.
        version: u32,
    },
    /// Worker → client: version accepted; the shard this worker serves.
    HelloAck {
        /// Worker protocol version.
        version: u32,
        /// First global row id of the shard.
        start: u64,
        /// Rows in the shard.
        len: u64,
        /// Vector dimensionality served.
        dim: u32,
    },
    /// Client → worker: top-`k` nearest neighbors of `query`.
    Search {
        /// Neighbors requested.
        k: u32,
        /// Full-precision query vector.
        query: Vec<f32>,
        /// v2 tail: the gateway's trace id for this query (`None` on v1
        /// connections — the frame then encodes byte-identically to v1).
        trace_id: Option<u64>,
    },
    /// Worker → client: `(global id, distance)` pairs, ascending by
    /// (distance, id). Distances travel as raw f32 bits, so the gateway
    /// merge is bit-identical to an in-process shard merge.
    SearchOk {
        /// Remapped neighbor list.
        neighbors: Vec<(u64, f32)>,
        /// v2 tail: echoed trace id + per-stage timings (`None` on v1
        /// connections or when the request carried no trace id).
        trace: Option<WireTrace>,
    },
    /// Worker → client: the request failed (or could not be parsed) with
    /// this typed message.
    Error {
        /// Human-readable failure description.
        message: String,
    },
    /// Liveness probe.
    Ping,
    /// Liveness reply.
    Pong,
    /// Client → worker (v2): request the worker's metrics-registry snapshot.
    MetricsPull,
    /// Worker → client (v2): the lossless registry snapshot (see
    /// `telemetry::registry::Registry::encode_snapshot`).
    MetricsText {
        /// Snapshot text (utf-8).
        text: String,
    },
}

impl Message {
    /// Frame kind tag (header byte 4).
    pub fn kind_tag(&self) -> u8 {
        match self {
            Message::Hello { .. } => 1,
            Message::HelloAck { .. } => 2,
            Message::Search { .. } => 3,
            Message::SearchOk { .. } => 4,
            Message::Error { .. } => 5,
            Message::Ping => 6,
            Message::Pong => 7,
            Message::MetricsPull => 8,
            Message::MetricsText { .. } => 9,
        }
    }

    /// Short kind name for error messages.
    pub fn kind_name(&self) -> &'static str {
        match self {
            Message::Hello { .. } => "hello",
            Message::HelloAck { .. } => "hello-ack",
            Message::Search { .. } => "search",
            Message::SearchOk { .. } => "search-ok",
            Message::Error { .. } => "error",
            Message::Ping => "ping",
            Message::Pong => "pong",
            Message::MetricsPull => "metrics-pull",
            Message::MetricsText { .. } => "metrics-text",
        }
    }

    fn encode_payload(&self) -> Result<Vec<u8>> {
        let mut p: Vec<u8> = Vec::new();
        match self {
            Message::Hello { version } => io::write_u32(&mut p, *version)?,
            Message::HelloAck { version, start, len, dim } => {
                io::write_u32(&mut p, *version)?;
                io::write_u64(&mut p, *start)?;
                io::write_u64(&mut p, *len)?;
                io::write_u32(&mut p, *dim)?;
            }
            Message::Search { k, query, trace_id } => {
                io::write_u32(&mut p, *k)?;
                io::write_u64(&mut p, query.len() as u64)?;
                io::write_f32s(&mut p, query)?;
                if let Some(tid) = trace_id {
                    io::write_u64(&mut p, *tid)?;
                }
            }
            Message::SearchOk { neighbors, trace } => {
                io::write_u64(&mut p, neighbors.len() as u64)?;
                for &(id, dist) in neighbors {
                    io::write_u64(&mut p, id)?;
                    p.extend_from_slice(&dist.to_le_bytes());
                }
                if let Some(t) = trace {
                    io::write_u64(&mut p, t.trace_id)?;
                    io::write_u64(&mut p, t.queue_ns)?;
                    io::write_u64(&mut p, t.scan_ns)?;
                    io::write_u64(&mut p, t.rerank_ns)?;
                    io::write_u64(&mut p, t.merge_ns)?;
                }
            }
            Message::Error { message } => {
                let bytes = message.as_bytes();
                io::write_u64(&mut p, bytes.len() as u64)?;
                io::write_bytes(&mut p, bytes)?;
            }
            Message::MetricsText { text } => {
                let bytes = text.as_bytes();
                io::write_u64(&mut p, bytes.len() as u64)?;
                io::write_bytes(&mut p, bytes)?;
            }
            Message::Ping | Message::Pong | Message::MetricsPull => {}
        }
        Ok(p)
    }

    fn decode_payload(tag: u8, payload: &[u8]) -> Result<Message> {
        let mut r: &[u8] = payload;
        let msg = match tag {
            1 => Message::Hello { version: io::read_u32(&mut r)? },
            2 => Message::HelloAck {
                version: io::read_u32(&mut r)?,
                start: io::read_u64(&mut r)?,
                len: io::read_u64(&mut r)?,
                dim: io::read_u32(&mut r)?,
            },
            3 => {
                let k = io::read_u32(&mut r)?;
                let count = io::read_u64_usize(&mut r)?;
                let query = io::read_f32s(&mut r, count)?;
                // The body is count-delimited, so the remaining bytes are
                // the optional v2 tail: exactly 0 (v1) or the tail size;
                // anything else falls through to the trailing-bytes error.
                let trace_id =
                    if r.len() == SEARCH_TAIL_BYTES { Some(io::read_u64(&mut r)?) } else { None };
                Message::Search { k, query, trace_id }
            }
            4 => {
                let count = io::read_u64_usize(&mut r)?;
                if count > io::MAX_ELEMS {
                    return Err(OpdrError::data("rpc: neighbor count too large"));
                }
                // Bounded preallocation: `count` is an untrusted length
                // field, so the vector grows only as bytes actually arrive.
                let mut neighbors = Vec::with_capacity(count.min(ALLOC_CHUNK));
                let mut b = [0u8; 4];
                for _ in 0..count {
                    let id = io::read_u64(&mut r)?;
                    r.read_exact(&mut b)?;
                    neighbors.push((id, f32::from_le_bytes(b)));
                }
                let trace = if r.len() == SEARCH_OK_TAIL_BYTES {
                    Some(WireTrace {
                        trace_id: io::read_u64(&mut r)?,
                        queue_ns: io::read_u64(&mut r)?,
                        scan_ns: io::read_u64(&mut r)?,
                        rerank_ns: io::read_u64(&mut r)?,
                        merge_ns: io::read_u64(&mut r)?,
                    })
                } else {
                    None
                };
                Message::SearchOk { neighbors, trace }
            }
            5 => {
                let len = io::read_u64_usize(&mut r)?;
                let bytes = io::read_bytes(&mut r, len)?;
                let message = String::from_utf8(bytes)
                    .map_err(|_| OpdrError::data("rpc: error message is not utf-8"))?;
                Message::Error { message }
            }
            6 => Message::Ping,
            7 => Message::Pong,
            8 => Message::MetricsPull,
            9 => {
                let len = io::read_u64_usize(&mut r)?;
                let bytes = io::read_bytes(&mut r, len)?;
                let text = String::from_utf8(bytes)
                    .map_err(|_| OpdrError::data("rpc: metrics text is not utf-8"))?;
                Message::MetricsText { text }
            }
            other => return Err(OpdrError::data(format!("rpc: unknown frame kind {other}"))),
        };
        if !r.is_empty() {
            return Err(OpdrError::data(format!(
                "rpc: {} trailing bytes after the payload",
                r.len()
            )));
        }
        Ok(msg)
    }
}

/// Encode one frame (header + payload) into a single buffer, so a frame is
/// always written with one `write_all` and a fault proxy can treat the
/// buffer as the frame boundary.
pub fn encode_frame(request_id: u64, msg: &Message) -> Result<Vec<u8>> {
    let payload = msg.encode_payload()?;
    if payload.len() > MAX_PAYLOAD_BYTES {
        return Err(OpdrError::data(format!(
            "rpc: {} payload of {} bytes exceeds the {} byte frame cap",
            msg.kind_name(),
            payload.len(),
            MAX_PAYLOAD_BYTES
        )));
    }
    // lint:allow(bounded-prealloc: encode path; payload.len() was cap-checked above)
    let mut buf = Vec::with_capacity(HEADER_BYTES + payload.len());
    buf.extend_from_slice(&FRAME_MAGIC);
    buf.push(msg.kind_tag());
    buf.extend_from_slice(&request_id.to_le_bytes());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(&crc32(&payload).to_le_bytes());
    buf.extend_from_slice(&payload);
    Ok(buf)
}

/// Read and validate one frame. Every failure is a typed error: bad magic,
/// unknown kind, an over-cap or lying length field, a CRC mismatch and
/// trailing payload bytes are all distinguished from transport errors
/// ([`OpdrError::Io`] — including read-deadline expiry, see
/// [`is_timeout`](super::is_timeout)).
pub fn read_frame(r: &mut dyn Read) -> Result<(u64, Message)> {
    let mut hdr = [0u8; HEADER_BYTES];
    r.read_exact(&mut hdr)?;
    decode_header_then_payload(&hdr, r)
}

/// Decode a frame from a byte slice (tests and fuzzing): the whole frame
/// must be present and nothing may trail it.
pub fn decode_frame(bytes: &[u8]) -> Result<(u64, Message)> {
    let mut r: &[u8] = bytes;
    let out = read_frame(&mut r)?;
    if !r.is_empty() {
        return Err(OpdrError::data(format!("rpc: {} trailing bytes after the frame", r.len())));
    }
    Ok(out)
}

fn decode_header_then_payload(
    hdr: &[u8; HEADER_BYTES],
    r: &mut dyn Read,
) -> Result<(u64, Message)> {
    if hdr[..4] != FRAME_MAGIC {
        return Err(OpdrError::data("rpc: bad frame magic"));
    }
    let kind = hdr[4];
    if !(1..=9).contains(&kind) {
        return Err(OpdrError::data(format!("rpc: unknown frame kind {kind}")));
    }
    let request_id = u64::from_le_bytes(hdr[5..13].try_into().expect("8 header bytes"));
    let len = u32::from_le_bytes(hdr[13..17].try_into().expect("4 header bytes")) as usize;
    let want_crc = u32::from_le_bytes(hdr[17..21].try_into().expect("4 header bytes"));
    if len > MAX_PAYLOAD_BYTES {
        // Fail before any allocation: the length field is untrusted.
        return Err(OpdrError::data(format!(
            "rpc: frame length {len} exceeds the {MAX_PAYLOAD_BYTES} byte cap"
        )));
    }
    let payload = io::read_bytes(r, len)?;
    let got_crc = crc32(&payload);
    if got_crc != want_crc {
        return Err(OpdrError::data(format!(
            "rpc: frame crc mismatch (want {want_crc:#010x}, got {got_crc:#010x})"
        )));
    }
    let msg = Message::decode_payload(kind, &payload)?;
    Ok((request_id, msg))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(id: u64, msg: &Message) {
        let bytes = encode_frame(id, msg).expect("encode");
        let (rid, decoded) = decode_frame(&bytes).expect("decode");
        assert_eq!(rid, id);
        let re = encode_frame(rid, &decoded).expect("re-encode");
        assert_eq!(bytes, re, "frame bytes must round-trip bit-exactly");
    }

    #[test]
    fn every_message_kind_roundtrips() {
        roundtrip(0, &Message::Hello { version: PROTOCOL_VERSION });
        roundtrip(1, &Message::HelloAck { version: 1, start: 7, len: 1000, dim: 64 });
        roundtrip(
            u64::MAX,
            &Message::Search { k: 10, query: vec![1.0, -2.5, f32::NAN], trace_id: None },
        );
        roundtrip(
            42,
            &Message::SearchOk {
                neighbors: vec![(0, 0.0), (u64::MAX, f32::INFINITY), (3, f32::NAN)],
                trace: None,
            },
        );
        roundtrip(3, &Message::Error { message: "shard on fire".to_string() });
        roundtrip(4, &Message::Ping);
        roundtrip(5, &Message::Pong);
        roundtrip(6, &Message::MetricsPull);
        roundtrip(7, &Message::MetricsText { text: "# TYPE x counter\nx 1\n".to_string() });
    }

    #[test]
    fn v2_trace_tails_roundtrip() {
        roundtrip(
            11,
            &Message::Search { k: 5, query: vec![0.25; 16], trace_id: Some(u64::MAX - 3) },
        );
        roundtrip(
            12,
            &Message::SearchOk {
                neighbors: vec![(9, 1.5), (2, f32::NAN)],
                trace: Some(WireTrace {
                    trace_id: 77,
                    queue_ns: 1,
                    scan_ns: u64::MAX,
                    rerank_ns: 0,
                    merge_ns: 42,
                }),
            },
        );
        // An empty neighbor list with a tail must not be mistaken for a
        // five-neighbor v1 frame (count is explicit, so it can't be).
        roundtrip(
            13,
            &Message::SearchOk { neighbors: vec![], trace: Some(WireTrace::default()) },
        );
    }

    #[test]
    fn v1_frames_without_tails_are_byte_identical_to_v1_layout() {
        // A `None`-tail Search encodes exactly the v1 payload: k u32,
        // count u64, count × f32 — nothing after. This is the downgrade
        // guarantee: v1 peers receive frames their decoder fully consumes.
        let msg = Message::Search { k: 3, query: vec![1.0, 2.0], trace_id: None };
        let bytes = encode_frame(1, &msg).expect("encode");
        assert_eq!(bytes.len() - HEADER_BYTES, 4 + 8 + 2 * 4);
        match decode_frame(&bytes).expect("decode").1 {
            Message::Search { trace_id, .. } => assert_eq!(trace_id, None),
            other => panic!("wrong kind {}", other.kind_name()),
        }
        let msg = Message::SearchOk { neighbors: vec![(1, 0.5)], trace: None };
        let bytes = encode_frame(2, &msg).expect("encode");
        assert_eq!(bytes.len() - HEADER_BYTES, 8 + 12);
        match decode_frame(&bytes).expect("decode").1 {
            Message::SearchOk { trace, .. } => assert_eq!(trace, None),
            other => panic!("wrong kind {}", other.kind_name()),
        }
    }

    #[test]
    fn partial_tail_is_a_typed_trailing_bytes_error() {
        // Remaining bytes that are neither 0 nor the exact tail size must
        // fail typed, not be half-consumed as a tail.
        let msg = Message::Search { k: 3, query: vec![1.0, 2.0], trace_id: None };
        let payload_garbage = |extra: usize| {
            let mut bytes = encode_frame(1, &msg).expect("encode");
            let mut payload = bytes.split_off(HEADER_BYTES);
            payload.resize(payload.len() + extra, 0xAB);
            bytes[13..17].copy_from_slice(&(payload.len() as u32).to_le_bytes());
            bytes[17..21].copy_from_slice(&crc32(&payload).to_le_bytes());
            bytes.extend_from_slice(&payload);
            bytes
        };
        for extra in [1usize, 7, 9, 40] {
            let err = decode_frame(&payload_garbage(extra)).expect_err("bad tail must fail");
            assert!(err.to_string().contains("trailing"), "extra={extra}: {err}");
        }
        // Exactly 8 extra bytes IS the v2 tail — decodes as a trace id.
        match decode_frame(&payload_garbage(8)).expect("v2 tail").1 {
            Message::Search { trace_id, .. } => {
                assert_eq!(trace_id, Some(u64::from_le_bytes([0xAB; 8])));
            }
            other => panic!("wrong kind {}", other.kind_name()),
        }
    }

    #[test]
    fn version_window_accepts_v1_and_v2_only() {
        assert!(version_supported(MIN_PROTOCOL_VERSION));
        assert!(version_supported(PROTOCOL_VERSION));
        assert!(!version_supported(0));
        assert!(!version_supported(PROTOCOL_VERSION + 1));
    }

    #[test]
    fn nan_distance_bits_survive_the_wire() {
        // A payload NaN with a nonstandard bit pattern must round-trip
        // bit-exactly — the gateway merge relies on raw-bits equality.
        let weird = f32::from_bits(0x7FC0_1234);
        let bytes = encode_frame(
            9,
            &Message::SearchOk { neighbors: vec![(5, weird)], trace: None },
        )
        .expect("encode");
        match decode_frame(&bytes).expect("decode").1 {
            Message::SearchOk { neighbors, .. } => {
                assert_eq!(neighbors[0].1.to_bits(), 0x7FC0_1234);
            }
            other => panic!("wrong kind {}", other.kind_name()),
        }
    }

    #[test]
    fn huge_length_field_fails_without_allocation() {
        let mut bytes = encode_frame(1, &Message::Ping).expect("encode");
        bytes[13..17].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode_frame(&bytes).expect_err("over-cap length must fail");
        assert!(err.to_string().contains("byte cap"), "got: {err}");
    }

    #[test]
    fn lying_length_field_fails_with_truncation_error() {
        // Length under the cap but beyond the actual bytes: the bounded
        // reader must hit EOF, not OOM.
        let msg = Message::Search { k: 3, query: vec![0.5; 8], trace_id: None };
        let mut bytes = encode_frame(1, &msg).expect("encode");
        bytes[13..17].copy_from_slice(&((MAX_PAYLOAD_BYTES - 1) as u32).to_le_bytes());
        assert!(decode_frame(&bytes).is_err());
    }

    #[test]
    fn corrupt_payload_fails_crc() {
        let msg = Message::Search { k: 3, query: vec![0.5; 8], trace_id: None };
        let mut bytes = encode_frame(1, &msg).expect("encode");
        let off = HEADER_BYTES + 5;
        bytes[off] ^= 0xFF;
        let err = decode_frame(&bytes).expect_err("corruption must fail");
        assert!(err.to_string().contains("crc"), "got: {err}");
    }

    #[test]
    fn bad_magic_and_bad_kind_are_typed() {
        let mut bytes = encode_frame(1, &Message::Ping).expect("encode");
        bytes[0] = b'X';
        assert!(decode_frame(&bytes).unwrap_err().to_string().contains("magic"));
        let mut bytes = encode_frame(1, &Message::Ping).expect("encode");
        bytes[4] = 200;
        assert!(decode_frame(&bytes).unwrap_err().to_string().contains("kind"));
    }

    #[test]
    fn truncation_at_every_boundary_is_a_typed_error() {
        let msg = Message::Search { k: 4, query: vec![1.0, 2.0, 3.0], trace_id: Some(7) };
        let bytes = encode_frame(77, &msg).expect("encode");
        for cut in 0..bytes.len() {
            let err = decode_frame(&bytes[..cut]).expect_err("truncated frame must fail");
            // Never a panic; always a typed error.
            let _ = err.to_string();
        }
    }
}
