//! Deterministic fault injection for the RPC layer — the test double the
//! distributed-serving correctness story is built on.
//!
//! Two pieces:
//!
//! * [`FaultyTransport`] — a framed sender that applies one scripted
//!   [`Fault`] per outbound frame **at the byte level**: drop the frame,
//!   forward only a prefix (then sever the connection), delay, duplicate,
//!   reorder with the following frame, or flip a byte at a scripted offset
//!   (header or payload). Used directly in unit / failure-injection tests
//!   against a live worker socket.
//! * [`FaultProxy`] — a loopback TCP proxy that relays whole frames between
//!   a client (the gateway) and an upstream worker, applying one script per
//!   direction. Scripts are consumed globally across reconnects, so a test
//!   can fault exactly the first handshake (or the third response) and
//!   assert the *next* connection heals.
//!
//! Faults are scripted per frame index — nothing is random — so every test
//! in the drop/truncate/delay/duplicate/reorder/corrupt ×
//! {handshake, request, response} matrix is reproducible.
//!
//! Both pieces work on raw header-delimited bytes, never on decoded
//! [`Message`]s, so they are kind-agnostic: protocol-v2 frames (trace
//! tails on `Search`/`SearchOk`, `MetricsPull`/`MetricsText`) relay and
//! fault exactly like v1 frames with no proxy changes.

use crate::error::Result;
use crate::rpc::frame::{encode_frame, Message, HEADER_BYTES, MAX_PAYLOAD_BYTES};
use crate::util::{lock_recover_ranked, ranks};
use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

/// One scripted fault, applied to one outbound frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Forward the frame unchanged.
    None,
    /// Never deliver the frame (the connection stays up).
    Drop,
    /// Deliver only the first `n` bytes, then sever the connection — the
    /// receiver sees a truncated frame followed by EOF.
    Truncate(usize),
    /// Sleep this many milliseconds before delivering (trips read
    /// deadlines when longer than the receiver's budget).
    Delay(u64),
    /// Deliver the frame twice back-to-back.
    Duplicate,
    /// Hold the frame and deliver it *after* the next one (a held frame
    /// with no successor on the same connection is never delivered).
    Reorder,
    /// Flip (XOR `0xFF`) the byte at this offset into the frame — offsets
    /// under [`HEADER_BYTES`] corrupt the header, larger ones the payload
    /// (offset is taken modulo the frame length).
    Corrupt(usize),
}

/// A finite script of per-frame faults; frames past the end are clean.
#[derive(Debug, Clone, Default)]
pub struct FaultScript {
    faults: Vec<Fault>,
}

impl FaultScript {
    /// No faults at all.
    pub fn clean() -> FaultScript {
        FaultScript { faults: Vec::new() }
    }

    /// Script from an explicit per-frame list.
    pub fn new(faults: Vec<Fault>) -> FaultScript {
        FaultScript { faults }
    }

    /// Clean for `skip` frames, then `fault`, then clean forever — the
    /// shape every matrix case uses (skip 0 = fault the handshake frame,
    /// skip 1 = fault the first post-handshake frame).
    pub fn fault_at(skip: usize, fault: Fault) -> FaultScript {
        // lint:allow(bounded-prealloc: `skip` is a test-script position (0 or 1), not wire data)
        let mut faults = vec![Fault::None; skip];
        faults.push(fault);
        FaultScript { faults }
    }

    fn into_state(self) -> Arc<Mutex<VecDeque<Fault>>> {
        Arc::new(Mutex::new(self.faults.into()))
    }
}

fn next_fault(state: &Mutex<VecDeque<Fault>>) -> Fault {
    let mut g = lock_recover_ranked(state, ranks::RPC_FAULTS);
    g.pop_front().unwrap_or(Fault::None)
}

/// Apply `fault` to an encoded frame, returning the byte chunks to forward
/// (in order) plus whether the connection must be severed afterwards and an
/// optional pre-delivery delay. `held` is the reorder buffer shared across
/// calls on one connection.
fn apply_fault(
    fault: Fault,
    bytes: Vec<u8>,
    held: &mut Option<Vec<u8>>,
) -> (Vec<Vec<u8>>, bool, Option<Duration>) {
    // A frame released from the reorder buffer rides behind the current one.
    let mut out: Vec<Vec<u8>> = Vec::new();
    let mut sever = false;
    let mut delay = None;
    match fault {
        Fault::None => out.push(bytes),
        Fault::Drop => {}
        Fault::Truncate(n) => {
            let n = n.min(bytes.len());
            out.push(bytes[..n].to_vec());
            sever = true;
        }
        Fault::Delay(ms) => {
            delay = Some(Duration::from_millis(ms));
            out.push(bytes);
        }
        Fault::Duplicate => {
            out.push(bytes.clone());
            out.push(bytes);
        }
        Fault::Reorder => {
            *held = Some(bytes);
        }
        Fault::Corrupt(off) => {
            let mut b = bytes;
            if !b.is_empty() {
                let off = off % b.len();
                b[off] ^= 0xFF;
            }
            out.push(b);
        }
    }
    if !matches!(fault, Fault::Reorder) {
        if let Some(h) = held.take() {
            out.push(h);
        }
    }
    (out, sever, delay)
}

/// A framed sender over any byte stream that applies a [`FaultScript`] to
/// its outbound frames. Receiving is passthrough (faults are injected on
/// the way out; point two of these at each other to fault both directions).
#[derive(Debug)]
pub struct FaultyTransport<S: Read + Write> {
    inner: S,
    script: Arc<Mutex<VecDeque<Fault>>>,
    held: Option<Vec<u8>>,
}

impl<S: Read + Write> FaultyTransport<S> {
    /// Wrap `inner`, faulting outbound frames per `script`.
    pub fn new(inner: S, script: FaultScript) -> FaultyTransport<S> {
        FaultyTransport { inner, script: script.into_state(), held: None }
    }

    /// Encode and send one frame through the fault script.
    pub fn send(&mut self, request_id: u64, msg: &Message) -> Result<()> {
        let bytes = encode_frame(request_id, msg)?;
        self.send_raw(bytes)
    }

    /// Send pre-encoded frame bytes through the fault script (lets fuzz
    /// tests inject already-mangled frames on top of scripted faults).
    pub fn send_raw(&mut self, bytes: Vec<u8>) -> Result<()> {
        let fault = next_fault(&self.script);
        let (chunks, sever, delay) = apply_fault(fault, bytes, &mut self.held);
        if let Some(d) = delay {
            thread::sleep(d);
        }
        for chunk in chunks {
            self.inner.write_all(&chunk)?;
        }
        self.inner.flush()?;
        if sever {
            // Severing is stream-specific; TcpStream severs on drop of the
            // write half — callers drop the transport after a truncation.
            return Ok(());
        }
        Ok(())
    }

    /// Receive one frame from the peer (no fault injection on this path).
    pub fn recv(&mut self) -> Result<(u64, Message)> {
        crate::rpc::frame::read_frame(&mut self.inner)
    }

    /// The wrapped stream (to shut a socket down after a truncation).
    pub fn inner(&self) -> &S {
        &self.inner
    }
}

/// A deterministic frame-relaying TCP proxy between a client and one
/// upstream worker, with one [`FaultScript`] per direction. Listens on an
/// ephemeral loopback port; scripts are consumed across all connections in
/// order, so reconnects after a fault observe the remaining (usually clean)
/// script tail.
#[derive(Debug)]
pub struct FaultProxy {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl FaultProxy {
    /// Start a proxy in front of `upstream`, faulting client→upstream
    /// frames per `request_script` and upstream→client frames per
    /// `response_script`.
    pub fn spawn(
        upstream: SocketAddr,
        request_script: FaultScript,
        response_script: FaultScript,
    ) -> Result<FaultProxy> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let req_state = request_script.into_state();
        let resp_state = response_script.into_state();
        let handle = thread::spawn(move || {
            // ORDERING: Relaxed — stop flag polled once per accept slice;
            // shutdown synchronizes through the join, not this load.
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((client, _)) => {
                        let Ok(server) = TcpStream::connect_timeout(
                            &upstream,
                            Duration::from_millis(2000),
                        ) else {
                            let _ = client.shutdown(Shutdown::Both);
                            continue;
                        };
                        let _ = client.set_nodelay(true);
                        let _ = server.set_nodelay(true);
                        spawn_relay(&client, &server, Arc::clone(&req_state));
                        spawn_relay(&server, &client, Arc::clone(&resp_state));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(FaultProxy { addr, stop, handle: Some(handle) })
    }

    /// The proxy's listen address — point the gateway's worker spec here.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting; existing relays die with their connections.
    pub fn shutdown(&mut self) {
        // ORDERING: Relaxed — stop flag; the accept thread observes it on
        // its next slice and the join provides the synchronization.
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for FaultProxy {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Relay whole frames `src` → `dst` through a shared fault-script state;
/// exits (severing both sockets) on EOF, a malformed upstream frame, or a
/// truncation fault.
fn spawn_relay(src: &TcpStream, dst: &TcpStream, script: Arc<Mutex<VecDeque<Fault>>>) {
    let (Ok(mut src), Ok(mut dst)) = (src.try_clone(), dst.try_clone()) else {
        let _ = src.shutdown(Shutdown::Both);
        let _ = dst.shutdown(Shutdown::Both);
        return;
    };
    thread::spawn(move || {
        let mut held: Option<Vec<u8>> = None;
        loop {
            let Ok(bytes) = read_raw_frame(&mut src) else { break };
            let fault = next_fault(&script);
            let (chunks, sever, delay) = apply_fault(fault, bytes, &mut held);
            if let Some(d) = delay {
                thread::sleep(d);
            }
            let mut write_failed = false;
            for chunk in chunks {
                if dst.write_all(&chunk).is_err() {
                    write_failed = true;
                    break;
                }
            }
            if sever || write_failed {
                break;
            }
        }
        let _ = src.shutdown(Shutdown::Both);
        let _ = dst.shutdown(Shutdown::Both);
    });
}

/// Read one frame's raw bytes (header + payload) without decoding the
/// payload — the relay only needs the boundary. The endpoints behind the
/// proxy are honest, so a malformed header here means the stream is done.
fn read_raw_frame(src: &mut TcpStream) -> std::io::Result<Vec<u8>> {
    let mut hdr = [0u8; HEADER_BYTES];
    src.read_exact(&mut hdr)?;
    let len = u32::from_le_bytes(hdr[13..17].try_into().expect("4 header bytes")) as usize;
    if len > MAX_PAYLOAD_BYTES {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "relay: frame length over cap",
        ));
    }
    let mut bytes = Vec::with_capacity(HEADER_BYTES + len.min(crate::index::io::ALLOC_CHUNK));
    bytes.extend_from_slice(&hdr);
    let mut remaining = len;
    let mut buf = [0u8; 8192];
    while remaining > 0 {
        let take = remaining.min(buf.len());
        src.read_exact(&mut buf[..take])?;
        bytes.extend_from_slice(&buf[..take]);
        remaining -= take;
    }
    Ok(bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn apply_fault_shapes() {
        let frame = vec![1u8, 2, 3, 4, 5];
        let mut held = None;

        let (out, sever, delay) = apply_fault(Fault::None, frame.clone(), &mut held);
        assert_eq!(out, vec![frame.clone()]);
        assert!(!sever && delay.is_none());

        let (out, _, _) = apply_fault(Fault::Drop, frame.clone(), &mut held);
        assert!(out.is_empty());

        let (out, sever, _) = apply_fault(Fault::Truncate(2), frame.clone(), &mut held);
        assert_eq!(out, vec![vec![1u8, 2]]);
        assert!(sever);

        let (out, _, delay) = apply_fault(Fault::Delay(7), frame.clone(), &mut held);
        assert_eq!(out, vec![frame.clone()]);
        assert_eq!(delay, Some(Duration::from_millis(7)));

        let (out, _, _) = apply_fault(Fault::Duplicate, frame.clone(), &mut held);
        assert_eq!(out.len(), 2);

        let (out, _, _) = apply_fault(Fault::Corrupt(1), frame.clone(), &mut held);
        assert_eq!(out[0][1], 2 ^ 0xFF);

        // Reorder holds the frame, then releases it behind the next one.
        let (out, _, _) = apply_fault(Fault::Reorder, vec![9u8], &mut held);
        assert!(out.is_empty());
        assert!(held.is_some());
        let (out, _, _) = apply_fault(Fault::None, vec![8u8], &mut held);
        assert_eq!(out, vec![vec![8u8], vec![9u8]]);
        assert!(held.is_none());
    }

    #[test]
    fn script_consumes_in_order_then_stays_clean() {
        let state = FaultScript::fault_at(1, Fault::Drop).into_state();
        assert_eq!(next_fault(&state), Fault::None);
        assert_eq!(next_fault(&state), Fault::Drop);
        assert_eq!(next_fault(&state), Fault::None);
        assert_eq!(next_fault(&state), Fault::None);
    }
}
