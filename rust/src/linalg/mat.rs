//! Row-major dense `f64` matrix.

use crate::error::{OpdrError, Result};

/// Dense row-major matrix of `f64`.
///
/// Fit-time math (PCA/MDS eigenproblems, regression) runs in `f64`;
/// embedding payloads stay `f32` elsewhere in the crate.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a flat row-major vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(OpdrError::shape(format!(
                "from_vec: {rows}x{cols} needs {} elems, got {}",
                rows * cols,
                data.len()
            )));
        }
        Ok(Mat { rows, cols, data })
    }

    /// Build from nested rows (test convenience).
    pub fn from_rows(rows: &[Vec<f64>]) -> Result<Self> {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        if rows.iter().any(|x| x.len() != c) {
            return Err(OpdrError::shape("from_rows: ragged input"));
        }
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            data.extend_from_slice(row);
        }
        Ok(Mat { rows: r, cols: c, data })
    }

    /// Build from an f32 row-major slice (embedding sets).
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(OpdrError::shape("from_f32: length mismatch"));
        }
        Ok(Mat { rows, cols, data: data.iter().map(|&x| x as f64).collect() })
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Flat row-major data.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable flat data.
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Row slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row slice.
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy of column `j`.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Transpose.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Matrix product `self * other`.
    pub fn matmul(&self, other: &Mat) -> Result<Mat> {
        if self.cols != other.rows {
            return Err(OpdrError::shape(format!(
                "matmul: {}x{} * {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        let mut out = Mat::zeros(self.rows, other.cols);
        // ikj loop order: streams `other` rows, cache-friendly for row-major.
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for j in 0..other.cols {
                    out_row[j] += a * orow[j];
                }
            }
        }
        Ok(out)
    }

    /// `self * vec`.
    pub fn matvec(&self, v: &[f64]) -> Result<Vec<f64>> {
        if v.len() != self.cols {
            return Err(OpdrError::shape("matvec: length mismatch"));
        }
        Ok((0..self.rows)
            .map(|i| self.row(i).iter().zip(v).map(|(a, b)| a * b).sum())
            .collect())
    }

    /// Scale in place.
    pub fn scale(&mut self, s: f64) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// Frobenius norm.
    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Is this matrix symmetric within `tol`?
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let m = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 2);
        assert_eq!(m[(0, 1)], 2.0);
        assert_eq!(m.row(1), &[3.0, 4.0]);
        assert_eq!(m.col(0), vec![1.0, 3.0]);
    }

    #[test]
    fn from_vec_rejects_bad_length() {
        assert!(Mat::from_vec(2, 2, vec![1.0; 3]).is_err());
    }

    #[test]
    fn from_rows_rejects_ragged() {
        assert!(Mat::from_rows(&[vec![1.0], vec![1.0, 2.0]]).is_err());
    }

    #[test]
    fn matmul_known_product() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        let b = Mat::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_shape_mismatch() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        assert!(a.matmul(&b).is_err());
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        let c = a.matmul(&Mat::eye(2)).unwrap();
        assert_eq!(c, a);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Mat::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]).unwrap();
        let t = a.transpose();
        assert_eq!(t.rows(), 3);
        assert_eq!(t[(2, 1)], 6.0);
        assert_eq!(t.transpose(), a);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        let v = a.matvec(&[1.0, 1.0]).unwrap();
        assert_eq!(v, vec![3.0, 7.0]);
        assert!(a.matvec(&[1.0]).is_err());
    }

    #[test]
    fn symmetry_check() {
        let s = Mat::from_rows(&[vec![2.0, 1.0], vec![1.0, 3.0]]).unwrap();
        assert!(s.is_symmetric(1e-12));
        let ns = Mat::from_rows(&[vec![2.0, 1.0], vec![0.0, 3.0]]).unwrap();
        assert!(!ns.is_symmetric(1e-12));
        assert!(!Mat::zeros(2, 3).is_symmetric(1e-12));
    }

    #[test]
    fn frobenius_norm() {
        let a = Mat::from_rows(&[vec![3.0, 0.0], vec![0.0, 4.0]]).unwrap();
        assert!((a.frobenius() - 5.0).abs() < 1e-12);
    }
}
