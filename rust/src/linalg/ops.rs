//! Centering, Gram and covariance operations shared by PCA and MDS.

use crate::error::{OpdrError, Result};
use crate::linalg::Mat;

/// Subtract the column means from a data matrix (rows = samples).
/// Returns the centered matrix and the mean vector.
pub fn center_columns(x: &Mat) -> (Mat, Vec<f64>) {
    let (m, d) = (x.rows(), x.cols());
    let mut means = vec![0.0; d];
    for i in 0..m {
        for (j, mean) in means.iter_mut().enumerate() {
            *mean += x[(i, j)];
        }
    }
    if m > 0 {
        for mean in &mut means {
            *mean /= m as f64;
        }
    }
    let mut c = x.clone();
    for i in 0..m {
        for j in 0..d {
            c[(i, j)] -= means[j];
        }
    }
    (c, means)
}

/// Sample covariance matrix `Xᶜᵀ Xᶜ / (m-1)` of row-sample data (d×d).
pub fn covariance_matrix(x: &Mat) -> Result<Mat> {
    let m = x.rows();
    if m < 2 {
        return Err(OpdrError::shape("covariance: need at least 2 samples"));
    }
    let (c, _) = center_columns(x);
    let mut cov = c.transpose().matmul(&c)?;
    cov.scale(1.0 / (m as f64 - 1.0));
    Ok(cov)
}

/// Gram matrix `Xᶜ Xᶜᵀ` of centered data (m×m). Shares the non-zero spectrum
/// with `XᶜᵀXᶜ` — the basis of the PCA "Gram trick" when d ≫ m.
pub fn gram_matrix(x: &Mat) -> Result<Mat> {
    let (c, _) = center_columns(x);
    c.matmul(&c.transpose())
}

/// Double-center a squared-distance matrix: `B = -½ J D² J`, `J = I - 11ᵀ/m`.
/// This is the classical-MDS Gram reconstruction (Torgerson 1952).
pub fn double_center(d_sq: &Mat) -> Result<Mat> {
    if d_sq.rows() != d_sq.cols() {
        return Err(OpdrError::shape("double_center: not square"));
    }
    let m = d_sq.rows();
    if m == 0 {
        return Ok(Mat::zeros(0, 0));
    }
    let mf = m as f64;
    let mut row_mean = vec![0.0; m];
    let mut col_mean = vec![0.0; m];
    let mut total = 0.0;
    for i in 0..m {
        for j in 0..m {
            let v = d_sq[(i, j)];
            row_mean[i] += v;
            col_mean[j] += v;
            total += v;
        }
    }
    for v in &mut row_mean {
        *v /= mf;
    }
    for v in &mut col_mean {
        *v /= mf;
    }
    total /= mf * mf;

    let mut b = Mat::zeros(m, m);
    for i in 0..m {
        for j in 0..m {
            b[(i, j)] = -0.5 * (d_sq[(i, j)] - row_mean[i] - col_mean[j] + total);
        }
    }
    Ok(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn centering_zeroes_means() {
        let x = Mat::from_rows(&[vec![1.0, 10.0], vec![3.0, 20.0], vec![5.0, 30.0]]).unwrap();
        let (c, means) = center_columns(&x);
        assert_eq!(means, vec![3.0, 20.0]);
        for j in 0..2 {
            let col_sum: f64 = (0..3).map(|i| c[(i, j)]).sum();
            assert!(col_sum.abs() < 1e-12);
        }
    }

    #[test]
    fn covariance_known_values() {
        // Two perfectly correlated columns.
        let x = Mat::from_rows(&[vec![0.0, 0.0], vec![1.0, 2.0], vec![2.0, 4.0]]).unwrap();
        let c = covariance_matrix(&x).unwrap();
        assert!((c[(0, 0)] - 1.0).abs() < 1e-12);
        assert!((c[(1, 1)] - 4.0).abs() < 1e-12);
        assert!((c[(0, 1)] - 2.0).abs() < 1e-12);
        assert!(c.is_symmetric(1e-12));
    }

    #[test]
    fn covariance_needs_two_samples() {
        let x = Mat::zeros(1, 4);
        assert!(covariance_matrix(&x).is_err());
    }

    #[test]
    fn gram_and_covariance_share_spectrum() {
        let mut rng = Rng::new(5);
        let x = Mat::from_vec(6, 10, rng.normal_vec(60)).unwrap();
        let g = gram_matrix(&x).unwrap(); // 6x6
        let mut cov = covariance_matrix(&x).unwrap(); // 10x10 (scaled by 1/(m-1))
        cov.scale(5.0); // undo the 1/(m-1): compare XᵀX vs XXᵀ spectra
        let eg = crate::linalg::eigh(&g).unwrap();
        let ec = crate::linalg::eigh(&cov).unwrap();
        // Top 5 non-zero eigenvalues must match (centered rank ≤ m-1 = 5).
        for i in 0..5 {
            assert!(
                (eg.values[i] - ec.values[i]).abs() < 1e-8 * (1.0 + eg.values[i].abs()),
                "i={i}: {} vs {}",
                eg.values[i],
                ec.values[i]
            );
        }
    }

    #[test]
    fn double_center_recovers_gram_of_points() {
        // Points in 2D; D²ij = |xi-xj|²; B should equal centered Gram.
        let pts = [(0.0, 0.0), (1.0, 0.0), (0.0, 2.0), (3.0, 1.0)];
        let m = pts.len();
        let mut dsq = Mat::zeros(m, m);
        for i in 0..m {
            for j in 0..m {
                let dx = pts[i].0 - pts[j].0;
                let dy = pts[i].1 - pts[j].1;
                dsq[(i, j)] = dx * dx + dy * dy;
            }
        }
        let b = double_center(&dsq).unwrap();
        // Build centered Gram directly.
        let x = Mat::from_rows(&pts.iter().map(|&(a, c)| vec![a, c]).collect::<Vec<_>>()).unwrap();
        let g = gram_matrix(&x).unwrap();
        assert!(b.max_abs_diff(&g) < 1e-10);
    }

    #[test]
    fn double_center_rejects_nonsquare() {
        assert!(double_center(&Mat::zeros(2, 3)).is_err());
    }

    #[test]
    fn double_center_empty_ok() {
        let b = double_center(&Mat::zeros(0, 0)).unwrap();
        assert_eq!(b.rows(), 0);
    }
}
