//! Symmetric eigendecomposition via the cyclic Jacobi method.
//!
//! Jacobi is chosen over QR because it is simple to verify, unconditionally
//! stable for symmetric input, and more than fast enough for the problem
//! sizes OPDR fits (≤ ~3000×3000 once, typically ≤ 300×300 per sweep point
//! thanks to the Gram trick in [`crate::reduction::Pca`]).

use crate::error::{OpdrError, Result};
use crate::linalg::Mat;

/// Result of [`eigh`]: eigenvalues descending, eigenvectors as columns of `vectors`
/// (i.e. `vectors.col(i)` pairs with `values[i]`).
#[derive(Debug, Clone)]
pub struct EighResult {
    /// Eigenvalues, sorted descending.
    pub values: Vec<f64>,
    /// Orthonormal eigenvectors; column `i` corresponds to `values[i]`.
    pub vectors: Mat,
}

/// Eigendecomposition of a symmetric matrix by cyclic Jacobi rotations.
///
/// Returns eigenpairs sorted by descending eigenvalue. Errors if the input is
/// not square/symmetric or if convergence fails (which for Jacobi indicates
/// NaN/Inf input).
pub fn eigh(a: &Mat) -> Result<EighResult> {
    if a.rows() != a.cols() {
        return Err(OpdrError::shape("eigh: matrix not square"));
    }
    if !a.is_symmetric(1e-8 * (1.0 + a.frobenius())) {
        return Err(OpdrError::shape("eigh: matrix not symmetric"));
    }
    if a.data().iter().any(|x| !x.is_finite()) {
        return Err(OpdrError::numeric("eigh: non-finite entries"));
    }
    let n = a.rows();
    if n == 0 {
        return Ok(EighResult { values: vec![], vectors: Mat::zeros(0, 0) });
    }

    let mut m = a.clone();
    let mut v = Mat::eye(n);
    let max_sweeps = 64;
    let tol = 1e-14 * (1.0 + a.frobenius());

    for _sweep in 0..max_sweeps {
        let off = off_diagonal_norm(&m);
        if off < tol {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < tol / (n as f64) {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                // Rotation angle.
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;

                // Apply rotation J(p,q,θ) on both sides: m = Jᵀ m J.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                // Accumulate eigenvectors.
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    // NaN-robust convergence check: a degenerate sweep (overflow inside
    // the rotations) can leave NaN in `m`, and `NaN > tol` is false — the
    // explicit NaN branch catches it instead of reporting convergence.
    let off = off_diagonal_norm(&m);
    if off.is_nan() || off > 1e-6 * (1.0 + a.frobenius()) {
        return Err(OpdrError::numeric("eigh: Jacobi did not converge"));
    }

    // Extract and sort descending.
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m[(i, i)], i)).collect();
    sort_eigenpairs_descending(&mut pairs);
    let values: Vec<f64> = pairs.iter().map(|&(val, _)| val).collect();
    let mut vectors = Mat::zeros(n, n);
    for (new_col, &(_, old_col)) in pairs.iter().enumerate() {
        for k in 0..n {
            vectors[(k, new_col)] = v[(k, old_col)];
        }
    }
    Ok(EighResult { values, vectors })
}

/// Sort `(eigenvalue, column)` pairs descending under the IEEE total
/// order. `partial_cmp(..).unwrap()` here used to panic if a degenerate
/// matrix (OPQ/PCA training on pathological data) ever produced a NaN
/// diagonal — `total_cmp` keeps the sort deterministic and panic-free, and
/// the NaN-robust convergence check above rejects such sweeps before the
/// result can leave this module.
fn sort_eigenpairs_descending(pairs: &mut [(f64, usize)]) {
    pairs.sort_by(|a, b| b.0.total_cmp(&a.0));
}

fn off_diagonal_norm(m: &Mat) -> f64 {
    let n = m.rows();
    let mut s = 0.0;
    for i in 0..n {
        for j in 0..n {
            if i != j {
                s += m[(i, j)] * m[(i, j)];
            }
        }
    }
    s.sqrt()
}

/// Power iteration for the dominant eigenpair (used for cheap spectral probes
/// and as an independent cross-check on `eigh` in tests).
pub fn power_iteration(a: &Mat, iters: usize, seed: u64) -> Result<(f64, Vec<f64>)> {
    if a.rows() != a.cols() {
        return Err(OpdrError::shape("power_iteration: not square"));
    }
    let n = a.rows();
    if n == 0 {
        return Err(OpdrError::shape("power_iteration: empty"));
    }
    let mut rng = crate::util::Rng::new(seed);
    let mut v: Vec<f64> = rng.normal_vec(n);
    normalize(&mut v);
    let mut lambda = 0.0;
    for _ in 0..iters {
        let mut w = a.matvec(&v)?;
        let norm = l2(&w);
        if norm < 1e-300 {
            return Err(OpdrError::numeric("power_iteration: zero vector"));
        }
        for x in &mut w {
            *x /= norm;
        }
        lambda = dot(&w, &a.matvec(&w)?);
        v = w;
    }
    Ok((lambda, v))
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}
fn l2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}
fn normalize(a: &mut [f64]) {
    let n = l2(a);
    if n > 0.0 {
        for x in a.iter_mut() {
            *x /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_symmetric(n: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let x = rng.normal();
                m[(i, j)] = x;
                m[(j, i)] = x;
            }
        }
        m
    }

    #[test]
    fn diagonal_matrix_eigenvalues() {
        let mut d = Mat::zeros(3, 3);
        d[(0, 0)] = 1.0;
        d[(1, 1)] = 5.0;
        d[(2, 2)] = 3.0;
        let r = eigh(&d).unwrap();
        assert_eq!(r.values, vec![5.0, 3.0, 1.0]);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] -> eigenvalues 3, 1.
        let a = Mat::from_rows(&[vec![2.0, 1.0], vec![1.0, 2.0]]).unwrap();
        let r = eigh(&a).unwrap();
        assert!((r.values[0] - 3.0).abs() < 1e-10);
        assert!((r.values[1] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn reconstruction_and_orthonormality() {
        let a = random_symmetric(12, 99);
        let r = eigh(&a).unwrap();
        // V Λ Vᵀ == A
        let mut lam = Mat::zeros(12, 12);
        for i in 0..12 {
            lam[(i, i)] = r.values[i];
        }
        let recon = r.vectors.matmul(&lam).unwrap().matmul(&r.vectors.transpose()).unwrap();
        assert!(recon.max_abs_diff(&a) < 1e-8, "diff={}", recon.max_abs_diff(&a));
        // VᵀV == I
        let vtv = r.vectors.transpose().matmul(&r.vectors).unwrap();
        assert!(vtv.max_abs_diff(&Mat::eye(12)) < 1e-10);
    }

    #[test]
    fn eigenvalues_sorted_descending() {
        let a = random_symmetric(8, 7);
        let r = eigh(&a).unwrap();
        for w in r.values.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
    }

    #[test]
    fn trace_equals_eigenvalue_sum() {
        let a = random_symmetric(10, 3);
        let r = eigh(&a).unwrap();
        let trace: f64 = (0..10).map(|i| a[(i, i)]).sum();
        let sum: f64 = r.values.iter().sum();
        assert!((trace - sum).abs() < 1e-9);
    }

    #[test]
    fn rejects_nonsquare_and_asymmetric() {
        assert!(eigh(&Mat::zeros(2, 3)).is_err());
        let ns = Mat::from_rows(&[vec![1.0, 2.0], vec![0.0, 1.0]]).unwrap();
        assert!(eigh(&ns).is_err());
    }

    #[test]
    fn rejects_non_finite() {
        let mut a = Mat::zeros(2, 2);
        a[(0, 0)] = f64::NAN;
        assert!(eigh(&a).is_err());
    }

    #[test]
    fn eigenpair_sort_is_total_and_never_panics_on_nan() {
        // Regression: this sort used `partial_cmp(..).unwrap()`, which
        // panicked the whole training path if a degenerate matrix ever put
        // a NaN on the Jacobi diagonal. The total order sorts finite pairs
        // descending and parks NaN deterministically instead of panicking.
        let mut pairs = vec![(1.0f64, 0usize), (f64::NAN, 1), (3.0, 2), (-2.0, 3)];
        sort_eigenpairs_descending(&mut pairs);
        let finite: Vec<usize> =
            pairs.iter().filter(|p| !p.0.is_nan()).map(|p| p.1).collect();
        assert_eq!(finite, vec![2, 0, 3], "finite pairs sorted descending");
        assert_eq!(pairs.iter().filter(|p| p.0.is_nan()).count(), 1);
        // Ties and signed zeros stay deterministic across calls.
        let mut a = vec![(0.0f64, 0usize), (-0.0, 1), (0.0, 2)];
        let mut b = a.clone();
        sort_eigenpairs_descending(&mut a);
        sort_eigenpairs_descending(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn degenerate_rank_deficient_matrix_still_decomposes() {
        // All-equal rows: rank 1, the kind of matrix degenerate OPQ/PCA
        // training feeds through MᵀM. Must decompose (or error), never
        // panic.
        let a = Mat::from_rows(&[
            vec![4.0, 4.0, 4.0],
            vec![4.0, 4.0, 4.0],
            vec![4.0, 4.0, 4.0],
        ])
        .unwrap();
        let r = eigh(&a).unwrap();
        assert!((r.values[0] - 12.0).abs() < 1e-9);
        assert!(r.values[1].abs() < 1e-9 && r.values[2].abs() < 1e-9);
    }

    #[test]
    fn empty_matrix_ok() {
        let r = eigh(&Mat::zeros(0, 0)).unwrap();
        assert!(r.values.is_empty());
    }

    #[test]
    fn power_iteration_matches_eigh() {
        let a = random_symmetric(9, 21);
        // Shift to make dominant eigenvalue positive & well separated in magnitude.
        let mut shifted = a.clone();
        for i in 0..9 {
            shifted[(i, i)] += 20.0;
        }
        let r = eigh(&shifted).unwrap();
        let (lam, _) = power_iteration(&shifted, 500, 1).unwrap();
        assert!((lam - r.values[0]).abs() < 1e-6, "power={lam} eigh={}", r.values[0]);
    }
}
