//! Dense linear-algebra substrate.
//!
//! The offline crate set has no `ndarray`/`nalgebra`, so the reducers are
//! built on this minimal, well-tested kernel set: a row-major `f64` matrix,
//! a cyclic-Jacobi symmetric eigendecomposition (the workhorse of both PCA
//! and classical MDS), and the centering/Gram utilities those methods need.
//!
//! Sizes in OPDR experiments are modest (the paper sweeps m ≤ 300 samples and
//! d ≤ 2816 dims; PCA fits run on min(m, d)-sized symmetric matrices thanks to
//! the Gram trick), so Jacobi's O(n³) per sweep is plenty and numerically
//! very robust.

pub mod eig;
pub mod mat;
pub mod ops;

pub use eig::{eigh, EighResult};
pub use mat::Mat;
pub use ops::{center_columns, double_center, gram_matrix, covariance_matrix};
