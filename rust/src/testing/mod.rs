//! Mini-proptest: a seeded property-testing harness.
//!
//! The offline registry has no `proptest`, so invariants are checked with
//! this small substitute: deterministic generators over a seeded [`Rng`],
//! a `forall` runner with case-count control, and greedy input shrinking for
//! numeric vectors. Property tests across the crate (measure additivity,
//! planner monotonicity, batcher ordering, kernel-vs-reference) run on it.

use crate::util::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed (case `i` uses `seed + i`).
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0x9E37 }
    }
}

/// Run `prop` on `cases` random inputs produced by `gen`; panic with the
/// failing seed and case index on first failure (re-runnable directly).
pub fn forall<T, G, P>(cfg: PropConfig, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let mut rng = Rng::new(cfg.seed.wrapping_add(case as u64));
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed (case {case}, seed {}): {msg}\ninput: {input:?}",
                cfg.seed.wrapping_add(case as u64)
            );
        }
    }
}

/// Assert two neighbor lists are byte-identical — same ids in the same
/// order with bit-identical distances. This is the exactness contract the
/// sharded fan-out/merge and every index persistence round-trip promise;
/// tests across the crate share it so the contract is stated once.
pub fn assert_same_neighbors(a: &[crate::knn::Neighbor], b: &[crate::knn::Neighbor]) {
    assert_eq!(a.len(), b.len(), "neighbor counts differ");
    for (rank, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.index, y.index, "rank {rank}: id mismatch");
        assert_eq!(
            x.distance.to_bits(),
            y.distance.to_bits(),
            "rank {rank}: distance bits differ ({} vs {})",
            x.distance,
            y.distance
        );
    }
}

/// Shrink a failing f32-vector input by greedy halving/truncation; returns
/// the smallest still-failing input found.
pub fn shrink_vec_f32<P>(input: Vec<f32>, mut fails: P) -> Vec<f32>
where
    P: FnMut(&[f32]) -> bool,
{
    debug_assert!(fails(&input), "shrink called with passing input");
    let mut current = input;
    loop {
        let mut improved = false;
        // Try removing halves.
        if current.len() > 1 {
            let half = current.len() / 2;
            for cand in [current[..half].to_vec(), current[half..].to_vec()] {
                if !cand.is_empty() && fails(&cand) {
                    current = cand;
                    improved = true;
                    break;
                }
            }
        }
        if improved {
            continue;
        }
        // Try zeroing elements.
        for i in 0..current.len() {
            if current[i] != 0.0 {
                let mut cand = current.clone();
                cand[i] = 0.0;
                if fails(&cand) {
                    current = cand;
                    improved = true;
                }
            }
        }
        if !improved {
            return current;
        }
    }
}

/// Generators for common inputs.
pub mod gen {
    use crate::util::Rng;

    /// Random vector length in `[lo, hi]`.
    pub fn len(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo + 1)
    }

    /// Random normal f32 vector.
    pub fn vec_f32(rng: &mut Rng, n: usize) -> Vec<f32> {
        rng.normal_vec_f32(n)
    }

    /// Random embedding block: (data, dim, m) with m in [m_lo, m_hi], dim in
    /// [d_lo, d_hi].
    pub fn embedding_block(
        rng: &mut Rng,
        m_lo: usize,
        m_hi: usize,
        d_lo: usize,
        d_hi: usize,
    ) -> (Vec<f32>, usize, usize) {
        let m = len(rng, m_lo, m_hi);
        let d = len(rng, d_lo, d_hi);
        (rng.normal_vec_f32(m * d), d, m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(
            PropConfig { cases: 32, seed: 1 },
            |rng| rng.normal_vec_f32(8),
            |v| {
                if v.len() == 8 {
                    Ok(())
                } else {
                    Err("wrong length".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failures() {
        forall(
            PropConfig { cases: 8, seed: 2 },
            |rng| rng.below(10),
            |&n| if n < 100 { Err(format!("always fails, n={n}")) } else { Ok(()) },
        );
    }

    #[test]
    fn shrink_finds_minimal_failure() {
        // Failing predicate: contains any negative value.
        let input = vec![1.0, -2.0, 3.0, 4.0, -5.0, 6.0, 7.0, 8.0];
        let small = shrink_vec_f32(input, |v| v.iter().any(|&x| x < 0.0));
        assert!(small.iter().any(|&x| x < 0.0));
        assert!(small.len() <= 2, "shrunk to {small:?}");
    }

    #[test]
    fn generators_in_bounds() {
        let mut rng = crate::util::Rng::new(3);
        for _ in 0..50 {
            let (data, d, m) = gen::embedding_block(&mut rng, 2, 10, 1, 5);
            assert_eq!(data.len(), d * m);
            assert!((2..=10).contains(&m));
            assert!((1..=5).contains(&d));
        }
    }
}
