//! Multimodal embedding pipeline.
//!
//! Maps raw [`MultimodalRecord`]s to embedding vectors through the paper's
//! encoder line-up: CLIP (text tower 512 + image tower 512, concatenated to
//! 1024), BERT (768, text only), ViT (768, image only) and BERT+PANNs
//! (768 + 2048 = 2816) for ESC-50 audio–text.
//!
//! Two interchangeable backends:
//! * [`RuntimeEncoder`] — executes the AOT-compiled JAX towers via the PJRT
//!   [`Engine`] (the production path; `make artifacts` first);
//! * [`HashEncoder`] — a pure-Rust deterministic stand-in (fixed random
//!   projection + tanh), used by tests and available when artifacts are
//!   absent. Different `ModelKind`s use different projection seeds, so model
//!   comparisons (Figs 7–9) exercise genuinely different geometries on both
//!   backends.

pub mod encoder;

pub use encoder::{Encoder, HashEncoder, RuntimeEncoder};

use crate::data::records::MultimodalRecord;
use crate::data::EmbeddingSet;
use crate::error::Result;

/// The embedding models evaluated in the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelKind {
    /// CLIP: text(512) ⊕ image(512) → 1024.
    Clip,
    /// BERT: text → 768.
    Bert,
    /// ViT: image → 768.
    Vit,
    /// BERT ⊕ PANNs-CNN14: text(768) ⊕ audio(2048) → 2816 (ESC-50 path).
    BertPanns,
}

impl ModelKind {
    /// All models compared in Figs 7–9.
    pub const FIGURE_MODELS: [ModelKind; 3] = [ModelKind::Bert, ModelKind::Vit, ModelKind::Clip];

    /// Parse from config / CLI.
    pub fn parse(s: &str) -> Option<ModelKind> {
        match s.to_ascii_lowercase().as_str() {
            "clip" => Some(ModelKind::Clip),
            "bert" => Some(ModelKind::Bert),
            "vit" => Some(ModelKind::Vit),
            "bert-panns" | "bertpanns" | "audio" | "concat-bert-panns" => Some(ModelKind::BertPanns),
            _ => None,
        }
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Clip => "clip",
            ModelKind::Bert => "bert",
            ModelKind::Vit => "vit",
            ModelKind::BertPanns => "bert-panns",
        }
    }

    /// Output dimensionality of the (concatenated) embedding.
    pub fn output_dim(&self) -> usize {
        match self {
            ModelKind::Clip => 1024,
            ModelKind::Bert => 768,
            ModelKind::Vit => 768,
            ModelKind::BertPanns => 2816,
        }
    }
}

/// Embed a record batch with the given encoder backend.
pub fn embed_records(
    encoder: &dyn Encoder,
    model: ModelKind,
    records: &[MultimodalRecord],
    label: &str,
) -> Result<EmbeddingSet> {
    let dim = model.output_dim();
    let mut data = Vec::with_capacity(records.len() * dim);
    // Encoders work on fixed batch sizes internally; chunk here.
    let bs = encoder.batch_size();
    let mut i = 0;
    while i < records.len() {
        let end = (i + bs).min(records.len());
        let out = encoder.encode_batch(model, &records[i..end])?;
        debug_assert_eq!(out.len(), (end - i) * dim);
        data.extend_from_slice(&out);
        i = end;
    }
    EmbeddingSet::new(format!("{label}/{}", model.name()), dim, data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::records::generate_records;
    use crate::data::DatasetKind;

    #[test]
    fn model_kind_roundtrip() {
        for m in [ModelKind::Clip, ModelKind::Bert, ModelKind::Vit, ModelKind::BertPanns] {
            assert_eq!(ModelKind::parse(m.name()), Some(m));
        }
        assert_eq!(ModelKind::Clip.output_dim(), 1024);
        assert_eq!(ModelKind::BertPanns.output_dim(), 2816);
    }

    #[test]
    fn embed_records_produces_right_shape() {
        let recs = generate_records(DatasetKind::Flickr30k, 11, 1);
        let enc = HashEncoder::default();
        let set = embed_records(&enc, ModelKind::Clip, &recs, "flickr").unwrap();
        assert_eq!(set.len(), 11);
        assert_eq!(set.dim(), 1024);
        assert!(set.label().contains("clip"));
    }

    #[test]
    fn different_models_give_different_embeddings() {
        let recs = generate_records(DatasetKind::Flickr30k, 4, 2);
        let enc = HashEncoder::default();
        let bert = embed_records(&enc, ModelKind::Bert, &recs, "x").unwrap();
        let vit = embed_records(&enc, ModelKind::Vit, &recs, "x").unwrap();
        assert_eq!(bert.dim(), vit.dim());
        assert_ne!(bert.data()[..10], vit.data()[..10]);
    }
}
