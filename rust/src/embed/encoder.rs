//! Encoder backends: PJRT runtime towers and the pure-Rust stand-in.

use crate::data::records::{
    MultimodalRecord, AUDIO_FRAMES, AUDIO_MELS, IMAGE_FEAT, IMAGE_PATCHES, TEXT_FEAT, TEXT_TOKENS,
};
use crate::embed::ModelKind;
use crate::error::{OpdrError, Result};
use crate::runtime::{ArrayF32, Engine};
use crate::util::Rng;

/// Fixed batch size the encoder artifacts are lowered with.
pub const ENCODER_BATCH: usize = 8;

/// An embedding backend.
pub trait Encoder {
    /// Encode up to [`Encoder::batch_size`] records; returns a row-major
    /// `len(records) × model.output_dim()` block.
    fn encode_batch(&self, model: ModelKind, records: &[MultimodalRecord]) -> Result<Vec<f32>>;

    /// Preferred batch size.
    fn batch_size(&self) -> usize {
        ENCODER_BATCH
    }

    /// Backend name for logs.
    fn backend_name(&self) -> &'static str;
}

/// PJRT-backed encoder: runs the AOT-lowered JAX towers.
pub struct RuntimeEncoder<'e> {
    engine: &'e Engine,
}

impl<'e> RuntimeEncoder<'e> {
    /// Wrap an engine (artifacts must include the tower modules).
    pub fn new(engine: &'e Engine) -> Self {
        RuntimeEncoder { engine }
    }

    fn run_tower(
        &self,
        artifact: &str,
        feats: &[f32],
        per_record: usize,
        n: usize,
        out_dim: usize,
    ) -> Result<Vec<f32>> {
        // Zero-pad the batch to ENCODER_BATCH records.
        let mut batch = vec![0.0f32; ENCODER_BATCH * per_record];
        batch[..n * per_record].copy_from_slice(&feats[..n * per_record]);
        let input = ArrayF32::new(batch, vec![ENCODER_BATCH, per_record])?;
        let out = self.engine.execute(artifact, &[input])?;
        let arr = out
            .into_iter()
            .next()
            .ok_or_else(|| OpdrError::runtime(format!("{artifact}: no output")))?;
        if arr.shape != vec![ENCODER_BATCH, out_dim] {
            return Err(OpdrError::runtime(format!(
                "{artifact}: unexpected output shape {:?}",
                arr.shape
            )));
        }
        Ok(arr.data[..n * out_dim].to_vec())
    }
}

impl Encoder for RuntimeEncoder<'_> {
    fn encode_batch(&self, model: ModelKind, records: &[MultimodalRecord]) -> Result<Vec<f32>> {
        let n = records.len();
        if n == 0 || n > ENCODER_BATCH {
            return Err(OpdrError::shape(format!(
                "encode_batch: got {n} records, batch size is {ENCODER_BATCH}"
            )));
        }
        let gather = |f: fn(&MultimodalRecord) -> &[f32], per: usize| -> Result<Vec<f32>> {
            let mut out = Vec::with_capacity(n * per);
            for r in records {
                let feats = f(r);
                if feats.len() != per {
                    return Err(OpdrError::shape("encode_batch: record feature size mismatch"));
                }
                out.extend_from_slice(feats);
            }
            Ok(out)
        };
        let text_per = TEXT_TOKENS * TEXT_FEAT;
        let image_per = IMAGE_PATCHES * IMAGE_FEAT;
        let audio_per = AUDIO_MELS * AUDIO_FRAMES;

        match model {
            ModelKind::Clip => {
                let text = gather(|r| &r.text, text_per)?;
                let image = gather(|r| &r.image, image_per)?;
                let t = self.run_tower("clip_text", &text, text_per, n, 512)?;
                let i = self.run_tower("clip_image", &image, image_per, n, 512)?;
                Ok(concat_rows(&t, 512, &i, 512, n))
            }
            ModelKind::Bert => {
                let text = gather(|r| &r.text, text_per)?;
                self.run_tower("bert", &text, text_per, n, 768)
            }
            ModelKind::Vit => {
                let image = gather(|r| &r.image, image_per)?;
                self.run_tower("vit", &image, image_per, n, 768)
            }
            ModelKind::BertPanns => {
                let text = gather(|r| &r.text, text_per)?;
                let audio = gather(|r| &r.audio, audio_per)?;
                let t = self.run_tower("bert", &text, text_per, n, 768)?;
                let a = self.run_tower("panns", &audio, audio_per, n, 2048)?;
                Ok(concat_rows(&t, 768, &a, 2048, n))
            }
        }
    }

    fn backend_name(&self) -> &'static str {
        "pjrt-runtime"
    }
}

/// Concatenate two row-major blocks per row: `n×(da+db)`.
fn concat_rows(a: &[f32], da: usize, b: &[f32], db: usize, n: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(n * (da + db));
    for i in 0..n {
        out.extend_from_slice(&a[i * da..(i + 1) * da]);
        out.extend_from_slice(&b[i * db..(i + 1) * db]);
    }
    out
}

/// Pure-Rust deterministic encoder: per-(model, modality) fixed random
/// projection followed by `tanh`. Preserves the cluster structure of the raw
/// records (it is a Lipschitz map), so accuracy-sweep behaviour matches the
/// runtime towers in shape, which is all Figs 7–9 need.
#[derive(Debug, Clone, Copy, Default)]
pub struct HashEncoder {
    /// Extra seed so tests can decorrelate encoders.
    pub seed: u64,
}

impl HashEncoder {
    fn project(&self, feats: &[f32], out_dim: usize, stream: u64) -> Vec<f32> {
        // The projection matrix is re-derived per call from the stream seed;
        // deterministic and allocation-bounded (row-at-a-time).
        let in_dim = feats.len();
        let mut out = vec![0.0f32; out_dim];
        let mut rng = Rng::new(self.seed ^ stream);
        let scale = (1.0 / in_dim as f64).sqrt();
        // Generate the matrix column-major on the fly: for each input feature,
        // a pseudo-random row of weights.
        for (j, &x) in feats.iter().enumerate() {
            if x == 0.0 {
                continue;
            }
            let mut row_rng = rng.fork(j as u64);
            for o in out.iter_mut() {
                *o += x * (row_rng.normal() * scale) as f32;
            }
        }
        for o in out.iter_mut() {
            *o = o.tanh();
        }
        out
    }
}

impl Encoder for HashEncoder {
    fn encode_batch(&self, model: ModelKind, records: &[MultimodalRecord]) -> Result<Vec<f32>> {
        let dim = model.output_dim();
        let mut out = Vec::with_capacity(records.len() * dim);
        for r in records {
            let v = match model {
                ModelKind::Clip => {
                    let mut v = self.project(&r.text, 512, 0xC11F_7E87);
                    v.extend(self.project(&r.image, 512, 0xC11F_1487));
                    v
                }
                ModelKind::Bert => self.project(&r.text, 768, 0xBE27_0001),
                ModelKind::Vit => self.project(&r.image, 768, 0x0017_0002),
                ModelKind::BertPanns => {
                    if r.audio.is_empty() {
                        return Err(OpdrError::data("bert-panns requires audio features"));
                    }
                    let mut v = self.project(&r.text, 768, 0xBE27_0001);
                    v.extend(self.project(&r.audio, 2048, 0xA0D1_0003));
                    v
                }
            };
            debug_assert_eq!(v.len(), dim);
            out.extend(v);
        }
        Ok(out)
    }

    fn batch_size(&self) -> usize {
        64
    }

    fn backend_name(&self) -> &'static str {
        "hash-fallback"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::records::generate_records;
    use crate::data::DatasetKind;

    #[test]
    fn hash_encoder_deterministic() {
        let recs = generate_records(DatasetKind::Flickr30k, 3, 7);
        let e = HashEncoder::default();
        let a = e.encode_batch(ModelKind::Clip, &recs).unwrap();
        let b = e.encode_batch(ModelKind::Clip, &recs).unwrap();
        assert_eq!(a, b);
        let e2 = HashEncoder { seed: 1 };
        let c = e2.encode_batch(ModelKind::Clip, &recs).unwrap();
        assert_ne!(a, c);
    }

    #[test]
    fn hash_encoder_preserves_class_structure() {
        // Same-class records should embed closer than cross-class on average.
        let recs = generate_records(DatasetKind::MaterialsObservable, 40, 9);
        let e = HashEncoder::default();
        let emb = e.encode_batch(ModelKind::Bert, &recs[..40.min(e.batch_size())]).unwrap();
        let dim = ModelKind::Bert.output_dim();
        let mut same = vec![];
        let mut diff = vec![];
        let n = emb.len() / dim;
        for i in 0..n {
            for j in (i + 1)..n {
                let d = crate::metrics::sq_euclidean(&emb[i * dim..(i + 1) * dim], &emb[j * dim..(j + 1) * dim]) as f64;
                if recs[i].class == recs[j].class {
                    same.push(d);
                } else {
                    diff.push(d);
                }
            }
        }
        if !same.is_empty() && !diff.is_empty() {
            assert!(crate::util::float::mean(&same) < crate::util::float::mean(&diff));
        }
    }

    #[test]
    fn bert_panns_requires_audio() {
        let recs = generate_records(DatasetKind::Flickr30k, 2, 3); // no audio
        let e = HashEncoder::default();
        assert!(e.encode_batch(ModelKind::BertPanns, &recs).is_err());
        let audio = generate_records(DatasetKind::Esc50, 2, 3);
        let out = e.encode_batch(ModelKind::BertPanns, &audio).unwrap();
        assert_eq!(out.len(), 2 * 2816);
    }

    #[test]
    fn concat_rows_interleaves() {
        let a = [1.0f32, 2.0, 10.0, 20.0]; // 2 rows × 2
        let b = [3.0f32, 30.0]; // 2 rows × 1
        let c = concat_rows(&a, 2, &b, 1, 2);
        assert_eq!(c, vec![1.0, 2.0, 3.0, 10.0, 20.0, 30.0]);
    }
}
