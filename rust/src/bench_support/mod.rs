//! Mini-criterion: the benchmark harness used by every `benches/` target
//! (the offline registry has no `criterion`; `Cargo.toml` sets
//! `harness = false` and targets call [`Bencher`] directly).
//!
//! Measures wall time with warmup, reports mean / p50 / p99 / throughput,
//! and detects obviously unstable runs (coefficient of variation).

use crate::util::float::{mean, percentile_sorted, stddev};
use crate::util::Stopwatch;
use std::time::Duration;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Case label.
    pub name: String,
    /// Measured iteration times (ns).
    pub samples_ns: Vec<f64>,
    /// Optional per-iteration item count (for throughput).
    pub items_per_iter: Option<u64>,
}

impl BenchResult {
    /// Mean iteration time.
    pub fn mean(&self) -> Duration {
        Duration::from_nanos(mean(&self.samples_ns) as u64)
    }

    /// Percentile of iteration time. NaN samples (a zero-duration clock
    /// glitch fed through a ratio, say) sort last via the IEEE total order
    /// instead of panicking the whole bench run.
    pub fn percentile(&self, q: f64) -> Duration {
        let mut sorted = self.samples_ns.clone();
        sorted.sort_by(f64::total_cmp);
        Duration::from_nanos(percentile_sorted(&sorted, q) as u64)
    }

    /// Items/sec if an item count was provided.
    pub fn throughput(&self) -> Option<f64> {
        let items = self.items_per_iter? as f64;
        let m = mean(&self.samples_ns);
        if m <= 0.0 {
            return None;
        }
        Some(items / (m / 1e9))
    }

    /// Coefficient of variation (stability indicator).
    pub fn cv(&self) -> f64 {
        let m = mean(&self.samples_ns);
        if m <= 0.0 {
            return 0.0;
        }
        stddev(&self.samples_ns) / m
    }

    /// One summary line.
    pub fn summary(&self) -> String {
        let tp = self
            .throughput()
            .map(|t| format!("  {:.0} items/s", t))
            .unwrap_or_default();
        let flag = if self.cv() > 0.25 { "  [unstable]" } else { "" };
        format!(
            "{:<44} mean={:>10}  p50={:>10}  p99={:>10}{}{}",
            self.name,
            crate::util::timer::fmt_duration(self.mean()),
            crate::util::timer::fmt_duration(self.percentile(0.5)),
            crate::util::timer::fmt_duration(self.percentile(0.99)),
            tp,
            flag
        )
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone, Copy)]
pub struct Bencher {
    /// Warmup iterations (not recorded).
    pub warmup_iters: usize,
    /// Recorded iterations.
    pub iters: usize,
    /// Hard cap on total measurement time.
    pub max_time: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup_iters: 3, iters: 20, max_time: Duration::from_secs(20) }
    }
}

impl Bencher {
    /// Quick preset for expensive end-to-end cases.
    pub fn quick() -> Self {
        Bencher { warmup_iters: 1, iters: 5, max_time: Duration::from_secs(30) }
    }

    /// Run a case; `f` is one measured iteration. Use `std::hint::black_box`
    /// inside `f` to keep results alive.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        self.run_with_items(name, None, &mut f)
    }

    /// Run a case with a per-iteration item count for throughput reporting.
    pub fn run_items<F: FnMut()>(&self, name: &str, items: u64, mut f: F) -> BenchResult {
        self.run_with_items(name, Some(items), &mut f)
    }

    fn run_with_items(&self, name: &str, items: Option<u64>, f: &mut dyn FnMut()) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let budget = Stopwatch::start();
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let sw = Stopwatch::start();
            f();
            samples.push(sw.elapsed_ns());
            if budget.elapsed() > self.max_time {
                break;
            }
        }
        BenchResult { name: name.to_string(), samples_ns: samples, items_per_iter: items }
    }
}

/// Print a bench section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_summarizes() {
        let b = Bencher { warmup_iters: 1, iters: 5, max_time: Duration::from_secs(5) };
        let r = b.run("sleep", || std::thread::sleep(Duration::from_millis(2)));
        assert_eq!(r.samples_ns.len(), 5);
        assert!(r.mean() >= Duration::from_millis(1));
        assert!(r.summary().contains("sleep"));
    }

    #[test]
    fn throughput_computed() {
        let b = Bencher { warmup_iters: 0, iters: 3, max_time: Duration::from_secs(5) };
        let r = b.run_items("t", 1000, || std::thread::sleep(Duration::from_millis(1)));
        let tp = r.throughput().unwrap();
        // 1000 items per ~1ms → ~1M items/s, allow wide slack.
        assert!(tp > 100_000.0 && tp < 5_000_000.0, "tp={tp}");
    }

    #[test]
    fn max_time_caps_iterations() {
        let b = Bencher { warmup_iters: 0, iters: 1000, max_time: Duration::from_millis(20) };
        let r = b.run("capped", || std::thread::sleep(Duration::from_millis(5)));
        assert!(r.samples_ns.len() < 1000);
    }

    #[test]
    fn nan_samples_do_not_panic_percentiles() {
        // Regression: `partial_cmp(..).unwrap()` here used to panic on any
        // NaN sample, taking the whole bench run down with it.
        let r = BenchResult {
            name: "nan".into(),
            samples_ns: vec![2e3, f64::NAN, 1e3],
            items_per_iter: None,
        };
        let p50 = r.percentile(0.5);
        assert!(p50 >= Duration::from_nanos(1), "{p50:?}");
        let _ = r.percentile(0.99); // NaN tail: no panic either
        assert!(r.summary().contains("nan"));
    }

    #[test]
    fn percentiles_ordered() {
        let r = BenchResult {
            name: "x".into(),
            samples_ns: vec![1e3, 2e3, 3e3, 4e3, 100e3],
            items_per_iter: None,
        };
        assert!(r.percentile(0.5) <= r.percentile(0.99));
        assert!(r.cv() > 0.5); // outlier-heavy
    }
}
