//! Background recall probe: online order-preservation monitoring.
//!
//! The coordinator samples every N-th completed search per collection and
//! ships a [`ProbeJob`] — the query in both spaces, the ids actually served,
//! and snapshots of the serving and full-dimensional data — to a single
//! probe thread over a bounded channel. The thread shadow-executes the query
//! as a flat exact scan in both spaces and publishes, per collection:
//!
//! - `recall@k` = |F ∩ E^X| / k — how much of the true full-dimensional
//!   neighborhood the served result F retained, and
//! - the paper's order-preserving measure `μ(F)` (Eq. 1)
//!   = |F ∩ E^Y ∩ E^X| / k — how much of it was preserved *through* the
//!   reduced serving space Y,
//!
//! as running-mean gauges ([`registry::PROBE_RECALL`], [`registry::PROBE_MU`])
//! plus a sample counter ([`registry::PROBE_SAMPLES_TOTAL`]). Sampling is
//! deterministic (a per-collection modulo counter, not a coin flip) so tests
//! can replay the exact same shadow set offline. The probe never touches the
//! serving path: jobs are dropped, not blocked on, when the channel is full,
//! and all scans run on the probe thread against `Arc` snapshots.

use super::registry::{self, Registry};
use crate::knn::knn_indices;
use crate::metrics::Metric;
use crate::util::{lock_recover_ranked, ranks};
use std::collections::HashMap;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// One sampled query to shadow-execute, with everything needed to do so off
/// the serving path.
#[derive(Debug, Clone)]
pub struct ProbeJob {
    /// Collection the query ran against (label for the published gauges).
    pub collection: String,
    /// The query in the original full-dimensional space `X`.
    pub query_full: Vec<f32>,
    /// The query projected into the serving space `Y` (identical to
    /// `query_full` when the collection serves unreduced).
    pub query_serving: Vec<f32>,
    /// Requested neighborhood size.
    pub k: usize,
    /// Ids the live index actually returned (the set `F`).
    pub served: Vec<usize>,
    /// Snapshot of the serving-space rows (`m × serving_dim`).
    pub serving: Arc<Vec<f32>>,
    /// Serving-space dimensionality.
    pub serving_dim: usize,
    /// Snapshot of the full-dimensional rows (`m × full_dim`).
    pub full: Arc<Vec<f32>>,
    /// Full-space dimensionality.
    pub full_dim: usize,
    /// Distance metric of the collection.
    pub metric: Metric,
}

/// Per-collection running aggregates.
#[derive(Debug, Default)]
struct ProbeStats {
    recall_sum: f64,
    mu_sum: f64,
    n: u64,
}

/// Handle to the probe thread. Dropping it (or calling
/// [`RecallProbe::shutdown`]) closes the channel; the thread drains every
/// queued job before exiting, so gauges are final once shutdown returns.
#[derive(Debug)]
pub struct RecallProbe {
    tx: Option<SyncSender<ProbeJob>>,
    handle: Option<JoinHandle<()>>,
    every: u64,
    seen: Mutex<HashMap<String, u64>>,
}

impl RecallProbe {
    /// Start the probe thread. `every` selects every N-th query per
    /// collection (1 = probe everything); `capacity` bounds the job queue.
    pub fn start(registry: Arc<Registry>, every: usize, capacity: usize) -> Self {
        let (tx, rx) = sync_channel::<ProbeJob>(capacity.max(1));
        let handle = std::thread::Builder::new()
            .name("opdr-recall-probe".into())
            .spawn(move || probe_loop(rx, &registry))
            .expect("spawn recall probe thread");
        RecallProbe {
            tx: Some(tx),
            handle: Some(handle),
            every: every.max(1) as u64,
            seen: Mutex::new(HashMap::new()),
        }
    }

    /// Deterministic sampler: true for the 1st, (N+1)-th, (2N+1)-th, ...
    /// completed search of each collection.
    pub fn should_sample(&self, collection: &str) -> bool {
        let mut g = lock_recover_ranked(&self.seen, ranks::PROBE_SEEN);
        let c = g.entry(collection.to_string()).or_insert(0);
        let pick = *c % self.every == 0;
        *c += 1;
        pick
    }

    /// Enqueue a job without blocking; returns false (dropping the job) when
    /// the probe is saturated or shut down.
    pub fn submit(&self, job: ProbeJob) -> bool {
        match &self.tx {
            Some(tx) => !matches!(
                tx.try_send(job),
                Err(TrySendError::Full(_) | TrySendError::Disconnected(_))
            ),
            None => false,
        }
    }

    /// Close the channel and wait for every queued job to be evaluated.
    pub fn shutdown(&mut self) {
        self.tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for RecallProbe {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn probe_loop(rx: Receiver<ProbeJob>, registry: &Registry) {
    let mut stats: HashMap<String, ProbeStats> = HashMap::new();
    while let Ok(job) = rx.recv() {
        let Some((recall, mu)) = evaluate(&job) else {
            continue; // malformed snapshot; never panic the probe thread
        };
        let s = stats.entry(job.collection.clone()).or_default();
        s.recall_sum += recall;
        s.mu_sum += mu;
        s.n += 1;
        let labels = [("collection", job.collection.as_str())];
        registry.gauge(registry::PROBE_RECALL, &labels).set(s.recall_sum / s.n as f64);
        registry.gauge(registry::PROBE_MU, &labels).set(s.mu_sum / s.n as f64);
        registry.counter(registry::PROBE_SAMPLES_TOTAL, &labels).inc();
    }
}

/// Shadow-execute one job: exact KNN in both spaces, then
/// `recall@k = |F ∩ E^X| / k` and `μ(F) = |F ∩ E^Y ∩ E^X| / k`.
pub fn evaluate(job: &ProbeJob) -> Option<(f64, f64)> {
    if job.full_dim == 0 || job.serving_dim == 0 {
        return None;
    }
    let m = job.full.len() / job.full_dim;
    let denom = job.k.min(m).max(1) as f64;
    let e_x: std::collections::HashSet<usize> =
        knn_indices(&job.query_full, &job.full, job.full_dim, job.k, job.metric)
            .ok()?
            .into_iter()
            .map(|nb| nb.index)
            .collect();
    let e_y: std::collections::HashSet<usize> =
        knn_indices(&job.query_serving, &job.serving, job.serving_dim, job.k, job.metric)
            .ok()?
            .into_iter()
            .map(|nb| nb.index)
            .collect();
    let hits_x = job.served.iter().filter(|i| e_x.contains(i)).count();
    let hits_xy =
        job.served.iter().filter(|i| e_x.contains(i) && e_y.contains(i)).count();
    Some((hits_x as f64 / denom, hits_xy as f64 / denom))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(
        collection: &str,
        served: Vec<usize>,
        full: Vec<f32>,
        serving: Vec<f32>,
        k: usize,
    ) -> ProbeJob {
        ProbeJob {
            collection: collection.into(),
            query_full: vec![0.0],
            query_serving: vec![0.0],
            k,
            served,
            serving: Arc::new(serving),
            serving_dim: 1,
            full: Arc::new(full),
            full_dim: 1,
            metric: Metric::Euclidean,
        }
    }

    #[test]
    fn evaluate_known_sets() {
        // Full space: rows at 0,1,2,3,4 ⇒ E^X of q=0 with k=2 is {0,1}.
        // Serving space: rows at 0,5,0.5,9,9.5 ⇒ E^Y = {0,2}.
        let full = vec![0.0f32, 1.0, 2.0, 3.0, 4.0];
        let serving = vec![0.0f32, 5.0, 0.5, 9.0, 9.5];
        // Served {0,1}: both in E^X ⇒ recall 1.0; only 0 also in E^Y ⇒ μ 0.5.
        let (recall, mu) = evaluate(&job("c", vec![0, 1], full, serving, 2)).unwrap();
        assert_eq!(recall, 1.0);
        assert_eq!(mu, 0.5);
    }

    #[test]
    fn probe_publishes_running_means_and_drains_on_shutdown() {
        let registry = Arc::new(Registry::new());
        let mut probe = RecallProbe::start(Arc::clone(&registry), 1, 64);
        let full = vec![0.0f32, 1.0, 2.0, 3.0, 4.0];
        // Identity serving space ⇒ E^Y = E^X ⇒ μ == recall.
        assert!(probe.submit(job("c", vec![0, 3], full.clone(), full.clone(), 2))); // recall 0.5
        assert!(probe.submit(job("c", vec![0, 1], full.clone(), full.clone(), 2))); // recall 1.0
        probe.shutdown();
        let labels = [("collection", "c")];
        assert_eq!(registry.counter(registry::PROBE_SAMPLES_TOTAL, &labels).get(), 2);
        let recall = registry.gauge(registry::PROBE_RECALL, &labels).get();
        let mu = registry.gauge(registry::PROBE_MU, &labels).get();
        assert!((recall - 0.75).abs() < 1e-12, "recall={recall}");
        assert!((mu - 0.75).abs() < 1e-12, "mu={mu}");
        // Shut-down probe rejects further jobs instead of panicking.
        assert!(!probe.submit(job("c", vec![0], full.clone(), full, 1)));
    }

    #[test]
    fn sampling_is_every_nth_per_collection() {
        let registry = Arc::new(Registry::new());
        let probe = RecallProbe::start(registry, 3, 8);
        let picks: Vec<bool> = (0..7).map(|_| probe.should_sample("a")).collect();
        assert_eq!(picks, vec![true, false, false, true, false, false, true]);
        // Independent counter per collection.
        assert!(probe.should_sample("b"));
    }
}
