//! Lightweight telemetry: counters and latency histograms.
//!
//! The coordinator records per-request latencies and throughput counters
//! here; the bench harness reads them back for its reports. Thread-safe via
//! atomics + a mutex-guarded histogram (contention is negligible next to the
//! work being measured).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

/// Lock a telemetry mutex, recovering from poisoning instead of cascading:
/// a panicking thread that held the histogram lock must not turn every
/// subsequent stats call on unrelated threads into a panic. Histogram state
/// is monotonic counters and buckets — the worst a poisoned update can leave
/// behind is one partially recorded sample, which is harmless telemetry
/// noise, never corruption worth crashing the serving path for.
fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Monotonic named counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    /// New zeroed counter.
    pub fn new() -> Self {
        Counter::default()
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket log-scale latency histogram (1µs .. ~17min, 5% resolution).
#[derive(Debug)]
pub struct LatencyHistogram {
    inner: Mutex<HistogramInner>,
}

#[derive(Debug)]
struct HistogramInner {
    // bucket i covers [BASE * GROWTH^i, BASE * GROWTH^(i+1))
    buckets: Vec<u64>,
    count: u64,
    sum_ns: u128,
    max_ns: u64,
    min_ns: u64,
}

const BASE_NS: f64 = 1_000.0; // 1µs
const GROWTH: f64 = 1.05;
const NBUCKETS: usize = 420; // 1µs * 1.05^420 ≈ 13 min

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// New empty histogram.
    pub fn new() -> Self {
        LatencyHistogram {
            inner: Mutex::new(HistogramInner {
                buckets: vec![0; NBUCKETS],
                count: 0,
                sum_ns: 0,
                max_ns: 0,
                min_ns: u64::MAX,
            }),
        }
    }

    /// Record one latency sample.
    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos() as u64;
        let idx = if (ns as f64) < BASE_NS {
            0
        } else {
            (((ns as f64 / BASE_NS).ln() / GROWTH.ln()) as usize).min(NBUCKETS - 1)
        };
        let mut g = lock_recover(&self.inner);
        g.buckets[idx] += 1;
        g.count += 1;
        g.sum_ns += ns as u128;
        g.max_ns = g.max_ns.max(ns);
        g.min_ns = g.min_ns.min(ns);
    }

    /// Number of samples.
    pub fn count(&self) -> u64 {
        lock_recover(&self.inner).count
    }

    /// Mean latency.
    pub fn mean(&self) -> Duration {
        let g = lock_recover(&self.inner);
        if g.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos((g.sum_ns / g.count as u128) as u64)
    }

    /// Approximate quantile (bucket upper bound), `q` in [0,1].
    pub fn quantile(&self, q: f64) -> Duration {
        let g = lock_recover(&self.inner);
        if g.count == 0 {
            return Duration::ZERO;
        }
        let target = (q.clamp(0.0, 1.0) * g.count as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &b) in g.buckets.iter().enumerate() {
            acc += b;
            if acc >= target.max(1) {
                let upper = BASE_NS * GROWTH.powi(i as i32 + 1);
                return Duration::from_nanos(upper.min(g.max_ns as f64) as u64);
            }
        }
        Duration::from_nanos(g.max_ns)
    }

    /// Max recorded sample.
    pub fn max(&self) -> Duration {
        Duration::from_nanos(lock_recover(&self.inner).max_ns)
    }

    /// Human summary line.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={} p50={} p99={} max={}",
            self.count(),
            crate::util::timer::fmt_duration(self.mean()),
            crate::util::timer::fmt_duration(self.quantile(0.5)),
            crate::util::timer::fmt_duration(self.quantile(0.99)),
            crate::util::timer::fmt_duration(self.max()),
        )
    }
}

/// Metrics bundle shared by the coordinator.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Requests accepted into the queue.
    pub requests: Counter,
    /// Requests completed.
    pub completed: Counter,
    /// Requests rejected (backpressure).
    pub rejected: Counter,
    /// Batches executed.
    pub batches: Counter,
    /// Total vectors scored.
    pub vectors_scored: Counter,
    /// End-to-end request latency.
    pub latency: LatencyHistogram,
    /// Time spent inside batch execution.
    pub exec_latency: LatencyHistogram,
}

impl Metrics {
    /// New zeroed bundle.
    pub fn new() -> Self {
        Metrics::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_concurrent_increments() {
        let c = std::sync::Arc::new(Counter::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c.inc();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 4000);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        // p50 within 10% of 500µs (bucket resolution is 5%).
        let p50us = p50.as_micros() as f64;
        assert!((p50us - 500.0).abs() < 60.0, "p50={p50us}µs");
        assert!(h.mean() >= Duration::from_micros(400));
        assert!(h.max() >= Duration::from_micros(999));
    }

    #[test]
    fn empty_histogram_safe() {
        let h = LatencyHistogram::new();
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.quantile(0.9), Duration::ZERO);
        assert!(h.summary().contains("n=0"));
    }

    #[test]
    fn tiny_and_huge_samples_clamped() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_nanos(1));
        h.record(Duration::from_secs(3600));
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn poisoned_histogram_lock_recovers_instead_of_cascading() {
        // Regression: one panicking thread holding the histogram lock used
        // to poison the registry and cascade panics into every unrelated
        // stats call afterwards. The recovery path must keep recording.
        let h = std::sync::Arc::new(LatencyHistogram::new());
        h.record(Duration::from_micros(3));
        let h2 = std::sync::Arc::clone(&h);
        let panicked = std::thread::spawn(move || {
            let _guard = h2.inner.lock().unwrap();
            panic!("poison the telemetry lock");
        })
        .join();
        assert!(panicked.is_err(), "the poisoning thread must have panicked");
        // Every accessor keeps working on the poisoned mutex.
        h.record(Duration::from_micros(7));
        assert_eq!(h.count(), 2);
        assert!(h.mean() > Duration::ZERO);
        assert!(h.quantile(0.5) > Duration::ZERO);
        assert!(h.max() >= Duration::from_micros(7));
        assert!(h.summary().contains("n=2"));
    }
}
