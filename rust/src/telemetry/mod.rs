//! Lightweight telemetry: counters, gauges, latency histograms, a labeled
//! [`Registry`] with Prometheus-style exposition, and a live recall probe.
//!
//! The coordinator records per-request latencies and throughput counters
//! here; the bench harness reads them back for its reports. Thread-safe via
//! atomics + a mutex-guarded histogram (contention is negligible next to the
//! work being measured). [`registry`] holds the labeled instrument registry
//! and exposition format, [`probe`] the background recall probe that turns
//! the paper's order-preserving measure μ into a runtime gauge.

pub mod probe;
pub mod recorder;
pub mod registry;

pub use probe::{ProbeJob, RecallProbe};
pub use recorder::{FlightRecorder, QueryRecord, ShardTiming};
pub use registry::{Gauge, Registry};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

// Poisoned-lock recovery: a panicking thread that held the histogram lock
// must not turn every subsequent stats call on unrelated threads into a
// panic. Histogram state is monotonic counters and buckets — the worst a
// poisoned update can leave behind is one partially recorded sample, which
// is harmless telemetry noise, never corruption worth crashing the serving
// path for. The helper itself now lives in `util::sync` so the coordinator
// and pool share one audited implementation (enforced by opdr-lint's
// `no-naked-lock-unwrap` rule).
pub use crate::util::lock_recover;
use crate::util::{lock_recover_ranked, ranks};

/// Monotonic named counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    /// New zeroed counter.
    pub fn new() -> Self {
        Counter::default()
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        // ORDERING: monotonic counter; readers only need an eventually
        // consistent total, nothing is published through this value.
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        // ORDERING: see `add` — a stale read is fine for telemetry.
        self.value.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket log-scale latency histogram (1µs .. ~13min, 5% resolution).
#[derive(Debug)]
pub struct LatencyHistogram {
    inner: Mutex<HistogramInner>,
}

#[derive(Debug)]
struct HistogramInner {
    // bucket i covers [BASE * GROWTH^i, BASE * GROWTH^(i+1))
    buckets: Vec<u64>,
    count: u64,
    sum_ns: u128,
    max_ns: u64,
    min_ns: u64,
}

const BASE_NS: f64 = 1_000.0; // 1µs
const GROWTH: f64 = 1.05;
const NBUCKETS: usize = 420; // 1µs * 1.05^420 ≈ 798s ≈ 13.3 min

/// A consistent copy of a histogram's full state — every bucket plus the
/// exact `count` / `sum_ns` / extrema. Because the buckets travel whole
/// (not as pre-rendered quantiles), two snapshots merge losslessly by
/// bucket-wise addition, which is what makes cluster-level federation of
/// per-worker histograms possible.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistogramSnapshot {
    /// Per-bucket sample counts (length [`LatencyHistogram::bucket_count`]).
    pub buckets: Vec<u64>,
    /// Total samples.
    pub count: u64,
    /// Exact sum of all samples in nanoseconds.
    pub sum_ns: u128,
    /// Largest recorded sample (ns).
    pub max_ns: u64,
    /// Smallest recorded sample (ns; `u64::MAX` when empty).
    pub min_ns: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// New empty histogram.
    pub fn new() -> Self {
        LatencyHistogram {
            inner: Mutex::new(HistogramInner {
                buckets: vec![0; NBUCKETS],
                count: 0,
                sum_ns: 0,
                max_ns: 0,
                min_ns: u64::MAX,
            }),
        }
    }

    /// Upper bound of the top bucket — the longest latency the histogram can
    /// resolve before clamping (samples above it still count, attributed to
    /// the top bucket).
    pub fn max_tracked() -> Duration {
        Duration::from_nanos((BASE_NS * GROWTH.powi(NBUCKETS as i32)) as u64)
    }

    /// Record one latency sample.
    pub fn record(&self, d: Duration) {
        let ns = d.as_nanos() as u64;
        let idx = if (ns as f64) < BASE_NS {
            0
        } else {
            (((ns as f64 / BASE_NS).ln() / GROWTH.ln()) as usize).min(NBUCKETS - 1)
        };
        let mut g = lock_recover_ranked(&self.inner, ranks::TELEMETRY_HISTOGRAM);
        g.buckets[idx] += 1;
        g.count += 1;
        g.sum_ns += ns as u128;
        g.max_ns = g.max_ns.max(ns);
        g.min_ns = g.min_ns.min(ns);
    }

    /// Number of samples.
    pub fn count(&self) -> u64 {
        lock_recover_ranked(&self.inner, ranks::TELEMETRY_HISTOGRAM).count
    }

    /// Mean latency.
    pub fn mean(&self) -> Duration {
        let g = lock_recover_ranked(&self.inner, ranks::TELEMETRY_HISTOGRAM);
        if g.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos((g.sum_ns / g.count as u128) as u64)
    }

    /// Sum of all recorded samples.
    pub fn total(&self) -> Duration {
        let g = lock_recover_ranked(&self.inner, ranks::TELEMETRY_HISTOGRAM);
        Duration::from_nanos(u64::try_from(g.sum_ns).unwrap_or(u64::MAX))
    }

    /// Approximate quantile (bucket upper bound), `q` in [0,1].
    pub fn quantile(&self, q: f64) -> Duration {
        let g = lock_recover_ranked(&self.inner, ranks::TELEMETRY_HISTOGRAM);
        if g.count == 0 {
            return Duration::ZERO;
        }
        let target = (q.clamp(0.0, 1.0) * g.count as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &b) in g.buckets.iter().enumerate() {
            acc += b;
            if acc >= target.max(1) {
                let upper = BASE_NS * GROWTH.powi(i as i32 + 1);
                return Duration::from_nanos(upper.min(g.max_ns as f64) as u64);
            }
        }
        Duration::from_nanos(g.max_ns)
    }

    /// Max recorded sample.
    pub fn max(&self) -> Duration {
        Duration::from_nanos(lock_recover_ranked(&self.inner, ranks::TELEMETRY_HISTOGRAM).max_ns)
    }

    /// Number of buckets a snapshot must carry.
    pub const fn bucket_count() -> usize {
        NBUCKETS
    }

    /// Consistent full-state copy (one lock acquisition).
    pub fn snapshot(&self) -> HistogramSnapshot {
        let g = lock_recover_ranked(&self.inner, ranks::TELEMETRY_HISTOGRAM);
        HistogramSnapshot {
            buckets: g.buckets.clone(),
            count: g.count,
            sum_ns: g.sum_ns,
            max_ns: g.max_ns,
            min_ns: g.min_ns,
        }
    }

    /// Bucket-wise merge of `s` into this histogram: every bucket adds,
    /// `count` / `sum_ns` add exactly, and the extrema fold (an empty
    /// snapshot is a no-op — its `min_ns` sentinel and zero `max_ns` fold
    /// away). Merging N worker snapshots into a fresh histogram yields
    /// exactly the histogram a single process recording all N sample
    /// streams would hold.
    pub fn merge_snapshot(&self, s: &HistogramSnapshot) {
        let mut g = lock_recover_ranked(&self.inner, ranks::TELEMETRY_HISTOGRAM);
        for (b, &sb) in g.buckets.iter_mut().zip(s.buckets.iter()) {
            *b = b.saturating_add(sb);
        }
        g.count = g.count.saturating_add(s.count);
        g.sum_ns = g.sum_ns.saturating_add(s.sum_ns);
        g.max_ns = g.max_ns.max(s.max_ns);
        g.min_ns = g.min_ns.min(s.min_ns);
    }

    /// [`LatencyHistogram::merge_snapshot`] from a live histogram.
    pub fn merge_from(&self, other: &LatencyHistogram) {
        self.merge_snapshot(&other.snapshot());
    }

    /// Human summary line.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={} p50={} p99={} max={}",
            self.count(),
            crate::util::timer::fmt_duration(self.mean()),
            crate::util::timer::fmt_duration(self.quantile(0.5)),
            crate::util::timer::fmt_duration(self.quantile(0.99)),
            crate::util::timer::fmt_duration(self.max()),
        )
    }
}

/// Per-stage histograms threaded through a query's execution path
/// (substrate/ADC scan → rerank → shard/delta merge → delta scan). The
/// fields are `Arc` handles so the trace clones cheaply into the `'static`
/// closures of the shard fan-out; every clone feeds the same histograms.
#[derive(Debug, Clone)]
pub struct SearchTrace {
    /// Substrate scan: flat distance sweep, IVF cell scan, HNSW graph walk,
    /// or the ADC pass of a quantized index.
    pub scan: Arc<LatencyHistogram>,
    /// Full-precision rerank after an ADC pass (quantized indexes only).
    pub rerank: Arc<LatencyHistogram>,
    /// Cross-shard / main+delta top-k merge.
    pub merge: Arc<LatencyHistogram>,
    /// Exhaustive scan of the unmerged delta segment.
    pub delta_scan: Arc<LatencyHistogram>,
}

impl SearchTrace {
    /// A trace whose stage histograms are registered under
    /// [`registry::STAGE_DURATION`] with `stage=` labels.
    pub fn registered(reg: &Registry) -> Self {
        SearchTrace {
            scan: reg.histogram(registry::STAGE_DURATION, &[("stage", "scan")]),
            rerank: reg.histogram(registry::STAGE_DURATION, &[("stage", "rerank")]),
            merge: reg.histogram(registry::STAGE_DURATION, &[("stage", "merge")]),
            delta_scan: reg.histogram(registry::STAGE_DURATION, &[("stage", "delta_scan")]),
        }
    }

    /// A trace backed by free-standing histograms (tests, benches).
    pub fn detached() -> Self {
        SearchTrace {
            scan: Arc::new(LatencyHistogram::new()),
            rerank: Arc::new(LatencyHistogram::new()),
            merge: Arc::new(LatencyHistogram::new()),
            delta_scan: Arc::new(LatencyHistogram::new()),
        }
    }
}

impl Default for SearchTrace {
    fn default() -> Self {
        Self::detached()
    }
}

/// Spans for the background write path (index rebuilds and delta
/// compactions): time spent building the replacement index and time spent
/// swapping it into the serving slot.
#[derive(Debug, Clone)]
pub struct BuildSpans {
    /// Building the replacement index off the serving path.
    pub build: Arc<LatencyHistogram>,
    /// Installing the built index (generation check + delta rebase + swap).
    pub swap: Arc<LatencyHistogram>,
}

impl BuildSpans {
    /// Spans registered under [`registry::STAGE_DURATION`].
    pub fn registered(reg: &Registry) -> Self {
        BuildSpans {
            build: reg.histogram(registry::STAGE_DURATION, &[("stage", "compaction_build")]),
            swap: reg.histogram(registry::STAGE_DURATION, &[("stage", "swap")]),
        }
    }

    /// Spans backed by free-standing histograms (tests).
    pub fn detached() -> Self {
        BuildSpans {
            build: Arc::new(LatencyHistogram::new()),
            swap: Arc::new(LatencyHistogram::new()),
        }
    }
}

/// Metrics bundle shared by the coordinator. Every instrument is an `Arc`
/// handle registered in [`Metrics::registry`], so the legacy `stats` line and
/// the Prometheus exposition are two views over the same storage.
#[derive(Debug)]
pub struct Metrics {
    /// The labeled registry backing every instrument below (plus the
    /// per-verb/per-collection series created on demand).
    pub registry: Arc<Registry>,
    /// Requests accepted into the queue.
    pub requests: Arc<Counter>,
    /// Requests completed.
    pub completed: Arc<Counter>,
    /// Requests rejected (backpressure).
    pub rejected: Arc<Counter>,
    /// Batches executed.
    pub batches: Arc<Counter>,
    /// Total vectors scored.
    pub vectors_scored: Arc<Counter>,
    /// End-to-end request latency (all searches, all collections).
    pub latency: Arc<LatencyHistogram>,
    /// Time spent inside batch execution.
    pub exec_latency: Arc<LatencyHistogram>,
    /// Time a search spent queued before its batch started executing.
    pub queue_wait: Arc<LatencyHistogram>,
    /// Query-path stage histograms (scan/rerank/merge/delta_scan).
    pub trace: SearchTrace,
    /// Appending projected rows to the delta segment.
    pub delta_append: Arc<LatencyHistogram>,
    /// Write-path spans (compaction build + swap).
    pub build_spans: BuildSpans,
}

impl Metrics {
    /// New bundle with every instrument registered in a fresh registry.
    pub fn new() -> Self {
        let registry = Arc::new(Registry::new());
        Metrics {
            requests: registry.counter(registry::REQUESTS_TOTAL, &[]),
            completed: registry.counter(registry::REQUESTS_COMPLETED_TOTAL, &[]),
            rejected: registry.counter(registry::REQUESTS_REJECTED_TOTAL, &[]),
            batches: registry.counter(registry::BATCHES_TOTAL, &[]),
            vectors_scored: registry.counter(registry::VECTORS_SCORED_TOTAL, &[]),
            latency: registry.histogram(registry::REQUEST_DURATION, &[("verb", "search")]),
            exec_latency: registry.histogram(registry::EXEC_DURATION, &[]),
            queue_wait: registry.histogram(registry::STAGE_DURATION, &[("stage", "queue_wait")]),
            trace: SearchTrace::registered(&registry),
            delta_append: registry
                .histogram(registry::STAGE_DURATION, &[("stage", "delta_append")]),
            build_spans: BuildSpans::registered(&registry),
            registry,
        }
    }

    /// Per-`(verb, collection)` request-duration histogram.
    pub fn verb_histogram(&self, verb: &str, collection: &str) -> Arc<LatencyHistogram> {
        self.registry
            .histogram(registry::REQUEST_DURATION, &[("verb", verb), ("collection", collection)])
    }

    /// Per-`(verb, collection)` request counter.
    pub fn verb_counter(&self, verb: &str, collection: &str) -> Arc<Counter> {
        self.registry
            .counter(registry::REQUESTS_TOTAL, &[("verb", verb), ("collection", collection)])
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_concurrent_increments() {
        let c = std::sync::Arc::new(Counter::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c.inc();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 4000);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        // p50 within 10% of 500µs (bucket resolution is 5%).
        let p50us = p50.as_micros() as f64;
        assert!((p50us - 500.0).abs() < 60.0, "p50={p50us}µs");
        assert!(h.mean() >= Duration::from_micros(400));
        assert!(h.max() >= Duration::from_micros(999));
    }

    #[test]
    fn empty_histogram_safe() {
        let h = LatencyHistogram::new();
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.quantile(0.9), Duration::ZERO);
        assert!(h.summary().contains("n=0"));
    }

    #[test]
    fn tiny_and_huge_samples_clamped() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_nanos(1));
        h.record(Duration::from_secs(3600));
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn top_bucket_upper_bound_pinned() {
        // The bucket layout resolves 1µs * 1.05^420 ≈ 798s ≈ 13.3 minutes —
        // this pins the constants against the module docs (a header once
        // claimed "~17min").
        let top = LatencyHistogram::max_tracked();
        assert!(
            top >= Duration::from_secs(12 * 60) && top <= Duration::from_secs(14 * 60),
            "top bucket bound {top:?} not ≈13min"
        );
        // A sample beyond the top bucket is clamped into it, and its
        // quantile is reported capped at the recorded max.
        let h = LatencyHistogram::new();
        h.record(Duration::from_secs(3600));
        assert_eq!(h.count(), 1);
        assert!(h.quantile(1.0) <= Duration::from_secs(3600));
        assert!(h.quantile(1.0) >= Duration::from_secs(12 * 60));
    }

    #[test]
    fn quantile_monotone_over_random_samples() {
        // Property: q1 <= q2 ⇒ quantile(q1) <= quantile(q2), over random
        // sample sets spanning several orders of magnitude.
        let mut rng = crate::util::Rng::new(7);
        for trial in 0..20 {
            let h = LatencyHistogram::new();
            let n = 1 + rng.below(200);
            for _ in 0..n {
                let us = 1 + rng.below(2_000_000);
                h.record(Duration::from_micros(us as u64));
            }
            let grid = [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 0.999, 1.0];
            for w in grid.windows(2) {
                let lo = h.quantile(w[0]);
                let hi = h.quantile(w[1]);
                assert!(lo <= hi, "trial {trial}: q={} -> {lo:?} > q={} -> {hi:?}", w[0], w[1]);
            }
        }
    }

    #[test]
    fn single_sample_all_quantiles_equal() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_millis(5));
        for q in [0.0, 0.5, 1.0] {
            // The bucket upper bound is clamped to the recorded max, so a
            // single-sample histogram reports that sample exactly.
            assert_eq!(h.quantile(q), Duration::from_millis(5), "q={q}");
        }
    }

    #[test]
    fn quantile_boundaries() {
        let h = LatencyHistogram::new();
        for i in 1..=100u64 {
            h.record(Duration::from_micros(i));
        }
        // q=0.0 resolves to the first non-empty bucket; q=1.0 to the max.
        assert!(h.quantile(0.0) <= h.quantile(0.01));
        assert!(h.quantile(0.0) >= Duration::from_nanos(1000));
        assert_eq!(h.quantile(1.0), h.max());
        // Out-of-range q clamps instead of panicking.
        assert_eq!(h.quantile(-3.0), h.quantile(0.0));
        assert_eq!(h.quantile(7.5), h.quantile(1.0));
    }

    #[test]
    fn total_sums_samples() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_micros(300));
        h.record(Duration::from_micros(700));
        assert_eq!(h.total(), Duration::from_micros(1000));
    }

    #[test]
    fn histogram_merge_is_exact_on_count_sum_extrema() {
        let a = LatencyHistogram::new();
        let b = LatencyHistogram::new();
        a.record(Duration::from_micros(100));
        a.record(Duration::from_micros(300));
        b.record(Duration::from_millis(20));
        let m = LatencyHistogram::new();
        m.merge_from(&a);
        m.merge_from(&b);
        assert_eq!(m.count(), 3);
        assert_eq!(m.total(), a.total() + b.total());
        assert_eq!(m.max(), b.max());
        // Merging an empty histogram is a no-op (the min/max sentinels of
        // the empty side must fold away, not poison the extrema).
        let before = m.snapshot();
        m.merge_from(&LatencyHistogram::new());
        assert_eq!(m.snapshot(), before);
    }

    #[test]
    fn prop_histogram_merge_preserves_total_and_bounds_quantiles() {
        // Property (PR 8 satellite): bucket-wise merge preserves `total()`
        // and `count()` exactly, and every quantile of the merge lies
        // between the inputs' min/max quantiles — the lower bound exactly,
        // the upper within one bucket width (GROWTH = 1.05): the merge can
        // lift a component's max-clamp, exposing up to the full bucket
        // upper bound where the component reported its clamped max.
        let mut rng = crate::util::Rng::new(4141);
        for trial in 0..30 {
            let a = LatencyHistogram::new();
            let b = LatencyHistogram::new();
            for _ in 0..rng.below(300) {
                a.record(Duration::from_micros(1 + rng.below(5_000_000) as u64));
            }
            // b is sometimes empty, sometimes on a different scale.
            for _ in 0..rng.below(60) {
                b.record(Duration::from_nanos(100 + rng.below(80_000_000) as u64));
            }
            let m = LatencyHistogram::new();
            m.merge_from(&a);
            m.merge_from(&b);
            assert_eq!(m.count(), a.count() + b.count(), "trial {trial}");
            assert_eq!(m.total(), a.total() + b.total(), "trial {trial}");
            if a.count() == 0 || b.count() == 0 {
                continue; // an empty side contributes quantile 0 — vacuous
            }
            for q in [0.0, 0.1, 0.5, 0.9, 0.99, 1.0] {
                let (qa, qb, qm) =
                    (a.quantile(q).as_nanos(), b.quantile(q).as_nanos(), m.quantile(q).as_nanos());
                let (lo, hi) = (qa.min(qb), qa.max(qb));
                assert!(qm >= lo, "trial {trial} q={q}: merged {qm} < min({qa}, {qb})");
                let hi_tol = (hi as f64 * 1.0501).ceil() as u128;
                assert!(qm <= hi_tol, "trial {trial} q={q}: merged {qm} > max({qa}, {qb})+5%");
            }
        }
    }

    #[test]
    fn poisoned_histogram_lock_recovers_instead_of_cascading() {
        // Regression: one panicking thread holding the histogram lock used
        // to poison the registry and cascade panics into every unrelated
        // stats call afterwards. The recovery path must keep recording.
        let h = std::sync::Arc::new(LatencyHistogram::new());
        h.record(Duration::from_micros(3));
        let h2 = std::sync::Arc::clone(&h);
        let panicked = std::thread::spawn(move || {
            // lint:allow(no-naked-lock-unwrap: deliberately poisoning the lock)
            let _guard = h2.inner.lock().unwrap();
            panic!("poison the telemetry lock");
        })
        .join();
        assert!(panicked.is_err(), "the poisoning thread must have panicked");
        // Every accessor keeps working on the poisoned mutex.
        h.record(Duration::from_micros(7));
        assert_eq!(h.count(), 2);
        assert!(h.mean() > Duration::ZERO);
        assert!(h.quantile(0.5) > Duration::ZERO);
        assert!(h.max() >= Duration::from_micros(7));
        assert!(h.summary().contains("n=2"));
    }

    #[test]
    fn metrics_bundle_is_registered_in_its_registry() {
        // The bundle handles and the registry series are the same storage —
        // the legacy stats line and the exposition can never disagree.
        let m = Metrics::new();
        m.requests.add(5);
        m.batches.inc();
        let via_registry = m.registry.counter(registry::REQUESTS_TOTAL, &[]);
        assert_eq!(via_registry.get(), 5);
        m.latency.record(Duration::from_micros(120));
        let text = m.registry.render();
        assert!(text.contains("opdr_requests_total 5"));
        assert!(text.contains("opdr_batches_total 1"));
        assert!(text.contains("opdr_request_duration_seconds_count{verb=\"search\"} 1"));
        assert!(text.contains("stage=\"queue_wait\""));
        assert!(text.contains("stage=\"scan\""));
    }
}
