//! Labeled metrics registry with Prometheus-style text exposition.
//!
//! Instruments ([`Counter`], [`Gauge`], [`LatencyHistogram`]) are keyed by
//! `(name, sorted label pairs)` and handed out as `Arc` handles: callers on
//! hot paths fetch a handle once and then touch only the lock-free
//! instrument, never the registry map. The registry itself is a
//! mutex-guarded `BTreeMap` so [`Registry::render`] walks families in a
//! stable, deterministic order.
//!
//! Exposition follows the Prometheus text format: counters and gauges as
//! plain samples, histograms as *summaries* — `quantile="0.5" / "0.99" /
//! "0.999"` samples in seconds plus `_sum` / `_count` series — because the
//! log-bucket [`LatencyHistogram`] already answers quantile queries directly
//! and shipping 420 cumulative buckets per series would drown the scrape.
//!
//! Metric-name constants for everything the coordinator publishes live at
//! the bottom of this module; the verb/stage/label conventions are documented
//! in `crate::coordinator`.

use super::{Counter, HistogramSnapshot, LatencyHistogram};
use crate::util::{lock_recover_ranked, ranks};
use crate::error::{OpdrError, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Last-value-wins gauge holding an `f64` (stored as raw bits in an
/// `AtomicU64`; no locking, torn reads impossible).
#[derive(Debug, Default)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    /// New gauge reading `0.0`.
    pub fn new() -> Self {
        Gauge::default()
    }

    /// Set the current value.
    pub fn set(&self, v: f64) {
        // ORDERING: last-value-wins gauge; the store is the whole payload
        // (raw f64 bits), no other memory is published alongside it.
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        // ORDERING: see `set` — a stale gauge read is fine for telemetry.
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// One registered instrument.
#[derive(Debug, Clone)]
enum Instrument {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<LatencyHistogram>),
}

/// Sorted `(key, value)` label pairs; part of the registry key.
type Labels = Vec<(String, String)>;

/// Labeled instrument registry. Cheap to share behind an `Arc`; get-or-create
/// accessors return `Arc` handles that stay valid for the registry's lifetime.
#[derive(Debug, Default)]
pub struct Registry {
    inner: Mutex<BTreeMap<(String, Labels), Instrument>>,
}

impl Registry {
    /// New empty registry.
    pub fn new() -> Self {
        Registry::default()
    }

    fn key(name: &str, labels: &[(&str, &str)]) -> (String, Labels) {
        let mut l: Labels =
            labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        l.sort();
        (name.to_string(), l)
    }

    /// Get or create the counter `name{labels}`.
    ///
    /// If the key is already registered as a different instrument kind the
    /// call returns a fresh *detached* counter (never a panic on the serving
    /// path); mixing kinds under one name is a programming error.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        let mut g = lock_recover_ranked(&self.inner, ranks::TELEMETRY_REGISTRY);
        let e = g
            .entry(Self::key(name, labels))
            .or_insert_with(|| Instrument::Counter(Arc::new(Counter::new())));
        match e {
            Instrument::Counter(c) => Arc::clone(c),
            _ => {
                debug_assert!(false, "metric {name} registered with a different kind");
                Arc::new(Counter::new())
            }
        }
    }

    /// Get or create the gauge `name{labels}` (kind-mismatch behaves like
    /// [`Registry::counter`]).
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        let mut g = lock_recover_ranked(&self.inner, ranks::TELEMETRY_REGISTRY);
        let e = g
            .entry(Self::key(name, labels))
            .or_insert_with(|| Instrument::Gauge(Arc::new(Gauge::new())));
        match e {
            Instrument::Gauge(v) => Arc::clone(v),
            _ => {
                debug_assert!(false, "metric {name} registered with a different kind");
                Arc::new(Gauge::new())
            }
        }
    }

    /// Get or create the latency histogram `name{labels}` (kind-mismatch
    /// behaves like [`Registry::counter`]).
    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Arc<LatencyHistogram> {
        let mut g = lock_recover_ranked(&self.inner, ranks::TELEMETRY_REGISTRY);
        let e = g
            .entry(Self::key(name, labels))
            .or_insert_with(|| Instrument::Histogram(Arc::new(LatencyHistogram::new())));
        match e {
            Instrument::Histogram(h) => Arc::clone(h),
            _ => {
                debug_assert!(false, "metric {name} registered with a different kind");
                Arc::new(LatencyHistogram::new())
            }
        }
    }

    /// Render every registered instrument in the Prometheus text format.
    ///
    /// Families are emitted in lexicographic name order with one `# TYPE`
    /// header each; histograms render as summaries with `quantile="0.5"`,
    /// `"0.99"`, `"0.999"` samples (seconds) plus `_sum` and `_count`.
    pub fn render(&self) -> String {
        // Snapshot the handles, then drop the map lock before touching the
        // (individually locked) histograms.
        let snapshot: Vec<((String, Labels), Instrument)> = {
            let g = lock_recover_ranked(&self.inner, ranks::TELEMETRY_REGISTRY);
            g.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
        };
        let mut out = String::new();
        let mut last_name = String::new();
        for ((name, labels), inst) in snapshot {
            if name != last_name {
                let kind = match inst {
                    Instrument::Counter(_) => "counter",
                    Instrument::Gauge(_) => "gauge",
                    Instrument::Histogram(_) => "summary",
                };
                let _ = writeln!(out, "# TYPE {name} {kind}");
                last_name = name.clone();
            }
            match inst {
                Instrument::Counter(c) => {
                    let _ = writeln!(out, "{}{} {}", name, fmt_labels(&labels, None), c.get());
                }
                Instrument::Gauge(v) => {
                    let _ = writeln!(out, "{}{} {}", name, fmt_labels(&labels, None), v.get());
                }
                Instrument::Histogram(h) => {
                    for q in ["0.5", "0.99", "0.999"] {
                        let qv: f64 = q.parse().unwrap_or(0.5);
                        let _ = writeln!(
                            out,
                            "{}{} {}",
                            name,
                            fmt_labels(&labels, Some(q)),
                            h.quantile(qv).as_secs_f64()
                        );
                    }
                    let _ = writeln!(
                        out,
                        "{}_sum{} {}",
                        name,
                        fmt_labels(&labels, None),
                        h.total().as_secs_f64()
                    );
                    let _ =
                        writeln!(out, "{}_count{} {}", name, fmt_labels(&labels, None), h.count());
                }
            }
        }
        out
    }

    /// Encode every instrument as a **lossless** text snapshot — the wire
    /// format of the `MetricsText` RPC frame.
    ///
    /// Unlike [`Registry::render`], nothing is summarized away: gauges
    /// travel as raw f64 bits, histogram sums as exact nanosecond u128s and
    /// histograms as their full (sparse) bucket vectors, so a snapshot
    /// loaded into a fresh registry renders **bit-for-bit** identically to
    /// the source and snapshots from N workers merge exactly
    /// (bucket-wise / counter-wise addition). Lines are
    /// space-separated tokens with `\` / space / newline escaped inside
    /// names and label strings.
    pub fn encode_snapshot(&self) -> String {
        let snapshot: Vec<((String, Labels), Instrument)> = {
            let g = lock_recover_ranked(&self.inner, ranks::TELEMETRY_REGISTRY);
            g.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
        };
        let mut out = String::from("opdr-metrics-snapshot v1\n");
        for ((name, labels), inst) in snapshot {
            let mut line = String::new();
            let _ = write!(line, "{} {}", snap_esc(&name), labels.len());
            for (k, v) in &labels {
                let _ = write!(line, " {} {}", snap_esc(k), snap_esc(v));
            }
            match inst {
                Instrument::Counter(c) => {
                    let _ = writeln!(out, "c {line} {}", c.get());
                }
                Instrument::Gauge(v) => {
                    let _ = writeln!(out, "g {line} {:016x}", v.get().to_bits());
                }
                Instrument::Histogram(h) => {
                    let s = h.snapshot();
                    let _ = write!(
                        out,
                        "h {line} {} {} {} {}",
                        s.count, s.sum_ns, s.max_ns, s.min_ns
                    );
                    for (i, &b) in s.buckets.iter().enumerate() {
                        if b != 0 {
                            let _ = write!(out, " {i}:{b}");
                        }
                    }
                    out.push('\n');
                }
            }
        }
        out
    }

    /// Merge a [`Registry::encode_snapshot`] text into this registry,
    /// appending `extra` label pairs to every series (the federation path
    /// passes `[("worker", "N")]` for the per-worker view and `[]` for the
    /// aggregated totals). Counters and histogram buckets add; gauges are
    /// last-write-wins. Loading one snapshot into a fresh registry
    /// reproduces the source exactly. Malformed input fails typed without
    /// partially applying the bad line's instrument.
    pub fn load_snapshot(&self, text: &str, extra: &[(&str, &str)]) -> Result<()> {
        let mut lines = text.lines();
        if lines.next().map(str::trim) != Some("opdr-metrics-snapshot v1") {
            return Err(OpdrError::data("metrics snapshot: bad or missing header"));
        }
        let bad = |what: &str, line: &str| {
            OpdrError::data(format!("metrics snapshot: {what} in line `{line}`"))
        };
        for line in lines {
            if line.trim().is_empty() {
                continue;
            }
            let mut t = line.split(' ');
            let kind = t.next().ok_or_else(|| bad("empty line", line))?;
            let name = snap_unesc(t.next().ok_or_else(|| bad("missing name", line))?)
                .ok_or_else(|| bad("bad name escape", line))?;
            let nlabels: usize = t
                .next()
                .and_then(|v| v.parse().ok())
                .ok_or_else(|| bad("bad label count", line))?;
            if nlabels > 64 {
                return Err(bad("label count too large", line));
            }
            let mut labels: Vec<(String, String)> = Vec::with_capacity(nlabels + extra.len());
            for _ in 0..nlabels {
                let k = snap_unesc(t.next().ok_or_else(|| bad("missing label key", line))?)
                    .ok_or_else(|| bad("bad label escape", line))?;
                let v = snap_unesc(t.next().ok_or_else(|| bad("missing label value", line))?)
                    .ok_or_else(|| bad("bad label escape", line))?;
                labels.push((k, v));
            }
            for (k, v) in extra {
                labels.push((k.to_string(), v.to_string()));
            }
            let label_refs: Vec<(&str, &str)> =
                labels.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
            match kind {
                "c" => {
                    let v: u64 = t
                        .next()
                        .and_then(|v| v.parse().ok())
                        .ok_or_else(|| bad("bad counter value", line))?;
                    if t.next().is_some() {
                        return Err(bad("trailing tokens", line));
                    }
                    self.counter(&name, &label_refs).add(v);
                }
                "g" => {
                    let bits = t
                        .next()
                        .and_then(|v| u64::from_str_radix(v, 16).ok())
                        .ok_or_else(|| bad("bad gauge bits", line))?;
                    if t.next().is_some() {
                        return Err(bad("trailing tokens", line));
                    }
                    self.gauge(&name, &label_refs).set(f64::from_bits(bits));
                }
                "h" => {
                    let mut next_u128 = |what| {
                        t.next()
                            .and_then(|v| v.parse::<u128>().ok())
                            .ok_or_else(|| bad(what, line))
                    };
                    let count = next_u128("bad histogram count")?;
                    let sum_ns = next_u128("bad histogram sum")?;
                    let max_ns = next_u128("bad histogram max")?;
                    let min_ns = next_u128("bad histogram min")?;
                    let mut buckets = vec![0u64; LatencyHistogram::bucket_count()];
                    for pair in t.by_ref() {
                        let (i, b) = pair
                            .split_once(':')
                            .and_then(|(i, b)| {
                                Some((i.parse::<usize>().ok()?, b.parse::<u64>().ok()?))
                            })
                            .ok_or_else(|| bad("bad bucket pair", line))?;
                        if i >= buckets.len() {
                            return Err(bad("bucket index out of range", line));
                        }
                        buckets[i] = b;
                    }
                    let snap = HistogramSnapshot {
                        buckets,
                        count: u64::try_from(count)
                            .map_err(|_| bad("histogram count overflow", line))?,
                        sum_ns,
                        max_ns: u64::try_from(max_ns)
                            .map_err(|_| bad("histogram max overflow", line))?,
                        min_ns: u64::try_from(min_ns)
                            .map_err(|_| bad("histogram min overflow", line))?,
                    };
                    self.histogram(&name, &label_refs).merge_snapshot(&snap);
                }
                other => return Err(bad(&format!("unknown instrument kind `{other}`"), line)),
            }
        }
        Ok(())
    }
}

/// Escape a snapshot token: `\` → `\\`, space → `\s`, newline → `\n` (the
/// snapshot grammar is space- and line-delimited).
fn snap_esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace(' ', "\\s").replace('\n', "\\n")
}

/// Inverse of [`snap_esc`]; `None` on a dangling or unknown escape.
fn snap_unesc(s: &str) -> Option<String> {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next()? {
            '\\' => out.push('\\'),
            's' => out.push(' '),
            'n' => out.push('\n'),
            _ => return None,
        }
    }
    Some(out)
}

/// Format a label set as `{k="v",...}`, optionally appending a
/// `quantile="q"` pair; empty label sets render as nothing.
fn fmt_labels(labels: &Labels, quantile: Option<&str>) -> String {
    if labels.is_empty() && quantile.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label(v))).collect();
    if let Some(q) = quantile {
        parts.push(format!("quantile=\"{q}\""));
    }
    format!("{{{}}}", parts.join(","))
}

/// Escape a label value per the Prometheus text format.
fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

// --- Metric names published by the coordinator ------------------------------
// (see `crate::coordinator` module docs for the full table)

/// Requests accepted into the queue (counter; also labeled per verb/collection).
pub const REQUESTS_TOTAL: &str = "opdr_requests_total";
/// Requests completed (counter).
pub const REQUESTS_COMPLETED_TOTAL: &str = "opdr_requests_completed_total";
/// Requests rejected by backpressure (counter).
pub const REQUESTS_REJECTED_TOTAL: &str = "opdr_requests_rejected_total";
/// Batches executed (counter).
pub const BATCHES_TOTAL: &str = "opdr_batches_total";
/// Vectors scored across all searches (counter).
pub const VECTORS_SCORED_TOTAL: &str = "opdr_vectors_scored_total";
/// End-to-end request duration, labeled `{verb, collection}` (summary).
pub const REQUEST_DURATION: &str = "opdr_request_duration_seconds";
/// Time inside batch execution (summary).
pub const EXEC_DURATION: &str = "opdr_exec_duration_seconds";
/// Pipeline stage duration, labeled `{stage}` (summary).
pub const STAGE_DURATION: &str = "opdr_stage_duration_seconds";
/// Live recall@k vs the flat exact scan, labeled `{collection}` (gauge).
pub const PROBE_RECALL: &str = "opdr_probe_recall_at_k";
/// Live order-preserving measure μ (paper Eq. 1), labeled `{collection}` (gauge).
pub const PROBE_MU: &str = "opdr_probe_op_measure_mu";
/// Shadow queries evaluated by the recall probe, labeled `{collection}` (counter).
pub const PROBE_SAMPLES_TOTAL: &str = "opdr_probe_samples_total";
/// Rows currently held per collection (gauge, labeled `{collection}`).
pub const COLLECTION_ROWS: &str = "opdr_collection_rows";
/// Shard count of the serving index (gauge, labeled `{collection}`).
pub const COLLECTION_SHARDS: &str = "opdr_collection_shards";
/// Rows in the unmerged delta segment (gauge, labeled `{collection}`).
pub const COLLECTION_DELTA_ROWS: &str = "opdr_collection_delta_rows";
/// Bytes kept on the cold tier (gauge, labeled `{collection}`).
pub const COLLECTION_COLD_BYTES: &str = "opdr_collection_cold_bytes";
/// Bytes memory-mapped from the cold tier (gauge, labeled `{collection}`).
pub const COLLECTION_MAPPED_BYTES: &str = "opdr_collection_mapped_bytes";
/// Gateway→worker RPC requests sent, labeled `{worker}` (counter).
pub const RPC_REQUESTS_TOTAL: &str = "opdr_rpc_requests_total";
/// RPC transport/protocol failures (non-timeout), labeled `{worker}` (counter).
pub const RPC_ERRORS_TOTAL: &str = "opdr_rpc_errors_total";
/// RPC requests that missed their deadline, labeled `{worker}` (counter).
pub const RPC_DEADLINE_TOTAL: &str = "opdr_rpc_deadline_total";
/// Gateway queries answered degraded (`partial = true`) (counter).
pub const RPC_PARTIAL_TOTAL: &str = "opdr_rpc_partial_results_total";
/// Gateway-side RPC round-trip duration, labeled `{worker}` (summary).
pub const RPC_REQUEST_DURATION: &str = "opdr_rpc_request_duration_seconds";
/// Per-worker liveness as seen by the gateway/supervisor, labeled `{worker}`
/// (gauge; 1 healthy, 0 down).
pub const RPC_WORKER_UP: &str = "opdr_rpc_worker_up";
/// Supervisor respawns of a crashed worker, labeled `{worker}` (counter).
pub const RPC_WORKER_RESTARTS: &str = "opdr_rpc_worker_restarts_total";
/// Per-shard stage timings reported in the v2 `SearchOk` trace tail and
/// recorded gateway-side, labeled `{worker, stage}` with stages
/// `queue_wait` / `scan` / `rerank` / `merge` (summary).
pub const RPC_SHARD_STAGE_DURATION: &str = "opdr_rpc_shard_stage_seconds";
/// Queries a shard worker answered, recorded in the worker's own registry
/// and federated with a `{worker}` label (counter).
pub const WORKER_QUERIES_TOTAL: &str = "opdr_worker_queries_total";
/// Worker-side end-to-end query duration (decode → reply encoded), in the
/// worker's own registry (summary).
pub const WORKER_QUERY_DURATION: &str = "opdr_worker_query_duration_seconds";
/// Metrics-federation scrapes that failed (dead/unreachable worker),
/// labeled `{worker}` (counter).
pub const RPC_SCRAPE_ERRORS_TOTAL: &str = "opdr_rpc_scrape_errors_total";

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn get_or_create_returns_same_instrument() {
        let r = Registry::new();
        let a = r.counter(REQUESTS_TOTAL, &[("verb", "search"), ("collection", "c")]);
        // Label order must not matter.
        let b = r.counter(REQUESTS_TOTAL, &[("collection", "c"), ("verb", "search")]);
        a.inc();
        assert_eq!(b.get(), 1);
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn distinct_labels_are_distinct_series() {
        let r = Registry::new();
        let a = r.counter(REQUESTS_TOTAL, &[("collection", "a")]);
        let b = r.counter(REQUESTS_TOTAL, &[("collection", "b")]);
        a.add(3);
        assert_eq!(b.get(), 0);
    }

    #[test]
    fn gauge_set_get_roundtrips_f64() {
        let g = Gauge::new();
        assert_eq!(g.get(), 0.0);
        g.set(0.8125);
        assert_eq!(g.get(), 0.8125);
        g.set(-1.5);
        assert_eq!(g.get(), -1.5);
    }

    #[test]
    fn render_emits_type_lines_quantiles_sum_count() {
        let r = Registry::new();
        r.counter(REQUESTS_TOTAL, &[]).add(7);
        r.gauge(PROBE_RECALL, &[("collection", "demo")]).set(0.9);
        let h = r.histogram(REQUEST_DURATION, &[("verb", "search"), ("collection", "demo")]);
        for _ in 0..10 {
            h.record(Duration::from_micros(250));
        }
        let text = r.render();
        assert!(text.contains("# TYPE opdr_requests_total counter"));
        assert!(text.contains("opdr_requests_total 7"));
        assert!(text.contains("# TYPE opdr_probe_recall_at_k gauge"));
        assert!(text.contains("opdr_probe_recall_at_k{collection=\"demo\"} 0.9"));
        assert!(text.contains("# TYPE opdr_request_duration_seconds summary"));
        for q in ["0.5", "0.99", "0.999"] {
            assert!(
                text.contains(&format!(
                    "opdr_request_duration_seconds{{collection=\"demo\",verb=\"search\",quantile=\"{q}\"}}"
                )),
                "missing quantile {q} in:\n{text}"
            );
        }
        let lbl = "{collection=\"demo\",verb=\"search\"}";
        assert!(text.contains(&format!("opdr_request_duration_seconds_count{lbl} 10")));
        assert!(text.contains(&format!("opdr_request_duration_seconds_sum{lbl}")));
    }

    #[test]
    fn render_order_is_deterministic() {
        let r = Registry::new();
        r.counter("z_metric", &[]).inc();
        r.counter("a_metric", &[]).inc();
        let text = r.render();
        let a = text.find("a_metric").unwrap();
        let z = text.find("z_metric").unwrap();
        assert!(a < z, "families must render in name order:\n{text}");
        assert_eq!(text, r.render());
    }

    #[test]
    fn label_values_are_escaped() {
        let r = Registry::new();
        r.counter("m", &[("collection", "we\"ird\\name")]).inc();
        let text = r.render();
        assert!(text.contains("m{collection=\"we\\\"ird\\\\name\"} 1"));
    }

    #[test]
    fn label_newlines_do_not_corrupt_the_exposition() {
        // Regression (PR 8 satellite): a label value carrying a newline must
        // render as the two-character escape `\n`, not a raw line break —
        // a raw break would end the sample line mid-value and corrupt the
        // scrape. Backslash must be escaped first (never double-escaped).
        let r = Registry::new();
        r.counter("m", &[("collection", "line1\nline2")]).inc();
        r.gauge("n", &[("path", "a\\nb")]).set(1.0);
        let text = r.render();
        assert!(text.contains("m{collection=\"line1\\nline2\"} 1"), "{text}");
        assert!(text.contains("n{path=\"a\\\\nb\"} 1"), "{text}");
        // Every emitted line is a comment, a `# TYPE` header, or a sample —
        // no line may start inside a label value.
        for line in text.lines() {
            assert!(
                line.starts_with('#') || line.starts_with('m') || line.starts_with('n'),
                "corrupted exposition line: {line:?}\n{text}"
            );
        }
    }

    #[test]
    fn snapshot_roundtrips_bit_for_bit() {
        let src = Registry::new();
        src.counter(REQUESTS_TOTAL, &[("verb", "search"), ("collection", "c c")]).add(17);
        // 0.1 + 0.2 is deliberately a non-terminating f64.
        src.gauge(PROBE_MU, &[("collection", "weird\\ name\nx")]).set(0.1 + 0.2);
        let h = src.histogram(REQUEST_DURATION, &[]);
        for i in 1..=500u64 {
            h.record(Duration::from_micros(i * 7));
        }
        let copy = Registry::new();
        copy.load_snapshot(&src.encode_snapshot(), &[]).expect("load");
        assert_eq!(copy.render(), src.render(), "snapshot must reproduce the render exactly");
        // And the histogram state itself is identical, not just the render.
        assert_eq!(copy.histogram(REQUEST_DURATION, &[]).snapshot(), h.snapshot());
    }

    #[test]
    fn snapshot_load_with_extra_labels_and_merge_sums() {
        // Two "workers" federate into one cluster registry: the worker
        // label separates the per-worker series, the unlabeled pass
        // aggregates counter values and histogram _sum/_count exactly.
        let w0 = Registry::new();
        let w1 = Registry::new();
        w0.counter(WORKER_QUERIES_TOTAL, &[]).add(3);
        w1.counter(WORKER_QUERIES_TOTAL, &[]).add(5);
        w0.histogram(WORKER_QUERY_DURATION, &[]).record(Duration::from_micros(100));
        w1.histogram(WORKER_QUERY_DURATION, &[]).record(Duration::from_micros(300));
        let cluster = Registry::new();
        for (i, w) in [&w0, &w1].into_iter().enumerate() {
            let snap = w.encode_snapshot();
            cluster.load_snapshot(&snap, &[("worker", &i.to_string())]).expect("labeled");
            cluster.load_snapshot(&snap, &[]).expect("aggregate");
        }
        assert_eq!(cluster.counter(WORKER_QUERIES_TOTAL, &[("worker", "0")]).get(), 3);
        assert_eq!(cluster.counter(WORKER_QUERIES_TOTAL, &[("worker", "1")]).get(), 5);
        assert_eq!(cluster.counter(WORKER_QUERIES_TOTAL, &[]).get(), 8);
        let agg = cluster.histogram(WORKER_QUERY_DURATION, &[]);
        assert_eq!(agg.count(), 2);
        assert_eq!(agg.total(), Duration::from_micros(400));
        let text = cluster.render();
        assert!(text.contains("opdr_worker_queries_total{worker=\"0\"} 3"), "{text}");
        assert!(text.contains("opdr_worker_queries_total{worker=\"1\"} 5"), "{text}");
        assert!(text.contains("opdr_worker_queries_total 8"), "{text}");
    }

    #[test]
    fn snapshot_rejects_malformed_input_typed() {
        let r = Registry::new();
        assert!(r.load_snapshot("not a snapshot", &[]).is_err());
        let hdr = "opdr-metrics-snapshot v1\n";
        for bad in [
            "c only_two_tokens\n",
            "c m 0 notanumber\n",
            "g m 0 zzzz\n",
            "h m 0 1 100 100 100 99999:1\n", // bucket index out of range
            "x m 0 1\n",                     // unknown kind
            "c m 1 key\n",                   // missing label value
            "c m 0 1 extra\n",               // trailing tokens
        ] {
            let text = format!("{hdr}{bad}");
            assert!(r.load_snapshot(&text, &[]).is_err(), "accepted malformed: {bad:?}");
        }
        // Nothing of the failed loads leaked into the registry.
        assert_eq!(r.render(), "");
    }
}
