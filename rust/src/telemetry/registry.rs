//! Labeled metrics registry with Prometheus-style text exposition.
//!
//! Instruments ([`Counter`], [`Gauge`], [`LatencyHistogram`]) are keyed by
//! `(name, sorted label pairs)` and handed out as `Arc` handles: callers on
//! hot paths fetch a handle once and then touch only the lock-free
//! instrument, never the registry map. The registry itself is a
//! mutex-guarded `BTreeMap` so [`Registry::render`] walks families in a
//! stable, deterministic order.
//!
//! Exposition follows the Prometheus text format: counters and gauges as
//! plain samples, histograms as *summaries* — `quantile="0.5" / "0.99" /
//! "0.999"` samples in seconds plus `_sum` / `_count` series — because the
//! log-bucket [`LatencyHistogram`] already answers quantile queries directly
//! and shipping 420 cumulative buckets per series would drown the scrape.
//!
//! Metric-name constants for everything the coordinator publishes live at
//! the bottom of this module; the verb/stage/label conventions are documented
//! in `crate::coordinator`.

use super::{lock_recover, Counter, LatencyHistogram};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Last-value-wins gauge holding an `f64` (stored as raw bits in an
/// `AtomicU64`; no locking, torn reads impossible).
#[derive(Debug, Default)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    /// New gauge reading `0.0`.
    pub fn new() -> Self {
        Gauge::default()
    }

    /// Set the current value.
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// One registered instrument.
#[derive(Debug, Clone)]
enum Instrument {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<LatencyHistogram>),
}

/// Sorted `(key, value)` label pairs; part of the registry key.
type Labels = Vec<(String, String)>;

/// Labeled instrument registry. Cheap to share behind an `Arc`; get-or-create
/// accessors return `Arc` handles that stay valid for the registry's lifetime.
#[derive(Debug, Default)]
pub struct Registry {
    inner: Mutex<BTreeMap<(String, Labels), Instrument>>,
}

impl Registry {
    /// New empty registry.
    pub fn new() -> Self {
        Registry::default()
    }

    fn key(name: &str, labels: &[(&str, &str)]) -> (String, Labels) {
        let mut l: Labels =
            labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        l.sort();
        (name.to_string(), l)
    }

    /// Get or create the counter `name{labels}`.
    ///
    /// If the key is already registered as a different instrument kind the
    /// call returns a fresh *detached* counter (never a panic on the serving
    /// path); mixing kinds under one name is a programming error.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        let mut g = lock_recover(&self.inner);
        let e = g
            .entry(Self::key(name, labels))
            .or_insert_with(|| Instrument::Counter(Arc::new(Counter::new())));
        match e {
            Instrument::Counter(c) => Arc::clone(c),
            _ => {
                debug_assert!(false, "metric {name} registered with a different kind");
                Arc::new(Counter::new())
            }
        }
    }

    /// Get or create the gauge `name{labels}` (kind-mismatch behaves like
    /// [`Registry::counter`]).
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        let mut g = lock_recover(&self.inner);
        let e = g
            .entry(Self::key(name, labels))
            .or_insert_with(|| Instrument::Gauge(Arc::new(Gauge::new())));
        match e {
            Instrument::Gauge(v) => Arc::clone(v),
            _ => {
                debug_assert!(false, "metric {name} registered with a different kind");
                Arc::new(Gauge::new())
            }
        }
    }

    /// Get or create the latency histogram `name{labels}` (kind-mismatch
    /// behaves like [`Registry::counter`]).
    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Arc<LatencyHistogram> {
        let mut g = lock_recover(&self.inner);
        let e = g
            .entry(Self::key(name, labels))
            .or_insert_with(|| Instrument::Histogram(Arc::new(LatencyHistogram::new())));
        match e {
            Instrument::Histogram(h) => Arc::clone(h),
            _ => {
                debug_assert!(false, "metric {name} registered with a different kind");
                Arc::new(LatencyHistogram::new())
            }
        }
    }

    /// Render every registered instrument in the Prometheus text format.
    ///
    /// Families are emitted in lexicographic name order with one `# TYPE`
    /// header each; histograms render as summaries with `quantile="0.5"`,
    /// `"0.99"`, `"0.999"` samples (seconds) plus `_sum` and `_count`.
    pub fn render(&self) -> String {
        // Snapshot the handles, then drop the map lock before touching the
        // (individually locked) histograms.
        let snapshot: Vec<((String, Labels), Instrument)> = {
            let g = lock_recover(&self.inner);
            g.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
        };
        let mut out = String::new();
        let mut last_name = String::new();
        for ((name, labels), inst) in snapshot {
            if name != last_name {
                let kind = match inst {
                    Instrument::Counter(_) => "counter",
                    Instrument::Gauge(_) => "gauge",
                    Instrument::Histogram(_) => "summary",
                };
                let _ = writeln!(out, "# TYPE {name} {kind}");
                last_name = name.clone();
            }
            match inst {
                Instrument::Counter(c) => {
                    let _ = writeln!(out, "{}{} {}", name, fmt_labels(&labels, None), c.get());
                }
                Instrument::Gauge(v) => {
                    let _ = writeln!(out, "{}{} {}", name, fmt_labels(&labels, None), v.get());
                }
                Instrument::Histogram(h) => {
                    for q in ["0.5", "0.99", "0.999"] {
                        let qv: f64 = q.parse().unwrap_or(0.5);
                        let _ = writeln!(
                            out,
                            "{}{} {}",
                            name,
                            fmt_labels(&labels, Some(q)),
                            h.quantile(qv).as_secs_f64()
                        );
                    }
                    let _ = writeln!(
                        out,
                        "{}_sum{} {}",
                        name,
                        fmt_labels(&labels, None),
                        h.total().as_secs_f64()
                    );
                    let _ =
                        writeln!(out, "{}_count{} {}", name, fmt_labels(&labels, None), h.count());
                }
            }
        }
        out
    }
}

/// Format a label set as `{k="v",...}`, optionally appending a
/// `quantile="q"` pair; empty label sets render as nothing.
fn fmt_labels(labels: &Labels, quantile: Option<&str>) -> String {
    if labels.is_empty() && quantile.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label(v))).collect();
    if let Some(q) = quantile {
        parts.push(format!("quantile=\"{q}\""));
    }
    format!("{{{}}}", parts.join(","))
}

/// Escape a label value per the Prometheus text format.
fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

// --- Metric names published by the coordinator ------------------------------
// (see `crate::coordinator` module docs for the full table)

/// Requests accepted into the queue (counter; also labeled per verb/collection).
pub const REQUESTS_TOTAL: &str = "opdr_requests_total";
/// Requests completed (counter).
pub const REQUESTS_COMPLETED_TOTAL: &str = "opdr_requests_completed_total";
/// Requests rejected by backpressure (counter).
pub const REQUESTS_REJECTED_TOTAL: &str = "opdr_requests_rejected_total";
/// Batches executed (counter).
pub const BATCHES_TOTAL: &str = "opdr_batches_total";
/// Vectors scored across all searches (counter).
pub const VECTORS_SCORED_TOTAL: &str = "opdr_vectors_scored_total";
/// End-to-end request duration, labeled `{verb, collection}` (summary).
pub const REQUEST_DURATION: &str = "opdr_request_duration_seconds";
/// Time inside batch execution (summary).
pub const EXEC_DURATION: &str = "opdr_exec_duration_seconds";
/// Pipeline stage duration, labeled `{stage}` (summary).
pub const STAGE_DURATION: &str = "opdr_stage_duration_seconds";
/// Live recall@k vs the flat exact scan, labeled `{collection}` (gauge).
pub const PROBE_RECALL: &str = "opdr_probe_recall_at_k";
/// Live order-preserving measure μ (paper Eq. 1), labeled `{collection}` (gauge).
pub const PROBE_MU: &str = "opdr_probe_op_measure_mu";
/// Shadow queries evaluated by the recall probe, labeled `{collection}` (counter).
pub const PROBE_SAMPLES_TOTAL: &str = "opdr_probe_samples_total";
/// Rows currently held per collection (gauge, labeled `{collection}`).
pub const COLLECTION_ROWS: &str = "opdr_collection_rows";
/// Shard count of the serving index (gauge, labeled `{collection}`).
pub const COLLECTION_SHARDS: &str = "opdr_collection_shards";
/// Rows in the unmerged delta segment (gauge, labeled `{collection}`).
pub const COLLECTION_DELTA_ROWS: &str = "opdr_collection_delta_rows";
/// Bytes kept on the cold tier (gauge, labeled `{collection}`).
pub const COLLECTION_COLD_BYTES: &str = "opdr_collection_cold_bytes";
/// Bytes memory-mapped from the cold tier (gauge, labeled `{collection}`).
pub const COLLECTION_MAPPED_BYTES: &str = "opdr_collection_mapped_bytes";
/// Gateway→worker RPC requests sent, labeled `{worker}` (counter).
pub const RPC_REQUESTS_TOTAL: &str = "opdr_rpc_requests_total";
/// RPC transport/protocol failures (non-timeout), labeled `{worker}` (counter).
pub const RPC_ERRORS_TOTAL: &str = "opdr_rpc_errors_total";
/// RPC requests that missed their deadline, labeled `{worker}` (counter).
pub const RPC_DEADLINE_TOTAL: &str = "opdr_rpc_deadline_total";
/// Gateway queries answered degraded (`partial = true`) (counter).
pub const RPC_PARTIAL_TOTAL: &str = "opdr_rpc_partial_results_total";
/// Gateway-side RPC round-trip duration, labeled `{worker}` (summary).
pub const RPC_REQUEST_DURATION: &str = "opdr_rpc_request_duration_seconds";
/// Per-worker liveness as seen by the gateway/supervisor, labeled `{worker}`
/// (gauge; 1 healthy, 0 down).
pub const RPC_WORKER_UP: &str = "opdr_rpc_worker_up";
/// Supervisor respawns of a crashed worker, labeled `{worker}` (counter).
pub const RPC_WORKER_RESTARTS: &str = "opdr_rpc_worker_restarts_total";

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn get_or_create_returns_same_instrument() {
        let r = Registry::new();
        let a = r.counter(REQUESTS_TOTAL, &[("verb", "search"), ("collection", "c")]);
        // Label order must not matter.
        let b = r.counter(REQUESTS_TOTAL, &[("collection", "c"), ("verb", "search")]);
        a.inc();
        assert_eq!(b.get(), 1);
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn distinct_labels_are_distinct_series() {
        let r = Registry::new();
        let a = r.counter(REQUESTS_TOTAL, &[("collection", "a")]);
        let b = r.counter(REQUESTS_TOTAL, &[("collection", "b")]);
        a.add(3);
        assert_eq!(b.get(), 0);
    }

    #[test]
    fn gauge_set_get_roundtrips_f64() {
        let g = Gauge::new();
        assert_eq!(g.get(), 0.0);
        g.set(0.8125);
        assert_eq!(g.get(), 0.8125);
        g.set(-1.5);
        assert_eq!(g.get(), -1.5);
    }

    #[test]
    fn render_emits_type_lines_quantiles_sum_count() {
        let r = Registry::new();
        r.counter(REQUESTS_TOTAL, &[]).add(7);
        r.gauge(PROBE_RECALL, &[("collection", "demo")]).set(0.9);
        let h = r.histogram(REQUEST_DURATION, &[("verb", "search"), ("collection", "demo")]);
        for _ in 0..10 {
            h.record(Duration::from_micros(250));
        }
        let text = r.render();
        assert!(text.contains("# TYPE opdr_requests_total counter"));
        assert!(text.contains("opdr_requests_total 7"));
        assert!(text.contains("# TYPE opdr_probe_recall_at_k gauge"));
        assert!(text.contains("opdr_probe_recall_at_k{collection=\"demo\"} 0.9"));
        assert!(text.contains("# TYPE opdr_request_duration_seconds summary"));
        for q in ["0.5", "0.99", "0.999"] {
            assert!(
                text.contains(&format!(
                    "opdr_request_duration_seconds{{collection=\"demo\",verb=\"search\",quantile=\"{q}\"}}"
                )),
                "missing quantile {q} in:\n{text}"
            );
        }
        let lbl = "{collection=\"demo\",verb=\"search\"}";
        assert!(text.contains(&format!("opdr_request_duration_seconds_count{lbl} 10")));
        assert!(text.contains(&format!("opdr_request_duration_seconds_sum{lbl}")));
    }

    #[test]
    fn render_order_is_deterministic() {
        let r = Registry::new();
        r.counter("z_metric", &[]).inc();
        r.counter("a_metric", &[]).inc();
        let text = r.render();
        let a = text.find("a_metric").unwrap();
        let z = text.find("z_metric").unwrap();
        assert!(a < z, "families must render in name order:\n{text}");
        assert_eq!(text, r.render());
    }

    #[test]
    fn label_values_are_escaped() {
        let r = Registry::new();
        r.counter("m", &[("collection", "we\"ird\\name")]).inc();
        let text = r.render();
        assert!(text.contains("m{collection=\"we\\\"ird\\\\name\"} 1"));
    }
}
