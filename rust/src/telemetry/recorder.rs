//! The slow-query flight recorder: a bounded ring of the last K complete
//! per-query span timelines, pinned preferentially for the queries worth
//! keeping (slow or `partial = true`).
//!
//! Every distributed query leaves one [`QueryRecord`]: its trace id, the
//! chosen `k`, per-shard RPC timing (gateway-observed round trip plus the
//! worker-reported queue/scan/rerank/merge stage splits from the v2 trace
//! tail), each shard's fault disposition, and a checksum of the merged
//! result. When a deadline miss or a fault-injected partial answer needs a
//! forensic artifact, the `SlowQueries` admin verb dumps the ring as
//! structured text — no re-run, no log spelunking.
//!
//! The ring is lock-cheap by construction: recording is one short
//! mutex-guarded `VecDeque` push (no allocation beyond the record itself,
//! no I/O), negligible next to the RPC round trip it describes. Eviction
//! prefers the oldest *unpinned* entry, so a burst of healthy traffic
//! cannot flush the one partial query that needs investigating; only when
//! every entry is pinned does the oldest pinned entry fall out.

use crate::util::timer::fmt_duration;
use crate::util::{lock_recover_ranked, ranks};
use std::collections::VecDeque;
use std::fmt::Write as _;
use std::sync::Mutex;
use std::time::Duration;

/// One shard's leg of a distributed query.
#[derive(Debug, Clone)]
pub struct ShardTiming {
    /// Worker name (the `worker` metric label).
    pub worker: String,
    /// True when the shard contributed to the merge.
    pub ok: bool,
    /// Typed failure reason when `ok` is false (deadline, transport,
    /// protocol — the fault disposition).
    pub error: Option<String>,
    /// Gateway-observed round trip for this leg.
    pub rtt: Duration,
    /// Worker-reported stage splits from the v2 trace tail, when present:
    /// `(queue_wait, scan, rerank, merge)`.
    pub stages: Option<(Duration, Duration, Duration, Duration)>,
}

/// One complete query timeline.
#[derive(Debug, Clone)]
pub struct QueryRecord {
    /// Gateway-assigned trace id (carried on the wire to every shard).
    pub trace_id: u64,
    /// Neighbors requested.
    pub k: usize,
    /// True when at least one shard contributed nothing.
    pub partial: bool,
    /// End-to-end gateway time (scatter through merge).
    pub total: Duration,
    /// CRC-32 over the merged `(id, distance-bits)` list — lets two runs
    /// of the same query be compared without storing the neighbors.
    pub result_checksum: u32,
    /// Per-shard legs, in slot order.
    pub shards: Vec<ShardTiming>,
}

#[derive(Debug)]
struct Entry {
    rec: QueryRecord,
    pinned: bool,
}

#[derive(Debug, Default)]
struct RingState {
    ring: VecDeque<Entry>,
    recorded: u64,
    evicted_pinned: u64,
}

/// Bounded ring of [`QueryRecord`]s with pinned-preferential eviction.
#[derive(Debug)]
pub struct FlightRecorder {
    state: Mutex<RingState>,
    capacity: usize,
    slow_threshold: Duration,
}

impl FlightRecorder {
    /// Ring holding at most `capacity` records; a query is pinned when it
    /// is `partial` or its end-to-end time reaches `slow_threshold`.
    pub fn new(capacity: usize, slow_threshold: Duration) -> FlightRecorder {
        FlightRecorder {
            state: Mutex::new(RingState::default()),
            capacity: capacity.max(1),
            slow_threshold,
        }
    }

    /// Ring capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Record one completed query.
    pub fn record(&self, rec: QueryRecord) {
        let pinned = rec.partial || rec.total >= self.slow_threshold;
        let mut g = lock_recover_ranked(&self.state, ranks::RECORDER_RING);
        g.recorded += 1;
        if g.ring.len() >= self.capacity {
            // Oldest unpinned first; only an all-pinned ring evicts a
            // pinned entry (the oldest), so healthy traffic can never
            // flush a degraded query's timeline.
            match g.ring.iter().position(|e| !e.pinned) {
                Some(i) => {
                    g.ring.remove(i);
                }
                None => {
                    g.ring.pop_front();
                    g.evicted_pinned += 1;
                }
            }
        }
        g.ring.push_back(Entry { rec, pinned });
    }

    /// Records currently held (oldest first).
    pub fn entries(&self) -> Vec<QueryRecord> {
        lock_recover_ranked(&self.state, ranks::RECORDER_RING).ring.iter().map(|e| e.rec.clone()).collect()
    }

    /// The held record with this trace id, if any.
    pub fn find(&self, trace_id: u64) -> Option<QueryRecord> {
        lock_recover_ranked(&self.state, ranks::RECORDER_RING)
            .ring
            .iter()
            .rev()
            .find(|e| e.rec.trace_id == trace_id)
            .map(|e| e.rec.clone())
    }

    /// Queries recorded over the recorder's lifetime (not just those still
    /// held).
    pub fn recorded_total(&self) -> u64 {
        lock_recover_ranked(&self.state, ranks::RECORDER_RING).recorded
    }

    /// Structured text dump — the `SlowQueries` admin verb's payload.
    /// Pinned (slow/partial) entries print first, then the healthy tail,
    /// each newest-first within its group.
    pub fn dump(&self) -> String {
        let g = lock_recover_ranked(&self.state, ranks::RECORDER_RING);
        let pinned_count = g.ring.iter().filter(|e| e.pinned).count();
        let mut out = format!(
            "flight-recorder: {} of {} entries held ({} pinned, {} recorded, {} pinned evicted); slow threshold {}\n",
            g.ring.len(),
            self.capacity,
            pinned_count,
            g.recorded,
            g.evicted_pinned,
            fmt_duration(self.slow_threshold),
        );
        for want_pinned in [true, false] {
            for e in g.ring.iter().rev().filter(|e| e.pinned == want_pinned) {
                let r = &e.rec;
                let disposition = if r.partial { "PARTIAL" } else { "ok" };
                let _ = writeln!(
                    out,
                    "trace={:#018x} k={} {} total={} shards_ok={}/{} checksum={:#010x}{}",
                    r.trace_id,
                    r.k,
                    disposition,
                    fmt_duration(r.total),
                    r.shards.iter().filter(|s| s.ok).count(),
                    r.shards.len(),
                    r.result_checksum,
                    if e.pinned { " [pinned]" } else { "" },
                );
                for s in &r.shards {
                    let _ = write!(
                        out,
                        "  shard worker={} {} rtt={}",
                        s.worker,
                        if s.ok { "ok" } else { "FAIL" },
                        fmt_duration(s.rtt),
                    );
                    if let Some((queue, scan, rerank, merge)) = s.stages {
                        let _ = write!(
                            out,
                            " queue_wait={} scan={} rerank={} merge={}",
                            fmt_duration(queue),
                            fmt_duration(scan),
                            fmt_duration(rerank),
                            fmt_duration(merge),
                        );
                    }
                    if let Some(err) = &s.error {
                        let _ = write!(out, " — {err}");
                    }
                    out.push('\n');
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(trace_id: u64, partial: bool, total_ms: u64) -> QueryRecord {
        QueryRecord {
            trace_id,
            k: 5,
            partial,
            total: Duration::from_millis(total_ms),
            result_checksum: 0xABCD,
            shards: vec![ShardTiming {
                worker: "0".into(),
                ok: !partial,
                error: partial.then(|| "rpc: request deadline exceeded".to_string()),
                rtt: Duration::from_millis(total_ms),
                stages: (!partial).then_some((
                    Duration::from_micros(2),
                    Duration::from_micros(40),
                    Duration::ZERO,
                    Duration::from_micros(1),
                )),
            }],
        }
    }

    #[test]
    fn ring_is_bounded_and_prefers_evicting_unpinned() {
        let fr = FlightRecorder::new(4, Duration::from_millis(100));
        fr.record(rec(1, true, 5)); // pinned: partial
        fr.record(rec(2, false, 1));
        fr.record(rec(3, false, 200)); // pinned: slow
        fr.record(rec(4, false, 1));
        fr.record(rec(5, false, 1)); // evicts 2 (oldest unpinned), not 1
        let held: Vec<u64> = fr.entries().iter().map(|r| r.trace_id).collect();
        assert_eq!(held, vec![1, 3, 4, 5]);
        fr.record(rec(6, true, 5)); // evicts 4
        fr.record(rec(7, true, 5)); // evicts 5
        let held: Vec<u64> = fr.entries().iter().map(|r| r.trace_id).collect();
        assert_eq!(held, vec![1, 3, 6, 7], "pinned entries must survive healthy churn");
        // All pinned now: the oldest pinned entry finally falls out.
        fr.record(rec(8, true, 5));
        let held: Vec<u64> = fr.entries().iter().map(|r| r.trace_id).collect();
        assert_eq!(held, vec![3, 6, 7, 8]);
        assert_eq!(fr.recorded_total(), 8);
    }

    #[test]
    fn find_returns_the_newest_match() {
        let fr = FlightRecorder::new(8, Duration::from_secs(1));
        fr.record(rec(9, false, 1));
        fr.record(rec(9, true, 2));
        assert!(fr.find(9).expect("held").partial, "newest record must win");
        assert!(fr.find(404).is_none());
    }

    #[test]
    fn dump_names_the_faulted_shard_and_pins_first() {
        let fr = FlightRecorder::new(8, Duration::from_millis(100));
        fr.record(rec(0x10, false, 1));
        fr.record(rec(0x42, true, 7));
        let dump = fr.dump();
        assert!(dump.contains("trace=0x0000000000000042 k=5 PARTIAL"), "{dump}");
        assert!(dump.contains("[pinned]"), "{dump}");
        assert!(dump.contains("shard worker=0 FAIL"), "{dump}");
        assert!(dump.contains("deadline exceeded"), "{dump}");
        assert!(dump.contains("queue_wait="), "{dump}");
        let partial_at = dump.find("0x0000000000000042").expect("partial entry");
        let healthy_at = dump.find("0x0000000000000010").expect("healthy entry");
        assert!(partial_at < healthy_at, "pinned entries must print first:\n{dump}");
    }
}
