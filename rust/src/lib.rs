//! # OPDR — Order-Preserving Dimension Reduction for Multimodal Semantic Embedding
//!
//! Reproduction of the AAAI 2026 paper (Gong, Shen, Guo, Tallent, Zhao).
//!
//! This crate is the Layer-3 coordinator of a three-layer Rust + JAX + Pallas
//! stack. It owns:
//!
//! * the **OPDR math** — the order-preserving measure `μ` (Eq. 1), the global
//!   accuracy `A_k` (Eq. 2), the closed-form fit `A_k = c0·log(n/m) + c1`
//!   (Eq. 4) and the dimensionality planner that inverts it ([`opdr`]);
//! * the **dimension-reduction substrates** — PCA (covariance and Gram-trick
//!   paths), classical MDS, SMACOF MDS, Gaussian random projection
//!   ([`reduction`]);
//! * the **retrieval substrates** — distance metrics, exact KNN, top-k
//!   selection, an IVF-Flat ANN index ([`metrics`], [`knn`]);
//! * the **ANN index subsystem** — a pluggable [`index::AnnIndex`] layer with
//!   exact, IVF-Flat and deterministic HNSW substrates (HNSW with Malkov
//!   Algorithm 4 heuristic neighbor selection by default), composable
//!   vector storage (flat f32, SQ8 scalar quantization at ~4×, and PQ/OPQ
//!   product quantization at ~16× with ADC lookup-table scans plus an
//!   order-exact full-precision rerank stage — at exhaustive `rerank_depth`
//!   the compressed top-k is bit-identical to the exact index), and index
//!   persistence through the versioned `OPDR` binary format; the
//!   coordinator picks a substrate per collection via a config-driven
//!   [`config::IndexPolicy`] ([`index`], [`index::pq`]);
//! * **segment sharding** — collections split into `S` index segments
//!   ([`index::shard`]): whole-segment builds fan out across the worker pool
//!   behind an atomic index swap (serving never blocks on a rebuild),
//!   queries fan out per shard and merge through the bounded top-k heap with
//!   a machine-checked order-exactness guarantee, and sharded indexes
//!   persist as version-3 multi-segment `OPDR` files;
//! * **incremental ingest** — appended rows land in a flat exact delta
//!   segment behind the immutable main index ([`index::delta`]) instead of
//!   invalidating it, searches merge `{main, delta}` order-exactly, and a
//!   background compaction folds the delta into a rebuilt main index behind
//!   a rebase-aware generation-guarded swap (an ingest racing a compaction
//!   lands in the new delta, never lost); delta-augmented indexes persist
//!   as version-4 `OPDR` files;
//! * the **mmap-backed cold tier** — full-precision rows (PQ rerank tiers,
//!   flat payloads) optionally leave RAM entirely ([`data::mapped`]):
//!   spilled to 64-byte-aligned on-disk vector files and served zero-copy
//!   through a validated read-only mapping (heap fallback where mmap is
//!   unavailable), so collections larger than memory serve from one box;
//!   cold indexes persist as version-5 `OPDR` files whose annex maps in
//!   place on load, and the tier is bit-identical to RAM serving
//!   (machine-checked);
//! * the **multimodal data substrates** — synthetic generators standing in for
//!   the paper's seven datasets, plus an embedding store ([`data`]);
//! * the **runtime** — a PJRT engine that loads AOT-compiled HLO artifacts
//!   produced by the build-time JAX/Pallas layer ([`runtime`], [`embed`]);
//! * the **serving coordinator** — worker pool, dynamic batcher, router and
//!   collection state for online multimodal KNN queries ([`coordinator`]);
//! * **distributed serving** — a length-prefixed binary RPC with a versioned
//!   handshake, per-message CRC and read/write deadlines ([`rpc`]), and a
//!   scatter-gather [`dist::Gateway`] over supervised shard-worker processes
//!   that merges per-shard top-k lists order-exactly and degrades to typed
//!   `partial = true` results when a shard is unreachable ([`dist`]); the
//!   guarantees are machine-checked under a deterministic fault-injection
//!   proxy (`tests/dist_it.rs`).
//!
//! Python never runs on the request path: `make artifacts` lowers the JAX/
//! Pallas graphs to `artifacts/*.hlo.txt` once, and everything here is pure
//! Rust + PJRT afterwards.

pub mod bench_support;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod embed;
pub mod error;
pub mod index;
pub mod knn;
pub mod linalg;
pub mod metrics;
pub mod opdr;
pub mod pool;
pub mod reduction;
pub mod report;
pub mod rpc;
pub mod runtime;
pub mod telemetry;
pub mod testing;
pub mod util;

pub use error::{OpdrError, Result};
