//! Small shared utilities: seeded RNG, timing, float helpers, lock hygiene.

pub mod float;
pub mod rng;
pub mod sync;
pub mod timer;

pub use rng::Rng;
pub use sync::lock_recover;
pub use timer::Stopwatch;
