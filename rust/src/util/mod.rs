//! Small shared utilities: seeded RNG, timing, float helpers, lock hygiene.

pub mod float;
pub mod rng;
pub mod sync;
pub mod timer;

pub use rng::Rng;
pub use sync::{lock_recover, lock_recover_ranked, ranks, LockRank, RankedGuard, LOCK_RANK_TABLE};
pub use timer::Stopwatch;
