//! Small shared utilities: seeded RNG, timing, float helpers.

pub mod float;
pub mod rng;
pub mod timer;

pub use rng::Rng;
pub use timer::Stopwatch;
