//! Poison-recovering lock acquisition — the repo-wide convention for every
//! `Mutex` guard (machine-checked by `opdr-lint`'s `no-naked-lock-unwrap`).

use std::sync::{Mutex, MutexGuard};

/// Acquire `m`, recovering the data if a previous holder panicked.
///
/// A naked `.lock().unwrap()` turns one panicked thread into a cascade:
/// every later acquirer dies on the poison flag even though the protected
/// data (counters, caches, histogram buckets) is still structurally sound.
/// Everything this repo guards with a `Mutex` is either idempotently
/// rebuildable (index-slot caches are invalidated wholesale, never patched)
/// or monotonic (telemetry counters), so serving degraded data beats
/// killing the serving thread. Callers whose critical sections could leave
/// *semantically* torn state must not use this — they should hold the guard
/// only around already-computed values (the pattern the coordinator uses).
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn recovers_after_a_poisoning_panic() {
        let m = Mutex::new(7u32);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = m.lock().unwrap(); // lint:allow(no-naked-lock-unwrap: deliberately poisoning)
            panic!("poison it");
        }));
        assert!(res.is_err());
        assert!(m.is_poisoned());
        *lock_recover(&m) += 1;
        assert_eq!(*lock_recover(&m), 8);
    }
}
