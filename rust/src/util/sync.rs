//! Poison-recovering lock acquisition and the lock-rank sentinel — the
//! repo-wide conventions for every `Mutex` guard (machine-checked by
//! `opdr-lint`'s `no-naked-lock-unwrap` and the `opdr-lint analyze`
//! concurrency pass).
//!
//! # Lock-rank table
//!
//! Every long-lived `Mutex` in the tree is a named *site* with a numeric
//! rank. Locks must be acquired in **strictly increasing rank order** within
//! a thread; the table below is the canonical total order, mirrored in
//! `rust/tools/lint/README.md` and enforced twice:
//!
//! - statically, by `opdr-lint analyze` (`lock-order` builds the
//!   acquired-while-holding graph across files; `rank-table-sync` checks
//!   every edge between ranked sites is rank-increasing and every constant
//!   below is actually used at a call site), and
//! - at runtime, by [`lock_recover_ranked`], whose debug-only thread-local
//!   held-rank stack panics on out-of-order acquisition before the lock is
//!   taken (a panic with a site name beats a silent deadlock). Release
//!   builds compile the checks out entirely.
//!
//! | site                        | rank | defining module            |
//! |-----------------------------|------|----------------------------|
//! | `coordinator.builds`        | 10   | `coordinator/server.rs`    |
//! | `coordinator.compactions`   | 15   | `coordinator/server.rs`    |
//! | `coordinator.state`         | 20   | `coordinator/state.rs`     |
//! | `coordinator.cache.serving` | 25   | `coordinator/state.rs`     |
//! | `coordinator.cache.full`    | 26   | `coordinator/state.rs`     |
//! | `coordinator.cache.padded`  | 27   | `coordinator/state.rs`     |
//! | `pool.queue`                | 30   | `pool.rs`                  |
//! | `dist.gateway`              | 40   | `coordinator/server.rs`    |
//! | `dist.slot`                 | 45   | `dist/gateway.rs`          |
//! | `rpc.faults`                | 50   | `rpc/fault.rs`             |
//! | `telemetry.registry`        | 60   | `telemetry/registry.rs`    |
//! | `recorder.ring`             | 65   | `telemetry/recorder.rs`    |
//! | `telemetry.histogram`       | 70   | `telemetry/mod.rs`         |
//! | `probe.seen`                | 75   | `telemetry/probe.rs`       |
//!
//! Rank gaps are deliberate: a new site slots between its neighbors without
//! renumbering. The ordering itself encodes the serving stack's call
//! direction — coordinator state machinery (which may publish into
//! telemetry) ranks *below* telemetry sinks (which never call back out), and
//! the gateway (which walks its slots and renders cluster metrics under its
//! own guard) ranks below both the slots and every telemetry site.

use std::sync::{Mutex, MutexGuard};

/// Acquire `m`, recovering the data if a previous holder panicked.
///
/// A naked `.lock().unwrap()` turns one panicked thread into a cascade:
/// every later acquirer dies on the poison flag even though the protected
/// data (counters, caches, histogram buckets) is still structurally sound.
/// Everything this repo guards with a `Mutex` is either idempotently
/// rebuildable (index-slot caches are invalidated wholesale, never patched)
/// or monotonic (telemetry counters), so serving degraded data beats
/// killing the serving thread. Callers whose critical sections could leave
/// *semantically* torn state must not use this — they should hold the guard
/// only around already-computed values (the pattern the coordinator uses).
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// A named lock site with its position in the repo's total acquisition
/// order (see the module docs for the canonical table).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LockRank {
    /// Site name as it appears in `opdr-lint analyze` diagnostics.
    pub name: &'static str,
    /// Position in the total order; lower ranks are acquired first.
    pub rank: u16,
}

impl LockRank {
    /// Define a ranked site (used by the [`ranks`] constants).
    pub const fn new(name: &'static str, rank: u16) -> LockRank {
        LockRank { name, rank }
    }
}

/// The canonical ranked sites. One constant per long-lived `Mutex`;
/// `opdr-lint analyze`'s `rank-table-sync` rule fails CI if a constant here
/// is never passed to [`lock_recover_ranked`] or if a static
/// acquired-while-holding edge contradicts these numbers.
pub mod ranks {
    use super::LockRank;

    /// `BuildTracker.inner` — in-flight build counts (`coordinator/server.rs`).
    pub const COORDINATOR_BUILDS: LockRank = LockRank::new("coordinator.builds", 10);
    /// `BuildTracker.compactions` — per-collection compaction totals.
    pub const COORDINATOR_COMPACTIONS: LockRank = LockRank::new("coordinator.compactions", 15);
    /// `IndexSlot.inner` — the generation-guarded index swap (`coordinator/state.rs`).
    pub const COORDINATOR_STATE: LockRank = LockRank::new("coordinator.state", 20);
    /// Serving-rows cache behind the slot (`coordinator/state.rs`).
    pub const CACHE_SERVING: LockRank = LockRank::new("coordinator.cache.serving", 25);
    /// Full-precision rows cache (`coordinator/state.rs`).
    pub const CACHE_FULL: LockRank = LockRank::new("coordinator.cache.full", 26);
    /// Padded 2-D array cache (`coordinator/state.rs`).
    pub const CACHE_PADDED: LockRank = LockRank::new("coordinator.cache.padded", 27);
    /// Worker job-queue receiver (`pool.rs`).
    pub const POOL_QUEUE: LockRank = LockRank::new("pool.queue", 30);
    /// The admin path's `Mutex<Gateway>` (`coordinator/server.rs`).
    pub const DIST_GATEWAY: LockRank = LockRank::new("dist.gateway", 40);
    /// `AddrCell.addr` — a shard slot's dialable address (`dist/gateway.rs`).
    pub const DIST_SLOT: LockRank = LockRank::new("dist.slot", 45);
    /// Fault-injection script position (`rpc/fault.rs`).
    pub const RPC_FAULTS: LockRank = LockRank::new("rpc.faults", 50);
    /// Registry instrument map (`telemetry/registry.rs`).
    pub const TELEMETRY_REGISTRY: LockRank = LockRank::new("telemetry.registry", 60);
    /// Flight-recorder ring state (`telemetry/recorder.rs`).
    pub const RECORDER_RING: LockRank = LockRank::new("recorder.ring", 65);
    /// Latency-histogram buckets (`telemetry/mod.rs`).
    pub const TELEMETRY_HISTOGRAM: LockRank = LockRank::new("telemetry.histogram", 70);
    /// Recall-probe dedup map (`telemetry/probe.rs`).
    pub const PROBE_SEEN: LockRank = LockRank::new("probe.seen", 75);
}

/// Every ranked site, in rank order. Kept exhaustive by the
/// `table_lists_every_rank_constant_in_order` test below; the README table
/// and the `rank-table-sync` lint keep the other mirrors honest.
pub const LOCK_RANK_TABLE: &[LockRank] = &[
    ranks::COORDINATOR_BUILDS,
    ranks::COORDINATOR_COMPACTIONS,
    ranks::COORDINATOR_STATE,
    ranks::CACHE_SERVING,
    ranks::CACHE_FULL,
    ranks::CACHE_PADDED,
    ranks::POOL_QUEUE,
    ranks::DIST_GATEWAY,
    ranks::DIST_SLOT,
    ranks::RPC_FAULTS,
    ranks::TELEMETRY_REGISTRY,
    ranks::RECORDER_RING,
    ranks::TELEMETRY_HISTOGRAM,
    ranks::PROBE_SEEN,
];

#[cfg(debug_assertions)]
thread_local! {
    /// Ranks this thread currently holds, in acquisition order. Guards may
    /// drop out of LIFO order, so release removes the *last* matching entry
    /// rather than popping blindly.
    static HELD: std::cell::RefCell<Vec<LockRank>> = const { std::cell::RefCell::new(Vec::new()) };
}

/// Guard returned by [`lock_recover_ranked`]. Dereferences like a plain
/// `MutexGuard`; in debug builds its drop unwinds the thread-local rank
/// stack. In release builds it is a zero-cost newtype.
pub struct RankedGuard<'a, T> {
    guard: MutexGuard<'a, T>,
    #[cfg(debug_assertions)]
    rank: LockRank,
}

impl<T> std::ops::Deref for RankedGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.guard
    }
}

impl<T> std::ops::DerefMut for RankedGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.guard
    }
}

#[cfg(debug_assertions)]
impl<T> Drop for RankedGuard<'_, T> {
    fn drop(&mut self) {
        HELD.with(|held| {
            let mut held = held.borrow_mut();
            if let Some(i) = held.iter().rposition(|h| *h == self.rank) {
                held.remove(i);
            }
        });
    }
}

/// [`lock_recover`] for a ranked site: in debug builds, panic if this
/// thread already holds a lock of equal or higher rank — *before* taking
/// `m`, so a genuine inversion surfaces as a named panic in every test run
/// instead of a once-in-a-blue-moon deadlock in production. Release builds
/// skip the bookkeeping entirely (`rank` is unused and [`RankedGuard`] is a
/// plain newtype), so the serving path pays nothing.
pub fn lock_recover_ranked<T>(m: &Mutex<T>, rank: LockRank) -> RankedGuard<'_, T> {
    #[cfg(debug_assertions)]
    HELD.with(|held| {
        let mut held = held.borrow_mut();
        if let Some(worst) = held.iter().find(|h| h.rank >= rank.rank) {
            panic!(
                "lock-rank inversion: acquiring {} (rank {}) while holding {} (rank {}) — \
                 see the lock-rank table in util::sync",
                rank.name, rank.rank, worst.name, worst.rank
            );
        }
        held.push(rank);
    });
    #[cfg(not(debug_assertions))]
    let _ = rank;
    RankedGuard {
        guard: lock_recover(m),
        #[cfg(debug_assertions)]
        rank,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn recovers_after_a_poisoning_panic() {
        let m = Mutex::new(7u32);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = m.lock().unwrap(); // lint:allow(no-naked-lock-unwrap: deliberately poisoning)
            panic!("poison it");
        }));
        assert!(res.is_err());
        assert!(m.is_poisoned());
        *lock_recover(&m) += 1;
        assert_eq!(*lock_recover(&m), 8);
    }

    #[test]
    fn table_lists_every_rank_constant_in_order() {
        assert!(!LOCK_RANK_TABLE.is_empty());
        for pair in LOCK_RANK_TABLE.windows(2) {
            assert!(
                pair[0].rank < pair[1].rank,
                "table not strictly increasing: {} ({}) before {} ({})",
                pair[0].name,
                pair[0].rank,
                pair[1].name,
                pair[1].rank
            );
        }
        let mut names: Vec<&str> = LOCK_RANK_TABLE.iter().map(|r| r.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), LOCK_RANK_TABLE.len(), "duplicate site name in table");
    }

    #[test]
    fn ranked_guard_derefs_like_a_plain_guard() {
        let m = Mutex::new(3u32);
        {
            let mut g = lock_recover_ranked(&m, ranks::COORDINATOR_STATE);
            *g += 1;
        }
        assert_eq!(*lock_recover(&m), 4);
    }

    #[test]
    fn in_order_acquisition_is_silent() {
        let a = Mutex::new(());
        let b = Mutex::new(());
        let _ga = lock_recover_ranked(&a, ranks::COORDINATOR_STATE);
        let _gb = lock_recover_ranked(&b, ranks::TELEMETRY_REGISTRY);
    }

    #[cfg(debug_assertions)]
    #[test]
    fn sentinel_panics_on_inversion() {
        let lo = Mutex::new(());
        let hi = Mutex::new(());
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _hi = lock_recover_ranked(&hi, ranks::TELEMETRY_REGISTRY);
            let _lo = lock_recover_ranked(&lo, ranks::COORDINATOR_STATE);
        }));
        let err = res.expect_err("inversion must panic in debug builds");
        let msg = err.downcast_ref::<String>().expect("panic carries a message");
        assert!(msg.contains("lock-rank inversion"), "unexpected message: {msg}");
        assert!(msg.contains("coordinator.state") && msg.contains("telemetry.registry"));
        // The unwind released everything: the same order still trips, and
        // the correct order is silent.
        let res2 = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _hi = lock_recover_ranked(&hi, ranks::TELEMETRY_REGISTRY);
            let _lo = lock_recover_ranked(&lo, ranks::COORDINATOR_STATE);
        }));
        assert!(res2.is_err());
        let _lo = lock_recover_ranked(&lo, ranks::COORDINATOR_STATE);
        let _hi = lock_recover_ranked(&hi, ranks::TELEMETRY_REGISTRY);
    }

    #[cfg(debug_assertions)]
    #[test]
    fn sentinel_panics_on_equal_rank_reacquisition() {
        let a = Mutex::new(());
        let b = Mutex::new(());
        let _ga = lock_recover_ranked(&a, ranks::POOL_QUEUE);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _gb = lock_recover_ranked(&b, ranks::POOL_QUEUE);
        }));
        assert!(res.is_err(), "equal-rank nesting must panic (it could self-deadlock)");
    }

    #[cfg(debug_assertions)]
    #[test]
    fn out_of_lifo_release_unwinds_correctly() {
        let a = Mutex::new(());
        let b = Mutex::new(());
        let ga = lock_recover_ranked(&a, ranks::COORDINATOR_STATE);
        let gb = lock_recover_ranked(&b, ranks::TELEMETRY_REGISTRY);
        drop(ga); // release the *outer* guard first
        drop(gb);
        // Stack is empty again: low-rank acquisition is silent.
        let _ga = lock_recover_ranked(&a, ranks::COORDINATOR_BUILDS);
    }

    #[test]
    fn ranks_are_thread_local() {
        let hi = Mutex::new(());
        let lo = Mutex::new(());
        let _hi = lock_recover_ranked(&hi, ranks::TELEMETRY_REGISTRY);
        // Another thread's rank stack is independent of ours.
        std::thread::spawn(move || {
            let _lo = lock_recover_ranked(&lo, ranks::COORDINATOR_STATE);
        })
        .join()
        .unwrap();
    }
}
