//! Deterministic, seedable RNG used everywhere randomness is needed.
//!
//! The offline crate set has no `rand`; this is a SplitMix64 generator (the
//! PRNG used to seed xoshiro in the reference implementations) with uniform,
//! normal (Box–Muller) and categorical samplers on top. Determinism matters:
//! every experiment in EXPERIMENTS.md records its seed, and the synthetic
//! dataset generators must be reproducible across runs.

/// SplitMix64 pseudo-random generator.
///
/// Passes BigCrush as a 64-bit mixer; period 2^64. Not cryptographic.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second output of Box–Muller.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a seed. Equal seeds give equal streams.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed, gauss_spare: None }
    }

    /// Derive an independent child stream (for per-dataset / per-worker RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        // Mix the stream id through one SplitMix64 step of a separate state so
        // fork(0) != self's continuation.
        let base = self.next_u64();
        Rng::new(base ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`; `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-adversarial) use.
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached spare for even calls).
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.gauss_spare.take() {
            return s;
        }
        loop {
            let u1 = self.uniform();
            let u2 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Vector of f32 standard normals (embedding payloads).
    pub fn normal_vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        // Partial Fisher–Yates: only the first k positions need finishing.
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_centered() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(9);
        let idx = r.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30);
        assert!(idx.iter().all(|&i| i < 100));
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(42);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(13);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 2);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
