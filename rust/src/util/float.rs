//! Float comparison and summary-statistics helpers.

/// Approximate equality with combined absolute/relative tolerance.
pub fn approx_eq(a: f64, b: f64, tol: f64) -> bool {
    let diff = (a - b).abs();
    diff <= tol || diff <= tol * a.abs().max(b.abs())
}

/// Assert-style approximate equality used in tests; returns a message on failure.
pub fn check_close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    if approx_eq(a, b, tol) {
        Ok(())
    } else {
        Err(format!("not close: {a} vs {b} (tol {tol})"))
    }
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile by linear interpolation on a *sorted* slice; `q` in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Dot product of two equal-length f32 slices, accumulated in f32.
///
/// Eight independent accumulators break the FP-add dependency chain so the
/// compiler can vectorize + pipeline (perf pass L3-1; ~6× over the naive
/// single-accumulator loop on this box — see EXPERIMENTS.md §Perf).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let ca = a.chunks_exact(8);
    let cb = b.chunks_exact(8);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (xa, xb) in ca.zip(cb) {
        for l in 0..8 {
            acc[l] += xa[l] * xb[l];
        }
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for i in 0..ra.len() {
        s += ra[i] * rb[i];
    }
    s
}

/// Squared L2 norm of an f32 slice.
#[inline]
pub fn norm_sq_f32(a: &[f32]) -> f32 {
    dot_f32(a, a)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn approx_eq_abs_and_rel() {
        assert!(approx_eq(1.0, 1.0 + 1e-9, 1e-6));
        assert!(approx_eq(1e9, 1e9 * (1.0 + 1e-7), 1e-6));
        assert!(!approx_eq(1.0, 1.1, 1e-3));
    }

    #[test]
    fn stats_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert!((stddev(&xs) - 1.25f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empty_stats_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[]), 0.0);
        assert!(percentile_sorted(&[], 0.5).is_nan());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0, 20.0, 30.0];
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 30.0);
        assert_eq!(percentile_sorted(&xs, 0.5), 15.0);
    }

    #[test]
    fn dot_and_norm() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, 5.0, 6.0];
        assert_eq!(dot_f32(&a, &b), 32.0);
        assert_eq!(norm_sq_f32(&a), 14.0);
    }
}
