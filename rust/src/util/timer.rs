//! Wall-clock timing helpers used by the bench harness and telemetry.

use std::time::{Duration, Instant};

/// A simple stopwatch; `elapsed` reads without stopping.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Elapsed since start.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed nanoseconds as f64 (for stats math).
    pub fn elapsed_ns(&self) -> f64 {
        self.elapsed().as_nanos() as f64
    }

    /// Elapsed seconds as f64.
    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Restart, returning the lap duration.
    pub fn lap(&mut self) -> Duration {
        let d = self.start.elapsed();
        self.start = Instant::now();
        d
    }
}

/// Format a duration compactly for reports ("1.23ms", "4.5µs", "2.1s").
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_measures_time() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(sw.elapsed() >= Duration::from_millis(4));
    }

    #[test]
    fn lap_restarts() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(3));
        let lap = sw.lap();
        assert!(lap >= Duration::from_millis(2));
        assert!(sw.elapsed() < lap);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500ns");
        assert!(fmt_duration(Duration::from_micros(12)).ends_with("µs"));
        assert!(fmt_duration(Duration::from_millis(12)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).ends_with('s'));
    }
}
