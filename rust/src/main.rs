//! `opdr` — leader entrypoint / CLI for the OPDR reproduction.
//!
//! Subcommands:
//!   gen-data   Generate a synthetic dataset and save it to the store.
//!   sweep      Run an accuracy-vs-n/m sweep and print/fit the series.
//!   plan       Calibrate the planner on a dataset and plan dims.
//!   figure     Regenerate a paper figure's series (1..6, esc50).
//!   serve-demo Start the coordinator, ingest, run a query storm, print stats.
//!   artifacts  Verify the PJRT artifacts load and execute.

use opdr::cli::Args;
use opdr::config::SweepSpec;
use opdr::data::{store, synth, DatasetKind};
use opdr::error::{OpdrError, Result};
use opdr::metrics::Metric;
use opdr::opdr::{fit_log_model, sweep::SweepConfig, Planner};
use opdr::reduction::ReducerKind;
use opdr::report::Table;

fn main() {
    let code = match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run() -> Result<()> {
    let mut args = Args::from_env()?;
    match args.subcommand.as_str() {
        "gen-data" => cmd_gen_data(&mut args),
        "sweep" => cmd_sweep(&mut args),
        "plan" => cmd_plan(&mut args),
        "figure" => cmd_figure(&mut args),
        "experiment" => cmd_experiment(&mut args),
        "serve-demo" => cmd_serve_demo(&mut args),
        "serve-worker" => cmd_serve_worker(&mut args),
        "artifacts" => cmd_artifacts(&mut args),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => Err(OpdrError::config(format!("unknown subcommand `{other}` (try help)"))),
    }
}

fn print_help() {
    println!(
        "opdr — Order-Preserving Dimension Reduction (AAAI 2026 reproduction)\n\n\
         USAGE: opdr <subcommand> [flags]\n\n\
         SUBCOMMANDS:\n\
           gen-data   --dataset <name> --n <count> [--dim D] [--seed S] [--out file]\n\
           sweep      --dataset <name> [--k K] [--metric M] [--reducer R] [--seed S]\n\
           plan       --dataset <name> --target-accuracy A [--m M] [--k K]\n\
           figure     --id <1..6|esc50> [--seed S]\n\
           experiment --config configs/<file>.toml\n\
           serve-demo [--n N] [--dim D] [--queries Q] [--use-runtime]\n\
                      [--distributed W] [--dist-connect-ms MS]\n\
                      [--dist-deadline-ms MS] [--k K]\n\
                      (with --distributed, --metrics prints the federated\n\
                      cluster exposition and the demo ends with a faulted\n\
                      worker + slow-query flight-recorder dump)\n\
                      [--index exact|ivf|hnsw] [--sq8] [--sq8-global]\n\
                      [--pq] [--pq-m M] [--pq-ksub K] [--opq]\n\
                      [--rerank-depth R] [--hnsw-m M] [--no-hnsw-heuristic]\n\
                      [--hnsw-ef-search EF] [--ivf-threshold T]\n\
                      [--shards S] [--shard-min-vectors V]\n\
                      [--incremental | --no-incremental] [--delta-max V]\n\
                      [--mmap-cold] [--cold-dir DIR]\n\
                      [--build-workers B] [--save-index file.opdx]\n\
                      [--metrics] [--recall-probe] [--probe-every N]\n\
           serve-worker --file shard.opdx [--start S] [--listen addr:port]\n\
                      [--heap]\n\
           artifacts  [--dir artifacts]\n\n\
         DATASETS: {}\n",
        DatasetKind::ALL.map(|d| d.name()).join(", ")
    );
}

fn parse_dataset(args: &mut Args) -> Result<DatasetKind> {
    let name = args.get_or("dataset", "materials-observable");
    DatasetKind::parse(&name).ok_or_else(|| OpdrError::config(format!("unknown dataset `{name}`")))
}

fn cmd_gen_data(args: &mut Args) -> Result<()> {
    let kind = parse_dataset(args)?;
    let n = args.get_usize_or("n", 1000)?;
    let dim = args.get_usize_or("dim", kind.default_embed_dim())?;
    let seed = args.get_u64_or("seed", 42)?;
    let out = args.get_or("out", &format!("data/{}.opdr", kind.name()));
    args.finish()?;

    let set = synth::generate(kind, n, dim, seed);
    if let Some(parent) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(parent)?;
    }
    store::save(&set, &out)?;
    println!("wrote {} vectors (dim {}) to {}", set.len(), set.dim(), out);
    Ok(())
}

fn cmd_sweep(args: &mut Args) -> Result<()> {
    let kind = parse_dataset(args)?;
    let k = args.get_usize_or("k", 5)?;
    let metric = Metric::parse(&args.get_or("metric", "l2sq"))
        .ok_or_else(|| OpdrError::config("bad --metric"))?;
    let reducer = ReducerKind::parse(&args.get_or("reducer", "pca"))
        .ok_or_else(|| OpdrError::config("bad --reducer"))?;
    let seed = args.get_u64_or("seed", 42)?;
    let dim = args.get_usize_or("dim", 256)?;
    args.finish()?;

    let spec = SweepSpec { dataset: kind, k, metric, reducer, seed, ..Default::default() };
    spec.validate()?;
    let sizes = kind.paper_sample_sizes();
    let total = *sizes.iter().max().unwrap() * 4;
    let set = synth::generate(kind, total, dim, seed);
    let cfg = SweepConfig {
        k,
        metric,
        reducer,
        sample_sizes: sizes,
        dims_per_m: 10,
        repeats: 2,
        seed,
    };
    let curve = opdr::opdr::accuracy_curve(&set, &cfg)?;
    let mut t = Table::new(&["n/m", "accuracy"]);
    for (r, a) in curve.binned(12) {
        t.row(&[format!("{r:.4}"), format!("{a:.4}")]);
    }
    println!("{}", t.render());
    let fit = fit_log_model(curve.points())?;
    println!(
        "fit: A = {:.4}·ln(n/m) + {:.4}   (R² = {:.4}, {} points)",
        fit.c0, fit.c1, fit.r_squared, fit.n_points
    );
    Ok(())
}

fn cmd_plan(args: &mut Args) -> Result<()> {
    let kind = parse_dataset(args)?;
    let target = args.get_f64_or("target-accuracy", 0.9)?;
    let m = args.get_usize_or("m", 200)?;
    let k = args.get_usize_or("k", 5)?;
    let dim = args.get_usize_or("dim", 256)?;
    let seed = args.get_u64_or("seed", 42)?;
    args.finish()?;

    let set = synth::generate(kind, m, dim, seed);
    let planner = Planner::calibrate(set.data(), dim, k, Metric::SqEuclidean, ReducerKind::Pca, seed)?;
    let fit = planner.fit();
    println!(
        "calibrated on {} ({} pts, dim {}): A = {:.4}·ln(n/m) + {:.4}  R²={:.3}",
        kind.name(),
        m,
        dim,
        fit.c0,
        fit.c1,
        fit.r_squared
    );
    let mut t = Table::new(&["target A", "planned dim(Y)"]);
    for a in [0.5, 0.7, 0.8, 0.9, 0.95, target] {
        t.row(&[format!("{a:.2}"), planner.dim_for_accuracy(a, m).to_string()]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_figure(args: &mut Args) -> Result<()> {
    let id = args.get_or("id", "1");
    let seed = args.get_u64_or("seed", 42)?;
    args.finish()?;
    run_figure(&id, seed, true).map(|_| ())
}

fn cmd_experiment(args: &mut Args) -> Result<()> {
    use opdr::config::ExperimentConfig;
    use opdr::report::write_csv;
    let path = args
        .get("config")
        .ok_or_else(|| OpdrError::config("experiment: --config <file.toml> required"))?
        .to_string();
    args.finish()?;
    let cfg = ExperimentConfig::from_file(&path)?;
    println!("experiment `{}` → {}/", cfg.name, cfg.out_dir);
    for spec in &cfg.sweeps {
        let sizes = if spec.sample_sizes.is_empty() {
            spec.dataset.paper_sample_sizes()
        } else {
            spec.sample_sizes.clone()
        };
        let total = sizes.iter().max().copied().unwrap_or(100) * 4;
        let set = synth::generate(spec.dataset, total, 256, spec.seed);
        let scfg = SweepConfig {
            k: spec.k,
            metric: spec.metric,
            reducer: spec.reducer,
            sample_sizes: sizes,
            dims_per_m: spec.dims_per_m,
            repeats: spec.repeats,
            seed: spec.seed,
        };
        let curve = opdr::opdr::accuracy_curve(&set, &scfg)?;
        let fit = fit_log_model(curve.points())?;
        println!(
            "  {}: A = {:.4}·ln(n/m) + {:.4}  R²={:.3}  ({} pts)",
            spec.dataset.name(),
            fit.c0,
            fit.c1,
            fit.r_squared,
            fit.n_points
        );
        let rows: Vec<Vec<String>> = curve
            .points()
            .iter()
            .map(|&(r, a)| vec![format!("{r}"), format!("{a}")])
            .collect();
        write_csv(
            format!("{}/{}_{}.csv", cfg.out_dir, cfg.name, spec.dataset.name()),
            &["ratio", "accuracy"],
            &rows,
        )?;
    }
    Ok(())
}

fn cmd_serve_demo(args: &mut Args) -> Result<()> {
    use opdr::config::ServeConfig;
    use opdr::coordinator::Coordinator;
    use opdr::index::IndexKind;
    // Distributed mode forks shard-worker processes and routes the storm
    // through the scatter-gather gateway; the dist tuning flags without
    // --distributed would be silently ignored, so reject them (mirrors the
    // `[dist]` TOML validation).
    let distributed = args.get_usize("distributed")?;
    let dist_connect = args.get_usize("dist-connect-ms")?;
    let dist_deadline = args.get_usize("dist-deadline-ms")?;
    if distributed.is_none() && (dist_connect.is_some() || dist_deadline.is_some()) {
        return Err(OpdrError::config(
            "serve-demo: --dist-connect-ms/--dist-deadline-ms require --distributed",
        ));
    }
    if let Some(workers) = distributed {
        return cmd_serve_demo_distributed(args, workers, dist_connect, dist_deadline);
    }
    let n = args.get_usize_or("n", 2000)?;
    let dim = args.get_usize_or("dim", 256)?;
    let queries = args.get_usize_or("queries", 500)?;
    let use_runtime = args.has("use-runtime");
    let index_flag = args.get("index").map(str::to_string);
    let index_name = index_flag.clone().unwrap_or_else(|| "ivf".to_string());
    let index_sq8 = args.has("sq8");
    let sq8_global_codebook = args.has("sq8-global");
    let index_pq = args.has("pq");
    let index_pq_m = args.get_usize("pq-m")?;
    let index_pq_ksub = args.get_usize("pq-ksub")?;
    let index_pq_opq = args.has("opq");
    let rerank_depth = args.get_usize("rerank-depth")?;
    // Dependent flags without --pq would be silently ignored; fail loudly
    // instead (mirrors the `[serve]` TOML validation).
    if !index_pq
        && (index_pq_m.is_some()
            || index_pq_ksub.is_some()
            || index_pq_opq
            || rerank_depth.is_some())
    {
        return Err(OpdrError::config(
            "serve-demo: --pq-m/--pq-ksub/--opq/--rerank-depth require --pq",
        ));
    }
    let index_pq_m = index_pq_m.unwrap_or(0);
    let index_pq_ksub = index_pq_ksub.unwrap_or(ServeConfig::default().index_pq_ksub);
    let rerank_depth = rerank_depth.unwrap_or(ServeConfig::default().rerank_depth);
    let hnsw_m = args.get_usize_or("hnsw-m", 16)?;
    let hnsw_ef_search = args.get_usize_or("hnsw-ef-search", 64)?;
    let hnsw_heuristic = !args.has("no-hnsw-heuristic");
    let ivf_threshold = args.get_usize_or("ivf-threshold", ServeConfig::default().ivf_threshold)?;
    let shards = args.get_usize_or("shards", ServeConfig::default().shards)?;
    let shard_min_vectors =
        args.get_usize_or("shard-min-vectors", ServeConfig::default().shard_min_vectors)?;
    let build_workers = args.get_usize_or("build-workers", ServeConfig::default().build_workers)?;
    // Incremental ingest is the default; --no-incremental selects the legacy
    // invalidate-on-ingest path (and then --delta-max would be silently
    // ignored, so reject the combination — mirrors the TOML validation).
    let force_incremental = args.has("incremental");
    let no_incremental = args.has("no-incremental");
    let delta_max = args.get_usize("delta-max")?;
    if force_incremental && no_incremental {
        return Err(OpdrError::config(
            "serve-demo: --incremental and --no-incremental are mutually exclusive",
        ));
    }
    if no_incremental && delta_max.is_some() {
        return Err(OpdrError::config("serve-demo: --delta-max requires incremental ingest"));
    }
    let incremental_ingest = !no_incremental;
    let delta_max_vectors = delta_max.unwrap_or(ServeConfig::default().delta_max_vectors);
    // Mmap cold tier: full-precision rows (flat payloads, PQ rerank tiers)
    // spill to cold files and serve zero-copy; --cold-dir without the
    // toggle would be silently ignored, so reject it (mirrors the TOML
    // validation).
    let cold_tier_mmap = args.has("mmap-cold");
    let cold_dir_flag = args.get("cold-dir").map(str::to_string);
    if !cold_tier_mmap && cold_dir_flag.is_some() {
        return Err(OpdrError::config("serve-demo: --cold-dir requires --mmap-cold"));
    }
    let cold_dir = cold_dir_flag.unwrap_or_else(|| ServeConfig::default().cold_dir);
    let save_index = args.get("save-index").map(str::to_string);
    // Observability flags: --metrics dumps the Prometheus-style exposition
    // after the storm; --recall-probe shadows a sampled fraction of the
    // queries against the exact scan (--probe-every without it would be
    // silently ignored — mirrors the TOML validation).
    let dump_metrics = args.has("metrics");
    let recall_probe = args.has("recall-probe");
    let probe_every = args.get_usize("probe-every")?;
    if !recall_probe && probe_every.is_some() {
        return Err(OpdrError::config("serve-demo: --probe-every requires --recall-probe"));
    }
    let recall_probe_every = probe_every.unwrap_or(ServeConfig::default().recall_probe_every);
    args.finish()?;

    let index_kind = IndexKind::parse(&index_name)
        .ok_or_else(|| OpdrError::config(format!("unknown --index `{index_name}`")))?;
    let cfg = ServeConfig {
        use_runtime,
        index_kind,
        index_sq8,
        sq8_global_codebook,
        index_pq,
        index_pq_m,
        index_pq_ksub,
        index_pq_opq,
        rerank_depth,
        hnsw_m,
        hnsw_ef_search,
        hnsw_heuristic,
        ivf_threshold,
        shards,
        shard_min_vectors,
        build_workers,
        incremental_ingest,
        delta_max_vectors,
        cold_tier_mmap,
        cold_dir,
        recall_probe,
        recall_probe_every,
        ..Default::default()
    };
    cfg.validate()?;
    let coord = Coordinator::start(cfg)?;
    coord.create_collection("demo", dim, Metric::SqEuclidean)?;
    let set = synth::generate(DatasetKind::Flickr30k, n, dim, 42);
    coord.ingest("demo", set.data().to_vec())?;
    let planned = coord.build_reduced("demo", 0.9, 10)?;
    // BuildReduced only auto-indexes above the size threshold; when the user
    // asked for an index explicitly, build it regardless so the flags (and
    // --save-index) always take effect.
    let index_requested = index_flag.is_some()
        || index_sq8
        || index_pq
        || shards > 1
        || cold_tier_mmap
        || save_index.is_some();
    if index_requested {
        coord.build_index("demo")?;
    }
    // Report the *effective* shard count: `shard_min_vectors` caps the
    // partition, so small collections may serve fewer shards than asked.
    let eff_shards = opdr::index::shard::shard_ranges(n, shards, shard_min_vectors).len();
    let storage = if index_pq {
        if index_pq_opq { "+pq/opq" } else { "+pq" }
    } else if index_sq8 {
        if sq8_global_codebook { "+sq8(global)" } else { "+sq8" }
    } else {
        ""
    };
    println!(
        "ingested {n} vectors (dim {dim}); OPDR planned serving dim = {planned}; \
         index policy = {}{}{}{}",
        index_kind.name(),
        storage,
        if eff_shards > 1 { format!(" x{eff_shards} shards") } else { String::new() },
        if cold_tier_mmap { " [mmap cold tier]" } else { "" }
    );

    let sw = opdr::util::Stopwatch::start();
    let mut rxs = Vec::new();
    for i in 0..queries {
        match coord.search_async("demo", set.vector(i % n).to_vec(), 10) {
            Ok(rx) => rxs.push(rx),
            Err(_) => {} // backpressure
        }
    }
    let mut ok = 0;
    for rx in rxs {
        if rx.recv().map(|r| r.is_ok()).unwrap_or(false) {
            ok += 1;
        }
    }
    let secs = sw.elapsed_secs();
    println!("completed {ok}/{queries} queries in {secs:.2}s ({:.0} qps)", ok as f64 / secs);
    if incremental_ingest && index_requested {
        // Incremental ingest in action: the appended batch lands in the
        // serving index's delta segment (visible as `delta=` in the stats
        // below) instead of invalidating the index.
        let extra = synth::generate(DatasetKind::Flickr30k, 64, dim, 7);
        coord.ingest("demo", extra.data().to_vec())?;
        let hit = coord.search("demo", extra.vector(0).to_vec(), 1)?;
        println!(
            "incremental ingest: +64 rows absorbed into the delta; first appended row \
             self-hits at id {}",
            hit.neighbors.first().map_or(0, |nb| nb.index)
        );
    }
    println!("{}", coord.stats()?);
    if dump_metrics {
        // Full labeled exposition: per-(verb, collection) quantiles, stage
        // histograms, probe gauges, collection topology.
        println!("{}", coord.metrics_text()?);
    }
    if let Some(path) = save_index {
        coord.save_index("demo", &path)?;
        println!("saved index segment to {path}");
    }
    coord.shutdown();
    Ok(())
}

/// `serve-demo --distributed W`: split the collection into W contiguous
/// shards persisted as version-5 cold files, fork/exec one supervised
/// `serve-worker` process per shard over loopback TCP, and drive the query
/// storm through the scatter-gather gateway. The run fails loudly if the
/// distributed answer is not bitwise identical to the unsharded exact scan.
fn cmd_serve_demo_distributed(
    args: &mut Args,
    workers: usize,
    connect_ms: Option<usize>,
    deadline_ms: Option<usize>,
) -> Result<()> {
    use opdr::config::DistConfig;
    use opdr::dist::{AddrCell, Gateway, ProcessWorker, Supervisor, WorkerHandle, WorkerSpec};
    use opdr::index::{AnnIndex, ExactIndex, StorageSpec};
    use opdr::telemetry::Registry;
    use std::sync::Arc;
    let n = args.get_usize_or("n", 2000)?;
    let dim = args.get_usize_or("dim", 64)?;
    let queries = args.get_usize_or("queries", 500)?;
    let k = args.get_usize_or("k", 10)?;
    let dump_metrics = args.has("metrics");
    // Single-process index flags make no sense here; finish() rejects any
    // that were passed.
    args.finish()?;
    let mut cfg = DistConfig { workers, ..Default::default() };
    if let Some(ms) = connect_ms {
        cfg.connect_timeout_ms = ms as u64;
    }
    if let Some(ms) = deadline_ms {
        cfg.request_deadline_ms = ms as u64;
    }
    cfg.validate()?;

    // Dataset, contiguous shard split, one version-5 cold file per worker
    // (the file is what makes supervised respawn ~0 time: the annex mmaps
    // back in place).
    let set = synth::generate(DatasetKind::Flickr30k, n, dim, 42);
    let metric = Metric::SqEuclidean;
    let ranges = opdr::index::shard::shard_ranges(n, workers, 1);
    let dir = std::env::temp_dir().join(format!("opdr-dist-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let exe = std::env::current_exe()?;
    let registry = Arc::new(Registry::new());
    let mut specs = Vec::new();
    let mut sups = Vec::new();
    for (i, range) in ranges.iter().enumerate() {
        let rows = &set.data()[range.start * dim..range.end * dim];
        let shard = ExactIndex::build(rows, dim, metric, &StorageSpec::flat(), 42)?;
        let path = dir.join(format!("shard-{i}.opdx"));
        store::save_index_cold(&shard, &path)?;
        let name = format!("w{i}");
        let cell = AddrCell::new("");
        let exe2 = exe.clone();
        let path2 = path.clone();
        let start = range.start;
        let factory = Box::new(move || -> Result<Box<dyn WorkerHandle>> {
            let mut cmd = std::process::Command::new(&exe2);
            cmd.arg("serve-worker")
                .arg("--file")
                .arg(&path2)
                .arg("--start")
                .arg(start.to_string())
                .arg("--listen")
                .arg("127.0.0.1:0");
            Ok(Box::new(ProcessWorker::spawn(cmd)?) as Box<dyn WorkerHandle>)
        });
        sups.push(Supervisor::start(
            name.clone(),
            Arc::clone(&cell),
            factory,
            Arc::clone(&registry),
        )?);
        specs.push(WorkerSpec { name, addr: cell });
    }
    let mut gw = Gateway::new(specs, cfg, Arc::clone(&registry));
    // Recall probe over the distributed path: sampled gateway answers are
    // shadow-executed against the unreduced corpus; distributed serving is
    // unreduced, so the recall@k and μ gauges must both read 1.0.
    gw.attach_probe("demo", Arc::new(set.data().to_vec()), dim, metric, 10);
    println!(
        "distributed serving: {} worker processes over {n} rows (dim {dim})",
        ranges.len()
    );

    // Headline guarantee, spot-checked live: gateway == unsharded scan,
    // bitwise.
    let reference = ExactIndex::build(set.data(), dim, metric, &StorageSpec::flat(), 42)?;
    let sample = gw.search(set.vector(0), k)?;
    let expect = reference.search(set.vector(0), k)?;
    let exact = !sample.partial
        && sample.neighbors.len() == expect.len()
        && sample
            .neighbors
            .iter()
            .zip(&expect)
            .all(|(a, b)| a.index == b.index && a.distance.to_bits() == b.distance.to_bits());
    println!(
        "order-exactness spot check vs unsharded scan: {}",
        if exact { "bitwise identical" } else { "MISMATCH" }
    );

    let sw = opdr::util::Stopwatch::start();
    let mut ok = 0usize;
    let mut partial = 0usize;
    for i in 0..queries {
        let r = gw.search(set.vector(i % n), k)?;
        ok += 1;
        if r.partial {
            partial += 1;
        }
    }
    let secs = sw.elapsed_secs();
    println!(
        "completed {ok}/{queries} gateway queries in {secs:.2}s ({:.0} qps), {partial} partial",
        ok as f64 / secs
    );
    // Drain the probe so its gauges cover every sampled query before any
    // exposition is rendered.
    gw.detach_probe();
    if dump_metrics {
        // Federated cluster exposition: every worker's registry scraped
        // over MetricsPull, each sample once `worker="wN"`-labeled and once
        // merged into the unlabeled aggregate, plus the gateway's own
        // series.
        println!("{}", gw.cluster_metrics());
    }
    // Flight-recorder demo: fault one worker, issue a query that degrades
    // to partial, and show the slow-query dump naming the faulted shard.
    if let Some(s) = sups.last_mut() {
        s.shutdown();
        let r = gw.search(set.vector(0), k)?;
        println!(
            "faulted worker `w{}`: query degraded to partial={} ({}/{} shards)",
            sups.len() - 1,
            r.partial,
            r.shards_ok,
            r.shards_total
        );
        let dump = gw.recorder().dump();
        let mut entries = 0;
        for line in dump.lines() {
            if line.starts_with("trace=") {
                entries += 1;
                if entries > 1 {
                    break; // header + the newest pinned (partial) entry only
                }
            }
            println!("{line}");
        }
    }
    for s in &mut sups {
        s.shutdown();
    }
    let _ = std::fs::remove_dir_all(&dir);
    if !exact {
        return Err(OpdrError::runtime(
            "distributed result diverged from the unsharded reference",
        ));
    }
    Ok(())
}

/// `serve-worker`: the child-process entrypoint spawned by
/// `serve-demo --distributed` (one per shard). Loads the shard's `OPDR`
/// file (version-5 files mmap their annex), binds, prints
/// `listening <addr>` for the parent and serves until killed.
fn cmd_serve_worker(args: &mut Args) -> Result<()> {
    let file = args
        .get("file")
        .map(str::to_string)
        .ok_or_else(|| OpdrError::config("serve-worker: --file <shard.opdx> is required"))?;
    let start = args.get_usize_or("start", 0)?;
    let listen = args.get_or("listen", "127.0.0.1:0");
    let heap = args.has("heap");
    args.finish()?;
    opdr::dist::run_worker_from_file(&file, start, &listen, heap)
}

fn cmd_artifacts(args: &mut Args) -> Result<()> {
    let dir = args.get_or("dir", "artifacts");
    args.finish()?;
    let engine = opdr::runtime::Engine::new(&dir)?;
    println!("platform: {}", engine.platform());
    for name in engine.manifest().names() {
        let sw = opdr::util::Stopwatch::start();
        engine.warmup(&name)?;
        println!("  {name}: compiled in {:.2}s", sw.elapsed_secs());
    }
    println!("all artifacts OK");
    Ok(())
}

/// Run a figure by id (datasets figures 1-6 + esc50), optionally printing.
fn run_figure(id: &str, seed: u64, verbose: bool) -> Result<Vec<opdr::opdr::sweep::AccuracyCurve>> {
    let datasets: Vec<(DatasetKind, &str)> = match id {
        "1" => vec![(DatasetKind::MaterialsObservable, "Figure 1: Observable")],
        "2" => vec![(DatasetKind::MaterialsStable, "Figure 2: Stable")],
        "3" => vec![(DatasetKind::MaterialsMetal, "Figure 3: Metal")],
        "4" => vec![(DatasetKind::MaterialsMagnetic, "Figure 4: Magnetic")],
        "5" => vec![(DatasetKind::Flickr30k, "Figure 5: Flickr30k")],
        "6" => vec![(DatasetKind::OmniCorpus, "Figure 6: OmniCorpus")],
        "esc50" => vec![(DatasetKind::Esc50, "ESC-50 (audio-text)")],
        other => {
            return Err(OpdrError::config(format!(
                "figure `{other}` is handled by the bench targets (7-12, metrics)"
            )))
        }
    };
    let mut curves = Vec::new();
    for (kind, title) in datasets {
        let sizes = kind.paper_sample_sizes();
        let total = sizes.iter().max().unwrap() * 4;
        let set = synth::generate(kind, total, kind.default_embed_dim().min(512), seed);
        let cfg = SweepConfig {
            sample_sizes: sizes,
            dims_per_m: 10,
            repeats: 2,
            seed,
            ..Default::default()
        };
        let curve = opdr::opdr::accuracy_curve(&set, &cfg)?;
        if verbose {
            println!("\n{title}");
            let mut t = Table::new(&["n/m", "accuracy"]);
            for (r, a) in curve.binned(10) {
                t.row(&[format!("{r:.4}"), format!("{a:.4}")]);
            }
            println!("{}", t.render());
            if let Ok(fit) = fit_log_model(curve.points()) {
                println!(
                    "fit: A = {:.4}·ln(n/m) + {:.4}  R²={:.3}",
                    fit.c0, fit.c1, fit.r_squared
                );
            }
        }
        curves.push(curve);
    }
    Ok(curves)
}
