//! Report writers: aligned console tables, CSV and minimal JSON.
//!
//! Every bench target prints the paper's series through [`Table`] and
//! persists them via [`write_csv`] / [`JsonWriter`] under `bench_out/`
//! (no `serde` offline — the JSON writer is a small escape-correct emitter).

use crate::error::Result;
use std::fmt::Write as _;
use std::path::Path;

/// A console table with aligned columns.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a header row.
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    /// Append a row (stringified cells).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        self.rows.push(cells.to_vec());
        self
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncols = self.header.len().max(self.rows.iter().map(Vec::len).max().unwrap_or(0));
        let mut widths = vec![0usize; ncols];
        let all = std::iter::once(&self.header).chain(self.rows.iter());
        for row in all {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |row: &[String], out: &mut String| {
            for (i, cell) in row.iter().enumerate() {
                let _ = write!(out, "{:<width$}  ", cell, width = widths[i]);
            }
            out.push('\n');
        };
        fmt_row(&self.header, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * ncols;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &mut out);
        }
        out
    }
}

/// Write series rows as CSV (`header` then `rows`), creating parent dirs.
pub fn write_csv(path: impl AsRef<Path>, header: &[&str], rows: &[Vec<String>]) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut out = String::new();
    out.push_str(&header.join(","));
    out.push('\n');
    for row in rows {
        let escaped: Vec<String> = row
            .iter()
            .map(|c| {
                if c.contains(',') || c.contains('"') || c.contains('\n') {
                    format!("\"{}\"", c.replace('"', "\"\""))
                } else {
                    c.clone()
                }
            })
            .collect();
        out.push_str(&escaped.join(","));
        out.push('\n');
    }
    std::fs::write(path, out)?;
    Ok(())
}

/// Minimal JSON object/array writer with correct string escaping.
#[derive(Debug, Default)]
pub struct JsonWriter {
    buf: String,
    stack: Vec<(char, bool)>, // (closer, has_items)
}

impl JsonWriter {
    /// New writer.
    pub fn new() -> Self {
        JsonWriter::default()
    }

    fn pre_value(&mut self) {
        if let Some(top) = self.stack.last_mut() {
            if top.1 {
                self.buf.push(',');
            }
            top.1 = true;
        }
    }

    /// Begin an object (as a value).
    pub fn begin_object(&mut self) -> &mut Self {
        self.pre_value();
        self.buf.push('{');
        self.stack.push(('}', false));
        self
    }

    /// Begin an array (as a value).
    pub fn begin_array(&mut self) -> &mut Self {
        self.pre_value();
        self.buf.push('[');
        self.stack.push((']', false));
        self
    }

    /// Close the innermost object/array.
    pub fn end(&mut self) -> &mut Self {
        if let Some((closer, _)) = self.stack.pop() {
            self.buf.push(closer);
        }
        self
    }

    /// Emit a key (inside an object); follow with a value call.
    pub fn key(&mut self, k: &str) -> &mut Self {
        self.pre_value();
        self.push_escaped(k);
        self.buf.push(':');
        // The upcoming value must not add its own comma.
        if let Some(top) = self.stack.last_mut() {
            top.1 = false;
        }
        self
    }

    /// String value.
    pub fn string(&mut self, v: &str) -> &mut Self {
        self.pre_value();
        self.push_escaped(v);
        self
    }

    /// Number value.
    pub fn number(&mut self, v: f64) -> &mut Self {
        self.pre_value();
        if v.is_finite() {
            let _ = write!(self.buf, "{v}");
        } else {
            self.buf.push_str("null");
        }
        self
    }

    /// Integer value.
    pub fn integer(&mut self, v: i64) -> &mut Self {
        self.pre_value();
        let _ = write!(self.buf, "{v}");
        self
    }

    /// Bool value.
    pub fn boolean(&mut self, v: bool) -> &mut Self {
        self.pre_value();
        self.buf.push_str(if v { "true" } else { "false" });
        self
    }

    fn push_escaped(&mut self, s: &str) {
        self.buf.push('"');
        for c in s.chars() {
            match c {
                '"' => self.buf.push_str("\\\""),
                '\\' => self.buf.push_str("\\\\"),
                '\n' => self.buf.push_str("\\n"),
                '\r' => self.buf.push_str("\\r"),
                '\t' => self.buf.push_str("\\t"),
                c if (c as u32) < 0x20 => {
                    let _ = write!(self.buf, "\\u{:04x}", c as u32);
                }
                c => self.buf.push(c),
            }
        }
        self.buf.push('"');
    }

    /// Final JSON text (stack must be empty).
    pub fn finish(self) -> String {
        debug_assert!(self.stack.is_empty(), "unclosed JSON scopes");
        self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["x".into(), "1".into()]);
        t.row(&["longer-name".into(), "2".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[0].starts_with("name"));
        assert!(lines[1].starts_with("---"));
        assert_eq!(lines.len(), 4);
        // Columns aligned: "value"/"1"/"2" start at same offset.
        let col = lines[0].find("value").unwrap();
        assert_eq!(&lines[2][col..col + 1], "1");
        assert_eq!(&lines[3][col..col + 1], "2");
    }

    #[test]
    fn csv_escaping_and_roundtrip_shape() {
        let dir = std::env::temp_dir().join("opdr_report_test");
        let path = dir.join("t.csv");
        write_csv(
            &path,
            &["a", "b"],
            &[vec!["1,2".to_string(), "plain".to_string()], vec!["q\"q".to_string(), "x".to_string()]],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("a,b\n"));
        assert!(text.contains("\"1,2\""));
        assert!(text.contains("\"q\"\"q\""));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn json_object_and_array() {
        let mut w = JsonWriter::new();
        w.begin_object();
        w.key("name").string("fig1");
        w.key("points").begin_array();
        w.begin_object();
        w.key("ratio").number(0.5);
        w.key("acc").number(0.9);
        w.end();
        w.end();
        w.key("count").integer(2);
        w.key("ok").boolean(true);
        w.end();
        let s = w.finish();
        assert_eq!(
            s,
            r#"{"name":"fig1","points":[{"ratio":0.5,"acc":0.9}],"count":2,"ok":true}"#
        );
    }

    #[test]
    fn json_escapes_specials() {
        let mut w = JsonWriter::new();
        w.begin_object();
        w.key("s").string("a\"b\\c\nd");
        w.end();
        assert_eq!(w.finish(), r#"{"s":"a\"b\\c\nd"}"#);
    }

    #[test]
    fn json_nan_becomes_null() {
        let mut w = JsonWriter::new();
        w.begin_array();
        w.number(f64::NAN);
        w.end();
        assert_eq!(w.finish(), "[null]");
    }
}
