//! Multimodal data substrates.
//!
//! The paper evaluates on seven datasets (four Materials Project subsets,
//! Flickr30k, OmniCorpus-037 CC, ESC-50), none of which are available in this
//! offline environment. Per the substitution rule, [`synth`] generates
//! synthetic embedding sets whose *geometry* matches each dataset's observed
//! regime (see DESIGN.md §1), and [`records`] generates the raw multimodal
//! records (token / patch / spectrogram features) that the [`crate::embed`]
//! pipeline pushes through the AOT-compiled encoder towers.
//!
//! [`store`] is the binary embedding store used to persist extraction results
//! between pipeline stages, and [`mapped`] is the mmap-backed cold vector
//! tier (the version-5 `OPDR` layout) that serves full-precision rows
//! zero-copy from disk for collections larger than RAM.

pub mod mapped;
pub mod records;
pub mod store;
pub mod synth;

use crate::error::{OpdrError, Result};

/// The seven evaluation datasets of the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DatasetKind {
    /// Materials Project "observable" subset (paper: 33,990 points).
    MaterialsObservable,
    /// Materials Project "stable" subset (48,884).
    MaterialsStable,
    /// Materials Project "metal" subset (72,252).
    MaterialsMetal,
    /// Materials Project "magnetic" subset (81,723).
    MaterialsMagnetic,
    /// Flickr30k image–text pairs (31,014).
    Flickr30k,
    /// OmniCorpus-037 CC image–text pairs (3,878,063; sweeps sample ≤ 300).
    OmniCorpus,
    /// ESC-50 audio–text pairs (2,000).
    Esc50,
}

impl DatasetKind {
    /// All datasets, figure order.
    pub const ALL: [DatasetKind; 7] = [
        DatasetKind::MaterialsObservable,
        DatasetKind::MaterialsStable,
        DatasetKind::MaterialsMetal,
        DatasetKind::MaterialsMagnetic,
        DatasetKind::Flickr30k,
        DatasetKind::OmniCorpus,
        DatasetKind::Esc50,
    ];

    /// Parse from a config / CLI string.
    pub fn parse(s: &str) -> Option<DatasetKind> {
        match s.to_ascii_lowercase().as_str() {
            "materials-observable" | "observable" => Some(DatasetKind::MaterialsObservable),
            "materials-stable" | "stable" => Some(DatasetKind::MaterialsStable),
            "materials-metal" | "metal" => Some(DatasetKind::MaterialsMetal),
            "materials-magnetic" | "magnetic" => Some(DatasetKind::MaterialsMagnetic),
            "flickr30k" | "flickr" => Some(DatasetKind::Flickr30k),
            "omnicorpus" | "omni" => Some(DatasetKind::OmniCorpus),
            "esc50" | "esc-50" => Some(DatasetKind::Esc50),
            _ => None,
        }
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::MaterialsObservable => "materials-observable",
            DatasetKind::MaterialsStable => "materials-stable",
            DatasetKind::MaterialsMetal => "materials-metal",
            DatasetKind::MaterialsMagnetic => "materials-magnetic",
            DatasetKind::Flickr30k => "flickr30k",
            DatasetKind::OmniCorpus => "omnicorpus",
            DatasetKind::Esc50 => "esc50",
        }
    }

    /// Paper cardinality (full dataset; sweeps use small subsets of this).
    pub fn paper_cardinality(&self) -> usize {
        match self {
            DatasetKind::MaterialsObservable => 33_990,
            DatasetKind::MaterialsStable => 48_884,
            DatasetKind::MaterialsMetal => 72_252,
            DatasetKind::MaterialsMagnetic => 81_723,
            DatasetKind::Flickr30k => 31_014,
            DatasetKind::OmniCorpus => 3_878_063,
            DatasetKind::Esc50 => 2_000,
        }
    }

    /// Subset sizes the paper sweeps for this dataset.
    pub fn paper_sample_sizes(&self) -> Vec<usize> {
        match self {
            DatasetKind::Flickr30k | DatasetKind::OmniCorpus => vec![10, 50, 100, 150, 300],
            DatasetKind::Esc50 => vec![10, 50, 100, 150, 300],
            _ => vec![10, 20, 30, 40, 50, 60, 70, 80],
        }
    }

    /// True for the four Materials Project subsets.
    pub fn is_materials(&self) -> bool {
        matches!(
            self,
            DatasetKind::MaterialsObservable
                | DatasetKind::MaterialsStable
                | DatasetKind::MaterialsMetal
                | DatasetKind::MaterialsMagnetic
        )
    }

    /// Default concatenated embedding dimensionality (CLIP text+image = 1024;
    /// ESC-50 uses BERT 768 + PANNs 2048 = 2816).
    pub fn default_embed_dim(&self) -> usize {
        match self {
            DatasetKind::Esc50 => 2816,
            _ => 1024,
        }
    }
}

/// A set of `n` embeddings of dimension `dim`, row-major `f32`.
#[derive(Debug, Clone, PartialEq)]
pub struct EmbeddingSet {
    dim: usize,
    data: Vec<f32>,
    label: String,
}

impl EmbeddingSet {
    /// Build from raw parts.
    pub fn new(label: impl Into<String>, dim: usize, data: Vec<f32>) -> Result<Self> {
        if dim == 0 {
            return Err(OpdrError::shape("EmbeddingSet: dim must be > 0"));
        }
        if data.len() % dim != 0 {
            return Err(OpdrError::shape("EmbeddingSet: data not a multiple of dim"));
        }
        Ok(EmbeddingSet { dim, data, label: label.into() })
    }

    /// Number of vectors.
    pub fn len(&self) -> usize {
        self.data.len() / self.dim
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Vector dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Row-major payload.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Dataset / pipeline label.
    pub fn label(&self) -> &str {
        &self.label
    }

    /// The `i`-th vector.
    pub fn vector(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// Subset by indices (copies).
    pub fn subset(&self, idx: &[usize]) -> Result<EmbeddingSet> {
        let mut data = Vec::with_capacity(idx.len() * self.dim);
        for &i in idx {
            if i >= self.len() {
                return Err(OpdrError::data(format!("subset: index {i} out of range")));
            }
            data.extend_from_slice(self.vector(i));
        }
        EmbeddingSet::new(self.label.clone(), self.dim, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for kind in DatasetKind::ALL {
            assert_eq!(DatasetKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(DatasetKind::parse("unknown"), None);
    }

    #[test]
    fn paper_metadata_sane() {
        assert_eq!(DatasetKind::Esc50.paper_cardinality(), 2000);
        assert_eq!(DatasetKind::MaterialsObservable.paper_sample_sizes().len(), 8);
        assert_eq!(DatasetKind::Flickr30k.paper_sample_sizes(), vec![10, 50, 100, 150, 300]);
        assert!(DatasetKind::MaterialsMetal.is_materials());
        assert!(!DatasetKind::Flickr30k.is_materials());
        assert_eq!(DatasetKind::Esc50.default_embed_dim(), 2816);
        assert_eq!(DatasetKind::Flickr30k.default_embed_dim(), 1024);
    }

    #[test]
    fn embedding_set_basics() {
        let set = EmbeddingSet::new("t", 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(set.len(), 2);
        assert_eq!(set.vector(1), &[3.0, 4.0]);
        assert!(!set.is_empty());
        let sub = set.subset(&[1]).unwrap();
        assert_eq!(sub.len(), 1);
        assert_eq!(sub.vector(0), &[3.0, 4.0]);
        assert!(set.subset(&[5]).is_err());
    }

    #[test]
    fn embedding_set_validation() {
        assert!(EmbeddingSet::new("t", 0, vec![]).is_err());
        assert!(EmbeddingSet::new("t", 3, vec![1.0; 4]).is_err());
    }
}
