//! Raw multimodal records: the inputs of the encoder towers.
//!
//! The paper extracts embeddings from image–text (and audio–text) pairs with
//! CLIP/ViT/BERT/PANNs. We cannot ship those datasets or checkpoints, so each
//! record here carries synthetic *features* shaped like the real inputs:
//! token-feature matrices for text, patch-feature matrices for images, and
//! mel-spectrogram frames for audio. Records from the same latent class share
//! correlated features, so encoder outputs inherit cluster structure just as
//! real embeddings do.
//!
//! Shapes are fixed to match the AOT artifacts (see `python/compile/aot.py`):
//! text `T×F = 32×64`, image `P×F = 64×64`, audio `M×T = 64×32`.

use crate::data::DatasetKind;
use crate::util::Rng;

/// Token count for text inputs.
pub const TEXT_TOKENS: usize = 32;
/// Feature width of text token features.
pub const TEXT_FEAT: usize = 64;
/// Patch count for image inputs.
pub const IMAGE_PATCHES: usize = 64;
/// Feature width of image patch features.
pub const IMAGE_FEAT: usize = 64;
/// Mel bands for audio inputs.
pub const AUDIO_MELS: usize = 64;
/// Frames for audio inputs.
pub const AUDIO_FRAMES: usize = 32;

/// One multimodal record: synthetic text + image (and optionally audio)
/// features plus the latent class that generated it.
#[derive(Debug, Clone)]
pub struct MultimodalRecord {
    /// Latent class id (cluster the record was drawn from).
    pub class: usize,
    /// Text token features, row-major `TEXT_TOKENS × TEXT_FEAT`.
    pub text: Vec<f32>,
    /// Image patch features, row-major `IMAGE_PATCHES × IMAGE_FEAT`.
    pub image: Vec<f32>,
    /// Audio mel features, row-major `AUDIO_MELS × AUDIO_FRAMES`
    /// (empty for non-audio datasets).
    pub audio: Vec<f32>,
}

/// Deterministically generate `n` records for a dataset kind.
pub fn generate_records(kind: DatasetKind, n: usize, seed: u64) -> Vec<MultimodalRecord> {
    let spec = crate::data::synth::spec_for(kind);
    let classes = spec.clusters.max(1);
    let mut rng = Rng::new(seed ^ 0x5ECD_0001);

    // Per-class prototype features for each modality.
    let mut proto_rng = rng.fork(10);
    let text_proto: Vec<f32> = proto_rng.normal_vec_f32(classes * TEXT_TOKENS * TEXT_FEAT);
    let image_proto: Vec<f32> = proto_rng.normal_vec_f32(classes * IMAGE_PATCHES * IMAGE_FEAT);
    let audio_proto: Vec<f32> = proto_rng.normal_vec_f32(classes * AUDIO_MELS * AUDIO_FRAMES);
    let with_audio = kind == DatasetKind::Esc50;

    let weights: Vec<f64> = (0..classes).map(|c| 1.0 / (1.0 + c as f64).sqrt()).collect();
    let mut point_rng = rng.fork(11);
    (0..n)
        .map(|_| {
            let class = point_rng.categorical(&weights);
            let jitter = spec.noise as f32 * 3.0 + 0.3;
            let text = mix(
                &text_proto[class * TEXT_TOKENS * TEXT_FEAT..(class + 1) * TEXT_TOKENS * TEXT_FEAT],
                jitter,
                &mut point_rng,
            );
            let image = mix(
                &image_proto
                    [class * IMAGE_PATCHES * IMAGE_FEAT..(class + 1) * IMAGE_PATCHES * IMAGE_FEAT],
                jitter,
                &mut point_rng,
            );
            let audio = if with_audio {
                mix(
                    &audio_proto
                        [class * AUDIO_MELS * AUDIO_FRAMES..(class + 1) * AUDIO_MELS * AUDIO_FRAMES],
                    jitter,
                    &mut point_rng,
                )
            } else {
                Vec::new()
            };
            MultimodalRecord { class, text, image, audio }
        })
        .collect()
}

fn mix(proto: &[f32], jitter: f32, rng: &mut Rng) -> Vec<f32> {
    proto.iter().map(|&p| p + jitter * rng.normal() as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_shaped() {
        let a = generate_records(DatasetKind::Flickr30k, 5, 1);
        let b = generate_records(DatasetKind::Flickr30k, 5, 1);
        assert_eq!(a.len(), 5);
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.class, rb.class);
            assert_eq!(ra.text, rb.text);
            assert_eq!(ra.text.len(), TEXT_TOKENS * TEXT_FEAT);
            assert_eq!(ra.image.len(), IMAGE_PATCHES * IMAGE_FEAT);
            assert!(ra.audio.is_empty());
        }
    }

    #[test]
    fn esc50_has_audio() {
        let recs = generate_records(DatasetKind::Esc50, 3, 2);
        for r in &recs {
            assert_eq!(r.audio.len(), AUDIO_MELS * AUDIO_FRAMES);
        }
    }

    #[test]
    fn same_class_records_closer_than_cross_class() {
        let recs = generate_records(DatasetKind::MaterialsObservable, 60, 3);
        let mut same = Vec::new();
        let mut diff = Vec::new();
        for i in 0..recs.len() {
            for j in (i + 1)..recs.len() {
                let d = crate::metrics::sq_euclidean(&recs[i].text, &recs[j].text) as f64;
                if recs[i].class == recs[j].class {
                    same.push(d);
                } else {
                    diff.push(d);
                }
            }
        }
        if !same.is_empty() && !diff.is_empty() {
            let ms = crate::util::float::mean(&same);
            let md = crate::util::float::mean(&diff);
            assert!(ms < md, "same-class {ms} should be < cross-class {md}");
        }
    }
}
