//! Binary embedding store.
//!
//! Persists [`EmbeddingSet`]s between pipeline stages (extract → reduce →
//! serve) without `serde`: a small versioned little-endian format.
//!
//! Layout: magic `OPDR` | u32 version | u32 label_len | label bytes |
//! u64 n | u64 dim | n·dim f32 payload.

use crate::data::EmbeddingSet;
use crate::error::{OpdrError, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"OPDR";
const VERSION: u32 = 1;

/// Serialize an embedding set to a writer.
pub fn write_embeddings<W: Write>(set: &EmbeddingSet, w: &mut W) -> Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    let label = set.label().as_bytes();
    w.write_all(&(label.len() as u32).to_le_bytes())?;
    w.write_all(label)?;
    w.write_all(&(set.len() as u64).to_le_bytes())?;
    w.write_all(&(set.dim() as u64).to_le_bytes())?;
    for &x in set.data() {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

/// Deserialize an embedding set from a reader.
pub fn read_embeddings<R: Read>(r: &mut R) -> Result<EmbeddingSet> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(OpdrError::data("store: bad magic"));
    }
    let version = read_u32(r)?;
    if version != VERSION {
        return Err(OpdrError::data(format!("store: unsupported version {version}")));
    }
    let label_len = read_u32(r)? as usize;
    if label_len > 1 << 20 {
        return Err(OpdrError::data("store: unreasonable label length"));
    }
    let mut label_bytes = vec![0u8; label_len];
    r.read_exact(&mut label_bytes)?;
    let label = String::from_utf8(label_bytes)
        .map_err(|_| OpdrError::data("store: label not UTF-8"))?;
    let n = read_u64(r)? as usize;
    let dim = read_u64(r)? as usize;
    if dim == 0 {
        return Err(OpdrError::data("store: dim is zero"));
    }
    let count = n
        .checked_mul(dim)
        .ok_or_else(|| OpdrError::data("store: size overflow"))?;
    if count > 1 << 31 {
        return Err(OpdrError::data("store: payload too large"));
    }
    let mut data = Vec::with_capacity(count);
    let mut buf = [0u8; 4];
    for _ in 0..count {
        r.read_exact(&mut buf)?;
        data.push(f32::from_le_bytes(buf));
    }
    EmbeddingSet::new(label, dim, data)
}

/// Save to a file path.
pub fn save(set: &EmbeddingSet, path: impl AsRef<Path>) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write_embeddings(set, &mut f)?;
    f.flush()?;
    Ok(())
}

/// Load from a file path.
pub fn load(path: impl AsRef<Path>) -> Result<EmbeddingSet> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    read_embeddings(&mut f)
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth, DatasetKind};

    #[test]
    fn roundtrip_in_memory() {
        let set = synth::generate(DatasetKind::Esc50, 10, 16, 1);
        let mut buf = Vec::new();
        write_embeddings(&set, &mut buf).unwrap();
        let back = read_embeddings(&mut buf.as_slice()).unwrap();
        assert_eq!(set, back);
    }

    #[test]
    fn roundtrip_via_file() {
        let set = synth::generate(DatasetKind::Flickr30k, 7, 12, 2);
        let dir = std::env::temp_dir().join("opdr_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("set.opdr");
        save(&set, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(set, back);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_corruption() {
        let set = synth::generate(DatasetKind::Flickr30k, 3, 4, 3);
        let mut buf = Vec::new();
        write_embeddings(&set, &mut buf).unwrap();
        // Bad magic.
        let mut bad = buf.clone();
        bad[0] = b'X';
        assert!(read_embeddings(&mut bad.as_slice()).is_err());
        // Bad version.
        let mut bad = buf.clone();
        bad[4] = 99;
        assert!(read_embeddings(&mut bad.as_slice()).is_err());
        // Truncated payload.
        let bad = &buf[..buf.len() - 3];
        assert!(read_embeddings(&mut &bad[..]).is_err());
    }

    #[test]
    fn empty_set_roundtrips() {
        let set = EmbeddingSet::new("empty", 8, vec![]).unwrap();
        let mut buf = Vec::new();
        write_embeddings(&set, &mut buf).unwrap();
        let back = read_embeddings(&mut buf.as_slice()).unwrap();
        assert_eq!(back.len(), 0);
        assert_eq!(back.dim(), 8);
    }
}
