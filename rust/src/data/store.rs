//! Binary embedding + index store.
//!
//! Persists [`EmbeddingSet`]s between pipeline stages (extract → reduce →
//! serve) and ANN index segments (so `BuildReduced`-built graphs and SQ8
//! codebooks survive restarts) without `serde`: a small versioned
//! little-endian format. The version field doubles as the segment type:
//!
//! * version 1 — embedding set: magic `OPDR` | u32 1 | u32 label_len |
//!   label bytes | u64 n | u64 dim | n·dim f32 payload;
//! * version 2 — index segment: magic `OPDR` | u32 2 | u32 index-kind tag |
//!   kind-specific payload (see [`crate::index`]);
//! * version 3 — sharded index: magic `OPDR` | u32 3 | u32 shard count |
//!   per shard a header (u32 kind tag | u8 metric tag | u64 n | u64 dim |
//!   u64 global start row | u64 payload bytes) and the shard's
//!   version-2-style payload (see [`crate::index::shard`]). Every header is
//!   validated against its decoded payload on load (including that the
//!   payload is fully consumed and that start rows are contiguous, so
//!   reordered segment records fail), trailing bytes after the last shard
//!   are rejected (shard-count mismatch), and version-2 single-segment
//!   files keep loading unchanged;
//! * version 4 — delta-augmented index (the record kind added with the
//!   incremental-ingest subsystem, see [`crate::index::delta`]): magic
//!   `OPDR` | u32 4 | u8 sharded flag | the main index's version-2-style
//!   (kind tag + payload) or version-3-style (shard payload) body | a delta
//!   record (u8 metric tag | u64 n | u64 dim | row-major f32 rows). The
//!   delta record is validated against the decoded main (matching metric
//!   and dim, non-empty, fully consumed), and version-2/3 files keep
//!   loading unchanged;
//! * version 5 — cold-tier index (the mmap-servable layout, see
//!   [`crate::data::mapped`] for the byte-level table): magic `OPDR` |
//!   u32 5 | a fixed 64-byte header (annex shape, 64-byte-aligned annex
//!   offset, annex byte length, body length, inner framing) | the index
//!   body (version-2/3/4-style bytes with full-precision vector payloads
//!   replaced by annex start-row references) | zero padding | the
//!   64-byte-aligned, length-prefixed f32 vector annex.
//!   [`load_index`] serves the annex **zero-copy via mmap** (heap fallback
//!   where mapping is unavailable; [`load_index_heap`] forces it), and the
//!   mapped and heap tiers return bit-identical search results. Version
//!   1–4 files keep loading via the heap path unchanged.
//!
//! Index payloads (version 2 and per shard in version 3) embed their vector
//! storage as a tagged record: 0 = flat f32, 1 = SQ8 codebooks + codes,
//! 2 = PQ codebooks + packed codes + optional OPQ rotation + rerank tier
//! (the record kind added with the PQ subsystem — see
//! [`crate::index::pq`]); inside version-5 files only, 3 = PQ with an
//! external rerank tier and 4 = external flat rows (annex references).
//! Tags unknown to a reader fail with a descriptive error, and files
//! written before a tag existed keep loading unchanged.
//!
//! Readers reject the other segment types with a descriptive error instead
//! of misparsing them, reject trailing bytes after any payload, and never
//! hand untrusted length fields to eager allocations (a lying header fails
//! with the typed truncation error instead of aborting on OOM).

use crate::data::mapped::{self, AnnexWriter, ColdContext, VectorFile};
use crate::data::EmbeddingSet;
use crate::error::{OpdrError, Result};
use crate::index::io::{read_bytes, read_u32, read_u64};
use crate::index::AnnIndex;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::Arc;

const MAGIC: &[u8; 4] = b"OPDR";
const VERSION: u32 = 1;
const INDEX_VERSION: u32 = 2;
const SHARDED_INDEX_VERSION: u32 = 3;
const DELTA_INDEX_VERSION: u32 = 4;
const COLD_INDEX_VERSION: u32 = mapped::COLD_VERSION;

/// Serialize an embedding set to a writer.
pub fn write_embeddings<W: Write>(set: &EmbeddingSet, w: &mut W) -> Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    let label = set.label().as_bytes();
    w.write_all(&(label.len() as u32).to_le_bytes())?;
    w.write_all(label)?;
    w.write_all(&(set.len() as u64).to_le_bytes())?;
    w.write_all(&(set.dim() as u64).to_le_bytes())?;
    for &x in set.data() {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

/// Deserialize an embedding set from a reader.
pub fn read_embeddings<R: Read>(r: &mut R) -> Result<EmbeddingSet> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(OpdrError::data("store: bad magic"));
    }
    let version = read_u32(r)?;
    if version == INDEX_VERSION
        || version == SHARDED_INDEX_VERSION
        || version == DELTA_INDEX_VERSION
        || version == COLD_INDEX_VERSION
    {
        return Err(OpdrError::data(
            "store: file holds an index segment, not an embedding set (use load_index)",
        ));
    }
    if version != VERSION {
        return Err(OpdrError::data(format!(
            "store: unsupported version {version} (embedding sets are version {VERSION})"
        )));
    }
    let label_len = read_u32(r)? as usize;
    if label_len > 1 << 20 {
        return Err(OpdrError::data("store: unreasonable label length"));
    }
    // Bounded preallocation (ALLOC_CHUNK contract): the length came off the
    // wire, so the buffer grows only as bytes actually arrive.
    let label_bytes = read_bytes(r, label_len)?;
    let label = String::from_utf8(label_bytes)
        .map_err(|_| OpdrError::data("store: label not UTF-8"))?;
    let n = read_u64(r)? as usize;
    let dim = read_u64(r)? as usize;
    if dim == 0 {
        return Err(OpdrError::data("store: dim is zero"));
    }
    let count = n
        .checked_mul(dim)
        .ok_or_else(|| OpdrError::data("store: size overflow"))?;
    if count > 1 << 31 {
        return Err(OpdrError::data("store: payload too large"));
    }
    // Bounded preallocation: `count` is an untrusted length field, so the
    // vector grows only as bytes actually arrive (a lying header fails
    // with the truncation error instead of aborting on OOM).
    let mut data = Vec::with_capacity(count.min(crate::index::io::ALLOC_CHUNK));
    let mut buf = [0u8; 4];
    for _ in 0..count {
        r.read_exact(&mut buf)?;
        data.push(f32::from_le_bytes(buf));
    }
    reject_trailing(r, "the embedding payload")?;
    EmbeddingSet::new(label, dim, data)
}

/// Declared-count/length mismatches leave payload behind; surface trailing
/// bytes instead of silently dropping rows, shards or whole records.
fn reject_trailing(r: &mut impl Read, what: &str) -> Result<()> {
    let mut probe = [0u8; 1];
    if r.read(&mut probe)? != 0 {
        return Err(OpdrError::data(format!(
            "store: trailing bytes after {what} (count mismatch?)"
        )));
    }
    Ok(())
}

/// Save to a file path.
pub fn save(set: &EmbeddingSet, path: impl AsRef<Path>) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write_embeddings(set, &mut f)?;
    f.flush()?;
    Ok(())
}

/// Load from a file path.
pub fn load(path: impl AsRef<Path>) -> Result<EmbeddingSet> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    read_embeddings(&mut f)
}

/// Serialize an ANN index: delta-augmented indexes become version-4 files,
/// sharded indexes version-3 multi-segment files, everything else the
/// unchanged version-2 single-segment format.
pub fn write_index<W: Write>(index: &dyn AnnIndex, w: &mut W) -> Result<()> {
    w.write_all(MAGIC)?;
    if index.as_delta().is_some() {
        w.write_all(&DELTA_INDEX_VERSION.to_le_bytes())?;
        return index.write_to(w);
    }
    if index.as_sharded().is_some() {
        w.write_all(&SHARDED_INDEX_VERSION.to_le_bytes())?;
        return index.write_to(w);
    }
    w.write_all(&INDEX_VERSION.to_le_bytes())?;
    w.write_all(&index.kind().tag().to_le_bytes())?;
    index.write_to(w)
}

/// Deserialize an ANN index from an `OPDR` version-2 (single-segment),
/// version-3 (sharded), version-4 (delta-augmented) or version-5
/// (cold-tier; heap-decoded — a streaming reader has no file to map) index
/// file.
pub fn read_index<R: Read>(r: &mut R) -> Result<Box<dyn AnnIndex>> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(OpdrError::data("store: bad magic"));
    }
    let version = read_u32(r)?;
    if version == VERSION {
        return Err(OpdrError::data(
            "store: file holds an embedding set, not an index segment (use load)",
        ));
    }
    if version == SHARDED_INDEX_VERSION {
        let index = crate::index::shard::ShardedIndex::read_from(r)?;
        reject_trailing(r, "the last shard")?;
        return Ok(Box::new(index));
    }
    if version == DELTA_INDEX_VERSION {
        let index = crate::index::delta::DeltaIndex::read_from(r)?;
        reject_trailing(r, "the delta record")?;
        return Ok(Box::new(index));
    }
    if version == COLD_INDEX_VERSION {
        // Streaming (pathless) readers cannot mmap; decode the annex to
        // the heap — results are bit-identical to the mapped tier.
        return read_cold_index(r);
    }
    if version != INDEX_VERSION {
        return Err(OpdrError::data(format!(
            "store: unsupported version {version} (index segments are versions \
             {INDEX_VERSION}, {SHARDED_INDEX_VERSION}, {DELTA_INDEX_VERSION} and \
             {COLD_INDEX_VERSION})"
        )));
    }
    let kind_tag = read_u32(r)?;
    let index = crate::index::read_index_payload(kind_tag, r)?;
    reject_trailing(r, "the index payload")?;
    Ok(index)
}

/// Read a version-5 cold index from a streaming reader (magic + version
/// already consumed): header, body bytes, zero padding, annex rows — the
/// annex lands on the heap because a generic reader has no file to map.
fn read_cold_index<R: Read>(r: &mut R) -> Result<Box<dyn AnnIndex>> {
    let header = mapped::ColdHeader::read_after_version(r)?;
    let body = crate::index::io::read_bytes(r, header.body_len)?;
    let mut pad = header.annex_offset - mapped::HEADER_BYTES - header.body_len;
    let mut buf = [0u8; 64];
    while pad > 0 {
        let take = pad.min(buf.len());
        r.read_exact(&mut buf[..take])?;
        if buf[..take].iter().any(|&b| b != 0) {
            return Err(OpdrError::data("store: nonzero padding before the cold annex"));
        }
        pad -= take;
    }
    let rows = crate::index::io::read_f32s(r, header.annex_elems())?;
    reject_trailing(r, "the cold annex")?;
    let file = VectorFile::from_heap(header.annex_n, header.annex_dim, rows)?;
    parse_cold_body(header.inner_version, &body, &ColdContext { file: Arc::new(file) })
}

/// Decode the index body of a version-5 file against its (mapped or heap)
/// annex.
fn parse_cold_body(
    inner_version: u32,
    body: &[u8],
    cx: &ColdContext,
) -> Result<Box<dyn AnnIndex>> {
    let mut r: &[u8] = body;
    let index: Box<dyn AnnIndex> = match inner_version {
        INDEX_VERSION => {
            let kind_tag = read_u32(&mut r)?;
            crate::index::read_index_payload_with(kind_tag, &mut r, Some(cx))?
        }
        SHARDED_INDEX_VERSION => {
            Box::new(crate::index::shard::ShardedIndex::read_with(&mut r, Some(cx))?)
        }
        DELTA_INDEX_VERSION => {
            Box::new(crate::index::delta::DeltaIndex::read_with(&mut r, Some(cx))?)
        }
        0 => {
            return Err(OpdrError::data(
                "store: file holds a bare cold vector annex, not an index segment",
            ))
        }
        other => {
            return Err(OpdrError::data(format!(
                "store: unknown inner body framing {other} in a cold index file"
            )))
        }
    };
    if !r.is_empty() {
        return Err(OpdrError::data(format!(
            "store: {} unconsumed bytes after the cold index body",
            r.len()
        )));
    }
    Ok(index)
}

/// Save an index to a file path.
pub fn save_index(index: &dyn AnnIndex, path: impl AsRef<Path>) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write_index(index, &mut f)?;
    f.flush()?;
    Ok(())
}

/// Serialize an ANN index as a version-5 cold file: the index body keeps
/// its version-2/3/4 framing (recorded in the header), while full-precision
/// vector payloads (flat rows, PQ rerank tiers) move into the
/// 64-byte-aligned annex so [`load_index`] can serve them mmap'd in place.
///
/// Note: the writer currently accumulates the annex in RAM before framing
/// it (the annex offset depends on the finished body), so *saving* peaks at
/// the same footprint as the RAM tier — only *serving* is zero-copy. A
/// streaming writer (spill the annex to a temp file alongside the body,
/// then splice) is a ROADMAP follow-on for collections whose tier exceeds
/// memory.
pub fn write_index_cold<W: Write>(index: &dyn AnnIndex, w: &mut W) -> Result<()> {
    let mut annex = AnnexWriter::new(index.dim());
    let mut body: Vec<u8> = Vec::new();
    let inner_version = if index.as_delta().is_some() {
        DELTA_INDEX_VERSION
    } else if index.as_sharded().is_some() {
        SHARDED_INDEX_VERSION
    } else {
        body.extend_from_slice(&index.kind().tag().to_le_bytes());
        INDEX_VERSION
    };
    index.write_cold(&mut body, &mut annex)?;
    mapped::write_cold_framed(w, inner_version, &body, &annex)
}

/// Save an index as a version-5 cold file (see [`write_index_cold`]).
pub fn save_index_cold(index: &dyn AnnIndex, path: impl AsRef<Path>) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write_index_cold(index, &mut f)?;
    f.flush()?;
    Ok(())
}

/// Load an index from a file path. Version-5 cold files serve their vector
/// annex zero-copy via mmap (heap fallback where mapping is unavailable);
/// version 1–4 files load via the heap path unchanged.
pub fn load_index(path: impl AsRef<Path>) -> Result<Box<dyn AnnIndex>> {
    load_index_impl(path.as_ref(), true)
}

/// [`load_index`] forcing the heap tier for version-5 files (used by the
/// bitwise mmap-vs-heap equivalence tests and by hosts without mmap).
pub fn load_index_heap(path: impl AsRef<Path>) -> Result<Box<dyn AnnIndex>> {
    load_index_impl(path.as_ref(), false)
}

fn load_index_impl(path: &Path, prefer_mmap: bool) -> Result<Box<dyn AnnIndex>> {
    // Peek the magic + version to route cold files through the mapping
    // path; anything else (including short files) takes the streaming
    // reader, which produces the uniform typed errors.
    let is_cold = {
        let mut f = std::fs::File::open(path)?;
        let mut head = [0u8; 8];
        f.read_exact(&mut head).is_ok()
            && &head[..4] == MAGIC
            && u32::from_le_bytes(head[4..8].try_into().unwrap()) == COLD_INDEX_VERSION
    };
    if !is_cold {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        return read_index(&mut f);
    }
    let file = if prefer_mmap { VectorFile::open(path)? } else { VectorFile::open_heap(path)? };
    let header = file.header().clone();
    let mut f = std::fs::File::open(path)?;
    f.seek(SeekFrom::Start(mapped::HEADER_BYTES as u64))?;
    // Bounded preallocation (ALLOC_CHUNK contract): `body_len` is a header
    // field off disk; read_bytes clamps the upfront allocation.
    let body = read_bytes(&mut f, header.body_len)?;
    parse_cold_body(header.inner_version, &body, &ColdContext { file: Arc::new(file) })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth, DatasetKind};

    #[test]
    fn roundtrip_in_memory() {
        let set = synth::generate(DatasetKind::Esc50, 10, 16, 1);
        let mut buf = Vec::new();
        write_embeddings(&set, &mut buf).unwrap();
        let back = read_embeddings(&mut buf.as_slice()).unwrap();
        assert_eq!(set, back);
    }

    #[test]
    fn roundtrip_via_file() {
        let set = synth::generate(DatasetKind::Flickr30k, 7, 12, 2);
        let dir = std::env::temp_dir().join("opdr_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("set.opdr");
        save(&set, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(set, back);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_corruption() {
        let set = synth::generate(DatasetKind::Flickr30k, 3, 4, 3);
        let mut buf = Vec::new();
        write_embeddings(&set, &mut buf).unwrap();
        // Bad magic.
        let mut bad = buf.clone();
        bad[0] = b'X';
        assert!(read_embeddings(&mut bad.as_slice()).is_err());
        // Bad version.
        let mut bad = buf.clone();
        bad[4] = 99;
        assert!(read_embeddings(&mut bad.as_slice()).is_err());
        // Truncated payload.
        let bad = &buf[..buf.len() - 3];
        assert!(read_embeddings(&mut &bad[..]).is_err());
    }

    #[test]
    fn empty_set_roundtrips() {
        let set = EmbeddingSet::new("empty", 8, vec![]).unwrap();
        let mut buf = Vec::new();
        write_embeddings(&set, &mut buf).unwrap();
        let back = read_embeddings(&mut buf.as_slice()).unwrap();
        assert_eq!(back.len(), 0);
        assert_eq!(back.dim(), 8);
    }

    #[test]
    fn truncated_header_rejected_at_every_cut() {
        let set = synth::generate(DatasetKind::Esc50, 2, 4, 1);
        let mut buf = Vec::new();
        write_embeddings(&set, &mut buf).unwrap();
        // Empty file, partial magic, cut inside version, label and counts:
        // every prefix of the header must fail cleanly, never panic.
        for cut in [0usize, 2, 5, 9, 14, 20] {
            assert!(
                read_embeddings(&mut &buf[..cut.min(buf.len())]).is_err(),
                "cut at {cut} accepted"
            );
        }
    }

    #[test]
    fn bad_magic_rejected() {
        let set = synth::generate(DatasetKind::Esc50, 2, 4, 1);
        let mut buf = Vec::new();
        write_embeddings(&set, &mut buf).unwrap();
        let mut bad = buf.clone();
        bad[..4].copy_from_slice(b"NOPE");
        let e = read_embeddings(&mut bad.as_slice()).unwrap_err().to_string();
        assert!(e.contains("bad magic"), "{e}");
    }

    #[test]
    fn unsupported_version_message_names_the_version() {
        let set = synth::generate(DatasetKind::Esc50, 2, 4, 1);
        let mut buf = Vec::new();
        write_embeddings(&set, &mut buf).unwrap();
        buf[4..8].copy_from_slice(&99u32.to_le_bytes());
        let e = read_embeddings(&mut buf.as_slice()).unwrap_err().to_string();
        assert!(e.contains("version 99"), "{e}");
    }

    #[test]
    fn label_roundtrips_including_unicode_and_empty() {
        for label in ["", "plain", "µ-measure/Δdim — 測定"] {
            let set = EmbeddingSet::new(label, 3, vec![0.5; 6]).unwrap();
            let mut buf = Vec::new();
            write_embeddings(&set, &mut buf).unwrap();
            let back = read_embeddings(&mut buf.as_slice()).unwrap();
            assert_eq!(back.label(), label);
        }
        // Invalid UTF-8 in the label region must error, not mangle.
        let set = EmbeddingSet::new("ab", 2, vec![0.0; 4]).unwrap();
        let mut buf = Vec::new();
        write_embeddings(&set, &mut buf).unwrap();
        buf[12] = 0xFF; // first label byte (magic 4 + version 4 + label_len 4)
        let e = read_embeddings(&mut buf.as_slice()).unwrap_err().to_string();
        assert!(e.contains("UTF-8"), "{e}");
    }

    #[test]
    fn index_segment_roundtrips_for_every_kind() {
        use crate::config::IndexPolicy;
        use crate::index::IndexKind;
        let set = synth::generate(DatasetKind::Flickr30k, 120, 12, 7);
        for (kind, sq8) in [
            (IndexKind::Exact, false),
            (IndexKind::Ivf, false),
            (IndexKind::Hnsw, false),
            (IndexKind::Hnsw, true),
        ] {
            let policy = IndexPolicy { kind, exact_threshold: 0, sq8, ..Default::default() };
            let idx = crate::index::build_index(
                set.data(),
                set.dim(),
                crate::metrics::Metric::SqEuclidean,
                &policy,
                3,
            )
            .unwrap();
            let mut buf = Vec::new();
            write_index(idx.as_ref(), &mut buf).unwrap();
            let back = read_index(&mut buf.as_slice()).unwrap();
            assert_eq!(back.kind(), kind);
            assert_eq!(back.len(), idx.len());
            assert_eq!(back.dim(), idx.dim());
            assert_eq!(back.quantized(), sq8);
            let q = set.vector(5);
            let a = idx.search(q, 5).unwrap();
            let b = back.search(q, 5).unwrap();
            crate::testing::assert_same_neighbors(&a, &b);
        }
    }

    #[test]
    fn index_and_embedding_segments_not_confusable() {
        use crate::config::IndexPolicy;
        let set = synth::generate(DatasetKind::Esc50, 30, 6, 2);
        let policy = IndexPolicy { exact_threshold: 0, ..Default::default() };
        let idx = crate::index::build_index(
            set.data(),
            set.dim(),
            crate::metrics::Metric::Euclidean,
            &policy,
            1,
        )
        .unwrap();

        let mut idx_buf = Vec::new();
        write_index(idx.as_ref(), &mut idx_buf).unwrap();
        let e = read_embeddings(&mut idx_buf.as_slice()).unwrap_err().to_string();
        assert!(e.contains("index segment"), "{e}");

        let mut emb_buf = Vec::new();
        write_embeddings(&set, &mut emb_buf).unwrap();
        let e = read_index(&mut emb_buf.as_slice()).unwrap_err().to_string();
        assert!(e.contains("embedding set"), "{e}");
    }

    fn sharded_fixture(shards: usize, sq8: bool) -> (Vec<u8>, crate::data::EmbeddingSet) {
        use crate::config::IndexPolicy;
        let set = synth::generate(DatasetKind::Flickr30k, 90, 10, 17);
        let policy = IndexPolicy {
            exact_threshold: 0,
            shards,
            shard_min_vectors: 1,
            sq8,
            ivf_nlist: 8,
            ivf_nprobe: 8,
            ..Default::default()
        };
        let idx = crate::index::build_index(
            set.data(),
            set.dim(),
            crate::metrics::Metric::SqEuclidean,
            &policy,
            6,
        )
        .unwrap();
        assert_eq!(idx.as_sharded().is_some(), shards > 1);
        let mut buf = Vec::new();
        write_index(idx.as_ref(), &mut buf).unwrap();
        (buf, set)
    }

    #[test]
    fn sharded_index_roundtrips_as_version_3_bit_identical() {
        for sq8 in [false, true] {
            let (buf, set) = sharded_fixture(3, sq8);
            assert_eq!(u32::from_le_bytes(buf[4..8].try_into().unwrap()), 3);
            let back = read_index(&mut buf.as_slice()).unwrap();
            let sh = back.as_sharded().expect("loads as sharded");
            assert_eq!(sh.num_shards(), 3);
            assert_eq!(back.len(), set.len());
            assert_eq!(back.quantized(), sq8);
            // Identical results to a freshly built copy of the same index.
            let rebuilt = read_index(&mut buf.as_slice()).unwrap();
            for qi in [0usize, 7, 42] {
                let a = back.search(set.vector(qi), 6).unwrap();
                let b = rebuilt.search(set.vector(qi), 6).unwrap();
                crate::testing::assert_same_neighbors(&a, &b);
                assert_eq!(a[0].index, qi, "self-hit lost through the store");
            }
        }
    }

    #[test]
    fn version_2_single_segment_files_still_load() {
        // Back-compat: a non-sharded index written before (and after) this
        // format revision is a version-2 file; it must keep loading.
        let (buf, set) = sharded_fixture(1, false);
        assert_eq!(u32::from_le_bytes(buf[4..8].try_into().unwrap()), 2);
        let back = read_index(&mut buf.as_slice()).unwrap();
        assert!(back.as_sharded().is_none());
        assert_eq!(back.len(), set.len());
        assert_eq!(back.search(set.vector(3), 1).unwrap()[0].index, 3);
    }

    #[test]
    fn sharded_corrupt_shard_header_rejected() {
        let (buf, _) = sharded_fixture(2, false);
        // Bytes: magic 4 | version 4 | shard count 4 | first shard kind tag 4.
        let mut bad = buf.clone();
        bad[12..16].copy_from_slice(&77u32.to_le_bytes());
        let e = read_index(&mut bad.as_slice()).unwrap_err().to_string();
        assert!(e.contains("shard 0") && e.contains("kind tag"), "{e}");
        // Zero shard count.
        let mut bad = buf.clone();
        bad[8..12].copy_from_slice(&0u32.to_le_bytes());
        let e = read_index(&mut bad.as_slice()).unwrap_err().to_string();
        assert!(e.contains("zero segment count"), "{e}");
        // Absurd shard count.
        let mut bad = buf.clone();
        bad[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        let e = read_index(&mut bad.as_slice()).unwrap_err().to_string();
        assert!(e.contains("unreasonable segment count"), "{e}");
    }

    #[test]
    fn sharded_truncated_shard_rejected() {
        let (buf, _) = sharded_fixture(2, false);
        // Cut inside the last shard's payload and at several header cuts.
        for cut in [buf.len() - 3, buf.len() / 2, 13, 9] {
            assert!(read_index(&mut &buf[..cut]).is_err(), "cut at {cut} accepted");
        }
    }

    #[test]
    fn sharded_shard_count_mismatch_rejected() {
        let (buf, _) = sharded_fixture(2, false);
        // Declare more shards than the file holds → truncated read.
        let mut more = buf.clone();
        more[8..12].copy_from_slice(&3u32.to_le_bytes());
        let e = read_index(&mut more.as_slice()).unwrap_err().to_string();
        assert!(e.contains("shard"), "{e}");
        // Declare fewer → trailing bytes must be rejected, not silently
        // dropped (that would serve a subset of the collection).
        let mut fewer = buf.clone();
        fewer[8..12].copy_from_slice(&1u32.to_le_bytes());
        let e = read_index(&mut fewer.as_slice()).unwrap_err().to_string();
        assert!(e.contains("trailing bytes"), "{e}");
    }

    #[test]
    fn pq_index_segment_roundtrips_and_corruption_rejected() {
        use crate::config::IndexPolicy;
        use crate::index::IndexKind;
        let set = synth::generate(DatasetKind::Flickr30k, 80, 8, 23);
        for (opq, shards) in [(false, 1), (true, 1), (false, 3)] {
            let policy = IndexPolicy {
                kind: IndexKind::Exact,
                exact_threshold: 0,
                pq: true,
                pq_opq: opq,
                rerank_depth: 80,
                shards,
                shard_min_vectors: 1,
                ..Default::default()
            };
            let idx = crate::index::build_index(
                set.data(),
                set.dim(),
                crate::metrics::Metric::SqEuclidean,
                &policy,
                9,
            )
            .unwrap();
            let mut buf = Vec::new();
            write_index(idx.as_ref(), &mut buf).unwrap();
            let back = read_index(&mut buf.as_slice()).unwrap();
            assert!(back.quantized());
            assert_eq!(back.storage_name(), "pq");
            assert_eq!(back.cold_bytes(), set.data().len() * 4);
            // Search results survive the round-trip bit-for-bit, and at
            // exhaustive rerank depth the self-hit is exact.
            for qi in [0usize, 17, 79] {
                let a = idx.search(set.vector(qi), 5).unwrap();
                let b = back.search(set.vector(qi), 5).unwrap();
                crate::testing::assert_same_neighbors(&a, &b);
                assert_eq!(a[0].index, qi, "self-hit lost (opq={opq} shards={shards})");
            }
            // Truncation anywhere inside the PQ record fails cleanly.
            for cut in [buf.len() - 3, buf.len() / 2, buf.len() / 4] {
                assert!(read_index(&mut &buf[..cut]).is_err(), "cut {cut} accepted");
            }
        }
        // Corrupting a PQ codebook f32 to NaN is caught by the reader. The
        // unsharded flat-exact layout is: magic 4 | version 4 | kind 4 |
        // metric 1 | storage tag 1 | 5×u64 pq header | rotation flag 1 |
        // codebooks...
        let policy = IndexPolicy {
            kind: IndexKind::Exact,
            exact_threshold: 0,
            pq: true,
            ..Default::default()
        };
        let idx = crate::index::build_index(
            set.data(),
            set.dim(),
            crate::metrics::Metric::SqEuclidean,
            &policy,
            9,
        )
        .unwrap();
        let mut buf = Vec::new();
        write_index(idx.as_ref(), &mut buf).unwrap();
        let cb_off = 4 + 4 + 4 + 1 + 1 + 40 + 1;
        let mut bad = buf.clone();
        bad[cb_off..cb_off + 4].copy_from_slice(&f32::NAN.to_le_bytes());
        let e = read_index(&mut bad.as_slice()).unwrap_err().to_string();
        assert!(e.contains("codebook"), "{e}");
    }

    fn delta_fixture(shards: usize) -> (Vec<u8>, crate::data::EmbeddingSet) {
        use crate::config::IndexPolicy;
        use crate::index::DeltaIndex;
        use std::sync::Arc;
        let set = synth::generate(DatasetKind::Flickr30k, 60, 8, 29);
        let policy = IndexPolicy {
            exact_threshold: 0,
            shards,
            shard_min_vectors: 1,
            ivf_nlist: 8,
            ivf_nprobe: 8,
            ..Default::default()
        };
        let main = crate::index::build_index(
            &set.data()[..48 * 8],
            8,
            crate::metrics::Metric::SqEuclidean,
            &policy,
            6,
        )
        .unwrap();
        let idx =
            DeltaIndex::from_parts(Arc::from(main), set.data()[48 * 8..].to_vec()).unwrap();
        let mut buf = Vec::new();
        write_index(&idx, &mut buf).unwrap();
        (buf, set)
    }

    #[test]
    fn delta_index_roundtrips_as_version_4_bit_identical() {
        for shards in [1usize, 3] {
            let (buf, set) = delta_fixture(shards);
            assert_eq!(u32::from_le_bytes(buf[4..8].try_into().unwrap()), 4);
            let back = read_index(&mut buf.as_slice()).unwrap();
            let d = back.as_delta().expect("loads as a delta wrapper");
            assert_eq!(d.main_len(), 48);
            assert_eq!(d.delta_len(), 12);
            assert_eq!(back.len(), set.len());
            // Delta rows (including rows past the main) survive bit-exactly.
            assert!(back.matches_data(set.data()));
            for qi in [0usize, 47, 48, 59] {
                let hits = back.search(set.vector(qi), 5).unwrap();
                assert_eq!(hits[0].index, qi, "self-hit lost through the store");
            }
        }
    }

    #[test]
    fn delta_index_corruption_rejected() {
        let (buf, _) = delta_fixture(1);
        // Truncation anywhere fails cleanly.
        for cut in [buf.len() - 3, buf.len() / 2, 9, 8] {
            assert!(read_index(&mut &buf[..cut]).is_err(), "cut at {cut} accepted");
        }
        // Trailing bytes after the delta record are rejected.
        let mut more = buf.clone();
        more.extend_from_slice(&[0xAB; 4]);
        let e = read_index(&mut more.as_slice()).unwrap_err().to_string();
        assert!(e.contains("trailing bytes"), "{e}");
        // Bad main layout flag (byte 8, right after magic + version).
        let mut bad = buf.clone();
        bad[8] = 9;
        let e = read_index(&mut bad.as_slice()).unwrap_err().to_string();
        assert!(e.contains("layout flag"), "{e}");
        // A version-4 file is not confusable with an embedding set.
        let e = read_embeddings(&mut buf.as_slice()).unwrap_err().to_string();
        assert!(e.contains("index segment"), "{e}");
    }

    fn cold_fixture(pq: bool, shards: usize, delta: bool) -> (Box<dyn AnnIndex>, EmbeddingSet) {
        use crate::config::IndexPolicy;
        use crate::index::DeltaIndex;
        use std::sync::Arc;
        let set = synth::generate(DatasetKind::Flickr30k, 72, 8, 31);
        let policy = IndexPolicy {
            kind: crate::index::IndexKind::Exact,
            exact_threshold: 0,
            pq,
            rerank_depth: 80,
            shards,
            shard_min_vectors: 1,
            ..Default::default()
        };
        let main_rows = if delta { 60 } else { 72 };
        let main = crate::index::build_index(
            &set.data()[..main_rows * 8],
            8,
            crate::metrics::Metric::SqEuclidean,
            &policy,
            13,
        )
        .unwrap();
        let idx: Box<dyn AnnIndex> = if delta {
            Box::new(
                DeltaIndex::from_parts(Arc::from(main), set.data()[main_rows * 8..].to_vec())
                    .unwrap(),
            )
        } else {
            main
        };
        (idx, set)
    }

    #[test]
    fn cold_v5_roundtrips_mmap_and_heap_bitwise() {
        let dir = std::env::temp_dir().join(format!("opdr_store_v5_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let cases =
            [(true, 1, false), (false, 1, false), (true, 3, false), (true, 1, true)];
        for (pq, shards, delta) in cases {
            let (idx, set) = cold_fixture(pq, shards, delta);
            let path = dir.join(format!("v5-{pq}-{shards}-{delta}.opdx"));
            save_index_cold(idx.as_ref(), &path).unwrap();
            // Declared as version 5 on disk.
            let raw = std::fs::read(&path).unwrap();
            assert_eq!(u32::from_le_bytes(raw[4..8].try_into().unwrap()), 5);
            let via_mmap = load_index(&path).unwrap();
            let via_heap = load_index_heap(&path).unwrap();
            assert_eq!(via_mmap.len(), idx.len());
            assert_eq!(via_heap.len(), idx.len());
            assert!(via_mmap.matches_data(set.data()), "mapped rows must be bitwise");
            assert!(via_heap.matches_data(set.data()));
            assert_eq!(via_heap.mapped_bytes(), 0, "forced heap load maps nothing");
            if pq {
                // The cold tier covers the PQ main's rows (a delta wrapper
                // keeps its write buffer inline and out of the tier).
                let main_rows = if delta { 60 } else { 72 };
                assert_eq!(via_mmap.cold_bytes(), main_rows * 8 * 4);
            }
            // Mapped, heap-loaded and original indexes search bitwise
            // identically (pq={pq} shards={shards} delta={delta}).
            for qi in [0usize, 35, 71] {
                let a = idx.search(set.vector(qi), 6).unwrap();
                let b = via_mmap.search(set.vector(qi), 6).unwrap();
                let c = via_heap.search(set.vector(qi), 6).unwrap();
                crate::testing::assert_same_neighbors(&a, &b);
                crate::testing::assert_same_neighbors(&a, &c);
            }
            // The streaming reader (no path to map) decodes it too.
            let via_stream = read_index(&mut raw.as_slice()).unwrap();
            let a = idx.search(set.vector(7), 5).unwrap();
            let b = via_stream.search(set.vector(7), 5).unwrap();
            crate::testing::assert_same_neighbors(&a, &b);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cold_v5_corruption_rejected() {
        let dir = std::env::temp_dir().join(format!("opdr_store_v5c_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let (idx, _) = cold_fixture(true, 1, false);
        let path = dir.join("v5.opdx");
        save_index_cold(idx.as_ref(), &path).unwrap();
        let good = std::fs::read(&path).unwrap();
        let try_load = |bytes: &[u8]| -> Result<Box<dyn AnnIndex>> {
            let bad = dir.join("bad.opdx");
            std::fs::write(&bad, bytes).unwrap();
            let mapped = load_index(&bad);
            let heap = load_index_heap(&bad);
            let streamed = read_index(&mut &bytes[..]);
            assert_eq!(mapped.is_err(), heap.is_err());
            assert_eq!(mapped.is_err(), streamed.is_err());
            mapped
        };
        // Truncation at several cuts (header, body, annex).
        for cut in [8usize, 40, 63, good.len() / 2, good.len() - 3] {
            assert!(try_load(&good[..cut]).is_err(), "cut at {cut} accepted");
        }
        // Trailing bytes after the annex.
        let mut more = good.clone();
        more.extend_from_slice(&[0xAB; 3]);
        assert!(try_load(&more).is_err());
        // Nonzero padding between body and annex (the header pins the
        // aligned offset, so padding bytes are load-bearing zeros).
        let body_len =
            u64::from_le_bytes(good[40..48].try_into().unwrap()) as usize;
        let annex_off = u64::from_le_bytes(good[24..32].try_into().unwrap()) as usize;
        if annex_off > 64 + body_len {
            let mut bad = good.clone();
            bad[annex_off - 1] = 7;
            assert!(try_load(&bad).is_err(), "nonzero padding accepted");
        }
        // A bare annex (no body) is a vector file, not an index.
        let rows = vec![0.5f32; 32];
        let bare = dir.join("bare.opdr");
        crate::data::mapped::write_cold_file(&bare, &rows, 4).unwrap();
        let e = load_index(&bare).unwrap_err().to_string();
        assert!(e.contains("bare cold vector annex"), "{e}");
        // And a v5 file is not confusable with an embedding set.
        let e = read_embeddings(&mut good.as_slice()).unwrap_err().to_string();
        assert!(e.contains("index segment"), "{e}");
        // An absurd annex reference inside the body is range-checked: flip
        // the external start row (last 8 body bytes of the pq record) to a
        // huge value. The body layout ends with the u64 start row.
        let mut bad = good.clone();
        bad[64 + body_len - 8..64 + body_len].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(try_load(&bad).is_err(), "absurd annex start row accepted");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn trailing_bytes_rejected_for_v1_and_v2() {
        // Hardening sweep: v3/v4 already rejected trailing bytes; v1
        // embedding sets and v2 single-segment indexes now do too.
        let set = synth::generate(DatasetKind::Esc50, 5, 4, 9);
        let mut buf = Vec::new();
        write_embeddings(&set, &mut buf).unwrap();
        buf.push(0xCD);
        let e = read_embeddings(&mut buf.as_slice()).unwrap_err().to_string();
        assert!(e.contains("trailing bytes"), "{e}");

        let (buf, _) = sharded_fixture(1, false);
        let mut bad = buf.clone();
        bad.extend_from_slice(&[0xCD; 2]);
        let e = read_index(&mut bad.as_slice()).unwrap_err().to_string();
        assert!(e.contains("trailing bytes"), "{e}");
    }

    #[test]
    fn absurd_length_fields_fail_without_huge_allocation() {
        // Hardening sweep: length fields from corrupt/hostile files used to
        // be fed to eager allocations unchecked; a lying header must fail
        // with the typed truncation/corruption error, never abort on OOM.
        // Each case patches one length field to an absurd-but-under-cap
        // value over a tiny file.
        let big = (1u64 << 29).to_le_bytes(); // 2^29 elements, well under MAX_ELEMS

        // v1 embedding set: n field (magic 4 | version 4 | label_len 4 |
        // label .. | n 8 | dim 8).
        let set = EmbeddingSet::new("ab", 4, vec![0.0; 8]).unwrap();
        let mut buf = Vec::new();
        write_embeddings(&set, &mut buf).unwrap();
        let n_off = 4 + 4 + 4 + 2;
        buf[n_off..n_off + 8].copy_from_slice(&big);
        assert!(read_embeddings(&mut buf.as_slice()).is_err());

        // v2 flat exact index: n field of the flat record (magic 4 |
        // version 4 | kind 4 | metric 1 | storage tag 1 | n 8 | dim 8).
        let (idx, _) = cold_fixture(false, 1, false);
        let mut buf = Vec::new();
        write_index(idx.as_ref(), &mut buf).unwrap();
        buf[14..22].copy_from_slice(&big);
        assert!(read_index(&mut buf.as_slice()).is_err());

        // v2 pq index: n field of the pq record (same prefix).
        let (idx, _) = cold_fixture(true, 1, false);
        let mut buf = Vec::new();
        write_index(idx.as_ref(), &mut buf).unwrap();
        buf[14..22].copy_from_slice(&big);
        assert!(read_index(&mut buf.as_slice()).is_err());

        // v3 sharded: first shard's payload length (magic 4 | version 4 |
        // count 4 | kind 4 | metric 1 | n 8 | dim 8 | start 8 | len 8).
        let (buf, _) = sharded_fixture(2, false);
        let mut bad = buf.clone();
        bad[41..49].copy_from_slice(&big);
        assert!(read_index(&mut bad.as_slice()).is_err());

        // v4 delta: the delta record's row count (last 16 + rows bytes; patch
        // via the known tail layout: metric 1 | n 8 | dim 8 | rows).
        let (buf, _) = delta_fixture(1);
        let rows_bytes = 12 * 8 * 4; // delta_fixture appends 12 rows of dim 8
        let n_off = buf.len() - rows_bytes - 16;
        let mut bad = buf.clone();
        bad[n_off..n_off + 8].copy_from_slice(&big);
        assert!(read_index(&mut bad.as_slice()).is_err());

        // v5 cold: body length field (offset 40) inflated past the file.
        let (idx, _) = cold_fixture(true, 1, false);
        let mut buf = Vec::new();
        write_index_cold(idx.as_ref(), &mut buf).unwrap();
        let mut bad = buf.clone();
        bad[40..48].copy_from_slice(&big);
        assert!(read_index(&mut bad.as_slice()).is_err());
    }

    #[test]
    fn index_file_save_load() {
        use crate::config::IndexPolicy;
        let set = synth::generate(DatasetKind::Flickr30k, 50, 8, 4);
        let policy = IndexPolicy { exact_threshold: 0, ..Default::default() };
        let idx = crate::index::build_index(
            set.data(),
            set.dim(),
            crate::metrics::Metric::SqEuclidean,
            &policy,
            2,
        )
        .unwrap();
        let dir = std::env::temp_dir().join(format!("opdr_idx_store_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.opdx");
        save_index(idx.as_ref(), &path).unwrap();
        let back = load_index(&path).unwrap();
        assert_eq!(back.len(), 50);
        std::fs::remove_dir_all(&dir).ok();
    }
}
