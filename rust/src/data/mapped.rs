//! Mmap-backed on-disk vector tier (the version-5 `OPDR` cold layout).
//!
//! PR 3's PQ subsystem banished the full-precision rerank rows to a
//! separately accounted "cold tier" — but kept them in RAM, capping
//! collection size at physical memory. This module is the missing half of
//! the DiskANN / Lucene-HNSW-codec pattern: quantized codes stay hot in
//! RAM, while full-precision vectors are served **zero-copy from a
//! page-aligned read-only file mapping** of an alignment-aware on-disk
//! layout.
//!
//! ## The version-5 cold layout
//!
//! A version-5 `OPDR` file is a fixed 64-byte header, an optional index
//! *body* (the familiar version-2/3/4 index bytes with full-precision
//! payloads externalized), zero padding, and a 64-byte-aligned,
//! length-prefixed **vector annex** holding the externalized rows:
//!
//! | offset | bytes | field |
//! |-------:|------:|-------|
//! |      0 |     4 | magic `OPDR` |
//! |      4 |     4 | u32 version = 5 |
//! |      8 |     8 | u64 annex row count `n` |
//! |     16 |     8 | u64 annex row dimensionality `dim` |
//! |     24 |     8 | u64 annex offset (absolute, 64-byte aligned) |
//! |     32 |     8 | u64 annex byte length (= `n·dim·4`, the length prefix) |
//! |     40 |     8 | u64 body byte length (0 = bare vector annex) |
//! |     48 |     4 | u32 inner body framing (0, or 2/3/4 like the store) |
//! |     52 |    12 | reserved, must be zero |
//! |     64 |     … | body, zero padding to the annex offset, annex rows |
//!
//! Because the annex is 64-byte aligned, little-endian and length-prefixed,
//! a reader can map the file and serve `row(id)` **in place** — no decode,
//! no copy, no resident footprint beyond the pages actually touched. The
//! header is validated against the real file length before any row is
//! served, so a truncated or trailing-byte-corrupted file fails loudly at
//! open instead of faulting mid-query.
//!
//! ## Pieces
//!
//! * [`VectorFile`] — a safe view over one cold file: validated header,
//!   bounds-checked `row(id) -> &[f32]`, and a graceful heap fallback on
//!   platforms/filesystems where `mmap` fails (or on big-endian targets,
//!   where in-place serving would misread the little-endian payload).
//! * [`RowBlock`] — the row-serving abstraction index storage builds on:
//!   RAM-resident rows or a `(file, start)` window into a [`VectorFile`].
//!   [`crate::index::VectorStore`] flat payloads and
//!   [`crate::index::PqStorage`] rerank tiers hold one of these, so the
//!   whole substrate matrix serves from either tier transparently.
//! * [`AnnexWriter`] / [`ColdContext`] — the serialization plumbing: a
//!   writer accumulates externalized rows while the index body serializes
//!   (each record keeps only a `u64` start row), and the context resolves
//!   those references back to [`RowBlock`]s at load time.
//!
//! Build-time spill files ([`VectorFile::spill`], used when
//! `[serve] cold_tier = "mmap"` is configured) are unlinked when the last
//! index referencing them drops, so a compaction's atomic swap cleans up
//! the previous generation's tier automatically. Files loaded explicitly
//! from disk are never deleted.
//!
//! Safety: the mapping is read-only and private; [`VectorFile`] is `Sync`
//! because no interior mutation exists. The one hazard mmap cannot rule
//! out is another process truncating the file underneath a live mapping
//! (SIGBUS on fault) — the cold tier directory is owned by the serving
//! process, and the length check at open rejects files that are already
//! short.

use crate::error::{OpdrError, Result};
use crate::index::io;
use std::fs::File;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// The `OPDR` version tag of the cold layout.
pub const COLD_VERSION: u32 = 5;

/// Fixed header size; also the smallest valid annex offset.
pub const HEADER_BYTES: usize = 64;

/// Alignment of the vector annex (one cache line; a superset of the 4-byte
/// `f32` alignment the in-place cast requires).
pub const ANNEX_ALIGN: usize = 64;

/// Round `x` up to the annex alignment (None on overflow — only reachable
/// from hostile headers).
fn align64(x: usize) -> Option<usize> {
    x.checked_add(ANNEX_ALIGN - 1).map(|v| v & !(ANNEX_ALIGN - 1))
}

/// Parsed + validated version-5 header.
#[derive(Debug, Clone, PartialEq, Eq)]
pub(crate) struct ColdHeader {
    /// Annex rows.
    pub annex_n: usize,
    /// Annex row dimensionality (0 iff the annex is empty).
    pub annex_dim: usize,
    /// Absolute, 64-byte-aligned file offset of the annex.
    pub annex_offset: usize,
    /// Annex byte length (`annex_n * annex_dim * 4`).
    pub annex_bytes: usize,
    /// Index body length in bytes (0 = bare vector annex).
    pub body_len: usize,
    /// Framing of the body: 0 (none) or the store's 2/3/4.
    pub inner_version: u32,
}

impl ColdHeader {
    /// Assemble a header for `body_len` body bytes and an annex of
    /// `n × dim` rows.
    pub(crate) fn new(
        n: usize,
        dim: usize,
        body_len: usize,
        inner_version: u32,
    ) -> Result<ColdHeader> {
        let annex_offset = align64(HEADER_BYTES + body_len)
            .ok_or_else(|| OpdrError::data("cold store: body too large"))?;
        let dim = if n == 0 { 0 } else { dim };
        let header = ColdHeader {
            annex_n: n,
            annex_dim: dim,
            annex_offset,
            annex_bytes: n * dim * 4,
            body_len,
            inner_version,
        };
        header.validate()?;
        Ok(header)
    }

    /// Serialize (including magic + version).
    pub(crate) fn write(&self, w: &mut dyn Write) -> Result<()> {
        w.write_all(b"OPDR")?;
        w.write_all(&COLD_VERSION.to_le_bytes())?;
        w.write_all(&(self.annex_n as u64).to_le_bytes())?;
        w.write_all(&(self.annex_dim as u64).to_le_bytes())?;
        w.write_all(&(self.annex_offset as u64).to_le_bytes())?;
        w.write_all(&(self.annex_bytes as u64).to_le_bytes())?;
        w.write_all(&(self.body_len as u64).to_le_bytes())?;
        w.write_all(&self.inner_version.to_le_bytes())?;
        w.write_all(&[0u8; 12])?;
        Ok(())
    }

    /// Parse the header fields that follow the magic + version prefix
    /// (which dispatching readers have already consumed), validating every
    /// structural invariant.
    pub(crate) fn read_after_version(r: &mut dyn Read) -> Result<ColdHeader> {
        let annex_n = io::read_u64_usize(r)?;
        let annex_dim = io::read_u64_usize(r)?;
        let annex_offset = io::read_u64_usize(r)?;
        let annex_bytes = io::read_u64_usize(r)?;
        let body_len = io::read_u64_usize(r)?;
        let inner_version = io::read_u32(r)?;
        let mut reserved = [0u8; 12];
        r.read_exact(&mut reserved)?;
        if reserved != [0u8; 12] {
            return Err(OpdrError::data("cold store: nonzero reserved header bytes"));
        }
        let header =
            ColdHeader { annex_n, annex_dim, annex_offset, annex_bytes, body_len, inner_version };
        header.validate()?;
        Ok(header)
    }

    /// Structural invariants: shape consistency, the length prefix, the
    /// 64-byte alignment and a recognized inner framing.
    fn validate(&self) -> Result<()> {
        if (self.annex_n == 0) != (self.annex_dim == 0) {
            return Err(OpdrError::data("cold store: corrupt annex shape"));
        }
        let elems = self
            .annex_n
            .checked_mul(self.annex_dim)
            .ok_or_else(|| OpdrError::data("cold store: annex size overflow"))?;
        let bytes = elems
            .checked_mul(4)
            .ok_or_else(|| OpdrError::data("cold store: annex size overflow"))?;
        if bytes != self.annex_bytes {
            return Err(OpdrError::data(format!(
                "cold store: annex length prefix {} != {} x {} rows",
                self.annex_bytes, self.annex_n, self.annex_dim
            )));
        }
        let expected_offset = HEADER_BYTES
            .checked_add(self.body_len)
            .and_then(align64)
            .ok_or_else(|| OpdrError::data("cold store: body length overflow"))?;
        if self.annex_offset != expected_offset {
            return Err(OpdrError::data(format!(
                "cold store: annex offset {} is not the aligned end of the body \
                 (expected {expected_offset})",
                self.annex_offset
            )));
        }
        match self.inner_version {
            0 if self.body_len == 0 => Ok(()),
            2 | 3 | 4 if self.body_len > 0 => Ok(()),
            other => Err(OpdrError::data(format!(
                "cold store: inner body framing {other} does not match body length {}",
                self.body_len
            ))),
        }
    }

    /// Annex element count (validated against overflow).
    pub(crate) fn annex_elems(&self) -> usize {
        self.annex_n * self.annex_dim
    }
}

// ---------------------------------------------------------------------------
// The raw mapping (unix-only; everything else falls back to heap reads).
// ---------------------------------------------------------------------------

#[cfg(all(unix, target_pointer_width = "64", target_endian = "little"))]
mod sys {
    use std::os::raw::{c_int, c_void};
    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
    pub const PROT_READ: c_int = 0x1;
    pub const MAP_PRIVATE: c_int = 0x02;
}

/// A whole-file read-only mapping. Page-aligned by construction (`mmap`
/// returns page-aligned addresses), so the 64-byte-aligned annex offset
/// keeps every row 4-byte aligned for the in-place `f32` cast.
#[derive(Debug)]
struct Map {
    ptr: *mut u8,
    len: usize,
}

// SAFETY: the mapping is PROT_READ/MAP_PRIVATE — immutable shared state
// with no interior mutability, so concurrent reads from many threads are
// sound.
unsafe impl Send for Map {}
unsafe impl Sync for Map {}

impl Map {
    /// Map `len` bytes of `f` read-only, or None when the platform or the
    /// filesystem refuses (the caller falls back to heap reads).
    #[cfg(all(unix, target_pointer_width = "64", target_endian = "little"))]
    fn try_map(f: &File, len: usize) -> Option<Map> {
        use std::os::unix::io::AsRawFd;
        if len == 0 {
            return None;
        }
        // SAFETY: mapping an owned, open fd read-only; the result is
        // checked against MAP_FAILED before use.
        let ptr = unsafe {
            sys::mmap(std::ptr::null_mut(), len, sys::PROT_READ, sys::MAP_PRIVATE, f.as_raw_fd(), 0)
        };
        if ptr.is_null() || ptr as isize == -1 {
            return None;
        }
        Some(Map { ptr: ptr as *mut u8, len })
    }

    #[cfg(not(all(unix, target_pointer_width = "64", target_endian = "little")))]
    fn try_map(_f: &File, _len: usize) -> Option<Map> {
        None
    }
}

impl Drop for Map {
    fn drop(&mut self) {
        unmap(self.ptr, self.len);
    }
}

#[cfg(all(unix, target_pointer_width = "64", target_endian = "little"))]
fn unmap(ptr: *mut u8, len: usize) {
    // SAFETY: `ptr`/`len` came from a successful mmap and are unmapped
    // exactly once (Drop).
    unsafe {
        sys::munmap(ptr as *mut std::os::raw::c_void, len);
    }
}

#[cfg(not(all(unix, target_pointer_width = "64", target_endian = "little")))]
fn unmap(_ptr: *mut u8, _len: usize) {}

#[derive(Debug)]
enum Backing {
    /// Zero-copy whole-file mapping; rows served in place.
    Mapped(Map),
    /// Heap fallback: the annex decoded into RAM (mmap refused, heap load
    /// requested, or a big-endian host).
    Heap(Vec<f32>),
}

// ---------------------------------------------------------------------------
// VectorFile: the safe view.
// ---------------------------------------------------------------------------

/// Distinct names for build-time spill files (many segments spill in
/// parallel into one cold directory).
static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

/// A validated, read-only view over one version-5 cold file's vector
/// annex: `row(id)` serves 64-byte-aligned `f32` rows zero-copy from the
/// mapping (or from the heap fallback when mapping is unavailable).
#[derive(Debug)]
pub struct VectorFile {
    header: ColdHeader,
    backing: Backing,
    /// Set for build-time spill files: remove the file when the last index
    /// referencing it drops (a compaction swap cleans up the old tier).
    unlink: Option<PathBuf>,
}

impl VectorFile {
    /// Open a cold file, preferring a zero-copy mapping and falling back
    /// to a heap read where mapping is unavailable.
    pub fn open(path: impl AsRef<Path>) -> Result<VectorFile> {
        VectorFile::open_with(path.as_ref(), true)
    }

    /// Open a cold file forcing the heap path (used by the exactness tests
    /// and by hosts without a usable mmap).
    pub fn open_heap(path: impl AsRef<Path>) -> Result<VectorFile> {
        VectorFile::open_with(path.as_ref(), false)
    }

    fn open_with(path: &Path, prefer_mmap: bool) -> Result<VectorFile> {
        let mut f = File::open(path)?;
        let mut head = [0u8; 8];
        f.read_exact(&mut head)?;
        if &head[..4] != b"OPDR" {
            return Err(OpdrError::data("cold store: bad magic"));
        }
        let version = u32::from_le_bytes(head[4..8].try_into().unwrap());
        if version != COLD_VERSION {
            return Err(OpdrError::data(format!(
                "cold store: version {version} is not the cold layout ({COLD_VERSION})"
            )));
        }
        let header = ColdHeader::read_after_version(&mut f)?;
        let file_len = f.metadata()?.len();
        let expected = (header.annex_offset as u64)
            .checked_add(header.annex_bytes as u64)
            .ok_or_else(|| OpdrError::data("cold store: file length overflow"))?;
        if file_len != expected {
            return Err(OpdrError::data(format!(
                "cold store: file is {file_len} bytes but the header declares {expected} \
                 (truncated or trailing bytes)"
            )));
        }
        // The padding between body and annex is load-bearing zeros (the
        // header pins the aligned offset); anything else is corruption.
        let pad = header.annex_offset - HEADER_BYTES - header.body_len;
        if pad > 0 {
            let mut buf = [0u8; ANNEX_ALIGN];
            f.seek(SeekFrom::Start((HEADER_BYTES + header.body_len) as u64))?;
            f.read_exact(&mut buf[..pad])?;
            if buf[..pad].iter().any(|&b| b != 0) {
                return Err(OpdrError::data("cold store: nonzero padding before the annex"));
            }
        }
        let backing = if header.annex_bytes == 0 {
            Backing::Heap(Vec::new())
        } else if prefer_mmap {
            match Map::try_map(&f, file_len as usize) {
                Some(m) => Backing::Mapped(m),
                None => Backing::Heap(read_annex(&mut f, &header)?),
            }
        } else {
            Backing::Heap(read_annex(&mut f, &header)?)
        };
        Ok(VectorFile { header, backing, unlink: None })
    }

    /// A purely in-memory vector file (the streaming heap path of
    /// [`crate::data::store::read_index`], which has no path to map).
    pub(crate) fn from_heap(n: usize, dim: usize, rows: Vec<f32>) -> Result<VectorFile> {
        if n.checked_mul(dim) != Some(rows.len()) {
            return Err(OpdrError::shape("cold store: heap annex shape mismatch"));
        }
        let header = ColdHeader::new(n, dim, 0, 0)?;
        Ok(VectorFile { header, backing: Backing::Heap(rows), unlink: None })
    }

    /// Spill `rows` to a fresh bare-annex cold file under `dir` and open
    /// it (mapped where possible). The file is unlinked when the returned
    /// view drops — spill files live exactly as long as the index tier
    /// built over them.
    pub fn spill(dir: &Path, rows: &[f32], dim: usize) -> Result<VectorFile> {
        std::fs::create_dir_all(dir)?;
        // ORDERING: Relaxed — the sequence only needs per-process
        // uniqueness (fetch_add is atomic at any ordering); no other
        // memory is published through the file-name counter.
        let path = dir.join(format!(
            "cold-{}-{}.opdr",
            std::process::id(),
            SPILL_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        write_cold_file(&path, rows, dim)?;
        let mut vf = match VectorFile::open(&path) {
            Ok(vf) => vf,
            Err(e) => {
                let _ = std::fs::remove_file(&path);
                return Err(e);
            }
        };
        vf.unlink = Some(path);
        Ok(vf)
    }

    /// Annex rows.
    pub fn n(&self) -> usize {
        self.header.annex_n
    }

    /// Annex row dimensionality.
    pub fn dim(&self) -> usize {
        self.header.annex_dim
    }

    /// True when rows are served zero-copy from the mapping.
    pub fn is_mapped(&self) -> bool {
        matches!(self.backing, Backing::Mapped(_))
    }

    /// Annex bytes served from the mapping (0 on the heap fallback).
    pub fn mapped_bytes(&self) -> usize {
        if self.is_mapped() {
            self.header.annex_bytes
        } else {
            0
        }
    }

    /// Annex bytes resident in RAM (the heap fallback; 0 when mapped).
    pub fn resident_bytes(&self) -> usize {
        if self.is_mapped() {
            0
        } else {
            self.header.annex_bytes
        }
    }

    /// Row `id` of the annex. Bounds-checked: an out-of-range id panics
    /// with a descriptive message (same contract as slice indexing; every
    /// deserialized reference is range-validated before rows are served).
    pub fn row(&self, id: usize) -> &[f32] {
        assert!(
            id < self.header.annex_n,
            "VectorFile::row: id {id} out of bounds (annex holds {} rows)",
            self.header.annex_n
        );
        let dim = self.header.annex_dim;
        match &self.backing {
            Backing::Heap(v) => &v[id * dim..(id + 1) * dim],
            Backing::Mapped(m) => {
                let off = self.header.annex_offset + id * dim * 4;
                debug_assert!(off + dim * 4 <= m.len);
                // SAFETY: the open-time length check pins
                // annex_offset + annex_bytes == mapping length, `id` is in
                // range, and the 64-byte-aligned annex keeps every row
                // 4-byte aligned; the mapping is immutable for `&self`.
                unsafe { std::slice::from_raw_parts(m.ptr.add(off) as *const f32, dim) }
            }
        }
    }

    /// The parsed header (store-internal: body framing + length).
    pub(crate) fn header(&self) -> &ColdHeader {
        &self.header
    }
}

impl Drop for VectorFile {
    fn drop(&mut self) {
        if let Some(path) = self.unlink.take() {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// Read the annex into a heap vector (seek + buffered little-endian
/// decode); the fallback serving tier.
fn read_annex(f: &mut File, header: &ColdHeader) -> Result<Vec<f32>> {
    f.seek(SeekFrom::Start(header.annex_offset as u64))?;
    let mut br = std::io::BufReader::with_capacity(1 << 20, f);
    io::read_f32s(&mut br, header.annex_elems())
}

/// Write a bare vector annex (no index body) — the build-time spill format.
pub fn write_cold_file(path: &Path, rows: &[f32], dim: usize) -> Result<()> {
    if dim == 0 || rows.len() % dim != 0 {
        return Err(OpdrError::shape("cold store: bad spill shape"));
    }
    let header = ColdHeader::new(rows.len() / dim, dim, 0, 0)?;
    let mut w = std::io::BufWriter::new(File::create(path)?);
    header.write(&mut w)?;
    io::write_f32s(&mut w, rows)?;
    w.flush()?;
    Ok(())
}

/// Frame an already-serialized cold index body + its annex as a version-5
/// file: header, body, zero padding to the aligned annex offset, rows.
pub(crate) fn write_cold_framed(
    w: &mut dyn Write,
    inner_version: u32,
    body: &[u8],
    annex: &AnnexWriter,
) -> Result<()> {
    let header = ColdHeader::new(annex.n_rows(), annex.dim, body.len(), inner_version)?;
    header.write(w)?;
    w.write_all(body)?;
    let pad = header.annex_offset - HEADER_BYTES - body.len();
    // lint:allow(bounded-prealloc: write path; pad < ALIGN by construction, not wire data)
    w.write_all(&vec![0u8; pad])?;
    io::write_f32s(w, &annex.rows)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Serialization plumbing: annex accumulation + reference resolution.
// ---------------------------------------------------------------------------

/// Accumulates rows externalized while an index body serializes into the
/// version-5 layout; each externalized payload keeps only its start row.
#[derive(Debug)]
pub struct AnnexWriter {
    dim: usize,
    rows: Vec<f32>,
}

impl AnnexWriter {
    /// A fresh annex for rows of dimensionality `dim`.
    pub fn new(dim: usize) -> AnnexWriter {
        AnnexWriter { dim, rows: Vec::new() }
    }

    /// Rows accumulated so far.
    pub fn n_rows(&self) -> usize {
        if self.dim == 0 {
            0
        } else {
            self.rows.len() / self.dim
        }
    }

    /// Append a row-major slice; returns its start row in the annex.
    pub fn push_slice(&mut self, rows: &[f32], dim: usize) -> Result<u64> {
        if dim != self.dim || dim == 0 || rows.len() % dim != 0 {
            return Err(OpdrError::shape(format!(
                "cold annex: pushing dim-{dim} rows into a dim-{} annex",
                self.dim
            )));
        }
        let start = self.n_rows() as u64;
        self.rows.extend_from_slice(rows);
        Ok(start)
    }

    /// Append every row of `block`; returns its start row in the annex.
    pub fn push_rows(&mut self, block: &RowBlock) -> Result<u64> {
        if block.dim() != self.dim || self.dim == 0 {
            return Err(OpdrError::shape(format!(
                "cold annex: pushing dim-{} rows into a dim-{} annex",
                block.dim(),
                self.dim
            )));
        }
        let start = self.n_rows() as u64;
        self.rows.reserve(block.n() * block.dim());
        for i in 0..block.n() {
            self.rows.extend_from_slice(block.row(i));
        }
        Ok(start)
    }
}

/// Load-time counterpart of [`AnnexWriter`]: resolves `u64` start-row
/// references inside a cold body back to windows of the file's annex.
#[derive(Debug, Clone)]
pub struct ColdContext {
    /// The open cold file whose annex the body references.
    pub file: Arc<VectorFile>,
}

// ---------------------------------------------------------------------------
// RowBlock: RAM-resident or tiered row storage.
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
enum RowBacking {
    Ram(Vec<f32>),
    Tiered { file: Arc<VectorFile>, start: usize },
}

/// Row-major `f32` rows, resident in RAM or served from a window of a
/// [`VectorFile`] annex. The index layer's vector payloads (flat stores,
/// PQ rerank tiers) hold one of these, so the same search code serves both
/// tiers — and equality / `matches` compare logical row content bitwise
/// regardless of backing.
#[derive(Debug, Clone)]
pub struct RowBlock {
    n: usize,
    dim: usize,
    backing: RowBacking,
}

impl RowBlock {
    /// RAM-resident rows.
    pub fn from_ram(dim: usize, data: Vec<f32>) -> Result<RowBlock> {
        if dim == 0 || data.len() % dim != 0 {
            return Err(OpdrError::shape("row block: bad shape"));
        }
        Ok(RowBlock { n: data.len() / dim, dim, backing: RowBacking::Ram(data) })
    }

    /// A window of `n` rows starting at `start` inside `file`'s annex.
    pub fn tiered(file: Arc<VectorFile>, start: usize, n: usize) -> Result<RowBlock> {
        let dim = file.dim();
        if dim == 0 {
            return Err(OpdrError::data("row block: cold file has an empty annex"));
        }
        let end = start
            .checked_add(n)
            .ok_or_else(|| OpdrError::data("row block: row range overflow"))?;
        if end > file.n() {
            return Err(OpdrError::data(format!(
                "row block: rows [{start}, {end}) outside the annex ({} rows)",
                file.n()
            )));
        }
        Ok(RowBlock { n, dim, backing: RowBacking::Tiered { file, start } })
    }

    /// Spill `data` into a fresh cold file under `dir` and serve it tiered
    /// (the `cold_tier = "mmap"` build path).
    pub fn spill(dir: &Path, data: &[f32], dim: usize) -> Result<RowBlock> {
        let file = Arc::new(VectorFile::spill(dir, data, dim)?);
        let n = file.n();
        RowBlock::tiered(file, 0, n)
    }

    /// Row count.
    pub fn n(&self) -> usize {
        self.n
    }

    /// True when no rows are held.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Row dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Row `id` (bounds-checked against this block's own window — a
    /// tiered block must never silently serve a neighboring block's rows
    /// from the shared annex).
    #[inline]
    pub fn row(&self, id: usize) -> &[f32] {
        assert!(id < self.n, "RowBlock::row: id {id} out of bounds (block holds {} rows)", self.n);
        match &self.backing {
            RowBacking::Ram(v) => &v[id * self.dim..(id + 1) * self.dim],
            RowBacking::Tiered { file, start } => file.row(start + id),
        }
    }

    /// Total logical bytes of the rows (resident + mapped).
    pub fn total_bytes(&self) -> usize {
        self.n * self.dim * std::mem::size_of::<f32>()
    }

    /// Bytes resident in RAM (0 for a mapped tier).
    pub fn resident_bytes(&self) -> usize {
        match &self.backing {
            RowBacking::Ram(_) => self.total_bytes(),
            RowBacking::Tiered { file, .. } => {
                if file.is_mapped() {
                    0
                } else {
                    self.total_bytes()
                }
            }
        }
    }

    /// Bytes served zero-copy from a mapping (0 when resident).
    pub fn mapped_bytes(&self) -> usize {
        self.total_bytes() - self.resident_bytes()
    }

    /// True when rows come from a mapped cold file.
    pub fn is_mapped(&self) -> bool {
        self.mapped_bytes() > 0
    }

    /// True when the held rows equal `other` bit-for-bit.
    pub fn matches(&self, other: &[f32]) -> bool {
        if other.len() != self.n * self.dim {
            return false;
        }
        (0..self.n).all(|i| {
            self.row(i)
                .iter()
                .zip(&other[i * self.dim..(i + 1) * self.dim])
                .all(|(a, b)| a.to_bits() == b.to_bits())
        })
    }

    /// Write every row as little-endian `f32`s (the inline serialization).
    pub fn write_f32s(&self, w: &mut dyn Write) -> Result<()> {
        for i in 0..self.n {
            io::write_f32s(w, self.row(i))?;
        }
        Ok(())
    }
}

impl PartialEq for RowBlock {
    fn eq(&self, other: &Self) -> bool {
        self.n == other.n && self.dim == other.dim && {
            (0..self.n).all(|i| {
                self.row(i).iter().zip(other.row(i)).all(|(a, b)| a.to_bits() == b.to_bits())
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("opdr_mapped_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn spill_roundtrips_rows_bitwise_mapped_and_heap() {
        let dir = tmp_dir("roundtrip");
        let dim = 6;
        let rows = Rng::new(7).normal_vec_f32(40 * dim);
        let path = dir.join("annex.opdr");
        write_cold_file(&path, &rows, dim).unwrap();
        let views = [
            (VectorFile::open(&path).unwrap(), false),
            (VectorFile::open_heap(&path).unwrap(), true),
        ];
        for (vf, forced_heap) in views {
            assert_eq!(vf.n(), 40);
            assert_eq!(vf.dim(), dim);
            if forced_heap {
                assert!(!vf.is_mapped());
                assert_eq!(vf.mapped_bytes(), 0);
                assert_eq!(vf.resident_bytes(), 40 * dim * 4);
            } else {
                // Mapped on capable hosts; the heap fallback is still
                // correct where mmap is unavailable.
                assert_eq!(vf.mapped_bytes() + vf.resident_bytes(), 40 * dim * 4);
            }
            for id in [0usize, 17, 39] {
                let got = vf.row(id);
                assert_eq!(got.len(), dim);
                for (a, b) in got.iter().zip(&rows[id * dim..(id + 1) * dim]) {
                    assert_eq!(a.to_bits(), b.to_bits(), "row {id}");
                }
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn spill_files_unlink_when_dropped() {
        let dir = tmp_dir("unlink");
        let rows = vec![1.0f32; 12];
        let block = RowBlock::spill(&dir, &rows, 4).unwrap();
        assert_eq!(block.n(), 3);
        assert!(block.matches(&rows));
        let files: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
        assert_eq!(files.len(), 1, "spill file exists while the block lives");
        drop(block);
        let files: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
        assert!(files.is_empty(), "spill file must be unlinked on drop");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn header_corruption_rejected() {
        let dir = tmp_dir("corrupt");
        let dim = 4;
        let rows = Rng::new(3).normal_vec_f32(8 * dim);
        let path = dir.join("annex.opdr");
        write_cold_file(&path, &rows, dim).unwrap();
        let good = std::fs::read(&path).unwrap();

        let reject = |bytes: &[u8], what: &str| {
            let bad = dir.join("bad.opdr");
            std::fs::write(&bad, bytes).unwrap();
            assert!(VectorFile::open(&bad).is_err(), "{what} accepted");
            assert!(VectorFile::open_heap(&bad).is_err(), "{what} accepted (heap)");
        };

        // Bad magic / version.
        let mut bad = good.clone();
        bad[0] = b'X';
        reject(&bad, "bad magic");
        let mut bad = good.clone();
        bad[4..8].copy_from_slice(&2u32.to_le_bytes());
        reject(&bad, "wrong version");
        // Length-prefix mismatch.
        let mut bad = good.clone();
        bad[32..40].copy_from_slice(&7u64.to_le_bytes());
        reject(&bad, "annex length prefix");
        // Misaligned annex offset.
        let mut bad = good.clone();
        bad[24..32].copy_from_slice(&65u64.to_le_bytes());
        reject(&bad, "misaligned offset");
        // Nonzero reserved bytes.
        let mut bad = good.clone();
        bad[55] = 1;
        reject(&bad, "reserved bytes");
        // Truncation and trailing bytes (the file-length prefix check).
        reject(&good[..good.len() - 3], "truncated annex");
        reject(&good[..HEADER_BYTES - 4], "truncated header");
        let mut bad = good.clone();
        bad.push(0xAB);
        reject(&bad, "trailing byte");
        // Absurd declared annex size fails the length check instead of
        // allocating (hostile-header hardening).
        let mut bad = good.clone();
        bad[8..16].copy_from_slice(&(u64::MAX / 8).to_le_bytes());
        reject(&bad, "absurd annex rows");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn row_bounds_checked() {
        let dir = tmp_dir("bounds");
        let rows = vec![0.5f32; 8];
        let block = RowBlock::spill(&dir, &rows, 4).unwrap();
        let caught =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| block.row(2).to_vec()));
        assert!(caught.is_err(), "out-of-bounds row must panic, not misread");
        // Tiered windows are range-validated at construction.
        let file = Arc::new(VectorFile::spill(&dir, &rows, 4).unwrap());
        assert!(RowBlock::tiered(Arc::clone(&file), 1, 2).is_err());
        assert!(RowBlock::tiered(file, 0, 2).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn row_block_equality_is_content_based_across_backings() {
        let dir = tmp_dir("eq");
        let dim = 3;
        let rows = Rng::new(11).normal_vec_f32(10 * dim);
        let ram = RowBlock::from_ram(dim, rows.clone()).unwrap();
        let tiered = RowBlock::spill(&dir, &rows, dim).unwrap();
        assert_eq!(ram, tiered);
        assert!(tiered.matches(&rows));
        let mut other = rows.clone();
        other[5] += 1.0;
        assert!(!tiered.matches(&other));
        // Inline serialization is identical from both backings.
        let (mut a, mut b) = (Vec::new(), Vec::new());
        ram.write_f32s(&mut a).unwrap();
        tiered.write_f32s(&mut b).unwrap();
        assert_eq!(a, b);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn annex_writer_tracks_starts_and_validates_dim() {
        let mut annex = AnnexWriter::new(4);
        assert_eq!(annex.push_slice(&[0.0; 8], 4).unwrap(), 0);
        assert_eq!(annex.push_slice(&[1.0; 4], 4).unwrap(), 2);
        assert_eq!(annex.n_rows(), 3);
        assert!(annex.push_slice(&[0.0; 6], 3).is_err());
        let block = RowBlock::from_ram(4, vec![2.0; 8]).unwrap();
        assert_eq!(annex.push_rows(&block).unwrap(), 3);
        assert_eq!(annex.n_rows(), 5);
    }
}
